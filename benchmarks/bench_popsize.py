"""Paper Fig. 9 / Table 5 analogue: which population size (K) first reaches
each target under K-Distributed — the evidence for running all K at once.

  PYTHONPATH=src python -m benchmarks.bench_popsize [--fids 1,8,15] [--dim 10]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core import ladder
from repro.fitness import bbob

TARGETS = np.array([1e2, 1e1, 1e0, 1e-1, 1e-2])


def first_descent_to_target(trace, f_opt):
    """For each target: log2 K of the first descent whose per-generation
    best crosses it (NaN if never)."""
    gen_best = trace["gen_best"]                  # (T, D)
    T, D = gen_best.shape
    best_per_descent = np.minimum.accumulate(gen_best, axis=0)
    out = np.full(len(TARGETS), np.nan)
    for i, tgt in enumerate(TARGETS):
        hit_gen = np.full(D, np.inf)
        for d in range(D):
            idx = np.nonzero(best_per_descent[:, d] - f_opt <= tgt)[0]
            if idx.size:
                hit_gen[d] = idx[0]
        if np.isfinite(hit_gen).any():
            out[i] = int(np.argmin(hit_gen))      # descent index == log2 K
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fids", default="1,8,15")
    ap.add_argument("--dim", type=int, default=10)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--runs", type=int, default=3)
    ap.add_argument("--gens", type=int, default=150)
    args = ap.parse_args(argv)
    fids = [int(f) for f in args.fids.split(",")]

    print("fid," + ",".join(f"log2K@{t:.0e}" for t in TARGETS))
    for fid in fids:
        inst = bbob.make_instance(fid, args.dim, 1)
        fit = lambda X: bbob.evaluate(fid, inst, X)
        f_opt = float(inst.f_opt)
        acc = []
        for r in range(args.runs):
            _, _, tr = ladder.run_concurrent(
                args.dim, args.devices, jax.random.PRNGKey(400 + r), fit,
                total_gens=args.gens)
            acc.append(first_descent_to_target(tr, f_opt))
        avg = np.nanmean(np.stack(acc), axis=0)
        cells = [f"{v:.1f}" if np.isfinite(v) else "—" for v in avg]
        print(f"{fid}," + ",".join(cells))
    return 0


if __name__ == "__main__":
    main()
