"""Campaign service vs sequential per-job runs on a streaming arrival trace.

The service exists so that independent jobs share the machine: admission at
segment boundaries keeps every island busy while jobs arrive, finish early
and leave — where a sequential per-job ``run_ipop`` driver serializes
head-of-line.  This benchmark plays one synthetic arrival trace (mixed dims,
budgets and fids over a couple of dim-classes) through both:

* **service** — all jobs stream through one ``CampaignServer`` (arrivals
  released by boundary count so the measurement is deterministic w.r.t. the
  schedule); per-job latency = submit→done wall time.
* **sequential** — jobs run one after another through
  ``run_ipop(backend="bucketed")`` in arrival order (the pre-service
  deployment); latency = queue wait + own run, against the same arrival
  clock.

Writes BENCH_service.json with useful-evals/s and p50/p95 job latency for
both (CI artifact; `run.py --smoke` runs the small config).  Wall times on
the CI container measure host/dispatch efficiency at identical work, not
hardware scaling — same caveat as BENCH_mesh.json.

  PYTHONPATH=src python -m benchmarks.bench_service [--jobs 8] [--dims 4,6]

``--soak`` switches to the sustained-load harness instead of the A/B: a
Poisson arrival trace of ``--soak-jobs`` (default: ``--jobs``) mixed jobs
streams through one long-lived server on ALL local devices (one island per
device per lane — under the CI mesh-8dev job this exercises 8 islands),
after a warm pass that populates the program cache (the steady state a real
service runs in).  The arrival loop is O(1) in host memory regardless of
the job count: specs are generated on the fly, finished tickets are
released every round, and the latency percentiles come from the
``service_time_to_completion_s`` histogram instead of a per-job list — so
``--soak --jobs 5000`` holds thousands of jobs at resident-set cost, and
the ``soak`` record's ``max_rss_mb`` proves it.  The section merged into
BENCH_service.json records p50/p95/p99 completion latency, sustained
useful-evals/s, max queue depth, rejected count and max-RSS;
``--slo-p99-s`` / ``--slo-min-evals-per-s`` turn it into an assertion
(exit 1 on violation — the CI soak-smoke gate), and ``--metrics-out`` tees
the per-round ``repro.obs`` series to a JSONL file (docs/METRICS.md walks
through reading one).

  PYTHONPATH=src python -m benchmarks.bench_service --soak \
      [--soak-jobs 24] [--arrive-every 1] [--slo-p99-s 60]

``--chaos`` is the fleet-supervision gate: the same deterministic job set
runs twice — fault-free, then under a ``FleetController`` with an injected
kill schedule (``--chaos-plan "island:boundary[:down_for],..."``, or a
schedule seeded from ``--seed``) and periodic snapshots — and the run
FAILS (exit 1) unless every job's final evals match exactly and best_f to
1e-12, and total recovery wall stays under ``--chaos-max-recovery-s``.
The ``chaos`` section records completed-evals/s under faults plus the
``fleet_*`` recovery accounting (failures, recovery modes, recovery wall,
lost work).

  PYTHONPATH=src python -m benchmarks.bench_service --chaos \
      [--chaos-plan 0:3:2] [--snapshot-every 2] [--chaos-max-recovery-s 60]

``--poison`` / ``--overload`` (combinable) are the request-lifecycle gate:
a protected set of healthy jobs streams through a fleet-supervised server
while the lifecycle machinery is attacked on the same lanes — ``--poison``
injects NaN-fitness jobs, a zero-headroom run deadline, a zero queue-TTL
and a mid-run cancel; ``--overload`` shrinks the admission queue and floods
it with low-priority jobs (priority sheds, backpressure rejects, and one
dedup-keyed resubmit per shed job).  The run FAILS (exit 1) unless every
submitted ticket reaches a terminal status, no island is ever graded dead,
every protected job finishes with evals exactly equal and best_f within
1e-12 of a fault-free reference, quarantines are exactly the injected
poison jobs, and compiles stay ≤ #buckets × #dim-classes.  The
``lifecycle`` section merged into the artifact records the terminal-status
census, lifecycle transition edges and shed/quarantine accounting.

  PYTHONPATH=src python -m benchmarks.bench_service --poison --overload \
      [--flood-jobs 12] [--snapshot-every 2]
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=8)
    ap.add_argument("--dims", default="4,6")
    ap.add_argument("--fids", default="1,8")
    ap.add_argument("--budget", type=int, default=4000)
    ap.add_argument("--lam-start", type=int, default=8)
    ap.add_argument("--kmax", type=int, default=2)
    ap.add_argument("--rows-per-island", type=int, default=4)
    ap.add_argument("--arrive-every", type=int, default=1,
                    help="one arrival per N service rounds")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_service.json")
    ap.add_argument("--soak", action="store_true",
                    help="run the sustained-load soak harness instead of "
                         "the service-vs-sequential A/B")
    ap.add_argument("--soak-jobs", type=int, default=None,
                    help="jobs in the soak arrival trace (default: --jobs)")
    ap.add_argument("--slo-p99-s", type=float, default=None,
                    help="assert soak p99 completion latency <= this")
    ap.add_argument("--slo-min-evals-per-s", type=float, default=None,
                    help="assert soak sustained useful-evals/s >= this")
    ap.add_argument("--metrics-out", default=None,
                    help="tee per-round obs metrics JSONL here (soak mode)")
    ap.add_argument("--chaos", action="store_true",
                    help="run the fault-injection recovery gate instead of "
                         "the service-vs-sequential A/B")
    ap.add_argument("--chaos-plan", default=None,
                    help="kill schedule 'island:boundary[:down_for],...' "
                         "(default: one kill seeded from --seed)")
    ap.add_argument("--snapshot-every", type=int, default=2,
                    help="fleet snapshot cadence in service rounds "
                         "(chaos mode)")
    ap.add_argument("--chaos-max-recovery-s", type=float, default=None,
                    help="assert total recovery wall <= this (chaos mode)")
    ap.add_argument("--poison", action="store_true",
                    help="lifecycle gate: inject NaN-fitness jobs, a "
                         "zero-headroom deadline, a zero queue-TTL and a "
                         "mid-run cancel alongside protected healthy jobs")
    ap.add_argument("--overload", action="store_true",
                    help="lifecycle gate: shrink the admission queue and "
                         "flood it with low-priority jobs (sheds + "
                         "backpressure + dedup resubmits)")
    ap.add_argument("--flood-jobs", type=int, default=12,
                    help="flood size for --overload")
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto-loadable trace_event JSON of the "
                         "measured pass here (raw spans beside it as .jsonl)")
    ap.add_argument("--postmortem-dir", default=None,
                    help="flight-recorder dump directory; in --chaos mode "
                         "the gate asserts one postmortem per injected kill")
    return ap


def _percentile(xs, q):
    import numpy as np
    return float(np.percentile(np.asarray(xs, float), q)) if xs else None


def _check_slo(soak: dict, p99_s, min_evals_per_s) -> list:
    """SLO violations of one soak record (empty = pass).  Pure so the CI
    gate's logic is unit-testable without a soak run."""
    out = []
    if p99_s is not None and soak["latency_p99_s"] > p99_s:
        out.append(f"p99 completion latency {soak['latency_p99_s']}s "
                   f"exceeds SLO {p99_s}s")
    if min_evals_per_s is not None and soak["evals_per_s"] < min_evals_per_s:
        out.append(f"sustained {soak['evals_per_s']} useful-evals/s below "
                   f"SLO {min_evals_per_s}")
    return out


def _max_rss_mb() -> float:
    import resource
    return round(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
                 / 1024.0, 1)               # ru_maxrss is KB on Linux


# span name -> soak phase (the wall breakdown is wholly derived from the
# trace; "queued" is a job's wait for admission, the rest are boundary work)
_PHASE_OF = {"queued": "admission", "dispatch": "dispatch",
             "pull": "pull", "retire": "retire"}


def _phase_walls(spans) -> dict:
    """Per-phase wall totals of one measured pass, from its spans."""
    out = {"admission": 0.0, "dispatch": 0.0, "pull": 0.0, "retire": 0.0}
    for s in spans:
        p = _PHASE_OF.get(s.name)
        if p is not None:
            out[p] += s.dur
    return {k: round(v, 4) for k, v in out.items()}


def _export_trace(args, violations=None):
    """Export the tracer to ``--trace-out`` (Chrome JSON + raw .jsonl);
    schema-validate the Chrome export, appending problems to
    ``violations``.  No-op without the flag."""
    if not args.trace_out:
        return None
    from repro import obs
    from repro.obs.trace import to_chrome, validate_chrome

    tr = obs.tracer()
    n = tr.export_chrome(args.trace_out)
    nj = tr.export_jsonl(args.trace_out + "l")
    problems = validate_chrome(to_chrome(tr.finished(),
                                         epoch_perf=tr.epoch_perf))
    if problems and violations is not None:
        violations.append(f"trace export failed schema validation: "
                          f"{problems[:3]}")
    print(f"[bench_service] wrote {args.trace_out} ({n} trace events; "
          f"{nj} spans in {args.trace_out}l)")
    return {"events": n, "spans": nj, "schema_errors": len(problems)}


def _run_soak(args):
    """The sustained-load harness: Poisson arrivals through one long-lived
    multi-island server; returns the BENCH_service.json ``soak`` record.

    O(1) host memory in the job count: arrivals are generated lazily,
    finished tickets are released every round (``server.release_ticket``),
    and percentiles come from the completion-latency histogram — nothing
    here holds a per-job list."""
    import jax
    import numpy as np

    from repro import obs
    from repro.service import (CampaignRequest, CampaignServer, QueueFull)

    n_jobs = args.soak_jobs if args.soak_jobs is not None else args.jobs
    rng = np.random.default_rng(args.seed)
    dims = [int(d) for d in args.dims.split(",")]
    fids = tuple(int(f) for f in args.fids.split(","))
    kw = dict(lam_start=args.lam_start, kmax_exp=args.kmax)
    max_budget = int(args.budget * 1.5)     # the draw's upper bound

    def job_stream():
        at = 0.0
        for _ in range(n_jobs):
            at += rng.exponential(scale=float(args.arrive_every))
            yield {"dim": int(rng.choice(dims)),
                   "fid": int(rng.choice(fids)),
                   "budget": int(args.budget * rng.uniform(0.5, 1.5)),
                   "seed": int(rng.integers(0, 2 ** 31)),
                   "arrive_round": int(at)}

    def make_server(metrics_out=None):
        return CampaignServer(bbob_fids=fids, max_budget=max_budget,
                              rows_per_island=args.rows_per_island,
                              devices=jax.devices(),
                              metrics_out=metrics_out, **kw)

    # warm pass: one job per dim class through an identically-configured
    # server traces every program into the module-level cache, so the
    # measured pass sees the long-lived service's steady state
    warm = make_server()
    for d in dims:
        warm.submit(CampaignRequest(dim=d, fid=fids[0], budget=max_budget))
    warm.drain()

    obs.reset_metrics()                     # measured pass owns the registry
    obs.reset_tracer()                      # ...and the span trace
    srv = make_server(metrics_out=args.metrics_out)
    t0 = time.perf_counter()
    stream = job_stream()
    nxt = next(stream, None)
    rnd = rejected = max_depth = completed = 0
    useful = 0
    while True:
        while nxt is not None and nxt["arrive_round"] <= rnd:
            try:
                srv.submit(CampaignRequest(
                    dim=nxt["dim"], fid=nxt["fid"],
                    budget=nxt["budget"], seed=nxt["seed"]))
                nxt = next(stream, None)
            except QueueFull:
                rejected += 1       # backpressure observed; retry next round
                break
        stats = srv.step()
        rnd += 1
        max_depth = max(max_depth, len(srv.queue))
        # release every terminal ticket (done or lifecycle-retired): host
        # state stays O(resident jobs)
        for t in [t for t in srv.tickets.values() if t.terminal]:
            if t.status == "done":
                completed += 1
                useful += t.fevals
            srv.release_ticket(t.job_id)
        if (not stats.progressed() and nxt is None
                and not len(srv.queue) and not srv._resident_jobs()):
            break
    wall = time.perf_counter() - t0
    lat = obs.metrics().histogram("service_time_to_completion_s")
    spans = obs.tracer().finished()
    # reconciliation surface: every job root span ends at exactly one
    # terminal lifecycle edge, so these two counts must agree (the trace↔
    # metrics test in tests/test_trace.py asserts it; recorded here so a
    # soak artifact carries its own cross-check)
    job_roots = sum(1 for s in spans if s.name == "job")
    edges = obs.metrics()
    terminal_edges = int(sum(
        s.value for (n, lkey), s in edges._series.items()
        if n == "service_job_lifecycle_total"
        and dict(lkey)["to"] in ("done", "rejected", "cancelled", "expired",
                                 "quarantined", "shed")))
    return {
        "jobs": n_jobs,
        "dims": dims, "fids": list(fids), "budget": args.budget,
        "n_devices": len(jax.devices()),
        "rounds": rnd,
        "wall_s": round(wall, 4),
        "useful_evals": int(useful),
        "evals_per_s": round(useful / max(wall, 1e-9), 1),
        "latency_p50_s": lat.quantile(0.50),
        "latency_p95_s": lat.quantile(0.95),
        "latency_p99_s": lat.quantile(0.99),
        "max_queue_depth": int(max_depth),
        "backpressure_rejects": int(rejected),
        "completed": completed,
        "max_rss_mb": _max_rss_mb(),
        "segment_compiles": srv.segment_compiles(),
        "lanes": len(srv.lanes),
        "phase_wall_s": _phase_walls(spans),
        "trace_spans": len(spans),
        "job_root_spans": job_roots,
        "terminal_lifecycle_edges": terminal_edges,
        "trace": _export_trace(args),
    }


def _run_chaos(args):
    """The recovery gate: one deterministic job set, run fault-free and
    then under an injected kill schedule with fleet supervision; returns
    ``(chaos_record, violations)``."""
    import tempfile

    import jax
    import numpy as np

    from repro import obs
    from repro.fleet import FaultPlan, FleetConfig
    from repro.fleet.controller import FleetController
    from repro.service import CampaignRequest, CampaignServer

    rng = np.random.default_rng(args.seed)
    dims = [int(d) for d in args.dims.split(",")]
    fids = tuple(int(f) for f in args.fids.split(","))
    kw = dict(lam_start=args.lam_start, kmax_exp=args.kmax)
    jobs = [{
        "dim": int(rng.choice(dims)),
        "fid": int(rng.choice(fids)),
        "budget": int(args.budget * rng.uniform(0.5, 1.5)),
        "seed": int(rng.integers(0, 2 ** 31)),
    } for _ in range(args.jobs)]
    max_budget = max(j["budget"] for j in jobs)
    n_islands = len(jax.devices())
    if args.chaos_plan:
        plan = FaultPlan.parse(args.chaos_plan)
    else:
        # single island has no survivor to host the rows: the kill must
        # come back (down_for) or recovery would park forever
        plan = FaultPlan.seeded(args.seed, n_islands, kills=1, horizon=6,
                                min_boundary=2,
                                down_for=3 if n_islands == 1 else 0)

    def run(supervised: bool):
        def submit_all(srv):
            return [srv.submit(CampaignRequest(
                dim=j["dim"], fid=j["fid"], budget=j["budget"],
                seed=j["seed"])) for j in jobs]
        if not supervised:
            srv = CampaignServer(bbob_fids=fids, max_budget=max_budget,
                                 rows_per_island=args.rows_per_island,
                                 devices=jax.devices(), **kw)
            tickets = submit_all(srv)
            srv.drain()
            return tickets, None
        with tempfile.TemporaryDirectory() as td:
            srv = CampaignServer(bbob_fids=fids, max_budget=max_budget,
                                 rows_per_island=args.rows_per_island,
                                 devices=jax.devices(), snapshot_dir=td,
                                 snapshot_every=args.snapshot_every, **kw)
            ctl = FleetController(srv, FleetConfig(
                snapshot_every=args.snapshot_every, plan=plan,
                postmortem_dir=args.postmortem_dir))
            tickets = submit_all(srv)
            t0 = time.perf_counter()
            ctl.drain()
            return tickets, time.perf_counter() - t0

    ref, _ = run(supervised=False)          # also the warm compile pass
    obs.reset_metrics()                     # chaos pass owns the registry
    obs.reset_tracer()                      # ...the span trace
    obs.reset_recorder()                    # ...and the flight recorder
    got, wall = run(supervised=True)

    divergences = []
    for tr, tg in zip(ref, got):
        if not tg.done:
            divergences.append(f"job {tg.job_id} did not complete")
            continue
        if tg.fevals != tr.fevals:
            divergences.append(f"job {tg.job_id} evals {tg.fevals} != "
                               f"fault-free {tr.fevals}")
        if not np.isclose(tg.best_f, tr.best_f, rtol=1e-12, atol=1e-12):
            divergences.append(f"job {tg.job_id} best_f {tg.best_f!r} != "
                               f"fault-free {tr.best_f!r} (rtol 1e-12)")

    reg = obs.metrics()
    rec_wall = reg.histogram("fleet_recovery_wall_s")
    lost = reg.histogram("fleet_lost_work_evals")

    def label_counts(name, label):
        return {dict(lkey)[label]: s.value
                for (n, lkey), s in reg._series.items() if n == name}

    # -- observability gates: every recovered job's trace must link across
    # the failure (recover event + a second running phase under the SAME
    # root), and every injected failure must have dumped a post-mortem
    # whose last-K timeline ends at the fault boundary itself ------------
    obs_gate = []
    spans = obs.tracer().finished()
    by_id = {s.span_id: s for s in spans}
    recovers = [s for s in spans if s.name == "recover" and "job" in s.attrs]
    linked = 0
    for s in recovers:
        root = by_id.get(s.parent_id)
        runs = [] if root is None else [
            c for c in spans
            if c.parent_id == root.span_id and c.name == "running"]
        if root is not None and root.name == "job" and len(runs) >= 2:
            linked += 1
        else:
            obs_gate.append(
                f"job {s.attrs.get('job')} recovery trace is not linked "
                f"to its pre-failure spans (parent chain broken)")
    n_failures = int(sum(
        label_counts("fleet_failures_total", "reason").values()))
    pm_files = []
    if args.postmortem_dir:
        import glob
        import os
        pm_files = sorted(glob.glob(os.path.join(
            args.postmortem_dir, "postmortem-*.json")))
        if len(pm_files) < n_failures:
            obs_gate.append(f"{len(pm_files)} postmortem artifacts for "
                            f"{n_failures} injected failures")
        for p in pm_files:
            with open(p) as fh:
                pm = json.load(fh)
            tl = pm.get("timeline", [])
            if not (tl and tl[-1].get("event") == "fault"
                    and tl[-1].get("boundary") == pm["boundary"]):
                obs_gate.append(
                    f"{os.path.basename(p)}: last-K timeline does not end "
                    f"at the injected fault boundary")
    trace_rec = _export_trace(args, obs_gate)

    useful = sum(t.fevals for t in got if t.status == "done")
    record = {
        "jobs": args.jobs, "dims": dims, "fids": list(fids),
        "n_devices": n_islands,
        "plan": [f"{e.island}:{e.boundary}:{e.down_for}"
                 for e in plan.events],
        "snapshot_every": args.snapshot_every,
        "wall_s": round(wall, 4),
        "useful_evals": int(useful),
        "evals_per_s": round(useful / max(wall, 1e-9), 1),
        "completed": sum(t.status == "done" for t in got),
        "failures": label_counts("fleet_failures_total", "reason"),
        "recoveries": label_counts("fleet_recoveries_total", "mode"),
        "recovery_wall_s_total": round(rec_wall.sum, 4),
        "recovery_events": rec_wall.count,
        "lost_work_evals_total": int(lost.sum),
        "divergences": divergences,
        "postmortems": [p.rsplit("/", 1)[-1] for p in pm_files],
        "recovered_trace_links": linked,
        "trace": trace_rec,
    }
    violations = list(divergences) + obs_gate
    if rec_wall.count == 0:
        violations.append("kill schedule injected no recovery "
                          "(plan never fired?)")
    if (args.chaos_max_recovery_s is not None
            and rec_wall.sum > args.chaos_max_recovery_s):
        violations.append(f"total recovery wall {rec_wall.sum:.3f}s exceeds "
                          f"bound {args.chaos_max_recovery_s}s")
    return record, violations


def _run_lifecycle(args):
    """The request-lifecycle gate (``--poison`` / ``--overload``): healthy
    protected jobs stream through a fleet-supervised server while poison
    jobs and/or an admission flood attack the same lanes; returns
    ``(lifecycle_record, violations)``."""
    import tempfile

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import obs
    from repro.fleet import FleetConfig
    from repro.fleet.controller import FleetController
    from repro.service import (CampaignRequest, CampaignServer,
                               FitnessRegistry, QueueFull)

    rng = np.random.default_rng(args.seed)
    dims = [int(d) for d in args.dims.split(",")]
    fids = tuple(int(f) for f in args.fids.split(","))
    kw = dict(lam_start=args.lam_start, kmax_exp=args.kmax)

    # protected set: these jobs must be untouched by everything below —
    # priority 5 outranks the flood, dedup keys make retries idempotent
    protected = [{
        "dim": int(rng.choice(dims)),
        "fid": int(rng.choice(fids)),
        "budget": int(args.budget * rng.uniform(0.5, 1.5)),
        "seed": int(rng.integers(0, 2 ** 31)),
        "priority": 5,
        "dedup_key": f"prot-{j}",
    } for j in range(args.jobs)]
    cancel_budget = args.budget * 2         # long enough to still be running
    max_budget = max(max(j["budget"] for j in protected),
                     cancel_budget, args.budget)

    def nan_fn(X):
        return jnp.full(X.shape[:-1], jnp.nan, X.dtype)

    def registry():
        reg = FitnessRegistry()
        reg.register("nan_fn", nan_fn)
        return reg

    def make_server(**extra):
        return CampaignServer(registry=registry(), bbob_fids=fids,
                              max_budget=max_budget,
                              rows_per_island=args.rows_per_island,
                              devices=jax.devices(), **kw, **extra)

    # fault-free reference: the protected set alone, unsupervised (also
    # the warm compile pass for its lanes)
    ref_srv = make_server()
    ref = [ref_srv.submit(CampaignRequest(**s)) for s in protected]
    ref_srv.drain()

    obs.reset_metrics()                     # measured pass owns the registry
    obs.reset_tracer()
    flood = [{
        "dim": int(rng.choice(dims)),
        "fid": int(rng.choice(fids)),
        "budget": int(args.budget * 0.5),
        "seed": int(rng.integers(0, 2 ** 31)),
        "priority": int(rng.integers(0, 3)),
        "dedup_key": f"flood-{j}",
    } for j in range(args.flood_jobs)] if args.overload else []

    with tempfile.TemporaryDirectory() as td:
        srv = make_server(snapshot_dir=td, snapshot_every=args.snapshot_every,
                          max_pending=4 if args.overload else 256)
        ctl = FleetController(srv, FleetConfig(
            snapshot_every=args.snapshot_every,
            postmortem_dir=args.postmortem_dir))
        t0 = time.perf_counter()
        pending_prot = list(protected)
        pending_poison, pending_flood = [], []
        prot, resubmitted, rejects = [], set(), 0
        nan_ids, t_cancel = [], None
        cancel_ok, cancel_rnd = None, None
        rnd = 0
        violations = []
        while True:
            while pending_prot:             # arrivals retry on backpressure
                try:
                    prot.append(srv.submit(CampaignRequest(**pending_prot[0])))
                    pending_prot.pop(0)
                except QueueFull:
                    rejects += 1
                    break
            if args.poison and rnd == 1:
                pending_poison = (
                    [("nan", {"dim": d, "fitness": "nan_fn",
                              "budget": args.budget, "seed": i,
                              "priority": 5})
                     for i, d in enumerate(dims)]
                    # expires in the queue / expires while running
                    + [("ttl", {"dim": dims[0], "fid": fids[0],
                                "budget": args.budget, "seed": 101,
                                "priority": 5, "queue_ttl_s": 0.0}),
                       ("deadline", {"dim": dims[0], "fid": fids[0],
                                     "budget": args.budget, "seed": 102,
                                     "priority": 5, "deadline_s": 1e-3}),
                       ("cancel", {"dim": dims[0], "fid": fids[0],
                                   "budget": cancel_budget, "seed": 103,
                                   "priority": 5})])
            while pending_poison:           # injections also retry
                kind, spec = pending_poison[0]
                try:
                    t = srv.submit(CampaignRequest(**spec))
                except QueueFull:
                    rejects += 1
                    break
                pending_poison.pop(0)
                if kind == "nan":
                    nan_ids.append(t.job_id)
                elif kind == "cancel":
                    t_cancel, cancel_rnd = t, rnd + 2
            if (cancel_rnd is not None and rnd >= cancel_rnd
                    and cancel_ok is None):
                cancel_ok = srv.cancel(t_cancel.job_id)
            if args.overload and rnd == 2:
                pending_flood = flood
                flood = []
            remaining = []
            for spec in pending_flood:
                try:
                    srv.submit(CampaignRequest(**spec))
                except QueueFull:
                    rejects += 1
                    remaining.append(spec)
            pending_flood = remaining
            # the resubmit contract: each shed flood job retries exactly
            # once with its original dedup key
            for t in list(srv.tickets.values()):
                k = t.request.dedup_key
                if (t.status == "shed" and k and k.startswith("flood-")
                        and k not in resubmitted):
                    resubmitted.add(k)
                    pending_flood.append({
                        "dim": t.request.dim, "fid": t.request.fid,
                        "budget": t.request.budget, "seed": t.request.seed,
                        "priority": t.request.priority, "dedup_key": k})
            stats = ctl.step()
            rnd += 1
            if rnd > 2000:
                violations.append("run did not terminate in 2000 rounds")
                break
            if (not stats.progressed() and not pending_prot
                    and not pending_poison and not pending_flood
                    and not len(srv.queue)
                    and not srv._resident_jobs() and not ctl._pending):
                break
        wall = time.perf_counter() - t0

        reg = obs.metrics()

        def label_counts(name, *labels):
            return {"|".join(dict(lkey)[l] for l in labels): s.value
                    for (n, lkey), s in reg._series.items() if n == name}

        statuses = {}
        for t in srv.tickets.values():
            statuses[t.status] = statuses.get(t.status, 0) + 1

        # -- the gates ------------------------------------------------------
        stuck = [t.job_id for t in srv.tickets.values() if not t.terminal]
        if stuck:
            violations.append(f"non-terminal tickets after drain: {stuck}")
        dead = [i for i in range(len(jax.devices()))
                if ctl.sup.health.state(i) != "alive"]
        if dead or label_counts("fleet_failures_total", "reason"):
            violations.append(
                f"lifecycle faults were graded as island faults: "
                f"dead={dead} "
                f"failures={label_counts('fleet_failures_total', 'reason')}")
        for tr, tg in zip(ref, prot):
            if tg.status != "done":
                violations.append(f"protected job {tg.job_id} ended "
                                  f"{tg.status!r}: {tg.reason}")
            elif tg.fevals != tr.fevals or not np.isclose(
                    tg.best_f, tr.best_f, rtol=1e-12, atol=1e-12):
                violations.append(
                    f"protected job {tg.job_id} diverged: evals "
                    f"{tg.fevals} vs {tr.fevals}, best_f {tg.best_f!r} "
                    f"vs {tr.best_f!r}")
        if args.poison:
            quarantined = [t for t in srv.tickets.values()
                           if t.status == "quarantined"]
            if sorted(t.job_id for t in quarantined) != sorted(nan_ids):
                violations.append(
                    f"quarantine set {[t.job_id for t in quarantined]} != "
                    f"injected poison jobs {nan_ids}")
            for t in quarantined:
                if "non-finite" not in t.reason or t.result is None:
                    violations.append(f"quarantined job {t.job_id} lacks "
                                      f"reason/partial result: {t.reason!r}")
            if statuses.get("expired", 0) < 2:
                violations.append("expected a queue-TTL and a run-deadline "
                                  f"expiry, saw {statuses.get('expired', 0)}")
            if cancel_ok is not True or t_cancel.status != "cancelled":
                violations.append(
                    f"mid-run cancel not honored (accepted={cancel_ok}, "
                    f"status={t_cancel.status if t_cancel else None})")
        if args.overload:
            if statuses.get("shed", 0) < 1:
                violations.append("overload produced no sheds")
            if not resubmitted:
                violations.append("no shed job exercised the dedup resubmit")
        n_buckets = args.kmax + 1
        if srv.segment_compiles() > n_buckets * len(srv.lanes):
            violations.append(
                f"compiles {srv.segment_compiles()} exceed bound "
                f"{n_buckets}*{len(srv.lanes)}")

        record = {
            "jobs": args.jobs, "dims": dims, "fids": list(fids),
            "poison": bool(args.poison), "overload": bool(args.overload),
            "flood_jobs": args.flood_jobs if args.overload else 0,
            "n_devices": len(jax.devices()),
            "rounds": rnd,
            "wall_s": round(wall, 4),
            "statuses": statuses,
            "lifecycle_edges": label_counts("service_job_lifecycle_total",
                                            "from", "to"),
            "quarantined": label_counts("service_quarantine_total",
                                        "reason"),
            "shed": statuses.get("shed", 0),
            "backpressure_rejects": int(rejects),
            "resubmits": len(resubmitted),
            "useful_evals": int(sum(t.fevals for t in srv.tickets.values()
                                    if t.status == "done")),
            "segment_compiles": srv.segment_compiles(),
            "lanes": len(srv.lanes),
            "protected_divergences": [v for v in violations
                                      if "protected job" in v],
        }
    return record, violations


def _merge_out(path: str, key: str, section: dict):
    """Merge one section into the (possibly existing) BENCH json so the A/B
    and soak results ride the same artifact file."""
    try:
        with open(path) as fh:
            out = json.load(fh)
    except (FileNotFoundError, json.JSONDecodeError):
        out = {}
    out[key] = section
    with open(path, "w") as fh:
        json.dump(out, fh, indent=2)
    return out


def main(argv=None):
    args = _parser().parse_args(argv)

    import jax

    jax.config.update("jax_enable_x64", True)

    if args.poison or args.overload:
        record, violations = _run_lifecycle(args)
        _merge_out(args.out, "lifecycle", record)
        print(json.dumps({"lifecycle": record}, indent=2))
        print(f"[bench_service] merged lifecycle results into {args.out}")
        for v in violations:
            print(f"[bench_service] LIFECYCLE GATE FAILURE: {v}",
                  file=sys.stderr)
        if not violations:
            print("[bench_service] lifecycle gate passed: every ticket "
                  "terminal, no island faulted, protected jobs exact")
        return 1 if violations else 0

    if args.chaos:
        record, violations = _run_chaos(args)
        _merge_out(args.out, "chaos", record)
        print(json.dumps({"chaos": record}, indent=2))
        print(f"[bench_service] merged chaos results into {args.out}")
        for v in violations:
            print(f"[bench_service] CHAOS GATE FAILURE: {v}",
                  file=sys.stderr)
        if not violations:
            print("[bench_service] chaos gate passed: recovery was "
                  "deterministic")
        return 1 if violations else 0

    if args.soak:
        soak = _run_soak(args)
        _merge_out(args.out, "soak", soak)
        print(json.dumps({"soak": soak}, indent=2))
        print(f"[bench_service] merged soak results into {args.out}")
        violations = _check_slo(soak, args.slo_p99_s,
                                args.slo_min_evals_per_s)
        for v in violations:
            print(f"[bench_service] SLO VIOLATION: {v}", file=sys.stderr)
        if not violations and (args.slo_p99_s is not None
                               or args.slo_min_evals_per_s is not None):
            print("[bench_service] SLO check passed")
        return 1 if violations else 0

    import numpy as np

    from repro.core.ipop import run_ipop
    from repro.fitness import bbob
    from repro.service import CampaignRequest, CampaignServer

    rng = np.random.default_rng(args.seed)
    dims = [int(d) for d in args.dims.split(",")]
    fids = tuple(int(f) for f in args.fids.split(","))
    jobs = [{
        "dim": int(rng.choice(dims)),
        "fid": int(rng.choice(fids)),
        "budget": int(args.budget * rng.uniform(0.5, 1.5)),
        "seed": int(rng.integers(0, 2 ** 31)),
        "arrive_round": j * args.arrive_every,
    } for j in range(args.jobs)]
    kw = dict(lam_start=args.lam_start, kmax_exp=args.kmax)
    max_budget = max(j["budget"] for j in jobs)

    def run_service():
        srv = CampaignServer(bbob_fids=fids, max_budget=max_budget,
                             rows_per_island=args.rows_per_island, **kw)
        t0 = time.perf_counter()
        pending, rnd = list(jobs), 0
        tickets = []
        while True:
            while pending and pending[0]["arrive_round"] <= rnd:
                spec = pending.pop(0)
                tickets.append(srv.submit(CampaignRequest(
                    dim=spec["dim"], fid=spec["fid"], budget=spec["budget"],
                    seed=spec["seed"])))
            stats = srv.step()
            rnd += 1
            if (not stats.progressed() and not pending
                    and not len(srv.queue) and not srv._resident_jobs()):
                break
        wall = time.perf_counter() - t0
        lats = [t.latency_s() for t in tickets]
        return srv, tickets, wall, lats

    # warm pass compiles every program; the measured pass reuses them (the
    # steady-state a long-lived service runs in)
    run_service()
    srv, tickets, wall_svc, lats_svc = run_service()
    useful_svc = sum(t.fevals for t in tickets)

    def run_sequential():
        # per-job standalone runs, arrival order, one at a time — latency is
        # wait-behind-the-queue + own wall, on the service run's round clock
        # mapped to arrival wall offsets (round r arrives when the service
        # admitted it, i.e. immediately for a sequential driver: use 0 —
        # conservative IN FAVOR of the baseline)
        t0 = time.perf_counter()
        lats, finish = [], 0.0
        useful = 0
        for spec in jobs:
            inst = bbob.make_instance(spec["fid"], spec["dim"], 1)
            fid = spec["fid"]
            fit = lambda X, inst=inst, fid=fid: bbob.evaluate(fid, inst, X)
            res = run_ipop(fit, spec["dim"], jax.random.PRNGKey(spec["seed"]),
                           backend="bucketed", max_evals=spec["budget"], **kw)
            finish = time.perf_counter() - t0
            lats.append(finish)                  # arrived at t=0, done at finish
            useful += res.total_fevals
        return time.perf_counter() - t0, lats, useful

    run_sequential()                             # warm compile pass
    wall_seq, lats_seq, useful_seq = run_sequential()

    out = {
        "config": {"jobs": args.jobs, "dims": dims, "fids": list(fids),
                   "budget": args.budget, **kw,
                   "rows_per_island": args.rows_per_island,
                   "arrive_every": args.arrive_every,
                   "note": "wall on shared-core CI CPUs measures host/"
                           "dispatch efficiency at identical work"},
        "service": {
            "wall_s": round(wall_svc, 4),
            "useful_evals": int(useful_svc),
            "evals_per_s": round(useful_svc / max(wall_svc, 1e-9), 1),
            "latency_p50_s": round(_percentile(lats_svc, 50), 4),
            "latency_p95_s": round(_percentile(lats_svc, 95), 4),
            "segment_compiles": srv.segment_compiles(),
            "lanes": len(srv.lanes),
        },
        "sequential": {
            "wall_s": round(wall_seq, 4),
            "useful_evals": int(useful_seq),
            "evals_per_s": round(useful_seq / max(wall_seq, 1e-9), 1),
            "latency_p50_s": round(_percentile(lats_seq, 50), 4),
            "latency_p95_s": round(_percentile(lats_seq, 95), 4),
        },
    }
    out["speedup"] = {
        "throughput": round(out["service"]["evals_per_s"]
                            / max(out["sequential"]["evals_per_s"], 1e-9), 3),
        "latency_p50": round(out["sequential"]["latency_p50_s"]
                             / max(out["service"]["latency_p50_s"], 1e-9), 3),
        "latency_p95": round(out["sequential"]["latency_p95_s"]
                             / max(out["service"]["latency_p95_s"], 1e-9), 3),
    }
    # merge (not overwrite) so a prior --soak section on the same artifact
    # file survives the A/B refresh and vice versa
    for k, v in out.items():
        _merge_out(args.out, k, v)
    print(json.dumps({"service": out["service"],
                      "sequential": out["sequential"],
                      "speedup": out["speedup"]}, indent=2))
    print(f"[bench_service] wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
