"""Campaign service vs sequential per-job runs on a streaming arrival trace.

The service exists so that independent jobs share the machine: admission at
segment boundaries keeps every island busy while jobs arrive, finish early
and leave — where a sequential per-job ``run_ipop`` driver serializes
head-of-line.  This benchmark plays one synthetic arrival trace (mixed dims,
budgets and fids over a couple of dim-classes) through both:

* **service** — all jobs stream through one ``CampaignServer`` (arrivals
  released by boundary count so the measurement is deterministic w.r.t. the
  schedule); per-job latency = submit→done wall time.
* **sequential** — jobs run one after another through
  ``run_ipop(backend="bucketed")`` in arrival order (the pre-service
  deployment); latency = queue wait + own run, against the same arrival
  clock.

Writes BENCH_service.json with useful-evals/s and p50/p95 job latency for
both (CI artifact; `run.py --smoke` runs the small config).  Wall times on
the CI container measure host/dispatch efficiency at identical work, not
hardware scaling — same caveat as BENCH_mesh.json.

  PYTHONPATH=src python -m benchmarks.bench_service [--jobs 8] [--dims 4,6]

``--soak`` switches to the sustained-load harness instead of the A/B: a
Poisson arrival trace of ``--soak-jobs`` mixed jobs streams through one
long-lived server on ALL local devices (one island per device per lane —
under the CI mesh-8dev job this exercises 8 islands), after a warm pass
that populates the program cache (the steady state a real service runs in).
The ``soak`` section merged into BENCH_service.json records p50/p95/p99
completion latency, sustained useful-evals/s, max queue depth and rejected
count; ``--slo-p99-s`` / ``--slo-min-evals-per-s`` turn it into an
assertion (exit 1 on violation — the CI soak-smoke gate), and
``--metrics-out`` tees the per-round ``repro.obs`` series to a JSONL file
(docs/METRICS.md walks through reading one).

  PYTHONPATH=src python -m benchmarks.bench_service --soak \
      [--soak-jobs 24] [--arrive-every 1] [--slo-p99-s 60]
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=8)
    ap.add_argument("--dims", default="4,6")
    ap.add_argument("--fids", default="1,8")
    ap.add_argument("--budget", type=int, default=4000)
    ap.add_argument("--lam-start", type=int, default=8)
    ap.add_argument("--kmax", type=int, default=2)
    ap.add_argument("--rows-per-island", type=int, default=4)
    ap.add_argument("--arrive-every", type=int, default=1,
                    help="one arrival per N service rounds")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_service.json")
    ap.add_argument("--soak", action="store_true",
                    help="run the sustained-load soak harness instead of "
                         "the service-vs-sequential A/B")
    ap.add_argument("--soak-jobs", type=int, default=24,
                    help="jobs in the soak arrival trace")
    ap.add_argument("--slo-p99-s", type=float, default=None,
                    help="assert soak p99 completion latency <= this")
    ap.add_argument("--slo-min-evals-per-s", type=float, default=None,
                    help="assert soak sustained useful-evals/s >= this")
    ap.add_argument("--metrics-out", default=None,
                    help="tee per-round obs metrics JSONL here (soak mode)")
    return ap


def _percentile(xs, q):
    import numpy as np
    return float(np.percentile(np.asarray(xs, float), q)) if xs else None


def _check_slo(soak: dict, p99_s, min_evals_per_s) -> list:
    """SLO violations of one soak record (empty = pass).  Pure so the CI
    gate's logic is unit-testable without a soak run."""
    out = []
    if p99_s is not None and soak["latency_p99_s"] > p99_s:
        out.append(f"p99 completion latency {soak['latency_p99_s']}s "
                   f"exceeds SLO {p99_s}s")
    if min_evals_per_s is not None and soak["evals_per_s"] < min_evals_per_s:
        out.append(f"sustained {soak['evals_per_s']} useful-evals/s below "
                   f"SLO {min_evals_per_s}")
    return out


def _run_soak(args):
    """The sustained-load harness: Poisson arrivals through one long-lived
    multi-island server; returns the BENCH_service.json ``soak`` record."""
    import jax
    import numpy as np

    from repro.service import (CampaignRequest, CampaignServer, QueueFull)

    rng = np.random.default_rng(args.seed)
    dims = [int(d) for d in args.dims.split(",")]
    fids = tuple(int(f) for f in args.fids.split(","))
    kw = dict(lam_start=args.lam_start, kmax_exp=args.kmax)
    gaps = rng.exponential(scale=float(args.arrive_every),
                           size=args.soak_jobs)
    arrive = np.floor(np.cumsum(gaps)).astype(int)
    jobs = [{
        "dim": int(rng.choice(dims)),
        "fid": int(rng.choice(fids)),
        "budget": int(args.budget * rng.uniform(0.5, 1.5)),
        "seed": int(rng.integers(0, 2 ** 31)),
        "arrive_round": int(arrive[j]),
    } for j in range(args.soak_jobs)]
    max_budget = max(j["budget"] for j in jobs)

    def make_server(metrics_out=None):
        return CampaignServer(bbob_fids=fids, max_budget=max_budget,
                              rows_per_island=args.rows_per_island,
                              devices=jax.devices(),
                              metrics_out=metrics_out, **kw)

    # warm pass: one job per dim class through an identically-configured
    # server traces every program into the module-level cache, so the
    # measured pass sees the long-lived service's steady state
    warm = make_server()
    for d in dims:
        warm.submit(CampaignRequest(dim=d, fid=fids[0], budget=max_budget))
    warm.drain()

    srv = make_server(metrics_out=args.metrics_out)
    t0 = time.perf_counter()
    pending, tickets = list(jobs), []
    rnd = rejected = max_depth = 0
    while True:
        while pending and pending[0]["arrive_round"] <= rnd:
            spec = pending[0]
            try:
                tickets.append(srv.submit(CampaignRequest(
                    dim=spec["dim"], fid=spec["fid"],
                    budget=spec["budget"], seed=spec["seed"])))
                pending.pop(0)
            except QueueFull:
                rejected += 1       # backpressure observed; retry next round
                break
        stats = srv.step()
        rnd += 1
        max_depth = max(max_depth, len(srv.queue))
        if (not stats.progressed() and not pending
                and not len(srv.queue) and not srv._resident_jobs()):
            break
    wall = time.perf_counter() - t0
    lats = [t.latency_s() for t in tickets if t.latency_s() is not None]
    useful = sum(t.fevals for t in tickets if t.done)
    return {
        "jobs": args.soak_jobs,
        "dims": dims, "fids": list(fids), "budget": args.budget,
        "n_devices": len(jax.devices()),
        "rounds": rnd,
        "wall_s": round(wall, 4),
        "useful_evals": int(useful),
        "evals_per_s": round(useful / max(wall, 1e-9), 1),
        "latency_p50_s": round(_percentile(lats, 50), 4),
        "latency_p95_s": round(_percentile(lats, 95), 4),
        "latency_p99_s": round(_percentile(lats, 99), 4),
        "max_queue_depth": int(max_depth),
        "backpressure_rejects": int(rejected),
        "completed": sum(t.done for t in tickets),
        "segment_compiles": srv.segment_compiles(),
        "lanes": len(srv.lanes),
    }


def _merge_out(path: str, key: str, section: dict):
    """Merge one section into the (possibly existing) BENCH json so the A/B
    and soak results ride the same artifact file."""
    try:
        with open(path) as fh:
            out = json.load(fh)
    except (FileNotFoundError, json.JSONDecodeError):
        out = {}
    out[key] = section
    with open(path, "w") as fh:
        json.dump(out, fh, indent=2)
    return out


def main(argv=None):
    args = _parser().parse_args(argv)

    import jax

    jax.config.update("jax_enable_x64", True)

    if args.soak:
        soak = _run_soak(args)
        _merge_out(args.out, "soak", soak)
        print(json.dumps({"soak": soak}, indent=2))
        print(f"[bench_service] merged soak results into {args.out}")
        violations = _check_slo(soak, args.slo_p99_s,
                                args.slo_min_evals_per_s)
        for v in violations:
            print(f"[bench_service] SLO VIOLATION: {v}", file=sys.stderr)
        if not violations and (args.slo_p99_s is not None
                               or args.slo_min_evals_per_s is not None):
            print("[bench_service] SLO check passed")
        return 1 if violations else 0

    import numpy as np

    from repro.core.ipop import run_ipop
    from repro.fitness import bbob
    from repro.service import CampaignRequest, CampaignServer

    rng = np.random.default_rng(args.seed)
    dims = [int(d) for d in args.dims.split(",")]
    fids = tuple(int(f) for f in args.fids.split(","))
    jobs = [{
        "dim": int(rng.choice(dims)),
        "fid": int(rng.choice(fids)),
        "budget": int(args.budget * rng.uniform(0.5, 1.5)),
        "seed": int(rng.integers(0, 2 ** 31)),
        "arrive_round": j * args.arrive_every,
    } for j in range(args.jobs)]
    kw = dict(lam_start=args.lam_start, kmax_exp=args.kmax)
    max_budget = max(j["budget"] for j in jobs)

    def run_service():
        srv = CampaignServer(bbob_fids=fids, max_budget=max_budget,
                             rows_per_island=args.rows_per_island, **kw)
        t0 = time.perf_counter()
        pending, rnd = list(jobs), 0
        tickets = []
        while True:
            while pending and pending[0]["arrive_round"] <= rnd:
                spec = pending.pop(0)
                tickets.append(srv.submit(CampaignRequest(
                    dim=spec["dim"], fid=spec["fid"], budget=spec["budget"],
                    seed=spec["seed"])))
            stats = srv.step()
            rnd += 1
            if (not stats.progressed() and not pending
                    and not len(srv.queue) and not srv._resident_jobs()):
                break
        wall = time.perf_counter() - t0
        lats = [t.latency_s() for t in tickets]
        return srv, tickets, wall, lats

    # warm pass compiles every program; the measured pass reuses them (the
    # steady-state a long-lived service runs in)
    run_service()
    srv, tickets, wall_svc, lats_svc = run_service()
    useful_svc = sum(t.fevals for t in tickets)

    def run_sequential():
        # per-job standalone runs, arrival order, one at a time — latency is
        # wait-behind-the-queue + own wall, on the service run's round clock
        # mapped to arrival wall offsets (round r arrives when the service
        # admitted it, i.e. immediately for a sequential driver: use 0 —
        # conservative IN FAVOR of the baseline)
        t0 = time.perf_counter()
        lats, finish = [], 0.0
        useful = 0
        for spec in jobs:
            inst = bbob.make_instance(spec["fid"], spec["dim"], 1)
            fid = spec["fid"]
            fit = lambda X, inst=inst, fid=fid: bbob.evaluate(fid, inst, X)
            res = run_ipop(fit, spec["dim"], jax.random.PRNGKey(spec["seed"]),
                           backend="bucketed", max_evals=spec["budget"], **kw)
            finish = time.perf_counter() - t0
            lats.append(finish)                  # arrived at t=0, done at finish
            useful += res.total_fevals
        return time.perf_counter() - t0, lats, useful

    run_sequential()                             # warm compile pass
    wall_seq, lats_seq, useful_seq = run_sequential()

    out = {
        "config": {"jobs": args.jobs, "dims": dims, "fids": list(fids),
                   "budget": args.budget, **kw,
                   "rows_per_island": args.rows_per_island,
                   "arrive_every": args.arrive_every,
                   "note": "wall on shared-core CI CPUs measures host/"
                           "dispatch efficiency at identical work"},
        "service": {
            "wall_s": round(wall_svc, 4),
            "useful_evals": int(useful_svc),
            "evals_per_s": round(useful_svc / max(wall_svc, 1e-9), 1),
            "latency_p50_s": round(_percentile(lats_svc, 50), 4),
            "latency_p95_s": round(_percentile(lats_svc, 95), 4),
            "segment_compiles": srv.segment_compiles(),
            "lanes": len(srv.lanes),
        },
        "sequential": {
            "wall_s": round(wall_seq, 4),
            "useful_evals": int(useful_seq),
            "evals_per_s": round(useful_seq / max(wall_seq, 1e-9), 1),
            "latency_p50_s": round(_percentile(lats_seq, 50), 4),
            "latency_p95_s": round(_percentile(lats_seq, 95), 4),
        },
    }
    out["speedup"] = {
        "throughput": round(out["service"]["evals_per_s"]
                            / max(out["sequential"]["evals_per_s"], 1e-9), 3),
        "latency_p50": round(out["sequential"]["latency_p50_s"]
                             / max(out["service"]["latency_p50_s"], 1e-9), 3),
        "latency_p95": round(out["sequential"]["latency_p95_s"]
                             / max(out["service"]["latency_p95_s"], 1e-9), 3),
    }
    # merge (not overwrite) so a prior --soak section on the same artifact
    # file survives the A/B refresh and vice versa
    for k, v in out.items():
        _merge_out(args.out, k, v)
    print(json.dumps({"service": out["service"],
                      "sequential": out["sequential"],
                      "speedup": out["speedup"]}, indent=2))
    print(f"[bench_service] wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
