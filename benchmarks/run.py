"""Benchmark aggregator — one section per paper table/figure + the roofline.

  PYTHONPATH=src python -m benchmarks.run [--full]

Default sizes are CI-scale (single CPU core); --full widens dims/functions
to the paper's ranges (hours on this container, intended for real hardware).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)


def section(title):
    print(f"\n=== {title} " + "=" * max(1, 60 - len(title)), flush=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    t0 = time.time()

    from benchmarks import (bench_comm_share, bench_ecdf, bench_linalg,
                            bench_popsize, bench_strategies, roofline)

    section("Fig.5/Table 1 — BLAS/GEMM linear-algebra rewrites")
    if args.full:
        bench_linalg.main(["--dims", "10,40,200,1000", "--ks", "1,256"])
    else:
        bench_linalg.main(["--dims", "10,40,200", "--ks", "1,16",
                           "--reps", "3"])

    section("Table 2 — strategy speedups over sequential IPOP (ERT model)")
    if args.full:
        bench_strategies.main(["--fids", "1,2,8,10,15,20", "--dim", "40",
                               "--devices", "512", "--cost-ms", "10",
                               "--runs", "5", "--gens", "400"])
    else:
        bench_strategies.main(["--fids", "1,8", "--dim", "10",
                               "--devices", "8", "--cost-ms", "1",
                               "--runs", "2", "--gens", "100",
                               "--max-evals", "25000"])

    section("Fig.8/Table 4 — ECDF over (function,target,run)")
    if args.full:
        bench_ecdf.main(["--fids", "1,2,8,10,15,20", "--dim", "40",
                         "--devices", "512", "--runs", "5"])
    else:
        bench_ecdf.main(["--fids", "1,8", "--dim", "10", "--devices", "8",
                         "--runs", "2", "--gens", "100",
                         "--max-evals", "25000"])

    section("Fig.9/Table 5 — best population size per (function,target)")
    if args.full:
        bench_popsize.main(["--fids", "1,7,8,15,17", "--dim", "40",
                            "--devices", "512", "--runs", "5",
                            "--gens", "400"])
    else:
        bench_popsize.main(["--fids", "1,8", "--dim", "10",
                            "--devices", "8", "--runs", "2",
                            "--gens", "100"])

    section("Fig.6 — comm/linalg share vs evaluation cost (CMA gen step)")
    bench_comm_share.main([])

    section("Roofline — single-pod baselines (from dry-run artifacts)")
    roofline.main(["--mesh", "pod"])

    section("Roofline — single-pod OPTIMIZED (flash + rowwise, §Perf)")
    roofline.main(["--mesh", "pod_opt"])

    section("Roofline — multi-pod (if artifacts present)")
    roofline.main(["--mesh", "multipod"])

    print(f"\n[benchmarks.run] total {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
