"""Benchmark aggregator — one section per paper table/figure + the roofline.

  PYTHONPATH=src python -m benchmarks.run [--full | --smoke]

Default sizes are CI-scale (single CPU core); --full widens dims/functions
to the paper's ranges (hours on this container, intended for real hardware).
--smoke runs the engine/kernel benchmarks only (a few minutes) and writes
the BENCH_kernels/BENCH_ladder/BENCH_bucketed/BENCH_mesh/BENCH_service
JSON artifacts for CI.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# allow `python benchmarks/run.py` without an editable install
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)


def section(title):
    print(f"\n=== {title} " + "=" * max(1, 60 - len(title)), flush=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="ladder bench only; writes BENCH_ladder.json")
    args = ap.parse_args(argv)
    t0 = time.time()

    if args.smoke:
        from benchmarks import (bench_kernels, bench_ladder, bench_mesh,
                                bench_service)
        section("Smoke — fused generation kernels vs PR-3 unfused op soup")
        # also writes the PR-7 residency A/B cells: sample_rng (in-kernel
        # counter stream vs host fold_in), resident_full_step_f1/f2
        # (eval-fused sample epilogue vs dispatched sample→eval chain) and
        # strategies_gram (KDistributed fused gram-family psum vs the PR-6
        # moments psum)
        bench_kernels.main(["--dims", "64,256,1024", "--gens", "40",
                            "--reps", "5", "--out", "BENCH_kernels.json"])
        section("Smoke — host-loop IPOP vs device-resident ladder")
        bench_ladder.main(["--dim", "6", "--fids", "1,8", "--runs", "2",
                           "--lam-start", "8", "--kmax", "2",
                           "--max-evals", "6000", "--out",
                           "BENCH_ladder.json"])
        section("Smoke — work-proportional campaigns (buckets + eigen blocks)")
        bench_ladder.main_bucketed(["--dim", "32", "--fids", "1,8",
                                    "--runs", "2", "--lam-start", "8",
                                    "--kmax", "4", "--max-evals", "20000",
                                    "--eigen-interval", "5", "--out",
                                    "BENCH_bucketed.json"])
        section("Smoke — mesh campaign engine, S1/S2 on 1→8 virtual devices")
        # re-execs itself in a subprocess with the 8-device XLA flag, so this
        # process keeps its single-device jax state
        bench_mesh.main(["--devices", "8", "--dim", "8", "--fids", "1,8",
                         "--runs", "4", "--lam-start", "8", "--kmax", "2",
                         "--max-evals", "6000", "--eigen-interval", "3",
                         "--out", "BENCH_mesh.json"])
        section("Smoke — campaign service vs sequential per-job runs")
        bench_service.main(["--jobs", "6", "--dims", "4,6", "--fids", "1,8",
                            "--budget", "3000", "--lam-start", "8",
                            "--kmax", "2", "--out", "BENCH_service.json"])
        section("Smoke — service soak (sustained load, SLO-gated)")
        # merges a `soak` section into the same BENCH_service.json artifact;
        # the generous p99 bound is an is-it-alive gate on CI CPUs, not a
        # hardware claim
        rc = bench_service.main(["--soak", "--soak-jobs", "8",
                                 "--dims", "4,6", "--fids", "1,8",
                                 "--budget", "2000", "--lam-start", "8",
                                 "--kmax", "2", "--slo-p99-s", "300",
                                 "--out", "BENCH_service.json"])
        if rc:
            return rc
        print(f"\n[benchmarks.run] total {time.time() - t0:.1f}s")
        return 0

    from benchmarks import (bench_comm_share, bench_ecdf, bench_ladder,
                            bench_linalg, bench_popsize, bench_strategies,
                            roofline)

    section("Ladder engine — host-loop vs device-resident (BENCH_ladder.json)")
    if args.full:
        bench_ladder.main(["--dim", "40", "--fids", "1,8,15", "--runs", "3",
                           "--lam-start", "12", "--kmax", "4",
                           "--max-evals", "60000"])
    else:
        bench_ladder.main([])

    section("Fig.5/Table 1 — BLAS/GEMM linear-algebra rewrites")
    if args.full:
        bench_linalg.main(["--dims", "10,40,200,1000", "--ks", "1,256"])
    else:
        bench_linalg.main(["--dims", "10,40,200", "--ks", "1,16",
                           "--reps", "3"])

    section("Table 2 — strategy speedups over sequential IPOP (ERT model)")
    if args.full:
        bench_strategies.main(["--fids", "1,2,8,10,15,20", "--dim", "40",
                               "--devices", "512", "--cost-ms", "10",
                               "--runs", "5", "--gens", "400"])
    else:
        bench_strategies.main(["--fids", "1,8", "--dim", "10",
                               "--devices", "8", "--cost-ms", "1",
                               "--runs", "2", "--gens", "100",
                               "--max-evals", "25000"])

    section("Fig.8/Table 4 — ECDF over (function,target,run)")
    if args.full:
        bench_ecdf.main(["--fids", "1,2,8,10,15,20", "--dim", "40",
                         "--devices", "512", "--runs", "5"])
    else:
        bench_ecdf.main(["--fids", "1,8", "--dim", "10", "--devices", "8",
                         "--runs", "2", "--gens", "100",
                         "--max-evals", "25000"])

    section("Fig.9/Table 5 — best population size per (function,target)")
    if args.full:
        bench_popsize.main(["--fids", "1,7,8,15,17", "--dim", "40",
                            "--devices", "512", "--runs", "5",
                            "--gens", "400"])
    else:
        bench_popsize.main(["--fids", "1,8", "--dim", "10",
                            "--devices", "8", "--runs", "2",
                            "--gens", "100"])

    section("Fig.6 — comm/linalg share vs evaluation cost (CMA gen step)")
    bench_comm_share.main([])

    section("Roofline — single-pod baselines (from dry-run artifacts)")
    roofline.main(["--mesh", "pod"])

    section("Roofline — single-pod OPTIMIZED (flash + rowwise, §Perf)")
    roofline.main(["--mesh", "pod_opt"])

    section("Roofline — multi-pod (if artifacts present)")
    roofline.main(["--mesh", "multipod"])

    print(f"\n[benchmarks.run] total {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
