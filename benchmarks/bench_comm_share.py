"""Paper Fig. 6 analogue: communication/linalg share of a generation vs
evaluation cost, from the CMA dry-run artifact + the parallel-time model.

The paper profiles a K=2⁸ descent on 256 MPI processes and shows MPI share
collapsing as per-evaluation cost grows from 0 to 100 ms.  Here the ES-side
costs come from the compiled artifact (collective + linalg time at hardware
bandwidth) and the evaluation term is swept analytically.

  PYTHONPATH=src python -m benchmarks.bench_comm_share
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from benchmarks.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, ARTIFACT_DIR

COSTS_MS = (0.0, 0.009, 1.0, 10.0, 100.0)   # paper: BBOB native ≈ ≤9ms @ d1000


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifact", default=None)
    args = ap.parse_args(argv)
    path = args.artifact
    if path is None:
        cands = sorted(glob.glob(os.path.join(ARTIFACT_DIR, "cma__*__pod.json")))
        if not cands:
            print("no CMA artifact — run: python -m repro.launch.dryrun --cma")
            return 1
        path = cands[0]
    with open(path) as f:
        m = json.load(f)
    t_comm = m["collective_bytes"]["total"] / ICI_BW
    t_es = max(m["flops"] / PEAK_FLOPS, m["bytes_accessed"] / HBM_BW)
    print(f"# per-generation ES overhead from {os.path.basename(path)}: "
          f"linalg/memory {t_es * 1e6:.1f}µs, collectives {t_comm * 1e6:.2f}µs")
    print("eval_cost_ms,comm_share,linalg_share,eval_share")
    for c in COSTS_MS:
        t_eval = c * 1e-3          # one eval per core per generation round
        tot = t_eval + t_es + t_comm
        print(f"{c},{t_comm / tot:.4f},{t_es / tot:.4f},{t_eval / tot:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
