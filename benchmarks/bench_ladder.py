"""Host-loop vs device-resident ladder: wall-time and evals/sec.

Runs the same (function, run) members once through the legacy host-driven
chunked IPOP loop (per-descent dispatch, host-side early exit) and once as a
single jitted/vmapped ladder campaign, and writes ``BENCH_ladder.json`` so
the perf trajectory of the ladder engine is recorded per commit.

  PYTHONPATH=src python -m benchmarks.bench_ladder [--dim 10] [--fids 1,8]
"""
from __future__ import annotations

import argparse
import json
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.core import ladder  # noqa: E402
from repro.core.ipop import run_ipop_hostloop  # noqa: E402
from repro.fitness import bbob  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=10)
    ap.add_argument("--fids", default="1,8")
    ap.add_argument("--runs", type=int, default=2)
    ap.add_argument("--lam-start", type=int, default=8)
    ap.add_argument("--kmax", type=int, default=3)
    ap.add_argument("--max-evals", type=int, default=12_000)
    ap.add_argument("--out", default="BENCH_ladder.json")
    args = ap.parse_args(argv)
    fids = [int(f) for f in args.fids.split(",")]
    members = [(fid, r) for fid in fids for r in range(args.runs)]

    # -- host-loop baseline: one python-driven ladder per member --------------
    t0 = time.perf_counter()
    host_evals, host_best = 0, []
    for j, (fid, _r) in enumerate(members):
        inst = bbob.make_instance(fid, args.dim, 1)
        fit = lambda X: bbob.evaluate(fid, inst, X)  # noqa: B023
        res = run_ipop_hostloop(
            fit, args.dim, jax.random.fold_in(jax.random.PRNGKey(0), j),
            lam_start=args.lam_start, kmax_exp=args.kmax,
            max_evals=args.max_evals)
        host_evals += res.total_fevals
        host_best.append(res.best_f)
    host_wall = time.perf_counter() - t0

    # -- device-resident ladder: ONE program for the whole campaign ----------
    engine = ladder.LadderEngine(
        n=args.dim, lam_start=args.lam_start, kmax_exp=args.kmax,
        schedule="sequential", max_evals=args.max_evals)
    t0 = time.perf_counter()
    res1 = ladder.run_campaign(engine, fids=fids, instances=(1,),
                               runs=args.runs, seed=0)
    jax.block_until_ready(res1.best_f)
    first_wall = time.perf_counter() - t0          # includes the one compile
    t0 = time.perf_counter()
    res2 = ladder.run_campaign(engine, fids=fids, instances=(1,),
                               runs=args.runs, seed=1)
    jax.block_until_ready(res2.best_f)
    steady_wall = time.perf_counter() - t0         # cached executable
    ladder_evals = int(np.sum(res2.total_fevals))

    out = {
        "config": {
            "dim": args.dim, "fids": fids, "runs": args.runs,
            "lam_start": args.lam_start, "kmax_exp": args.kmax,
            "max_evals": args.max_evals, "lam_max": engine.lam_max,
            "members": len(members),
            "note": "evals/sec counts useful (unpadded) evaluations; the "
                    "ladder additionally pays lam_max padding on device",
        },
        "host_loop": {
            "wall_s": round(host_wall, 4),
            "evals": int(host_evals),
            "evals_per_s": round(host_evals / max(host_wall, 1e-9), 1),
        },
        "ladder": {
            "first_call_wall_s": round(first_wall, 4),
            "wall_s": round(steady_wall, 4),
            "evals": ladder_evals,
            "evals_per_s": round(ladder_evals / max(steady_wall, 1e-9), 1),
            "compiles": res2.compiles,
        },
        "speedup_steady": round(
            (ladder_evals / max(steady_wall, 1e-9))
            / max(host_evals / max(host_wall, 1e-9), 1e-9), 3),
    }
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)
    print(json.dumps(out, indent=2))
    print(f"[bench_ladder] wrote {args.out}")
    return out


if __name__ == "__main__":
    main()
