"""Ladder-engine benchmarks: host loop vs padded scan vs rung buckets.

Two sections, two artifacts:

* ``main`` (``BENCH_ladder.json``) — the PR-1 comparison: legacy host-driven
  chunked IPOP loop vs the device-resident λ_max-padded ladder campaign,
  now also reporting the padded engine's per-rung padding waste.
* ``main_bucketed`` (``BENCH_bucketed.json``) — the work-proportional
  comparison on a config where padding actually bites (kmax_exp=4 → 16×
  λ padding, eigen_interval>1): PR-1's flat scan (whose ``lax.cond`` eigen
  laziness vmap silently defeats) vs this PR's nested-scan padded engine vs
  the rung-bucketed segment driver under both scheduling policies, with
  per-bucket steady-state timings and padded-vs-useful accounting.

  PYTHONPATH=src python -m benchmarks.bench_ladder [--dim 10] [--fids 1,8]
  PYTHONPATH=src python -m benchmarks.bench_ladder --bucketed [--dim 32]
"""
from __future__ import annotations

import argparse
import json
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.core import bucketed, ladder  # noqa: E402
from repro.core.ipop import run_ipop_hostloop  # noqa: E402
from repro.fitness import bbob  # noqa: E402


def _timed_campaign(engine, fids, runs, seed):
    t0 = time.perf_counter()
    res = ladder.run_campaign(engine, fids=fids, instances=(1,), runs=runs,
                              seed=seed)
    jax.block_until_ready(res.best_f)
    return res, time.perf_counter() - t0


def _timed_bucketed(engine, fids, runs, seed):
    t0 = time.perf_counter()
    res = bucketed.run_campaign_bucketed(engine, fids=fids, instances=(1,),
                                         runs=runs, seed=seed)
    jax.block_until_ready(res.best_f)
    return res, time.perf_counter() - t0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=10)
    ap.add_argument("--fids", default="1,8")
    ap.add_argument("--runs", type=int, default=2)
    ap.add_argument("--lam-start", type=int, default=8)
    ap.add_argument("--kmax", type=int, default=3)
    ap.add_argument("--max-evals", type=int, default=12_000)
    ap.add_argument("--out", default="BENCH_ladder.json")
    args = ap.parse_args(argv)
    fids = [int(f) for f in args.fids.split(",")]
    members = [(fid, r) for fid in fids for r in range(args.runs)]

    # -- host-loop baseline: one python-driven ladder per member --------------
    t0 = time.perf_counter()
    host_evals, host_best = 0, []
    for j, (fid, _r) in enumerate(members):
        inst = bbob.make_instance(fid, args.dim, 1)
        fit = lambda X: bbob.evaluate(fid, inst, X)  # noqa: B023
        res = run_ipop_hostloop(
            fit, args.dim, jax.random.fold_in(jax.random.PRNGKey(0), j),
            lam_start=args.lam_start, kmax_exp=args.kmax,
            max_evals=args.max_evals)
        host_evals += res.total_fevals
        host_best.append(res.best_f)
    host_wall = time.perf_counter() - t0

    # -- device-resident ladder: ONE program for the whole campaign ----------
    engine = ladder.LadderEngine(
        n=args.dim, lam_start=args.lam_start, kmax_exp=args.kmax,
        schedule="sequential", max_evals=args.max_evals)
    res1, first_wall = _timed_campaign(engine, fids, args.runs, 0)
    res2, steady_wall = _timed_campaign(engine, fids, args.runs, 1)
    ladder_evals = int(np.sum(res2.total_fevals))

    out = {
        "config": {
            "dim": args.dim, "fids": fids, "runs": args.runs,
            "lam_start": args.lam_start, "kmax_exp": args.kmax,
            "max_evals": args.max_evals, "lam_max": engine.lam_max,
            "members": len(members),
            "note": "evals/sec counts useful (unpadded) evaluations; the "
                    "ladder additionally pays lam_max padding on device — "
                    "see BENCH_bucketed.json for the work-proportional "
                    "engines",
        },
        "host_loop": {
            "wall_s": round(host_wall, 4),
            "evals": int(host_evals),
            "evals_per_s": round(host_evals / max(host_wall, 1e-9), 1),
        },
        "ladder": {
            "first_call_wall_s": round(first_wall, 4),
            "wall_s": round(steady_wall, 4),
            "evals": ladder_evals,
            "evals_per_s": round(ladder_evals / max(steady_wall, 1e-9), 1),
            "compiles": res2.compiles,
            "padding": bucketed.padding_report(
                res2.trace, args.lam_start, args.kmax, engine.lam_max),
        },
        "speedup_steady": round(
            (ladder_evals / max(steady_wall, 1e-9))
            / max(host_evals / max(host_wall, 1e-9), 1e-9), 3),
    }
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)
    print(json.dumps(out, indent=2))
    print(f"[bench_ladder] wrote {args.out}")
    return out


def main_bucketed(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--fids", default="1,8")
    ap.add_argument("--runs", type=int, default=2)
    ap.add_argument("--lam-start", type=int, default=8)
    ap.add_argument("--kmax", type=int, default=4)
    ap.add_argument("--max-evals", type=int, default=20_000)
    ap.add_argument("--eigen-interval", type=int, default=5)
    ap.add_argument("--out", default="BENCH_bucketed.json")
    args = ap.parse_args(argv)
    fids = [int(f) for f in args.fids.split(",")]
    kw = dict(n=args.dim, lam_start=args.lam_start, kmax_exp=args.kmax,
              max_evals=args.max_evals, eigen_interval=args.eigen_interval)

    sections = {}

    def ladder_section(label, eigen_schedule):
        eng = ladder.LadderEngine(schedule="sequential",
                                  eigen_schedule=eigen_schedule, **kw)
        _, first = _timed_campaign(eng, fids, args.runs, 0)
        res, steady = _timed_campaign(eng, fids, args.runs, 1)
        evals = int(np.sum(res.total_fevals))
        sections[label] = {
            "first_call_wall_s": round(first, 4),
            "wall_s": round(steady, 4),
            "evals": evals,
            "evals_per_s": round(evals / max(steady, 1e-9), 1),
            "compiles": res.compiles,
            "padding": bucketed.padding_report(
                res.trace, args.lam_start, args.kmax, eng.lam_max),
        }
        return evals / max(steady, 1e-9)

    # PR-1's engine: flat scan, λ_max padding, eigh every vmapped generation
    flat_rate = ladder_section("ladder_flat_pr1", "flat")
    # this PR, axis 2: nested scan — eigh once per eigen block
    ladder_section("ladder_nested", "nested")

    # this PR, axis 1+2: rung buckets over the nested scan
    for policy in ("cover", "min"):
        eng_b = bucketed.BucketedLadderEngine(policy=policy, **kw)
        _, first = _timed_bucketed(eng_b, fids, args.runs, 0)
        res_b, steady = _timed_bucketed(eng_b, fids, args.runs, 1)
        sections[f"bucketed_{policy}"] = {
            "first_call_wall_s": round(first, 4),
            "wall_s": round(steady, 4),
            "evals": res_b.useful_evals,
            "evals_per_s": round(res_b.useful_evals / max(steady, 1e-9), 1),
            "compiles": res_b.compiles,
            "segments": res_b.segments,
            "bucket_wall_s": {str(k): v
                              for k, v in res_b.bucket_wall_s.items()},
            "padding": {
                "useful_evals": res_b.useful_evals,
                "padded_evals": res_b.padded_evals,
                "waste": round(res_b.padding_waste(), 3),
            },
        }

    out = {
        "config": {
            "dim": args.dim, "fids": fids, "runs": args.runs,
            "lam_start": args.lam_start, "kmax_exp": args.kmax,
            "max_evals": args.max_evals,
            "eigen_interval": args.eigen_interval,
            "lam_max": (2 ** args.kmax) * args.lam_start,
            "note": "useful-evals/sec, identical workload per engine; "
                    "ladder_flat_pr1 is PR 1's λ_max-padded flat-scan "
                    "engine (vmap-defeated eigh laziness)",
        },
        **sections,
        "speedups_vs_flat_ladder": {
            label: round(sections[label]["evals_per_s"]
                         / max(flat_rate, 1e-9), 3)
            for label in sections if label != "ladder_flat_pr1"
        },
    }
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)
    print(json.dumps(out, indent=2))
    print(f"[bench_ladder] wrote {args.out}")
    return out


if __name__ == "__main__":
    import sys
    if "--bucketed" in sys.argv:
        sys.argv.remove("--bucketed")
        main_bucketed()
    else:
        main()
