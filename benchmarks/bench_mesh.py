"""Mesh campaign engine scaling curve — S1 (ordered) vs S2 (concurrent).

Runs the same BBOB campaign through ``distributed/mesh_engine.py`` on
campaign meshes carved out of 1, 2, 4, ... virtual CPU devices (prefixes of
the ``--xla_force_host_platform_device_count`` fleet) for BOTH deployment
strategies, against the single-device bucketed driver as the baseline, and
writes the useful-evals/sec curve to ``BENCH_mesh.json`` (the CI artifact).

Virtual CPU devices share the machine's physical cores, so absolute
wall-clock does not scale the way the paper's Fugaku CMGs do — the curve's
value is (a) the per-strategy dispatch/synchronization overhead at each
device count on identical work, and (b) a smoke-level proof that both
strategies run, re-bucket and stay budget-correct on a real multi-device
mesh.  ``main`` re-execs itself in a subprocess with the XLA flag set (the
device count must precede jax's first import), so callers like
``benchmarks/run.py --smoke`` keep their own single-device jax state.

  PYTHONPATH=src python -m benchmarks.bench_mesh [--devices 8] [--dim 16]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

_INNER_ENV = "_BENCH_MESH_INNER"


def _parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--fids", default="1,8")
    ap.add_argument("--runs", type=int, default=4)
    ap.add_argument("--lam-start", type=int, default=8)
    ap.add_argument("--kmax", type=int, default=3)
    ap.add_argument("--max-evals", type=int, default=8000)
    ap.add_argument("--eigen-interval", type=int, default=3)
    ap.add_argument("--out", default="BENCH_mesh.json")
    return ap


def main(argv=None):
    """Outer entry: spawn the real benchmark with the virtual-device flag."""
    args = _parser().parse_args(argv)
    if os.environ.get(_INNER_ENV) == "1":
        return _inner(args)
    env = dict(os.environ)
    env[_INNER_ENV] = "1"
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices} "
        + env.get("XLA_FLAGS", ""))
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, os.path.abspath(__file__)]
    if argv is not None:
        cmd += list(argv)
    else:
        cmd += sys.argv[1:]
    subprocess.run(cmd, check=True, env=env, cwd=root)
    return 0


def _inner(args):
    import time

    import jax

    jax.config.update("jax_enable_x64", True)

    import numpy as np

    from repro.core import bucketed
    from repro.distributed import mesh_engine
    from repro.launch.mesh import make_campaign_mesh

    fids = [int(f) for f in args.fids.split(",")]
    kw = dict(n=args.dim, lam_start=args.lam_start, kmax_exp=args.kmax,
              max_evals=args.max_evals, eigen_interval=args.eigen_interval)
    devs = jax.devices()
    assert len(devs) >= args.devices, devs
    counts = [d for d in (1, 2, 4, 8, 16, 32) if d <= args.devices]

    def timed(fn):
        fn()                                    # warm (compile) pass
        t0 = time.perf_counter()
        res = fn()
        return res, time.perf_counter() - t0

    # -- single-device bucketed baseline --------------------------------------
    eng_b = bucketed.BucketedLadderEngine(**kw)
    res_b, wall_b = timed(lambda: bucketed.run_campaign_bucketed(
        eng_b, fids=fids, instances=(1,), runs=args.runs, seed=1))
    baseline = {
        "wall_s": round(wall_b, 4),
        "useful_evals": res_b.useful_evals,
        "evals_per_s": round(res_b.useful_evals / max(wall_b, 1e-9), 1),
        "compiles": res_b.compiles,
    }

    # -- 1 → P device curve, both strategies ----------------------------------
    curve = {"ordered": [], "concurrent": []}
    for d in counts:
        mesh = make_campaign_mesh(devices=devs[:d])
        for strategy in ("ordered", "concurrent"):
            eng = mesh_engine.MeshCampaignEngine(strategy=strategy,
                                                 mesh=mesh, **kw)
            res, wall = timed(lambda: mesh_engine.run_campaign_mesh(
                eng, fids=fids, instances=(1,), runs=args.runs, seed=1))
            np.testing.assert_array_equal(res.total_fevals,
                                          res_b.total_fevals)
            curve[strategy].append({
                "devices": d,
                "wall_s": round(wall, 4),
                "useful_evals": res.useful_evals,
                "evals_per_s": round(res.useful_evals / max(wall, 1e-9), 1),
                "compiles": res.compiles,
                "segments": len(res.segments),
                "exchange_rounds": len(res.exchange),
                "padding_waste": round(res.padding_waste(), 3),
            })
            print(f"[bench_mesh] {strategy:10s} d={d}  wall={wall:.3f}s  "
                  f"{curve[strategy][-1]['evals_per_s']:.0f} evals/s",
                  flush=True)

    out = {
        "config": {
            "dim": args.dim, "fids": fids, "runs": args.runs,
            "lam_start": args.lam_start, "kmax_exp": args.kmax,
            "max_evals": args.max_evals,
            "eigen_interval": args.eigen_interval,
            "members": len(fids) * args.runs,
            "device_counts": counts,
            "note": "useful-evals/sec on identical work per cell; virtual "
                    "CPU devices share physical cores, so the curve "
                    "measures dispatch/synchronization overhead (S1 "
                    "barrier-per-segment vs S2 islands), not hardware "
                    "scaling",
        },
        "bucketed_baseline": baseline,
        "mesh": curve,
        "speedup_vs_bucketed": {
            s: {str(r["devices"]): round(
                r["evals_per_s"] / max(baseline["evals_per_s"], 1e-9), 3)
                for r in rows}
            for s, rows in curve.items()
        },
    }
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)
    print(json.dumps(out["speedup_vs_bucketed"], indent=2))
    print(f"[bench_mesh] wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
