"""Roofline derivation from the dry-run artifacts (§Roofline deliverable).

Reads ``benchmarks/artifacts/*.json`` (written by repro.launch.dryrun) and
reports, per (arch × shape × mesh):

  compute    = FLOPs_per_device / PEAK_FLOPS            [s]
  memory     = bytes_per_device / HBM_BW                [s]
  collective = collective_bytes_per_device / ICI_BW     [s]

The artifact numbers come from the loop-trip-corrected HLO analyzer
(distributed/hlo_analyzer.py) over the *per-device* SPMD module, so no
division by chip count is needed here.  MODEL_FLOPS (useful work) is
6·N·D for training and 2·N·D for inference, with N_active for MoE.

  PYTHONPATH=src python -m benchmarks.roofline [--md] [--mesh pod]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

# TPU v5e hardware constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def model_flops_for(meta: dict) -> float:
    """Useful-work FLOPs (global): 6·N_eff·D (train) / 2·N_eff·D (inference).

    N_eff counts matmul-participating parameters: the input-embedding GATHER
    is excluded (untied tables); the LM head counts for train/decode but not
    prefill (only the final position projects to logits)."""
    n = meta["model"].get("n_active_params") or meta["model"].get("n_params")
    if not n:
        return 0.0
    try:
        from repro.configs import get_config
        cfg = get_config(meta["arch"])
        embed = cfg.vocab * cfg.d_model
        head = embed
        if cfg.embed_inputs and not cfg.tied_embeddings:
            n = n - embed                       # gather, not matmul
        if meta["shape"].startswith("prefill"):
            n = n - head                        # head applied at last pos only
    except Exception:
        pass
    shape = meta["shape"]
    dims = {"train_4k": (4096, 256), "prefill_32k": (32768, 32),
            "decode_32k": (1, 128), "long_500k": (1, 1)}.get(shape)
    if dims is None:
        # non-LM cells (CMA strategy steps / mesh-engine segments) have no
        # token-based useful-FLOPs model; their roofline rows keep the
        # compute/memory/collective split with useful% = 0
        return 0.0
    tokens = dims[0] * dims[1]
    mult = 6.0 if meta["kind"] == "train" else 2.0
    return mult * n * tokens


def _analytic_kernel_bytes(meta: dict, tag: str) -> float:
    """Pallas-kernel HBM streaming traffic substituted for the XLA tile
    traffic each named-scope tag measures (flash attention /
    kernels/rwkv6_wkv.py-style recurrent kernels)."""
    try:
        from repro.configs import get_config
        from repro.models.flash_xla import kernel_hbm_bytes
        cfg = get_config(meta["arch"])
    except Exception:
        return 0.0
    shape = meta["shape"]
    dims = {"train_4k": (4096, 256), "prefill_32k": (32768, 32)}.get(shape)
    if dims is None:
        return 0.0
    S, gb = dims
    dp = 16 if meta["mesh"].count("x") == 1 else 32
    B_local = max(1, gb // dp)
    passes = 3.0 if meta["kind"] == "train" else 1.0

    if tag == "flash_tile" and cfg.n_heads:
        n_attn = cfg.n_layers
        if cfg.family == "vlm":
            n_attn = cfg.n_layers - cfg.n_layers // cfg.cross_every
        if cfg.family == "hybrid":
            n_attn = cfg.n_layers // cfg.shared_attn_every
        per = kernel_hbm_bytes(B_local, S, S, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, 512, 2)
        if meta["kind"] != "train":
            per = per * 0.4                        # fwd share only
        return per * n_attn
    if tag == "wkv_tile" and cfg.family == "ssm":
        # streams r/k/v/w in, o out (+ grads in bwd); state stays in VMEM
        return passes * 5 * B_local * S * cfg.d_model * 2 * cfg.n_layers
    if tag == "ssd_tile" and cfg.family == "hybrid":
        d_in = cfg.ssm_expand * cfg.d_model
        return passes * 6 * B_local * S * (d_in + 2 * cfg.ssm_state) * 2 \
            * cfg.n_layers
    return 0.0


def rows_from_artifacts(mesh_tag: str = "pod", art_dir: str = ARTIFACT_DIR):
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, f"*__{mesh_tag}.json"))):
        with open(path) as f:
            meta = json.load(f)
        flops_dev = meta["flops"]
        bytes_dev = meta["bytes_accessed"]
        coll_dev = meta["collective_bytes"]["total"]
        n_dev = meta["n_devices"]
        # TPU-kernelized memory: XLA materializes flash/WKV/SSD tiles between
        # kernels (tagged via named_scope); the Pallas kernels keep them in
        # VMEM — substitute their analytic HBM traffic (EXPERIMENTS §Perf).
        bytes_kern = bytes_dev
        for tag, tile_b in meta.get("tagged_bytes", {}).items():
            if tile_b:
                bytes_kern = bytes_kern - tile_b + _analytic_kernel_bytes(
                    meta, tag)
        t_c = flops_dev / PEAK_FLOPS
        t_m = bytes_kern / HBM_BW
        t_x = coll_dev / ICI_BW
        dominant = max((t_c, "compute"), (t_m, "memory"),
                       (t_x, "collective"))[1]
        mf = model_flops_for(meta)
        useful = mf / (flops_dev * n_dev) if flops_dev else 0.0
        bound = max(t_c, t_m, t_x)
        rows.append({
            "arch": meta["arch"], "shape": meta["shape"],
            "mesh": meta["mesh"],
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "t_memory_xla_s": bytes_dev / HBM_BW,
            "dominant": dominant,
            "model_flops": mf,
            "useful_ratio": useful,
            # roofline fraction: how much of the bound step is useful compute
            "roofline_frac": (mf / n_dev / PEAK_FLOPS) / bound if bound else 0.0,
            "peak_gb": meta.get("memory", {}).get("peak_bytes", 0) / 1e9,
            "collective_counts": meta["collective_bytes"].get("counts", {}),
        })
    return rows


def fmt_table(rows, md: bool = True) -> str:
    head = ["arch", "shape", "compute_s", "memory_s", "collective_s",
            "dominant", "useful%", "roofline%"]
    lines = []
    if md:
        lines.append("| " + " | ".join(head) + " |")
        lines.append("|" + "---|" * len(head))
    else:
        lines.append(",".join(head))
    for r in rows:
        cells = [r["arch"], r["shape"], f"{r['t_compute_s']:.3e}",
                 f"{r['t_memory_s']:.3e}", f"{r['t_collective_s']:.3e}",
                 r["dominant"], f"{100 * r['useful_ratio']:.1f}",
                 f"{100 * r['roofline_frac']:.1f}"]
        lines.append(("| " + " | ".join(cells) + " |") if md
                     else ",".join(cells))
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod",
                    help="artifact tag: pod | multipod | pod_opt | "
                         "multipod_opt | any --suffix variant")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--json-out")
    args = ap.parse_args(argv)
    rows = rows_from_artifacts(args.mesh)
    if not rows:
        print(f"no artifacts for mesh '{args.mesh}' in {ARTIFACT_DIR} — "
              "run: python -m repro.launch.dryrun --all")
        return 1
    print(fmt_table(rows, md=args.md))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
