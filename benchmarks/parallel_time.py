"""Parallel-time model (DESIGN.md §2, last row).

This CPU-only container cannot measure Fugaku/TPU wall-clock, so speedup
tables use an explicit, reported model — the deployment the paper describes
(§3.2.1): each evaluation runs on a dedicated core/slot, so

  t_gen(λ, d) = eval_cost · ⌈λ / (λ_slots · d)⌉ + t_linalg(n) + t_comm(d)

with d the devices owned by the descent.  The sequential baseline evaluates
one point at a time: t_gen = λ·eval_cost + t_linalg.  Reported ERT tables
also list raw evaluation counts so the model's contribution is transparent.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CostModel:
    eval_cost_s: float = 1e-3          # per-evaluation blackbox cost
    lam_slots: int = 12                # evaluations per device (paper: T=12)
    linalg_ref_s: float = 3e-5         # t_linalg at n=10 (measured, 1 core)
    comm_per_round_s: float = 2e-5     # scatter+gather / psum latency

    def t_linalg(self, n: int) -> float:
        # eigh amortized O(n³)/interval + O(n²) updates ≈ quadratic-ish here
        return self.linalg_ref_s * (n / 10.0) ** 2

    def gen_time_parallel(self, lam: int, devices: int, n: int) -> float:
        rounds = int(np.ceil(lam / (self.lam_slots * max(devices, 1))))
        return (rounds * self.eval_cost_s + self.t_linalg(n)
                + self.comm_per_round_s)

    def gen_time_sequential(self, lam: int, n: int) -> float:
        return lam * self.eval_cost_s + self.t_linalg(n)


def seq_times_from_evals(evals: np.ndarray, n: int,
                         cm: CostModel) -> np.ndarray:
    """Cumulative evaluations → modeled wall time (sequential execution)."""
    return evals * cm.eval_cost_s            # linalg amortized: eval-dominated


def ert(hit_times: np.ndarray, budget_times: np.ndarray) -> float:
    """Expected RunTime (paper §4.3.1): Σ time spent across runs (hit time
    for successful runs, full budget for unsuccessful) / #successes."""
    ok = np.isfinite(hit_times)
    if not ok.any():
        return np.inf
    total = hit_times[ok].sum() + budget_times[~ok].sum()
    return float(total / ok.sum())
