"""Paper Fig. 5 / Table 1 analogue: the linear-algebra rewrites.

Compares, per dimension n ∈ {10, 40, 200, 1000} and population λ = K·12:
  * loop-form covariance adaptation (λ rank-1 updates — the reference C
    code's original eq. 2) vs the GEMM-form rewrite (paper eq. 3);
  * loop-form sampling (λ matvecs, eq. 1) vs the batched GEMM rewrite;
  * the share of linear algebra in a full CMA-ES generation before/after
    (Table 1 analogue).

On TPU the GEMM forms additionally route to the fused Pallas kernels
(kernels/cma_update.py — one HBM pass); on this CPU container both forms run
through XLA, which is exactly the paper's BLAS-vs-loops comparison.

  PYTHONPATH=src python -m benchmarks.bench_linalg [--dims 10,40,200] [--ks 1,16]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time(fn, reps=5):
    jax.block_until_ready(fn())                # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


@jax.jit
def _loop_cov_update(C, Y, w, p_c, decay, c_mu, c_1):
    """Paper eq. 2 as written: λ sequential rank-1 updates."""
    def body(i, acc):
        return acc + w[i] * jnp.outer(Y[i], Y[i])
    gram = jax.lax.fori_loop(0, Y.shape[0], body, jnp.zeros_like(C))
    return decay * C + c_mu * gram + c_1 * jnp.outer(p_c, p_c)


@jax.jit
def _gemm_cov_update(C, Y, w, p_c, decay, c_mu, c_1):
    return ref.rank_mu_update(C, Y, w, p_c, decay, c_mu, c_1)


@jax.jit
def _loop_sample(m, sigma, B, D, Z):
    def body(i, X):
        return X.at[i].set(m + sigma * (B @ (D * Z[i])))
    return jax.lax.fori_loop(0, Z.shape[0], body, jnp.zeros_like(Z))


@jax.jit
def _gemm_sample(m, sigma, B, D, Z):
    return ref.sample_points(m, sigma, B, D, Z)


def run(dims, ks, reps=5):
    rows = []
    for n in dims:
        key = jax.random.PRNGKey(n)
        kC, kY, kp, kz = jax.random.split(key, 4)
        A = jax.random.normal(kC, (n, n))
        C = A @ A.T / n + jnp.eye(n)
        Bmat, _ = jnp.linalg.qr(A)
        D = jnp.abs(jax.random.normal(kp, (n,))) + 0.5
        p_c = jax.random.normal(kp, (n,))
        for K in ks:
            lam = K * 12
            Y = jax.random.normal(kY, (lam, n))
            w = jnp.abs(jax.random.normal(kz, (lam,)))
            w = w / w.sum()
            Z = jax.random.normal(kz, (lam, n))
            m = jnp.zeros((n,))

            t_loop_c = _time(lambda: _loop_cov_update(
                C, Y, w, p_c, 0.9, 0.05, 0.05), reps=reps)
            t_gemm_c = _time(lambda: _gemm_cov_update(
                C, Y, w, p_c, 0.9, 0.05, 0.05), reps=reps)
            t_loop_s = _time(lambda: _loop_sample(m, 0.3, Bmat, D, Z),
                             reps=reps)
            t_gemm_s = _time(lambda: _gemm_sample(m, 0.3, Bmat, D, Z),
                             reps=reps)
            t_eig = _time(lambda: jnp.linalg.eigh(C), reps=max(1, reps // 2))
            rows.append(dict(
                n=n, K=K, lam=lam,
                cov_loop_us=t_loop_c * 1e6, cov_gemm_us=t_gemm_c * 1e6,
                cov_speedup=t_loop_c / t_gemm_c,
                samp_loop_us=t_loop_s * 1e6, samp_gemm_us=t_gemm_s * 1e6,
                samp_speedup=t_loop_s / t_gemm_s,
                eigh_us=t_eig * 1e6))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dims", default="10,40,200")
    ap.add_argument("--ks", default="1,16")
    ap.add_argument("--reps", type=int, default=5)
    args = ap.parse_args(argv)
    dims = [int(d) for d in args.dims.split(",")]
    ks = [int(k) for k in args.ks.split(",")]
    rows = run(dims, ks, args.reps)
    print("n,K,lam,cov_loop_us,cov_gemm_us,cov_speedup,"
          "samp_loop_us,samp_gemm_us,samp_speedup,eigh_us")
    for r in rows:
        print(f"{r['n']},{r['K']},{r['lam']},{r['cov_loop_us']:.1f},"
              f"{r['cov_gemm_us']:.1f},{r['cov_speedup']:.2f},"
              f"{r['samp_loop_us']:.1f},{r['samp_gemm_us']:.1f},"
              f"{r['samp_speedup']:.2f},{r['eigh_us']:.1f}")
    return rows


if __name__ == "__main__":
    main()
