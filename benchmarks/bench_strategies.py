"""Paper Table 2 / Table 3 analogue: parallel-strategy speedups over the
sequential IPOP-CMA-ES, per (function, target), with the parallel-time model
(benchmarks/parallel_time.py) at configurable evaluation granularity.

  PYTHONPATH=src python -m benchmarks.bench_strategies \
      [--fids 1,8,10,15] [--dim 10] [--devices 8] [--cost-ms 1] [--runs 3] \
      [--impl xla|xla_unfused]

``--impl`` A/Bs the collectives update path: the default ``xla`` runs the
fused gram-family psum (one ``Ysᵀ·[Ys|√w]`` dot + ``masked_update_from_gram``
per generation), ``xla_unfused`` the PR-6 4-tuple moments psum.  Kernel-level
timings for the same A/B live in BENCH_kernels.json (``strategies_gram``).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.parallel_time import CostModel, ert
from repro.core import ladder
from repro.core.ipop import run_ipop
from repro.core.strategies import KReplicated
from repro.fitness import bbob

TARGETS = np.array([1e2, 1e1, 1e0, 1e-1, 1e-2])


def kd_hit_times(kd, trace, f_opt, cm: CostModel, devices: int):
    """Per-target wall-time (model) at which K-Distributed first hits.

    Every K-Distributed generation is one lockstep round: all descents run
    concurrently on their device groups, so t_gen = eval rounds (=1, one
    eval per core) + linalg + comm.
    """
    t_gen = cm.gen_time_parallel(kd.lam_start, 1, kd.n)   # 1 round
    best = np.minimum.accumulate(trace["best_f"])
    hits = np.full(len(TARGETS), np.inf)
    for g, bf in enumerate(best):
        for i, t in enumerate(TARGETS):
            if np.isinf(hits[i]) and bf - f_opt <= t:
                hits[i] = (g + 1) * t_gen
    return hits, len(best) * t_gen


def seq_hit_times(res, f_opt, cm: CostModel):
    hits_ev = res.hit_evals(TARGETS, f_opt)
    return hits_ev * cm.eval_cost_s, res.total_fevals * cm.eval_cost_s


def kr_hit_times(out, f_opt, cm: CostModel, devices: int, lam_start: int,
                 n: int):
    hits = np.full(len(TARGETS), np.inf)
    t = 0.0
    best = np.inf
    for ph in out["phases"]:
        lam = ph["lam"]
        d_per = max(1, devices // max(1, ph["n_groups"]))
        t_gen = cm.gen_time_parallel(lam, d_per, n)
        for bf in ph["best_f"]:
            t += t_gen
            best = min(best, bf)
            for i, tgt in enumerate(TARGETS):
                if np.isinf(hits[i]) and best - f_opt <= tgt:
                    hits[i] = t
    return hits, t


def run(fids, dim, devices, cost_ms, runs, gens, max_evals, impl="xla"):
    cm = CostModel(eval_cost_s=cost_ms * 1e-3)
    rows = []
    for fid in fids:
        inst = bbob.make_instance(fid, dim, 1)
        fit = lambda X: bbob.evaluate(fid, inst, X)
        f_opt = float(inst.f_opt)
        seq_h, kd_h, kr_h = [], [], []
        seq_b, kd_b, kr_b = [], [], []
        for r in range(runs):
            # sequential IPOP: the whole restart ladder as one device program
            res = run_ipop(fit, dim, jax.random.PRNGKey(100 + r),
                           max_evals=max_evals)
            h, b = seq_hit_times(res, f_opt, cm)
            seq_h.append(h); seq_b.append(b)

            # concurrent rungs on the strategies collectives, single jit
            kd, _, tr = ladder.run_concurrent(
                dim, devices, jax.random.PRNGKey(200 + r), fit,
                total_gens=gens, impl=impl)
            h, b = kd_hit_times(kd, tr, f_opt, cm, devices)
            kd_h.append(h); kd_b.append(b)

            kr = KReplicated(n=dim, n_devices=devices, impl=impl)
            out = kr.run_sim(jax.random.PRNGKey(300 + r), fit,
                             phase_gens=gens, max_evals=max_evals)
            h, b = kr_hit_times(out, f_opt, cm, devices, 12, dim)
            kr_h.append(h); kr_b.append(b)

        for i, tgt in enumerate(TARGETS):
            e_seq = ert(np.array([h[i] for h in seq_h]), np.array(seq_b))
            e_kd = ert(np.array([h[i] for h in kd_h]), np.array(kd_b))
            e_kr = ert(np.array([h[i] for h in kr_h]), np.array(kr_b))
            rows.append(dict(
                fid=fid, target=tgt, ert_seq=e_seq, ert_kdist=e_kd,
                ert_krep=e_kr,
                speedup_kdist=e_seq / e_kd if np.isfinite(e_kd) else np.nan,
                speedup_krep=e_seq / e_kr if np.isfinite(e_kr) else np.nan))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fids", default="1,8")
    ap.add_argument("--dim", type=int, default=10)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--cost-ms", type=float, default=1.0)
    ap.add_argument("--runs", type=int, default=3)
    ap.add_argument("--gens", type=int, default=120)
    ap.add_argument("--max-evals", type=int, default=40_000)
    ap.add_argument("--impl", default="xla",
                    help="collectives update path: xla (fused gram-family "
                         "psum, default) | xla_unfused (PR-6 moments psum)")
    args = ap.parse_args(argv)
    fids = [int(f) for f in args.fids.split(",")]
    rows = run(fids, args.dim, args.devices, args.cost_ms, args.runs,
               args.gens, args.max_evals, impl=args.impl)
    print("fid,target,ert_seq_s,ert_kdist_s,ert_krep_s,"
          "speedup_kdist,speedup_krep")
    for r in rows:
        def f(v):
            return f"{v:.3g}" if np.isfinite(v) else "inf"
        print(f"{r['fid']},{r['target']:.0e},{f(r['ert_seq'])},"
              f"{f(r['ert_kdist'])},{f(r['ert_krep'])},"
              f"{f(r['speedup_kdist'])},{f(r['speedup_krep'])}")
    return rows


if __name__ == "__main__":
    main()
