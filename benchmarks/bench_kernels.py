"""Kernel-level benchmark of the fused generation path (PR 4).

Sweeps the problem dimension across the rung ladder's population sizes and
measures, per (n, λ):

* ``update_core`` — the O(n²) state-update ops alone, PR-3 unfused soup
  (rank-μ gram dot + y_w GEMV + covariance combine + ``0.5·(C+Cᵀ)``
  symmetrize + whitened-step GEMVs) vs the fused op
  (``ref.fused_gen_update``: ONE gram-family dot, no symmetrize pass — C
  stays symmetric by construction).  This is the λ-independent cost the
  ROADMAP named as the remaining per-step lever at large n.
* ``full_step`` — the whole masked generation update as the engines run it
  (order statistics + heavy op + O(n) epilogue + stop-masking), same A/B.
* ``sample`` — fused (Y, X)-in-one-pass sampling vs the separate
  transform + axpy epilogue.

Also lowers the fused XLA step and the slot-batched Pallas megakernel
(interpret off-TPU) as roofline cells — flops / bytes per generation via
the loop-aware HLO analyzer — so the dry-run artifact family covers the
new kernels.

  PYTHONPATH=src python -m benchmarks.bench_kernels [--dims 64,256,1024]

Writes BENCH_kernels.json (CI artifact via the BENCH_*.json glob).
"""
from __future__ import annotations

import argparse
import json
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import cmaes  # noqa: E402
from repro.core.params import CMAConfig, make_params  # noqa: E402
from repro.distributed import hlo_analyzer  # noqa: E402
from repro.kernels import ref  # noqa: E402


def _time_scan(body, carry0, gens: int, reps: int) -> float:
    """Best-of-reps seconds per generation for a jitted scanned body."""
    fn = jax.jit(lambda c: jax.lax.scan(body, c, None, length=gens)[0])
    out = fn(carry0)
    jax.block_until_ready(out)
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(carry0))
        best = min(best, (time.perf_counter() - t0) / gens)
    return best


def _bench_cell(n: int, lam: int, gens: int, reps: int) -> dict:
    cfg = CMAConfig(n=n, lam=lam, eigen_interval=10 ** 9)  # update ops only
    p = make_params(cfg)
    key = jax.random.PRNGKey(0)
    st = cmaes.init_state(cfg, key, jnp.zeros(n), 1.0)
    y0, x = cmaes.sample_population(st, key, lam)
    f = jnp.sum(x ** 2, axis=-1)
    w = cmaes.rank_weights(f, p)

    def live(s, a):
        # carry-dependent guard: stops XLA constant-folding the population
        # dots out of the scan (s.stop is always False at runtime)
        return jnp.where(s.stop, jnp.zeros_like(a), a)

    # -- update core: the O(n²) ops this PR fuses -------------------------
    def core_unfused(s, _):
        y = live(s, y0)
        y_w = w @ y
        gram = ref.rank_mu_gram(y, w)
        whiten = s.B @ ((s.B.T @ y_w) / jnp.maximum(s.D, 1e-300))
        psn = 0.7 * s.p_sigma + 0.3 * whiten
        pcn = 0.8 * s.p_c + 0.2 * y_w
        cn = ref.covariance_combine(s.C, gram, pcn, 0.9, p.c_mu, p.c_1)
        cn = 0.5 * (cn + cn.T)
        return s._replace(C=cn, p_sigma=psn, p_c=pcn), 0

    def core_fused(s, _):
        cn, psn, pcn, _yw = ref.fused_gen_update(
            s.C, s.B, s.D, s.p_sigma, s.p_c, live(s, y0), w, p.c_sigma,
            p.mu_eff, p.c_c, p.c_1, p.c_mu, p.chi_n,
            (s.gen + 1).astype(s.m.dtype))
        return s._replace(C=cn, p_sigma=psn, p_c=pcn), 0

    # -- full masked step as the engines run it ---------------------------
    def full_unfused(s, _):
        mom = cmaes.compute_moments(live(s, y0), f, x, p, lam)
        return cmaes.masked_update(cfg, p, s, mom, impl="xla_unfused",
                                   eigen="defer"), 0

    def full_fused(s, _):
        return cmaes.masked_update_fused(cfg, p, s, live(s, y0), f, x,
                                         impl="xla", eigen="defer"), 0

    # -- sampling: separate transform+axpy vs fused (Y, X) ----------------
    z = cmaes.sample_z(st, key, lam)

    def samp_unfused(s, _):
        yy = ref.sample_transform(s.B, s.D, z)
        xx = s.m[None, :] + s.sigma * yy
        return s._replace(m=s.m + 0.0 * xx[0]), 0

    def samp_fused(s, _):
        yy, xx = ref.gen_sample(s.m, s.sigma, s.B, s.D, z)
        return s._replace(m=s.m + 0.0 * xx[0]), 0

    # -- in-kernel-RNG stream vs host fold_in stream (PR-7 tier) ----------
    # both draw fresh Z every generation (keyed off the carried s.gen, so
    # the threefry work stays inside the scan); the counter stream is the
    # pallas_rng tier's XLA ref — bit-exact with the Mosaic kernel
    def samp_hostkey(s, _):
        k2 = jax.random.fold_in(key, s.gen)
        zz = cmaes.sample_z(s, k2, lam)
        yy, xx = ref.gen_sample(s.m, s.sigma, s.B, s.D, zz)
        return s._replace(gen=s.gen + 1, m=s.m + 0.0 * xx[0]), 0

    def samp_ctrkey(s, _):
        sd = jnp.asarray(jax.random.fold_in(key, s.gen), jnp.uint32)
        yy, xx = ref.gen_sample_rng(s.m, s.sigma, s.B, s.D, sd, lam)
        return s._replace(gen=s.gen + 1, m=s.m + 0.0 * xx[0]), 0

    # -- full resident generation: sample → eval → update (eval-fused) ----
    # baseline = the PR-6 engine chain on a (1, 2) BBOB menu: fused sample
    # emits X, the vmapped fid switch evaluates it, stats index X;
    # fused = the eval-fused epilogue — F rides the sample op, X is never
    # materialized and x_best is reconstructed as m + σ·y*
    from repro.fitness import bbob

    def _mask(s, new):
        return jax.tree_util.tree_map(
            lambda old, nw: jnp.where(s.stop, old, nw), s, new)

    def resid_cells(fid):
        inst = bbob.make_instance(fid, n, 1)
        sepc = bbob.separable_coeffs(inst, (1, 2))
        coef = lambda s: cmaes.gen_coef(p, s)

        def dispatched(s, _):
            yy, xx = ref.gen_sample(s.m, s.sigma, s.B, s.D, live(s, z))
            fv = bbob.evaluate_dynamic(inst, xx, (1, 2))
            w2, f_sorted, x_best, n_evals = cmaes.population_stats(
                fv, xx, p, lam)
            c = coef(s)
            cn, psn, pcn, y_w = ref.fused_gen_update(
                s.C, s.B, s.D, s.p_sigma, s.p_c, yy, w2, c["c_sigma"],
                c["mu_eff"], c["c_c"], c["c_1"], c["c_mu"], c["chi_n"],
                c["gen1"])
            return _mask(s, cmaes._finish_update(
                cfg, p, s, f_sorted, x_best, n_evals, cn, psn, pcn, y_w,
                "defer")), 0

        def evalfused(s, _):
            yy, fv = ref.gen_sample_eval(s.m, s.sigma, s.B, s.D,
                                         live(s, z), sepc)
            w2, f_sorted, x_best, n_evals = cmaes.population_stats_from_y(
                fv, yy, s.m, s.sigma, p, lam)
            c = coef(s)
            cn, psn, pcn, y_w = ref.fused_gen_update(
                s.C, s.B, s.D, s.p_sigma, s.p_c, yy, w2, c["c_sigma"],
                c["mu_eff"], c["c_c"], c["c_1"], c["c_mu"], c["chi_n"],
                c["gen1"])
            return _mask(s, cmaes._finish_update(
                cfg, p, s, f_sorted, x_best, n_evals, cn, psn, pcn, y_w,
                "defer")), 0
        return dispatched, evalfused

    resid_f1 = resid_cells(1)
    resid_f2 = resid_cells(2)

    cell = {}
    for name, unf, fus in (("update_core", core_unfused, core_fused),
                           ("full_step", full_unfused, full_fused),
                           ("sample", samp_unfused, samp_fused),
                           ("sample_rng", samp_hostkey, samp_ctrkey),
                           ("resident_full_step_f1", *resid_f1),
                           ("resident_full_step_f2", *resid_f2)):
        tu = _time_scan(unf, st, gens, reps)
        tf = _time_scan(fus, st, gens, reps)
        cell[name] = {
            "unfused_ms": round(tu * 1e3, 5), "fused_ms": round(tf * 1e3, 5),
            "speedup": round(tu / max(tf, 1e-12), 3),
        }
        if name.startswith("resident_full_step"):
            # the acceptance currency: useful fitness evaluations per second
            # through the whole sample→eval→update generation
            cell[name]["unfused_evals_per_s"] = round(lam / tu, 1)
            cell[name]["fused_evals_per_s"] = round(lam / tf, 1)
    return cell


def _strategies_cell(n: int, chunk: int, reps: int) -> dict:
    """A/B of the collectives update path (PR-7 tentpole c): the compiled
    ``KDistributed.chunk_fn`` per generation under ``impl="xla_unfused"``
    (PR-6's 4-tuple moments psum + ``masked_update``) vs the default fused
    path (ONE √w-factored ``Ysᵀ·[Ys|√w]`` gram-family psum +
    ``masked_update_from_gram``, no symmetrize pass)."""
    from repro.core import strategies

    sphere = lambda X: jnp.sum(X ** 2, axis=-1)

    def per_gen(impl: str) -> float:
        kd = strategies.KDistributed(n=n, n_devices=3, lam_start=16,
                                     lam_slots=16, kmax_exp=1, impl=impl,
                                     eigen_interval=8)
        carry = kd.init_carry(jax.random.PRNGKey(0))
        fn = jax.jit(jax.vmap(kd.chunk_fn(sphere, ("ev",), chunk),
                              in_axes=(None, None), out_axes=0,
                              axis_name="ev", axis_size=3))
        keys = jax.random.split(jax.random.PRNGKey(1), chunk)
        jax.block_until_ready(fn(carry, keys))
        best = np.inf
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(carry, keys))
            best = min(best, (time.perf_counter() - t0) / chunk)
        return best

    tu, tf = per_gen("xla_unfused"), per_gen("xla")
    return {"unfused_ms": round(tu * 1e3, 5), "fused_ms": round(tf * 1e3, 5),
            "speedup": round(tu / max(tf, 1e-12), 3)}


def _roofline_cells(n: int, lam: int) -> dict:
    """Lower the fused step (XLA ref) and the slot-batched Pallas megakernel
    as first-class roofline cells: flops/bytes per generation."""
    cfg = CMAConfig(n=n, lam=lam, eigen_interval=10 ** 9)
    p = make_params(cfg)
    st = cmaes.init_state(cfg, jax.random.PRNGKey(0), jnp.zeros(n), 1.0)
    y, x = cmaes.sample_population(st, jax.random.PRNGKey(1), lam)
    f = jnp.sum(x ** 2, axis=-1)

    def fused_step(s):
        return cmaes.masked_update_fused(cfg, p, s, y, f, x, impl="xla",
                                         eigen="defer")

    txt = jax.jit(fused_step).lower(st).compile().as_text()
    stats = hlo_analyzer.analyze(txt)
    out = {"xla_fused_step": {"flops": stats["flops"],
                              "bytes": stats["bytes"]}}

    # slot-batched megakernel (interpret lowering off-TPU): S=2 slots
    from repro.kernels import ops as kops
    S = 2
    rep = lambda a: jnp.broadcast_to(a[None], (S,) + a.shape)
    w = cmaes.rank_weights(f, p)
    coef = {k: jnp.broadcast_to(v, (S,))
            for k, v in cmaes.gen_coef(p, st).items()}

    def mega(C, B, D, ps, pc, Y, W):
        return kops.gen_update(C, B, D, ps, pc, Y, W, coef, impl="pallas")

    txt_k = jax.jit(mega).lower(
        rep(st.C), rep(st.B), rep(st.D), rep(st.p_sigma), rep(st.p_c),
        rep(y), rep(w)).compile().as_text()
    stats_k = hlo_analyzer.analyze(txt_k)
    out["pallas_megakernel_2slots"] = {"flops": stats_k["flops"],
                                       "bytes": stats_k["bytes"]}
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dims", default="64,256,1024")
    ap.add_argument("--lam-start", type=int, default=8)
    ap.add_argument("--kmax", type=int, default=4)
    ap.add_argument("--gens", type=int, default=50)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args(argv)
    dims = [int(d) for d in args.dims.split(",")]
    rungs = [(2 ** k) * args.lam_start for k in range(args.kmax + 1)]

    out = {"config": {
        "dims": dims, "rung_lams": rungs, "gens": args.gens,
        "reps": args.reps, "dtype": "float64",
        "note": "update_core = the O(n²) per-generation state-update ops "
                "(PR-3 unfused soup vs the fused one-dot/no-symmetrize "
                "path); full_step adds order statistics, O(n) epilogue and "
                "stop-masking (identical in both); sample_rng A/Bs the "
                "pallas_rng tier's counter stream against the host fold_in "
                "stream, resident_full_step_* A/Bs the eval-fused sample "
                "epilogue (X never stored) against the dispatched "
                "sample->eval chain, strategies_gram A/Bs KDistributed's "
                "fused gram-family psum against the PR-6 moments psum; on "
                "CPU the residency cells are ~neutral (XLA fuses the eval "
                "chain and caches absorb the X store) - the HBM win they "
                "pin is the accelerator surface, while update_core / "
                "strategies_gram are genuine CPU wins; times are "
                "best-of-reps per generation on CPU",
    }, "cells": {}, "ladder_speedup": {}, "strategies_gram": {},
        "roofline": {}}

    for n in dims:
        gens = max(10, min(args.gens, 8000 // n if n >= 512 else args.gens))
        per_rung = {}
        for lam in rungs:
            per_rung[str(lam)] = _bench_cell(n, lam, gens, args.reps)
            print(f"[bench_kernels] n={n} lam={lam}: "
                  + ", ".join(f"{k} {v['speedup']}x"
                              for k, v in per_rung[str(lam)].items()),
                  flush=True)
        out["cells"][str(n)] = per_rung
        out["ladder_speedup"][str(n)] = {
            sec: round(float(np.exp(np.mean(
                [np.log(per_rung[str(lam)][sec]["speedup"])
                 for lam in rungs]))), 3)
            for sec in ("update_core", "full_step", "sample", "sample_rng",
                        "resident_full_step_f1", "resident_full_step_f2")
        }
        out["strategies_gram"][str(n)] = _strategies_cell(n, 16, args.reps)
        print(f"[bench_kernels] n={n} strategies_gram "
              f"{out['strategies_gram'][str(n)]['speedup']}x", flush=True)
        out["roofline"][str(n)] = _roofline_cells(n, min(rungs[-1], 64))

    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)
    print(json.dumps(out["ladder_speedup"], indent=2))
    print(f"[bench_kernels] wrote {args.out}")
    return out


if __name__ == "__main__":
    main()
