"""Paper Fig. 8 / Table 4 analogue: ECDF of (function, target, run) triplets
hit vs modeled wall time, for the three algorithms.

  PYTHONPATH=src python -m benchmarks.bench_ecdf [--fids 1,8,10] [--dim 10]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.bench_strategies import (kd_hit_times, kr_hit_times,
                                         seq_hit_times)
from benchmarks.parallel_time import CostModel
from repro.core import ladder
from repro.core.ipop import run_ipop
from repro.core.strategies import KReplicated
from repro.fitness import bbob


def collect_hits(fids, dim, devices, cost_ms, runs, gens, max_evals):
    cm = CostModel(eval_cost_s=cost_ms * 1e-3)
    hits = {"seq": [], "kdist": [], "krep": []}
    ends = {"seq": 0.0, "kdist": 0.0, "krep": 0.0}
    for fid in fids:
        inst = bbob.make_instance(fid, dim, 1)
        fit = lambda X: bbob.evaluate(fid, inst, X)
        f_opt = float(inst.f_opt)
        for r in range(runs):
            res = run_ipop(fit, dim, jax.random.PRNGKey(100 + r),
                           max_evals=max_evals)
            h, b = seq_hit_times(res, f_opt, cm)
            hits["seq"].extend(h)
            ends["seq"] = max(ends["seq"], b)

            kd, _, tr = ladder.run_concurrent(
                dim, devices, jax.random.PRNGKey(200 + r), fit,
                total_gens=gens)
            h, b = kd_hit_times(kd, tr, f_opt, cm, devices)
            hits["kdist"].extend(h)
            ends["kdist"] = max(ends["kdist"], b)

            kr = KReplicated(n=dim, n_devices=devices)
            out = kr.run_sim(jax.random.PRNGKey(300 + r), fit,
                             phase_gens=gens, max_evals=max_evals)
            h, b = kr_hit_times(out, f_opt, cm, devices, 12, dim)
            hits["krep"].extend(h)
            ends["krep"] = max(ends["krep"], b)
    return {k: np.asarray(v) for k, v in hits.items()}, ends


def ecdf_at(hits: np.ndarray, t: float) -> float:
    return float(np.mean(hits <= t)) if hits.size else 0.0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fids", default="1,8")
    ap.add_argument("--dim", type=int, default=10)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--cost-ms", type=float, default=1.0)
    ap.add_argument("--runs", type=int, default=2)
    ap.add_argument("--gens", type=int, default=120)
    ap.add_argument("--max-evals", type=int, default=40_000)
    args = ap.parse_args(argv)
    fids = [int(f) for f in args.fids.split(",")]
    hits, ends = collect_hits(fids, args.dim, args.devices, args.cost_ms,
                              args.runs, args.gens, args.max_evals)

    # ECDF curves over a log time grid
    tmax = max(ends.values())
    grid = np.logspace(np.log10(max(1e-3, args.cost_ms * 1e-3)),
                       np.log10(max(tmax, 1e-2)), 12)
    print("t_s," + ",".join(hits.keys()))
    for t in grid:
        print(f"{t:.3g}," + ",".join(f"{ecdf_at(hits[k], t):.3f}"
                                     for k in hits))
    # Table 4 analogue: ECD value at K-Distributed's final timestamp
    t_ref = ends["kdist"]
    print(f"# ECD at K-Distributed final t={t_ref:.3g}s: "
          + ", ".join(f"{k}={ecdf_at(hits[k], t_ref):.3f}" for k in hits))
    return hits, ends


if __name__ == "__main__":
    main()
