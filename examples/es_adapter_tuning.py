"""IPOP-CMA-ES over a real neural-network objective (paper §4.1's expensive-
evaluation regime, on this repo's own LM substrate).

A reduced qwen2-family model is trained for a few steps, then CMA-ES tunes a
34-dimensional adapter (per-layer output gains + head scales) to minimize
validation cross-entropy — fitness = one forward pass per candidate, the
kind of seconds-per-evaluation blackbox the paper targets (§4.1).

  PYTHONPATH=src python examples/es_adapter_tuning.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core import cmaes
from repro.core.params import CMAConfig, make_params
from repro.data.pipeline import SyntheticTokens
from repro.fitness.nn_fitness import make_nn_fitness
from repro.models import lm


def main():
    cfg = smoke_config("qwen2-0.5b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg, seq_len=32, global_batch=4, seed=1)
    val_batch = {k: jnp.asarray(v) for k, v in data.batch_at(999).items()}

    fitness, space = make_nn_fitness(cfg, params, val_batch)
    print(f"adapter dim n = {space.dim}; "
          f"baseline val CE = {float(fitness(jnp.zeros((1, space.dim)))[0]):.4f}")

    cma_cfg = CMAConfig(n=space.dim, lam=12, sigma0=0.5, dtype="float64")
    cma_params = make_params(cma_cfg)
    final = cmaes.run(cma_cfg, cma_params,
                      lambda X: fitness(X).astype(jnp.float64),
                      jax.random.PRNGKey(2),
                      x0=jnp.zeros((space.dim,)), max_gens=25)
    print(f"after {int(final.fevals)} NN evaluations: "
          f"best val CE = {float(final.best_f):.4f} "
          f"(Δ = {float(final.best_f) - float(fitness(jnp.zeros((1, space.dim)))[0]):+.4f})")
    print("best gains (first 8):",
          np.round(np.asarray(final.best_x[:8]), 3))


if __name__ == "__main__":
    main()
