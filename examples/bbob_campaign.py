"""BBOB campaign: the paper's §4 experiment at laptop scale.

Runs sequential IPOP, K-Replicated and K-Distributed over a set of BBOB
functions, collects per-(function, target) hitting evaluations, and prints
a Table-2-style speedup summary (evaluation-parallel time model: a
generation of a descent with population λ on d devices costs ⌈λ/λ_slots/d⌉
rounds — the paper's 1-eval-per-core deployment).

  PYTHONPATH=src python examples/bbob_campaign.py [--fids 1,8,10] [--dim 10]
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core.ipop import run_ipop
from repro.core.strategies import KDistributed, KReplicated
from repro.fitness import bbob

TARGETS = np.array([1e2, 1e1, 1e0, 1e-1, 1e-2])


def hits_from_trace(best_over_time, evals_over_time, f_opt):
    hits = np.full(len(TARGETS), np.inf)
    best = np.inf
    for bf, fe in zip(best_over_time, evals_over_time):
        best = min(best, bf)
        for i, t in enumerate(TARGETS):
            if np.isinf(hits[i]) and best - f_opt <= t:
                hits[i] = fe
    return hits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fids", default="1,8,10")
    ap.add_argument("--dim", type=int, default=10)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--gens", type=int, default=120)
    args = ap.parse_args()
    fids = [int(f) for f in args.fids.split(",")]

    print(f"{'f':>3} {'target':>8} {'seq-IPOP':>10} {'K-Dist':>10} "
          f"{'K-Rep':>10}   (evaluations to target)")
    for fid in fids:
        inst = bbob.make_instance(fid, args.dim, 1)
        fit = lambda X: bbob.evaluate(fid, inst, X)
        f_opt = float(inst.f_opt)

        res = run_ipop(fit, args.dim, jax.random.PRNGKey(1),
                       max_evals=60_000)
        seq_hits = res.hit_evals(TARGETS, f_opt)

        kd = KDistributed(n=args.dim, n_devices=args.devices)
        _, tr = kd.run_sim(jax.random.PRNGKey(2), fit, total_gens=args.gens)
        kd_hits = hits_from_trace(tr["best_f"], tr["fevals"], f_opt)

        kr = KReplicated(n=args.dim, n_devices=args.devices)
        out = kr.run_sim(jax.random.PRNGKey(3), fit, phase_gens=args.gens,
                         max_evals=60_000)
        bfs = np.concatenate([p["best_f"] for p in out["phases"]])
        fes = np.concatenate([p["fevals"] for p in out["phases"]])
        kr_hits = hits_from_trace(bfs, fes, f_opt)

        for i, t in enumerate(TARGETS):
            row = [seq_hits[i], kd_hits[i], kr_hits[i]]
            cells = [f"{v:10.0f}" if np.isfinite(v) else f"{'—':>10}"
                     for v in row]
            print(f"{fid:>3} {t:>8.0e} {cells[0]} {cells[1]} {cells[2]}")


if __name__ == "__main__":
    main()
