"""BBOB campaign: the paper's §4 experiment at laptop scale.

The sequential-IPOP column now runs on the device-resident ladder engine
(core/ladder.py): every (function, run) member of the campaign is one batch
row of a single jitted/vmapped scanned program with in-place doubled-λ
restarts — one compile for the whole table.  K-Distributed runs all rungs
concurrently on the strategies collectives inside one jit
(``ladder.run_concurrent``); K-Replicated keeps its phase barriers.

  PYTHONPATH=src python examples/bbob_campaign.py [--fids 1,8,10] [--dim 10]
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import ladder
from repro.core.strategies import KReplicated
from repro.fitness import bbob

TARGETS = np.array([1e2, 1e1, 1e0, 1e-1, 1e-2])


def hits_from_trace(best_over_time, evals_over_time, f_opt):
    hits = np.full(len(TARGETS), np.inf)
    best = np.inf
    for bf, fe in zip(best_over_time, evals_over_time):
        best = min(best, bf)
        for i, t in enumerate(TARGETS):
            if np.isinf(hits[i]) and best - f_opt <= t:
                hits[i] = fe
    return hits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fids", default="1,8,10")
    ap.add_argument("--dim", type=int, default=10)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--gens", type=int, default=120)
    ap.add_argument("--max-evals", type=int, default=60_000)
    ap.add_argument("--kmax", type=int, default=5)
    args = ap.parse_args()
    fids = [int(f) for f in args.fids.split(",")]

    # -- sequential IPOP: whole campaign = ONE jitted/vmapped ladder program --
    engine = ladder.LadderEngine(
        n=args.dim, lam_start=12, kmax_exp=args.kmax, schedule="sequential",
        max_evals=args.max_evals)
    camp = ladder.run_campaign(engine, fids=fids, instances=(1,), runs=1,
                               seed=1)
    seq_hits_all = camp.hit_evals(TARGETS)          # (B, targets)
    print(f"[campaign] {len(camp.members)} members, one ladder program, "
          f"compiles={camp.compiles}")

    print(f"{'f':>3} {'target':>8} {'seq-IPOP':>10} {'K-Dist':>10} "
          f"{'K-Rep':>10}   (evaluations to target)")
    for j, fid in enumerate(fids):
        inst = bbob.make_instance(fid, args.dim, 1)
        fit = lambda X: bbob.evaluate(fid, inst, X)  # noqa: B023
        f_opt = float(inst.f_opt)

        seq_hits = seq_hits_all[j]

        _, _, tr = ladder.run_concurrent(
            args.dim, args.devices, jax.random.PRNGKey(2), fit,
            total_gens=args.gens)
        kd_hits = hits_from_trace(tr["best_f"], tr["fevals"], f_opt)

        kr = KReplicated(n=args.dim, n_devices=args.devices)
        out = kr.run_sim(jax.random.PRNGKey(3), fit, phase_gens=args.gens,
                         max_evals=args.max_evals)
        bfs = np.concatenate([p["best_f"] for p in out["phases"]])
        fes = np.concatenate([p["fevals"] for p in out["phases"]])
        kr_hits = hits_from_trace(bfs, fes, f_opt)

        for i, t in enumerate(TARGETS):
            row = [seq_hits[i], kd_hits[i], kr_hits[i]]
            cells = [f"{v:10.0f}" if np.isfinite(v) else f"{'—':>10}"
                     for v in row]
            print(f"{fid:>3} {t:>8.0e} {cells[0]} {cells[1]} {cells[2]}")


if __name__ == "__main__":
    main()
