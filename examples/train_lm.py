"""End-to-end training driver: a ~100M-param qwen2-family model for a few
hundred steps on synthetic structured text, with checkpoint/restart and
loss-curve verification.

NOTE on this 1-core CPU container a step takes O(1 min) — use --steps 20
for a demo (checkpoints let you accumulate runs); on real hardware the same
driver runs the full config on the production mesh.  The CI-sized variant
is tests/test_train_substrate.py::test_trainer_loss_decreases_and_restarts.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import shutil

from repro.configs import smoke_config
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--fresh", action="store_true")
    args = ap.parse_args()
    if args.fresh:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    # ~100M params: qwen2 family, scaled-up smoke config
    cfg = dataclasses.replace(
        smoke_config("qwen2-0.5b"),
        n_layers=8, d_model=512, d_ff=2048, n_heads=8, n_kv_heads=4,
        head_dim=64, vocab=32768, logits_chunk=512, q_chunk=256)
    print(f"model: {cfg.n_params() / 1e6:.1f}M params")

    tc = TrainerConfig(
        total_steps=args.steps, ckpt_every=100, ckpt_dir=args.ckpt_dir,
        log_every=10,
        train=ts_mod.TrainConfig(
            microbatches=1,
            adamw=opt_mod.AdamWConfig(lr=1e-3, warmup_steps=30,
                                      total_steps=args.steps)))
    trainer = Trainer(cfg, tc, seq_len=256, global_batch=8)
    trainer.run(resume=not args.fresh)

    losses = [h["loss"] for h in trainer.history]
    if len(losses) >= 20:
        first, last = sum(losses[:10]) / 10, sum(losses[-10:]) / 10
        print(f"loss: {first:.3f} → {last:.3f} "
              f"({'LEARNING ✓' if last < first else 'no improvement ✗'})")


if __name__ == "__main__":
    main()
