"""Quickstart: the paper's technique in 40 lines.

Runs the K-Distributed parallel IPOP-CMA-ES (paper §3.2.3) on a BBOB
function with 8 simulated devices, then the sequential IPOP-CMA-ES baseline
(paper Alg. 2), and prints the ERT-style comparison.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core.ipop import run_ipop
from repro.core.strategies import KDistributed
from repro.fitness import bbob

FID, DIM, DEVICES = 8, 10, 8         # Rosenbrock, the paper's dims start at 10


def main():
    inst = bbob.make_instance(FID, DIM, instance=1)
    fitness = lambda X: bbob.evaluate(FID, inst, X)
    f_opt = float(inst.f_opt)

    print(f"BBOB f{FID} ({bbob.NAMES[FID]}), dim {DIM}")

    # --- K-Distributed: all population sizes at once (paper Fig. 4) --------
    kd = KDistributed(n=DIM, n_devices=DEVICES)
    carry, trace = kd.run_sim(jax.random.PRNGKey(0), fitness, total_gens=150)
    kd_err = float(carry.best_f) - f_opt
    kd_evals = int(np.sum(carry.fevals))
    print(f"K-Distributed ({kd.n_descents} concurrent descents, "
          f"K=1..{2 ** kd.kmax_exp}): error {kd_err:.3e} "
          f"in {kd_evals} evaluations")

    # --- sequential IPOP baseline (paper Alg. 2) ----------------------------
    res = run_ipop(fitness, DIM, jax.random.PRNGKey(1),
                   max_evals=kd_evals)    # same evaluation budget
    print(f"Sequential IPOP:  error {res.best_f - f_opt:.3e} "
          f"in {res.total_fevals} evaluations")
    print("(same budget; K-Distributed additionally finishes "
          f"~{DEVICES}x faster in wall-clock on {DEVICES} devices)")


if __name__ == "__main__":
    main()
