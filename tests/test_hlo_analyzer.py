"""Validate the loop-aware HLO analyzer:

1. on scan-free programs it agrees with XLA's own cost_analysis;
2. it scales with scan trip counts where cost_analysis does not (the quirk
   the roofline correction exists for);
3. collective parsing matches hand-computed byte counts on a known program.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.distributed.hlo_analyzer import analyze, shape_bytes


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def _xla_cost(compiled) -> dict:
    """cost_analysis() returns a dict on new jax, a 1-list of dicts on old."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def test_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    c = _compile(lambda x, y: x @ y, a, b)
    got = analyze(c.as_text())["flops"]
    want = 2 * 128 * 256 * 512
    assert abs(got - want) / want < 0.01
    # agrees with XLA's own number on a loop-free program
    xla = _xla_cost(c).get("flops", 0.0)
    assert abs(got - xla) / max(xla, 1) < 0.05


def test_chained_matmul_agrees_with_xla():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def fn(x):
        for _ in range(4):
            x = jnp.tanh(x @ x)
        return x

    c = _compile(fn, a)
    got = analyze(c.as_text())["flops"]
    xla = _xla_cost(c).get("flops", 0.0)
    assert abs(got - xla) / xla < 0.10


def test_scan_trip_count_scaling():
    """cost_analysis is flat in depth; the analyzer scales linearly."""
    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def make(n):
        def fn(x):
            def body(h, _):
                return jnp.tanh(h @ h), None
            h, _ = jax.lax.scan(body, x, None, length=n)
            return h
        return fn

    c4 = _compile(make(4), a)
    c16 = _compile(make(16), a)
    xla4 = _xla_cost(c4).get("flops", 0.0)
    xla16 = _xla_cost(c16).get("flops", 0.0)
    assert abs(xla16 - xla4) / xla4 < 0.05          # the quirk, confirmed

    got4 = analyze(c4.as_text())["flops"]
    got16 = analyze(c16.as_text())["flops"]
    assert got4 > 0
    ratio = got16 / got4
    assert 3.5 < ratio < 4.5, f"trip scaling broken: {ratio}"
    want4 = 4 * 2 * 128 ** 3
    assert abs(got4 - want4) / want4 < 0.15


def test_nested_scan_scaling():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def fn(x):
        def outer(h, _):
            def inner(g, _):
                return g @ g, None
            g, _ = jax.lax.scan(inner, h, None, length=3)
            return g, None
        h, _ = jax.lax.scan(outer, x, None, length=5)
        return h
    c = _compile(fn, a)
    got = analyze(c.as_text())["flops"]
    want = 5 * 3 * 2 * 64 ** 3
    assert abs(got - want) / want < 0.2


def test_shape_bytes():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("bf16[2,4,8]") == 64 * 2
    assert shape_bytes("(f32[16]{0}, s32[4]{0})") == 64 + 16
    assert shape_bytes("pred[7]") == 7


def test_collective_bytes_psum():
    """all-reduce of a known buffer under shard_map, 8 fake devices."""
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (run in the dry-run env)")
    from jax.sharding import PartitionSpec as P

    from repro.core.eval_dispatch import shard_map_compat
    mesh = jax.make_mesh((jax.device_count(),), ("x",))
    fn = shard_map_compat(lambda x: jax.lax.psum(x, "x"), mesh=mesh,
                          in_specs=P("x"), out_specs=P())
    x = jax.ShapeDtypeStruct((jax.device_count(), 1024), jnp.float32)
    c = jax.jit(fn).lower(x).compile()
    coll = analyze(c.as_text())["collective_bytes"]
    assert coll["all-reduce"] >= 1024 * 4
    assert coll["total"] >= coll["all-reduce"]


def test_bytes_positive_and_reasonable():
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    c = _compile(lambda x: jnp.tanh(x @ x), a)
    got = analyze(c.as_text())["bytes"]
    # ≥ read A twice + write out;  ≤ a few× that (fusion copies)
    assert 2 * 512 * 512 * 4 <= got <= 20 * 512 * 512 * 4
