"""Per-architecture smoke tests: reduced config of the same family, one
forward + loss + grad step and a prefill→decode roundtrip on CPU.

Asserts output shapes, finiteness (no NaNs), and prefill/decode logits
consistency where the math guarantees it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.distributed import sharding
from repro.models import lm

pytestmark = pytest.mark.slow  # heavy model/train/serve tier — excluded from fast CI


def _batch_for(cfg, B, S, key):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.embed_inputs:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
    else:
        batch["frames"] = jax.random.normal(ks[0], (B, S, cfg.d_model),
                                            jnp.bfloat16)
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            ks[1], (B, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
    batch["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    B, S = 2, 64
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(1))

    hidden, aux = jax.jit(lambda p, b: lm.forward(cfg, p, b))(params, batch)
    assert hidden.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden.astype(jnp.float32))))

    val, metrics = jax.jit(lambda p, b: lm.loss(cfg, p, b))(params, batch)
    assert np.isfinite(float(val))
    # random init + uniform labels ⇒ CE ≈ ln(vocab)
    assert 0.0 < float(metrics["ce"]) < 3.0 * np.log(cfg.vocab)


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_step(arch):
    cfg = smoke_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, 2, 32, jax.random.PRNGKey(1))

    def loss_fn(p):
        return lm.loss(cfg, p, batch)[0]

    g = jax.jit(jax.grad(loss_fn))(params)
    leaves = jax.tree_util.tree_leaves(g)
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
               for l in leaves)
    # at least one non-zero gradient leaf
    assert any(float(jnp.max(jnp.abs(l.astype(jnp.float32)))) > 0
               for l in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """decode(token S) after prefill(0..S−1) ≈ forward(0..S) at position S."""
    cfg = smoke_config(arch)
    if cfg.n_experts:
        # MoE capacity dropping is batch-dependent (forward routes B·S tokens,
        # decode routes B) — give every expert full capacity so no token is
        # ever dropped and the paths are mathematically identical.
        import dataclasses
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.n_experts) / cfg.experts_per_tok)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S, max_len = 2, 33, 64
    full = _batch_for(cfg, B, S, jax.random.PRNGKey(1))
    pre = {k: (v[:, :S - 1] if k in ("tokens", "frames") else v)
           for k, v in full.items() if k != "labels"}
    step = {k: (v[:, S - 1:S] if k in ("tokens", "frames") else v)
            for k, v in full.items() if k != "labels"}

    _, cache = jax.jit(lambda p, b: lm.prefill(cfg, p, b, max_len))(params, pre)
    assert int(cache["length"]) == S - 1
    logits_dec, cache = jax.jit(
        lambda p, c, b: lm.decode_step(cfg, p, c, b))(params, cache, step)
    assert int(cache["length"]) == S

    hidden, _ = lm.forward(cfg, params, {k: v for k, v in full.items()
                                         if k != "labels"})
    logits_ref = lm.logits_last(cfg, params, hidden)
    assert logits_dec.shape == (B, cfg.vocab)
    np.testing.assert_allclose(np.asarray(logits_dec, np.float32),
                               np.asarray(logits_ref, np.float32),
                               rtol=0.15, atol=0.15)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    """The production config's analytic size is in the published ballpark."""
    cfg = get_config(arch)
    n = cfg.n_params()
    expected = {
        "musicgen-large": (1.5e9, 3.0e9),
        "qwen2-0.5b": (0.3e9, 0.8e9),
        "phi3-mini-3.8b": (3.0e9, 4.5e9),
        "gemma3-27b": (20e9, 32e9),
        "gemma3-4b": (3.0e9, 6.0e9),
        "rwkv6-3b": (2.0e9, 4.0e9),
        # NOTE: the assignment's exact dims (48L × 64e × d_ff 1408) total ~28B;
        # the "16b" in the name matches Moonlight's config only with fewer MoE
        # layers.  Spec dims take precedence (DESIGN.md §5); active ≈ 3B ✓.
        "moonshot-v1-16b-a3b": (24e9, 31e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
        "zamba2-7b": (5.5e9, 9.0e9),
        "llama-3.2-vision-90b": (80e9, 100e9),
    }[arch]
    assert expected[0] < n < expected[1], f"{arch}: {n/1e9:.2f}B params"


@pytest.mark.parametrize("arch", ARCHS)
def test_sharding_specs_cover_big_leaves(arch):
    """Every large leaf of the smoke param tree gets a non-trivial spec on a
    4×2 mesh (divisibility fallback must not silently replicate everything)."""
    cfg = smoke_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices() * 8)[:8].reshape(4, 2), ("data", "model"))
    specs = sharding.param_specs(params, mesh)
    flat_p = sharding.tree_paths(params)
    flat_s = sharding.tree_paths(specs)
    n_sharded = 0
    for path, leaf in flat_p.items():
        spec = flat_s[path]
        assert len(spec) == leaf.ndim or spec == jax.sharding.PartitionSpec()
        if any(a is not None for a in spec):
            n_sharded += 1
    assert n_sharded >= 3, f"{arch}: only {n_sharded} sharded leaves"
