"""Property-based tests (hypothesis) on system invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the optional 'hypothesis' dep (test extra)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import cmaes, eval_dispatch
from repro.core.params import CMAConfig, make_params
from repro.distributed.hlo_analyzer import shape_bytes
from repro.fitness import bbob

SET = dict(deadline=None, max_examples=20)


# ---------------------------------------------------------------------------
# ranking / weights
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(-1e6, 1e6, allow_subnormal=False), min_size=4,
                max_size=40, unique=True),
       st.randoms())
@settings(**SET)
def test_rank_weights_permutation_invariant(vals, rnd):
    """Weights follow fitness RANK: permuting a population of distinct
    fitnesses permutes the weights identically; total stays 1.  (With ties
    the tie-break is by slot index, so equivariance holds only up to
    tied-group weight sums — covered below.  Subnormals excluded: XLA-CPU
    flushes them to zero, manufacturing ties.)"""
    f = np.asarray(vals, np.float64)
    lam = len(f)
    cfg = CMAConfig(n=4, lam=lam)
    params = make_params(cfg)
    w1 = np.asarray(cmaes.rank_weights(jnp.asarray(f), params))
    perm = np.asarray(rnd.sample(range(lam), lam))
    w2 = np.asarray(cmaes.rank_weights(jnp.asarray(f[perm]), params))
    np.testing.assert_allclose(w1[perm], w2, rtol=1e-12)
    np.testing.assert_allclose(w1.sum(), 1.0, rtol=1e-9)


@given(st.lists(st.sampled_from([0.0, 1.0, 2.0]), min_size=4, max_size=24),
       st.randoms())
@settings(**SET)
def test_rank_weights_tied_group_sums_invariant(vals, rnd):
    """Under ties, the total weight per distinct fitness VALUE is
    permutation-invariant (individual tied slots may swap weights)."""
    f = np.asarray(vals, np.float64)
    lam = len(f)
    cfg = CMAConfig(n=4, lam=lam)
    params = make_params(cfg)
    perm = np.asarray(rnd.sample(range(lam), lam))
    w1 = np.asarray(cmaes.rank_weights(jnp.asarray(f), params))
    w2 = np.asarray(cmaes.rank_weights(jnp.asarray(f[perm]), params))
    for v in np.unique(f):
        np.testing.assert_allclose(w1[f == v].sum(), w2[f[perm] == v].sum(),
                                   rtol=1e-12, atol=1e-15)


@given(st.lists(st.floats(-100, 100), min_size=2, max_size=24),
       st.integers(0, 5))
@settings(**SET)
def test_local_ranks_match_global_argsort(vals, n_inf):
    """local_ranks (the distributed path) == centralized argsort ranks,
    including ties and failed (+inf) evaluations."""
    f = np.asarray(vals + [np.inf] * n_inf, np.float64)
    full = jnp.asarray(f)
    order = np.argsort(f, kind="stable")
    central = np.empty(len(f), np.int64)
    central[order] = np.arange(len(f))
    got = np.asarray(eval_dispatch.local_ranks(full, full,
                                               jnp.asarray(0)))
    finite = np.isfinite(f)
    np.testing.assert_array_equal(got[finite], central[finite])


# ---------------------------------------------------------------------------
# BBOB
# ---------------------------------------------------------------------------

@given(st.integers(1, 24), st.integers(2, 12), st.integers(0, 3))
@settings(**SET)
def test_bbob_fopt_is_lower_bound(fid, dim, instance):
    inst = bbob.make_instance(fid, dim, instance)
    X = np.asarray(jax.random.uniform(
        jax.random.PRNGKey(fid * 100 + dim), (16, dim),
        jnp.float64, -5.0, 5.0))
    vals = np.asarray(bbob.evaluate(fid, inst, jnp.asarray(X)))
    assert np.all(np.isfinite(vals))
    assert np.all(vals >= float(inst.f_opt) - 1e-6)


# ---------------------------------------------------------------------------
# chunked CE == full CE
# ---------------------------------------------------------------------------

@given(st.integers(1, 3), st.sampled_from([5, 8, 16]),
       st.sampled_from([3, 8, 64]))
@settings(**SET)
def test_chunked_ce_equals_full(B, S, chunk):
    from repro.configs import smoke_config
    from repro.models import lm
    cfg = dataclasses.replace(smoke_config("phi3-mini-3.8b"),
                              logits_chunk=chunk, dtype="float32")
    key = jax.random.PRNGKey(S * chunk)
    hidden = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    got = float(lm.chunked_ce(cfg, params, hidden, labels))
    head = lm.head_matrix(cfg, params)
    logits = hidden @ head
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = float(jnp.mean(lse - gold))
    np.testing.assert_allclose(got, want, rtol=1e-5)


# ---------------------------------------------------------------------------
# HLO shape parsing
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(1, 64), min_size=0, max_size=4),
       st.sampled_from(["f32", "bf16", "s32", "pred", "f64"]))
@settings(**SET)
def test_shape_bytes_roundtrip(dims, dt):
    size = {"f32": 4, "bf16": 2, "s32": 4, "pred": 1, "f64": 8}[dt]
    n = int(np.prod(dims)) if dims else 1
    s = f"{dt}[{','.join(map(str, dims))}]{{{0}}}"
    assert shape_bytes(s) == n * size


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

@given(st.sampled_from(["qwen2-0.5b", "rwkv6-3b", "zamba2-7b",
                        "phi3.5-moe-42b-a6.6b"]))
@settings(deadline=None, max_examples=4)
def test_sharded_dims_divide_mesh(arch):
    from repro.configs import smoke_config
    from repro.distributed import sharding
    from repro.models import lm
    cfg = smoke_config(arch)
    params = jax.eval_shape(lambda k: lm.init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices() * 8)[:8].reshape(4, 2), ("data", "model"))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    specs = sharding.param_specs(params, mesh)
    flat_p = sharding.tree_paths(params)
    flat_s = sharding.tree_paths(specs)
    for path, leaf in flat_p.items():
        for dim, ax in zip(leaf.shape, flat_s[path]):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([sizes[a] for a in axes]))
            assert dim % total == 0, f"{path}: {dim} vs {axes}"
