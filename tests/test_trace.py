"""Causal tracing + flight recorder (src/repro/obs/trace.py, recorder.py).

Four things are pinned here:

* **tracer semantics** — explicit start/end spans on a monotonic clock,
  parent/trace-id chaining, the bounded ring (evictions counted), the
  context-manager/event sugar and the ``service_trace_*`` series;
* **read surfaces** — Chrome/Perfetto ``trace_event`` assembly routes
  job spans to async per-job tracks and island spans to per-(lane,
  island) lane tracks (schema-validated), the span JSONL round-trips
  through the crash-safe reader, and the ``--summarize`` digest
  (critical path per job, busy/blocked/idle per island) is exact on a
  synthetic trace;
* **the flight recorder** — a bounded last-K ring per island whose
  ``dump`` writes a ``postmortem-<island>-<boundary>.json`` carrying
  the timeline and the island's trace spans;
* **trace ↔ metrics reconciliation** — on real runs the spans agree
  with the counters: one ended "job" root per terminal lifecycle edge,
  span-derived busy seconds match the segment-wall histograms, and the
  PR-6 zero-new-device-syncs pin holds WITH tracing enabled
  (``jax.device_get`` count == boundary-pull observations).
"""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from conftest import hermetic_subproc_env
from repro.core.ipop import run_ipop
from repro.obs import registry as reg_mod
from repro.obs import trace as trace_mod
# NOTE: ``from repro.obs import recorder`` would bind the accessor
# FUNCTION (obs/__init__ re-exports it, shadowing the submodule) — import
# the module's names directly
from repro.obs.recorder import (FlightRecorder, recorder as _recorder,
                                set_recorder)
from repro.obs.registry import MetricsRegistry
from repro.obs.trace import (Tracer, load_jsonl, summarize, validate_chrome)
from repro.service import CampaignRequest, CampaignServer

ROOT = Path(__file__).resolve().parents[1]

KW = dict(lam_start=8, kmax_exp=2)
TERMINAL = ("done", "rejected", "cancelled", "expired", "quarantined",
            "shed")


@pytest.fixture
def fresh_metrics():
    prev = reg_mod.set_metrics(MetricsRegistry())
    yield reg_mod.metrics()
    reg_mod.set_metrics(prev)


@pytest.fixture
def fresh_tracer():
    prev = trace_mod.set_tracer(Tracer())
    yield trace_mod.tracer()
    trace_mod.set_tracer(prev)


@pytest.fixture
def fresh_recorder():
    prev = set_recorder(FlightRecorder())
    yield _recorder()
    set_recorder(prev)


def series(reg, name):
    return {lkey: s for (n, lkey), s in reg._series.items() if n == name}


# ---------------------------------------------------------------------------
# tracer semantics
# ---------------------------------------------------------------------------

def test_span_chain_and_series(fresh_metrics, fresh_tracer):
    tr = fresh_tracer
    root = tr.start("job", job=7)
    child = tr.start("queued", parent=root, job=7)
    assert child.trace_id == root.trace_id          # chained trace
    assert child.parent_id == root.span_id
    assert fresh_metrics.gauge("service_trace_active").value == 2
    tr.end(child)
    tr.end(root, status="done", reason="")
    assert root.attrs["status"] == "done"           # end-attrs merge
    assert root.t1 >= root.t0 and root.dur >= 0
    assert [s.name for s in tr.finished()] == ["queued", "job"]
    assert tr.active_count() == 0
    assert fresh_metrics.gauge("service_trace_active").value == 0
    got = {dict(lkey)["span"]: s.value
           for lkey, s in series(fresh_metrics,
                                 "service_trace_spans_total").items()}
    assert got == {"queued": 1, "job": 1}
    # wall anchor maps perf time to unix time
    assert abs(tr.unix(root.t0) - tr.epoch_unix
               - (root.t0 - tr.epoch_perf)) < 1e-6


def test_ring_is_bounded_and_evictions_counted(fresh_metrics, fresh_tracer):
    tr = Tracer(capacity=8)
    prev = trace_mod.set_tracer(tr)
    try:
        for i in range(20):
            tr.event("pull", island=0, boundary=i)
        spans = tr.finished()
        assert len(spans) == 8 and tr.dropped == 12
        assert [s.attrs["boundary"] for s in spans] == list(range(12, 20))
        assert fresh_metrics.counter(
            "service_trace_dropped_total").value == 12
    finally:
        trace_mod.set_tracer(prev)


def test_span_context_manager_and_event(fresh_metrics, fresh_tracer):
    tr = fresh_tracer
    with tr.span("dispatch", island=0, bucket=1) as s:
        s.attrs["hit"] = True
    assert s.t1 is not None and s.attrs["hit"] is True
    ev = tr.event("health", island=0, state="dead")
    assert ev.t1 == ev.t0 or ev.t1 > ev.t0          # instantaneous marker
    assert tr.active_count() == 0


# ---------------------------------------------------------------------------
# read surfaces: chrome export, jsonl round-trip, digest, CLI
# ---------------------------------------------------------------------------

def _emit_vertical(tr):
    """One job trace + one island lane + one host span."""
    root = tr.start("job", job=1, dim=4)
    q = tr.start("queued", parent=root, job=1)
    tr.end(q)
    r = tr.start("running", parent=root, job=1)
    with tr.span("pull", lane="L", island=0, boundary=0):
        pass
    with tr.span("dispatch", lane="L", island=0, bucket=0, boundary=0):
        pass
    with tr.span("snapshot"):                       # host track
        pass
    tr.end(r)
    tr.end(root, status="done", reason="")
    return root


def test_chrome_export_routes_tracks(fresh_metrics, fresh_tracer, tmp_path):
    tr = fresh_tracer
    root = _emit_vertical(tr)
    path = tmp_path / "trace.json"
    n = tr.export_chrome(str(path))
    obj = json.loads(path.read_text())
    assert len(obj["traceEvents"]) == n
    assert validate_chrome(obj) == []

    evs = obj["traceEvents"]
    # job spans: async b/e pairs on the jobs process, keyed by trace id
    pairs = [e for e in evs if e.get("ph") in ("b", "e")]
    assert pairs and all(e["pid"] == trace_mod.JOB_PID
                         and e["id"] == f"job:{root.trace_id:x}"
                         for e in pairs)
    assert {e["name"] for e in pairs} == {"job", "queued", "running"}
    # island spans: complete events on one lane-track per (lane, island)
    lanes = [e for e in evs if e.get("cat") == "island"]
    assert {e["name"] for e in lanes} == {"pull", "dispatch"}
    assert len({e["tid"] for e in lanes}) == 1      # same (lane, island)
    # host spans land on the host process; metadata names every track
    assert any(e.get("cat") == "host" and e["name"] == "snapshot"
               for e in evs)
    names = [e["args"]["name"] for e in evs if e["ph"] == "M"]
    assert {"host", "islands", "jobs"} <= set(names)
    assert any("island 0" in n for n in names)

    # the validator actually catches malformed events
    bad = {"traceEvents": [{"ph": "Q", "name": "x", "pid": 1},
                           {"ph": "X", "name": "y", "pid": 1, "ts": 0.0},
                           {"ph": "b", "name": "z", "pid": 1, "ts": 0.0}]}
    errs = validate_chrome(bad)
    assert len(errs) == 3


def test_jsonl_round_trip_and_torn_tail(fresh_metrics, fresh_tracer,
                                        tmp_path):
    tr = fresh_tracer
    _emit_vertical(tr)
    path = tmp_path / "spans.jsonl"
    n = tr.export_jsonl(str(path))
    spans = load_jsonl(str(path))
    assert len(spans) == n == len(tr.finished())
    assert spans[-1]["name"] == "job"
    assert spans[-1]["attrs"]["status"] == "done"
    # a torn final line (writer died mid-write) is tolerated...
    with open(path, "a") as fh:
        fh.write('{"trace_id": 1, "name": "tru')
    assert len(load_jsonl(str(path))) == n
    # ...corruption in the MIDDLE is real damage and must raise
    lines = path.read_text().splitlines()
    lines[0] = lines[0][:-5]
    path.write_text("\n".join(lines) + "\n")
    with pytest.raises(json.JSONDecodeError):
        load_jsonl(str(path))


def _sp(sid, name, t0, t1, parent=None, trace=None, **attrs):
    return {"trace_id": trace if trace is not None else sid,
            "span_id": sid, "parent_id": parent, "name": name,
            "t0": float(t0), "t1": float(t1), "dur_s": float(t1 - t0),
            "attrs": attrs}


def test_summarize_digest_is_exact():
    spans = [
        _sp(1, "job", 0.0, 10.0, job=9, status="done"),
        _sp(2, "queued", 0.0, 2.0, parent=1, trace=1, job=9),
        _sp(3, "running", 2.0, 10.0, parent=1, trace=1, job=9),
        # island 0: busy 3s (segment) + blocked 1s (pull), window 10s
        _sp(4, "segment", 0.0, 3.0, island=0, bucket=1),
        _sp(5, "pull", 3.0, 4.0, island=0, boundary=1),
        _sp(6, "health", 9.0, 10.0, island=0, state="alive"),  # neutral
        _sp(7, "orphan", 0.0, 1.0, parent=99),
    ]
    d = summarize(spans)
    assert d["spans"] == 7 and d["open_parents_missing"] == [99]
    (job,) = d["jobs"]
    assert job["job"] == 9 and job["status"] == "done"
    assert job["total_s"] == 10.0
    assert job["critical_path_s"] == pytest.approx(10.0)   # 2s + 8s
    assert job["phases"] == {"queued": 2.0, "running": 8.0}
    isl = d["islands"]["0"]
    assert isl["spans"] == 3
    assert isl["busy_s"] == pytest.approx(3.0)
    assert isl["blocked_s"] == pytest.approx(1.0)
    assert isl["busy_frac"] == pytest.approx(0.3)
    assert isl["blocked_frac"] == pytest.approx(0.1)
    assert isl["idle_frac"] == pytest.approx(0.6)


def test_trace_cli_summarize_and_validate(fresh_metrics, fresh_tracer,
                                          tmp_path):
    tr = fresh_tracer
    _emit_vertical(tr)
    jsonl = tmp_path / "t.jsonl"
    chrome = tmp_path / "t.json"
    tr.export_jsonl(str(jsonl))
    tr.export_chrome(str(chrome))
    env = hermetic_subproc_env()
    out = subprocess.run(
        [sys.executable, "-m", "repro.obs.trace", "--summarize", str(jsonl)],
        check=True, cwd=ROOT, env=env, capture_output=True, text=True)
    digest = json.loads(out.stdout)
    assert digest["jobs"][0]["job"] == 1 and "0" in digest["islands"]
    subprocess.run(
        [sys.executable, "-m", "repro.obs.trace", "--validate", str(chrome)],
        check=True, cwd=ROOT, env=env)
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [{"ph": "?"}]}))
    r = subprocess.run(
        [sys.executable, "-m", "repro.obs.trace", "--validate", str(bad)],
        cwd=ROOT, env=env, capture_output=True, text=True)
    assert r.returncode == 1 and "unknown ph" in r.stderr


def test_schema_check_exits_nonzero_with_unified_diff(tmp_path):
    from repro.obs import schema as schema_mod
    env = hermetic_subproc_env()
    doc = tmp_path / "M.md"
    doc.write_text(f"# metrics\n\n{schema_mod.BEGIN_MARK}\n"
                   f"{schema_mod.END_MARK}\n")
    subprocess.run(
        [sys.executable, "-m", "repro.obs.schema", "--write", str(doc)],
        check=True, cwd=ROOT, env=env)
    subprocess.run(
        [sys.executable, "-m", "repro.obs.schema", "--check", str(doc)],
        check=True, cwd=ROOT, env=env)
    doc.write_text(doc.read_text().replace(
        "service_trace_spans_total", "service_trace_spams_total"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.obs.schema", "--check", str(doc)],
        cwd=ROOT, env=env, capture_output=True, text=True)
    assert r.returncode != 0
    assert "---" in r.stderr and "+++" in r.stderr and "@@" in r.stderr
    assert "-| `service_trace_spams_total`" in r.stderr
    assert "+| `service_trace_spans_total`" in r.stderr


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def test_recorder_ring_and_postmortem_dump(fresh_metrics, fresh_tracer,
                                           fresh_recorder, tmp_path):
    rec = fresh_recorder
    for b in range(20):
        rec.observe(0, b, wall=0.01, fevals=100 * b, grade="alive",
                    verdicts=[])
    rec.observe(1, 0, wall=0.02, fevals=5, grade="alive", verdicts=[])
    assert len(rec.last(0)) == rec.k == 16          # bounded per-island
    assert rec.last(0)[-1]["boundary"] == 19
    assert len(rec.last(1)) == 1                    # rings are per-island
    obs_n = {dict(lkey)["island"]: s.value
             for lkey, s in series(fresh_metrics,
                                   "obs_recorder_observations_total").items()}
    assert obs_n == {"0": 20, "1": 1}

    # island-attributed spans ride into the dump; other islands' don't
    tr = fresh_tracer
    tr.event("pull", island=0, boundary=19)
    tr.event("pull", island=1, boundary=0)
    rec.observe(0, 20, event="fault", grade="dead", reason="killed")
    pm = rec.dump(0, 20, "dead", extra={"reason": "killed"},
                  out_dir=str(tmp_path))
    path = tmp_path / "postmortem-0-20.json"
    assert path.exists() and pm["path"] == str(path)
    disk = json.loads(path.read_text())
    assert disk["island"] == 0 and disk["boundary"] == 20
    assert disk["trigger"] == "dead" and disk["extra"] == {"reason": "killed"}
    assert len(disk["timeline"]) == 16              # the last-K window
    assert disk["timeline"][-1]["event"] == "fault"
    assert [s["attrs"]["island"] for s in disk["spans"]] == [0]
    assert fresh_metrics.counter("obs_recorder_postmortems_total",
                                 trigger="dead").value == 1

    # without an out_dir the dump is in-memory only (the record still
    # returns so callers can attach it to reports)
    pm2 = rec.dump(1, 0, "quarantine")
    assert "path" not in pm2 and pm2["trigger"] == "quarantine"


# ---------------------------------------------------------------------------
# /statusz
# ---------------------------------------------------------------------------

def test_statusz_endpoint_and_server_snapshot(fresh_metrics, fresh_tracer):
    import urllib.error
    import urllib.request

    srv = CampaignServer(bbob_fids=(1, 8), max_budget=3000,
                         rows_per_island=2, **KW)
    srv.submit(CampaignRequest(dim=4, fid=1, budget=1000, seed=3))
    srv.step()
    httpd, port = reg_mod.start_metrics_server(fresh_metrics,
                                               status_fn=srv.statusz)
    try:
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/statusz") as resp:
            assert resp.status == 200
            assert resp.headers["Content-Type"] == "application/json"
            status = json.loads(resp.read().decode())
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics") as resp:
            assert resp.status == 200
    finally:
        httpd.shutdown()
    assert status["queue_depth"] == 0 and status["resident_jobs"] == 1
    assert status["boundary"] >= 1
    assert status["active_traces"] >= 1             # the job's root is open
    (lane,) = status["lanes"].values()
    (isl,) = lane["islands"].values()
    assert isl["health"] == "alive" and 0.0 < isl["occupancy"] <= 1.0
    assert isl["down"] is False

    # a metrics server WITHOUT a status_fn keeps /statusz a 404
    httpd2, port2 = reg_mod.start_metrics_server(fresh_metrics)
    try:
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"http://127.0.0.1:{port2}/statusz")
    finally:
        httpd2.shutdown()
    srv.drain()


# ---------------------------------------------------------------------------
# trace <-> metrics reconciliation on real runs
# ---------------------------------------------------------------------------

def test_bucketed_busy_fraction_reconciles_with_segment_wall(fresh_metrics,
                                                             fresh_tracer):
    run_ipop(lambda X: jnp.sum(X ** 2, axis=-1), 4, jax.random.PRNGKey(0),
             backend="bucketed", max_evals=3000, **KW)
    digest = summarize([s.to_json() for s in fresh_tracer.finished()])
    isl = digest["islands"]["all"]                  # drive_segments' island
    seg_wall = sum(h.sum for h in
                   series(fresh_metrics, "bucketed_segment_wall_s").values())
    sync_wall = fresh_metrics.histogram("bucketed_sync_s").sum
    # span-derived busy/blocked seconds bracket the histogram walls: the
    # spans cover the same host regions plus O(us) bookkeeping
    assert isl["busy_s"] == pytest.approx(seg_wall, rel=0.2, abs=0.05)
    assert isl["blocked_s"] == pytest.approx(sync_wall, rel=0.2, abs=0.05)
    assert isl["busy_frac"] + isl["blocked_frac"] + isl["idle_frac"] \
        == pytest.approx(1.0, abs=1e-3)
    n_segs = sum(s.value for s in
                 series(fresh_metrics, "bucketed_segments_total").values())
    assert sum(1 for s in fresh_tracer.finished()
               if s.name == "segment") == n_segs


def test_service_trace_reconciles_with_lifecycle_and_sync_pin(
        fresh_metrics, fresh_tracer, count_device_get):
    srv = CampaignServer(bbob_fids=(1, 8), max_budget=5000,
                         rows_per_island=2, **KW)
    t_a = srv.submit(CampaignRequest(dim=4, fid=8, budget=2000, seed=7))
    t_b = srv.submit(CampaignRequest(dim=4, fid=1, budget=1500, seed=3))
    srv.drain()
    assert t_a.done and t_b.done

    spans = fresh_tracer.finished()
    roots = [s for s in spans if s.name == "job"]
    terminal_edges = sum(
        s.value for lkey, s in
        series(fresh_metrics, "service_job_lifecycle_total").items()
        if dict(lkey)["to"] in TERMINAL)
    # EXACT reconciliation: one ended root span per terminal edge
    assert len(roots) == terminal_edges == 2
    assert {s.attrs["job"] for s in roots} == {t_a.job_id, t_b.job_id}
    assert all(s.attrs["status"] == "done" for s in roots)
    # every root chains queued -> running lifecycle children
    for r in roots:
        kids = [s for s in spans if s.parent_id == r.span_id]
        assert {"queued", "running"} <= {k.name for k in kids}
        assert all(k.trace_id == r.trace_id for k in kids)
    # job spans never carry island attrs (they must stay on job tracks)
    assert all("island" not in s.attrs for s in roots)

    # the PR-6 zero-new-device-syncs pin, re-asserted WITH tracing on:
    # every device_get is an observed boundary pull, and every pull span
    # is one histogram observation
    pulls = sum(h.count for h in
                series(fresh_metrics, "service_boundary_pull_s").values())
    assert count_device_get["n"] == pulls
    assert sum(1 for s in spans if s.name == "pull") == pulls
    # compile spans saw the warm cache or traced within the bound
    compiles = [s for s in spans if s.name == "compile"]
    assert compiles
    assert sum(1 for s in compiles if not s.attrs["hit"]) \
        <= (KW["kmax_exp"] + 1) * len(srv.lanes)
    # spans counter total == ring content (nothing dropped on this run)
    emitted = sum(s.value for s in
                  series(fresh_metrics, "service_trace_spans_total").values())
    assert emitted == len(spans) and fresh_tracer.dropped == 0
