"""Correctness of the §Perf optimization paths against their baselines:
rowwise MoE dispatch, CMA comm schedules, f32-Gram reduction."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import lm, moe as moe_mod


def test_moe_rowwise_matches_global_when_uncapped():
    """With capacity ≥ worst case, rowwise and global dispatch are the same
    mathematical function (per-row capping is the only semantic delta)."""
    key = jax.random.PRNGKey(0)
    B, S, d, E, k = 2, 16, 32, 8, 2
    p = moe_mod.init_moe_params(key, d, 64, E, True, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32)
    cf = float(E) / k                      # capacity == all tokens, no drops
    out_g, aux_g = moe_mod.moe(p, x, k, cf, dispatch="global")
    out_r, aux_r = moe_mod.moe(p, x, k, cf, dispatch="rowwise")
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux_g), float(aux_r), rtol=1e-5)


def test_moe_rowwise_grads_finite_and_learn():
    cfg = dataclasses.replace(smoke_config("phi3.5-moe-42b-a6.6b"),
                              moe_dispatch="rowwise", attn_impl="flash")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                          0, cfg.vocab),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32),
                                          0, cfg.vocab)}
    val, _ = lm.loss(cfg, params, batch)
    assert np.isfinite(float(val))
    g = jax.grad(lambda p: lm.loss(cfg, p, batch)[0])(params)
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
               for l in jax.tree_util.tree_leaves(g))
    # router must receive gradient (dispatch is differentiable via gates)
    gr = g["segments"]["unit"]["moe"]["router"]
    assert float(jnp.max(jnp.abs(gr))) > 0


@pytest.mark.parametrize("kw", [dict(comm="central"),
                                dict(comm="stacked", gram_dtype="float32")])
def test_kdist_comm_variants_match_stacked(kw):
    """All comm schedules compute the same generation mathematically."""
    from repro.core.strategies import KDistributed
    from repro.fitness import bbob
    inst = bbob.make_instance(8, 6, 1)
    fit = lambda X: bbob.evaluate(8, inst, X)

    ref = KDistributed(n=6, n_devices=8, comm="stacked")
    var = KDistributed(n=6, n_devices=8, **kw)
    _, tr_ref = ref.run_sim(jax.random.PRNGKey(0), fit, total_gens=20)
    _, tr_var = var.run_sim(jax.random.PRNGKey(0), fit, total_gens=20)
    tol = 1e-3 if kw.get("gram_dtype") else 1e-8
    np.testing.assert_allclose(tr_ref["best_f"], tr_var["best_f"],
                               rtol=tol, atol=tol)
