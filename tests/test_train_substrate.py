"""Training substrate: optimizer math, microbatch equivalence, compression,
data determinism, checkpoint roundtrip + crash-restart + elastic re-shard,
and a short end-to-end trainer run whose loss decreases."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs import smoke_config
from repro.data.pipeline import SyntheticTokens
from repro.distributed import compression
from repro.models import lm
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod
from repro.train.trainer import Trainer, TrainerConfig

pytestmark = pytest.mark.slow  # heavy model/train/serve tier — excluded from fast CI


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_matches_reference_scalar():
    """Hand-rolled AdamW vs a trusted numpy reference on a toy quadratic."""
    cfg = opt_mod.AdamWConfig(lr=0.1, beta1=0.9, beta2=0.999, eps=1e-8,
                              weight_decay=0.0, grad_clip=1e9,
                              warmup_steps=0, total_steps=10**9)
    params = {"mlp": {"wi": jnp.asarray([[1.0]])}}
    state = opt_mod.init_opt_state(params)
    p_np, m, v = 1.0, 0.0, 0.0
    for t in range(1, 6):
        g = 2.0 * p_np
        grads = {"mlp": {"wi": jnp.asarray([[g]])}}
        params, state, _ = opt_mod.adamw_update(cfg, params, grads, state)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        p_np -= 0.1 * (m / (1 - 0.9 ** t)) / (np.sqrt(v / (1 - 0.999 ** t))
                                              + 1e-8)
        np.testing.assert_allclose(
            float(params["mlp"]["wi"][0, 0]), p_np, rtol=1e-5)


def test_grad_clip_caps_update():
    cfg = opt_mod.AdamWConfig(grad_clip=1.0, warmup_steps=0,
                              weight_decay=0.0)
    params = {"mlp": {"wi": jnp.ones((4, 4))}}
    state = opt_mod.init_opt_state(params)
    big = {"mlp": {"wi": jnp.full((4, 4), 1e6)}}
    _, _, metrics = opt_mod.adamw_update(cfg, params, big, state)
    assert float(metrics["grad_norm"]) > 1e6  # reported pre-clip


def test_lr_schedule_shape():
    cfg = opt_mod.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              min_lr_frac=0.1)
    lrs = [float(opt_mod.lr_schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 55, 100, 200)]
    assert lrs[0] == 0.0 and abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert 0.1 < lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-6 and abs(lrs[5] - 0.1) < 1e-6


# ---------------------------------------------------------------------------
# microbatching
# ---------------------------------------------------------------------------

def test_microbatch_grads_equal_full_batch():
    import dataclasses
    cfg = dataclasses.replace(smoke_config("qwen2-0.5b"), dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg, seq_len=32, global_batch=4)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    g1, l1, _ = ts_mod.grads_and_loss(cfg, params, batch, microbatches=1)
    g4, l4, _ = ts_mod.grads_and_loss(cfg, params, batch, microbatches=4)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-4)
    flat1 = jax.tree_util.tree_leaves(g1)
    flat4 = jax.tree_util.tree_leaves(g4)
    for a, b in zip(flat1, flat4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-4)


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 3.0
    q, s = compression.quantize_int8(x)
    d = compression.dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(d - x))) <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates_residual():
    params = {"w": jnp.zeros((64,))}
    ef = compression.init_error_feedback(params)
    g = {"w": jnp.full((64,), 1e-4)}       # tiny vs amax → quantizes to 0
    total = jnp.zeros((64,))
    for _ in range(10):
        gq, ef = compression.compress_with_feedback(
            {"w": g["w"] + 0 * total}, ef)
        total = total + gq["w"]
    # with EF the long-run average must track the true gradient
    np.testing.assert_allclose(np.asarray(total) / 10, np.asarray(g["w"]),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_shard_disjoint():
    cfg = smoke_config("qwen2-0.5b")
    a = SyntheticTokens(cfg, 16, 8, shard_index=0, num_shards=2, seed=3)
    b = SyntheticTokens(cfg, 16, 8, shard_index=1, num_shards=2, seed=3)
    full = SyntheticTokens(cfg, 16, 8, shard_index=0, num_shards=1, seed=3)
    ba, bb = a.batch_at(5), b.batch_at(5)
    bf = full.batch_at(5)
    # shard 0 + shard 1 == the global batch, in order
    np.testing.assert_array_equal(
        np.concatenate([ba["tokens"], bb["tokens"]]), bf["tokens"])
    # deterministic replay
    np.testing.assert_array_equal(a.batch_at(5)["tokens"], ba["tokens"])
    # different steps differ
    assert not np.array_equal(a.batch_at(6)["tokens"], ba["tokens"])


def test_data_prefetch_iterator():
    cfg = smoke_config("qwen2-0.5b")
    d = SyntheticTokens(cfg, 16, 2, seed=1)
    it = d.iterate(start_step=3)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], d.batch_at(3)["tokens"])


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

@pytest.fixture
def ckpt_dir(tmp_path):
    return str(tmp_path / "ckpt")


def test_checkpoint_roundtrip(ckpt_dir):
    cfg = smoke_config("rwkv6-3b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = opt_mod.init_opt_state(params)
    store.save(ckpt_dir, 7, (params, opt))
    assert store.latest_step(ckpt_dir) == 7
    p2, o2 = store.restore(ckpt_dir, 7, (params, opt))
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(o2.step) == int(opt.step)


def test_checkpoint_ignores_partial_writes(ckpt_dir):
    params = {"w": jnp.ones((4,))}
    store.save(ckpt_dir, 1, params)
    # simulate a crash mid-write at step 2: .tmp dir only
    os.makedirs(os.path.join(ckpt_dir, "step_00000002.tmp"))
    assert store.latest_step(ckpt_dir) == 1


def test_checkpoint_elastic_reshard(ckpt_dir):
    """Save unsharded, restore under a 2-device mesh with real shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    params = {"mlp": {"wi": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
    store.save(ckpt_dir, 3, params)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"mlp": {"wi": NamedSharding(mesh, P("data", None))}}
    p2 = store.restore(ckpt_dir, 3, params, sh)
    np.testing.assert_array_equal(np.asarray(p2["mlp"]["wi"]),
                                  np.asarray(params["mlp"]["wi"]))
    assert p2["mlp"]["wi"].sharding == sh["mlp"]["wi"]


def test_checkpoint_prune(ckpt_dir):
    params = {"w": jnp.ones((2,))}
    for s in (1, 2, 3, 4, 5):
        store.save(ckpt_dir, s, params)
    store.prune(ckpt_dir, keep=2)
    assert sorted(store.latest_candidates(ckpt_dir)) == [4, 5]


# ---------------------------------------------------------------------------
# trainer end-to-end (the "train a model for a few hundred steps" driver is
# examples/train_lm.py; this is its fast CI-sized variant)
# ---------------------------------------------------------------------------

def test_trainer_loss_decreases_and_restarts(ckpt_dir):
    cfg = smoke_config("qwen2-0.5b")
    tc = TrainerConfig(total_steps=30, ckpt_every=10, ckpt_dir=ckpt_dir,
                       log_every=1000,
                       train=ts_mod.TrainConfig(
                           adamw=opt_mod.AdamWConfig(
                               lr=3e-3, warmup_steps=5, total_steps=30)))
    tr = Trainer(cfg, tc, seq_len=32, global_batch=8, log_fn=lambda s: None)
    tr.run(resume=False)
    first = np.mean([h["loss"] for h in tr.history[:5]])
    last = np.mean([h["loss"] for h in tr.history[-5:]])
    assert last < first - 0.2, f"no learning: {first:.3f} → {last:.3f}"

    # crash-restart: a new trainer resumes from the newest checkpoint
    tr2 = Trainer(cfg, tc, seq_len=32, global_batch=8, log_fn=lambda s: None)
    params, _ = tr2.init_state()
    _, _, start = tr2.try_restore(params, opt_mod.init_opt_state(params))
    assert start == 30
