"""Mesh campaign engine (distributed/mesh_engine.py).

Two layers:

* in-process tests on a 1-device campaign mesh — the mesh engine must be a
  strict superset of the bucketed driver (same trajectories, same compile
  bound, ipop backend wiring) even degenerate, so the default single-device
  tier exercises the full code path;
* the REAL 8-virtual-device equivalence suite runs as a subprocess
  (tests/mesh_check.py) because ``--xla_force_host_platform_device_count``
  must precede jax's first device query — the pattern conftest.py documents.
  The CI mesh job additionally runs that script in-process under the env
  flag (.github/workflows/ci.yml).
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import bucketed
from repro.core.ipop import run_ipop
from repro.distributed import mesh_engine
from repro.fitness import bbob

KW = dict(n=4, lam_start=8, kmax_exp=2, max_evals=5000)


def _bucketed_campaign(kw=KW, runs=2, seed=0, **extra):
    eng = bucketed.BucketedLadderEngine(**kw, **extra)
    return bucketed.run_campaign_bucketed(eng, fids=(1, 8), instances=(1,),
                                          runs=runs, seed=seed)


def _mesh_campaign(strategy, kw=KW, runs=2, seed=0, **extra):
    eng = mesh_engine.MeshCampaignEngine(strategy=strategy, **kw, **extra)
    return eng, mesh_engine.run_campaign_mesh(eng, fids=(1, 8), instances=(1,),
                                              runs=runs, seed=seed)


@pytest.mark.parametrize("strategy", ["ordered", "concurrent"])
def test_mesh_matches_bucketed_on_one_device(strategy):
    """Degenerate 1-device mesh: both strategies must reproduce the bucketed
    driver exactly (same schedule decisions, same per-member trajectories)."""
    res_b = _bucketed_campaign()
    eng_m, res_m = _mesh_campaign(strategy)
    assert eng_m.n_devices == 1

    np.testing.assert_array_equal(res_b.total_fevals, res_m.total_fevals)
    np.testing.assert_allclose(res_b.best_f, res_m.best_f,
                               rtol=1e-5, atol=1e-7)
    for b in range(len(res_b.members)):
        rb = np.asarray(res_b.trace.ran)[b, :, 0]
        rm = np.asarray(res_m.trace.ran)[b, :, 0]
        for field in ("k_idx", "gen", "fevals", "stop_reason", "stopped"):
            np.testing.assert_array_equal(
                np.asarray(getattr(res_b.trace, field))[b, :, 0][rb],
                np.asarray(getattr(res_m.trace, field))[b, :, 0][rm],
                err_msg=field)
    # useful work identical; compile bound holds
    assert res_m.useful_evals == res_b.useful_evals
    assert 1 <= res_m.compiles <= KW["kmax_exp"] + 1
    assert res_m.strategy == strategy and res_m.n_devices == 1
    # the exchanged budget scalar converges to the campaign total
    assert res_m.exchange[-1]["global_fevals"] == int(
        np.sum(res_m.total_fevals))


@pytest.mark.parametrize("strategy", ["ordered", "concurrent"])
def test_run_ipop_mesh_backend_matches_bucketed(strategy):
    inst = bbob.make_instance(8, 4, 1)
    fit = lambda X: bbob.evaluate(8, inst, X)
    kw = dict(lam_start=8, kmax_exp=2, max_evals=4000)
    r_b = run_ipop(fit, 4, jax.random.PRNGKey(7), backend="bucketed", **kw)
    r_m = run_ipop(fit, 4, jax.random.PRNGKey(7), backend="mesh",
                   mesh_strategy=strategy, **kw)
    assert r_b.total_fevals == r_m.total_fevals
    assert len(r_b.descents) == len(r_m.descents)
    for db, dm in zip(r_b.descents, r_m.descents):
        assert db.k_exp == dm.k_exp and db.lam == dm.lam
        np.testing.assert_array_equal(db.fevals, dm.fevals)
        assert db.stop_reason == dm.stop_reason
    np.testing.assert_allclose(r_b.best_f, r_m.best_f, rtol=1e-5, atol=1e-7)


def test_s1_speculative_overlap_trajectory_identity():
    """Satellite (PR 7): with the S1 exchange scalars folded lazily at the
    boundary pull, the ordered driver runs the PR-5 speculative
    double-buffered dispatch (``overlap=True``, now the default).  A
    speculative miss discards its output without touching the accepted
    carry, so the trajectory must be IDENTICAL to the pinned
    ``overlap=False`` driver — and the exchange records must still
    reconcile segment-for-segment."""
    eng_o, res_o = _mesh_campaign("ordered")                 # overlap default
    assert eng_o.overlap
    eng_p, res_p = _mesh_campaign("ordered", overlap=False)
    np.testing.assert_array_equal(res_o.total_fevals, res_p.total_fevals)
    np.testing.assert_array_equal(res_o.best_f, res_p.best_f)   # bitwise
    for field in ("k_idx", "gen", "fevals", "stop_reason", "stopped", "ran"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res_o.trace, field)),
            np.asarray(getattr(res_p.trace, field)), err_msg=field)
    # one exchange record per ACCEPTED segment, same fold sequence, and the
    # budget scalar still converges to the campaign total in both drivers
    assert len(res_o.exchange) == len(res_o.segments)
    assert len(res_p.exchange) == len(res_p.segments)
    assert [e["global_fevals"] for e in res_o.exchange] == \
        [e["global_fevals"] for e in res_p.exchange]
    assert res_o.exchange[-1]["global_fevals"] == int(
        np.sum(res_o.total_fevals))


def test_budget_below_one_generation_is_empty_progress():
    eng = mesh_engine.MeshCampaignEngine(n=3, lam_start=8, kmax_exp=1,
                                         max_evals=4)
    res = mesh_engine.run_campaign_mesh(eng, fids=(1,), runs=2)
    assert res.useful_evals == 0 and res.segments == []
    assert res.trace.ran.shape[1] == 0
    assert res.hit_evals(np.array([1e2])).shape == (2, 1)


def test_unknown_strategy_rejected():
    with pytest.raises(ValueError, match="strategy"):
        mesh_engine.MeshCampaignEngine(n=3, strategy="barrier-free")


def test_island_program_cache_reuses_across_engines():
    """Satellite: island bring-up is O(buckets) — a second campaign (new
    engine instance, same bucket shapes + mesh) reuses every island program
    from the module-level compilation cache instead of re-tracing."""
    mesh_engine.clear_island_program_cache()
    eng1, res1 = _mesh_campaign("concurrent")
    s1 = mesh_engine.island_cache_stats()
    assert s1["traces"] >= 1 and s1["programs"] == s1["traces"]
    eng2, res2 = _mesh_campaign("concurrent", seed=1)
    s2 = mesh_engine.island_cache_stats()
    assert s2["traces"] == s1["traces"], (s1, s2)   # zero new traces
    assert s2["hits"] > s1["hits"]
    # per-engine accounting still bounds per-campaign compiles
    assert 1 <= res2.compiles <= KW["kmax_exp"] + 1
    assert eng1._island_keys == eng2._island_keys
    # a generic-fitness single run keys by the closure object: two calls with
    # distinct closures never share a program (no stale-fitness replay)
    from repro.fitness import bbob
    inst = bbob.make_instance(1, 4, 1)
    eng3 = mesh_engine.MeshCampaignEngine(strategy="concurrent", **KW)
    before = mesh_engine.island_cache_stats()["programs"]
    mesh_engine.run_mesh_single(eng3, jax.random.PRNGKey(0),
                                lambda X: bbob.evaluate(1, inst, X))
    mid = mesh_engine.island_cache_stats()["programs"]
    assert mid > before
    eng4 = mesh_engine.MeshCampaignEngine(strategy="concurrent", **KW)
    mesh_engine.run_mesh_single(eng4, jax.random.PRNGKey(0),
                                lambda X: bbob.evaluate(1, inst, X))
    after = mesh_engine.island_cache_stats()["programs"]
    assert after > mid


@pytest.mark.timeout(540)
def test_mesh_equivalence_on_8_virtual_devices():
    """The acceptance suite: trajectory/ECDF equivalence of both strategies
    vs backend="bucketed" on a real 8-device campaign mesh, compiles ≤
    #buckets under shard_map, inert-padding rows, S2 early sharing — all
    asserted inside tests/mesh_check.py under
    XLA_FLAGS=--xla_force_host_platform_device_count=8."""
    script = os.path.join(os.path.dirname(__file__), "mesh_check.py")
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(root, "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, script], env=env,
                          capture_output=True, text=True, timeout=520)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "MESH-CHECK-OK" in proc.stdout
