"""Unit + convergence tests for the CMA-ES core (paper Alg. 1 / §3.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cmaes, stopping
from repro.core.params import CMAConfig, make_params


def sphere(x):
    return jnp.sum(x ** 2, axis=-1)


def rosenbrock(x):
    return jnp.sum(100.0 * (x[..., 1:] - x[..., :-1] ** 2) ** 2
                   + (1.0 - x[..., :-1]) ** 2, axis=-1)


def elli(x):
    n = x.shape[-1]
    scales = 10.0 ** (6.0 * jnp.arange(n) / (n - 1))
    return jnp.sum(scales * x ** 2, axis=-1)


class TestParams:
    def test_weights_sum_to_one(self):
        for lam in (8, 12, 48, 3072):
            p = make_params(CMAConfig(n=10, lam=lam))
            np.testing.assert_allclose(float(jnp.sum(p.weights)), 1.0, rtol=1e-12)

    def test_weights_decreasing_positive(self):
        p = make_params(CMAConfig(n=10, lam=12))
        w = np.asarray(p.weights)
        mu = int(p.mu)
        assert np.all(np.diff(w[:mu]) < 0) and np.all(w[:mu] > 0)
        assert np.all(w[mu:] == 0)

    def test_padded_lambda(self):
        cfg = CMAConfig(n=10, lam=12, lam_max=96)
        p = make_params(cfg, lam=24)
        assert p.weights.shape == (96,)
        assert int(p.lam) == 24
        np.testing.assert_allclose(float(jnp.sum(p.weights)), 1.0, rtol=1e-12)

    def test_learning_rates_sane(self):
        for n in (2, 10, 40, 200, 1000):
            p = make_params(CMAConfig(n=n, lam=12))
            assert 0 < float(p.c_sigma) < 1
            assert 0 < float(p.c_c) < 1
            assert 0 < float(p.c_1) + float(p.c_mu) < 1
            assert float(p.d_sigma) >= 1

    def test_chi_n(self):
        p = make_params(CMAConfig(n=1000, lam=12))
        # E||N(0,I_n)|| ~ sqrt(n - 0.5) for large n
        assert abs(float(p.chi_n) - np.sqrt(1000)) < 1.0


class TestStepMechanics:
    def test_mean_moves_toward_better_points(self):
        cfg = CMAConfig(n=4, lam=16)
        p = make_params(cfg)
        key = jax.random.PRNGKey(0)
        st = cmaes.init_state(cfg, key, 3.0 * jnp.ones(4), 1.0)
        st2 = cmaes.step(cfg, p, st, sphere, jax.random.PRNGKey(1))
        # one generation on the sphere from (3,3,3,3): mean should move closer to 0
        assert float(sphere(st2.m)) < float(sphere(st.m))
        assert int(st2.gen) == 1
        assert int(st2.fevals) == 16

    def test_covariance_spd_and_symmetric(self):
        cfg = CMAConfig(n=6, lam=12)
        p = make_params(cfg)
        st = cmaes.init_state(cfg, jax.random.PRNGKey(0), jnp.ones(6), 0.5)
        for i in range(30):
            st = cmaes.step(cfg, p, st, rosenbrock, jax.random.PRNGKey(i + 1))
        C = np.asarray(st.C)
        np.testing.assert_allclose(C, C.T, atol=1e-12)
        assert np.all(np.linalg.eigvalsh(C) > 0)

    def test_masked_update_freezes_stopped_descent(self):
        cfg = CMAConfig(n=4, lam=8)
        p = make_params(cfg)
        st = cmaes.init_state(cfg, jax.random.PRNGKey(0), jnp.ones(4), 0.3)
        st = st._replace(stop=jnp.asarray(True))
        st2 = cmaes.step(cfg, p, st, sphere, jax.random.PRNGKey(1))
        np.testing.assert_array_equal(np.asarray(st.m), np.asarray(st2.m))
        assert int(st2.gen) == 0

    def test_rank_weights_match_sort(self):
        cfg = CMAConfig(n=4, lam=8)
        p = make_params(cfg)
        f = jnp.asarray([5.0, 1.0, 3.0, 2.0, 9.0, 0.5, 7.0, 4.0])
        w = cmaes.rank_weights(f, p)
        order = np.argsort(np.asarray(f))
        expected = np.zeros(8)
        expected[order] = np.asarray(p.weights)[:8]
        np.testing.assert_allclose(np.asarray(w), expected, rtol=1e-12)

    def test_masked_fitness_gets_zero_weight(self):
        cfg = CMAConfig(n=4, lam=8)
        p = make_params(cfg)
        f = jnp.asarray([5.0, jnp.inf, 3.0, 2.0, jnp.inf, 0.5, 7.0, 4.0])
        w = cmaes.rank_weights(f, p)
        assert float(w[1]) == 0.0 and float(w[4]) == 0.0


class TestConvergence:
    @pytest.mark.parametrize("n,lam,gens", [(4, 12, 250), (10, 16, 500)])
    def test_sphere(self, n, lam, gens):
        cfg = CMAConfig(n=n, lam=lam)
        p = make_params(cfg)
        final = cmaes.run(cfg, p, sphere, jax.random.PRNGKey(42),
                          2.0 * jnp.ones(n), 1.0, max_gens=gens)
        assert float(final.best_f) < 1e-10

    def test_rosenbrock_10d(self):
        cfg = CMAConfig(n=10, lam=24)
        p = make_params(cfg)
        final = cmaes.run(cfg, p, rosenbrock, jax.random.PRNGKey(3),
                          jnp.zeros(10), 0.5, max_gens=1200)
        assert float(final.best_f) < 1e-8

    def test_high_conditioning_elli(self):
        cfg = CMAConfig(n=8, lam=16)
        p = make_params(cfg)
        final = cmaes.run(cfg, p, elli, jax.random.PRNGKey(7),
                          jnp.ones(8), 0.5, max_gens=1500)
        assert float(final.best_f) < 1e-8

    def test_larger_population_same_machinery(self):
        # IPOP regime: λ = 2^5·12 = 384 on a padded width — one descent still works
        cfg = CMAConfig(n=6, lam=384, lam_max=384)
        p = make_params(cfg)
        final = cmaes.run(cfg, p, sphere, jax.random.PRNGKey(0),
                          jnp.ones(6), 0.5, max_gens=80)
        assert float(final.best_f) < 1e-10


class TestStopping:
    def test_tolfun_triggers_on_converged_sphere(self):
        cfg = CMAConfig(n=4, lam=12)
        p = make_params(cfg)
        final = cmaes.run(cfg, p, sphere, jax.random.PRNGKey(0),
                          jnp.ones(4), 0.5, max_gens=2000)
        assert bool(final.stop)
        reason = int(final.stop_reason)
        assert reason & (stopping.TOLFUN | stopping.TOLFUNHIST | stopping.TOLX)

    def test_maxiter(self):
        cfg = CMAConfig(n=4, lam=12, max_iter=5)
        p = make_params(cfg)
        final = cmaes.run(cfg, p, sphere, jax.random.PRNGKey(0),
                          jnp.ones(4), 0.5, max_gens=10)
        assert bool(final.stop)
        assert int(final.stop_reason) & stopping.MAXITER
        assert int(final.gen) <= 10

    def test_flat_function_stops(self):
        cfg = CMAConfig(n=4, lam=12)
        p = make_params(cfg)
        flat = lambda x: jnp.zeros(x.shape[0], x.dtype)
        final = cmaes.run(cfg, p, flat, jax.random.PRNGKey(0),
                          jnp.ones(4), 0.5, max_gens=500)
        assert bool(final.stop)  # TolUpSigma / TolFun on flat landscape

    def test_reason_to_str(self):
        s = stopping.reason_to_str(stopping.TOLFUN | stopping.TOLX)
        assert "TolFun" in s and "TolX" in s
