"""Device-resident IPOP ladder engine (core/ladder.py).

Covers the PR's acceptance bar: host-loop ↔ ladder trajectory equivalence on
the shared key schedule, in-place doubled-λ restarts, the single-compile
whole-campaign program, and the batched BBOB dispatch it rides on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cmaes, ladder, stopping
from repro.core.ipop import run_ipop, run_ipop_hostloop
from repro.core.params import ladder_params, select_params
from repro.fitness import bbob


# ---------------------------------------------------------------------------
# equivalence: device-resident sequential ladder == host-loop baseline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fid", [1, 8])
def test_ladder_matches_hostloop(fid):
    n = 4
    inst = bbob.make_instance(fid, n, 1)
    fit = lambda X: bbob.evaluate(fid, inst, X)
    kw = dict(lam_start=8, kmax_exp=2, max_evals=5000)
    res_l = run_ipop(fit, n, jax.random.PRNGKey(7), **kw)
    res_h = run_ipop_hostloop(fit, n, jax.random.PRNGKey(7), **kw)

    assert res_l.total_fevals == res_h.total_fevals
    assert len(res_l.descents) == len(res_h.descents)
    for dl, dh in zip(res_l.descents, res_h.descents):
        assert dl.k_exp == dh.k_exp and dl.lam == dh.lam
        assert len(dl.best_f) == len(dh.best_f)
        # the two programs are the same arithmetic modulo batched-vs-unbatched
        # lowering (vmapped eigh/GEMM); on f8 that ~1e-13 seed difference is
        # amplified chaotically late in a descent, hence the loose tolerance
        np.testing.assert_allclose(dl.best_f, dh.best_f, rtol=1e-5, atol=1e-7)
        np.testing.assert_array_equal(dl.fevals, dh.fevals)
        assert dl.stop_reason == dh.stop_reason
    np.testing.assert_allclose(res_l.best_f, res_h.best_f,
                               rtol=1e-5, atol=1e-7)


def test_ladder_rungs_increase_and_budget_respected():
    fn, inst = bbob.make_fitness(1, 4)
    res = run_ipop(fn, 4, jax.random.PRNGKey(0), lam_start=8,
                   kmax_exp=2, max_evals=6000)
    assert res.best_f - float(inst.f_opt) < 1e-8
    lams = [d.lam for d in res.descents]
    assert lams == sorted(lams) and len(lams) >= 1
    assert res.total_fevals <= 6000


# ---------------------------------------------------------------------------
# in-place restart: λ doubles, state re-initializes on device
# ---------------------------------------------------------------------------

def test_forced_stop_doubles_lambda_and_reinits_in_place():
    engine = ladder.LadderEngine(n=4, lam_start=6, kmax_exp=2,
                                 schedule="sequential", max_evals=10**9)
    base = jax.random.PRNGKey(3)
    carry = engine.init_carry(base)
    m_before = np.asarray(carry.states.m).copy()

    # force MaxIter on the next update: gen already at the rung-0 allowance
    big = jnp.broadcast_to(select_params(engine.sparams, 0).max_iter, (1,))
    carry = carry._replace(states=carry.states._replace(
        gen=big.astype(jnp.int32)))

    sphere = lambda X: jnp.sum(X ** 2, axis=-1)
    carry2, trace = engine.gen_step(carry, base, sphere)

    assert bool(trace.stopped[0])
    assert int(trace.stop_reason[0]) & stopping.MAXITER
    # λ doubled: rung 0 → rung 1, params gathered from the stack
    assert int(carry2.k_idx[0]) == 1
    assert int(select_params(engine.sparams, carry2.k_idx[0]).lam) == 12
    # state re-initialized in place (no host round-trip): gen reset, σ reset,
    # C back to identity, mean re-drawn from the fresh incarnation key
    assert int(carry2.states.gen[0]) == 0
    assert float(carry2.states.sigma[0]) == pytest.approx(engine.cfg.sigma0)
    np.testing.assert_array_equal(np.asarray(carry2.states.C[0]), np.eye(4))
    assert int(carry2.incarnation[0]) == 1
    assert int(carry2.states.restarts[0]) == 1
    expected = ladder.fresh_state(
        engine.cfg, ladder.slot_key(base, 0, 1), engine.domain)
    np.testing.assert_allclose(np.asarray(carry2.states.m[0]),
                               np.asarray(expected.m))
    assert not np.allclose(np.asarray(carry2.states.m[0]), m_before[0])


def test_sequential_slot_retires_after_last_rung():
    # a flat function trips TolUpSigma/TolFun quickly on every rung
    flat = lambda X: jnp.zeros(X.shape[0], X.dtype)
    engine = ladder.LadderEngine(n=3, lam_start=4, kmax_exp=1,
                                 schedule="sequential", max_evals=10**6)
    carry, trace = engine.run(jax.random.PRNGKey(0), flat, total_gens=400)
    ran = np.asarray(trace.ran)[:, 0]
    stops = np.asarray(trace.stopped)[:, 0]
    assert stops.sum() == 2            # both rungs stopped
    assert not bool(np.asarray(carry.active)[0])   # slot retired
    assert not ran[-1]                 # trailing generations are masked no-ops


def test_concurrent_schedule_restarts_double_in_place():
    flat = lambda X: jnp.zeros(X.shape[0], X.dtype)
    engine = ladder.LadderEngine(n=3, lam_start=4, kmax_exp=2,
                                 schedule="concurrent", max_evals=10**6)
    carry, trace = engine.run(jax.random.PRNGKey(1), flat, total_gens=300)
    k_idx = np.asarray(carry.k_idx)
    inc = np.asarray(carry.incarnation)
    assert (inc >= 1).all()                       # every slot restarted
    assert (k_idx <= engine.kmax_exp).all()       # doubling clips at the top
    assert k_idx[0] > 0                           # slot 0 walked up the ladder
    assert bool(np.asarray(carry.active).all())   # concurrent slots never retire


# ---------------------------------------------------------------------------
# whole-campaign single program
# ---------------------------------------------------------------------------

def test_campaign_single_compile_and_converges():
    engine = ladder.LadderEngine(n=4, lam_start=8, kmax_exp=2,
                                 schedule="sequential", max_evals=5000)
    res = ladder.run_campaign(engine, fids=(1, 8), instances=(1,), runs=2,
                              seed=0)
    # ≥2 fids × ≥2 runs in ONE jitted/vmapped program: one executable
    assert len(res.members) == 4
    assert res.compiles == 1
    # a second campaign with the same shapes reuses the cached executable
    res2 = ladder.run_campaign(engine, fids=(1, 8), instances=(1,), runs=2,
                               seed=9)
    assert res2.compiles == 1
    # sphere members must converge; every member respects the budget
    err = res.best_f - res.f_opt
    for (fid, _i, _r), e in zip(res.members, err):
        if fid == 1:
            assert e < 1e-8
    assert (res.total_fevals <= 5000).all()
    # campaign member 0 reproduces a standalone run on the same derived key
    inst = bbob.make_instance(1, 4, 1)
    fit = lambda X: bbob.evaluate(1, inst, X)
    solo = run_ipop(fit, 4, jax.random.fold_in(jax.random.PRNGKey(0), 0),
                    lam_start=8, kmax_exp=2, max_evals=5000)
    np.testing.assert_allclose(solo.best_f, res.best_f[0], rtol=1e-9)


def test_campaign_hit_evals_monotone():
    engine = ladder.LadderEngine(n=4, lam_start=8, kmax_exp=1,
                                 schedule="sequential", max_evals=4000)
    res = ladder.run_campaign(engine, fids=(1,), instances=(1,), runs=2)
    hits = res.hit_evals(np.array([1e2, 1e-8]))
    assert hits.shape == (2, 2)
    assert (hits[:, 0] <= hits[:, 1]).all()
    assert np.isfinite(hits[:, 0]).all()


# ---------------------------------------------------------------------------
# batched BBOB dispatch
# ---------------------------------------------------------------------------

def test_evaluate_stacked_matches_static_dispatch():
    n = 5
    fids = (1, 8, 21)          # includes a Gallagher (peak padding path)
    insts = [bbob.make_instance(f, n, 1) for f in fids]
    stacked = bbob.stack_instances(insts)
    assert stacked.peaks_y.shape == (3, 101, n)
    X = jax.random.uniform(jax.random.PRNGKey(0), (3, 7, n),
                           jnp.float64, -5.0, 5.0)
    fid_arr = jnp.asarray(fids, jnp.int32)
    out = jax.jit(lambda fa, i, x: bbob.evaluate_stacked(fa, i, x, fids))(
        fid_arr, stacked, X)
    assert out.shape == (3, 7)
    for j, f in enumerate(fids):
        np.testing.assert_allclose(np.asarray(out[j]),
                                   np.asarray(bbob.evaluate(f, insts[j], X[j])),
                                   rtol=1e-12)


def test_padded_gen_step_matches_dense_step_on_unpadded_width():
    """λ == λ_max: the padded step must reduce to the dense cmaes.step."""
    from repro.core.params import CMAConfig, make_params
    cfg = CMAConfig(n=4, lam=12)
    p = make_params(cfg)
    sphere = lambda X: jnp.sum(X ** 2, axis=-1)
    st = cmaes.init_state(cfg, jax.random.PRNGKey(0), jnp.ones(4), 0.5)
    k = jax.random.PRNGKey(1)
    a = ladder.padded_gen_step(cfg, p, st, k, sphere)
    b = cmaes.step(cfg, p, st, sphere, k)
    np.testing.assert_allclose(np.asarray(a.m), np.asarray(b.m), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(a.C), np.asarray(b.C), rtol=1e-12)


def test_check_stop_stacked_matches_per_slot():
    """The stacked stopping helper agrees with per-rung check_stop calls."""
    from repro.core.params import select_params as sel
    engine = ladder.LadderEngine(n=4, lam_start=6, kmax_exp=2,
                                 schedule="concurrent", max_evals=10**6)
    carry = engine.init_carry(jax.random.PRNGKey(2))
    params_k = sel(engine.sparams, carry.k_idx)
    f_sorted = jnp.broadcast_to(
        jnp.sort(jnp.arange(engine.lam_max, dtype=jnp.float64)),
        (engine.n_slots, engine.lam_max))
    stacked = stopping.check_stop_stacked(engine.cfg, params_k,
                                          carry.states, f_sorted)
    for s in range(engine.n_slots):
        one = stopping.check_stop(engine.cfg, sel(params_k, s),
                                  jax.tree_util.tree_map(lambda a: a[s],
                                                         carry.states),
                                  f_sorted[s])
        assert int(stacked[s]) == int(one)


def test_concurrent_budget_never_overspent():
    """Slots spending from the shared budget in one step must not overshoot."""
    sphere = lambda X: jnp.sum(X ** 2, axis=-1)
    engine = ladder.LadderEngine(n=3, lam_start=4, kmax_exp=2,
                                 schedule="concurrent", max_evals=38)
    carry, _ = engine.run(jax.random.PRNGKey(0), sphere, total_gens=20)
    assert int(carry.total_fevals) <= 38


def test_ladder_params_per_rung_max_iter():
    from repro.core.params import CMAConfig
    cfg = CMAConfig(n=10, lam=48, lam_max=48)
    sp = ladder_params(cfg, lam_start=12, kmax_exp=2)
    assert sp.lam.tolist() == [12, 24, 48]
    mi = sp.max_iter.tolist()
    assert mi[0] > mi[1] > 0           # smaller rungs get more generations
    w = np.asarray(sp.weights)
    assert w.shape == (3, 48)
    np.testing.assert_allclose(w.sum(axis=1), 1.0, rtol=1e-12)
