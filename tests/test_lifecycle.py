"""Request-lifecycle hardening (service/queue.py + service/server.py) — PR 9.

The acceptance bar, in the fast tier:

* **state machine** — every submitted job ends in exactly one terminal
  status (done / rejected / cancelled / expired / quarantined / shed);
  cancellation is honored immediately for queued jobs and at the next
  segment boundary for running ones (with a partial ``IPOPResult``);
  queue-TTL and run deadlines retire jobs host-side at the existing
  boundary pull; poison jobs (non-finite best_f, flat feval watermark)
  are quarantined instead of spinning forever;
* **zero-cost enforcement** — lifecycle verdicts ride the arrays the
  boundary already pulled: no new device syncs (device_get count == pull
  observations) and no new segment programs (compiles ≤ #buckets ×
  #dim-classes throughout a chaos mix);
* **priority shedding + dedup** — a full queue sheds its lowest-priority
  pending ticket for a strictly higher-priority submit; resubmits with a
  ``dedup_key`` are idempotent against live/completed tickets and admit
  fresh after a shed/cancel/expiry;
* **fleet composition** — a quarantined poison job is a JOB verdict, not
  an island one: the health detector grades per-job progress, so the
  island hosting a NaN job stays ALIVE and co-resident healthy jobs
  complete bit-identically (the PR-8 stall-detector blind spot);
* **registry generations** — registering a callable on a live server
  opens generation g+1: new jobs compile fresh gen-g+1 program families
  while resident gen-g lanes run their cached programs untouched (zero
  recompiles, asserted via program-cache stats);
* **durability** — terminal statuses, reasons, pending cancels and dedup
  pins round-trip snapshots; pre-lifecycle (PR-8 shape) snapshots still
  restore.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ipop import run_ipop
from repro.fleet import FleetConfig
from repro.fleet.controller import FleetController
from repro.obs import registry as reg_mod
from repro.obs.registry import MetricsRegistry
from repro.service import (AdmissionQueue, CampaignRequest, CampaignServer,
                           CampaignTicket, FitnessRegistry, QueueFull)
from repro.service.server import program_cache_stats

KW = dict(lam_start=8, kmax_exp=2)


def shifted_sphere(X):
    return jnp.sum((X - 1.2) ** 2, axis=-1)


def nan_fitness(X):
    """A poison objective: every evaluation is NaN, so best_f never leaves
    inf (NaN comparisons are False in the ladder's best update)."""
    return jnp.full(X.shape[:-1], jnp.nan, X.dtype)


def make_registry():
    reg = FitnessRegistry()
    reg.register("shifted_sphere", shifted_sphere)
    reg.register("nan_fn", nan_fitness)
    return reg


def make_server(**extra):
    kw = dict(registry=make_registry(), bbob_fids=(1, 8), max_budget=5000,
              rows_per_island=2, **KW)
    kw.update(extra)
    return CampaignServer(**kw)


@pytest.fixture
def fresh_metrics():
    prev = reg_mod.set_metrics(MetricsRegistry())
    yield reg_mod.metrics()
    reg_mod.set_metrics(prev)


@pytest.fixture
def count_device_get(monkeypatch):
    calls = {"n": 0}
    real = jax.device_get

    def counting(x):
        calls["n"] += 1
        return real(x)

    monkeypatch.setattr(jax, "device_get", counting)
    return calls


def series(reg, name):
    return {lkey: s for (n, lkey), s in reg._series.items() if n == name}


def counter_sum(reg, name, **labels):
    return sum(s.value for lkey, s in series(reg, name).items()
               if all(dict(lkey).get(k) == v for k, v in labels.items()))


def heap_ok(heap):
    """The binary-heap invariant every non-destructive operation must keep."""
    return all(heap[(i - 1) // 2] <= heap[i] for i in range(1, len(heap)))


# ---------------------------------------------------------------------------
# the state machine: cancel / deadline / TTL / quarantine
# ---------------------------------------------------------------------------

def test_cancel_queued_and_running():
    srv = make_server(rows_per_island=1)
    t_run = srv.submit(CampaignRequest(dim=4, fid=8, budget=3000, seed=7))
    t_q = srv.submit(CampaignRequest(dim=4, fid=1, budget=2000, seed=3))
    srv.step()                          # t_run admitted, t_q queued (1 row)
    assert t_run.status == "running" and t_q.status == "queued"

    # queued cancel: immediate, idempotent
    assert srv.cancel(t_q.job_id) is True
    assert t_q.status == "cancelled" and t_q.reason == "cancelled by client"
    assert srv.cancel(t_q.job_id) is False      # already terminal
    assert len(srv.queue) == 0

    # running cancel: honored at the next boundary, with a partial result
    assert srv.cancel(t_run.job_id) is True
    assert t_run.status == "running"            # not yet — boundary applies it
    srv.step()
    assert t_run.status == "cancelled"
    assert t_run.reason == "cancelled by client"
    assert t_run.result is not None             # trajectory up to the boundary
    assert 0 < t_run.fevals < t_run.request.budget
    assert t_run.result.total_fevals == t_run.fevals
    assert srv.cancel(12345) is False           # unknown id
    srv.drain()                                 # idles out cleanly
    assert all(t.terminal for t in srv.tickets.values())


def test_deadline_and_ttl_expiry():
    srv = make_server(rows_per_island=1)
    t_run = srv.submit(CampaignRequest(dim=4, fid=8, budget=3000, seed=7,
                                       deadline_s=3600.0))
    t_q = srv.submit(CampaignRequest(dim=4, fid=1, budget=2000, seed=3,
                                     queue_ttl_s=3600.0))
    assert t_run.deadline_at is not None and t_q.ttl_at is not None
    srv.step()                          # t_run admitted (deadline survived)
    assert t_run.status == "running" and t_q.status == "queued"

    # queue TTL: force the armed instant into the past — next step expires
    # the ticket before admission, host clock only
    t_q.ttl_at = 0.0
    srv.step()
    assert t_q.status == "expired" and t_q.reason == "queue TTL exceeded"

    # run deadline: enforced at the boundary pull, partial result lands
    t_run.deadline_at = 0.0
    srv.step()
    assert t_run.status == "expired"
    assert t_run.reason == "deadline exceeded while running"
    assert t_run.result is not None and t_run.fevals > 0
    srv.drain()


def test_nan_poison_is_quarantined_with_partial_result(fresh_metrics):
    reg = fresh_metrics
    srv = make_server()
    t_bad = srv.submit(CampaignRequest(dim=4, fitness="nan_fn",
                                       budget=3000, seed=1))
    t_ok = srv.submit(CampaignRequest(dim=4, fid=1, budget=1500, seed=3))
    srv.drain()
    assert t_bad.status == "quarantined"
    assert "non-finite" in t_bad.reason
    assert t_bad.result is not None and t_bad.fevals > 0
    assert not np.isfinite(t_bad.best_f)
    assert t_bad.fevals < t_bad.request.budget  # retired at the FIRST verdict
    assert t_ok.done                            # co-tenant unaffected
    assert counter_sum(reg, "service_quarantine_total",
                       reason="nonfinite") == 1
    assert counter_sum(reg, "service_job_lifecycle_total",
                       **{"from": "running", "to": "quarantined"}) == 1


def test_no_progress_watermark_verdict():
    """Unit test of the flat-feval quarantine: only boundaries the job was
    actually DISPATCHED charge the watermark, progress resets it, and the
    explicit-cancel verdict outranks it."""
    srv = make_server(quarantine_stall_boundaries=2)
    t = CampaignTicket(job_id=99,
                       request=CampaignRequest(dim=4, fid=1, budget=100))
    v = srv._row_verdict(t, 99, 10, 1.0, True, now=0.0)
    assert v is None                            # first observation
    assert srv._row_verdict(t, 99, 10, 1.0, False, now=0.0) is None
    assert srv._noprog[99][1] == 0              # not dispatched: not charged
    assert srv._row_verdict(t, 99, 10, 1.0, True, now=0.0) is None  # flat #1
    v = srv._row_verdict(t, 99, 10, 1.0, True, now=0.0)             # flat #2
    assert v is not None and v[0] == "quarantined" and "no progress" in v[1]
    assert 99 not in srv._noprog                # verdict clears the record
    # progress resets the count
    assert srv._row_verdict(t, 99, 10, 1.0, True, now=0.0) is None
    assert srv._row_verdict(t, 99, 10, 1.0, True, now=0.0) is None
    assert srv._row_verdict(t, 99, 20, 1.0, True, now=0.0) is None
    assert srv._noprog[99] == (20, 0)
    # precedence: cancel > deadline > poison
    srv._cancels.add(99)
    t.deadline_at = 0.0
    assert srv._row_verdict(t, 99, 20, float("nan"), True,
                            now=1.0)[0] == "cancelled"
    srv._cancels.discard(99)
    assert srv._row_verdict(t, 99, 20, float("nan"), True,
                            now=1.0)[0] == "expired"
    t.deadline_at = None
    assert srv._row_verdict(t, 99, 20, float("nan"), True,
                            now=1.0)[0] == "quarantined"


# ---------------------------------------------------------------------------
# admission queue: shedding, non-destructive take, no starvation
# ---------------------------------------------------------------------------

def test_queue_sheds_lowest_priority_on_strict_win():
    q = AdmissionQueue(max_pending=2)
    t_mid = q.submit(CampaignRequest(dim=4, fid=1, budget=100, priority=1))
    t_lo = q.submit(CampaignRequest(dim=4, fid=1, budget=100, priority=0))
    # an equal-priority submit still gets backpressure (ties never shed)
    with pytest.raises(QueueFull):
        q.submit(CampaignRequest(dim=4, fid=1, budget=100, priority=0))
    # a strictly higher-priority submit displaces the lowest-priority ticket
    t_hi = q.submit(CampaignRequest(dim=4, fid=1, budget=100, priority=5))
    assert t_lo.status == "shed" and "priority-5" in t_lo.reason
    # the new lowest is prio-1: another tie is backpressure again
    with pytest.raises(QueueFull):
        q.submit(CampaignRequest(dim=4, fid=1, budget=100, priority=1))
    assert t_lo.terminal
    assert q.drain_shed() == [t_lo] and q.drain_shed() == []
    assert len(q) == 2 and heap_ok(q._heap)
    assert {t.job_id for t in q.pending()} == {t_mid.job_id, t_hi.job_id}


def test_take_is_nondestructive_and_never_starves():
    rng = np.random.default_rng(0)
    q = AdmissionQueue(max_pending=64)
    wide = q.submit(CampaignRequest(dim=16, fid=1, budget=100, priority=9))
    narrow = [q.submit(CampaignRequest(dim=4, fid=1, budget=100,
                                       priority=int(rng.integers(0, 4))))
              for _ in range(20)]
    out = []
    while True:
        item = q.take(lambda r: r.dim == 4)
        if item is None:
            break
        assert heap_ok(q._heap)         # removal never breaks the heap
        out.append(item[1])
    # the blocked high-priority wide job never starves placeable narrow ones
    assert len(out) == len(narrow)
    prios = [t.request.priority for t in out]
    assert prios == sorted(prios, reverse=True)
    for p in set(prios):                # FIFO within a priority
        ids = [t.job_id for t in out if t.request.priority == p]
        assert ids == sorted(ids)
    assert len(q) == 1
    assert q.take()[1] is wide
    # remove + expire keep the invariant too
    for _ in range(12):
        q.submit(CampaignRequest(dim=4, fid=1, budget=100,
                                 priority=int(rng.integers(0, 4))))
    victims = [t for i, t in enumerate(q.pending()) if i % 3 == 0]
    for t in victims[:2]:
        assert q.remove(t.job_id) is t and heap_ok(q._heap)
    for t in victims[2:]:
        t.ttl_at = 0.0
    expired = q.expire(now_s=1.0)
    assert heap_ok(q._heap)
    assert sorted(t.job_id for t in expired) == sorted(
        t.job_id for t in victims[2:])
    assert all(t.status == "expired" for t in expired)


def test_server_shed_then_dedup_resubmit(fresh_metrics):
    reg = fresh_metrics
    srv = make_server(max_pending=2)
    r1 = CampaignRequest(dim=4, fid=1, budget=1200, seed=0, dedup_key="a")
    r2 = CampaignRequest(dim=4, fid=8, budget=1200, seed=1, dedup_key="b")
    t1 = srv.submit(r1)
    t2 = srv.submit(r2)
    # dedup short-circuit: a live ticket's key returns the SAME ticket
    assert srv.submit(CampaignRequest(dim=4, fid=1, budget=1200, seed=0,
                                      dedup_key="a")) is t1
    t3 = srv.submit(CampaignRequest(dim=4, fid=1, budget=800, seed=2,
                                    priority=5))
    assert t2.status == "shed"          # lowest-priority youngest displaced
    assert counter_sum(reg, "service_shed_total") == 1
    assert counter_sum(reg, "service_jobs_total", event="shed") == 1
    srv.drain()
    assert t1.done and t3.done
    # terminal-failed key admits the retry fresh; done key stays pinned
    t2b = srv.submit(CampaignRequest(dim=4, fid=8, budget=1200, seed=1,
                                     dedup_key="b"))
    assert t2b is not t2 and t2b.job_id != t2.job_id
    assert srv.submit(CampaignRequest(dim=4, fid=1, budget=1200, seed=0,
                                      dedup_key="a")) is t1   # done: pinned
    srv.drain()
    assert t2b.done
    # releasing a ticket unpins its key: the next resubmit starts fresh
    srv.release_ticket(t1.job_id)
    t1b = srv.submit(CampaignRequest(dim=4, fid=1, budget=1200, seed=0,
                                     dedup_key="a"))
    assert t1b.job_id != t1.job_id
    srv.drain()
    assert t1b.done


# ---------------------------------------------------------------------------
# the zero-cost contract: no new syncs, no new programs
# ---------------------------------------------------------------------------

def test_lifecycle_mix_adds_no_syncs_or_programs(fresh_metrics,
                                                 count_device_get):
    reg = fresh_metrics
    srv = make_server(rows_per_island=2, max_pending=2)
    t_bad = srv.submit(CampaignRequest(dim=4, fitness="nan_fn",
                                       budget=2500, seed=1))
    t_run = srv.submit(CampaignRequest(dim=4, fid=8, budget=3000, seed=7))
    srv.step()                          # both admitted
    srv.cancel(t_run.job_id)
    t_q1 = srv.submit(CampaignRequest(dim=4, fid=1, budget=1000, seed=2,
                                      queue_ttl_s=3600.0))
    t_q2 = srv.submit(CampaignRequest(dim=4, fid=1, budget=1000, seed=3))
    t_hi = srv.submit(CampaignRequest(dim=4, fid=1, budget=800, seed=4,
                                      priority=5))
    assert t_q2.status == "shed"
    t_q1.ttl_at = 0.0                   # expires at the next step
    srv.drain()

    assert t_bad.status == "quarantined"
    assert t_run.status == "cancelled"
    assert t_q1.status == "expired"
    assert t_hi.done
    assert all(t.terminal for t in srv.tickets.values())
    # the whole state machine is in the lifecycle series
    edges = {(dict(lkey)["from"], dict(lkey)["to"]): s.value
             for lkey, s in series(reg, "service_job_lifecycle_total").items()}
    assert edges[("new", "queued")] == 5
    assert edges[("queued", "shed")] == 1
    assert edges[("queued", "expired")] == 1
    assert edges[("running", "cancelled")] == 1
    assert edges[("running", "quarantined")] == 1
    assert edges[("running", "done")] == 1

    # zero new syncs: every device_get is an observed boundary pull —
    # cancel/deadline/quarantine enforcement pulled nothing extra
    pulls = sum(h.count for h in
                series(reg, "service_boundary_pull_s").values())
    assert pulls > 0
    assert count_device_get["n"] == pulls
    # zero new programs: the compile bound holds through the chaos mix
    assert srv.segment_compiles() <= (KW["kmax_exp"] + 1) * len(srv.lanes)


# ---------------------------------------------------------------------------
# fleet composition: poison is a job verdict, never an island one
# ---------------------------------------------------------------------------

def test_poison_job_never_kills_island(fresh_metrics, tmp_path):
    reg = fresh_metrics
    # reference: the healthy job alone, unsupervised
    ref = make_server()
    t_ref = ref.submit(CampaignRequest(dim=4, fid=8, budget=2500, seed=7))
    ref.drain()

    # stall_boundaries=1: a single mis-graded no-progress round would kill
    # the island — the tightest setting the blind spot could trip
    srv = make_server(snapshot_dir=str(tmp_path / "ck"))
    ctl = FleetController(srv, FleetConfig(snapshot_every=2,
                                           stall_boundaries=1))
    t_bad = srv.submit(CampaignRequest(dim=4, fitness="nan_fn",
                                       budget=2500, seed=1))
    t_ok = srv.submit(CampaignRequest(dim=4, fid=8, budget=2500, seed=7))
    ctl.drain()
    assert t_bad.status == "quarantined"
    assert t_ok.done
    assert ctl.sup.health.state(0) == "alive"
    assert counter_sum(reg, "fleet_failures_total") == 0
    # the healthy co-tenant is bit-identical to running alone (row-keyed
    # sampling: a quarantined neighbour never perturbs a trajectory)
    assert t_ok.fevals == t_ref.fevals
    np.testing.assert_allclose(t_ok.best_f, t_ref.best_f,
                               rtol=1e-12, atol=1e-12)
    assert len(t_ok.result.descents) == len(t_ref.result.descents)
    for a, b in zip(t_ref.result.descents, t_ok.result.descents):
        np.testing.assert_array_equal(np.asarray(a.fevals),
                                      np.asarray(b.fevals))
        np.testing.assert_allclose(a.best_f, b.best_f,
                                   rtol=1e-12, atol=1e-12)
    # an island whose residents are all retired dispatches nothing and must
    # never be graded "stalled" — idle supervised rounds keep it ALIVE
    for _ in range(3):
        ctl.step()
    assert ctl.sup.health.state(0) == "alive"


def test_slot_reuse_is_not_a_corrupt_read(fresh_metrics, tmp_path):
    """Readmission into a freed row resets its feval counter to 0 — a
    legitimate regress of the island's summed watermark that must trigger
    neither the corrupt-read retry nor a stall/dead verdict."""
    reg = fresh_metrics
    srv = make_server(rows_per_island=1, snapshot_dir=str(tmp_path / "ck"))
    ctl = FleetController(srv, FleetConfig(snapshot_every=2,
                                           stall_boundaries=2, retries=1))
    t_a = srv.submit(CampaignRequest(dim=4, fid=1, budget=1500, seed=3))
    t_b = srv.submit(CampaignRequest(dim=4, fid=8, budget=1500, seed=5))
    ctl.drain()                         # B re-uses A's row after A finishes
    assert t_a.done and t_b.done
    assert ctl.sup.health.state(0) == "alive"
    assert counter_sum(reg, "fleet_pull_retries_total") == 0


# ---------------------------------------------------------------------------
# registry generations: versioned rollout without recompiling residents
# ---------------------------------------------------------------------------

def test_registry_rollout_zero_recompiles_of_resident_lanes():
    srv = make_server()
    t0 = srv.submit(CampaignRequest(dim=4, fitness="shifted_sphere",
                                    budget=3000, seed=5))
    for _ in range(2):
        srv.step()                      # gen-0 lane is mid-flight
    lane0 = srv.lanes[srv._lane_key(t0.request)]
    assert lane0.key[4] == 0
    progs0 = set(lane0.used_programs)
    pc0 = program_cache_stats()

    # live rollout: registering on a running server opens generation 1
    srv.registry.register("late_sphere",
                          lambda X: jnp.sum((X - 0.5) ** 2, axis=-1))
    assert srv.registry.generation == 1
    t1 = srv.submit(CampaignRequest(dim=4, fitness="late_sphere",
                                    budget=1500, seed=9))
    srv.drain()
    assert t0.done and t1.done

    lane1 = srv.lanes[srv._lane_key(t1.request)]
    assert lane1.key[4] == 1 and lane1.key[:4] == lane0.key[:4]
    assert len(lane1.custom_fns) == len(lane0.custom_fns) + 1
    # every trace since the rollout is a NEW program key (gen-1 families or
    # gen-0 buckets first reached post-rollout) — no resident family was
    # re-traced: the cache delta equals exactly the set of new keys
    pc1 = program_cache_stats()
    new_keys = (lane0.used_programs | lane1.used_programs) - progs0
    assert pc1["traces"] - pc0["traces"] == len(new_keys)
    assert pc1["hits"] > pc0["hits"]    # the resident lane kept reusing
    assert lane1.used_programs.isdisjoint(lane0.used_programs)
    n_buckets = KW["kmax_exp"] + 1
    assert srv.segment_compiles() <= n_buckets * len(srv.lanes)
    # the resident gen-0 job ran to its normal trajectory through the rollout
    r = run_ipop(shifted_sphere, 4, jax.random.PRNGKey(5),
                 backend="bucketed", max_evals=3000, **KW)
    assert r.total_fevals == t0.fevals
    np.testing.assert_allclose(r.best_f, t0.best_f, rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# durability: lifecycle state rides snapshots; old snapshots still restore
# ---------------------------------------------------------------------------

def test_snapshot_roundtrips_lifecycle_states_and_dedup(tmp_path):
    d = str(tmp_path / "ck")
    srv = make_server(snapshot_dir=d)
    t_run = srv.submit(CampaignRequest(dim=4, fid=8, budget=3000, seed=7,
                                       dedup_key="keep"))
    t_bad = srv.submit(CampaignRequest(dim=4, fitness="nan_fn",
                                       budget=2000, seed=1))
    srv.step()
    srv.step()                          # nan job quarantined at boundary 2
    assert t_bad.status == "quarantined"
    t_c = srv.submit(CampaignRequest(dim=6, fid=1, budget=1000, seed=2))
    srv.cancel(t_c.job_id)              # queued → cancelled
    t_e = srv.submit(CampaignRequest(dim=6, fid=1, budget=1000, seed=3,
                                     queue_ttl_s=3600.0))
    t_e.ttl_at = 0.0
    srv._expire_queued()                # queued → expired
    srv.cancel(t_run.job_id)            # running → PENDING cancel
    srv.snapshot()
    del srv

    srv2 = CampaignServer.restore(d, registry=make_registry())
    r_run = srv2.tickets[t_run.job_id]
    assert r_run.status == "running"
    assert srv2._cancels == {t_run.job_id}      # pending cancel rode along
    assert srv2._dedup == {"keep": t_run.job_id}
    for t in (t_bad, t_c, t_e):
        r = srv2.tickets[t.job_id]
        assert r.status == t.status and r.reason == t.reason
    assert srv2.tickets[t_bad.job_id].result is not None
    # dedup against the restored live ticket returns it unchanged
    assert srv2.submit(CampaignRequest(dim=4, fid=8, budget=3000, seed=7,
                                       dedup_key="keep")) is r_run
    srv2.drain()
    assert r_run.status == "cancelled"          # honored post-restore
    assert r_run.result is not None
    # a terminal-failed key now admits fresh
    t_new = srv2.submit(CampaignRequest(dim=4, fid=8, budget=1200, seed=7,
                                        dedup_key="keep"))
    assert t_new.job_id != t_run.job_id
    srv2.drain()
    assert t_new.done


def test_pre_lifecycle_snapshot_still_restores(tmp_path):
    """A PR-8-era snapshot — 4-tuple lane keys, no cancels/dedup/registry
    meta, no lifecycle request fields — restores with empty defaults."""
    d = str(tmp_path / "ck")
    srv = make_server(snapshot_dir=d)
    t_done = srv.submit(CampaignRequest(dim=4, fid=1, budget=1500, seed=5))
    srv.drain()
    t_live = srv.submit(CampaignRequest(dim=4, fid=8, budget=3000, seed=7))
    for _ in range(3):
        srv.step()
    step = srv.snapshot()
    srv.drain()                         # uninterrupted reference
    ref_live = srv.tickets[t_live.job_id]
    del srv

    # strip every lifecycle-era key, reverting the snapshot to its PR-8 shape
    p = os.path.join(d, f"step_{step:08d}", "meta.json")
    with open(p) as fh:
        meta = json.load(fh)
    for k in ("cancels", "dedup", "registry"):
        meta.pop(k)
    for k in ("quarantine_nonfinite", "quarantine_stall_boundaries"):
        meta["config"].pop(k)
    for lm in meta["lanes"]:
        assert len(lm["key"]) == 5
        lm["key"] = lm["key"][:4]
    for jm in meta["jobs"].values():
        jm.pop("reason")
        for k in ("queue_ttl_s", "deadline_s", "dedup_key"):
            jm["request"].pop(k)
    with open(p, "w") as fh:
        json.dump(meta, fh)

    srv2 = CampaignServer.restore(d, registry=make_registry())
    assert all(len(k) == 5 and k[4] == 0 for k in srv2.lanes)   # padded
    assert srv2._cancels == set() and srv2._dedup == {}
    assert srv2.tickets[t_done.job_id].done
    srv2.drain()
    got = srv2.tickets[t_live.job_id]
    assert got.done
    assert got.fevals == ref_live.fevals
    np.testing.assert_allclose(got.best_f, ref_live.best_f,
                               rtol=1e-12, atol=1e-12)
