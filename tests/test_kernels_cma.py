"""Pallas kernel ↔ pure-jnp oracle allclose sweeps (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.cma_sample import cma_sample
from repro.kernels.cma_update import cma_rank_mu_update

SHAPES = [  # (lam, n)
    (8, 4), (12, 10), (24, 40), (48, 130), (96, 200), (12, 257), (384, 64),
]
DTYPES = [jnp.float32, jnp.float64]


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, dtype=dtype)


@pytest.mark.parametrize("lam,n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_cma_sample_matches_ref(lam, n, dtype):
    k = jax.random.split(jax.random.PRNGKey(lam * 1000 + n), 4)
    m = _rand(k[0], (n,), dtype)
    B = _rand(k[1], (n, n), dtype)
    D = jnp.abs(_rand(k[2], (n,), dtype)) + 0.1
    Z = _rand(k[3], (lam, n), dtype)
    sigma = jnp.asarray(0.37, dtype)
    got = cma_sample(m, sigma, B, D, Z, interpret=True)
    want = ref.sample_points(m, sigma, B, D, Z)
    tol = 1e-5 if dtype == jnp.float32 else 1e-6  # kernel accumulates in f32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("lam,n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_cma_rank_mu_update_matches_ref(lam, n, dtype):
    k = jax.random.split(jax.random.PRNGKey(lam * 7 + n), 4)
    C = _rand(k[0], (n, n), dtype)
    C = C @ C.T / n + jnp.eye(n, dtype=dtype)
    Y = _rand(k[1], (lam, n), dtype)
    w = jnp.abs(_rand(k[2], (lam,), dtype))
    w = w / jnp.sum(w)
    p_c = _rand(k[3], (n,), dtype)
    decay, c_mu, c_1 = 0.9, 0.08, 0.02
    got = cma_rank_mu_update(C, Y, w, p_c, decay, c_mu, c_1, interpret=True)
    want = ref.rank_mu_update(C, Y, w, p_c,
                              jnp.asarray(decay, dtype), jnp.asarray(c_mu, dtype),
                              jnp.asarray(c_1, dtype))
    tol = 1e-5 if dtype == jnp.float32 else 1e-6
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


def test_update_zero_weights_padding_no_effect():
    """Padded population slots (w=0) must not change the result."""
    lam, n, pad = 12, 16, 20
    k = jax.random.split(jax.random.PRNGKey(0), 4)
    C = jnp.eye(n, dtype=jnp.float64)
    Y = _rand(k[0], (lam, n), jnp.float64)
    Ypad = jnp.concatenate([Y, 1e6 * jnp.ones((pad, n))])  # garbage rows
    w = jnp.abs(_rand(k[1], (lam,), jnp.float64))
    wpad = jnp.concatenate([w, jnp.zeros(pad)])
    p_c = _rand(k[2], (n,), jnp.float64)
    a = cma_rank_mu_update(C, Y, w, p_c, 0.9, 0.08, 0.02, interpret=True)
    b = cma_rank_mu_update(C, Ypad, wpad, p_c, 0.9, 0.08, 0.02, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-12)


def test_block_shape_sweep():
    """Different BlockSpec tilings must agree (shape-edge correctness)."""
    lam, n = 40, 96
    k = jax.random.split(jax.random.PRNGKey(5), 3)
    C = jnp.eye(n, dtype=jnp.float32)
    Y = _rand(k[0], (lam, n), jnp.float32)
    w = jnp.ones((lam,), jnp.float32) / lam
    p_c = _rand(k[1], (n,), jnp.float32)
    want = ref.rank_mu_update(C, Y, w, p_c, jnp.float32(0.9), jnp.float32(0.08),
                              jnp.float32(0.02))
    for bi, bj, bk in [(32, 32, 8), (96, 96, 40), (64, 32, 16), (128, 128, 128)]:
        got = cma_rank_mu_update(C, Y, w, p_c, 0.9, 0.08, 0.02,
                                 bi=bi, bj=bj, bk=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
