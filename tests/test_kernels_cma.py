"""Pallas kernel ↔ pure-jnp oracle allclose sweeps (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.cma_gen import cma_gen_sample
from repro.kernels.cma_sample import cma_sample
from repro.kernels.cma_update import cma_rank_mu_update

SHAPES = [  # (lam, n)
    (8, 4), (12, 10), (24, 40), (48, 130), (96, 200), (12, 257), (384, 64),
]
DTYPES = [jnp.float32, jnp.float64]


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, dtype=dtype)


@pytest.mark.parametrize("lam,n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_cma_sample_matches_ref(lam, n, dtype):
    k = jax.random.split(jax.random.PRNGKey(lam * 1000 + n), 4)
    m = _rand(k[0], (n,), dtype)
    B = _rand(k[1], (n, n), dtype)
    D = jnp.abs(_rand(k[2], (n,), dtype)) + 0.1
    Z = _rand(k[3], (lam, n), dtype)
    sigma = jnp.asarray(0.37, dtype)
    got = cma_sample(m, sigma, B, D, Z, interpret=True)
    want = ref.sample_points(m, sigma, B, D, Z)
    tol = 1e-5 if dtype == jnp.float32 else 1e-6  # kernel accumulates in f32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("lam,n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_cma_rank_mu_update_matches_ref(lam, n, dtype):
    k = jax.random.split(jax.random.PRNGKey(lam * 7 + n), 4)
    C = _rand(k[0], (n, n), dtype)
    C = C @ C.T / n + jnp.eye(n, dtype=dtype)
    Y = _rand(k[1], (lam, n), dtype)
    w = jnp.abs(_rand(k[2], (lam,), dtype))
    w = w / jnp.sum(w)
    p_c = _rand(k[3], (n,), dtype)
    decay, c_mu, c_1 = 0.9, 0.08, 0.02
    got = cma_rank_mu_update(C, Y, w, p_c, decay, c_mu, c_1, interpret=True)
    want = ref.rank_mu_update(C, Y, w, p_c,
                              jnp.asarray(decay, dtype), jnp.asarray(c_mu, dtype),
                              jnp.asarray(c_1, dtype))
    tol = 1e-5 if dtype == jnp.float32 else 1e-6
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


def test_update_zero_weights_padding_no_effect():
    """Padded population slots (w=0) must not change the result."""
    lam, n, pad = 12, 16, 20
    k = jax.random.split(jax.random.PRNGKey(0), 4)
    C = jnp.eye(n, dtype=jnp.float64)
    Y = _rand(k[0], (lam, n), jnp.float64)
    Ypad = jnp.concatenate([Y, 1e6 * jnp.ones((pad, n))])  # garbage rows
    w = jnp.abs(_rand(k[1], (lam,), jnp.float64))
    wpad = jnp.concatenate([w, jnp.zeros(pad)])
    p_c = _rand(k[2], (n,), jnp.float64)
    a = cma_rank_mu_update(C, Y, w, p_c, 0.9, 0.08, 0.02, interpret=True)
    b = cma_rank_mu_update(C, Ypad, wpad, p_c, 0.9, 0.08, 0.02, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-12)


def test_block_shape_sweep():
    """Different BlockSpec tilings must agree (shape-edge correctness)."""
    lam, n = 40, 96
    k = jax.random.split(jax.random.PRNGKey(5), 3)
    C = jnp.eye(n, dtype=jnp.float32)
    Y = _rand(k[0], (lam, n), jnp.float32)
    w = jnp.ones((lam,), jnp.float32) / lam
    p_c = _rand(k[1], (n,), jnp.float32)
    want = ref.rank_mu_update(C, Y, w, p_c, jnp.float32(0.9), jnp.float32(0.08),
                              jnp.float32(0.02))
    for bi, bj, bk in [(32, 32, 8), (96, 96, 40), (64, 32, 16), (128, 128, 128)]:
        got = cma_rank_mu_update(C, Y, w, p_c, 0.9, 0.08, 0.02,
                                 bi=bi, bj=bj, bk=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# slot-batched fused generation kernels (kernels/cma_gen.py)
# ---------------------------------------------------------------------------

# (S, lam, n) — non-block-multiple on purpose: odd n, λ < 8, prime-ish dims
GEN_SHAPES = [(1, 8, 4), (3, 12, 10), (2, 6, 7), (2, 24, 40), (1, 4, 13),
              (2, 9, 130)]


def _gen_inputs(S, lam, n, dtype, seed=0):
    k = jax.random.split(jax.random.PRNGKey(seed), 9)
    m = _rand(k[0], (S, n), dtype)
    sigma = jnp.abs(_rand(k[1], (S,), dtype)) + 0.2
    B = _rand(k[2], (S, n, n), dtype)
    D = jnp.abs(_rand(k[3], (S, n), dtype)) + 0.1
    Z = _rand(k[4], (S, lam, n), dtype)
    C = _rand(k[5], (S, n, n), dtype)
    C = C @ jnp.swapaxes(C, -1, -2) / n + jnp.eye(n, dtype=dtype)
    p_sigma = _rand(k[6], (S, n), dtype)
    p_c = _rand(k[7], (S, n), dtype)
    w = jnp.abs(_rand(k[8], (S, lam), dtype))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    coef = {"c_sigma": jnp.full((S,), 0.3, dtype),
            "mu_eff": jnp.full((S,), 3.2, dtype),
            "c_c": jnp.full((S,), 0.2, dtype),
            "c_1": jnp.full((S,), 0.02, dtype),
            "c_mu": jnp.full((S,), 0.08, dtype),
            "chi_n": jnp.full((S,), float(np.sqrt(n)), dtype),
            "gen1": jnp.full((S,), 5.0, dtype)}
    return m, sigma, B, D, Z, C, p_sigma, p_c, w, coef


@pytest.mark.parametrize("S,lam,n", GEN_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_gen_sample_matches_ref(S, lam, n, dtype):
    m, sigma, B, D, Z, *_ = _gen_inputs(S, lam, n, dtype)
    Yk, Xk = cma_gen_sample(m, sigma, B, D, Z, interpret=True)
    Yr, Xr = ref.gen_sample(m, sigma, B, D, Z)
    tol = 1e-4 if n >= 100 else 1e-5       # kernel accumulates in f32
    np.testing.assert_allclose(np.asarray(Yk), np.asarray(Yr),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(Xk), np.asarray(Xr),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("S,lam,n", GEN_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_gen_update_matches_ref(S, lam, n, dtype):
    _, _, B, D, _, C, p_sigma, p_c, w, coef = _gen_inputs(S, lam, n, dtype)
    Y = _rand(jax.random.PRNGKey(7), (S, lam, n), dtype)
    got = ops.gen_update(C, B, D, p_sigma, p_c, Y, w, coef, impl="pallas")
    want = ops.gen_update(C, B, D, p_sigma, p_c, Y, w, coef, impl="xla")
    tol = 2e-4 if n >= 100 else 5e-5
    for name, a, b in zip(("C", "p_sigma", "p_c", "y_w"), got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=tol, atol=tol, err_msg=name)


def test_gen_update_masked_inactive_slot():
    """A fully inactive slot (all-zero weights — parked/stopped in the
    ladder) must ride through the slot-batched kernel without contaminating
    live slots, and its own gram/y_w/path pulls must be zero."""
    S, lam, n = 3, 12, 10
    _, _, B, D, _, C, p_sigma, p_c, w, coef = _gen_inputs(S, lam, n,
                                                          jnp.float64)
    w = w.at[1].set(0.0)                       # slot 1 fully masked
    got = ops.gen_update(C, B, D, p_sigma, p_c,
                         _rand(jax.random.PRNGKey(3), (S, lam, n),
                               jnp.float64),
                         w, coef, impl="pallas")
    want = ops.gen_update(C, B, D, p_sigma, p_c,
                          _rand(jax.random.PRNGKey(3), (S, lam, n),
                                jnp.float64),
                          w, coef, impl="xla")
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-5, atol=5e-5)
    # masked slot: y_w exactly zero, p_c shrinks by exactly (1 - c_c)
    np.testing.assert_array_equal(np.asarray(got[3][1]), np.zeros(n))
    np.testing.assert_allclose(np.asarray(got[2][1]),
                               0.8 * np.asarray(p_c[1]), rtol=1e-6)


def test_gen_update_zero_weight_rows_inert():
    """Garbage Y rows with zero weight (λ < λ_pad padding) cannot change any
    output — the in-kernel form of the repo-wide masking convention."""
    S, lam, pad, n = 2, 8, 7, 12
    _, _, B, D, _, C, p_sigma, p_c, w, coef = _gen_inputs(S, lam, n,
                                                          jnp.float64)
    Y = _rand(jax.random.PRNGKey(11), (S, lam, n), jnp.float64)
    Ypad = jnp.concatenate([Y, 1e6 * jnp.ones((S, pad, n))], axis=1)
    wpad = jnp.concatenate([w, jnp.zeros((S, pad))], axis=1)
    a = ops.gen_update(C, B, D, p_sigma, p_c, Y, w, coef, impl="pallas")
    b = ops.gen_update(C, B, D, p_sigma, p_c, Ypad, wpad, coef,
                       impl="pallas")
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6,
                                   atol=1e-6)


def test_gen_kernels_slot_batch_consistent():
    """Slot-batched invocation ≡ per-slot invocations (the leading grid
    axis must not couple slots)."""
    S, lam, n = 3, 10, 9
    m, sigma, B, D, Z, C, p_sigma, p_c, w, coef = _gen_inputs(S, lam, n,
                                                              jnp.float64)
    Yb, Xb = cma_gen_sample(m, sigma, B, D, Z, interpret=True)
    got = ops.gen_update(C, B, D, p_sigma, p_c, Yb, w, coef, impl="pallas")
    for s in range(S):
        Ys, Xs = cma_gen_sample(m[s:s + 1], sigma[s:s + 1], B[s:s + 1],
                                D[s:s + 1], Z[s:s + 1], interpret=True)
        np.testing.assert_allclose(np.asarray(Yb[s]), np.asarray(Ys[0]),
                                   rtol=1e-6)
        one = ops.gen_update(C[s], B[s], D[s], p_sigma[s], p_c[s], Yb[s],
                             w[s], {k: v[s] for k, v in coef.items()},
                             impl="pallas")
        for a, b in zip(got, one):
            np.testing.assert_allclose(np.asarray(a[s]), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)


def test_fused_ref_C_symmetric_by_construction():
    """The √w gram factoring must keep C' symmetric without a repair pass —
    the fused path's core perf claim (no 0.5·(C + Cᵀ) transpose-add)."""
    S, lam, n = 1, 16, 33
    _, _, B, D, _, C, p_sigma, p_c, w, coef = _gen_inputs(S, lam, n,
                                                          jnp.float64)
    Y = _rand(jax.random.PRNGKey(5), (S, lam, n), jnp.float64)
    C_new, *_ = ops.gen_update(C, B, D, p_sigma, p_c, Y, w, coef, impl="xla")
    C_new = np.asarray(C_new[0])
    assert np.abs(C_new - C_new.T).max() < 1e-15 * np.abs(C_new).max()


# ---------------------------------------------------------------------------
# dispatch satellites (ops.resolve_impl)
# ---------------------------------------------------------------------------

def test_resolve_impl_unknown_raises():
    with pytest.raises(ValueError, match="unknown impl"):
        ops.resolve_impl("cuda")
    with pytest.raises(ValueError, match="unknown impl"):
        ops.validate_impl("")


def test_resolve_impl_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "xla_unfused")
    assert ops.resolve_impl("auto") == "xla_unfused"
    assert ops.resolve_impl("pallas") == "xla_unfused"
    assert not ops.use_fused("xla")
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "bogus")
    with pytest.raises(ValueError, match="unknown impl"):
        ops.resolve_impl("xla")
    monkeypatch.delenv("REPRO_KERNEL_IMPL")
    assert ops.resolve_impl("auto") in ("xla", "pallas")
    assert ops.use_fused("auto") and not ops.use_fused("xla_unfused")


def test_auto_falls_back_when_megakernel_exceeds_vmem(monkeypatch):
    """impl="auto" must not route onto whole-(n,n)-tile Pallas programs
    that cannot fit a 16 MB-VMEM core; an explicit "pallas" — caller arg
    or env override — is honored."""
    assert ops._megakernel_fits(256, jnp.float64)
    assert not ops._megakernel_fits(1024, jnp.float64)
    assert ops._megakernel_fits(700, jnp.float32)
    assert not ops._megakernel_fits(900, jnp.float32)
    assert ops._gen_impl("auto", 2048, jnp.float64) == "xla"
    assert ops._gen_impl("pallas", 2048, jnp.float64) == "pallas"
    small = ops._gen_impl("auto", 16, jnp.float64)
    assert small == ("pallas" if jax.default_backend() == "tpu" else "xla")
    # the sample kernel's chunked tiles admit much larger n than the
    # whole-matrix megakernel
    assert ops._sample_fits(1024, jnp.float64)
    assert ops._sample_fits(2048, jnp.float32)
    # env-forced pallas counts as explicit: no silent downgrade
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
    assert ops._gen_impl("auto", 2048, jnp.float64) == "pallas"
    # caller typos still raise even while the override is set
    with pytest.raises(ValueError, match="unknown impl"):
        ops.resolve_impl("pallsa")


def test_backend_probe_cached():
    """The TPU probe must be cached, not re-queried at every traced op."""
    assert ops._on_tpu() == (jax.default_backend() == "tpu")
    assert ops._on_tpu.cache_info().currsize == 1
    before = ops._on_tpu.cache_info().hits
    ops.resolve_impl("auto")
    assert ops._on_tpu.cache_info().hits > before


# ---------------------------------------------------------------------------
# in-kernel RNG tier (PR 7): cma_*_rng kernels ↔ the XLA threefry ref stream
# ---------------------------------------------------------------------------

from repro.kernels.cma_gen import (cma_gen_sample_eval,  # noqa: E402
                                   cma_gen_sample_rng,
                                   cma_gen_sample_rng_eval, cma_sample_z_rng)

RNG_SHAPES = [(1, 8, 4), (3, 12, 10), (2, 6, 7), (2, 9, 130)]


def _seeds(S, seed=0):
    return jax.random.key_data(
        jax.random.split(jax.random.PRNGKey(seed), S)).astype(jnp.uint32) \
        if hasattr(jax.random, "key_data") else \
        jnp.asarray(jax.random.split(jax.random.PRNGKey(seed), S), jnp.uint32)


@pytest.mark.parametrize("S,lam,n", RNG_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_rng_kernel_z_bit_exact_vs_xla_ref(S, lam, n, dtype):
    """The acceptance pin of the pallas_rng tier: the in-kernel counter
    stream and ``ref.sample_z_rng`` are the SAME bits (jitted both sides —
    eager op-by-op execution may round transcendentals differently)."""
    seeds = _seeds(S, seed=S * 100 + lam)
    zk = jax.jit(lambda s: cma_sample_z_rng(s, lam=lam, n=n, dtype=dtype,
                                            interpret=True))(seeds)
    zr = jax.jit(lambda s: ref.sample_z_rng(s, lam, n, dtype))(seeds)
    np.testing.assert_array_equal(np.asarray(zk), np.asarray(zr))


def test_rng_stream_moments_and_slot_independence():
    """Sanity on the distribution: the counter stream is ~N(0, 1) and
    distinct (slot, row) seeds decorrelate."""
    z = np.asarray(ref.sample_z_rng(_seeds(4, seed=9), 256, 64, jnp.float64))
    assert abs(z.mean()) < 0.01 and abs(z.std() - 1.0) < 0.01
    assert abs(np.corrcoef(z[0].ravel(), z[1].ravel())[0, 1]) < 0.05
    # per-slot draws differ (seeded per slot)
    assert np.abs(z[0] - z[1]).max() > 0.1


@pytest.mark.parametrize("S,lam,n", [(2, 12, 10), (1, 8, 33)])
def test_rng_gen_sample_kernel_matches_ref(S, lam, n):
    m, sigma, B, D, *_ = _gen_inputs(S, lam, n, jnp.float64)
    seeds = _seeds(S, seed=3)
    Yk, Xk = cma_gen_sample_rng(m, sigma, B, D, seeds, lam=lam,
                                interpret=True)
    Yr, Xr = ref.gen_sample_rng(m, sigma, B, D, seeds, lam)
    np.testing.assert_allclose(np.asarray(Yk), np.asarray(Yr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(Xk), np.asarray(Xr),
                               rtol=1e-5, atol=1e-5)


def _sep_parts(fid, n, S, dtype):
    from repro.fitness import bbob
    sep = bbob.separable_coeffs(bbob.make_instance(fid, n, 1), (1, 2))
    return ops._sep_slots(sep, S, n, dtype)


@pytest.mark.parametrize("fid", [1, 2])
def test_rng_eval_fused_kernel_matches_ref(fid):
    """The full residency kernel (seeds → (Y, F)) against the XLA ref —
    same counter stream, same separable fitness algebra."""
    from repro.fitness import bbob
    S, lam, n = 2, 12, 10
    m, sigma, B, D, *_ = _gen_inputs(S, lam, n, jnp.float64)
    seeds = _seeds(S, seed=7)
    sep = bbob.separable_coeffs(bbob.make_instance(fid, n, 1), (1, 2))
    Yk, Fk = cma_gen_sample_rng_eval(m, sigma, B, D, seeds,
                                     *_sep_parts(fid, n, S, jnp.float64),
                                     lam=lam, interpret=True)
    Yr, Fr = ref.gen_sample_rng_eval(m, sigma, B, D, seeds, lam, sep)
    np.testing.assert_allclose(np.asarray(Yk), np.asarray(Yr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(Fk), np.asarray(Fr),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("fid", [1, 2])
def test_rng_sample_eval_kernel_matches_ref(fid):
    """Eval-fused epilogue with a host-provided Z (the non-RNG fused tier)."""
    from repro.fitness import bbob
    S, lam, n = 2, 9, 13
    m, sigma, B, D, Z, *_ = _gen_inputs(S, lam, n, jnp.float64)
    sep = bbob.separable_coeffs(bbob.make_instance(fid, n, 1), (1, 2))
    Yk, Fk = cma_gen_sample_eval(m, sigma, B, D, Z,
                                 *_sep_parts(fid, n, S, jnp.float64),
                                 interpret=True)
    Yr, Fr = ref.gen_sample_eval(m, sigma, B, D, Z, sep)
    np.testing.assert_allclose(np.asarray(Yk), np.asarray(Yr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(Fk), np.asarray(Fr),
                               rtol=1e-5, atol=1e-5)


def test_rng_dispatch_cpu_fallback_is_same_stream():
    """Off TPU, ``impl="pallas_rng"`` must route the sample through the XLA
    threefry ref — the BIT-exact same stream, so the fallback never changes
    a trajectory — and the backend probe is a one-shot static False."""
    S, lam, n = 2, 8, 6
    m, sigma, B, D, *_ = _gen_inputs(S, lam, n, jnp.float64)
    seeds = _seeds(S, seed=1)
    if jax.default_backend() != "tpu":
        assert not ops._rng_kernel_supported()
        assert ops._rng_kernel_supported.cache_info().currsize == 1
    got = jax.jit(lambda *a: ops.gen_sample_rng(*a, lam, impl="pallas_rng")
                  )(m, sigma, B, D, seeds)
    want = jax.jit(lambda *a: ref.gen_sample_rng(*a, lam)
                   )(m, sigma, B, D, seeds)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rng_tier_dispatch_semantics(monkeypatch):
    """"auto" NEVER resolves to pallas_rng (switching the RNG stream is a
    caller-level trajectory decision); explicit requests and the env
    override are honored; the tier counts as fused and kernel-tier."""
    assert ops.resolve_impl("auto") != "pallas_rng"
    assert ops.resolve_impl("pallas_rng") == "pallas_rng"
    assert ops.use_fused("pallas_rng")
    assert ops._kernel_tier("pallas_rng")
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas_rng")
    assert ops.resolve_impl("auto") == "pallas_rng"
    assert ops.resolve_impl("xla") == "pallas_rng"
    monkeypatch.delenv("REPRO_KERNEL_IMPL")
    assert ops.resolve_impl("auto") in ("xla", "pallas")
