"""BBOB suite tests: optimum consistency, batching, transforms, CMA-ES solves."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cmaes
from repro.core.params import CMAConfig, make_params
from repro.fitness import bbob
from repro.fitness.surrogates import with_flops_cost

ALL_FIDS = list(range(1, 25))


@pytest.mark.parametrize("fid", ALL_FIDS)
@pytest.mark.parametrize("n", [2, 10, 40])
def test_optimum_value(fid, n):
    """f(x_opt) == f_opt for every function and dimension."""
    inst = bbob.make_instance(fid, n, instance=0)
    val = bbob.evaluate(fid, inst, inst.x_opt[None, :])
    np.testing.assert_allclose(float(val[0]), float(inst.f_opt),
                               rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("fid", ALL_FIDS)
def test_optimum_is_local_min(fid):
    """Random perturbations never beat the optimum."""
    n = 10
    inst = bbob.make_instance(fid, n, instance=1)
    key = jax.random.PRNGKey(fid)
    # stay inside the domain so boundary penalties don't mask regressions
    pert = jax.random.uniform(key, (256, n), jnp.float64, -0.5, 0.5)
    X = jnp.clip(inst.x_opt[None, :] + pert, -5.0, 5.0)
    vals = bbob.evaluate(fid, inst, X)
    assert float(jnp.min(vals)) >= float(inst.f_opt) - 1e-9


@pytest.mark.parametrize("fid", ALL_FIDS)
def test_batch_and_jit(fid):
    n = 6
    fn, inst = bbob.make_fitness(fid, n)
    X = jax.random.uniform(jax.random.PRNGKey(0), (32, n), jnp.float64, -5, 5)
    vals = jax.jit(fn)(X)
    assert vals.shape == (32,)
    assert bool(jnp.all(jnp.isfinite(vals)))
    # single-row and batch agree (XLA may reassociate the batched GEMMs,
    # so exact bitwise equality is not guaranteed across batch shapes)
    np.testing.assert_allclose(np.asarray(jax.jit(fn)(X[3:4]))[0],
                               np.asarray(vals)[3], rtol=1e-10)


def test_instances_differ():
    a = bbob.make_instance(8, 10, instance=0)
    b = bbob.make_instance(8, 10, instance=1)
    assert not np.allclose(np.asarray(a.x_opt), np.asarray(b.x_opt))


def test_rotation_orthogonal():
    inst = bbob.make_instance(10, 40)
    R = np.asarray(inst.R)
    np.testing.assert_allclose(R @ R.T, np.eye(40), atol=1e-10)


def test_t_osz_fixed_points():
    # T_osz(0) = 0, sign-preserving, monotone-ish on small values
    x = jnp.asarray([-2.0, -1e-8, 0.0, 1e-8, 2.0])
    y = bbob.t_osz(x)
    assert float(y[2]) == 0.0
    assert bool(jnp.all(jnp.sign(y) == jnp.sign(x)))


def test_t_asy_identity_below_zero():
    x = jnp.asarray([-3.0, -0.1, 0.0])
    np.testing.assert_allclose(np.asarray(bbob.t_asy(x, 0.2)), np.asarray(x))


def test_f_pen_zero_inside_domain():
    x = jnp.asarray([[4.9, -4.9, 0.0]])
    assert float(bbob.f_pen(x)[0]) == 0.0
    x = jnp.asarray([[5.5, 0.0, 0.0]])
    np.testing.assert_allclose(float(bbob.f_pen(x)[0]), 0.25)


def test_gallagher_peak_count():
    i21 = bbob.make_instance(21, 5)
    i22 = bbob.make_instance(22, 5)
    assert i21.peaks_y.shape[0] == 101
    assert i22.peaks_y.shape[0] == 21


@pytest.mark.parametrize("fid", [1, 2, 5, 8, 10, 11, 12, 14])
def test_cmaes_solves_unimodal_bbob(fid):
    """CMA-ES reaches target 1e-8 on the unimodal functions (paper's easy set)."""
    n = 6
    fn, inst = bbob.make_fitness(fid, n)
    cfg = CMAConfig(n=n, lam=16)
    p = make_params(cfg)
    key = jax.random.PRNGKey(fid * 11)
    x0 = jax.random.uniform(key, (n,), jnp.float64, -4, 4)
    final = cmaes.run(cfg, p, fn, jax.random.PRNGKey(fid), x0, 2.0,
                      max_gens=1500)
    err = float(final.best_f) - float(inst.f_opt)
    assert err < 1e-8, f"f{fid}: residual {err}"


def test_flops_cost_wrapper_preserves_values():
    fn, inst = bbob.make_fitness(1, 4)
    wrapped = with_flops_cost(fn, extra_flops=1e6)
    X = jax.random.uniform(jax.random.PRNGKey(0), (8, 4), jnp.float64, -5, 5)
    np.testing.assert_allclose(np.asarray(wrapped(X)), np.asarray(fn(X)),
                               rtol=1e-12)
