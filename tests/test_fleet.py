"""Fleet supervision (src/repro/fleet/) — PR 8.

The acceptance bar, in the fast tier:

* **recovery determinism** — a seeded ``FaultPlan`` that kills an island
  mid-campaign yields a final ``IPOPResult`` identical to the fault-free
  run on every backend (bucketed / mesh-S2 / service): exact eval counts
  and descent structure, best_f to the repo's 1e-12 relocation bar
  (bit-exact on the single-island engine paths, where recovery is pure
  replay of the same programs on the same state);
* **fault-injection coverage** — corrupt boundary reads are retried (and
  counted), delay faults only cost wall time, kills are recovered from
  the last snapshot, down_for islands rejoin and get repopulated;
* **health detector semantics** — deadline → suspect → dead with a retry
  budget, stalls need an expected-progress marker, revive resets;
* **job persistence** — snapshots round-trip finished jobs' full results
  and every ticket's streamed-update tail (``--resume`` streams identical
  tickets);
* **zero overhead when disabled** — no supervisor ⇒ no new device syncs,
  no fleet_* series, no new segment programs (extends the pins in
  tests/test_obs.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.core.ipop import run_ipop
from repro.fleet import (CORRUPT, DELAY, KILL, FaultEvent, FaultPlan,
                         FleetConfig, FleetHealth, HealthConfig)
from repro.fleet.controller import (FleetController, occupancy_skew)
from repro.obs import registry as reg_mod
from repro.obs.registry import MetricsRegistry
from repro.service import (CampaignRequest, CampaignServer, FitnessRegistry,
                           SlotAllocator)

KW = dict(lam_start=8, kmax_exp=2)


def sphere(X):
    return jnp.sum(X * X, axis=-1)


@pytest.fixture
def fresh_metrics():
    prev = reg_mod.set_metrics(MetricsRegistry())
    yield reg_mod.metrics()
    reg_mod.set_metrics(prev)


def series(reg, name):
    return {lkey: s for (n, lkey), s in reg._series.items() if n == name}


def counter_sum(reg, name, **labels):
    return sum(s.value for lkey, s in series(reg, name).items()
               if all(dict(lkey).get(k) == v for k, v in labels.items()))


def assert_same_result(ref, got, exact=True):
    """The recovery-determinism bar: exact descent structure and eval
    counts always; best_f bit-exact on pure-replay paths, 1e-12 on
    relocation paths (the repo's established per-shape-fusion bar)."""
    assert got.total_fevals == ref.total_fevals
    assert len(got.descents) == len(ref.descents)
    for a, b in zip(ref.descents, got.descents):
        assert a.k_exp == b.k_exp and a.lam == b.lam
        assert a.stop_reason == b.stop_reason
        np.testing.assert_array_equal(np.asarray(a.fevals),
                                      np.asarray(b.fevals))
        np.testing.assert_array_equal(np.asarray(a.gens), np.asarray(b.gens))
        if exact:
            np.testing.assert_array_equal(np.asarray(a.best_f),
                                          np.asarray(b.best_f))
        else:
            np.testing.assert_allclose(a.best_f, b.best_f,
                                       rtol=1e-12, atol=1e-12)
    if exact:
        assert got.best_f == ref.best_f
    else:
        np.testing.assert_allclose(got.best_f, ref.best_f,
                                   rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# fault plans (pure)
# ---------------------------------------------------------------------------

def test_fault_plan_validation_and_lookup():
    with pytest.raises(ValueError):
        FaultEvent("explode", island=0, boundary=1)
    with pytest.raises(ValueError):
        FaultEvent(KILL, island=0, boundary=0)   # nothing to recover yet
    with pytest.raises(ValueError):
        FaultEvent(DELAY, island=-1, boundary=1)
    p = FaultPlan([FaultEvent(KILL, island=1, boundary=3, down_for=2),
                   FaultEvent(DELAY, island=0, boundary=2, delay_s=0.1),
                   FaultEvent(DELAY, island=0, boundary=2, delay_s=0.2),
                   FaultEvent(CORRUPT, island=0, boundary=4)])
    assert [e.boundary for e in p.kills_at(3)] == [3]
    assert p.kill_at(1, 3) is not None and p.kill_at(0, 3) is None
    assert p.delay(0, 2) == pytest.approx(0.3)      # delays accumulate
    assert p.corrupts(0, 4) and not p.corrupts(0, 3)
    assert p.max_boundary() == 4


def test_fault_plan_seeded_and_parse():
    a = FaultPlan.seeded(11, 4, kills=2, delays=1, corrupts=1)
    b = FaultPlan.seeded(11, 4, kills=2, delays=1, corrupts=1)
    assert [(e.kind, e.island, e.boundary) for e in a.events] == \
           [(e.kind, e.island, e.boundary) for e in b.events]
    kills = [e for e in a.events if e.kind == KILL]
    assert len(kills) == 2
    assert len({e.island for e in kills}) == 2       # one kill per island
    assert all(e.boundary >= 1 for e in kills)

    p = FaultPlan.parse("0:2,1:5:3", down_for=1)
    ks = [e for e in p.events if e.kind == KILL]
    assert [(e.island, e.boundary, e.down_for) for e in ks] == \
           [(0, 2, 1), (1, 5, 3)]                    # per-cell down_for wins


# ---------------------------------------------------------------------------
# health detector (pure)
# ---------------------------------------------------------------------------

def test_health_deadline_suspect_then_dead(fresh_metrics):
    h = FleetHealth(HealthConfig(deadline_s=1.0, retries=1))
    h.observe(0, 0, 100.0, wall_s=0.1)
    assert h.state(0) == "alive"
    h.observe(0, 1, 200.0, wall_s=2.0)               # over deadline
    assert h.state(0) == "suspect"
    h.observe(0, 2, 300.0, wall_s=0.1)               # fast pull clears it
    assert h.state(0) == "alive"
    h.observe(0, 3, 400.0, wall_s=2.0)
    h.observe(0, 4, 500.0, wall_s=2.0)               # retry budget exhausted
    assert h.is_dead(0) and h.island(0).reason == "deadline"
    assert h.dead_islands() == [0]
    h.revive(0, 5)
    assert h.state(0) == "alive" and not h.dead_islands()
    # the state gauge followed the transitions
    g = fresh_metrics.gauge("fleet_island_state", island=0)
    assert g.value == 0.0


def test_health_stall_needs_expected_progress():
    h = FleetHealth(HealthConfig(deadline_s=10.0, stall_boundaries=2))
    h.observe(0, 0, 50.0, wall_s=0.01)
    for b in range(1, 5):                            # idle: no dispatch
        h.observe(0, b, 50.0, wall_s=0.01, expect_progress=False)
    assert h.state(0) == "alive"
    h.observe(0, 5, 50.0, wall_s=0.01)               # dispatched, no progress
    assert not h.is_dead(0)
    h.observe(0, 6, 50.0, wall_s=0.01)
    assert h.is_dead(0) and h.island(0).reason == "stalled"
    # progress watermark rebases after a restore (no false stall verdicts)
    h.revive(0, 7)
    h.reset_progress(0, 20.0)
    h.observe(0, 8, 30.0, wall_s=0.01)
    assert h.state(0) == "alive"


def test_occupancy_skew_is_the_rebalance_signal():
    al = SlotAllocator(2, 4)
    for j in range(4):
        al.alloc(j, 100, island=0)                   # all on island 0
    assert occupancy_skew(al) == 1.0
    al.release(0, 3)
    al.alloc(9, 100, island=1)
    assert occupancy_skew(al) == 0.5
    balanced, _moves, _layout = al.repack(2)
    assert occupancy_skew(balanced) == 0.0           # repack balances


# ---------------------------------------------------------------------------
# recovery determinism: every backend vs its fault-free run
# ---------------------------------------------------------------------------

RUN_KW = dict(max_evals=3000, **KW)


def test_bucketed_kill_recovery_bit_identical(fresh_metrics):
    key = jax.random.PRNGKey(0)
    ref = run_ipop(sphere, 6, key, backend="bucketed", **RUN_KW)
    plan = FaultPlan([FaultEvent(KILL, island=0, boundary=3)])
    got = run_ipop(sphere, 6, key, backend="bucketed",
                   fleet=FleetConfig(snapshot_every=2, plan=plan), **RUN_KW)
    assert_same_result(ref, got, exact=True)
    reg = fresh_metrics
    assert counter_sum(reg, "fleet_failures_total", reason="killed") == 1
    assert counter_sum(reg, "fleet_recoveries_total", mode="replayed") == 1
    assert reg.histogram("fleet_recovery_wall_s").count == 1
    assert reg.histogram("fleet_lost_work_evals").count == 1


def test_bucketed_corrupt_and_delay_faults_are_absorbed(fresh_metrics):
    key = jax.random.PRNGKey(0)
    ref = run_ipop(sphere, 6, key, backend="bucketed", **RUN_KW)
    plan = FaultPlan([FaultEvent(CORRUPT, island=0, boundary=2),
                      FaultEvent(DELAY, island=0, boundary=1, delay_s=0.01)])
    got = run_ipop(sphere, 6, key, backend="bucketed",
                   fleet=FleetConfig(snapshot_every=2, plan=plan), **RUN_KW)
    assert_same_result(ref, got, exact=True)
    # the garbled read was re-pulled, not believed (and not a death)
    assert counter_sum(fresh_metrics, "fleet_pull_retries_total") >= 1
    assert counter_sum(fresh_metrics, "fleet_failures_total") == 0


def test_mesh_kill_recovery_bit_identical(fresh_metrics):
    key = jax.random.PRNGKey(0)
    ref = run_ipop(sphere, 6, key, backend="mesh", **RUN_KW)
    plan = FaultPlan([FaultEvent(KILL, island=0, boundary=2)])
    got = run_ipop(sphere, 6, key, backend="mesh",
                   fleet=FleetConfig(snapshot_every=2, plan=plan), **RUN_KW)
    assert_same_result(ref, got, exact=True)
    assert counter_sum(fresh_metrics, "fleet_recoveries_total",
                       mode="replayed") == 1


def test_service_kill_park_and_rejoin_identical(fresh_metrics):
    """Single-island service: the kill parks the row (no survivor has
    capacity), the island rejoins after ``down_for`` boundaries and the
    row replays — same final result as the fault-free run."""
    key = jax.random.PRNGKey(0)
    ref = run_ipop(sphere, 6, key, backend="service", **RUN_KW)
    plan = FaultPlan([FaultEvent(KILL, island=0, boundary=2, down_for=2)])
    got = run_ipop(sphere, 6, key, backend="service",
                   fleet=FleetConfig(snapshot_every=2, plan=plan), **RUN_KW)
    assert_same_result(ref, got, exact=False)
    reg = fresh_metrics
    assert counter_sum(reg, "fleet_recoveries_total", mode="requeued") == 1
    assert counter_sum(reg, "fleet_recoveries_total", mode="rejoined") == 1
    assert counter_sum(reg, "fleet_recoveries_total", mode="reassigned") == 1


# ---------------------------------------------------------------------------
# service-level controller: reassignment onto survivors + rebalancing
# ---------------------------------------------------------------------------

def shifted_sphere(X):
    return jnp.sum((X - 1.2) ** 2, axis=-1)


def make_registry():
    reg = FitnessRegistry()
    reg.register("shifted_sphere", shifted_sphere)
    return reg


def make_server(n_islands=2, **extra):
    dev = jax.devices()[0]
    kw = dict(registry=make_registry(), bbob_fids=(1, 8), max_budget=5000,
              rows_per_island=2, devices=[dev] * n_islands, **KW)
    kw.update(extra)
    return CampaignServer(**kw)


def _submit_pair(srv):
    return [srv.submit(CampaignRequest(dim=4, fid=8, budget=3000, seed=7)),
            srv.submit(CampaignRequest(dim=4, fitness="shifted_sphere",
                                       budget=2000, seed=3))]


def test_service_kill_reassigns_rows_to_survivor(fresh_metrics, tmp_path):
    ref_srv = make_server()
    ref = _submit_pair(ref_srv)
    ref_srv.drain()

    srv = make_server(snapshot_dir=str(tmp_path / "ckpt"))
    ts = _submit_pair(srv)
    ctl = FleetController(srv, FleetConfig(
        snapshot_every=2,
        plan=FaultPlan([FaultEvent(KILL, island=1, boundary=3)])))
    assert srv.snapshot_every == 2          # controller owns the cadence
    ctl.drain()

    assert 1 in srv.down_islands            # never came back (down_for=0)
    reg = fresh_metrics
    assert counter_sum(reg, "fleet_failures_total", reason="killed") == 1
    assert counter_sum(reg, "fleet_recoveries_total", mode="reassigned") == 1
    for tr, tg in zip(ref, ts):
        assert tg.done
        assert tg.island == 0               # relocated onto the survivor
        assert tg.fevals == tr.fevals
        assert_same_result(tr.result, tg.result, exact=False)


def test_rejoin_triggers_rebalance_back_onto_returned_island(fresh_metrics,
                                                             tmp_path):
    """down_for kill on a 2-island lane with survivor head-room: the dead
    island's rows are REASSIGNED onto the survivor (it has free rows), so
    the island rejoins empty — and the rejoin-triggered repack spreads the
    lane across both islands again."""
    srv = make_server(rows_per_island=4, snapshot_dir=str(tmp_path / "ckpt"))
    ts = [srv.submit(CampaignRequest(dim=4, fid=1, budget=5000, seed=s))
          for s in range(4)]                # 2 rows per island, 2 free each
    ctl = FleetController(srv, FleetConfig(
        snapshot_every=2, skew_threshold=0.4,
        plan=FaultPlan([FaultEvent(KILL, island=1, boundary=3,
                                   down_for=1)])))
    lane = None
    for _ in range(5):                      # kill at b=3, rejoin at b=4
        ctl.step()
        if lane is None:
            lane = next(iter(srv.lanes.values()))
    reg = fresh_metrics
    assert counter_sum(reg, "fleet_recoveries_total", mode="reassigned") == 2
    assert counter_sum(reg, "fleet_recoveries_total", mode="rejoined") == 1
    assert counter_sum(reg, "fleet_rebalances_total", trigger="rejoin") >= 1
    assert occupancy_skew(lane.allocator) <= 0.25   # repacked across both
    ctl.drain()
    assert not srv.down_islands
    for t in ts:
        assert t.done


def test_load_skew_triggers_rebalance_without_any_failure(fresh_metrics):
    """Satellite: the repack trigger fires on plain load imbalance — short
    jobs retire one island's rows while the other stays full — with no
    fault anywhere in the run."""
    srv = make_server(rows_per_island=4)
    # admission balances islands round-robin: even submissions land on
    # island 0, odd on island 1 — so the short jobs all retire from one side
    ts = [srv.submit(CampaignRequest(dim=4, fid=1, budget=b, seed=s))
          for s, b in enumerate([600, 5000, 600, 5000])]
    ctl = FleetController(srv, FleetConfig(skew_threshold=0.4))
    for _ in range(30):
        ctl.step()
        if counter_sum(fresh_metrics, "fleet_rebalances_total",
                       trigger="skew"):
            break
    assert counter_sum(fresh_metrics, "fleet_rebalances_total",
                       trigger="skew") >= 1
    assert counter_sum(fresh_metrics, "fleet_failures_total") == 0
    ctl.drain()
    for t in ts:
        assert t.done


# ---------------------------------------------------------------------------
# satellite: full job persistence across snapshots
# ---------------------------------------------------------------------------

def test_snapshot_persists_results_and_update_tails(tmp_path):
    d = str(tmp_path / "ckpt")
    srv = make_server(n_islands=1, snapshot_dir=d)
    t_done = srv.submit(CampaignRequest(dim=4, fid=1, budget=1500, seed=5))
    srv.drain()
    t_live = srv.submit(CampaignRequest(dim=4, fid=8, budget=3000, seed=7))
    for _ in range(3):
        srv.step()                          # t_live mid-flight, streaming
    assert t_done.done and t_done.result is not None
    assert t_live.updates
    srv.snapshot()
    del srv

    srv2 = CampaignServer.restore(d, registry=make_registry())
    r_done = srv2.tickets[t_done.job_id]
    assert r_done.done
    # the FULL result rode the snapshot: scalars, descents, best_x arrays
    assert_same_result(t_done.result, r_done.result, exact=True)
    np.testing.assert_array_equal(np.asarray(t_done.result.best_x),
                                  np.asarray(r_done.result.best_x))
    # streamed ticket tails are identical after resume
    assert srv2.tickets[t_live.job_id].updates == t_live.updates
    assert r_done.updates == t_done.updates
    srv2.drain()
    assert srv2.tickets[t_live.job_id].done


def test_release_ticket_frees_host_memory_only_when_done():
    srv = make_server(n_islands=1)
    t = srv.submit(CampaignRequest(dim=4, fid=1, budget=1200, seed=0))
    assert srv.release_ticket(t.job_id) is None      # still running
    srv.drain()
    released = srv.release_ticket(t.job_id)
    assert released is t and t.job_id not in srv.tickets
    assert srv.release_ticket(t.job_id) is None      # idempotent
    # retired rows stay recognised: a follow-up job still drains cleanly
    t2 = srv.submit(CampaignRequest(dim=4, fid=1, budget=1000, seed=1))
    srv.drain()
    assert t2.done


# ---------------------------------------------------------------------------
# zero overhead when disabled / no new programs when enabled
# ---------------------------------------------------------------------------

def test_supervision_adds_no_segment_programs(fresh_metrics, tmp_path):
    """The recovery path replays EXISTING programs: a supervised server
    (with a kill) compiles exactly what the plain server compiled."""
    plain = make_server()
    _submit_pair(plain)
    plain.drain()
    baseline = plain.segment_compiles()

    srv = make_server(snapshot_dir=str(tmp_path / "ckpt"))
    _submit_pair(srv)
    ctl = FleetController(srv, FleetConfig(
        snapshot_every=2,
        plan=FaultPlan([FaultEvent(KILL, island=1, boundary=3)])))
    ctl.drain()
    assert srv.segment_compiles() == baseline


def test_no_supervisor_means_no_fleet_series(fresh_metrics):
    run_ipop(sphere, 4, jax.random.PRNGKey(0), backend="bucketed",
             max_evals=1500, fleet=None, **KW)
    assert not any(n.startswith("fleet_")
                   for (n, _l) in fresh_metrics._series)


def test_fleet_rejects_engineless_backends():
    with pytest.raises(ValueError, match="fleet supervision"):
        run_ipop(sphere, 4, jax.random.PRNGKey(0), backend="hostloop",
                 fleet=FleetConfig(), max_evals=1000, **KW)
