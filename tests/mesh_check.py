"""8-virtual-device equivalence checks for the mesh campaign engine.

Executed as a SUBPROCESS by tests/test_mesh_engine.py (and directly by the
CI mesh job): ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` must be
set before jax's first import locks the device count, which a pytest
process that already initialized jax on 1 CPU device cannot do in-process.

Everything runs under one identical XLA environment, so the comparisons are
exactly the single-device bucketed driver vs the mesh engine on a REAL
8-device campaign mesh:

* trajectory equivalence of ``strategy="ordered"`` and ``"concurrent"`` vs
  ``backend="bucketed"`` on f1/f8 at ``eigen_interval == 1`` (per-member
  generation structure exactly equal, floats to the usual per-shape XLA
  fusion tolerance) — including a non-divisible batch (6 members on 8
  devices) exercising the inert-padding rows;
* ``compiles ≤ #buckets`` for the shard_map (ordered) runners at the
  jit-cache level and for the concurrent path at the traced-program level;
* ECDF equivalence at ``eigen_interval > 1`` (segment cuts are shard-local
  under S2, so only the ECDF is preserved there);
* S2 ``stop_at`` early sharing: every island retires once the exchanged
  global best reaches the target.

Prints ``MESH-CHECK-OK`` and exits 0 iff every assertion holds.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.core import bucketed  # noqa: E402
from repro.distributed import mesh_engine  # noqa: E402

KW = dict(n=4, lam_start=8, kmax_exp=2, max_evals=5000)


def assert_trajectory_equal(res_b, res_m):
    np.testing.assert_array_equal(res_b.total_fevals, res_m.total_fevals)
    np.testing.assert_allclose(res_b.best_f, res_m.best_f,
                               rtol=1e-5, atol=1e-7)
    for b in range(len(res_b.members)):
        rb = np.asarray(res_b.trace.ran)[b, :, 0]
        rm = np.asarray(res_m.trace.ran)[b, :, 0]
        for field in ("k_idx", "gen", "fevals", "stop_reason", "stopped"):
            np.testing.assert_array_equal(
                np.asarray(getattr(res_b.trace, field))[b, :, 0][rb],
                np.asarray(getattr(res_m.trace, field))[b, :, 0][rm],
                err_msg=field)
        np.testing.assert_allclose(
            np.asarray(res_b.trace.best_f)[b, :, 0][rb],
            np.asarray(res_m.trace.best_f)[b, :, 0][rm],
            rtol=1e-5, atol=1e-7)


def main():
    assert jax.device_count() == 8, jax.devices()
    n_buckets = KW["kmax_exp"] + 1

    # -- trajectory equivalence at eigen_interval == 1 (B=8 on 8 devices) ----
    eng_b = bucketed.BucketedLadderEngine(**KW)
    res_b = bucketed.run_campaign_bucketed(eng_b, fids=(1, 8), instances=(1,),
                                           runs=4, seed=0)
    assert eng_b.full.cfg.eigen_interval == 1
    for strategy in ("ordered", "concurrent"):
        eng_m = mesh_engine.MeshCampaignEngine(strategy=strategy, **KW)
        assert eng_m.n_devices == 8
        res_m = mesh_engine.run_campaign_mesh(eng_m, fids=(1, 8),
                                              instances=(1,), runs=4, seed=0)
        assert_trajectory_equal(res_b, res_m)
        assert 1 <= res_m.compiles <= n_buckets, res_m.compiles
        if strategy == "ordered":
            # jit-cache-level: every shard_map runner compiled exactly once
            # (guarded like MeshCampaignEngine.compiles() — _cache_size is a
            # private jit attribute that an unpinned jax may drop)
            for key, fn in eng_m._runner_cache.items():
                cs = getattr(fn, "_cache_size", None)
                if callable(cs):
                    assert int(cs()) == 1, (key, cs())
        else:
            assert res_m.shard_segments is not None
            islands_used = sum(1 for s in res_m.shard_segments if s)
            assert islands_used == 8          # every island ran its slice
        assert res_m.exchange and \
            res_m.exchange[-1]["global_fevals"] == int(
                np.sum(res_m.total_fevals))
        print(f"trajectory[{strategy}] OK  compiles={res_m.compiles} "
              f"segments={len(res_m.segments)}")

    # -- inert padding: 6 members on 8 devices -------------------------------
    res_b6 = bucketed.run_campaign_bucketed(eng_b, fids=(1, 8), instances=(1,),
                                            runs=3, seed=2)
    for strategy in ("ordered", "concurrent"):
        eng_m = mesh_engine.MeshCampaignEngine(strategy=strategy, **KW)
        res_m6 = mesh_engine.run_campaign_mesh(eng_m, fids=(1, 8),
                                               instances=(1,), runs=3, seed=2)
        assert len(res_m6.members) == 6
        assert res_m6.trace.ran.shape[0] == 6     # pad rows sliced off
        assert_trajectory_equal(res_b6, res_m6)
        print(f"padding[{strategy}] OK")

    # -- ECDF equivalence at eigen_interval > 1 ------------------------------
    kw = dict(n=8, lam_start=8, kmax_exp=1, max_evals=4000, eigen_interval=4)
    eng_b2 = bucketed.BucketedLadderEngine(**kw)
    res_b2 = bucketed.run_campaign_bucketed(eng_b2, fids=(1, 8),
                                            instances=(1,), runs=4, seed=0)
    targets = np.array([1e2, 1e0, 1e-4])
    hits_b = np.isfinite(res_b2.hit_evals(targets)).mean(axis=0)
    B = len(res_b2.members)
    for strategy in ("ordered", "concurrent"):
        eng_m = mesh_engine.MeshCampaignEngine(strategy=strategy, **kw)
        res_m2 = mesh_engine.run_campaign_mesh(eng_m, fids=(1, 8),
                                               instances=(1,), runs=4, seed=0)
        hits_m = np.isfinite(res_m2.hit_evals(targets)).mean(axis=0)
        assert np.all(np.abs(hits_b - hits_m) <= 1.0 / B + 1e-9), \
            (strategy, hits_b, hits_m)
        for (fid, _i, _r), err in zip(res_m2.members,
                                      res_m2.best_f - res_m2.f_opt):
            if fid == 1:
                assert err < 1e-6
        assert (res_m2.total_fevals <= kw["max_evals"]).all()
        print(f"ecdf[{strategy}] OK")

    # -- S2 early sharing: stop_at retires every island ----------------------
    eng_s = mesh_engine.MeshCampaignEngine(strategy="concurrent",
                                           stop_at=1e30, **KW)
    res_s = mesh_engine.run_campaign_mesh(eng_s, fids=(1, 8), instances=(1,),
                                          runs=4, seed=0)
    assert any(e.get("stopped_early") for e in res_s.exchange)
    # one round of segments at most — the exchange stopped everything after it
    assert len(res_s.exchange) <= 2
    assert int(np.sum(res_s.total_fevals)) < int(np.sum(res_b.total_fevals))
    print("stop_at OK")

    print("MESH-CHECK-OK")


if __name__ == "__main__":
    main()
