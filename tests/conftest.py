"""Test-session config.

x64 is enabled globally: the CMA-ES core follows the paper's double-precision
reference C code (tolerances down to 1e-12).  All model/training code passes
explicit dtypes (bf16/f32) and is unaffected.

NOTE: XLA_FLAGS / host-device-count overrides are deliberately NOT set here —
smoke tests and benches must see the real single CPU device.  Multi-device
tests spawn subprocesses (see tests/test_strategies.py) or use
``jax.make_mesh`` on 1 device.
"""
import os

import jax
import pytest

jax.config.update("jax_enable_x64", True)

# a leaked global kernel-dispatch override would silently re-route every
# impl= A/B test (e.g. the fused-vs-unfused HLO pins) to one path; the
# suite must see the caller's impl verbatim
os.environ.pop("REPRO_KERNEL_IMPL", None)


@pytest.fixture
def count_device_get(monkeypatch):
    """Count ``jax.device_get`` calls — ``bucketed.pull_schedule`` is the
    tree's only call site, so the count IS the number of device syncs.
    Shared by test_obs.py and test_trace.py: both pin the zero-new-syncs
    contract (every sync is an observed boundary pull)."""
    calls = {"n": 0}
    real = jax.device_get

    def counting(x):
        calls["n"] += 1
        return real(x)

    monkeypatch.setattr(jax, "device_get", counting)
    return calls


def hermetic_subproc_env() -> dict:
    """Minimal env for multi-device subprocess tests — but keep the platform
    pin: on containers that ship an accelerator plugin (e.g. libtpu),
    dropping JAX_PLATFORMS makes the child probe real hardware and hang
    against the TPU metadata service."""
    return {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
            **({"JAX_PLATFORMS": os.environ["JAX_PLATFORMS"]}
               if "JAX_PLATFORMS" in os.environ else {})}
