"""Test-session config.

x64 is enabled globally: the CMA-ES core follows the paper's double-precision
reference C code (tolerances down to 1e-12).  All model/training code passes
explicit dtypes (bf16/f32) and is unaffected.

NOTE: XLA_FLAGS / host-device-count overrides are deliberately NOT set here —
smoke tests and benches must see the real single CPU device.  Multi-device
tests spawn subprocesses (see tests/test_strategies.py) or use
``jax.make_mesh`` on 1 device.
"""
import jax

jax.config.update("jax_enable_x64", True)
