"""Amortized eigendecomposition in the scanned ladder (paper §3.1).

The flat PR-1 scan guarded ``eigh`` with a per-descent ``lax.cond`` — which
vmap lowers to a select that executes BOTH branches, so every vmapped
campaign generation paid the full O(n³) factorization regardless of
``eigen_interval``.  The nested scan (``ladder.scan_eigen_blocks``) makes
the cadence structural; these tests pin the *executed* ``eigh`` count at
the HLO level via trip-count-aware instruction accounting
(``hlo_analyzer.count_ops``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cmaes, ladder
from repro.distributed import hlo_analyzer
from repro.fitness import bbob

EIGH_PATTERN = r"syevd|Eigh"   # LAPACK/cusolver custom-call targets


def _campaign_hlo(eigen_schedule: str, total_gens: int, interval: int) -> str:
    eng = ladder.LadderEngine(n=6, lam_start=8, kmax_exp=1,
                              schedule="sequential", max_evals=10_000,
                              eigen_interval=interval,
                              eigen_schedule=eigen_schedule)
    runner = eng.campaign_runner((1,), total_gens)
    insts = bbob.stack_instances([bbob.make_instance(1, 6, 1)])
    keys = jnp.stack([jax.random.PRNGKey(0)])
    return runner.lower(keys, insts).compile().as_text()


def test_nested_campaign_executes_ceil_T_over_interval_eighs():
    T, interval = 100, 5
    txt = _campaign_hlo("nested", T, interval)
    n_eigh = hlo_analyzer.count_ops(txt, EIGH_PATTERN)
    assert n_eigh == -(-T // interval)          # ⌈T/eigen_interval⌉ — not T


def test_flat_campaign_pays_eigh_every_generation():
    """Regression pin of the vmap-defeated cond: the PR-1 flat scan lowers
    to one eigh per generation no matter what eigen_interval says."""
    T, interval = 100, 5
    txt = _campaign_hlo("flat", T, interval)
    assert hlo_analyzer.count_ops(txt, EIGH_PATTERN) == T


def test_nested_interval_not_dividing_T_rounds_up():
    T, interval = 20, 3
    txt = _campaign_hlo("nested", T, interval)
    n_blocks = -(-T // interval)                # 7 blocks = 21 generations
    assert hlo_analyzer.count_ops(txt, EIGH_PATTERN) == n_blocks


def test_bucketed_segments_amortize_eigh_too():
    from repro.core import bucketed
    eng = bucketed.BucketedLadderEngine(n=6, lam_start=8, kmax_exp=1,
                                        max_evals=10_000, eigen_interval=5)
    seg_gens = eng.bucket_seg_gens(0, need_gens=100)
    runner = eng.segment_runner(0, (1,), seg_gens)
    insts = bbob.stack_instances([bbob.make_instance(1, 6, 1)])
    keys = jnp.stack([jax.random.PRNGKey(0)])
    carry = eng._init_runner(keys)
    txt = runner.lower(keys, insts, carry).compile().as_text()
    assert hlo_analyzer.count_ops(txt, EIGH_PATTERN) == seg_gens // 5


def _sphere(X):
    return jnp.sum(X ** 2, axis=-1)


def test_kdistributed_chunk_amortizes_eigh():
    """Satellite port of scan_eigen_blocks into the strategies chunk scan:
    a whole-block chunk executes ⌈T/interval⌉ batched eighs, not T."""
    from repro.core.strategies import KDistributed
    T, interval = 20, 5
    kd = KDistributed(n=6, n_devices=3, lam_start=8, lam_slots=8,
                      kmax_exp=1, eigen_interval=interval)
    fn = jax.jit(jax.vmap(kd.chunk_fn(_sphere, ("ev",), T),
                          in_axes=(None, None), out_axes=0,
                          axis_name="ev", axis_size=kd.n_devices))
    carry = kd.init_carry(jax.random.PRNGKey(0))
    keys = jax.random.split(jax.random.PRNGKey(1), T)
    txt = fn.lower(carry, keys).compile().as_text()
    assert hlo_analyzer.count_ops(txt, EIGH_PATTERN) == T // interval


def test_kdistributed_ragged_chunk_keeps_flat_scan():
    """Regression pin of the vmap-defeated lazy cond: a chunk that does not
    divide into whole eigen blocks falls back to the flat scan, which pays
    one eigh per generation regardless of eigen_interval."""
    from repro.core.strategies import KDistributed
    T, interval = 18, 5
    kd = KDistributed(n=6, n_devices=3, lam_start=8, lam_slots=8,
                      kmax_exp=1, eigen_interval=interval)
    fn = jax.jit(jax.vmap(kd.chunk_fn(_sphere, ("ev",), T),
                          in_axes=(None, None), out_axes=0,
                          axis_name="ev", axis_size=kd.n_devices))
    carry = kd.init_carry(jax.random.PRNGKey(0))
    keys = jax.random.split(jax.random.PRNGKey(1), T)
    txt = fn.lower(carry, keys).compile().as_text()
    assert hlo_analyzer.count_ops(txt, EIGH_PATTERN) == T


def test_kreplicated_phase_chunk_amortizes_eigh():
    from repro.core import strategies
    T, interval = 20, 5
    kr = strategies.KReplicated(n=6, n_devices=2, lam_start=8, lam_slots=8,
                                eigen_interval=interval)
    cfg, params, G, g = kr.phase_cfg(1)          # one 2-device descent
    run_chunk = kr.phase_chunk_fn(cfg, params, _sphere, T)
    inner = jax.vmap(run_chunk, in_axes=0, out_axes=0, axis_name="grp")
    outer = jax.jit(jax.vmap(inner, in_axes=0, out_axes=0, axis_name="mem"))
    states = kr.init_phase_states(cfg, G, jax.random.PRNGKey(0))  # (G, ...)
    st = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (g,) + a.shape), states)
    rep = lambda a: jnp.broadcast_to(a[None, None], (g, G) + a.shape)
    carry = strategies.KRepCarry(
        state=st, best_f=rep(jnp.asarray(jnp.inf, cfg.jdtype)),
        best_x=rep(jnp.zeros((kr.n,), cfg.jdtype)),
        fevals=rep(jnp.asarray(0, jnp.int64)))
    keys = jnp.broadcast_to(
        jax.random.split(jax.random.PRNGKey(1), T)[None, None],
        (g, G, T, 2))
    txt = outer.lower(carry, keys).compile().as_text()
    assert hlo_analyzer.count_ops(txt, EIGH_PATTERN) == T // interval


def test_run_concurrent_with_eigen_interval_converges():
    """End-to-end: the nested chunk inside run_concurrent still optimizes."""
    kd, carry, trace = ladder.run_concurrent(
        n=6, n_devices=3, key=jax.random.PRNGKey(0), fitness_fn=_sphere,
        total_gens=100, lam_start=8, kmax_exp=1, eigen_interval=5)
    assert int(kd.cfg.eigen_interval) == 5
    assert float(carry.best_f) < 1e-5
    assert np.all(np.diff(trace["best_f"]) <= 1e-15)


def test_nested_equals_flat_when_interval_is_1():
    """interval == 1: every generation refreshes in both schedules, so the
    nested restructuring must not change the trajectory."""
    kw = dict(n=4, lam_start=8, kmax_exp=2, schedule="sequential",
              max_evals=4000)
    eng_n = ladder.LadderEngine(**kw)
    eng_f = ladder.LadderEngine(eigen_schedule="flat", **kw)
    assert eng_n.cfg.eigen_interval == 1
    r_n = ladder.run_campaign(eng_n, fids=(1, 8), instances=(1,), runs=1,
                              seed=0)
    r_f = ladder.run_campaign(eng_f, fids=(1, 8), instances=(1,), runs=1,
                              seed=0)
    np.testing.assert_array_equal(r_n.total_fevals, r_f.total_fevals)
    np.testing.assert_allclose(r_n.best_f, r_f.best_f, rtol=1e-9)


def test_update_from_moments_eigen_modes():
    from repro.core.params import CMAConfig, make_params
    cfg = CMAConfig(n=4, lam=8, eigen_interval=10)
    p = make_params(cfg)
    st = cmaes.init_state(cfg, jax.random.PRNGKey(0), jnp.ones(4), 0.5)
    y, x = cmaes.sample_population(st, jax.random.PRNGKey(1), 8)
    f = jnp.sum(x ** 2, axis=-1)
    mom = cmaes.compute_moments(y, f, x, p, 8)

    deferred = cmaes.update_from_moments(cfg, p, st, mom, eigen="defer")
    np.testing.assert_array_equal(np.asarray(deferred.B), np.asarray(st.B))
    np.testing.assert_array_equal(np.asarray(deferred.D), np.asarray(st.D))
    assert int(deferred.last_eigen_gen) == int(st.last_eigen_gen)

    always = cmaes.update_from_moments(cfg, p, st, mom, eigen="always")
    assert int(always.last_eigen_gen) == int(st.gen) + 1
    # B/D really factorize the new covariance
    C_rec = np.asarray(always.B) @ np.diag(np.asarray(always.D) ** 2) \
        @ np.asarray(always.B).T
    np.testing.assert_allclose(C_rec, np.asarray(always.C), atol=1e-12)
    # covariance itself advances identically in every mode
    np.testing.assert_array_equal(np.asarray(deferred.C),
                                  np.asarray(always.C))

    with pytest.raises(ValueError, match="eigen"):
        cmaes.update_from_moments(cfg, p, st, mom, eigen="sometimes")


def test_row_keyed_sampling_is_prefix_stable():
    """Row i's draw must not depend on how many rows the program pads to —
    the property the bucketed engine's equivalence rests on."""
    from repro.core.params import CMAConfig
    cfg = CMAConfig(n=5, lam=8)
    st = cmaes.init_state(cfg, jax.random.PRNGKey(0), jnp.zeros(5), 1.0)
    k = jax.random.PRNGKey(42)
    y8, x8 = cmaes.sample_population(st, k, 8)
    y32, x32 = cmaes.sample_population(st, k, 32)
    np.testing.assert_array_equal(np.asarray(y8), np.asarray(y32)[:8])
    np.testing.assert_array_equal(np.asarray(x8), np.asarray(x32)[:8])
