"""Eval-fused sample epilogue (PR 7): residency HLO pins + backend parity.

For an all-separable BBOB fid menu (``bbob.FUSABLE_FIDS`` — f1 sphere, f2
ellipsoid) the fitness fuses into the sample epilogue (``ref.gen_sample_eval``
/ ``kernels/cma_gen.py``): segment programs return (Y, F) and the (λ, n) X
tile never gets an HBM buffer.  Pinned here at the compiled-HLO level, and
the fused programs must be TRAJECTORY-IDENTICAL to the dispatched two-program
fallback (``REPRO_EVAL_FUSION=0``) across the bucketed / mesh / service
backends — the separable algebra is IEEE-exact against ``evaluate_dynamic``
on the same X, so fevals, best-f and ECDF agree bitwise.

Also pins tentpole (c): the strategies collectives path lowers exactly one
(n, n+1) gram-family dot per generation (``Ysᵀ·[Ys | √w]``), with the
PR-6 moments soup's separate (n, n) gram dot gone.
"""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucketed, strategies
from repro.distributed import hlo_analyzer, mesh_engine
from repro.fitness import bbob

N, LAM = 6, 8
LAMN = r"f64\[(?:\d+,)*8,6\]"          # any leading batch dims, then (λ, n)


# ---------------------------------------------------------------------------
# HLO pins
# ---------------------------------------------------------------------------

def _toplevel_instrs(txt):
    """(comp, instr) pairs outside fusion bodies — the instructions that own
    an HBM buffer (inner ops of a kLoop/kOutput fusion never materialize)."""
    comps = hlo_analyzer.parse_module(txt)
    bodies = set()
    for c in comps.values():
        for i in c.instrs:
            m = re.search(r"calls=%?([\w.\-]+)", i.rest)
            if i.opcode == "fusion" and m:
                bodies.add(m.group(1))
    return [(c, i) for c in comps.values() if c.name not in bodies
            for i in c.instrs]


def _fused_segment_hlo(monkeypatch, fusion: str):
    monkeypatch.setenv("REPRO_EVAL_FUSION", fusion)
    eng = bucketed.BucketedLadderEngine(n=N, lam_start=LAM, kmax_exp=0,
                                        max_evals=10_000, impl="xla")
    seg_gens = eng.bucket_seg_gens(0, need_gens=20)
    runner = eng.segment_runner(0, (1, 2), seg_gens)
    insts = bbob.stack_instances([bbob.make_instance(1, N, 1),
                                  bbob.make_instance(2, N, 1)])
    keys = jnp.stack([jax.random.PRNGKey(0), jax.random.PRNGKey(1)])
    carry = eng._init_runner(keys)
    return runner.lower(keys, insts, carry).compile().as_text()


def test_fused_segment_zero_x_materialization(monkeypatch):
    """The residency pin: in a fused-fid segment program the ONLY (λ, n)
    tensors with HBM buffers are the Z draw (consumes raw u32 key material)
    and the Y transform dot — nothing (λ, n)-shaped is computed FROM Y, so
    X = m + σ·Y never materializes."""
    top = _toplevel_instrs(_fused_segment_hlo(monkeypatch, "1"))
    lam_n = [(c, i) for c, i in top if re.search(LAMN, i.shape)]
    assert lam_n, "expected the Y transform in the segment body"
    dots = [i.name for _, i in lam_n if i.opcode == "dot"]
    assert dots, "the Y = (Z·D)·Bᵀ transform must be a materialized dot"
    # no (λ, n) instruction consumes a (λ, n) dot output: X is never stored
    for _, i in lam_n:
        for d in dots:
            assert not re.search(rf"%{re.escape(d)}\b", i.rest), (
                f"(λ,n) instr {i.name} consumes Y dot {d} — X materialized")
    # and every (λ, n) buffer is one of {Z draw, Y dot}: two per gen body
    non_dot = [i for _, i in lam_n if i.opcode != "dot"]
    for i in non_dot:
        assert "u32[" in i.rest, (
            f"unexpected (λ,n) producer {i.opcode} {i.name} (not the Z draw)")


def test_dispatched_segment_keeps_two_program_shape(monkeypatch):
    """The fallback still compiles and keeps the Y dot; the pin above is
    about the fused program, not a claim the dispatched one is worse on
    CPU (XLA may fuse X into the eval reduction there too)."""
    top = _toplevel_instrs(_fused_segment_hlo(monkeypatch, "0"))
    assert any(i.opcode == "dot" and re.search(LAMN, i.shape)
               for _, i in top)


FAM_DOT = r"f64\[(?:\d+,)*6,7\]\S* dot\b"     # (n, n+1) gram-family dot
GRAM_DOT = r"f64\[(?:\d+,)*6,6\]\S* dot\b"    # PR-6 separate (n, n) gram


def _kdist_chunk_hlo(impl: str, chunk: int = 8) -> str:
    sphere = lambda X: jnp.sum(X ** 2, axis=-1)
    kd = strategies.KDistributed(n=N, n_devices=3, lam_start=8, lam_slots=8,
                                 kmax_exp=1, impl=impl, eigen_interval=8)
    carry = kd.init_carry(jax.random.PRNGKey(0))
    fn = jax.jit(jax.vmap(kd.chunk_fn(sphere, ("ev",), chunk),
                          in_axes=(None, None), out_axes=0,
                          axis_name="ev", axis_size=3))
    keys = jax.random.split(jax.random.PRNGKey(1), chunk)
    return fn.lower(carry, keys).compile().as_text()


def test_strategies_one_gram_family_dot_per_generation():
    """Tentpole (c): the collectives path executes ONE √w-factored
    ``Ysᵀ·[Ys | √w]`` contraction per generation and the separate (n, n)
    gram dot of the moments soup is gone."""
    txt = _kdist_chunk_hlo("xla")
    assert hlo_analyzer.count_instrs(txt, FAM_DOT) == 8
    assert hlo_analyzer.count_instrs(txt, GRAM_DOT) == 0


def test_strategies_unfused_baseline_keeps_moments_gram():
    txt = _kdist_chunk_hlo("xla_unfused")
    assert hlo_analyzer.count_instrs(txt, GRAM_DOT) == 8
    assert hlo_analyzer.count_instrs(txt, FAM_DOT) == 0


# ---------------------------------------------------------------------------
# backend parity: fused vs dispatched must be trajectory-identical
# ---------------------------------------------------------------------------

TARGETS = np.array([1e2, 1e0, 1e-2, 1e-6])


def _bucketed_campaign(monkeypatch, fusion: str):
    monkeypatch.setenv("REPRO_EVAL_FUSION", fusion)
    eng = bucketed.BucketedLadderEngine(n=4, lam_start=8, kmax_exp=2,
                                        max_evals=4000, impl="xla")
    return bucketed.run_campaign_bucketed(eng, fids=(1, 2), instances=(1,),
                                          runs=2, seed=0)


def test_bucketed_fused_matches_dispatched_bitwise(monkeypatch):
    r_f = _bucketed_campaign(monkeypatch, "1")
    r_d = _bucketed_campaign(monkeypatch, "0")
    np.testing.assert_array_equal(r_f.total_fevals, r_d.total_fevals)
    np.testing.assert_array_equal(r_f.best_f, r_d.best_f)
    np.testing.assert_array_equal(r_f.best_x, r_d.best_x)
    assert r_f.useful_evals == r_d.useful_evals
    np.testing.assert_array_equal(r_f.hit_evals(TARGETS),
                                  r_d.hit_evals(TARGETS))


@pytest.mark.parametrize("strategy", ["ordered", "concurrent"])
def test_mesh_fused_matches_dispatched_bitwise(strategy, monkeypatch):
    def run(fusion):
        monkeypatch.setenv("REPRO_EVAL_FUSION", fusion)
        eng = mesh_engine.MeshCampaignEngine(strategy=strategy, n=4,
                                             lam_start=8, kmax_exp=2,
                                             max_evals=4000)
        return mesh_engine.run_campaign_mesh(eng, fids=(1, 2),
                                             instances=(1,), runs=2, seed=0)
    r_f, r_d = run("1"), run("0")
    np.testing.assert_array_equal(r_f.total_fevals, r_d.total_fevals)
    np.testing.assert_array_equal(r_f.best_f, r_d.best_f)
    np.testing.assert_array_equal(r_f.hit_evals(TARGETS),
                                  r_d.hit_evals(TARGETS))
    assert r_f.useful_evals == r_d.useful_evals


def test_bucketed_counts_eval_fused_generations(monkeypatch):
    from repro import obs
    reg = obs.metrics()
    before = reg.counter("bucketed_eval_fused_generations_total").value
    _bucketed_campaign(monkeypatch, "1")
    mid = reg.counter("bucketed_eval_fused_generations_total").value
    assert mid > before                      # fused menu: generations counted
    _bucketed_campaign(monkeypatch, "0")
    assert reg.counter("bucketed_eval_fused_generations_total").value == mid


# ---------------------------------------------------------------------------
# service: same-fid jobs join running fused program families
# ---------------------------------------------------------------------------

def _make_server():
    from repro.service import CampaignServer, FitnessRegistry
    return CampaignServer(registry=FitnessRegistry(), bbob_fids=(1, 2),
                          max_budget=5000, rows_per_island=2,
                          lam_start=8, kmax_exp=2)


def _run_service_jobs(monkeypatch, fusion: str):
    from repro.service import CampaignRequest
    monkeypatch.setenv("REPRO_EVAL_FUSION", fusion)
    srv = _make_server()
    t1 = srv.submit(CampaignRequest(dim=4, fid=1, budget=2000, seed=3))
    t2 = srv.submit(CampaignRequest(dim=4, fid=2, budget=1500, seed=5))
    for _ in range(2):
        srv.step()                           # lane is mid-flight
    # same-fid mid-flight arrival must JOIN the running program family
    t3 = srv.submit(CampaignRequest(dim=4, fid=1, budget=1200, seed=13))
    srv.drain()
    compiles = srv.segment_compiles()
    t4 = srv.submit(CampaignRequest(dim=4, fid=2, budget=1000, seed=17))
    srv.drain()
    assert t4.done
    assert srv.segment_compiles() == compiles, "same-fid job added a program"
    return [t.result for t in (t1, t2, t3, t4)]


def test_service_fused_menu_joins_programs_and_matches_dispatched(
        monkeypatch):
    res_f = _run_service_jobs(monkeypatch, "1")
    res_d = _run_service_jobs(monkeypatch, "0")
    for rf, rd in zip(res_f, res_d):
        assert rf.total_fevals == rd.total_fevals
        assert len(rf.descents) == len(rd.descents)
        for df, dd in zip(rf.descents, rd.descents):
            assert df.k_exp == dd.k_exp and df.lam == dd.lam
            np.testing.assert_array_equal(df.fevals, dd.fevals)
            np.testing.assert_array_equal(df.best_f, dd.best_f)
            assert df.stop_reason == dd.stop_reason
        np.testing.assert_array_equal(rf.best_f, rd.best_f)
