"""Serving engine: batched greedy generation, cache_specs shapes, and a
subprocess mini dry-run proving the multi-device lowering path end-to-end."""
import json
import subprocess
import sys

from conftest import hermetic_subproc_env
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.distributed import sharding
from repro.models import lm
from repro.serve.engine import Engine, Request

pytestmark = pytest.mark.slow  # heavy model/train/serve tier — excluded from fast CI

SUBPROC_ENV = hermetic_subproc_env()


def test_engine_generates_consistent_greedy():
    cfg = smoke_config("qwen2-0.5b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=(12,), dtype=np.int32)
               for _ in range(3)]
    reqs = [Request(prompt=p, max_new_tokens=6) for p in prompts]
    eng.generate(reqs)
    for r in reqs:
        assert r.out.shape == (6,)
        assert np.all((0 <= r.out) & (r.out < cfg.vocab))
    # same prompt twice in one batch → identical greedy continuations
    reqs2 = [Request(prompt=prompts[0], max_new_tokens=6),
             Request(prompt=prompts[0], max_new_tokens=6)]
    eng.generate(reqs2)
    np.testing.assert_array_equal(reqs2[0].out, reqs2[1].out)


def test_cache_specs_name_based():
    cfg = smoke_config("zamba2-7b")
    cache = lm.init_cache(cfg, B=8, max_len=32)
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices() * 8)[:8].reshape(4, 2), ("data", "model"))
    specs = sharding.cache_specs(cache, mesh, batch=8)

    def axes_of(ax):
        return ax if isinstance(ax, tuple) else (ax,)
    # KV leaves shard batch over dp and (divisible) heads over model
    assert "data" in axes_of(specs["shared_k"][1])
    assert "model" in axes_of(specs["shared_k"][3])
    # SSM state: batch over dp, heads over model, state dims replicated
    assert "data" in axes_of(specs["ssm"][2])
    assert specs["ssm"][4] is None and specs["ssm"][5] is None
    assert specs["length"] == jax.sharding.PartitionSpec()


def test_cache_specs_long_context_seq_sharding():
    """B=1 long-context decode: KV sequence dim shards over 'data' (SP)."""
    cfg = smoke_config("gemma3-27b")
    cache = lm.init_cache(cfg, B=1, max_len=64)
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices() * 8)[:8].reshape(4, 2), ("data", "model"))
    specs = sharding.cache_specs(cache, mesh, batch=1)
    assert specs["global_k"][2] == "data"          # (U, B, S, Hk, Dh)


MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, dataclasses, jax, jax.numpy as jnp
from repro.configs import smoke_config
from repro.launch import specs as specs_mod
from repro.launch.dryrun import _named, _batch_shardings
from repro.distributed import sharding
from repro.serve import engine as serve_engine
from repro.train import optimizer as opt_mod, train_step as ts_mod

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = dataclasses.replace(smoke_config("qwen2-0.5b"), attn_impl="flash")
sharding.set_mesh(mesh)

# train lowering
params_abs = specs_mod.params_abstract(cfg)
opt_abs = jax.eval_shape(opt_mod.init_opt_state, params_abs)
batch_abs = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
psh, osh, _ = ts_mod.shardings_for(cfg, mesh, params_abstract=params_abs)
bsh = _batch_shardings(mesh, batch_abs)
step = ts_mod.make_train_step(cfg, ts_mod.TrainConfig(microbatches=2), mesh)
c = jax.jit(step, in_shardings=(psh, osh, bsh)).lower(
    params_abs, opt_abs, batch_abs).compile()
ca = c.cost_analysis()
ca = ca[0] if isinstance(ca, list) else ca   # 0.4.x returns [dict]
assert ca.get("flops", 0) > 0

# decode lowering
cache_abs = specs_mod.cache_abstract(cfg, 8, 64)
csh = _named(mesh, sharding.cache_specs(cache_abs, mesh, 8))
psh2 = _named(mesh, sharding.param_specs(
    specs_mod.params_abstract(cfg, dtype=cfg.dtype), mesh))
fn = serve_engine.make_serve_step(cfg, mesh)
tok = {"tokens": jax.ShapeDtypeStruct((8, 1), jnp.int32)}
c2 = jax.jit(fn, in_shardings=(psh2, csh, _batch_shardings(mesh, tok))).lower(
    specs_mod.params_abstract(cfg, dtype=cfg.dtype), cache_abs, tok).compile()
print(json.dumps({"ok": True}))
"""


def test_mini_dryrun_8_devices():
    """The dry-run path on an 8-device mesh in a subprocess (the production
    512-device run is exercised by repro.launch.dryrun — EXPERIMENTS)."""
    out = subprocess.run([sys.executable, "-c", MINI_DRYRUN],
                         capture_output=True, text=True, timeout=540,
                         env=SUBPROC_ENV)
    assert out.returncode == 0, out.stderr[-2000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]
