"""Shape/dtype sweeps for the LM Pallas kernels against the pure-jnp
oracles (interpret mode on CPU — bit-correct kernel body semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6_wkv import wkv6_forward, CHUNK

pytestmark = pytest.mark.slow  # heavy model/train/serve tier — excluded from fast CI


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Hk,D", [
    (1, 128, 4, 4, 64),       # MHA, one block
    (2, 256, 4, 2, 64),       # GQA 2:1, multi q/kv blocks
    (1, 384, 8, 1, 128),      # MQA, non-pow2 seq (padding path)
    (2, 129, 4, 4, 64),       # ragged seq → q-pad
])
def test_flash_attention_causal(dtype, B, S, H, Hk, D):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hk, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hk, D), dtype)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [32, 100, 128])
def test_flash_attention_sliding_window(window):
    B, S, H, Hk, D = 1, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hk, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hk, D), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window,
                          interpret=True, bq=64, bkv=64)
    want = ref.flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_block_shape_independence():
    """Output must not depend on the BlockSpec tiling."""
    B, S, H, Hk, D = 1, 256, 2, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hk, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hk, D), jnp.float32)
    o1 = flash_attention(q, k, v, bq=128, bkv=128, interpret=True)
    o2 = flash_attention(q, k, v, bq=64, bkv=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,D", [
    (1, CHUNK * 2, 2, 32),
    (2, CHUNK * 4, 4, 64),
    (1, CHUNK * 8, 1, 128),
])
def test_wkv6_kernel(dtype, B, S, H, D):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, H, D), dtype)
    v = jax.random.normal(ks[2], (B, S, H, D), dtype)
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, D), jnp.float32))
    logw = jnp.clip(logw, -5.0, -1e-6)
    u = jax.random.normal(ks[4], (H, D), jnp.float32) * 0.1
    got = wkv6_forward(r, k, v, logw, u, interpret=True)
    want = ref.wkv6(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_wkv6_state_carry_matches_sequential():
    """The kernel's cross-chunk state carry must equal a token-by-token
    recurrence (the decode path), not just the chunked oracle."""
    B, S, H, D = 1, CHUNK * 3, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    r = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    logw = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (B, S, H, D))),
                    -5.0, -1e-6).astype(jnp.float32)
    u = jax.random.normal(ks[4], (H, D), jnp.float32) * 0.1

    got = wkv6_forward(r, k, v, logw, u, interpret=True)

    # sequential recurrence
    S_state = np.zeros((B, H, D, D), np.float32)
    outs = np.zeros((B, S, H, D), np.float32)
    rn, kn, vn, wn = map(np.asarray, (r, k, v, logw))
    un = np.asarray(u)
    for t in range(S):
        kv = np.einsum("bhd,bhe->bhde", kn[:, t], vn[:, t])
        outs[:, t] = np.einsum("bhd,bhde->bhe", rn[:, t],
                               S_state + un[None, :, :, None] * kv)
        S_state = np.exp(wn[:, t])[..., None] * S_state + kv
    np.testing.assert_allclose(np.asarray(got), outs, rtol=1e-4, atol=1e-4)
