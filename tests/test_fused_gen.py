"""Fused generation path: HLO pins + engine-level equivalence (PR 4).

The fused update (ref.fused_gen_update / kernels/cma_gen.py) must lower to
exactly ONE gram-family dot-general per generation — the (n, n+1)-shaped
``[gram | y_w] = Y_sᵀ·[Y_s | √w]`` contraction — with the pre-PR-4 op soup
(separate (n, n) gram dot + y_w GEMV) gone.  Pinned at the HLO level with
the same trip-count-aware accounting the eigen-amortization tests use
(``hlo_analyzer.count_instrs``, the shape-aware sibling of ``count_ops``).

Engine level: ``backend="bucketed"`` under the fused ``impl="xla"`` must be
trajectory-equivalent to the PR-3 unfused path (``impl="xla_unfused"``) —
identical generation structure, tolerance-bounded best-f — and the
``compiles ≤ #buckets`` invariant must survive the new dispatch.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucketed, cmaes, ladder
from repro.core.ipop import run_ipop
from repro.core.params import CMAConfig, make_params
from repro.distributed import hlo_analyzer

N, LAM, T = 6, 8, 40

# vmap inserts unit batch dims in campaign programs — allow leading 1s
DOT_N_NP1 = r"f64\[(?:1,)*6,7\]\S* dot\b"      # the fused gram-family dot
DOT_N_N = r"f64\[(?:1,)*6,6\]\S* dot\b"        # the unfused separate gram


def _scan_hlo(impl: str, T: int = T) -> str:
    cfg = CMAConfig(n=N, lam=LAM, eigen_interval=1)
    p = make_params(cfg)
    sphere = lambda X: jnp.sum(X ** 2, axis=-1)

    def body(st, k):
        st = ladder.padded_gen_step(cfg, p, st, k, sphere, impl=impl)
        return st, st.best_f

    st = cmaes.init_state(cfg, jax.random.PRNGKey(0), jnp.zeros(N), 1.0)
    ks = jax.random.split(jax.random.PRNGKey(1), T)
    fn = jax.jit(lambda s, k: jax.lax.scan(body, s, k))
    return fn.lower(st, ks).compile().as_text()


def test_fused_path_one_gram_family_dot_per_generation():
    txt = _scan_hlo("xla")
    assert hlo_analyzer.count_instrs(txt, DOT_N_NP1) == T
    # the separate (n, n) gram dot of the unfused soup must be GONE
    assert hlo_analyzer.count_instrs(txt, DOT_N_N) == 0


def test_unfused_path_keeps_separate_gram_dot():
    """Regression pin of the baseline shape: the PR-3 op soup lowers the
    gram as its own (n, n) dot and has no (n, n+1) fused dot."""
    txt = _scan_hlo("xla_unfused")
    assert hlo_analyzer.count_instrs(txt, DOT_N_N) == T
    assert hlo_analyzer.count_instrs(txt, DOT_N_NP1) == 0


def test_fused_path_drops_one_population_dot_per_generation():
    """The y_w GEMV rides the fused dot: one fewer dot per generation."""
    fused = hlo_analyzer.count_instrs(_scan_hlo("xla"), r" dot\b")
    unfused = hlo_analyzer.count_instrs(_scan_hlo("xla_unfused"), r" dot\b")
    assert unfused - fused == T


def test_bucketed_campaign_hlo_pins_fused_dot():
    """The pin holds inside the real (jit+vmap) bucketed segment programs,
    not just a hand-rolled scan."""
    from repro.fitness import bbob
    eng = bucketed.BucketedLadderEngine(n=N, lam_start=8, kmax_exp=1,
                                        max_evals=10_000, impl="xla")
    seg_gens = eng.bucket_seg_gens(0, need_gens=30)
    runner = eng.segment_runner(0, (1,), seg_gens)
    insts = bbob.stack_instances([bbob.make_instance(1, N, 1)])
    keys = jnp.stack([jax.random.PRNGKey(0)])
    carry = eng._init_runner(keys)
    txt = runner.lower(keys, insts, carry).compile().as_text()
    assert hlo_analyzer.count_instrs(txt, DOT_N_NP1) == seg_gens
    assert hlo_analyzer.count_instrs(txt, DOT_N_N) == 0


# ---------------------------------------------------------------------------
# engine-level equivalence: fused vs the PR-3 unfused path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fid", [1, 8])
def test_bucketed_fused_matches_unfused_trajectory(fid):
    """backend="bucketed" under impl="xla" (fused) vs impl="xla_unfused"
    (PR-3): the fused path regroups identical arithmetic, so the two differ
    by rounding only.  On the sphere that leaves the whole generation
    structure intact; on Rosenbrock the eps-level seed noise is chaotically
    amplified over hundreds of generations, so a data-dependent stopping
    criterion may fire a couple of generations apart — the structure must
    still match rung-for-rung with near-identical descent lengths, and the
    early trajectory (before chaos decorrelates) must agree tightly."""
    from repro.fitness import bbob
    inst = bbob.make_instance(fid, 4, 1)
    fit = lambda X: bbob.evaluate(fid, inst, X)
    kw = dict(lam_start=8, kmax_exp=2, max_evals=4000, backend="bucketed")
    r_f = run_ipop(fit, 4, jax.random.PRNGKey(3), impl="xla", **kw)
    r_u = run_ipop(fit, 4, jax.random.PRNGKey(3), impl="xla_unfused", **kw)
    assert len(r_f.descents) == len(r_u.descents)
    assert abs(r_f.total_fevals - r_u.total_fevals) \
        <= 0.02 * r_u.total_fevals + 2 * 32
    for df, du in zip(r_f.descents, r_u.descents):
        assert df.k_exp == du.k_exp and df.lam == du.lam
        assert abs(len(df.fevals) - len(du.fevals)) \
            <= max(3, 0.02 * len(du.fevals))
        common = min(len(df.fevals), len(du.fevals))
        np.testing.assert_array_equal(df.fevals[:common], du.fevals[:common])
        # pre-chaos prefix: tight; the eps seed needs ~dozens of gens to grow
        head = min(common, 30)
        np.testing.assert_allclose(df.best_f[:head], du.best_f[:head],
                                   rtol=1e-6, atol=1e-9)
    if fid == 1:   # sphere: no chaotic amplification — full strictness
        assert r_f.total_fevals == r_u.total_fevals
        for df, du in zip(r_f.descents, r_u.descents):
            np.testing.assert_array_equal(df.fevals, du.fevals)
        np.testing.assert_allclose(r_f.best_f, r_u.best_f,
                                   rtol=1e-5, atol=1e-7)


def test_compiles_le_buckets_under_fused_dispatch():
    """The new dispatch must not leak extra compilations: one program per
    bucket, reused across campaigns, exactly as PR 2/3 pinned."""
    eng = bucketed.BucketedLadderEngine(n=4, lam_start=8, kmax_exp=2,
                                        max_evals=5000, impl="xla")
    res = bucketed.run_campaign_bucketed(eng, fids=(1, 8), instances=(1,),
                                         runs=2, seed=0)
    assert 1 <= res.compiles <= 3
    res2 = bucketed.run_campaign_bucketed(eng, fids=(1, 8), instances=(1,),
                                          runs=2, seed=5)
    assert res2.compiles <= 3


def test_run_ipop_validates_impl_at_entry():
    sphere = lambda X: jnp.sum(X ** 2, axis=-1)
    for backend in ("ladder", "bucketed", "mesh", "hostloop"):
        with pytest.raises(ValueError, match="unknown impl"):
            run_ipop(sphere, 3, jax.random.PRNGKey(0), lam_start=4,
                     kmax_exp=1, max_evals=100, backend=backend,
                     impl="not-an-impl")


def test_engine_configs_validate_impl():
    with pytest.raises(ValueError, match="unknown impl"):
        ladder.LadderEngine(n=3, lam_start=4, kmax_exp=1, impl="mosaic")
    with pytest.raises(ValueError, match="unknown impl"):
        bucketed.BucketedLadderEngine(n=3, lam_start=4, kmax_exp=1,
                                      impl="mosaic")


def test_ladder_campaign_runs_on_pallas_interpret():
    """The slot-batched megakernels must survive the real engine context —
    jit + campaign vmap on top of the slot grid axis (interpret mode off
    TPU).  f32 in-kernel accumulation leaves ~1e-13 residual on the
    sphere where the f64 ref reaches exact zero."""
    eng = ladder.LadderEngine(n=4, lam_start=8, kmax_exp=1, max_evals=1200,
                              schedule="sequential", impl="pallas")
    res = ladder.run_campaign(eng, fids=(1,), instances=(1,), runs=2,
                              seed=0)
    assert (np.asarray(res.best_f) - np.asarray(res.f_opt) < 1e-8).all()
    assert (np.asarray(res.total_fevals) <= 1200).all()


def test_ladder_engine_fused_unfused_ecdf_equivalent():
    """Whole-ladder sanity at the padded engine level: fused and unfused
    campaigns hit the same targets on the sphere within one member."""
    kw = dict(n=4, lam_start=8, kmax_exp=1, max_evals=3000,
              schedule="sequential")
    res_f = ladder.run_campaign(ladder.LadderEngine(impl="xla", **kw),
                                fids=(1,), instances=(1,), runs=2, seed=0)
    res_u = ladder.run_campaign(
        ladder.LadderEngine(impl="xla_unfused", **kw),
        fids=(1,), instances=(1,), runs=2, seed=0)
    np.testing.assert_array_equal(res_f.total_fevals, res_u.total_fevals)
    targets = np.array([1e2, 1e-1, 1e-6])
    hits_f = np.isfinite(res_f.hit_evals(targets)).mean(axis=0)
    hits_u = np.isfinite(res_u.hit_evals(targets)).mean(axis=0)
    assert np.all(np.abs(hits_f - hits_u) <= 0.5 + 1e-9)
    assert (res_f.best_f < 1e-8).all() and (res_u.best_f < 1e-8).all()
