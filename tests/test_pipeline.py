"""Pipeline parallelism: GPipe schedule correctness vs sequential layers."""
import json
import subprocess
import sys

from conftest import hermetic_subproc_env
import pytest

from repro.distributed.pipeline import bubble_fraction

pytestmark = pytest.mark.slow  # heavy model/train/serve tier — excluded from fast CI

SUBPROC_ENV = hermetic_subproc_env()


def test_bubble_fraction_law():
    assert bubble_fraction(1, 1) == 0.0
    assert abs(bubble_fraction(4, 2) - 1 / 5) < 1e-12
    assert bubble_fraction(32, 2) < 0.04


PIPE_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_apply

mesh = jax.make_mesh((4,), ("pod",))
S, M, mb, d = 4, 6, 3, 8
key = jax.random.PRNGKey(0)
W = jax.random.normal(key, (S, d, d)) * 0.3
b = jax.random.normal(jax.random.PRNGKey(1), (S, d)) * 0.1
params = {"w": W, "b": b}
xs = jax.random.normal(jax.random.PRNGKey(2), (M, mb, d))

def stage(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])

got = pipeline_apply(stage, params, xs, mesh, axis="pod")

want = xs
for s in range(S):
    want = jnp.tanh(want @ W[s] + b[s])

ok = bool(jnp.allclose(got, want, rtol=1e-5, atol=1e-5))
print(json.dumps({"ok": ok,
                  "max_err": float(jnp.max(jnp.abs(got - want)))}))
"""


def test_gpipe_matches_sequential_4_stages():
    out = subprocess.run([sys.executable, "-c", PIPE_PROG],
                         capture_output=True, text=True, timeout=300,
                         env=SUBPROC_ENV)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"], f"pipeline mismatch: max_err={res['max_err']}"
