"""Tests for the K-Distributed / K-Replicated mesh schedules (paper §3.2).

Run via the vmap simulation path: bit-identical program to the shard_map
production path (same per-device code, same named-axis collectives).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cmaes, ipop, strategies
from repro.fitness import bbob


def sphere(X):
    return jnp.sum(X ** 2, axis=-1)


class TestHeapLayout:
    def test_descent_of(self):
        # devices [0 | 1 2 | 3 4 5 6 | 7..14] → descents 0,1,1,2,2,2,2,3...
        got = np.asarray(strategies.heap_descent_of(jnp.arange(15), 15))
        want = [0, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 3, 3, 3, 3]
        np.testing.assert_array_equal(got, want)

    def test_group_sizes_sum(self):
        kd = strategies.KDistributed(n=4, n_devices=8, lam_start=4, lam_slots=4)
        assert kd.kmax_exp == 2
        assert kd.n_active == 7
        assert kd.n_descents == 3


class TestKDistributed:
    def test_converges_on_sphere(self):
        kd = strategies.KDistributed(n=4, n_devices=7, lam_start=6, lam_slots=6,
                                     kmax_exp=2, domain=(-5, 5))
        carry, trace = kd.run_sim(jax.random.PRNGKey(0), sphere, total_gens=120)
        assert float(carry.best_f) < 1e-8
        # best-so-far is monotonically non-increasing
        bf = trace["best_f"]
        assert np.all(np.diff(bf) <= 1e-15)

    def test_descent_populations(self):
        kd = strategies.KDistributed(n=4, n_devices=7, lam_start=6, lam_slots=6,
                                     kmax_exp=2)
        lams = np.asarray(kd.sparams.lam)
        np.testing.assert_array_equal(lams, [6, 12, 24])

    def test_eval_accounting(self):
        kd = strategies.KDistributed(n=3, n_devices=7, lam_start=4, lam_slots=4,
                                     kmax_exp=2)
        carry, trace = kd.run_sim(jax.random.PRNGKey(1), sphere, total_gens=10)
        # per gen: 4 + 8 + 16 = 28 evaluations
        assert int(trace["fevals"][-1]) == 28 * 10
        np.testing.assert_array_equal(np.asarray(carry.fevals), [40, 80, 160])

    def test_replicated_consistency_across_devices(self):
        """All devices must hold identical carries (SPMD invariant)."""
        kd = strategies.KDistributed(n=3, n_devices=3, lam_start=4, lam_slots=4,
                                     kmax_exp=1)
        carry = kd.init_carry(jax.random.PRNGKey(0))
        keys = jax.random.split(jax.random.PRNGKey(1), 5)
        fn = jax.vmap(kd.chunk_fn(sphere, ("ev",), 5), in_axes=(None, None),
                      out_axes=0, axis_name="ev", axis_size=3)
        carry_b, _ = fn(carry, keys)
        for leaf in jax.tree_util.tree_leaves(carry_b):
            for d in range(1, 3):
                np.testing.assert_array_equal(np.asarray(leaf[0]),
                                              np.asarray(leaf[d]))

    def test_distributed_matches_dense_oracle(self):
        """One distributed generation == dense CMA-ES update on gathered points."""
        n, lam_start, kmax = 5, 4, 1
        kd = strategies.KDistributed(n=n, n_devices=3, lam_start=lam_start,
                                     lam_slots=lam_start, kmax_exp=kmax,
                                     restart_on_stop=False)
        carry = kd.init_carry(jax.random.PRNGKey(7))
        gen_key = jax.random.PRNGKey(13)
        fn = jax.vmap(kd.chunk_fn(sphere, ("ev",), 1), in_axes=(None, None),
                      out_axes=0, axis_name="ev", axis_size=3)
        carry2_b, _ = fn(carry, gen_key[None])
        carry2 = jax.tree_util.tree_map(lambda a: a[0], carry2_b)

        # dense replay: regenerate each device's points with the same keys
        for desc, devs in [(0, [0]), (1, [1, 2])]:
            st0 = jax.tree_util.tree_map(lambda a: a[desc], carry.states)
            ys, xs = [], []
            for d in devs:
                k = jax.random.fold_in(gen_key, d)
                k = jax.random.fold_in(k, 0)
                k_s, _ = jax.random.split(k)
                y, x = cmaes.sample_population(st0, k_s, lam_start)
                ys.append(y)
                xs.append(x)
            Y = jnp.concatenate(ys)
            X = jnp.concatenate(xs)
            f = sphere(X)
            params_d = jax.tree_util.tree_map(lambda a: a[desc], kd.sparams)
            mom = cmaes.compute_moments(Y, f, X, params_d, kd.lam_max)
            dense = cmaes.update_from_moments(kd.cfg, params_d, st0, mom)
            dist = jax.tree_util.tree_map(lambda a: a[desc], carry2.states)
            np.testing.assert_allclose(np.asarray(dist.m), np.asarray(dense.m),
                                       rtol=1e-10)
            np.testing.assert_allclose(np.asarray(dist.C), np.asarray(dense.C),
                                       rtol=1e-10)
            np.testing.assert_allclose(np.asarray(dist.sigma),
                                       np.asarray(dense.sigma), rtol=1e-10)

    def test_restart_in_place(self):
        """Descents restart with fresh state on a flat function (all criteria fire)."""
        flat = lambda X: jnp.zeros(X.shape[0], X.dtype)
        kd = strategies.KDistributed(n=3, n_devices=3, lam_start=4, lam_slots=4,
                                     kmax_exp=1)
        carry, trace = kd.run_sim(jax.random.PRNGKey(0), flat, total_gens=400)
        assert int(np.sum(trace["stopped"])) > 0
        assert int(np.max(np.asarray(carry.restarts))) >= 1

    def test_straggler_masking_still_converges(self):
        kd = strategies.KDistributed(n=3, n_devices=7, lam_start=6, lam_slots=6,
                                     kmax_exp=2, drop_prob=0.25)
        carry, _ = kd.run_sim(jax.random.PRNGKey(5), sphere, total_gens=150)
        assert float(carry.best_f) < 1e-6

    def test_shard_map_matches_sim_on_1dev(self):
        kd = strategies.KDistributed(n=3, n_devices=1, lam_start=8, lam_slots=8,
                                     kmax_exp=0)
        c1, t1 = kd.run_sim(jax.random.PRNGKey(2), sphere, total_gens=20)
        from repro.launch.mesh import make_eval_mesh
        mesh = make_eval_mesh(1)
        c2, t2 = kd.run_on_mesh(mesh, jax.random.PRNGKey(2), sphere,
                                total_gens=20)
        np.testing.assert_allclose(float(c1.best_f), float(c2.best_f), rtol=1e-12)
        np.testing.assert_allclose(t1["best_f"], t2["best_f"], rtol=1e-12)


class TestKReplicated:
    def test_phases_progress_and_converge(self):
        kr = strategies.KReplicated(n=4, n_devices=4, lam_start=6, lam_slots=6)
        res = kr.run_sim(jax.random.PRNGKey(0), sphere, phase_gens=150)
        assert res["best_f"] < 1e-8
        assert len(res["phases"]) >= 1
        lams = [p["lam"] for p in res["phases"]]
        assert lams == sorted(lams)  # increasing population phases

    def test_phase_descent_counts(self):
        kr = strategies.KReplicated(n=3, n_devices=8, lam_start=4, lam_slots=4)
        cfg, params, G, g = kr.phase_cfg(0)
        assert (G, g, cfg.lam) == (8, 1, 4)
        cfg, params, G, g = kr.phase_cfg(3)
        assert (G, g, cfg.lam) == (1, 8, 32)

    def test_bbob_rastrigin_multistart_beats_single(self):
        """K-Replicated's many restarts help on multimodal f3 (paper's premise)."""
        fn, inst = bbob.make_fitness(3, 4, instance=2)
        kr = strategies.KReplicated(n=4, n_devices=8, lam_start=6, lam_slots=6)
        res = kr.run_sim(jax.random.PRNGKey(1), fn, phase_gens=120,
                         phases=[0, 1])
        err = res["best_f"] - float(inst.f_opt)
        assert err < 10.0  # multiple parallel descents find a decent basin

    def test_evals_stop_when_descents_stop(self):
        flat = lambda X: jnp.zeros(X.shape[0], X.dtype)
        kr = strategies.KReplicated(n=3, n_devices=2, lam_start=4, lam_slots=4)
        res = kr.run_sim(jax.random.PRNGKey(0), flat, phase_gens=500)
        ph = res["phases"][0]
        # once all groups stopped the phase ends (barrier) — trace is finite
        assert ph["n_stopped"][-1] == ph["n_groups"]


class TestSequentialIPOP:
    def test_ipop_ladder(self):
        fn, inst = bbob.make_fitness(1, 4)
        res = ipop.run_ipop(fn, 4, jax.random.PRNGKey(0), lam_start=8,
                            kmax_exp=2, max_evals=30_000)
        assert res.best_f - float(inst.f_opt) < 1e-8
        assert len(res.descents) >= 1
        lams = [d.lam for d in res.descents]
        assert lams == sorted(lams)

    def test_hit_evals(self):
        fn, inst = bbob.make_fitness(1, 4)
        res = ipop.run_ipop(fn, 4, jax.random.PRNGKey(0), lam_start=8,
                            kmax_exp=1, max_evals=20_000)
        hits = res.hit_evals(np.asarray([1e2, 1e-8]), float(inst.f_opt))
        assert hits[0] <= hits[1]
        assert np.isfinite(hits[0])
