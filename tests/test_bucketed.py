"""Rung-bucketed campaign engine (core/bucketed.py).

Covers the PR's acceptance bar: bucketed ↔ λ_max-padded trajectory
equivalence on the shared key schedule (f1/f8), ECDF-level equivalence when
the eigen cadence changes, compile-count ≤ number of rung buckets, the
budget-counter dtype fix under disabled x64, and the bucket-config
derivation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucketed, ladder
from repro.core.ipop import run_ipop
from repro.core.params import CMAConfig, bucket_config, make_params

KW = dict(n=4, lam_start=8, kmax_exp=2, max_evals=5000)


def _campaigns(policy="cover", seed=0, kw=KW, fids=(1, 8), runs=2,
               **extra):
    eng_p = ladder.LadderEngine(schedule="sequential", **kw, **extra)
    res_p = ladder.run_campaign(eng_p, fids=fids, instances=(1,), runs=runs,
                                seed=seed)
    eng_b = bucketed.BucketedLadderEngine(policy=policy, **kw, **extra)
    res_b = bucketed.run_campaign_bucketed(eng_b, fids=fids, instances=(1,),
                                           runs=runs, seed=seed)
    return res_p, res_b


# ---------------------------------------------------------------------------
# equivalence: bucketed segment driver == λ_max-padded engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["cover", "min"])
def test_bucketed_matches_padded_campaign(policy):
    """At eigen_interval == 1 (n=4 default) the per-generation arithmetic is
    identical; only per-shape XLA fusion rounding separates the programs —
    the same tolerance the host-loop baseline comparison carries."""
    res_p, res_b = _campaigns(policy)
    assert ladder.LadderEngine(schedule="sequential",
                               **KW).cfg.eigen_interval == 1

    np.testing.assert_array_equal(res_p.total_fevals, res_b.total_fevals)
    np.testing.assert_allclose(res_p.best_f, res_b.best_f,
                               rtol=1e-5, atol=1e-7)
    for b in range(len(res_p.members)):
        rp = res_p.trace.ran[b, :, 0]
        rb = res_b.trace.ran[b, :, 0]
        # identical per-member generation structure: rungs walked, gens per
        # rung, within-descent eval counters, stop reasons
        for field in ("k_idx", "gen", "fevals", "stop_reason", "stopped"):
            np.testing.assert_array_equal(
                getattr(res_p.trace, field)[b, :, 0][rp],
                getattr(res_b.trace, field)[b, :, 0][rb], err_msg=field)
        np.testing.assert_allclose(res_p.trace.best_f[b, :, 0][rp],
                                   res_b.trace.best_f[b, :, 0][rb],
                                   rtol=1e-5, atol=1e-7)


def test_bucketed_never_pays_lam_max_on_low_rungs():
    res_p, res_b = _campaigns("min")
    # the padded engine pays λ_max per executed generation; the bucketed
    # driver's padded spend must be strictly smaller on the same trajectory
    lam_max = (2 ** KW["kmax_exp"]) * KW["lam_start"]
    T = res_p.trace.ran.shape[1]
    padded_padded = len(res_p.members) * T * lam_max
    assert res_b.padded_evals < padded_padded
    assert res_b.padding_waste() < padded_padded / max(res_b.useful_evals, 1)
    # useful work is identical across engines (same trajectories)
    useful_p = int(np.sum(np.where(
        res_p.trace.ran, KW["lam_start"] * 2 ** res_p.trace.k_idx, 0)))
    assert useful_p == res_b.useful_evals


def test_compile_count_le_number_of_buckets():
    eng_b = bucketed.BucketedLadderEngine(**KW)
    res = bucketed.run_campaign_bucketed(eng_b, fids=(1, 8), instances=(1,),
                                         runs=2, seed=0)
    n_buckets = KW["kmax_exp"] + 1
    assert 1 <= res.compiles <= n_buckets
    # a second campaign with the same shapes reuses every cached executable
    res2 = bucketed.run_campaign_bucketed(eng_b, fids=(1, 8), instances=(1,),
                                          runs=2, seed=3)
    assert res2.compiles <= n_buckets


def test_ecdf_equivalence_when_eigen_cadence_changes():
    """eigen_interval > 1: the nested scan's cadence is block-/segment-local
    rather than per-descent, so trajectories differ — but the engines must
    stay equivalent at the ECDF level (fraction of (member, target) pairs
    hit within the budget)."""
    kw = dict(n=8, lam_start=8, kmax_exp=1, max_evals=4000)
    res_p, res_b = _campaigns("cover", kw=kw, eigen_interval=4)
    targets = np.array([1e2, 1e0, 1e-4])
    hits_p = np.isfinite(res_p.hit_evals(targets)).mean(axis=0)
    hits_b = np.isfinite(res_b.hit_evals(targets)).mean(axis=0)
    B = len(res_p.members)
    assert np.all(np.abs(hits_p - hits_b) <= 1.0 / B + 1e-9)
    # sphere members must converge under both engines
    for (fid, _i, _r), ep, eb in zip(res_p.members,
                                     res_p.best_f - res_p.f_opt,
                                     res_b.best_f - res_b.f_opt):
        if fid == 1:
            assert ep < 1e-6 and eb < 1e-6
    # budget respected everywhere
    assert (res_b.total_fevals <= kw["max_evals"]).all()


def test_budget_below_one_generation_returns_empty_progress():
    """A budget that cannot pay for a single λ_start generation must yield
    the same empty-progress result as the padded ladder backend, not crash
    in the segment driver."""
    from repro.fitness import bbob
    inst = bbob.make_instance(1, 3, 1)
    fit = lambda X: bbob.evaluate(1, inst, X)
    kw = dict(lam_start=8, kmax_exp=1, max_evals=4)
    r_l = run_ipop(fit, 3, jax.random.PRNGKey(0), **kw)
    r_b = run_ipop(fit, 3, jax.random.PRNGKey(0), backend="bucketed", **kw)
    assert r_l.total_fevals == r_b.total_fevals == 0
    assert r_l.descents == r_b.descents == []

    eng = bucketed.BucketedLadderEngine(n=3, **kw)
    res = bucketed.run_campaign_bucketed(eng, fids=(1,), runs=2)
    assert res.useful_evals == 0 and res.segments == []
    assert res.trace.ran.shape[1] == 0          # zero-generation trace
    assert res.hit_evals(np.array([1e2])).shape == (2, 1)


def test_run_ipop_bucketed_backend_matches_ladder():
    from repro.fitness import bbob
    inst = bbob.make_instance(8, 4, 1)
    fit = lambda X: bbob.evaluate(8, inst, X)
    kw = dict(lam_start=8, kmax_exp=2, max_evals=4000)
    r_l = run_ipop(fit, 4, jax.random.PRNGKey(7), **kw)
    r_b = run_ipop(fit, 4, jax.random.PRNGKey(7), backend="bucketed", **kw)
    assert r_l.total_fevals == r_b.total_fevals
    assert len(r_l.descents) == len(r_b.descents)
    for dl, db in zip(r_l.descents, r_b.descents):
        assert dl.k_exp == db.k_exp and dl.lam == db.lam
        np.testing.assert_array_equal(dl.fevals, db.fevals)
        assert dl.stop_reason == db.stop_reason
    np.testing.assert_allclose(r_l.best_f, r_b.best_f, rtol=1e-5, atol=1e-7)


def test_overlap_driver_is_trajectory_identical():
    """Double-buffered dispatch (satellite): the speculative next-segment
    dispatch either lands (same bucket — its output IS what the unoverlapped
    driver would compute) or is discarded, so the two drivers must agree on
    every trace field; the host sync is recorded per segment and most
    boundaries keep the bucket (spec hits)."""
    eng = bucketed.BucketedLadderEngine(**KW)
    res = bucketed.run_campaign_bucketed(eng, fids=(1, 8), instances=(1,),
                                         runs=2, seed=0)
    eng_o = bucketed.BucketedLadderEngine(overlap=True, **KW)
    res_o = bucketed.run_campaign_bucketed(eng_o, fids=(1, 8), instances=(1,),
                                           runs=2, seed=0)
    np.testing.assert_array_equal(res.total_fevals, res_o.total_fevals)
    for field in ("ran", "k_idx", "gen", "fevals", "stop_reason", "stopped"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res.trace, field)),
            np.asarray(getattr(res_o.trace, field)), err_msg=field)
    np.testing.assert_allclose(res.trace.best_f, res_o.trace.best_f,
                               rtol=1e-12, atol=1e-12)
    # same bucket schedule, spec bookkeeping present, hits happen
    assert [s["bucket"] for s in res.segments] == \
        [s["bucket"] for s in res_o.segments]
    assert all("spec_hit" in s and "sync_s" in s for s in res_o.segments)
    if len(res_o.segments) > 1:
        assert any(s["spec_hit"] for s in res_o.segments)
    assert not any("spec_hit" in s for s in res.segments)
    assert res_o.compiles <= KW["kmax_exp"] + 1


# ---------------------------------------------------------------------------
# bucket configs (params.bucket_config)
# ---------------------------------------------------------------------------

def test_bucket_config_inherits_trajectory_knobs():
    cfg = CMAConfig(n=10, lam=128, lam_max=128, sigma0=2.5, tolfun=1e-9,
                    eigen_interval=7)
    cfg_b = bucket_config(cfg, 16)
    assert cfg_b.lam == cfg_b.lam_max == 16
    assert cfg_b.eigen_interval == 7 and cfg_b.tolfun == 1e-9
    assert cfg_b.hist_len == cfg.hist_len and cfg_b.sigma0 == cfg.sigma0
    # per-rung max_iter re-derives from the rung's own λ when auto
    assert cfg_b.max_iter == 100 + int(3000 * 10 / 16)
    with pytest.raises(ValueError):
        bucket_config(cfg, 256)
    # identical weight prefixes: a rung-1 descent padded to 16 or to 128
    p_wide = make_params(cfg, lam=16)
    p_narrow = make_params(cfg_b, lam=16)
    np.testing.assert_array_equal(np.asarray(p_wide.weights)[:16],
                                  np.asarray(p_narrow.weights))
    assert float(p_wide.mu_eff) == float(p_narrow.mu_eff)


# ---------------------------------------------------------------------------
# budget counter dtype under disabled x64 (satellite fix)
# ---------------------------------------------------------------------------

def test_budget_counter_respects_x64_availability():
    eng = ladder.LadderEngine(n=3, lam_start=4, kmax_exp=1, max_evals=2000)
    carry = eng.init_carry(jax.random.PRNGKey(0))
    assert carry.total_fevals.dtype == jnp.int64       # x64 on (conftest)

    with jax.experimental.disable_x64():
        eng32 = ladder.LadderEngine(n=3, lam_start=4, kmax_exp=1,
                                    max_evals=2000, dtype="float32")
        carry32 = eng32.init_carry(jax.random.PRNGKey(0))
        # explicit int32 — no silent downcast warning path
        assert carry32.total_fevals.dtype == jnp.int32
        # a budget that cannot fit the available counter is rejected up front
        # instead of silently wrapping negative mid-campaign
        with pytest.raises(ValueError, match="overflow"):
            ladder.LadderEngine(n=3, lam_start=4, kmax_exp=1,
                                max_evals=2 ** 31, dtype="float32")
        # smoke: a short non-x64 run works and respects the budget
        sphere = lambda X: jnp.sum(X ** 2, axis=-1)
        carry_f, _ = eng32.run(jax.random.PRNGKey(1), sphere, total_gens=40)
        assert int(carry_f.total_fevals) <= 2000
    # the same budget is fine with x64 on
    eng64 = ladder.LadderEngine(n=3, lam_start=4, kmax_exp=1,
                                max_evals=2 ** 31)
    assert eng64.init_carry(jax.random.PRNGKey(0)).total_fevals.dtype \
        == jnp.int64


# ---------------------------------------------------------------------------
# vectorized hit_evals (satellite)
# ---------------------------------------------------------------------------

def test_hit_evals_matches_reference_loop():
    eng = ladder.LadderEngine(schedule="sequential", **KW)
    res = ladder.run_campaign(eng, fids=(1, 8), instances=(1,), runs=2,
                              seed=0)
    targets = np.array([1e3, 1e0, 1e-5, 1e-9])
    got = res.hit_evals(targets)

    # reference: the former B×targets double loop
    gb = np.asarray(res.trace.global_best)
    fe = np.asarray(res.trace.total_fevals)
    want = np.full((gb.shape[0], len(targets)), np.inf)
    for b in range(gb.shape[0]):
        err = gb[b] - res.f_opt[b]
        for i, t in enumerate(targets):
            idx = np.nonzero(err <= t)[0]
            if idx.size:
                want[b, i] = fe[b, idx[0]]
    np.testing.assert_array_equal(got, want)
    assert got.shape == (len(res.members), len(targets))
