"""8-virtual-device checks for the campaign service (service/server.py).

Executed as a SUBPROCESS by tests/test_service.py (and directly by the CI
``mesh-8dev`` job): the virtual-device flag must precede jax's first import —
same pattern as tests/mesh_check.py.

Asserts, all under one 8-device XLA environment:

* a service whose lanes run S2-style islands over a 4-device fleet serves a
  heterogeneous streaming trace (mixed fids/dims/budgets/priorities + one
  custom callable, admitted mid-flight) with per-job results equal to the
  same trace on a single-device server — island placement is
  trajectory-neutral;
* the elastic re-shard path end-to-end: snapshot the 4-device server
  mid-flight, kill it, restore onto ALL 8 devices (the allocator re-packs
  resident rows across the doubled island grid), drain, and reproduce the
  uninterrupted reference per job to float64 checkpoint exactness;
* lifecycle states ride the elastic re-shard: quarantined / cancelled
  tickets (reasons, partial results) and dedup pins restored from a
  4-device snapshot onto 8 devices intact, with the surviving job
  draining to completion;
* ``checkpoint/store.restore(shardings=...)`` re-places a stacked campaign
  carry written from a 4-device mesh onto an 8-device mesh (the store-level
  elastic re-shard the service layers on);
* compiles stay ≤ #buckets × #dim-classes throughout;
* the mesh engine's island-program cache serves repeat campaigns without
  new traces (satellite: O(buckets) island bring-up).

Prints ``SERVICE-CHECK-OK`` and exits 0 iff every assertion holds.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import tempfile  # noqa: E402

import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402
import numpy as np  # noqa: E402

from repro.checkpoint import store  # noqa: E402
from repro.core import bucketed  # noqa: E402
from repro.distributed import mesh_engine  # noqa: E402
from repro.distributed.sharding import campaign_shardings  # noqa: E402
from repro.launch.mesh import make_campaign_mesh  # noqa: E402
from repro.service import (CampaignRequest, CampaignServer,  # noqa: E402
                           FitnessRegistry)

KW = dict(lam_start=8, kmax_exp=2)


def shifted_sphere(X):
    return jnp.sum((X - 1.2) ** 2, axis=-1)


def make_registry():
    reg = FitnessRegistry()
    reg.register("shifted_sphere", shifted_sphere)
    return reg


def make_server(devices, **extra):
    kw = dict(registry=make_registry(), bbob_fids=(1, 8), max_budget=5000,
              rows_per_island=2, devices=devices, **KW)
    kw.update(extra)
    return CampaignServer(**kw)


def run_trace(srv):
    """The shared submission schedule: 4 jobs up front, 2 mid-flight."""
    ts = [srv.submit(CampaignRequest(dim=4, fid=8, budget=3000, seed=7)),
          srv.submit(CampaignRequest(dim=4, fid=1, budget=2200, seed=3,
                                     priority=2)),
          srv.submit(CampaignRequest(dim=6, fid=8, budget=2500, seed=11)),
          srv.submit(CampaignRequest(dim=4, fitness="shifted_sphere",
                                     budget=1500, seed=5))]
    for _ in range(2):
        srv.step()
    ts += [srv.submit(CampaignRequest(dim=4, fid=1, budget=1800, seed=13)),
           srv.submit(CampaignRequest(dim=6, fid=1, budget=1200, seed=17))]
    return ts


def assert_jobs_equal(ts_ref, srv, rtol=1e-12):
    for tr in ts_ref:
        tb = srv.tickets[tr.job_id]
        assert tb.done, (tr.job_id, tb.status)
        assert tr.fevals == tb.fevals, (tr.job_id, tr.fevals, tb.fevals)
        np.testing.assert_allclose(tr.best_f, tb.best_f, rtol=rtol, atol=rtol)
        assert len(tr.result.descents) == len(tb.result.descents)
        for d1, d2 in zip(tr.result.descents, tb.result.descents):
            assert d1.k_exp == d2.k_exp
            np.testing.assert_array_equal(d1.fevals, d2.fevals)
            np.testing.assert_allclose(d1.best_f, d2.best_f,
                                       rtol=rtol, atol=rtol)


def main():
    assert jax.device_count() == 8, jax.devices()
    devs = jax.devices()
    n_buckets = KW["kmax_exp"] + 1

    # -- single-device reference for the whole trace -------------------------
    srv_1 = make_server([devs[0]], rows_per_island=8)
    ts_1 = run_trace(srv_1)
    srv_1.drain()

    # -- 4-device islands serve the identical trace --------------------------
    srv_4 = make_server(devs[:4])
    ts_4 = run_trace(srv_4)
    srv_4.drain()
    for t1, t4 in zip(ts_1, ts_4):
        assert t1.fevals == t4.fevals
        np.testing.assert_allclose(t1.best_f, t4.best_f,
                                   rtol=1e-5, atol=1e-7)
        assert len(t1.result.descents) == len(t4.result.descents)
        for d1, d4 in zip(t1.result.descents, t4.result.descents):
            np.testing.assert_array_equal(d1.fevals, d4.fevals)
    assert srv_4.segment_compiles() <= n_buckets * len(srv_4.lanes)
    for lane in srv_4.lanes.values():
        assert len(lane.islands) == 4
    print(f"islands[4dev] OK  compiles={srv_4.segment_compiles()} "
          f"lanes={len(srv_4.lanes)}")

    # -- elastic kill-and-resume: snapshot on 4 devices, restore on 8 --------
    ckpt = tempfile.mkdtemp(prefix="svc_ckpt_")
    srv_a = make_server(devs[:4], snapshot_dir=ckpt)
    ts_a = run_trace(srv_a)
    for _ in range(2):
        srv_a.step()
    step = srv_a.snapshot()
    resident_at_kill = srv_a._resident_jobs()
    assert resident_at_kill > 0
    del srv_a                                         # the kill

    srv_8 = CampaignServer.restore(ckpt, registry=make_registry(),
                                   devices=devs)
    for lane in srv_8.lanes.values():
        assert lane.allocator.n_islands == 8          # re-packed onto 8
        assert len(lane.islands) == 8
    assert srv_8._resident_jobs() == resident_at_kill
    srv_8.drain()
    assert_jobs_equal(ts_4, srv_8)                    # vs uninterrupted run
    print(f"elastic-resume[4→8] OK  step={step} "
          f"resident_at_kill={resident_at_kill}")

    # -- lifecycle states ride the elastic re-shard --------------------------
    def nan_fn(X):
        return jnp.full(X.shape[:-1], jnp.nan, X.dtype)

    def lc_registry():
        reg = FitnessRegistry()
        reg.register("shifted_sphere", shifted_sphere)
        reg.register("nan_fn", nan_fn)
        return reg

    ck2 = tempfile.mkdtemp(prefix="svc_lc_")
    srv_l = make_server(devs[:4], registry=lc_registry(), snapshot_dir=ck2)
    t_ok = srv_l.submit(CampaignRequest(dim=4, fid=8, budget=3000, seed=7,
                                        dedup_key="keep"))
    t_bad = srv_l.submit(CampaignRequest(dim=4, fitness="nan_fn",
                                         budget=2000, seed=1))
    srv_l.step()
    srv_l.step()
    assert t_bad.status == "quarantined", t_bad.status
    t_c = srv_l.submit(CampaignRequest(dim=6, fid=1, budget=1000, seed=2))
    assert srv_l.cancel(t_c.job_id)
    srv_l.snapshot()
    del srv_l                                         # the kill

    srv_l8 = CampaignServer.restore(ck2, registry=lc_registry(),
                                    devices=devs)
    rb = srv_l8.tickets[t_bad.job_id]
    assert rb.status == "quarantined" and "non-finite" in rb.reason
    assert rb.result is not None and rb.fevals > 0
    assert srv_l8.tickets[t_c.job_id].status == "cancelled"
    assert srv_l8._dedup == {"keep": t_ok.job_id}
    ro = srv_l8.tickets[t_ok.job_id]
    assert srv_l8.submit(CampaignRequest(dim=4, fid=8, budget=3000, seed=7,
                                         dedup_key="keep")) is ro
    srv_l8.drain()
    assert ro.done, ro.status
    print("lifecycle-reshard[4→8] OK")

    # -- store-level elastic re-shard of a stacked campaign carry ------------
    eng = bucketed.BucketedLadderEngine(n=4, max_evals=4000, **KW)
    keys = jnp.stack([jax.random.fold_in(jax.random.PRNGKey(0), j)
                      for j in range(16)])
    mesh4 = make_campaign_mesh(devices=devs[:4])
    carry = eng._init_runner(keys)
    carry = jax.tree_util.tree_map(
        lambda a: jax.device_put(a, NamedSharding(mesh4, P("camp"))), carry)
    d2 = tempfile.mkdtemp(prefix="svc_store_")
    store.save(d2, 1, {"carry": carry}, meta={"devices": 4})
    mesh8 = make_campaign_mesh(devices=devs)
    template = jax.eval_shape(eng._init_runner, keys)
    back = store.restore(d2, 1, {"carry": template},
                         shardings={"carry": campaign_shardings(
                             template, mesh8)})["carry"]
    for a, b in zip(jax.tree_util.tree_leaves(carry),
                    jax.tree_util.tree_leaves(back)):
        assert len(b.sharding.device_set) == 8        # re-placed on 8 devices
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert store.load_meta(d2, 1) == {"devices": 4}
    print("store-reshard[4→8] OK")

    # -- mesh-engine island cache: repeat campaigns trace nothing new --------
    mesh_engine.clear_island_program_cache()
    kwm = dict(n=4, lam_start=8, kmax_exp=2, max_evals=5000)
    eng1 = mesh_engine.MeshCampaignEngine(strategy="concurrent", **kwm)
    mesh_engine.run_campaign_mesh(eng1, fids=(1, 8), instances=(1,), runs=4,
                                  seed=0)
    s1 = mesh_engine.island_cache_stats()
    eng2 = mesh_engine.MeshCampaignEngine(strategy="concurrent", **kwm)
    res2 = mesh_engine.run_campaign_mesh(eng2, fids=(1, 8), instances=(1,),
                                         runs=4, seed=1)
    s2 = mesh_engine.island_cache_stats()
    assert s2["traces"] == s1["traces"], (s1, s2)     # O(buckets) bring-up
    assert s2["hits"] > s1["hits"]
    assert 1 <= res2.compiles <= n_buckets
    print(f"island-cache OK  {s2}")

    print("SERVICE-CHECK-OK")


if __name__ == "__main__":
    main()
