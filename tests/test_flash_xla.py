"""flash_xla (custom-VJP memory-linear attention) vs the materialized
oracle: values AND gradients, causal/windowed/GQA, block-size independence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models.flash_xla import flash_mha

pytestmark = pytest.mark.slow  # heavy model/train/serve tier — excluded from fast CI


def _inputs(B, S, H, Hk, D, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, S, H, D), dtype),
            jax.random.normal(ks[1], (B, S, Hk, D), dtype),
            jax.random.normal(ks[2], (B, S, Hk, D), dtype))


@pytest.mark.parametrize("B,S,H,Hk,D,window", [
    (2, 128, 4, 4, 32, 0),
    (1, 256, 4, 2, 64, 0),
    (1, 192, 4, 2, 32, 64),      # sliding window, ragged blocks
    (2, 130, 2, 1, 32, 0),       # pad path
])
def test_flash_forward_matches_oracle(B, S, H, Hk, D, window):
    q, k, v = _inputs(B, S, H, Hk, D)
    got = flash_mha(q, k, v, True, window, 64, 64)
    want = ref.flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [0, 48])
def test_flash_grads_match_oracle(window):
    B, S, H, Hk, D = 1, 96, 4, 2, 32
    q, k, v = _inputs(B, S, H, Hk, D, seed=1)

    def loss_flash(q, k, v):
        o = flash_mha(q, k, v, True, window, 32, 32)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    def loss_ref(q, k, v):
        o = ref.flash_attention(q, k, v, causal=True, window=window)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"d{name} mismatch")


def test_flash_block_size_independence():
    q, k, v = _inputs(1, 160, 2, 2, 32, seed=2)
    o1 = flash_mha(q, k, v, True, 0, 160, 160)
    o2 = flash_mha(q, k, v, True, 0, 32, 64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


def test_lm_flash_equals_naive():
    """Whole-model equivalence: attn_impl=flash vs naive on a smoke arch."""
    import dataclasses
    from repro.configs import smoke_config
    from repro.models import lm
    base = dataclasses.replace(smoke_config("qwen2-0.5b"), dtype="float32")
    naive = dataclasses.replace(base, attn_impl="naive")
    flash = dataclasses.replace(base, attn_impl="flash")
    params = lm.init_params(naive, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 48),
                                          0, base.vocab),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 48),
                                          0, base.vocab)}
    l_naive, _ = lm.loss(naive, params, batch)
    l_flash, _ = lm.loss(flash, params, batch)
    np.testing.assert_allclose(float(l_naive), float(l_flash), rtol=1e-4)

    g_naive = jax.grad(lambda p: lm.loss(naive, p, batch)[0])(params)
    g_flash = jax.grad(lambda p: lm.loss(flash, p, batch)[0])(params)
    for a, b in zip(jax.tree_util.tree_leaves(g_naive),
                    jax.tree_util.tree_leaves(g_flash)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-4)
