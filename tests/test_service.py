"""Campaign service (src/repro/service/).

Covers the PR's acceptance bar in the fast tier:

* end-to-end: heterogeneous requests (mixed dims, budgets, arrival times,
  a non-BBOB callable) admitted MID-FLIGHT into running lanes all complete,
  each with a per-job trajectory equivalent to a standalone
  ``run_ipop(backend="bucketed")`` on the shared key schedule;
* no per-request recompilation: segment compiles stay ≤ #buckets ×
  #dim-classes, and an extra same-class request adds zero programs;
* kill-and-resume: a snapshot (stacked ``CMAState`` + allocator map through
  checkpoint/store.py) restores into a fresh server that reproduces the
  uninterrupted run's remaining trajectory;
* admission-queue backpressure/priority, slot-allocator bitmap/repack,
  early target retirement, and the ``run_ipop(backend="service")`` wiring.

The REAL multi-device suite (S2-style islands on an 8-virtual-device fleet,
elastic 4→8 re-shard restore) runs as a subprocess — tests/service_check.py,
same pattern as tests/mesh_check.py — and in-process in the CI
``mesh-8dev`` job.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.core.ipop import run_ipop
from repro.fitness import bbob
from repro.service import (AdmissionQueue, CampaignRequest, CampaignServer,
                           FitnessRegistry, QueueFull, SlotAllocator)

KW = dict(lam_start=8, kmax_exp=2)


def shifted_sphere(X):
    return jnp.sum((X - 1.2) ** 2, axis=-1)


def make_registry():
    reg = FitnessRegistry()
    reg.register("shifted_sphere", shifted_sphere)
    return reg


def make_server(**extra):
    kw = dict(registry=make_registry(), bbob_fids=(1, 8), max_budget=5000,
              rows_per_island=2, **KW)
    kw.update(extra)
    return CampaignServer(**kw)


def assert_matches_standalone(ticket, fitness_fn, dim, seed):
    """Per-job trajectory equivalence with run_ipop(backend='bucketed')."""
    ref = run_ipop(fitness_fn, dim, jax.random.PRNGKey(seed),
                   backend="bucketed", max_evals=ticket.request.budget, **KW)
    res = ticket.result
    assert res is not None and ticket.done
    assert ref.total_fevals == res.total_fevals
    assert len(ref.descents) == len(res.descents)
    for dr, ds in zip(ref.descents, res.descents):
        assert dr.k_exp == ds.k_exp and dr.lam == ds.lam
        np.testing.assert_array_equal(dr.fevals, ds.fevals)
        np.testing.assert_array_equal(dr.gens, ds.gens)
        assert dr.stop_reason == ds.stop_reason
        np.testing.assert_allclose(dr.best_f, ds.best_f,
                                   rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(ref.best_f, res.best_f, rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# the acceptance test: heterogeneous streaming admission
# ---------------------------------------------------------------------------

def test_end_to_end_heterogeneous_mid_flight_admission():
    srv = make_server()
    # two jobs up front (dim-4 lane fills both rows of its island)
    t_a = srv.submit(CampaignRequest(dim=4, fid=8, budget=3000, seed=7))
    t_b = srv.submit(CampaignRequest(dim=4, fid=1, budget=2000, seed=3))
    for _ in range(2):
        srv.step()                      # lane is mid-flight now
    # mid-flight arrivals: a custom callable, a new dim-class, and a third
    # dim-4 job that must WAIT for a freed row (slot reuse)
    t_c = srv.submit(CampaignRequest(dim=4, fitness="shifted_sphere",
                                     budget=1500, seed=5))
    t_d = srv.submit(CampaignRequest(dim=6, fid=8, budget=2500, seed=11))
    t_e = srv.submit(CampaignRequest(dim=4, fid=1, budget=1200, seed=13))
    srv.drain()

    for t in (t_a, t_b, t_c, t_d, t_e):
        assert t.done, t.status
        assert t.updates, "ticket never streamed progress"
        assert t.fevals <= t.request.budget

    # per-job trajectory equivalence with standalone runs on the same keys
    inst8_4 = bbob.make_instance(8, 4, 1)
    inst1_4 = bbob.make_instance(1, 4, 1)
    inst8_6 = bbob.make_instance(8, 6, 1)
    assert_matches_standalone(
        t_a, lambda X: bbob.evaluate(8, inst8_4, X), 4, 7)
    assert_matches_standalone(
        t_b, lambda X: bbob.evaluate(1, inst1_4, X), 4, 3)
    assert_matches_standalone(t_c, shifted_sphere, 4, 5)
    assert_matches_standalone(
        t_d, lambda X: bbob.evaluate(8, inst8_6, X), 6, 11)
    assert_matches_standalone(
        t_e, lambda X: bbob.evaluate(1, inst1_4, X), 4, 13)

    # compile bound: ≤ #buckets × #dim-classes, and admission never recompiles
    n_buckets = KW["kmax_exp"] + 1
    compiles = srv.segment_compiles()
    assert 1 <= compiles <= n_buckets * len(srv.lanes)
    t_f = srv.submit(CampaignRequest(dim=4, fid=8, budget=1000, seed=17))
    srv.drain()
    assert t_f.done
    assert srv.segment_compiles() == compiles   # zero new programs


def test_run_ipop_service_backend_matches_bucketed():
    inst = bbob.make_instance(8, 4, 1)
    fit = lambda X: bbob.evaluate(8, inst, X)
    kw = dict(lam_start=8, kmax_exp=2, max_evals=4000)
    r_b = run_ipop(fit, 4, jax.random.PRNGKey(7), backend="bucketed", **kw)
    r_s = run_ipop(fit, 4, jax.random.PRNGKey(7), backend="service", **kw)
    assert r_b.total_fevals == r_s.total_fevals
    assert len(r_b.descents) == len(r_s.descents)
    for db, ds in zip(r_b.descents, r_s.descents):
        assert db.k_exp == ds.k_exp
        np.testing.assert_array_equal(db.fevals, ds.fevals)
        assert db.stop_reason == ds.stop_reason
    np.testing.assert_allclose(r_b.best_f, r_s.best_f, rtol=1e-5, atol=1e-7)
    with pytest.raises(ValueError, match="total_gens"):
        run_ipop(fit, 4, jax.random.PRNGKey(7), backend="service",
                 total_gens=10, **kw)


def test_target_early_retirement():
    srv = make_server()
    # a target the very first generations reach: the job retires long before
    # its budget (stop_at-style early sharing, per job)
    t = srv.submit(CampaignRequest(dim=4, fid=1, budget=5000, seed=0,
                                   target=1e3))
    srv.drain()
    assert t.done
    assert t.best_f <= 1e3
    assert t.fevals < 5000


# ---------------------------------------------------------------------------
# kill-and-resume (satellite: checkpoint round-trip of campaign state)
# ---------------------------------------------------------------------------

def _submit_resume_jobs(srv):
    return [srv.submit(CampaignRequest(dim=4, fid=8, budget=3000, seed=7)),
            srv.submit(CampaignRequest(dim=4, fid=1, budget=2500, seed=3)),
            srv.submit(CampaignRequest(dim=4, fitness="shifted_sphere",
                                       budget=1500, seed=5))]


def test_snapshot_kill_resume_reproduces_trajectory(tmp_path):
    ref = make_server(rows_per_island=3)
    ts_ref = _submit_resume_jobs(ref)
    ref.drain()

    d = str(tmp_path / "ckpt")
    srv = make_server(rows_per_island=3, snapshot_dir=d)
    ts = _submit_resume_jobs(srv)
    for _ in range(3):
        srv.step()
    step = srv.snapshot()
    assert store.latest_step(d) == step
    assert store.load_meta(d, step)["boundary"] == 3
    del srv                                     # the "kill"

    srv2 = CampaignServer.restore(d, registry=make_registry())
    assert srv2._resident_jobs() == 3           # allocator map round-tripped
    srv2.drain()
    for tr in ts_ref:
        tb = srv2.tickets[tr.job_id]
        assert tb.done
        assert tr.fevals == tb.fevals
        np.testing.assert_allclose(tr.best_f, tb.best_f,
                                   rtol=1e-12, atol=1e-12)
        # the remaining trajectory is reproduced: full descent structure
        assert len(tr.result.descents) == len(tb.result.descents)
        for d1, d2 in zip(tr.result.descents, tb.result.descents):
            assert d1.k_exp == d2.k_exp
            np.testing.assert_array_equal(d1.fevals, d2.fevals)
            np.testing.assert_allclose(d1.best_f, d2.best_f,
                                       rtol=1e-12, atol=1e-12)
    del ts


def test_restore_requeues_pending_and_accepts_new_jobs(tmp_path):
    """A job still QUEUED at snapshot time rides the meta and is re-queued on
    restore with its id preserved; fresh submissions after the restore must
    not collide with the re-queued heap entries (the queue's sequence counter
    fast-forwards past every restored id) and everything drains."""
    d = str(tmp_path / "ckpt")
    srv = make_server(rows_per_island=1, snapshot_dir=d)
    t0 = srv.submit(CampaignRequest(dim=4, fid=1, budget=1200, seed=0))
    t1 = srv.submit(CampaignRequest(dim=4, fid=8, budget=1200, seed=1))
    srv.step()                          # t0 admitted; t1 queued (1 row)
    assert t1.status == "queued"
    srv.snapshot()
    del srv

    srv2 = CampaignServer.restore(d, registry=make_registry())
    assert [t.job_id for t in srv2.queue.pending()] == [t1.job_id]
    # two fresh submissions: the second would have reused sequence number 1
    # (== t1's restored slot) before the counter fast-forward
    t2 = srv2.submit(CampaignRequest(dim=4, fid=1, budget=1000, seed=2))
    t3 = srv2.submit(CampaignRequest(dim=4, fid=1, budget=1000, seed=3))
    assert len(srv2.queue.pending()) == 3   # sorts without comparing requests
    srv2.drain()
    for t in (t2, t3):
        assert t.done and t.latency_s() is not None
    resumed = srv2.tickets[t1.job_id]
    assert resumed.done
    assert resumed.latency_s() is None      # timestamps don't ride snapshots


def test_unplaceable_job_is_rejected_not_hung():
    srv = make_server(max_lanes=1)
    t_ok = srv.submit(CampaignRequest(dim=4, fid=1, budget=1000, seed=0))
    t_no = srv.submit(CampaignRequest(dim=6, fid=1, budget=1000, seed=1))
    srv.drain()                         # must terminate, not RuntimeError
    assert t_ok.done
    assert t_no.status == "rejected"


def test_program_cache_evicts_closure_keyed_entries():
    from repro.distributed.mesh_engine import ProgramCache
    pc = ProgramCache(max_closure_entries=2)
    for j in range(4):                  # closure-keyed: capped at 2
        pc.get(("x", (lambda X: X), j), lambda: object())
    for j in range(4):                  # static keys: never evicted
        pc.get(("static", j), lambda: object())
    snap = pc.snapshot()
    assert snap["traces"] == 8 and snap["programs"] == 6
    pc.get(("static", 0), lambda: object())
    assert pc.snapshot()["hits"] == 1


def test_store_roundtrip_of_stacked_carry_and_allocator(tmp_path):
    """checkpoint/store.py round-trip of the raw campaign state pieces —
    stacked CMAState carry + allocator map — independent of the server."""
    from repro.core import bucketed as bmod
    eng = bmod.BucketedLadderEngine(n=4, max_evals=4000, **KW)
    keys = jnp.stack([jax.random.fold_in(jax.random.PRNGKey(0), j)
                      for j in range(4)])
    carry = eng._init_runner(keys)
    al = SlotAllocator(2, 2)
    al.alloc(10, 1000)
    al.alloc(11, 2000)
    d = str(tmp_path / "ck")
    store.save(d, 5, {"carry": carry}, meta={"alloc": al.to_meta()})
    meta = store.load_meta(d, 5)
    al2 = SlotAllocator.from_meta(meta["alloc"])
    assert al2.occupied() == al.occupied()
    assert [list(b) for b in al2.budgets] == [list(b) for b in al.budgets]
    template = jax.eval_shape(eng._init_runner, keys)
    back = store.restore(d, 5, {"carry": template})["carry"]
    for a, b in zip(jax.tree_util.tree_leaves(carry),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# queue + allocator units
# ---------------------------------------------------------------------------

def test_queue_backpressure_and_priority():
    q = AdmissionQueue(max_pending=2)
    r_lo = CampaignRequest(dim=4, fid=1, budget=100, priority=0)
    r_hi = CampaignRequest(dim=4, fid=1, budget=100, priority=5)
    t1 = q.submit(r_lo)
    t2 = q.submit(r_hi)
    with pytest.raises(QueueFull):
        q.submit(CampaignRequest(dim=4, fid=1, budget=100))
    # priority first, FIFO within a priority
    req, t = q.take()
    assert t is t2 and req.priority == 5
    req, t = q.take()
    assert t is t1
    assert q.take() is None
    # predicate-matched take skips non-matching higher-priority entries
    q2 = AdmissionQueue()
    q2.submit(CampaignRequest(dim=8, fid=1, budget=100, priority=9))
    tb = q2.submit(CampaignRequest(dim=4, fid=1, budget=100, priority=0))
    req, t = q2.take(lambda r: r.dim == 4)
    assert t is tb and len(q2) == 1


def test_request_validation():
    with pytest.raises(ValueError, match="exactly one"):
        CampaignRequest(dim=4, budget=100).validate()
    with pytest.raises(ValueError, match="exactly one"):
        CampaignRequest(dim=4, budget=100, fid=1,
                        fitness="x").validate()
    srv = make_server()
    with pytest.raises(ValueError, match="max_budget"):
        srv.submit(CampaignRequest(dim=4, fid=1, budget=10 ** 9))
    with pytest.raises(ValueError, match="menu"):
        srv.submit(CampaignRequest(dim=4, fid=24, budget=100))
    with pytest.raises(ValueError, match="unknown fitness"):
        srv.submit(CampaignRequest(dim=4, fitness="nope", budget=100))
    # registering after freeze() no longer raises: it opens generation g+1
    g0 = srv.registry.generation
    srv.registry.register("late", shifted_sphere)
    assert srv.registry.generation == g0 + 1
    assert "late" in srv.registry.names
    assert "late" not in srv.registry.names_at(g0)
    with pytest.raises(ValueError, match="already registered"):
        srv.registry.register("late", shifted_sphere)
    with pytest.raises(ValueError, match="negative|>= 0"):
        CampaignRequest(dim=4, fid=1, budget=100, deadline_s=-1).validate()


def test_allocator_bitmap_and_repack():
    al = SlotAllocator(2, 2)
    spots = [al.alloc(j, 100 * (j + 1)) for j in range(4)]
    assert None not in spots and al.free_rows() == 0
    assert al.alloc(9, 1) is None               # full
    al.release(*spots[1])
    assert al.free_rows() == 1
    al.alloc(9, 900)
    # repack 2×2 → 4×1: every resident job lands exactly once, budgets ride
    occ = al.occupied()
    new, moves, layout = al.repack(4, 1)
    assert sorted(moves) == sorted(j for (_i, _r, j) in occ)
    assert new.capacity == 4 and new.free_rows() == 0
    placed = {int(j): int(new.budgets[i][r]) for i, r, j in new.occupied()}
    want = {int(j): int(al.budgets[i][r]) for i, r, j in occ}
    assert placed == want
    # layout names the old cell every occupied new cell pulls from
    filled = [src for isl in layout for src in isl if src is not None]
    assert sorted(filled) == sorted((i, r) for i, r, _j in occ)
    with pytest.raises(ValueError, match="repack"):
        al.repack(1, 2)                         # 4 jobs into 2 rows


def test_zero_budget_job_completes_empty():
    srv = make_server()
    t = srv.submit(CampaignRequest(dim=4, fid=1, budget=4, seed=0))
    srv.drain()
    assert t.done and t.fevals == 0
    assert t.result.descents == []


# ---------------------------------------------------------------------------
# the 8-virtual-device suite (subprocess, mesh_check pattern)
# ---------------------------------------------------------------------------

@pytest.mark.timeout(540)
def test_service_on_8_virtual_devices():
    """S2-style lane islands over a real multi-device fleet + elastic 4→8
    re-shard restore — asserted inside tests/service_check.py under
    XLA_FLAGS=--xla_force_host_platform_device_count=8."""
    script = os.path.join(os.path.dirname(__file__), "service_check.py")
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(root, "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, script], env=env,
                          capture_output=True, text=True, timeout=520)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "SERVICE-CHECK-OK" in proc.stdout
