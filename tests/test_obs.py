"""Observability layer (src/repro/obs/) — PR 6.

Three things are pinned here:

* **registry semantics** — schema-validated emission (unknown name /
  wrong kind / wrong label set raise at the emission site), counter
  monotonicity, fixed log-bucket histograms, the JSONL sink and the
  prometheus-style text exposition / HTTP endpoint;
* **the instrumented vertical** — a tiny bucketed run and a 2-job
  service run emit exactly the series docs/METRICS.md documents, with
  values that reconcile against the engines' own accounting;
* **the zero-overhead contract** — instrumentation adds NO device syncs
  (``jax.device_get`` calls == boundary-pull observations; the pull is
  the tree's only call site), NO new segment programs, and leaves the
  PR-4 fused-generation HLO pins intact.
"""
import json
import subprocess
import sys
import urllib.error
import urllib.request
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hermetic_subproc_env
from repro.core.ipop import run_ipop
from repro.obs import registry as reg_mod
from repro.obs import schema as schema_mod
from repro.obs.registry import MetricsRegistry
from repro.service import CampaignRequest, CampaignServer

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))           # benchmarks.* (repo-root package)

KW = dict(lam_start=8, kmax_exp=2)


@pytest.fixture
def fresh_metrics():
    """Swap in an empty process-wide registry; restore the previous one."""
    prev = reg_mod.set_metrics(MetricsRegistry())
    yield reg_mod.metrics()
    reg_mod.set_metrics(prev)


def series(reg, name):
    """{label-tuple: instrument} for one metric name."""
    return {lkey: s for (n, lkey), s in reg._series.items() if n == name}


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_instrument_semantics():
    reg = MetricsRegistry()
    c = reg.counter("service_jobs_total", event="submitted")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)                       # counters are monotone
    # same (name, labels) -> same series; different labels -> distinct
    assert reg.counter("service_jobs_total", event="submitted") is c
    assert reg.counter("service_jobs_total", event="completed") is not c

    g = reg.gauge("service_queue_depth")
    g.set(4)
    g.set(2)                            # gauges may go down
    assert g.value == 2.0

    h = reg.histogram("service_snapshot_s")
    assert h.buckets == schema_mod.TIME_BUCKETS_S
    h.observe(1e-6)                     # below the first edge
    h.observe(0.02)
    h.observe(5e4)                      # beyond the last edge -> +Inf bucket
    assert h.count == 3 and h.counts[0] == 1 and h.counts[-1] == 1
    assert h.sum == pytest.approx(1e-6 + 0.02 + 5e4)
    assert h.quantile(0.5) <= 0.0316228     # 0.02 lands in the <=10^-1.5 edge
    assert h.quantile(1.0) == float("inf")
    assert MetricsRegistry().histogram("service_snapshot_s").quantile(0.5) \
        is None


def test_emission_is_schema_validated():
    reg = MetricsRegistry()
    with pytest.raises(KeyError):
        reg.counter("no_such_metric_total")
    with pytest.raises(TypeError):
        reg.gauge("service_jobs_total", event="submitted")   # it's a counter
    with pytest.raises(ValueError):
        reg.counter("service_jobs_total")                    # missing label
    with pytest.raises(ValueError):
        reg.counter("service_jobs_total", event="x", extra="y")


def test_schema_table_conventions():
    assert len(schema_mod.SPECS) == len(schema_mod.SCHEMA)
    for s in schema_mod.SCHEMA:
        assert s.name.split("_")[0] in ("bucketed", "mesh", "service",
                                        "fleet", "obs")
        if s.kind == schema_mod.COUNTER:
            assert s.name.endswith("_total"), s.name
        if s.kind == schema_mod.HISTOGRAM:
            # second-valued by default; eval-count histograms (fleet lost
            # work) carry the _evals suffix and explicit decade buckets
            if s.name.endswith("_evals"):
                assert s.unit == "evaluations", s.name
                assert s.buckets == schema_mod.EVAL_BUCKETS, s.name
            else:
                assert s.name.endswith("_s") and s.unit == "s", s.name
            assert list(s.buckets) == sorted(s.buckets) and s.buckets
        else:
            assert not s.buckets
    edges = schema_mod.log_buckets(1e-2, 1e1, per_decade=1)
    assert edges == (0.01, 0.1, 1.0, 10.0)


def test_jsonl_round_trip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("service_jobs_total", event="submitted").inc(3)
    reg.histogram("service_admission_wait_s").observe(0.5)
    path = tmp_path / "m.jsonl"
    reg.flush_jsonl(str(path))
    reg.counter("service_jobs_total", event="submitted").inc()
    reg.flush_jsonl(str(path))

    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert [ln["seq"] for ln in lines] == [0, 1]
    assert all("unix_s" in ln for ln in lines)
    assert lines[-1]["metrics"] == reg.collect()
    jobs = [m for m in lines[0]["metrics"]
            if m["name"] == "service_jobs_total"]
    assert jobs == [{"name": "service_jobs_total", "type": "counter",
                     "labels": {"event": "submitted"}, "value": 3.0}]
    hist = [m for m in lines[0]["metrics"]
            if m["name"] == "service_admission_wait_s"][0]
    assert hist["count"] == 1 and hist["sum"] == 0.5
    assert sum(c for _le, c in hist["buckets"]) == 1


def test_text_exposition_and_http_endpoint():
    reg = MetricsRegistry()
    reg.counter("service_jobs_total", event="submitted").inc(2)
    h = reg.histogram("service_boundary_pull_s", lane="d4.l8.k2.float64")
    h.observe(0.001)
    h.observe(0.002)
    txt = reg.render_text()
    assert '# TYPE service_jobs_total counter' in txt
    assert 'service_jobs_total{event="submitted"} 2' in txt
    # histogram buckets are CUMULATIVE and label-merged with le=
    assert 'le="+Inf"' in txt
    last = [ln for ln in txt.splitlines()
            if ln.startswith("service_boundary_pull_s_bucket")][-1]
    assert last.endswith(" 2")
    assert 'service_boundary_pull_s_count{lane="d4.l8.k2.float64"} 2' in txt

    httpd, port = reg_mod.start_metrics_server(reg)
    try:
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics") as resp:
            assert resp.status == 200
            assert resp.read().decode() == reg.render_text()
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"http://127.0.0.1:{port}/nope")
    finally:
        httpd.shutdown()


def test_obs_package_is_jax_and_numpy_free():
    """The schema drift check (CI lint step) and the registry must not pay
    a jax/numpy import — pinned in a clean interpreter."""
    code = ("import sys, repro.obs.registry, repro.obs.schema, "
            "repro.obs.trace, repro.obs.recorder; "
            "assert 'jax' not in sys.modules, 'obs imported jax'; "
            "assert 'numpy' not in sys.modules, 'obs imported numpy'")
    subprocess.run([sys.executable, "-c", code], check=True, cwd=ROOT,
                   env=hermetic_subproc_env())


def test_metrics_docs_match_schema():
    """docs/METRICS.md's generated table is current (the CI drift gate)."""
    assert schema_mod.check_file(str(ROOT / "docs" / "METRICS.md")), (
        "docs/METRICS.md is stale — regenerate with "
        "PYTHONPATH=src python -m repro.obs.schema --write docs/METRICS.md")


# ---------------------------------------------------------------------------
# the instrumented vertical + the zero-overhead contract
# ---------------------------------------------------------------------------

def test_bucketed_run_emits_series_without_new_syncs(fresh_metrics,
                                                     count_device_get):
    reg = fresh_metrics
    res = run_ipop(lambda X: jnp.sum(X ** 2, axis=-1), 4,
                   jax.random.PRNGKey(0), backend="bucketed",
                   max_evals=3000, **KW)

    # one sync observation per device_get: instrumentation added none
    syncs = reg.histogram("bucketed_sync_s")
    assert syncs.count > 0
    assert count_device_get["n"] == syncs.count

    # useful evals reconcile with the engine's own accounting
    useful = reg.counter("bucketed_useful_evals_total").value
    assert useful == res.total_fevals
    padded = sum(s.value for s in
                 series(reg, "bucketed_padded_evals_total").values())
    assert padded >= useful

    segs = series(reg, "bucketed_segments_total")
    assert segs and sum(s.value for s in segs.values()) == syncs.count - 1
    for lkey in segs:                   # per-bucket labels, k within range
        (_k, v), = lkey
        assert 0 <= int(v) <= KW["kmax_exp"]
    walls = series(reg, "bucketed_segment_wall_s")
    assert set(walls) == set(segs)
    assert sum(h.count for h in walls.values()) == syncs.count - 1
    assert all(s.value > 0 for s in
               series(reg, "bucketed_eigh_blocks_total").values())


def test_service_two_job_run_emits_documented_series(fresh_metrics,
                                                     count_device_get,
                                                     tmp_path):
    reg = fresh_metrics
    mpath = tmp_path / "rounds.jsonl"
    srv = CampaignServer(bbob_fids=(1, 8), max_budget=5000,
                         rows_per_island=2, metrics_out=str(mpath), **KW)
    t_a = srv.submit(CampaignRequest(dim=4, fid=8, budget=2000, seed=7))
    t_b = srv.submit(CampaignRequest(dim=4, fid=1, budget=1500, seed=3))
    srv.drain()
    assert t_a.done and t_b.done

    # lifecycle counters tell the 2-job story end to end
    jobs = {dict(lkey)["event"]: s.value
            for lkey, s in series(reg, "service_jobs_total").items()}
    assert jobs["submitted"] == jobs["admitted"] == jobs["completed"] == 2
    assert jobs.get("rejected", 0) == 0
    assert reg.histogram("service_admission_wait_s").count == 2
    assert reg.histogram("service_time_to_first_ticket_s").count == 2
    assert reg.histogram("service_time_to_completion_s").count == 2

    rounds = reg.counter("service_boundaries_total").value
    assert rounds > 0
    assert reg.gauge("service_queue_depth").value == 0      # drained
    occ = series(reg, "service_slot_occupancy")
    assert occ and all(0.0 <= g.value <= 1.0 for g in occ.values())
    hit_rate = reg.gauge("service_program_cache_hit_rate").value
    assert 0.0 <= hit_rate <= 1.0

    # no new syncs: every device_get is an observed boundary pull
    pulls = sum(h.count for h in
                series(reg, "service_boundary_pull_s").values())
    assert pulls > 0
    assert count_device_get["n"] == pulls

    # per-round JSONL flush: one line per service round, seq in order
    lines = [json.loads(ln) for ln in mpath.read_text().splitlines()]
    assert len(lines) == rounds
    assert [ln["seq"] for ln in lines] == list(range(len(lines)))

    # every emitted series is documented (and labeled as documented)
    for (name, lkey), _s in reg._series.items():
        spec = schema_mod.SPECS[name]
        assert tuple(sorted(dict(lkey))) == tuple(sorted(spec.labels))

    # zero new programs: the compile bound holds WITH instrumentation, and
    # another same-class job traces nothing new
    compiles = srv.segment_compiles()
    assert compiles <= (KW["kmax_exp"] + 1) * len(srv.lanes)
    t_c = srv.submit(CampaignRequest(dim=4, fid=1, budget=1200, seed=13))
    srv.drain()
    assert t_c.done
    assert srv.segment_compiles() == compiles


def test_fused_gen_hlo_pins_survive_instrumentation():
    """The PR-4 pin, re-run on top of the instrumented tree: exactly one
    gram-family (n, n+1) dot per generation, no separate (n, n) gram."""
    import test_fused_gen as tfg

    from repro.distributed import hlo_analyzer
    txt = tfg._scan_hlo("xla", T=10)
    assert hlo_analyzer.count_instrs(txt, tfg.DOT_N_NP1) == 10
    assert hlo_analyzer.count_instrs(txt, tfg.DOT_N_N) == 0


# ---------------------------------------------------------------------------
# soak-harness plumbing (pure pieces; the full soak runs in CI smoke)
# ---------------------------------------------------------------------------

def test_soak_slo_check_is_pure():
    from benchmarks.bench_service import _check_slo
    soak = {"latency_p99_s": 2.0, "evals_per_s": 1000.0}
    assert _check_slo(soak, None, None) == []
    assert _check_slo(soak, 5.0, 500.0) == []
    viol = _check_slo(soak, 1.0, 2000.0)
    assert len(viol) == 2
    assert "p99" in viol[0] and "evals/s" in viol[1]


def test_bench_json_sections_merge(tmp_path):
    from benchmarks.bench_service import _merge_out
    out = tmp_path / "BENCH_service.json"
    _merge_out(str(out), "service", {"p50": 1.0})
    _merge_out(str(out), "soak", {"latency_p99_s": 2.0})
    _merge_out(str(out), "service", {"p50": 3.0})       # overwrite one key
    data = json.loads(out.read_text())
    assert data == {"service": {"p50": 3.0}, "soak": {"latency_p99_s": 2.0}}
