"""RWKV-6 "Finch" (arXiv:2404.05892): data-dependent decay linear attention.

Time-mix layer: token shift with LoRA-produced data-dependent interpolation
(µ), data-dependent per-channel decay w_t = exp(−exp(ŵ_t)), bonus u, per-head
GroupNorm, SiLU output gate.  Channel-mix layer: token-shifted squared-ReLU
FFN (the classic RWKV channel mix).

The WKV recurrence     S_t = diag(w_t)·S_{t−1} + k_t ⊗ v_t,
                       o_t = r_t·(S_{t−1} + diag(u)·k_t ⊗ v_t)
is evaluated in *chunked-parallel* form (FLA-style): within a chunk of length
c the pairwise decays are a (c × c) matmul in f32; across chunks a scan
carries the (d_k × d_v) state.  Exponent safety: per-token log-decay is
clamped to [−LOG_CLAMP, −1e−6] and c = 16, bounding every exponential by
e^{16·LOG_CLAMP} < f32 max (DESIGN.md hardware-adaptation notes).

Decode is the O(1)-state recurrence — no KV cache, which is why the
``long_500k`` cell is trivially runnable for this architecture.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers

LOG_CLAMP = 5.0
CHUNK = 16
LORA_MIX = 32
LORA_DECAY = 64


def init_rwkv_params(key, d_model: int, head_dim: int, param_dtype) -> dict:
    H = d_model // head_dim
    ks = jax.random.split(key, 12)
    d = d_model
    return {
        # token-shift interpolation: base µ per component + data-dependent LoRA
        "mu_base": jnp.zeros((5, d), param_dtype),          # r,k,v,w,g
        "mu_x": jnp.zeros((d,), param_dtype),
        "maa_w1": layers.dense_init(ks[0], (d, 5 * LORA_MIX), param_dtype),
        "maa_w2": 0.0 * layers.dense_init(ks[1], (5, LORA_MIX, d), param_dtype,
                                          in_axis=1),
        # projections
        "wr": layers.dense_init(ks[2], (d, d), param_dtype),
        "wk": layers.dense_init(ks[3], (d, d), param_dtype),
        "wv": layers.dense_init(ks[4], (d, d), param_dtype),
        "wg": layers.dense_init(ks[5], (d, d), param_dtype),
        "wo": layers.dense_init(ks[6], (d, d), param_dtype),
        # data-dependent decay
        "w0": jnp.full((d,), -1.0, param_dtype),            # base log-log decay
        "dec_w1": layers.dense_init(ks[7], (d, LORA_DECAY), param_dtype),
        "dec_w2": 0.0 * layers.dense_init(ks[8], (LORA_DECAY, d), param_dtype),
        # bonus
        "u": jnp.zeros((H, head_dim), param_dtype),
        # per-head output GroupNorm
        "ln_x_scale": jnp.ones((H, head_dim), param_dtype),
        "ln_x_bias": jnp.zeros((H, head_dim), param_dtype),
    }


def init_channel_mix_params(key, d_model: int, d_ff: int, param_dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d_model,), 0.5, param_dtype),
        "mu_r": jnp.full((d_model,), 0.5, param_dtype),
        "wk": layers.dense_init(ks[0], (d_model, d_ff), param_dtype),
        "wv": layers.dense_init(ks[1], (d_ff, d_model), param_dtype),
        "wr": layers.dense_init(ks[2], (d_model, d_model), param_dtype),
    }


class RWKVState(NamedTuple):
    wkv: jnp.ndarray        # (B, H, Dk, Dv) per-layer recurrent state
    shift_tm: jnp.ndarray   # (B, d) last token (time mix)
    shift_cm: jnp.ndarray   # (B, d) last token (channel mix)


def init_rwkv_state(batch: int, d_model: int, head_dim: int, dtype) -> RWKVState:
    H = d_model // head_dim
    return RWKVState(
        wkv=jnp.zeros((batch, H, head_dim, head_dim), jnp.float32),
        shift_tm=jnp.zeros((batch, d_model), dtype),
        shift_cm=jnp.zeros((batch, d_model), dtype))


def _data_dependent_mix(p, x, x_prev):
    """RWKV6 token shift: returns the 5 mixed streams (r,k,v,w,g)."""
    dt = x.dtype
    dx = x_prev - x                                             # (B,S,d)
    xx = x + dx * p["mu_x"].astype(dt)
    t = jnp.tanh(jnp.einsum("bsd,dm->bsm", xx, p["maa_w1"].astype(dt)))
    t = t.reshape(*xx.shape[:2], 5, LORA_MIX)
    delta = jnp.einsum("bsem,emd->bsed", t, p["maa_w2"].astype(dt))
    mu = p["mu_base"].astype(dt)[None, None] + delta            # (B,S,5,d)
    return x[:, :, None, :] + dx[:, :, None, :] * mu            # (B,S,5,d)


def _decay(p, xw):
    """Per-token per-channel log decay, clamped for chunk-safe exponentials."""
    dt = xw.dtype
    lo = jnp.einsum("bsd,dr->bsr", xw, p["dec_w1"].astype(dt))
    ww = p["w0"].astype(jnp.float32) + jnp.einsum(
        "bsr,rd->bsd", jnp.tanh(lo), p["dec_w2"].astype(dt)).astype(jnp.float32)
    return jnp.clip(-jnp.exp(ww), -LOG_CLAMP, -1e-6)            # (B,S,d) f32


def wkv_chunked(r, k, v, logw, u, state):
    """Chunked-parallel WKV.  r,k,v: (B,S,H,D); logw: (B,S,H,D) f32;
    u: (H,D); state: (B,H,D,Dv) f32.  Returns (o, new_state).

    The body runs under ``named_scope("wkv_tile")``: its inter-kernel tile
    traffic lives in VMEM under the Pallas kernel (kernels/rwkv6_wkv.py) —
    the roofline substitutes the kernel's streaming HBM traffic
    (EXPERIMENTS §Perf, same attribution as flash attention)."""
    with jax.named_scope("wkv_tile"):
        return _wkv_chunked_impl(r, k, v, logw, u, state)


def _wkv_chunked_impl(r, k, v, logw, u, state):
    B, S, H, D = r.shape
    assert S % CHUNK == 0, "caller pads to a CHUNK multiple"
    n_chunks = S // CHUNK
    dt = r.dtype

    def reshape_c(x):
        return x.reshape(B, n_chunks, CHUNK, H, D).swapaxes(0, 1)

    rc, kc, vc, wc = map(reshape_c, (r, k, v, logw))            # (n,B,c,H,D)

    def body(S_prev, inp):
        rr, kk, vv, ww = inp                                    # (B,c,H,D)
        rr32 = rr.astype(jnp.float32)
        kk32 = kk.astype(jnp.float32)
        vv32 = vv.astype(jnp.float32)
        Lc = jnp.cumsum(ww, axis=1)                             # Σ_{s≤t} (B,c,H,D)
        Lc_prev = Lc - ww                                       # Σ_{s<t}
        Lc_last = Lc[:, -1:]
        # intra-chunk pairwise decays: A[t,j] = Σ_d r_t·k_j·e^{Lc_prev_t − Lc_j},
        # strict lower triangle (exponent ≤ 0 ⇔ j < t after clamping)
        q_t = rr32 * jnp.exp(Lc_prev)                           # ≤ e^0
        k_in = kk32 * jnp.exp(-Lc)                              # ≤ e^{c·clamp}
        A = jnp.einsum("bthd,bjhd->bhtj", q_t, k_in)
        tri = jnp.tril(jnp.ones((CHUNK, CHUNK), bool), k=-1)
        A = jnp.where(tri[None, None], A, 0.0)
        o = jnp.einsum("bhtj,bjhd->bthd", A, vv32)
        # diagonal bonus term: r_t·(u ⊙ k_t) v_t
        diag = jnp.einsum("bthd,hd,bthd->bth", rr32, u.astype(jnp.float32), kk32)
        o = o + diag[..., None] * vv32
        # cross-chunk: r_t·e^{Lc_prev_t} · S_prev
        o = o + jnp.einsum("bthd,bhdv->bthv", rr32 * jnp.exp(Lc_prev), S_prev)
        # state update: S_new = e^{Lc_last} ⊙ S + Σ_j (k_j e^{Lc_last−Lc_j}) ⊗ v_j
        k_out = kk32 * jnp.exp(Lc_last - Lc)                    # ≤ e^0
        S_new = (jnp.exp(Lc_last)[:, 0, :, :, None] * S_prev
                 + jnp.einsum("bjhd,bjhv->bhdv", k_out, vv32))
        return S_new, o.astype(dt)

    state, o = jax.lax.scan(body, state, (rc, kc, vc, wc))
    return o.swapaxes(0, 1).reshape(B, S, H, D), state


def time_mix(p: dict, x: jnp.ndarray, shift: jnp.ndarray, wkv_state,
             head_dim: int):
    """Full-sequence RWKV6 attention replacement.  x (B,S,d)."""
    B, S, d = x.shape
    H = d // head_dim
    dt = x.dtype
    x_prev = jnp.concatenate([shift[:, None, :], x[:, :-1]], axis=1)
    mixed = _data_dependent_mix(p, x, x_prev)                   # (B,S,5,d)
    xr, xk, xv, xw, xg = [mixed[:, :, i] for i in range(5)]

    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dt)).reshape(B, S, H, head_dim)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(dt)).reshape(B, S, H, head_dim)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(dt)).reshape(B, S, H, head_dim)
    g = jnp.einsum("bsd,de->bse", xg, p["wg"].astype(dt))
    logw = _decay(p, xw).reshape(B, S, H, head_dim)

    pad = (-S) % CHUNK
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)),
                       constant_values=-1e-6)
    o, new_state = wkv_chunked(r, k, v, logw, p["u"], wkv_state)
    o = o[:, :S]

    o = layers.groupnorm_heads(o, p["ln_x_scale"], p["ln_x_bias"])
    o = o.reshape(B, S, d) * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", o, p["wo"].astype(dt))
    return out, x[:, -1, :], new_state


def time_mix_decode(p: dict, x: jnp.ndarray, shift: jnp.ndarray, wkv_state,
                    head_dim: int):
    """One-token recurrence (decode).  x (B,1,d)."""
    B, _, d = x.shape
    H = d // head_dim
    dt = x.dtype
    x_prev = shift[:, None, :]
    mixed = _data_dependent_mix(p, x, x_prev)
    xr, xk, xv, xw, xg = [mixed[:, :, i] for i in range(5)]
    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dt)).reshape(B, H, head_dim)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(dt)).reshape(B, H, head_dim)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(dt)).reshape(B, H, head_dim)
    g = jnp.einsum("bsd,de->bse", xg, p["wg"].astype(dt))[:, 0]
    logw = _decay(p, xw).reshape(B, H, head_dim)

    r32, k32, v32 = (a.astype(jnp.float32) for a in (r, k, v))
    u32 = p["u"].astype(jnp.float32)
    # o = r·(S + u ⊙ k ⊗ v);  S' = e^{logw} ⊙ S + k ⊗ v
    kv = jnp.einsum("bhd,bhv->bhdv", k32, v32)
    o = jnp.einsum("bhd,bhdv->bhv", r32, wkv_state + u32[None, :, :, None] * kv)
    new_state = jnp.exp(logw)[..., None] * wkv_state + kv
    o = layers.groupnorm_heads(o.astype(dt), p["ln_x_scale"], p["ln_x_bias"])
    o = o.reshape(B, d) * jax.nn.silu(g)
    out = jnp.einsum("bd,de->be", o, p["wo"].astype(dt))
    return out[:, None, :], x[:, -1, :], new_state


def channel_mix(p: dict, x: jnp.ndarray, shift: jnp.ndarray):
    dt = x.dtype
    x_prev = jnp.concatenate([shift[:, None, :], x[:, :-1]], axis=1)
    xk = x + (x_prev - x) * p["mu_k"].astype(dt)
    xr = x + (x_prev - x) * p["mu_r"].astype(dt)
    kk = jnp.square(jax.nn.relu(
        jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(dt))))
    vv = jnp.einsum("bsf,fd->bsd", kk, p["wv"].astype(dt))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dt)))
    return rr * vv, x[:, -1, :]
