"""Dense feed-forward blocks: (Sw)iGLU-gated and plain two-layer MLPs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def init_mlp_params(key, d_model: int, d_ff: int, glu: bool,
                    param_dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"wi": layers.dense_init(ks[0], (d_model, d_ff), param_dtype),
         "wo": layers.dense_init(ks[1], (d_ff, d_model), param_dtype)}
    if glu:
        p["wg"] = layers.dense_init(ks[2], (d_model, d_ff), param_dtype)
    return p


def mlp(p: dict, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    dt = x.dtype
    fn = layers.activation(act)
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt))
    if "wg" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dt))
        h = fn(g) * h
    else:
        h = fn(h)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dt))
