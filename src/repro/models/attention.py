"""GQA attention: causal / sliding-window / cross, training and cached decode.

Training/prefill attention is *query-chunked*: scores are materialized only
for (q_chunk × kv) tiles, so a 32k-token prefill never allocates an
S×S score tensor (the memory-roofline term that would otherwise dominate —
see EXPERIMENTS §Roofline).  Sliding-window layers additionally slice the KV
range per chunk, making compute O(S·W) instead of O(S²).

Decode reads a pre-allocated KV cache ring.  For long-context decode the
cache may be sequence-sharded across the 'data' axis (split-K attention) —
the einsums below are written so XLA SPMD partitions them with a psum merge.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers


class AttnConfig(NamedTuple):
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 1e4
    pos: str = "rope"            # rope | none (positions baked into embeds)
    sliding_window: int = 0      # 0 → full causal
    causal: bool = True
    q_chunk: int = 1024
    impl: str = "naive"          # naive | flash (see configs/base.py)
    batch_tp: bool = False       # shard attention batch over (dp, model)


def init_attn_params(key, cfg: AttnConfig, param_dtype,
                     kv_input_dim: Optional[int] = None) -> dict:
    d, H, Hk, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    d_kv_in = kv_input_dim if kv_input_dim is not None else d
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], (d, H, Dh), param_dtype),
        "wk": layers.dense_init(ks[1], (d_kv_in, Hk, Dh), param_dtype),
        "wv": layers.dense_init(ks[2], (d_kv_in, Hk, Dh), param_dtype),
        "wo": layers.dense_init(ks[3], (H, Dh, d), param_dtype, in_axis=(0, 1)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, Dh), param_dtype)
        p["bk"] = jnp.zeros((Hk, Dh), param_dtype)
        p["bv"] = jnp.zeros((Hk, Dh), param_dtype)
    return p


def _project_qkv(p, cfg: AttnConfig, x, kv_x, q_pos, kv_pos):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.pos == "rope":
        q = layers.apply_rope(q, q_pos, cfg.rope_theta)
        k = layers.apply_rope(k, kv_pos, cfg.rope_theta)
    return q, k, v


def _sdpa_chunk(q, k, v, mask, scale):
    """q (B,C,H,Dh), k/v (B,Skv,Hk,Dh) with GQA broadcast; mask (B,C,Skv) or None."""
    B, C, H, Dh = q.shape
    Hk = k.shape[2]
    rep = H // Hk
    qg = q.reshape(B, C, Hk, rep, Dh)
    logits = jnp.einsum("bchrk,bshk->bhrcs", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    if mask is not None:
        logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhrcs,bshk->bchrk", probs, v.astype(jnp.float32))
    return out.reshape(B, C, H, Dh).astype(q.dtype)


def attend_full(p: dict, cfg: AttnConfig, x: jnp.ndarray,
                positions: jnp.ndarray,
                kv_x: Optional[jnp.ndarray] = None,
                kv_positions: Optional[jnp.ndarray] = None,
                return_kv: bool = False):
    """Training / prefill attention over a full sequence (query-chunked).

    x (B, S, d); kv_x given ⇒ cross-attention (no causal mask, no window).
    ``return_kv`` ⇒ returns (out, (k, v)) for prefill cache construction.
    """
    B, S, d = x.shape
    cross = kv_x is not None
    kv_x = x if kv_x is None else kv_x
    kv_positions = positions if kv_positions is None else kv_positions
    Skv = kv_x.shape[1]
    scale = 1.0 / math.sqrt(cfg.head_dim)

    q, k, v = _project_qkv(p, cfg, x, kv_x, positions, kv_positions)

    # flash path: memory-linear custom-VJP attention (index-order masks —
    # every self-attn call site uses arange positions).  §Perf iteration 1.
    if cfg.impl == "flash" and not cross and cfg.causal:
        from repro.models.flash_xla import flash_mha
        resharded = False
        if cfg.batch_tp:
            from jax.sharding import PartitionSpec as Pspec
            from repro.distributed import sharding as shd
            mesh = shd.get_mesh()
            if mesh is not None:
                all_ax = tuple(mesh.axis_names)
                n_all = int(mesh.devices.size)
                if n_all and B % n_all == 0:
                    spec = Pspec(all_ax, None, None, None)
                    q = shd.constrain(q, spec)
                    k = shd.constrain(k, spec)
                    v = shd.constrain(v, spec)
                    resharded = True
        out = flash_mha(q, k, v, cfg.causal, cfg.sliding_window)
        if resharded:
            out = shd.constrain(
                out, Pspec(shd.dp_axes(mesh), None, None, None))
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
        if return_kv:
            return y, (k, v)
        return y

    cq = min(cfg.q_chunk, S)
    n_chunks = -(-S // cq)
    pad = n_chunks * cq - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qs = q.reshape(B, n_chunks, cq, cfg.n_heads, cfg.head_dim)
    q_pos_pad = jnp.pad(positions, ((0, 0), (0, pad))) if pad else positions
    qpos = q_pos_pad.reshape(B, n_chunks, cq)

    kv_idx = kv_positions  # (B, Skv)

    # sliding-window layers only read a (W + cq)-sized KV slice per q chunk:
    # compute O(S·W) instead of O(S²) (DESIGN.md; assumes token order).
    windowed = (cfg.sliding_window > 0 and cfg.causal and not cross
                and Skv > cfg.sliding_window + cq)
    if windowed:
        kv_len = -(-(cfg.sliding_window + cq) // cq) * cq

    def one_chunk(c):
        if windowed:
            start = jnp.clip(c * cq + cq - kv_len, 0, Skv - kv_len)
            kc = jax.lax.dynamic_slice_in_dim(k, start, kv_len, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, kv_len, axis=1)
            kidx = jax.lax.dynamic_slice_in_dim(kv_idx, start, kv_len, axis=1)
        else:
            kc, vc, kidx = k, v, kv_idx
        qc = qs[:, c]
        pc = qpos[:, c]                                   # (B, cq)
        if cross or not cfg.causal:
            mask = None
        else:
            mask = kidx[:, None, :] <= pc[:, :, None]     # causal
            if cfg.sliding_window > 0:
                mask &= kidx[:, None, :] > pc[:, :, None] - cfg.sliding_window
        return _sdpa_chunk(qc, kc, vc, mask, scale)

    out = jax.lax.map(one_chunk, jnp.arange(n_chunks))    # (n_chunks, B, cq, H, Dh)
    out = jnp.moveaxis(out, 0, 1).reshape(B, n_chunks * cq, cfg.n_heads,
                                          cfg.head_dim)[:, :S]
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    if return_kv:
        return y, (k, v)
    return y


# ---------------------------------------------------------------------------
# cached decode
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jnp.ndarray        # (B, S_max, Hk, Dh)
    v: jnp.ndarray        # (B, S_max, Hk, Dh)
    length: jnp.ndarray   # () int32 — tokens currently in cache


def init_kv_cache(cfg: AttnConfig, batch: int, max_len: int, dtype) -> KVCache:
    eff = min(max_len, cfg.sliding_window) if cfg.sliding_window > 0 else max_len
    shape = (batch, eff, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   length=jnp.asarray(0, jnp.int32))


def decode_step(p: dict, cfg: AttnConfig, x: jnp.ndarray, pos: jnp.ndarray,
                cache: KVCache) -> tuple[jnp.ndarray, KVCache]:
    """One-token decode.  x (B, 1, d), pos (B, 1) absolute positions."""
    B = x.shape[0]
    scale = 1.0 / math.sqrt(cfg.head_dim)
    q, k_new, v_new = _project_qkv(p, cfg, x, x, pos, pos)

    S_max = cache.k.shape[1]
    slot = jnp.mod(cache.length, S_max)    # ring for sliding-window caches
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, slot, axis=1)
    new_len = cache.length + 1

    # ring-aware slot→token map: slot i holds the latest token t ≡ i (mod S_max)
    # with t < new_len; negative values mark not-yet-written slots.
    idx = jnp.arange(S_max)
    tok_pos = idx + ((new_len - 1 - idx) // S_max) * S_max
    valid = (tok_pos >= 0) & (tok_pos < new_len)
    if cfg.sliding_window > 0:
        valid &= tok_pos > (pos[:, 0].max() - cfg.sliding_window)

    mask = jnp.broadcast_to(valid[None, None, :], (B, 1, S_max))
    out = _sdpa_chunk(q, k, v, mask, scale)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, KVCache(k=k, v=v, length=new_len)


def cross_decode(p: dict, cfg: AttnConfig, x: jnp.ndarray,
                 kv_k: jnp.ndarray, kv_v: jnp.ndarray) -> jnp.ndarray:
    """Decode against a fixed (precomputed) cross-attention KV set."""
    scale = 1.0 / math.sqrt(cfg.head_dim)
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
    out = _sdpa_chunk(q, kv_k, kv_v, None, scale)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def precompute_cross_kv(p: dict, cfg: AttnConfig, kv_x: jnp.ndarray):
    dt = kv_x.dtype
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return k, v
