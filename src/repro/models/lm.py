"""Unified LM assembly for the 10 assigned architectures.

One functional module covers every family via a *pattern-unit* scanned layer
stack (keeps HLO size O(1) in depth — essential for the 512-device dry-run):

  dense / moe / audio : uniform units of 1 layer (scan over n_layers)
  gemma3 (local:global): units of (5 sliding-local + 1 global) + local tail
  vlm                 : units of (4 self-attn + 1 gated cross-attn)
  ssm (rwkv6)         : uniform RWKV6 time-mix/channel-mix units
  hybrid (zamba2)     : units of (6 mamba2 + shared transformer block) + tail

Params are nested dicts; every stacked subtree lives under ``segments/`` and
is sharded by suffix rules (distributed/sharding.py).  The public surface:

  init_params(cfg, key)                  → params
  forward(cfg, params, batch)            → (hidden, aux_loss)
  loss(cfg, params, batch)               → (scalar, metrics)   # chunked CE
  init_cache(cfg, batch, max_len, dtype) → cache pytree
  prefill(cfg, params, batch, max_len)   → (last_logits, cache)
  decode_step(cfg, params, cache, batch) → (logits, cache)     # 1 token

``batch`` dict keys: tokens (B,S) int32 | frames (B,S,d) [audio stub] |
img_embeds (B,N,d) [vlm stub] | labels (B,S) int32 (train only).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.models import attention, layers, mamba2, mlp as mlp_mod, moe as moe_mod, rwkv6

MOE_AUX_COEF = 0.01


# ---------------------------------------------------------------------------
# config plumbing
# ---------------------------------------------------------------------------

def _attn_cfg(cfg: ModelConfig, *, window: int = 0, theta: float = 0.0,
              d_model: int = 0, causal: bool = True) -> attention.AttnConfig:
    return attention.AttnConfig(
        d_model=d_model or cfg.d_model,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        qkv_bias=cfg.qkv_bias,
        rope_theta=theta or cfg.rope_theta,
        pos="rope" if cfg.pos == "rope" else "none",
        sliding_window=window, causal=causal, q_chunk=cfg.q_chunk,
        impl=cfg.attn_impl, batch_tp=cfg.attn_batch_tp)


def _norm_fns(cfg: ModelConfig):
    return layers.make_norm(cfg.norm)


def gemma_units(cfg: ModelConfig):
    """(n_units, n_tail) for the (local×k + global) pattern."""
    unit = cfg.local_per_global + 1
    return cfg.n_layers // unit, cfg.n_layers % unit


def zamba_units(cfg: ModelConfig):
    unit = cfg.shared_attn_every
    return cfg.n_layers // unit, cfg.n_layers % unit


def vlm_units(cfg: ModelConfig):
    unit = cfg.cross_every
    assert cfg.n_layers % unit == 0
    return cfg.n_layers // unit, unit - 1   # (n_units, self-layers per unit)


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _stack_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def _attn_layer_init(cfg: ModelConfig, pdt, *, d_model: int = 0):
    norm_init, _ = _norm_fns(cfg)
    d = d_model or cfg.d_model
    acfg = _attn_cfg(cfg, d_model=d)

    def init(key):
        k1, k2 = jax.random.split(key)
        p = {"ln1": norm_init(d, pdt),
             "attn": attention.init_attn_params(k1, acfg, pdt),
             "ln2": norm_init(d, pdt)}
        if cfg.family == "moe":
            p["moe"] = moe_mod.init_moe_params(
                k2, cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.glu, pdt)
        else:
            p["mlp"] = mlp_mod.init_mlp_params(k2, d, cfg.d_ff, cfg.glu, pdt)
        return p
    return init


def _cross_layer_init(cfg: ModelConfig, pdt):
    norm_init, _ = _norm_fns(cfg)
    acfg = _attn_cfg(cfg, causal=False)

    def init(key):
        k1, k2 = jax.random.split(key)
        return {"ln1": norm_init(cfg.d_model, pdt),
                "attn": attention.init_attn_params(k1, acfg, pdt),
                "ln2": norm_init(cfg.d_model, pdt),
                "mlp": mlp_mod.init_mlp_params(k2, cfg.d_model, cfg.d_ff,
                                               cfg.glu, pdt),
                "gate_attn": jnp.zeros((), pdt),
                "gate_ffn": jnp.zeros((), pdt)}
    return init


def _rwkv_layer_init(cfg: ModelConfig, pdt):
    norm_init, _ = _norm_fns(cfg)

    def init(key):
        k1, k2 = jax.random.split(key)
        return {"ln1": norm_init(cfg.d_model, pdt),
                "tmix": rwkv6.init_rwkv_params(k1, cfg.d_model,
                                               cfg.rwkv_head_dim, pdt),
                "ln2": norm_init(cfg.d_model, pdt),
                "cmix": rwkv6.init_channel_mix_params(k2, cfg.d_model,
                                                      cfg.d_ff, pdt)}
    return init


def _mamba_layer_init(cfg: ModelConfig, pdt):
    norm_init, _ = _norm_fns(cfg)

    def init(key):
        return {"ln": norm_init(cfg.d_model, pdt),
                "mamba": mamba2.init_mamba_params(
                    key, cfg.d_model, cfg.ssm_state, cfg.ssm_head_dim,
                    cfg.ssm_expand, pdt)}
    return init


def _shared_block_init(cfg: ModelConfig, key, pdt):
    """Zamba2 shared transformer block over concat(x, x_embed) — width 2d."""
    norm_init, _ = _norm_fns(cfg)
    d2 = 2 * cfg.d_model
    acfg = _attn_cfg(cfg, d_model=d2)
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": norm_init(d2, pdt),
            "attn": attention.init_attn_params(k1, acfg, pdt),
            "ln2": norm_init(d2, pdt),
            "mlp": mlp_mod.init_mlp_params(k2, d2, cfg.d_ff, cfg.glu, pdt),
            "shared_proj": layers.dense_init(k3, (d2, cfg.d_model), pdt)}


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    pdt = jnp.dtype(cfg.param_dtype)
    norm_init, _ = _norm_fns(cfg)
    keys = jax.random.split(key, 8)
    p: dict = {}
    if cfg.embed_inputs:
        p["tok_embed"] = layers.embed_init(keys[0], (cfg.vocab, cfg.d_model), pdt)

    seg: dict = {}
    if cfg.family in ("dense", "moe", "audio") and not cfg.local_per_global:
        seg["unit"] = _stack_init(_attn_layer_init(cfg, pdt), keys[1],
                                  cfg.n_layers)
    elif cfg.local_per_global:                               # gemma3
        n_units, n_tail = gemma_units(cfg)
        k = cfg.local_per_global
        init_one = _attn_layer_init(cfg, pdt)
        seg["unit"] = {
            "local": _stack_init(lambda kk: _stack_init(init_one, kk, k),
                                 keys[1], n_units),
            "global": _stack_init(init_one, keys[2], n_units)}
        if n_tail:
            seg["tail"] = _stack_init(init_one, keys[3], n_tail)
    elif cfg.family == "vlm":
        n_units, n_self = vlm_units(cfg)
        init_self = _attn_layer_init(cfg, pdt)
        seg["unit"] = {
            "self": _stack_init(lambda kk: _stack_init(init_self, kk, n_self),
                                keys[1], n_units),
            "cross": _stack_init(_cross_layer_init(cfg, pdt), keys[2], n_units)}
    elif cfg.family == "ssm":
        seg["unit"] = _stack_init(_rwkv_layer_init(cfg, pdt), keys[1],
                                  cfg.n_layers)
        p["ln0"] = norm_init(cfg.d_model, pdt)               # RWKV post-embed LN
    elif cfg.family == "hybrid":
        n_units, n_tail = zamba_units(cfg)
        u = cfg.shared_attn_every
        init_one = _mamba_layer_init(cfg, pdt)
        seg["unit"] = {"mamba": _stack_init(
            lambda kk: _stack_init(init_one, kk, u), keys[1], n_units)}
        if n_tail:
            seg["tail"] = _stack_init(init_one, keys[3], n_tail)
        p["shared"] = _shared_block_init(cfg, keys[2], pdt)
    else:
        raise ValueError(cfg.family)

    p["segments"] = seg
    p["final_norm"] = norm_init(cfg.d_model, pdt)
    if not cfg.tied_embeddings and cfg.vocab:
        p["lm_head"] = layers.dense_init(keys[4], (cfg.d_model, cfg.vocab), pdt)
    return p


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed(cfg: ModelConfig, params: dict, batch: dict) -> jnp.ndarray:
    dt = jnp.dtype(cfg.dtype)
    if cfg.embed_inputs:
        x = params["tok_embed"].astype(dt)[batch["tokens"]]
        if cfg.tied_embeddings or cfg.name.startswith("gemma"):
            x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    else:
        x = batch["frames"].astype(dt)
    if cfg.pos == "sinusoidal":
        B, S = x.shape[:2]
        pos = batch.get("positions")
        if pos is None:
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = x + layers.sinusoidal_positions(pos, cfg.d_model).astype(dt)
    return x


def head_matrix(cfg: ModelConfig, params: dict) -> jnp.ndarray:
    """(d, V) projection — tied archs reuse the embedding."""
    if cfg.tied_embeddings:
        return params["tok_embed"].T
    return params["lm_head"]


# ---------------------------------------------------------------------------
# layer bodies (full sequence — train / prefill)
# ---------------------------------------------------------------------------

def _attn_block(cfg: ModelConfig, p: dict, x, positions, *, window=0,
                theta=0.0, d_model=0, collect_kv=False):
    _, norm = _norm_fns(cfg)
    acfg = _attn_cfg(cfg, window=window, theta=theta, d_model=d_model)
    a = attention.attend_full(p["attn"], acfg, norm(p["ln1"], x), positions,
                              return_kv=collect_kv)
    kv = None
    if collect_kv:
        a, kv = a
    x = x + a
    h = norm(p["ln2"], x)
    if "moe" in p:
        f, aux = moe_mod.moe(p["moe"], h, cfg.experts_per_tok,
                             cfg.capacity_factor, cfg.act,
                             dispatch=cfg.moe_dispatch)
    else:
        f, aux = mlp_mod.mlp(p["mlp"], h, cfg.act), jnp.zeros((), jnp.float32)
    return x + f, aux, kv


def _cross_attn_cfg(cfg: ModelConfig) -> attention.AttnConfig:
    """Cross-attn: no causal mask, no RoPE (llama-3.2-vision style)."""
    return attention.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, qkv_bias=cfg.qkv_bias, pos="none",
        causal=False, q_chunk=cfg.q_chunk)


def _cross_block(cfg: ModelConfig, p: dict, x, img):
    """Gated cross-attention layer (training path, query-chunked)."""
    _, norm = _norm_fns(cfg)
    dt = x.dtype
    B, S = x.shape[:2]
    zpos = jnp.zeros((B, img.shape[1]), jnp.int32)
    a = attention.attend_full(p["attn"], _cross_attn_cfg(cfg),
                              norm(p["ln1"], x),
                              jnp.zeros((B, S), jnp.int32),
                              kv_x=img, kv_positions=zpos)
    x = x + jnp.tanh(p["gate_attn"].astype(dt)) * a
    f = mlp_mod.mlp(p["mlp"], norm(p["ln2"], x), cfg.act)
    return x + jnp.tanh(p["gate_ffn"].astype(dt)) * f


def _cross_block_cached(cfg: ModelConfig, p: dict, x, img_kv):
    """Decode path against the precomputed cross K/V."""
    _, norm = _norm_fns(cfg)
    dt = x.dtype
    a = attention.cross_decode(p["attn"], _cross_attn_cfg(cfg),
                               norm(p["ln1"], x), img_kv[0], img_kv[1])
    x = x + jnp.tanh(p["gate_attn"].astype(dt)) * a
    f = mlp_mod.mlp(p["mlp"], norm(p["ln2"], x), cfg.act)
    return x + jnp.tanh(p["gate_ffn"].astype(dt)) * f


def _img_kv(cfg: ModelConfig, p_cross: dict, img_embeds):
    """Precompute cross-attn K/V from (stub) image patch embeddings."""
    return attention.precompute_cross_kv(p_cross["attn"], _cross_attn_cfg(cfg),
                                         img_embeds)


def _rwkv_block(cfg: ModelConfig, p: dict, x, state: Optional[rwkv6.RWKVState]):
    _, norm = _norm_fns(cfg)
    B, _, d = x.shape
    if state is None:
        state = rwkv6.init_rwkv_state(B, d, cfg.rwkv_head_dim, x.dtype)
    o, sh_tm, wkv = rwkv6.time_mix(p["tmix"], norm(p["ln1"], x),
                                   state.shift_tm, state.wkv,
                                   cfg.rwkv_head_dim)
    x = x + o
    o, sh_cm = rwkv6.channel_mix(p["cmix"], norm(p["ln2"], x), state.shift_cm)
    x = x + o
    return x, rwkv6.RWKVState(wkv=wkv, shift_tm=sh_tm, shift_cm=sh_cm)


def _mamba_block(cfg: ModelConfig, p: dict, x,
                 state: Optional[mamba2.MambaState]):
    _, norm = _norm_fns(cfg)
    o, new_state = mamba2.mamba_layer(
        p["mamba"], norm(p["ln"], x), cfg.d_model, cfg.ssm_state,
        cfg.ssm_head_dim, cfg.ssm_expand, state)
    return x + o, new_state


def _shared_block(cfg: ModelConfig, sp: dict, x, x0, positions,
                  collect_kv=False):
    """Zamba2 shared block: full transformer at width 2d, projected back."""
    _, norm = _norm_fns(cfg)
    d2 = 2 * cfg.d_model
    acfg = _attn_cfg(cfg, d_model=d2)
    h2 = jnp.concatenate([x, x0], axis=-1)
    a = attention.attend_full(sp["attn"], acfg, norm(sp["ln1"], h2), positions,
                              return_kv=collect_kv)
    kv = None
    if collect_kv:
        a, kv = a
    h2 = h2 + a
    h2 = h2 + mlp_mod.mlp(sp["mlp"], norm(sp["ln2"], h2), cfg.act)
    return x + jnp.einsum("bse,ed->bsd", h2, sp["shared_proj"].astype(x.dtype)), kv


def _maybe_remat(cfg: ModelConfig, fn):
    return jax.checkpoint(fn) if cfg.remat else fn


def _constrain_act(x):
    mesh = sharding.get_mesh()
    if mesh is None:
        return x
    return sharding.constrain(x, sharding.batch_spec(mesh, x.ndim - 1))


# ---------------------------------------------------------------------------
# forward (full sequence)
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: dict, batch: dict):
    """Full-sequence forward.  Returns (hidden (B,S,d), moe_aux_loss)."""
    x = embed(cfg, params, batch)
    B, S = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    seg = params["segments"]
    aux = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe", "audio") and not cfg.local_per_global:
        def body(x, lp):
            x, a, _ = _attn_block(cfg, lp, x, positions)
            return _constrain_act(x), a
        body = _maybe_remat(cfg, body)
        def scan_f(carry, lp):
            x, acc = carry
            x, a = body(x, lp)
            return (x, acc + a), None
        (x, aux), _ = jax.lax.scan(scan_f, (x, aux), seg["unit"])

    elif cfg.local_per_global:                                # gemma3
        thetas = (cfg.rope_theta, cfg.rope_theta_global or cfg.rope_theta)

        def unit_body(x, up):
            def loc(x, lp):
                x, _, _ = _attn_block(cfg, lp, x, positions,
                                      window=cfg.sliding_window,
                                      theta=thetas[0])
                return _constrain_act(x), None
            x, _ = jax.lax.scan(loc, x, up["local"])
            x, _, _ = _attn_block(cfg, up["global"], x, positions,
                                  theta=thetas[1])
            return _constrain_act(x), None
        unit_body = _maybe_remat(cfg, unit_body)
        x, _ = jax.lax.scan(lambda x, up: unit_body(x, up), x, seg["unit"])
        if "tail" in seg:
            def tail_body(x, lp):
                x, _, _ = _attn_block(cfg, lp, x, positions,
                                      window=cfg.sliding_window,
                                      theta=thetas[0])
                return _constrain_act(x), None
            tail_body = _maybe_remat(cfg, tail_body)
            x, _ = jax.lax.scan(tail_body, x, seg["tail"])

    elif cfg.family == "vlm":
        img = batch["img_embeds"].astype(x.dtype)

        def unit_body(x, up):
            def one_self(x, lp):
                x, _, _ = _attn_block(cfg, lp, x, positions)
                return _constrain_act(x), None
            x, _ = jax.lax.scan(one_self, x, up["self"])
            x = _cross_block(cfg, up["cross"], x, img)
            return _constrain_act(x), None
        unit_body = _maybe_remat(cfg, unit_body)
        x, _ = jax.lax.scan(lambda x, up: unit_body(x, up), x, seg["unit"])

    elif cfg.family == "ssm":
        _, norm = _norm_fns(cfg)
        x = norm(params["ln0"], x)

        def body(x, lp):
            x, _ = _rwkv_block(cfg, lp, x, None)
            return _constrain_act(x), None
        body = _maybe_remat(cfg, body)
        x, _ = jax.lax.scan(body, x, seg["unit"])

    elif cfg.family == "hybrid":
        x0 = x

        def unit_body(x, up):
            def one_mamba(x, lp):
                x, _ = _mamba_block(cfg, lp, x, None)
                return _constrain_act(x), None
            x, _ = jax.lax.scan(one_mamba, x, up["mamba"])
            x, _ = _shared_block(cfg, params["shared"], x, x0, positions)
            return _constrain_act(x), None
        unit_body = _maybe_remat(cfg, unit_body)
        x, _ = jax.lax.scan(lambda x, up: unit_body(x, up), x, seg["unit"])
        if "tail" in seg:
            def tail_body(x, lp):
                x, _ = _mamba_block(cfg, lp, x, None)
                return _constrain_act(x), None
            tail_body = _maybe_remat(cfg, tail_body)
            x, _ = jax.lax.scan(tail_body, x, seg["tail"])
    else:
        raise ValueError(cfg.family)

    _, norm = _norm_fns(cfg)
    return norm(params["final_norm"], x), aux


# ---------------------------------------------------------------------------
# chunked cross-entropy (never materializes (B, S, V))
# ---------------------------------------------------------------------------

def chunked_ce(cfg: ModelConfig, params: dict, hidden: jnp.ndarray,
               labels: jnp.ndarray):
    """Scan the sequence in ``logits_chunk`` slices; f32 log-sum-exp."""
    head = head_matrix(cfg, params)                    # (d, V)
    B, S, d = hidden.shape
    C = min(cfg.logits_chunk, S)
    pad = (-S) % C
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = hidden.shape[1] // C
    hs = jnp.moveaxis(hidden.reshape(B, nc, C, d), 1, 0)
    ys = jnp.moveaxis(labels.reshape(B, nc, C), 1, 0)

    def chunk_loss(h, y):
        logits = jnp.einsum("bcd,dv->bcv", h, head.astype(h.dtype),
                            preferred_element_type=jnp.float32)
        valid = (y >= 0)
        ysafe = jnp.clip(y, 0, None)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ysafe[..., None], axis=-1)[..., 0]
        nll = jnp.where(valid, lse - gold, 0.0)
        return jnp.sum(nll).astype(jnp.float32), \
            jnp.sum(valid).astype(jnp.float32)

    chunk_loss = jax.checkpoint(chunk_loss) if cfg.remat else chunk_loss

    def scan_f(acc, inp):
        h, y = inp
        s, c = chunk_loss(h, y)
        return (acc[0] + s, acc[1] + c), None

    (total, count), _ = jax.lax.scan(
        scan_f, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hs, ys))
    return total / jnp.maximum(count, 1.0)


def loss(cfg: ModelConfig, params: dict, batch: dict):
    hidden, aux = forward(cfg, params, batch)
    ce = chunked_ce(cfg, params, hidden, batch["labels"])
    total = ce + MOE_AUX_COEF * aux
    return total, {"ce": ce, "moe_aux": aux}


def logits_last(cfg: ModelConfig, params: dict, hidden: jnp.ndarray):
    """(B, V) logits of the final position (prefill output)."""
    head = head_matrix(cfg, params)
    h = hidden[:, -1, :]
    return jnp.einsum("bd,dv->bv", h, head.astype(h.dtype),
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------

def _kv_shape(cfg: ModelConfig, B: int, L: int, *, d_model: int = 0):
    return (B, L, cfg.n_kv_heads, cfg.head_dim)


def _win(cfg: ModelConfig, max_len: int) -> int:
    return min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len


def init_cache(cfg: ModelConfig, B: int, max_len: int, dtype=None) -> dict:
    dt = jnp.dtype(dtype or cfg.dtype)
    z = lambda shape: jnp.zeros(shape, dt)
    cache: dict = {"length": jnp.zeros((), jnp.int32)}

    if cfg.family in ("dense", "moe", "audio") and not cfg.local_per_global:
        kv = _kv_shape(cfg, B, max_len)
        cache["k"] = z((cfg.n_layers,) + kv)
        cache["v"] = z((cfg.n_layers,) + kv)
    elif cfg.local_per_global:
        n_units, n_tail = gemma_units(cfg)
        k = cfg.local_per_global
        w = _win(cfg, max_len)
        cache["local_k"] = z((n_units, k) + _kv_shape(cfg, B, w))
        cache["local_v"] = z((n_units, k) + _kv_shape(cfg, B, w))
        cache["global_k"] = z((n_units,) + _kv_shape(cfg, B, max_len))
        cache["global_v"] = z((n_units,) + _kv_shape(cfg, B, max_len))
        if n_tail:
            cache["tail_k"] = z((n_tail,) + _kv_shape(cfg, B, w))
            cache["tail_v"] = z((n_tail,) + _kv_shape(cfg, B, w))
    elif cfg.family == "vlm":
        n_units, n_self = vlm_units(cfg)
        kv = _kv_shape(cfg, B, max_len)
        img_kv = (B, cfg.n_img_tokens, cfg.n_kv_heads, cfg.head_dim)
        cache["self_k"] = z((n_units, n_self) + kv)
        cache["self_v"] = z((n_units, n_self) + kv)
        cache["cross_k"] = z((n_units,) + img_kv)
        cache["cross_v"] = z((n_units,) + img_kv)
    elif cfg.family == "ssm":
        L, d, Dh = cfg.n_layers, cfg.d_model, cfg.rwkv_head_dim
        H = d // Dh
        cache["wkv"] = jnp.zeros((L, B, H, Dh, Dh), jnp.float32)
        cache["shift_tm"] = z((L, B, d))
        cache["shift_cm"] = z((L, B, d))
    elif cfg.family == "hybrid":
        n_units, n_tail = zamba_units(cfg)
        u = cfg.shared_attn_every
        d_in = cfg.ssm_expand * cfg.d_model
        H = d_in // cfg.ssm_head_dim
        conv_dim = d_in + 2 * cfg.ssm_state
        ssm = (B, H, cfg.ssm_state, cfg.ssm_head_dim)
        conv = (B, mamba2.CONV_K - 1, conv_dim)
        cache["ssm"] = jnp.zeros((n_units, u) + ssm, jnp.float32)
        cache["conv"] = z((n_units, u) + conv)
        kv = _kv_shape(cfg, B, max_len)
        cache["shared_k"] = z((n_units,) + kv)
        cache["shared_v"] = z((n_units,) + kv)
        cache["x0"] = z((B, cfg.d_model))           # embedding residual stream
        if n_tail:
            cache["tail_ssm"] = jnp.zeros((n_tail,) + ssm, jnp.float32)
            cache["tail_conv"] = z((n_tail,) + conv)
    else:
        raise ValueError(cfg.family)
    return cache


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def _pad_to(x, L: int, axis: int):
    pad = L - x.shape[axis]
    if pad <= 0:
        return x[tuple(slice(None) if i != axis else slice(0, L)
                       for i in range(x.ndim))]
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _window_tail(kv, w: int):
    """Keep the last min(S, w) positions, padded/rolled into a w-ring."""
    k, v = kv
    S = k.shape[1]
    if S <= w:
        return _pad_to(k, w, 1), _pad_to(v, w, 1)
    # ring layout: slot i holds token t ≡ i (mod w) — matches decode_step
    idx = jnp.arange(S - w, S)
    slots = jnp.mod(idx, w)
    kw = jnp.zeros((k.shape[0], w) + k.shape[2:], k.dtype).at[:, slots].set(
        k[:, idx])
    vw = jnp.zeros_like(kw).at[:, slots].set(v[:, idx])
    return kw, vw


def prefill(cfg: ModelConfig, params: dict, batch: dict, max_len: int):
    """Run the full prompt, returning (last-position logits, primed cache)."""
    x = embed(cfg, params, batch)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    seg = params["segments"]
    cache = init_cache(cfg, B, max_len)
    cache["length"] = jnp.asarray(S, jnp.int32)
    pad_kv = lambda kv: (_pad_to(kv[0], max_len, 1), _pad_to(kv[1], max_len, 1))

    if cfg.family in ("dense", "moe", "audio") and not cfg.local_per_global:
        def body(x, lp):
            x, _, kv = _attn_block(cfg, lp, x, positions, collect_kv=True)
            return _constrain_act(x), pad_kv(kv)
        x, kvs = jax.lax.scan(body, x, seg["unit"])
        cache["k"], cache["v"] = kvs

    elif cfg.local_per_global:
        n_units, n_tail = gemma_units(cfg)
        w = _win(cfg, max_len)
        thetas = (cfg.rope_theta, cfg.rope_theta_global or cfg.rope_theta)

        def unit_body(x, up):
            def loc(x, lp):
                x, _, kv = _attn_block(cfg, lp, x, positions,
                                       window=cfg.sliding_window,
                                       theta=thetas[0], collect_kv=True)
                return _constrain_act(x), _window_tail(kv, w)
            x, lkv = jax.lax.scan(loc, x, up["local"])
            x, _, gkv = _attn_block(cfg, up["global"], x, positions,
                                    theta=thetas[1], collect_kv=True)
            return _constrain_act(x), (lkv, pad_kv(gkv))
        x, (lkvs, gkvs) = jax.lax.scan(unit_body, x, seg["unit"])
        cache["local_k"], cache["local_v"] = lkvs
        cache["global_k"], cache["global_v"] = gkvs
        if n_tail:
            def tail_body(x, lp):
                x, _, kv = _attn_block(cfg, lp, x, positions,
                                       window=cfg.sliding_window,
                                       theta=thetas[0], collect_kv=True)
                return _constrain_act(x), _window_tail(kv, w)
            x, tkvs = jax.lax.scan(tail_body, x, seg["tail"])
            cache["tail_k"], cache["tail_v"] = tkvs

    elif cfg.family == "vlm":
        img = batch["img_embeds"].astype(x.dtype)

        def unit_body(x, up):
            def one_self(x, lp):
                x, _, kv = _attn_block(cfg, lp, x, positions, collect_kv=True)
                return _constrain_act(x), pad_kv(kv)
            x, skv = jax.lax.scan(one_self, x, up["self"])
            ckv = _img_kv(cfg, up["cross"], img)
            x = _cross_block_cached(cfg, up["cross"], x, ckv)
            return _constrain_act(x), (skv, ckv)
        x, (skvs, ckvs) = jax.lax.scan(unit_body, x, seg["unit"])
        cache["self_k"], cache["self_v"] = skvs
        cache["cross_k"], cache["cross_v"] = ckvs

    elif cfg.family == "ssm":
        _, norm = _norm_fns(cfg)
        x = norm(params["ln0"], x)

        def body(x, lp):
            x, st = _rwkv_block(cfg, lp, x, None)
            return _constrain_act(x), st
        x, sts = jax.lax.scan(body, x, seg["unit"])
        cache["wkv"], cache["shift_tm"], cache["shift_cm"] = (
            sts.wkv, sts.shift_tm, sts.shift_cm)

    elif cfg.family == "hybrid":
        x0 = x
        cache["x0"] = x0[:, -1, :]

        def unit_body(x, up):
            def one_mamba(x, lp):
                x, st = _mamba_block(cfg, lp, x, None)
                return _constrain_act(x), st
            x, msts = jax.lax.scan(one_mamba, x, up["mamba"])
            x, kv = _shared_block(cfg, params["shared"], x, x0, positions,
                                  collect_kv=True)
            return _constrain_act(x), (msts, pad_kv(kv))
        x, (msts, skvs) = jax.lax.scan(unit_body, x, seg["unit"])
        cache["ssm"], cache["conv"] = msts.ssm, msts.conv
        cache["shared_k"], cache["shared_v"] = skvs
        if "tail" in seg:
            def tail_body(x, lp):
                x, st = _mamba_block(cfg, lp, x, None)
                return _constrain_act(x), st
            x, tsts = jax.lax.scan(tail_body, x, seg["tail"])
            cache["tail_ssm"], cache["tail_conv"] = tsts.ssm, tsts.conv
    else:
        raise ValueError(cfg.family)

    _, norm = _norm_fns(cfg)
    hidden = norm(params["final_norm"], x)
    return logits_last(cfg, params, hidden), cache


# ---------------------------------------------------------------------------
# decode (one token)
# ---------------------------------------------------------------------------

def _dec_attn(cfg: ModelConfig, p, x, pos, k, v, length, *, window=0,
              theta=0.0, d_model=0):
    acfg = _attn_cfg(cfg, window=window, theta=theta, d_model=d_model)
    _, norm = _norm_fns(cfg)
    kvc = attention.KVCache(k=k, v=v, length=length)
    a, kvc = attention.decode_step(p["attn"], acfg, norm(p["ln1"], x), pos, kvc)
    x = x + a
    h = norm(p["ln2"], x)
    if "moe" in p:
        f, _ = moe_mod.moe(p["moe"], h, cfg.experts_per_tok,
                           cfg.capacity_factor, cfg.act,
                           dispatch=cfg.moe_dispatch)
    else:
        f = mlp_mod.mlp(p["mlp"], h, cfg.act)
    return x + f, kvc.k, kvc.v


def decode_step(cfg: ModelConfig, params: dict, cache: dict, batch: dict):
    """One-token step.  batch: {"tokens": (B,1)} or {"frames": (B,1,d)}.
    Returns ((B, V) logits, updated cache)."""
    length = cache["length"]
    x = embed(cfg, params, dict(batch, positions=None))
    B = x.shape[0]
    pos = jnp.broadcast_to(length[None, None], (B, 1)).astype(jnp.int32)
    if cfg.pos == "sinusoidal":                     # embed() used position 0
        x = x - layers.sinusoidal_positions(jnp.zeros((B, 1), jnp.int32),
                                            cfg.d_model).astype(x.dtype)
        x = x + layers.sinusoidal_positions(pos, cfg.d_model).astype(x.dtype)
    seg = params["segments"]
    new = dict(cache)

    if cfg.family in ("dense", "moe", "audio") and not cfg.local_per_global:
        def body(x, inp):
            lp, k, v = inp
            x, k, v = _dec_attn(cfg, lp, x, pos, k, v, length)
            return x, (k, v)
        x, (ks, vs) = jax.lax.scan(body, x, (seg["unit"], cache["k"],
                                             cache["v"]))
        new["k"], new["v"] = ks, vs

    elif cfg.local_per_global:
        thetas = (cfg.rope_theta, cfg.rope_theta_global or cfg.rope_theta)

        def unit_body(x, inp):
            up, lk, lv, gk, gv = inp
            def loc(x, i2):
                lp, k, v = i2
                x, k, v = _dec_attn(cfg, lp, x, pos, k, v, length,
                                    window=cfg.sliding_window, theta=thetas[0])
                return x, (k, v)
            x, (lk, lv) = jax.lax.scan(loc, x, (up["local"], lk, lv))
            x, gk, gv = _dec_attn(cfg, up["global"], x, pos, gk, gv, length,
                                  theta=thetas[1])
            return x, (lk, lv, gk, gv)
        x, (lk, lv, gk, gv) = jax.lax.scan(
            unit_body, x, (seg["unit"], cache["local_k"], cache["local_v"],
                           cache["global_k"], cache["global_v"]))
        new["local_k"], new["local_v"] = lk, lv
        new["global_k"], new["global_v"] = gk, gv
        if "tail" in seg:
            def tail(x, i2):
                lp, k, v = i2
                x, k, v = _dec_attn(cfg, lp, x, pos, k, v, length,
                                    window=cfg.sliding_window, theta=thetas[0])
                return x, (k, v)
            x, (tk, tv) = jax.lax.scan(tail, x, (seg["tail"], cache["tail_k"],
                                                 cache["tail_v"]))
            new["tail_k"], new["tail_v"] = tk, tv

    elif cfg.family == "vlm":
        def unit_body(x, inp):
            up, sk, sv, ck, cv = inp
            def one_self(x, i2):
                lp, k, v = i2
                x, k, v = _dec_attn(cfg, lp, x, pos, k, v, length)
                return x, (k, v)
            x, (sk, sv) = jax.lax.scan(one_self, x, (up["self"], sk, sv))
            x = _cross_block_cached(cfg, up["cross"], x, (ck, cv))
            return x, (sk, sv)
        x, (sk, sv) = jax.lax.scan(
            unit_body, x, (seg["unit"], cache["self_k"], cache["self_v"],
                           cache["cross_k"], cache["cross_v"]))
        new["self_k"], new["self_v"] = sk, sv

    elif cfg.family == "ssm":
        _, norm = _norm_fns(cfg)
        x = norm(params["ln0"], x)

        def body(x, inp):
            lp, wkv, stm, scm = inp
            o, sh_tm, wkv = rwkv6.time_mix_decode(
                lp["tmix"], norm(lp["ln1"], x), stm, wkv, cfg.rwkv_head_dim)
            x = x + o
            o, sh_cm = rwkv6.channel_mix(lp["cmix"], norm(lp["ln2"], x), scm)
            x = x + o
            return x, (wkv, sh_tm, sh_cm)
        x, (wkv, stm, scm) = jax.lax.scan(
            body, x, (seg["unit"], cache["wkv"], cache["shift_tm"],
                      cache["shift_cm"]))
        new["wkv"], new["shift_tm"], new["shift_cm"] = wkv, stm, scm

    elif cfg.family == "hybrid":
        _, norm = _norm_fns(cfg)
        x0 = x[:, 0, :]                      # current token's embedding
        new["x0"] = x0
        x0b = x0[:, None, :]

        def mamba_dec(x, i2):
            lp, ssm, conv = i2
            o, st = mamba2.mamba_decode(
                lp["mamba"], norm(lp["ln"], x),
                mamba2.MambaState(ssm=ssm, conv=conv), cfg.d_model,
                cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_expand)
            return x + o, (st.ssm, st.conv)

        def unit_body(x, inp):
            up, ssm, conv, sk, sv = inp
            x, (ssm, conv) = jax.lax.scan(mamba_dec, x,
                                          (up["mamba"], ssm, conv))
            # shared block decode (width 2d) against its KV cache
            d2 = 2 * cfg.d_model
            acfg = _attn_cfg(cfg, d_model=d2)
            h2 = jnp.concatenate([x, x0b], axis=-1)
            kvc = attention.KVCache(k=sk, v=sv, length=length)
            a, kvc = attention.decode_step(
                params["shared"]["attn"], acfg,
                norm(params["shared"]["ln1"], h2), pos, kvc)
            h2 = h2 + a
            h2 = h2 + mlp_mod.mlp(params["shared"]["mlp"],
                                  norm(params["shared"]["ln2"], h2), cfg.act)
            x = x + jnp.einsum(
                "bse,ed->bsd", h2,
                params["shared"]["shared_proj"].astype(x.dtype))
            return x, (ssm, conv, kvc.k, kvc.v)
        x, (ssm, conv, sk, sv) = jax.lax.scan(
            unit_body, x, (seg["unit"], cache["ssm"], cache["conv"],
                           cache["shared_k"], cache["shared_v"]))
        new["ssm"], new["conv"] = ssm, conv
        new["shared_k"], new["shared_v"] = sk, sv
        if "tail" in seg:
            x, (tssm, tconv) = jax.lax.scan(
                mamba_dec, x, (seg["tail"], cache["tail_ssm"],
                               cache["tail_conv"]))
            new["tail_ssm"], new["tail_conv"] = tssm, tconv
    else:
        raise ValueError(cfg.family)

    _, norm = _norm_fns(cfg)
    hidden = norm(params["final_norm"], x)
    new["length"] = length + 1
    return logits_last(cfg, params, hidden), new
