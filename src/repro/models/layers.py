"""Shared model building blocks: norms, positions, parameter init helpers.

Parameters are plain nested dicts of jnp arrays.  Sharding is attached by
``repro/distributed/sharding.py`` which walks the same tree and assigns a
PartitionSpec per leaf from its path (MaxText-style logical rules).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, param_dtype, in_axis: int = 0) -> jnp.ndarray:
    """Truncated-normal fan-in init (LeCun-style)."""
    fan_in = 1
    if isinstance(in_axis, int):
        fan_in = shape[in_axis]
    else:
        for a in in_axis:
            fan_in *= shape[a]
    std = 1.0 / jnp.sqrt(jnp.asarray(float(fan_in)))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(param_dtype)


def embed_init(key, shape, param_dtype) -> jnp.ndarray:
    """(V, d) embedding, std 1/√d — unit-scale activations after the
    √d multiplier used by tied/gemma archs, and sane tied-head logits."""
    std = shape[-1] ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(param_dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_params(d, param_dtype):
    return {"scale": jnp.zeros((d,), param_dtype)}


def rmsnorm(params, x, eps: float = 1e-6, gemma_style: bool = True):
    """RMSNorm with (1 + w) scale (zeros-init), computed in f32."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = params["scale"].astype(jnp.float32)
    y = y * (1.0 + w) if gemma_style else y * w
    return y.astype(dt)


def layernorm_params(d, param_dtype):
    return {"scale": jnp.ones((d,), param_dtype),
            "bias": jnp.zeros((d,), param_dtype)}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dt)


def make_norm(kind: str):
    if kind == "rmsnorm":
        return rmsnorm_params, lambda p, x, eps=1e-6: rmsnorm(p, x, eps)
    if kind == "layernorm":
        return layernorm_params, lambda p, x, eps=1e-5: layernorm(p, x, eps)
    raise ValueError(kind)


def groupnorm_heads(x, scale, bias, eps: float = 64e-5):
    """Per-head GroupNorm over the channel dim (RWKV6 ln_x): x (..., H, D)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# positions
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) int32."""
    dt = x.dtype
    freqs = rope_freqs(x.shape[-1], theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs       # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(dt)


def sinusoidal_positions(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """positions (B, S) → (B, S, d) classic transformer sin/cos table."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# activation
# ---------------------------------------------------------------------------

def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu, "sqrelu": lambda x: jnp.square(jax.nn.relu(x)),
            }[name]
