"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

Dispatch is MegaBlocks-style: the (token × k) expert assignments are sorted
by expert id and scattered into a per-expert capacity buffer (E, C, d), so
the expert GEMMs are dense einsums over contiguous buffers — no (tokens, E,
C) one-hot tensors.  With experts sharded over the 'model' axis (EP), XLA
SPMD turns the scatter/gather into the expected all-to-alls.

Tokens beyond capacity are dropped (pass-through residual), matching
capacity-factor MoE training practice; C = ceil(tokens·k/E · capacity_factor).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


def init_moe_params(key, d_model: int, d_ff: int, n_experts: int, glu: bool,
                    param_dtype) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "router": layers.dense_init(ks[0], (d_model, n_experts), param_dtype),
        "wi": layers.dense_init(ks[1], (n_experts, d_model, d_ff), param_dtype,
                                in_axis=1),
        "wo": layers.dense_init(ks[2], (n_experts, d_ff, d_model), param_dtype,
                                in_axis=1),
    }
    if glu:
        p["wg"] = layers.dense_init(ks[3], (n_experts, d_model, d_ff),
                                    param_dtype, in_axis=1)
    return p


def moe(p: dict, x: jnp.ndarray, n_experts_per_tok: int,
        capacity_factor: float = 1.25, act: str = "silu",
        dispatch: str = "global") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) → (out, aux_loss).  Load-balance aux loss is Switch-style.

    dispatch:
      "global"  — one argsort over all B·S·k assignments (baseline).  Under
                  SPMD with tokens dp-sharded, XLA lowers the global sort to
                  a collective-permute sorting network and all-reduces the
                  dp-partial expert buffers — the dominant collective cost of
                  every MoE train cell (EXPERIMENTS §Perf iteration 2).
      "rowwise" — sort/capacity per sequence row: the sort vmaps over the
                  sharded batch dim (zero collectives), expert buffers get a
                  per-row capacity C_b = ⌈S·k/E·cf⌉, expert GEMMs stay
                  EP-local; only the (B, E, C_b, d) combine crosses the
                  model axis.  Trade-off: capacity is enforced per
                  (row, expert) — marginally more dropping under skewed
                  routing (same spirit as grouped/hierarchical capacity).
    """
    if dispatch == "rowwise":
        return moe_rowwise(p, x, n_experts_per_tok, capacity_factor, act)
    B, S, d = x.shape
    dt = x.dtype
    E = p["router"].shape[1]
    k = n_experts_per_tok
    T = B * S
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)             # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)            # renormalize

    # Switch-style load-balancing auxiliary loss
    density = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux_loss = E * jnp.sum(density * density_proxy)

    # ---- sort-based dispatch -------------------------------------------------
    C = max(1, int((T * k) / E * capacity_factor + 0.999))
    flat_e = expert_idx.reshape(T * k)                          # (Tk,)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    flat_gate = gate_vals.reshape(T * k)

    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    gate_sorted = flat_gate[order]
    # position within the expert's segment
    seg_start = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
    pos_in_e = jnp.arange(T * k) - seg_start[e_sorted]
    keep = pos_in_e < C
    slot = jnp.where(keep, e_sorted * C + pos_in_e, E * C)      # overflow slot

    buf = jnp.zeros((E * C + 1, d), dt).at[slot].set(xt[tok_sorted])
    hidden = buf[:E * C].reshape(E, C, d)

    fn = layers.activation(act)
    h = jnp.einsum("ecd,edf->ecf", hidden, p["wi"].astype(dt))
    if "wg" in p:
        g = jnp.einsum("ecd,edf->ecf", hidden, p["wg"].astype(dt))
        h = fn(g) * h
    else:
        h = fn(h)
    out_e = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))   # (E, C, d)

    out_flat = out_e.reshape(E * C, d)
    gathered = jnp.where(keep[:, None],
                         out_flat[jnp.clip(slot, 0, E * C - 1)], 0.0)
    weighted = gathered * gate_sorted[:, None].astype(dt)
    out = jnp.zeros((T, d), dt).at[tok_sorted].add(weighted)
    return out.reshape(B, S, d), aux_loss.astype(jnp.float32)


def moe_rowwise(p: dict, x: jnp.ndarray, n_experts_per_tok: int,
                capacity_factor: float = 1.25, act: str = "silu"
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row-local dispatch (see ``moe`` docstring) — §Perf iteration 2.

    Shardings are PINNED through the dispatch: XLA's propagation otherwise
    re-shards the per-row sort across the whole mesh and rebuilds the global
    sorting network this path exists to avoid (measured in §Perf iter 2.4)."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as shd

    B, S, d = x.shape
    dt = x.dtype
    E = p["router"].shape[1]
    k = n_experts_per_tok
    C = max(1, int(S * k / E * capacity_factor + 0.999))     # per-row capacity

    mesh = shd.get_mesh()
    if mesh is not None:
        dp = shd.dp_axes(mesh)
        row = lambda t: shd.constrain(t, P(dp, *([None] * (t.ndim - 1))))
        ep = lambda t: shd.constrain(t, P(dp, "model", *([None] * (t.ndim - 2))))
    else:
        row = ep = lambda t: t

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                  # (B, S, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    density = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], E), axis=(0, 1))
    density_proxy = jnp.mean(probs, axis=(0, 1))
    aux_loss = E * jnp.sum(density * density_proxy)

    # ---- per-row sort dispatch (vmapped over the sharded batch dim) --------
    flat_e = row(expert_idx.reshape(B, S * k))
    flat_tok = jnp.broadcast_to(jnp.repeat(jnp.arange(S), k)[None],
                                (B, S * k))
    flat_gate = row(gate_vals.reshape(B, S * k))

    order = row(jnp.argsort(flat_e, axis=1, stable=True))    # row-local sort
    e_sorted = row(jnp.take_along_axis(flat_e, order, axis=1))
    tok_sorted = row(jnp.take_along_axis(flat_tok, order, axis=1))
    gate_sorted = row(jnp.take_along_axis(flat_gate, order, axis=1))
    seg_start = jax.vmap(
        lambda es: jnp.searchsorted(es, jnp.arange(E), side="left"))(e_sorted)
    pos_in_e = jnp.arange(S * k)[None] - jnp.take_along_axis(
        seg_start, e_sorted, axis=1)
    keep = row(pos_in_e < C)
    slot = row(jnp.where(keep, e_sorted * C + pos_in_e, E * C))

    def scatter_row(xr, tok, sl):
        return jnp.zeros((E * C + 1, d), dt).at[sl].set(xr[tok])[:E * C]
    buf = row(jax.vmap(scatter_row)(x, tok_sorted, slot))    # (B, E·C, d)
    hidden = ep(buf.reshape(B, E, C, d))                     # EP re-shard

    fn = layers.activation(act)
    h = ep(jnp.einsum("becd,edf->becf", hidden, p["wi"].astype(dt)))
    if "wg" in p:
        g = jnp.einsum("becd,edf->becf", hidden, p["wg"].astype(dt))
        h = ep(fn(g) * h)
    else:
        h = ep(fn(h))
    out_e = ep(jnp.einsum("becf,efd->becd", h, p["wo"].astype(dt)))

    def gather_row(oe, sl, tok, gv, kp):
        flat = oe.reshape(E * C, d)
        got = jnp.where(kp[:, None], flat[jnp.clip(sl, 0, E * C - 1)], 0.0)
        return jnp.zeros((S, d), dt).at[tok].add(got * gv[:, None].astype(dt))
    out = row(jax.vmap(gather_row)(out_e, slot, tok_sorted, gate_sorted,
                                   keep))
    return out, aux_loss.astype(jnp.float32)
