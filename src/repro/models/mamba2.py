"""Mamba-2 (SSD, arXiv:2405.21060) — chunked state-space duality form.

The selective-SSM recurrence (per head, A scalar)
    h_t = e^{dt_t·A}·h_{t−1} + dt_t·B_t ⊗ x_t ,   y_t = C_t·h_t + D·x_t
is evaluated chunk-parallel: within a chunk the (c × c) decay kernel
L[t,j] = e^{cumA_t − cumA_j} (j ≤ t, always ≤ 1 — unconditionally stable
exponents, unlike RWKV's per-channel decays) turns the recurrence into two
matmuls; across chunks a scan carries the (H, N, P) state.  This is the
attention-free mixer of the zamba2-7b hybrid; decode is O(1)-state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers

CHUNK = 64
CONV_K = 4


def init_mamba_params(key, d_model: int, d_state: int, head_dim: int = 64,
                      expand: int = 2, param_dtype=jnp.float32) -> dict:
    d_inner = expand * d_model
    H = d_inner // head_dim
    G = 1                                    # single B/C group
    conv_dim = d_inner + 2 * G * d_state
    ks = jax.random.split(key, 4)
    return {
        "in_proj": layers.dense_init(
            ks[0], (d_model, 2 * d_inner + 2 * G * d_state + H), param_dtype),
        "conv_w": layers.dense_init(ks[1], (CONV_K, conv_dim), param_dtype),
        "conv_b": jnp.zeros((conv_dim,), param_dtype),
        "A_log": jnp.zeros((H,), param_dtype),        # A = −exp(A_log) = −1 init
        "D": jnp.ones((H,), param_dtype),
        "dt_bias": jnp.zeros((H,), param_dtype),
        "norm_scale": jnp.ones((d_inner,), param_dtype),
        "out_proj": layers.dense_init(ks[2], (d_inner, d_model), param_dtype),
    }


class MambaState(NamedTuple):
    ssm: jnp.ndarray        # (B, H, N, P) f32
    conv: jnp.ndarray       # (B, CONV_K−1, conv_dim) ring of last inputs


def init_mamba_state(batch: int, d_model: int, d_state: int,
                     head_dim: int = 64, expand: int = 2,
                     dtype=jnp.bfloat16) -> MambaState:
    d_inner = expand * d_model
    H = d_inner // head_dim
    conv_dim = d_inner + 2 * d_state
    return MambaState(
        ssm=jnp.zeros((batch, H, d_state, head_dim), jnp.float32),
        conv=jnp.zeros((batch, CONV_K - 1, conv_dim), dtype))


def _split_proj(p, x, d_model, d_state, head_dim, expand):
    d_inner = expand * d_model
    H = d_inner // head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * d_state], axis=-1)
    return z, xbc, dt, d_inner, H


def _causal_conv(p, xbc, prev=None):
    """Depthwise causal conv, k=4.  prev: (B, k−1, C) history for decode."""
    dt = xbc.dtype
    w = p["conv_w"].astype(dt)                         # (K, C)
    if prev is None:
        pad = jnp.zeros((xbc.shape[0], CONV_K - 1, xbc.shape[-1]), dt)
    else:
        pad = prev
    xp = jnp.concatenate([pad, xbc], axis=1)           # (B, S+K−1, C)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(CONV_K))
    return jax.nn.silu(out + p["conv_b"].astype(dt)), xp[:, -(CONV_K - 1):]


def ssd_chunked(x, dt_h, A, Bm, Cm, state):
    """x (B,S,H,P); dt_h (B,S,H) post-softplus; A (H,)≤0 log-decay rate;
    Bm/Cm (B,S,N); state (B,H,N,P) f32.  Returns (y, new_state).

    ``named_scope("ssd_tile")``: tile traffic attributed for the roofline's
    kernelized memory term (a Pallas SSD kernel keeps the (c×c) decay tile
    and state in VMEM — same structure as kernels/rwkv6_wkv.py)."""
    with jax.named_scope("ssd_tile"):
        return _ssd_chunked_impl(x, dt_h, A, Bm, Cm, state)


def _ssd_chunked_impl(x, dt_h, A, Bm, Cm, state):
    Bsz, S, H, Pd = x.shape
    N = Bm.shape[-1]
    assert S % CHUNK == 0
    nc = S // CHUNK
    dt = x.dtype

    xc = x.reshape(Bsz, nc, CHUNK, H, Pd).swapaxes(0, 1)
    dtc = dt_h.reshape(Bsz, nc, CHUNK, H).swapaxes(0, 1)
    Bc = Bm.reshape(Bsz, nc, CHUNK, N).swapaxes(0, 1)
    Cc = Cm.reshape(Bsz, nc, CHUNK, N).swapaxes(0, 1)

    def body(h, inp):
        xx, dd, BB, CC = inp                            # (B,c,H,P),(B,c,H),(B,c,N)
        xx32 = xx.astype(jnp.float32)
        dd32 = dd.astype(jnp.float32)
        BB32 = BB.astype(jnp.float32)
        CC32 = CC.astype(jnp.float32)
        dA = dd32 * A[None, None, :]                    # (B,c,H) ≤ 0
        cumA = jnp.cumsum(dA, axis=1)                   # inclusive
        # decay kernel L[t,j] = e^{cumA_t − cumA_j}, j ≤ t (≤ 1 always)
        L = jnp.exp(cumA[:, :, None, :] - cumA[:, None, :, :])   # (B,c,c,H)
        tri = jnp.tril(jnp.ones((CHUNK, CHUNK), bool))
        L = jnp.where(tri[None, :, :, None], L, 0.0)
        # scores (C_t · B_j) shared across heads (G=1)
        G_tj = jnp.einsum("btn,bjn->btj", CC32, BB32)   # (B,c,c)
        M = G_tj[..., None] * L                         # (B,c,c,H)
        y = jnp.einsum("btjh,bjh,bjhp->bthp", M, dd32, xx32)
        # inter-chunk: y += C_t · e^{cumA_t} · h
        decay_in = jnp.exp(cumA)                        # (B,c,H)
        y = y + jnp.einsum("btn,bth,bhnp->bthp", CC32, decay_in, h)
        # state: h' = e^{cumA_last}·h + Σ_j e^{cumA_last−cumA_j}·dt_j·B_j ⊗ x_j
        decay_out = jnp.exp(cumA[:, -1:, :] - cumA)     # (B,c,H) ≤ 1
        h_new = (jnp.exp(cumA[:, -1])[:, :, None, None] * h
                 + jnp.einsum("bjn,bjh,bjhp->bhnp", BB32, decay_out * dd32, xx32))
        return h_new, y.astype(dt)

    state, y = jax.lax.scan(body, state, (xc, dtc, Bc, Cc))
    return y.swapaxes(0, 1).reshape(Bsz, S, H, Pd), state


def mamba_layer(p: dict, x: jnp.ndarray, d_model: int, d_state: int,
                head_dim: int = 64, expand: int = 2,
                state: MambaState | None = None):
    """Full-sequence Mamba2 mixer.  x (B,S,d) → (y, new_state)."""
    B_, S, _ = x.shape
    z, xbc, dtp, d_inner, H = _split_proj(p, x, d_model, d_state, head_dim, expand)
    conv_prev = state.conv if state is not None else None
    xbc, conv_tail = _causal_conv(p, xbc, conv_prev)
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)
    xs = xs.reshape(B_, S, H, head_dim)
    dt_h = jax.nn.softplus(dtp.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    pad = (-S) % CHUNK
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_h = jnp.pad(dt_h, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    ssm0 = state.ssm if state is not None else jnp.zeros(
        (B_, H, d_state, head_dim), jnp.float32)
    y, ssm = ssd_chunked(xs, dt_h, A, Bm, Cm, ssm0)
    y = y[:, :S] + p["D"].astype(y.dtype)[None, None, :, None] * xs[:, :S]
    y = y.reshape(B_, S, d_inner)
    # gated RMSNorm (Mamba2's norm(y)·silu(z), zeros-free scale=ones init)
    y = layers.rmsnorm({"scale": p["norm_scale"] - 1.0}, y) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    new_state = MambaState(ssm=ssm, conv=conv_tail)
    return out, new_state


def mamba_decode(p: dict, x: jnp.ndarray, state: MambaState, d_model: int,
                 d_state: int, head_dim: int = 64, expand: int = 2):
    """One-token recurrence.  x (B,1,d)."""
    B_ = x.shape[0]
    z, xbc, dtp, d_inner, H = _split_proj(p, x, d_model, d_state, head_dim, expand)
    xbc, conv_tail = _causal_conv(p, xbc, state.conv)
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)
    xs32 = xs.reshape(B_, H, head_dim).astype(jnp.float32)
    dt_h = jax.nn.softplus(dtp.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))[:, 0]   # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt_h * A[None, :])                                    # (B,H)
    B32 = Bm[:, 0].astype(jnp.float32)                                 # (B,N)
    C32 = Cm[:, 0].astype(jnp.float32)
    dBx = jnp.einsum("bn,bh,bhp->bhnp", B32, dt_h, xs32)
    h_new = dA[:, :, None, None] * state.ssm + dBx
    y = jnp.einsum("bn,bhnp->bhp", C32, h_new)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xs32
    y = y.reshape(B_, 1, d_inner).astype(x.dtype)
    y = layers.rmsnorm({"scale": p["norm_scale"] - 1.0}, y) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, MambaState(ssm=h_new, conv=conv_tail)
