"""Memory-linear (flash) attention for the XLA path — custom-VJP, pure JAX.

The baseline attention materializes per-q-chunk probability tensors and the
backward pass of the chunk loop SAVES them (15 GB/device on the qwen2
train_4k cell — EXPERIMENTS §Roofline).  This implementation never stores
probabilities:

  forward : online-softmax over KV blocks (running m/l/acc in the scan
            carry), returns O and the per-row stats (m, l);
  backward: two blockwise passes that RECOMPUTE p from (m, l) —
            pass 1: dQ over q-blocks × kv-blocks,
            pass 2: dK/dV over kv-blocks × q-blocks —
            so the transient working set is one (bq × bkv) tile.

This is the same algorithm as kernels/flash_attention.py (the Pallas TPU
kernel); XLA fuses each tile body into a handful of kernels.  Masking is
index-based (causal / sliding window over token order), which matches every
training/prefill call site (positions are arange).  GQA folds the group
dim into q rows.

§Perf iteration 1 measured on qwen2-0.5b train_4k (single-pod):
memory 38.6 s → see EXPERIMENTS §Perf; probs no longer saved.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG = -1e30


def _blk(x, i, size, axis):
    return jax.lax.dynamic_slice_in_dim(x, i * size, size, axis=axis)


def _mask(qi, ki, bq, bkv, causal, window, skv_valid):
    q_ids = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    k_ids = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    m = k_ids < skv_valid                       # KV padding
    if causal:
        m &= k_ids <= q_ids
    if window > 0:
        m &= k_ids > q_ids - window
    return m


def _kv_block_ids(qi, bq, bkv, nkv, window):
    """KV block indices a q block must visit.  window > 0 ⇒ a STATIC-length
    range ending at the q block's diagonal (O(S·W) total tiles instead of
    O(S²) — the gemma sliding-window win, §Perf iteration 1.3).  Returns
    (ids, valid) — invalid slots are gated off (never double-counted)."""
    if window <= 0:
        return jnp.arange(nkv), jnp.ones((nkv,), jnp.bool_)
    n_need = min(nkv, -(-(window + bq) // bkv) + 1)
    last = (qi * bq + bq - 1) // bkv                    # diagonal block
    ids = last - (n_need - 1) + jnp.arange(n_need)
    valid = (ids >= 0) & (ids < nkv)
    return jnp.clip(ids, 0, nkv - 1), valid


def _fwd_qblock(q_b, k, v, qi, *, bq, bkv, causal, window, skv_valid, scale):
    """Online softmax of one q block against its kv-block range.
    q_b (B,bq,G,D) f32 where G folds (Hk, rep);  k/v (B,nkv*bkv,Hk,D)."""
    B, _, G, D = q_b.shape
    Hk = k.shape[2]
    rep = G // Hk
    nkv = k.shape[1] // bkv

    def body(carry, kiv):
        ki, ok = kiv
        m_r, l_r, acc = carry
        k_b = _blk(k, ki, bkv, 1).astype(jnp.float32)     # (B,bkv,Hk,D)
        v_b = _blk(v, ki, bkv, 1).astype(jnp.float32)
        s = jnp.einsum("bqhrd,bkhd->bqhrk",
                       q_b.reshape(B, bq, Hk, rep, D) * scale, k_b)
        msk = _mask(qi, ki, bq, bkv, causal, window, skv_valid) & ok
        s = jnp.where(msk[None, :, None, None, :], s, NEG)
        s = s.reshape(B, bq, G, bkv)
        m_new = jnp.maximum(m_r, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_r - m_new)
        l_new = l_r * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqhrk,bkhd->bqhrd",
                        p.reshape(B, bq, Hk, rep, bkv), v_b)
        acc = acc * corr[..., None] + pv.reshape(B, bq, G, D)
        return (m_new, l_new, acc), None

    ids, valid = _kv_block_ids(qi, bq, bkv, nkv, window)
    init = (jnp.full((B, bq, G), NEG, jnp.float32),
            jnp.zeros((B, bq, G), jnp.float32),
            jnp.zeros((B, bq, G, D), jnp.float32))
    (m_r, l_r, acc), _ = jax.lax.scan(body, init, (ids, valid))
    l_safe = jnp.maximum(l_r, 1e-30)
    return acc / l_safe[..., None], m_r, l_safe


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_mha(q, k, v, causal: bool = True, window: int = 0,
              bq: int = 512, bkv: int = 512):
    """q (B,S,H,D); k/v (B,Skv,Hk,D) → (B,S,H,D).  Index-order masking."""
    with jax.named_scope("flash_tile"):
        o, _, _ = _flash_fwd_impl(q, k, v, causal, window, bq, bkv)
    return o


def _flash_fwd_impl(q, k, v, causal, window, bq, bkv):
    B, S, H, D = q.shape
    Skv = k.shape[1]
    scale = D ** -0.5
    bq = min(bq, S)
    bkv = min(bkv, Skv)
    pq, pkv = (-S) % bq, (-Skv) % bkv
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0))) if pkv else k
    vp = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0))) if pkv else v
    nq = qp.shape[1] // bq

    def per_qblock(qi):
        q_b = _blk(qp, qi, bq, 1).astype(jnp.float32)
        return _fwd_qblock(q_b, kp, vp, qi, bq=bq, bkv=bkv, causal=causal,
                           window=window, skv_valid=Skv, scale=scale)

    o, m_r, l_r = jax.lax.map(per_qblock, jnp.arange(nq))
    o = jnp.moveaxis(o, 0, 1).reshape(B, nq * bq, H, D)[:, :S]
    m_r = jnp.moveaxis(m_r, 0, 1).reshape(B, nq * bq, H)[:, :S]
    l_r = jnp.moveaxis(l_r, 0, 1).reshape(B, nq * bq, H)[:, :S]
    return o.astype(q.dtype), m_r, l_r


def _flash_fwd(q, k, v, causal, window, bq, bkv):
    with jax.named_scope("flash_tile"):
        o, m_r, l_r = _flash_fwd_impl(q, k, v, causal, window, bq, bkv)
    return o, (q, k, v, o, m_r, l_r)


def _flash_bwd(causal, window, bq, bkv, res, do):
    with jax.named_scope("flash_tile"):
        return _flash_bwd_impl(causal, window, bq, bkv, res, do)


def _flash_bwd_impl(causal, window, bq, bkv, res, do):
    q, k, v, o, m_r, l_r = res
    B, S, H, D = q.shape
    Skv, Hk = k.shape[1], k.shape[2]
    rep = H // Hk
    scale = D ** -0.5
    bq_ = min(bq, S)
    bkv_ = min(bkv, Skv)
    pq, pkv = (-S) % bq_, (-Skv) % bkv_

    pad_q = lambda x: jnp.pad(x, ((0, 0), (0, pq)) + ((0, 0),) * (x.ndim - 2)) \
        if pq else x
    pad_k = lambda x: jnp.pad(x, ((0, 0), (0, pkv)) + ((0, 0),) * (x.ndim - 2)) \
        if pkv else x
    qp, op, dop = map(pad_q, (q, o, do))
    mp, lp = map(pad_q, (m_r, l_r))
    kp, vp = map(pad_k, (k, v))
    nq = qp.shape[1] // bq_
    nkv = kp.shape[1] // bkv_

    # delta_i = Σ_d do_i · o_i   (B,S,H)
    delta = jnp.sum(dop.astype(jnp.float32) * op.astype(jnp.float32), axis=-1)

    def p_tile(q_b, k_b, m_b, l_b, qi, ki, ok=True):
        """Recompute the (bq × bkv) probability tile from saved stats."""
        s = jnp.einsum("bqhrd,bkhd->bqhrk",
                       q_b.reshape(B, bq_, Hk, rep, D) * scale, k_b)
        msk = _mask(qi, ki, bq_, bkv_, causal, window, Skv) & ok
        s = jnp.where(msk[None, :, None, None, :], s, NEG)
        s = s.reshape(B, bq_, H, bkv_)
        return jnp.exp(s - m_b[..., None]) / l_b[..., None]

    # ---- pass 1: dQ (loop q blocks; scan kv blocks) -------------------------
    def dq_block(qi):
        q_b = _blk(qp, qi, bq_, 1).astype(jnp.float32)
        do_b = _blk(dop, qi, bq_, 1).astype(jnp.float32)
        m_b = _blk(mp, qi, bq_, 1)
        l_b = _blk(lp, qi, bq_, 1)
        d_b = _blk(delta, qi, bq_, 1)

        def body(dq_acc, kiv):
            ki, ok = kiv
            k_b = _blk(kp, ki, bkv_, 1).astype(jnp.float32)
            v_b = _blk(vp, ki, bkv_, 1).astype(jnp.float32)
            p = p_tile(q_b, k_b, m_b, l_b, qi, ki, ok)      # (B,bq,H,bkv)
            dp = jnp.einsum("bqhrd,bkhd->bqhrk",
                            do_b.reshape(B, bq_, Hk, rep, D),
                            v_b).reshape(B, bq_, H, bkv_)
            ds = p * (dp - d_b[..., None])                   # (B,bq,H,bkv)
            dq_c = jnp.einsum("bqhrk,bkhd->bqhrd",
                              ds.reshape(B, bq_, Hk, rep, bkv_), k_b)
            return dq_acc + dq_c.reshape(B, bq_, H, D) * scale, None

        ids, valid = _kv_block_ids(qi, bq_, bkv_, nkv, window)
        dq0 = jnp.zeros((B, bq_, H, D), jnp.float32)
        dq_b, _ = jax.lax.scan(body, dq0, (ids, valid))
        return dq_b

    dq = jax.lax.map(dq_block, jnp.arange(nq))
    dq = jnp.moveaxis(dq, 0, 1).reshape(B, nq * bq_, H, D)[:, :S]

    # ---- pass 2: dK, dV (loop kv blocks; scan q blocks) ----------------------
    def _q_block_ids(ki):
        """q blocks that can attend to kv block ki (window-restricted)."""
        if window <= 0:
            return jnp.arange(nq), jnp.ones((nq,), jnp.bool_)
        n_need = min(nq, -(-(window + bkv_) // bq_) + 1)
        first = (ki * bkv_) // bq_
        ids = first + jnp.arange(n_need)
        valid = (ids >= 0) & (ids < nq)
        return jnp.clip(ids, 0, nq - 1), valid

    def dkv_block(ki):
        k_b = _blk(kp, ki, bkv_, 1).astype(jnp.float32)
        v_b = _blk(vp, ki, bkv_, 1).astype(jnp.float32)

        def body(carry, qiv):
            qi, ok = qiv
            dk_acc, dv_acc = carry
            q_b = _blk(qp, qi, bq_, 1).astype(jnp.float32)
            do_b = _blk(dop, qi, bq_, 1).astype(jnp.float32)
            m_b = _blk(mp, qi, bq_, 1)
            l_b = _blk(lp, qi, bq_, 1)
            d_b = _blk(delta, qi, bq_, 1)
            p = p_tile(q_b, k_b, m_b, l_b, qi, ki, ok)
            # dV += Σ_q p · do   (sum over q rows and group reps)
            dv_c = jnp.einsum("bqhrk,bqhrd->bkhd",
                              p.reshape(B, bq_, Hk, rep, bkv_),
                              do_b.reshape(B, bq_, Hk, rep, D))
            dp = jnp.einsum("bqhrd,bkhd->bqhrk",
                            do_b.reshape(B, bq_, Hk, rep, D),
                            v_b).reshape(B, bq_, H, bkv_)
            ds = p * (dp - d_b[..., None])
            dk_c = jnp.einsum("bqhrk,bqhrd->bkhd",
                              ds.reshape(B, bq_, Hk, rep, bkv_),
                              q_b.reshape(B, bq_, Hk, rep, D))
            return (dk_acc + dk_c * scale, dv_acc + dv_c), None

        ids, valid = _q_block_ids(ki)
        z = jnp.zeros((B, bkv_, Hk, D), jnp.float32)
        (dk_b, dv_b), _ = jax.lax.scan(body, (z, z), (ids, valid))
        return dk_b, dv_b

    dk, dv = jax.lax.map(dkv_block, jnp.arange(nkv))
    dk = jnp.moveaxis(dk, 0, 1).reshape(B, nkv * bkv_, Hk, D)[:, :Skv]
    dv = jnp.moveaxis(dv, 0, 1).reshape(B, nkv * bkv_, Hk, D)[:, :Skv]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_mha.defvjp(_flash_fwd, _flash_bwd)


def kernel_hbm_bytes(B, S, Skv, H, Hk, D, bq, dtype_bytes=2):
    """Analytic HBM traffic of the Pallas flash kernel for one layer's
    fwd+bwd (kernels/flash_attention.py design: Q/O streamed once, K/V
    re-streamed per q block, tiles live in VMEM scratch):
      fwd : read Q + n_q·(K+V) + write O
      bwd : 2 passes, each re-reads the same (dq pass re-streams K/V per
            q block; dkv pass re-streams Q/dO per kv block) + dQ/dK/dV."""
    nq = -(-S // bq)
    q_b = B * S * H * D * dtype_bytes
    kv_b = B * Skv * Hk * D * dtype_bytes
    fwd = q_b + nq * 2 * kv_b + q_b
    dq_pass = q_b * 3 + nq * 2 * kv_b + q_b          # q,do,delta + kv + dq
    dkv_pass = 2 * kv_b + nq * (q_b // max(nq, 1)) * 3 + 2 * kv_b
    return fwd + dq_pass + dkv_pass
