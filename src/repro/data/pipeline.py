"""Deterministic, shard-aware synthetic token pipeline with host prefetch.

Design mirrors a production loader:
  * every (step, global_example_index) maps to a unique counter-mode PRNG
    stream — restart-stable, order-independent, resumable from any step
    (the checkpoint stores only ``step``);
  * each data-parallel host materializes only its shard of the global batch
    (``shard_index`` / ``num_shards``), so no host ever holds the global
    batch — the property that matters at 1000+ nodes;
  * a background thread keeps a small prefetch queue ahead of the training
    loop (overlap host data gen with device compute).

Synthetic text is a structured Markov-ish stream (not iid uniform) so that
cross-entropy actually decreases during the example training runs.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np

from repro.configs.base import ModelConfig


class SyntheticTokens:
    def __init__(self, cfg: ModelConfig, seq_len: int, global_batch: int,
                 shard_index: int = 0, num_shards: int = 1, seed: int = 0):
        assert global_batch % num_shards == 0
        self.cfg = cfg
        self.seq = seq_len
        self.local_batch = global_batch // num_shards
        self.shard = shard_index
        self.num_shards = num_shards
        self.seed = seed
        # fixed random "grammar": each token deterministically prefers a
        # successor band — learnable structure for the example runs
        rng = np.random.default_rng(seed ^ 0x5EED)
        self.vocab = min(cfg.vocab, 32_768)
        self._succ = rng.integers(0, self.vocab, size=(self.vocab,),
                                  dtype=np.int64)

    def batch_at(self, step: int) -> dict:
        """The (deterministic) local batch for a global step."""
        B, S = self.local_batch, self.seq
        out = np.empty((B, S + 1), dtype=np.int32)
        for i in range(B):
            g = step * (B * self.num_shards) + self.shard * B + i
            rng = np.random.default_rng((self.seed, g))
            toks = np.empty(S + 1, dtype=np.int64)
            toks[0] = rng.integers(0, self.vocab)
            noise = rng.random(S)
            jumps = rng.integers(0, self.vocab, size=S)
            for t in range(S):
                toks[t + 1] = (self._succ[toks[t]] if noise[t] < 0.8
                               else jumps[t])
            out[i] = toks
        batch = {"tokens": out[:, :-1], "labels": out[:, 1:]}
        if not self.cfg.embed_inputs:                     # audio stub
            rng = np.random.default_rng((self.seed, step, self.shard))
            batch["frames"] = rng.standard_normal(
                (B, S, self.cfg.d_model)).astype(np.float32)
            del batch["tokens"]
        if self.cfg.family == "vlm":
            rng = np.random.default_rng((self.seed, step, self.shard, 7))
            batch["img_embeds"] = rng.standard_normal(
                (B, self.cfg.n_img_tokens, self.cfg.d_model)).astype(np.float32)
        return batch

    def iterate(self, start_step: int = 0,
                prefetch: int = 2) -> Iterator[dict]:
        """Prefetching iterator from ``start_step`` (resume point)."""
        q: queue.Queue = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            s = start_step
            while not stop.is_set():
                try:
                    q.put(self.batch_at(s), timeout=0.5)
                    s += 1
                except queue.Full:
                    continue

        th = threading.Thread(target=producer, daemon=True)
        th.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
