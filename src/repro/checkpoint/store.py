"""Sharded checkpointing: async writer, atomic commit, elastic re-shard.

Layout (one directory per step):
    ckpt_dir/step_000123.tmp/     — being written (never restored from)
    ckpt_dir/step_000123/         — atomically renamed once complete
        manifest.json             — tree structure, shapes, dtypes, step
        <leaf-path>.npy           — one file per pytree leaf

Restore is *elastic*: leaves are loaded as host arrays and re-placed with
``jax.device_put`` under the restoring mesh's shardings — the mesh shape may
differ from the writing run's (scale up/down between runs).  On a multi-host
deployment each host would write only its owned shards (jax
``process_index`` slicing); this container is single-process, so the
writer path is exercised end-to-end with local shards.

Fault-tolerance contract (used by train/trainer.py):
  * writes happen on a background thread — training never blocks on I/O;
  * a crash mid-write leaves only a ``.tmp`` dir, which restore ignores;
  * ``latest_step`` finds the newest committed checkpoint for restart.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            out.update(_flatten(tree[k], f"{prefix}/{k}" if prefix else str(k)))
    elif hasattr(tree, "_fields"):                       # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k),
                                f"{prefix}/{k}" if prefix else str(k)))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/{i}" if prefix else str(i)))
    else:
        out[prefix] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}/{k}" if prefix else str(k))
                for k, v in template.items()}
    if hasattr(template, "_fields"):
        return type(template)(*[
            _unflatten_into(getattr(template, k), flat,
                            f"{prefix}/{k}" if prefix else str(k))
            for k in template._fields])
    if isinstance(template, (tuple, list)):
        return type(template)(
            _unflatten_into(v, flat, f"{prefix}/{i}" if prefix else str(i))
            for i, v in enumerate(template))
    return flat[prefix]


def _leaf_file(path: str) -> str:
    return path.replace("/", "__") + ".npy"


def save(ckpt_dir: str, step: int, tree: Any, *, blocking: bool = True,
         meta: Optional[dict] = None) -> Optional[threading.Thread]:
    """Write a checkpoint; async (returns the writer thread) if not blocking.

    ``meta`` (optional, JSON-serializable) is written as ``meta.json`` INSIDE
    the step directory, so it commits atomically with the array leaves — the
    campaign service stores its allocator map / job table here and can never
    observe arrays without the bookkeeping that interprets them (or vice
    versa) after a crash.
    """
    flat = _flatten(tree)
    # snapshot to host memory synchronously (cheap; device→host copy),
    # so the async writer never races live training buffers
    host = {k: np.asarray(v) for k, v in flat.items()}

    def write():
        tmp = os.path.join(ckpt_dir, f"step_{step:08d}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": {}}
        for k, v in host.items():
            np.save(os.path.join(tmp, _leaf_file(k)), v)
            manifest["leaves"][k] = {"shape": list(v.shape),
                                     "dtype": str(v.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if meta is not None:
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                            # atomic commit

    if blocking:
        write()
        return None
    th = threading.Thread(target=write, daemon=False)
    th.start()
    return th


def load_meta(ckpt_dir: str, step: int) -> Optional[dict]:
    """Read the ``meta.json`` committed with step (None if absent)."""
    p = os.path.join(ckpt_dir, f"step_{step:08d}", "meta.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template: Any,
            shardings: Any = None) -> Any:
    """Load a checkpoint into ``template``'s structure.

    ``shardings`` (optional pytree of NamedShardings matching the template)
    re-places every leaf for the restoring mesh — elastic re-shard.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t = _flatten(template)
    missing = set(flat_t) - set(manifest["leaves"])
    if missing:
        raise ValueError(f"checkpoint at step {step} missing leaves: "
                         f"{sorted(missing)[:5]} ...")
    flat = {}
    flat_sh = _flatten(shardings) if shardings is not None else None
    for k, t in flat_t.items():
        arr = np.load(os.path.join(d, _leaf_file(k)))
        want = tuple(getattr(t, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch for {k}: {arr.shape} vs {want}")
        if flat_sh is not None:
            flat[k] = jax.device_put(arr, flat_sh[k])
        else:
            flat[k] = jax.device_put(arr.astype(
                getattr(t, "dtype", arr.dtype)))
    return _unflatten_into(template, flat)


def prune(ckpt_dir: str, keep: int = 3):
    """Delete all but the newest ``keep`` committed checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(s for s in (latest_candidates(ckpt_dir)))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def latest_candidates(ckpt_dir: str):
    return [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp")]
