"""Pallas TPU kernel: chunked-parallel RWKV-6 WKV recurrence.

    S_t = diag(w_t)·S_{t−1} + k_t ⊗ v_t
    o_t = r_t·(S_{t−1} + diag(u)·k_t ⊗ v_t)

TPU adaptation of the FLA chunked form (models/rwkv6.py is the oracle):
  * grid = (B·H, S/CHUNK) with the chunk axis innermost; the (D × D) f32
    recurrent state lives in VMEM scratch and persists across chunk steps —
    HBM sees one pass over r/k/v/w and one write of o, state never leaves
    VMEM;
  * the intra-chunk pairwise term is one (c × c) MXU contraction of
    decay-weighted q/k tiles; cumulative log-decays are a VPU cumsum;
  * exponent safety: per-token log-decay is clamped in the surrounding
    layer to [−LOG_CLAMP, −1e-6] and CHUNK = 16 keeps every exponential
    ≤ e^{16·5} < f32 max (same bound as the reference — a larger MXU-
    friendlier chunk needs sub-block renormalization; see EXPERIMENTS
    §Perf for the measured trade-off).
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import jax.numpy as jnp

CHUNK = 16


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref, *,
            chunk: int, d: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    rr = r_ref[0].astype(jnp.float32)            # (c, D)
    kk = k_ref[0].astype(jnp.float32)
    vv = v_ref[0].astype(jnp.float32)
    ww = w_ref[0].astype(jnp.float32)            # log-decays ≤ −1e−6
    u = u_ref[0].astype(jnp.float32)             # (1, D) bonus

    Lc = jnp.cumsum(ww, axis=0)                  # Σ_{s≤t}
    Lc_prev = Lc - ww                            # Σ_{s<t}
    Lc_last = Lc[-1:]

    q_t = rr * jnp.exp(Lc_prev)
    k_in = kk * jnp.exp(-Lc)
    A = jax.lax.dot_general(q_t, k_in, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (c, c)
    t_ids = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_ids = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.where(j_ids < t_ids, A, 0.0)         # strict lower triangle
    o = jax.lax.dot_general(A, vv, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # diagonal bonus r_t·(u ⊙ k_t)·v_t
    diag = jnp.sum(rr * u * kk, axis=1, keepdims=True)
    o = o + diag * vv
    # cross-chunk: r_t e^{Lc_prev_t} · S_prev
    o = o + jax.lax.dot_general(q_t, state_ref[...],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0] = o.astype(o_ref.dtype)
    # state: S ← e^{Lc_last} ⊙ S + Σ_j (k_j e^{Lc_last − Lc_j}) ⊗ v_j
    k_out = kk * jnp.exp(Lc_last - Lc)
    state_ref[...] = (jnp.exp(Lc_last).T * state_ref[...]
                      + jax.lax.dot_general(k_out, vv,
                                            (((0,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv6_forward(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 logw: jnp.ndarray, u: jnp.ndarray, *,
                 interpret: bool = False) -> jnp.ndarray:
    """r/k/v (B, S, H, D); logw (B, S, H, D) f32 (clamped ≤ −1e−6); u (H, D).
    Returns o (B, S, H, D).  S must be a multiple of CHUNK (caller pads)."""
    B, S, H, D = r.shape
    assert S % CHUNK == 0
    nc = S // CHUNK

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, D)

    rf, kf, vf, wf = map(fold, (r, k, v, logw))
    uf = jnp.broadcast_to(u[None], (B, H, D)).reshape(B * H, 1, D)

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=CHUNK, d=D),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, CHUNK, D), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, CHUNK, D), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, CHUNK, D), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, CHUNK, D), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, 1, D), lambda h, c: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, CHUNK, D), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), r.dtype),
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
