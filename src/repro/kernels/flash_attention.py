"""Pallas TPU kernel: GQA flash attention (causal / sliding-window / full).

Online-softmax tiling (Dao et al.) adapted to the TPU memory hierarchy:
  * grid = (B·H, S_q/bq, S_kv/bkv) with the KV axis innermost; the running
    (m, l, acc) state lives in VMEM scratch, persisting across KV steps —
    the HBM→VMEM traffic is exactly one pass over Q, K, V and one write of O;
  * the (bq × bkv) logit tile is produced by one MXU contraction
    (jax.lax.dot_general, f32 accumulation), the rescale epilogue runs on
    the VPU;
  * GQA: the kv-head index map folds h → h·Hk/H, so kv tiles for grouped
    query heads hit the same VMEM block (no HBM re-read between group
    members on the same core);
  * causal / sliding-window tiles that are fully masked are skipped with
    ``pl.when`` (no MXU work), matching the O(S·W) sliding-window cost of
    the XLA reference path.

``ops.flash_attention`` routes to this kernel on TPU and to ``ref.py``'s
pure-jnp oracle elsewhere; tests sweep shapes/dtypes in interpret mode.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import jax.numpy as jnp

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bkv: int, n_kv: int, causal: bool, window: int,
            scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bkv

    # block-level skip: in causal mode KV blocks strictly above the diagonal
    # contribute nothing; with a window, KV blocks entirely left of
    # (q_start − window) contribute nothing either.
    relevant = True
    if causal:
        relevant = k_start <= q_start + bq - 1
    if window > 0:
        relevant = jnp.logical_and(
            relevant, k_start + bkv - 1 > q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, d)
        v = v_ref[0].astype(jnp.float32)                  # (bkv, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        k_ids = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            mask = mask & (k_ids <= q_ids)
        if window > 0:
            mask = mask & (k_ids > q_ids - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                            # (bq, bkv)
        corr = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bkv", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    bq: int = 128, bkv: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q (B, S, H, D); k/v (B, S_kv, Hk, D) with H % Hk == 0 → (B, S, H, D)."""
    B, S, H, D = q.shape
    Skv, Hk = k.shape[1], k.shape[2]
    assert H % Hk == 0
    rep = H // Hk
    scale = D ** -0.5

    bq = min(bq, S)
    bkv = min(bkv, Skv)
    pq = (-S) % bq
    pkv = (-Skv) % bkv
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    # pad KV with positions masked out by a window/causal guard; for the
    # full-attention case the pad rows are masked via k_ids >= Skv below —
    # handled by padding K with −inf-producing zeros and masking in-kernel
    kp = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0))) if pkv else k
    vp = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0))) if pkv else v

    Sq_p, Skv_p = S + pq, Skv + pkv
    # fold (B, H): move heads next to batch
    qf = qp.transpose(0, 2, 1, 3).reshape(B * H, Sq_p, D)
    kf = kp.transpose(0, 2, 1, 3).reshape(B * Hk, Skv_p, D)
    vf = vp.transpose(0, 2, 1, 3).reshape(B * Hk, Skv_p, D)

    n_q = Sq_p // bq
    n_kv = Skv_p // bkv

    # KV padding: under a causal mask, pad keys carry k_ids ≥ S_kv > q_ids
    # and are masked out automatically.  The non-causal path (cross-attn)
    # is served by the XLA reference; keep the kernel strict there.
    if pkv > 0 and not causal:
        raise NotImplementedError(
            "non-causal flash kernel requires S_kv % bkv == 0")

    grid = (B * H, n_q, n_kv)
    kernel = functools.partial(
        _kernel, bq=bq, bkv=bkv, n_kv=n_kv, causal=causal, window=window,
        scale=scale)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, D), lambda h, i, j, *, rep=rep:
                         (h // rep, j, 0)),
            pl.BlockSpec((1, bkv, D), lambda h, i, j, *, rep=rep:
                         (h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),       # m
            pltpu.VMEM((bq, 1), jnp.float32),       # l
            pltpu.VMEM((bq, D), jnp.float32),       # acc
        ],
        interpret=interpret,
    )(qf, kf, vf)

    out = out.reshape(B, H, Sq_p, D).transpose(0, 2, 1, 3)[:, :S]
    return out
