"""Jitted wrappers that route each hot-spot op to its Pallas kernel or jnp ref.

``impl`` semantics (used across core/ and models/):
  * ``"xla"``     — pure-jnp reference path (ref.py).  Default on CPU: XLA
                    already lowers these GEMMs well, and Mosaic kernels cannot
                    compile for the CPU backend.
  * ``"pallas"``  — the Pallas kernel, compiled by Mosaic (TPU) or executed in
                    interpret mode elsewhere (correctness-equivalent, slow).
  * ``"auto"``    — "pallas" on TPU backends, "xla" otherwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.cma_sample import cma_sample
from repro.kernels.cma_update import cma_rank_mu_update


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "xla"
    return impl


def sample_transform(B, D, Z, impl: str = "auto"):
    """Y = Z·diag(D)·Bᵀ (lam, n)."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.sample_transform(B, D, Z)
    zero = jnp.zeros((B.shape[0],), Z.dtype)
    one = jnp.ones((), Z.dtype)
    return cma_sample(zero, one, B, D, Z, interpret=not _on_tpu())


def sample_points(m, sigma, B, D, Z, impl: str = "auto"):
    """X = M + σ·B·diag(D)·Z (lam, n) — fused kernel when impl=pallas."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.sample_points(m, sigma, B, D, Z)
    return cma_sample(m, sigma, B, D, Z, interpret=not _on_tpu())


def rank_mu_gram(Y, w, impl: str = "auto"):
    """Σ wᵢ yᵢyᵢᵀ — the paper's rank-λ GEMM (eq. 3)."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.rank_mu_gram(Y, w)
    n = Y.shape[1]
    zeros = jnp.zeros((n, n), Y.dtype)
    zvec = jnp.zeros((n,), Y.dtype)
    return cma_rank_mu_update(zeros, Y, w, zvec, 0.0, 1.0, 0.0,
                              interpret=not _on_tpu())


def covariance_combine(C, gram, p_c, decay, c_mu, c_1, impl: str = "auto"):
    """decay·C + c_μ·gram + c₁·p_c p_cᵀ (cheap epilogue; always jnp).

    The fused path (kernel computing gram+epilogue in one pass) is
    ``rank_mu_update`` below — used when the caller still has Y at hand.
    """
    return ref.covariance_combine(C, gram, p_c, decay, c_mu, c_1)


def rank_mu_update(C, Y, w, p_c, decay, c_mu, c_1, impl: str = "auto"):
    """Fully fused covariance adaptation: one HBM read+write of C."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.rank_mu_update(C, Y, w, p_c, decay, c_mu, c_1)
    return cma_rank_mu_update(C, Y, w, p_c, decay, c_mu, c_1,
                              interpret=not _on_tpu())


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    impl: str = "auto"):
    """GQA flash attention (see kernels/flash_attention.py)."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.flash_attention(q, k, v, causal=causal, window=window)
    from repro.kernels.flash_attention import flash_attention as fa
    return fa(q, k, v, causal=causal, window=window, interpret=not _on_tpu())


def wkv6(r, k, v, logw, u, impl: str = "auto"):
    """Chunked RWKV-6 WKV (see kernels/rwkv6_wkv.py)."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.wkv6(r, k, v, logw, u)
    from repro.kernels.rwkv6_wkv import wkv6_forward
    return wkv6_forward(r, k, v, logw, u, interpret=not _on_tpu())
