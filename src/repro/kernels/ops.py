"""Jitted wrappers that route each hot-spot op to its Pallas kernel or jnp ref.

``impl`` semantics (used across core/ and models/):
  * ``"xla"``     — pure-jnp reference path (ref.py), with the generation
                    step FUSED (ref.fused_gen_update / ref.gen_sample —
                    one gram-family dot per generation).  Default on CPU:
                    XLA already lowers these GEMMs well, and Mosaic kernels
                    cannot compile for the CPU backend.
  * ``"xla_unfused"`` — the pre-PR-4 jnp op soup (separate gram / combine /
                    whiten calls).  Kept as the measured regression baseline
                    (benchmarks/bench_kernels.py) and for trajectory A/B
                    tests; at the per-op level it behaves exactly like
                    ``"xla"``.
  * ``"pallas"``  — the Pallas kernels, compiled by Mosaic (TPU) or executed
                    in interpret mode elsewhere (correctness-equivalent,
                    slow — the interpret path exists for the equivalence
                    tests, not for production CPU runs).
  * ``"pallas_rng"`` — ``"pallas"`` plus in-kernel RNG for the generation
                    sample: Z is drawn inside ``cma_gen_sample_rng`` from a
                    portable threefry2x32 counter stream seeded per slot,
                    so the host-shaped ``fold_in`` stream and the HBM Z
                    operand disappear.  A DIFFERENT (but still
                    counter-based, prefix-stable) stream from the default
                    row-keyed one — trajectories are not comparable across
                    tiers, which is why ``"auto"`` never selects it.  Off
                    TPU (or if the Mosaic probe fails) the sample falls
                    back to the XLA threefry ref — the bit-exact same
                    stream, so the fallback never changes a trajectory.
  * ``"auto"``    — "pallas" on TPU backends, "xla" otherwise.  Never
                    resolves to "pallas_rng": switching the RNG stream is
                    a trajectory-level decision the caller must make
                    explicitly.

``REPRO_KERNEL_IMPL`` (env) overrides the caller's choice globally — handy
for A/B runs of a whole campaign without threading a flag through every
engine config.  It is consulted at TRACE time, so export it before the
first engine call of the process; already-compiled programs keep the impl
they were traced with (tests/conftest.py scrubs it so the suite stays
hermetic).  Unknown values, from either source, raise immediately.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.cma_gen import (COEF_FIELDS, cma_gen_sample,
                                   cma_gen_sample_eval, cma_gen_sample_rng,
                                   cma_gen_sample_rng_eval, cma_gen_update,
                                   cma_sample_z_rng)
from repro.kernels.cma_sample import cma_sample
from repro.kernels.cma_update import cma_rank_mu_update

IMPL_CHOICES = ("auto", "xla", "xla_unfused", "pallas", "pallas_rng")


@functools.lru_cache(maxsize=1)
def _on_tpu() -> bool:
    # cached: jax.default_backend() initializes the backend and takes a
    # platform lock — re-querying it inside every traced op call added
    # measurable per-trace overhead.  The backend cannot change after the
    # first jax computation in a process, so one probe is authoritative.
    return jax.default_backend() == "tpu"


def validate_impl(impl: str) -> str:
    """Membership check without resolution — for config/entry validation."""
    if impl not in IMPL_CHOICES:
        raise ValueError(
            f"unknown impl {impl!r}; expected one of {IMPL_CHOICES}")
    return impl


def resolve_impl(impl: str) -> str:
    validate_impl(impl)             # caller typos raise even under override
    env = os.environ.get("REPRO_KERNEL_IMPL", "").strip()
    if env:
        impl = validate_impl(env)
    if impl == "auto":
        return "pallas" if _on_tpu() else "xla"
    return impl


def use_fused(impl: str) -> bool:
    """Static dispatch for the generation step: fused path unless the caller
    explicitly pinned the pre-PR-4 op soup."""
    return resolve_impl(impl) != "xla_unfused"


def _kernel_tier(impl: str) -> bool:
    """True for every resolved tier that routes through the Pallas kernels
    ("pallas_rng" is "pallas" plus the in-kernel sample RNG — all non-sample
    ops treat the two identically)."""
    return impl in ("pallas", "pallas_rng")


@functools.lru_cache(maxsize=1)
def _rng_kernel_supported() -> bool:
    """One-shot probe (satellite of the residency PR): can the in-kernel
    RNG sample kernel actually compile and run on this backend?  Mosaic on
    TPU is probed with a tiny real call; everywhere else the answer is a
    static False — the XLA threefry ref IS the bit-exact same stream, so
    the CPU fallback never changes a trajectory and interpret-mode kernels
    stay a test-only surface (they are orders of magnitude too slow for
    production CPU runs)."""
    if not _on_tpu():
        return False
    try:
        seeds = jnp.zeros((1, 2), jnp.uint32)
        jax.block_until_ready(
            cma_sample_z_rng(seeds, lam=8, n=128, dtype=jnp.float32))
        return True
    except Exception:                                   # pragma: no cover
        return False


def sample_transform(B, D, Z, impl: str = "auto"):
    """Y = Z·diag(D)·Bᵀ (lam, n)."""
    impl = resolve_impl(impl)
    if not _kernel_tier(impl):
        return ref.sample_transform(B, D, Z)
    zero = jnp.zeros((B.shape[0],), Z.dtype)
    one = jnp.ones((), Z.dtype)
    return cma_sample(zero, one, B, D, Z, interpret=not _on_tpu())


def sample_points(m, sigma, B, D, Z, impl: str = "auto"):
    """X = M + σ·B·diag(D)·Z (lam, n) — fused kernel when impl=pallas."""
    impl = resolve_impl(impl)
    if not _kernel_tier(impl):
        return ref.sample_points(m, sigma, B, D, Z)
    return cma_sample(m, sigma, B, D, Z, interpret=not _on_tpu())


def rank_mu_gram(Y, w, impl: str = "auto"):
    """Σ wᵢ yᵢyᵢᵀ — the paper's rank-λ GEMM (eq. 3)."""
    impl = resolve_impl(impl)
    if not _kernel_tier(impl):
        return ref.rank_mu_gram(Y, w)
    n = Y.shape[1]
    zeros = jnp.zeros((n, n), Y.dtype)
    zvec = jnp.zeros((n,), Y.dtype)
    return cma_rank_mu_update(zeros, Y, w, zvec, 0.0, 1.0, 0.0,
                              interpret=not _on_tpu())


# ---------------------------------------------------------------------------
# fused generation step (kernels/cma_gen.py ↔ ref.gen_sample/fused_gen_update)
# ---------------------------------------------------------------------------

def _stacked(*arrays):
    """Add a singleton slot axis to per-slot arrays (kernels are slot-batched)."""
    return tuple(a[None] for a in arrays)


def _megakernel_fits(n: int, dtype) -> bool:
    """VMEM-fit check for the whole-(n,n)-tile update megakernel: ~4 f32
    n² tiles (C, B, gram accumulator, C') plus the dtype-width C/B input
    tiles must fit a 16 MB core."""
    itemsize = jnp.dtype(dtype).itemsize
    tile_bytes = n * n * (4 * 4 + 2 * itemsize)
    return tile_bytes <= 12 * 1024 * 1024


def _sample_fits(n: int, dtype) -> bool:
    """The fused sample kernel only holds chunked tiles — a (np, bn) B
    slab plus three (bl, np) row blocks — so its bound is far looser than
    the update megakernel's whole-matrix one."""
    itemsize = jnp.dtype(dtype).itemsize
    bn = bl = 128
    tile_bytes = n * bn * (4 + itemsize) + 3 * bl * n * (4 + itemsize)
    return tile_bytes <= 12 * 1024 * 1024


def _gen_impl(impl: str, n: int, dtype, fits=_megakernel_fits) -> str:
    """Dispatch for the fused generation ops.  ``"auto"`` silently falls
    back to the fused XLA ref when the kernel's tiles cannot fit VMEM
    instead of failing in Mosaic; an EXPLICIT pallas request — from the
    caller or from the ``REPRO_KERNEL_IMPL`` override — is honored (and
    fails loudly) so kernel work at larger n stays drivable."""
    resolved = resolve_impl(impl)
    env = os.environ.get("REPRO_KERNEL_IMPL", "").strip()
    requested = env if env else impl
    if resolved == "pallas" and requested == "auto" and not fits(n, dtype):
        return "xla"
    return resolved


def gen_sample(m, sigma, B, D, Z, impl: str = "auto"):
    """Fused sampling: (Y, X) in one pass.

    Slot-batched when ``Z`` carries a leading slot axis (ndim == 3) — the
    stacked-slot ladder programs call this ONCE for all slots; per-slot
    arrays are accepted too (a singleton slot axis is added for the kernel).
    """
    impl = _gen_impl(impl, Z.shape[-1], Z.dtype, fits=_sample_fits)
    if not _kernel_tier(impl):
        return ref.gen_sample(m, sigma, B, D, Z)
    if Z.ndim == 3:
        return cma_gen_sample(m, sigma, B, D, Z, interpret=not _on_tpu())
    m1, B1, D1, Z1 = _stacked(m, B, D, Z)
    Y, X = cma_gen_sample(m1, jnp.asarray(sigma)[None], B1, D1, Z1,
                          interpret=not _on_tpu())
    return Y[0], X[0]


def _sep_slots(sep, S: int, n: int, dtype):
    """Broadcast a ``bbob.SepCoeffs`` (shared by all slots of a run, or
    already per-slot) to the kernel's per-slot layout."""
    return (jnp.broadcast_to(jnp.asarray(sep.scale, dtype), (S, n)),
            jnp.broadcast_to(jnp.asarray(sep.shift, dtype), (S, n)),
            jnp.broadcast_to(jnp.asarray(sep.f_opt, dtype), (S,)),
            jnp.broadcast_to(jnp.asarray(sep.mode, jnp.int32), (S,)),
            jnp.broadcast_to(jnp.asarray(sep.valid), (S,)))


def gen_sample_rng(m, sigma, B, D, seeds, lam: int, impl: str = "auto"):
    """Fused sampling with the in-kernel threefry counter stream: per-slot
    ``seeds`` (S, 2) uint32 replace the (S, lam, n) Z operand, so nothing
    host-shaped (and no HBM Z) exists on the sampled path.  Returns (Y, X).

    The Mosaic kernel runs only when the resolved tier is ``"pallas_rng"``
    AND the one-shot backend probe passes; every other combination takes
    ``ref.gen_sample_rng`` — the bit-exact same stream under jit, so the
    fallback is trajectory-invisible.  Slot-batched like ``gen_sample``.
    """
    impl = _gen_impl(impl, B.shape[-1], B.dtype, fits=_sample_fits)
    if impl == "pallas_rng" and _rng_kernel_supported():
        if B.ndim == 3:
            return cma_gen_sample_rng(m, sigma, B, D, seeds, lam=lam)
        m1, B1, D1 = _stacked(m, B, D)
        Y, X = cma_gen_sample_rng(m1, jnp.asarray(sigma)[None], B1, D1,
                                  jnp.asarray(seeds)[None], lam=lam)
        return Y[0], X[0]
    return ref.gen_sample_rng(m, sigma, B, D, seeds, lam)


def gen_sample_eval(m, sigma, B, D, Z, sep, impl: str = "auto"):
    """Eval-fused sampling for separable fids: returns (Y, F) with the
    fitness computed in the sample epilogue — X never materializes in HBM.
    ``sep`` is a ``bbob.SepCoeffs``; on the XLA tiers the same algebra runs
    as ``ref.gen_sample_eval`` (bit-identical to the dispatched
    ``evaluate_dynamic`` on the same X)."""
    impl = _gen_impl(impl, Z.shape[-1], Z.dtype, fits=_sample_fits)
    if not _kernel_tier(impl):
        return ref.gen_sample_eval(m, sigma, B, D, Z, sep)
    batched = Z.ndim == 3
    if not batched:
        m, B, D, Z = _stacked(m, B, D, Z)
        sigma = jnp.asarray(sigma)[None]
    S, n = Z.shape[0], Z.shape[-1]
    Y, F = cma_gen_sample_eval(m, sigma, B, D, Z,
                               *_sep_slots(sep, S, n, Z.dtype),
                               interpret=not _on_tpu())
    return (Y, F) if batched else (Y[0], F[0])


def gen_sample_rng_eval(m, sigma, B, D, seeds, lam: int, sep,
                        impl: str = "auto"):
    """The full residency path: seeds → (Y, F) in one kernel — in-kernel
    RNG plus eval-fused epilogue.  Kernel only under a probed
    ``"pallas_rng"``; otherwise the XLA threefry ref with the fused
    separable eval (same stream, same fitness algebra)."""
    impl = _gen_impl(impl, B.shape[-1], B.dtype, fits=_sample_fits)
    if impl == "pallas_rng" and _rng_kernel_supported():
        batched = B.ndim == 3
        if not batched:
            m, B, D = _stacked(m, B, D)
            sigma = jnp.asarray(sigma)[None]
            seeds = jnp.asarray(seeds)[None]
        S, n = B.shape[0], B.shape[-1]
        Y, F = cma_gen_sample_rng_eval(m, sigma, B, D, seeds,
                                       *_sep_slots(sep, S, n, B.dtype),
                                       lam=lam)
        return (Y, F) if batched else (Y[0], F[0])
    return ref.gen_sample_rng_eval(m, sigma, B, D, seeds, lam, sep)


def gen_update(C, B, D, p_sigma, p_c, Y, w, coef, impl: str = "auto"):
    """Fused O(n²) generation update — C/B/D read from HBM once.

    ``coef`` is a dict-like of per-slot scalars with the fields named in
    ``cma_gen.COEF_FIELDS`` (``gen1`` = 1-based generation counter as a
    float).  Slot-batched when ``C`` carries a leading slot axis; returns
    ``(C_new, p_sigma_new, p_c_new, y_w)`` with matching batching.

    The megakernel computes in f32 regardless of the state dtype (the MXU
    has no f64 path); f64 campaigns that need strict double-precision
    trajectories should pin ``impl="xla"``.  Under ``impl="auto"``,
    problems whose whole-matrix tiles exceed VMEM fall back to the fused
    XLA ref (``_megakernel_fits``).
    """
    impl = _gen_impl(impl, C.shape[-1], C.dtype)
    if not _kernel_tier(impl):
        fn = ref.fused_gen_update
        args = (coef["c_sigma"], coef["mu_eff"], coef["c_c"], coef["c_1"],
                coef["c_mu"], coef["chi_n"], coef["gen1"])
        if C.ndim == 3:
            return jax.vmap(fn)(C, B, D, p_sigma, p_c, Y, w, *args)
        return fn(C, B, D, p_sigma, p_c, Y, w, *args)
    batched = C.ndim == 3
    if not batched:
        C, B, Y = (a[None] for a in (C, B, Y))
        D, p_sigma, p_c, w = (a[None] for a in (D, p_sigma, p_c, w))
    cs = jnp.stack([jnp.broadcast_to(
        jnp.asarray(coef[f], jnp.float32), C.shape[:1])
        for f in COEF_FIELDS], axis=1)
    out = cma_gen_update(C, B, D, p_sigma, p_c, Y, w, cs,
                         interpret=not _on_tpu())
    return out if batched else tuple(o[0] for o in out)


def covariance_combine(C, gram, p_c, decay, c_mu, c_1, impl: str = "auto"):
    """decay·C + c_μ·gram + c₁·p_c p_cᵀ (cheap epilogue; always jnp).

    The fused path (kernel computing gram+epilogue in one pass) is
    ``rank_mu_update`` below — used when the caller still has Y at hand.
    """
    return ref.covariance_combine(C, gram, p_c, decay, c_mu, c_1)


def rank_mu_update(C, Y, w, p_c, decay, c_mu, c_1, impl: str = "auto"):
    """Fully fused covariance adaptation: one HBM read+write of C."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.rank_mu_update(C, Y, w, p_c, decay, c_mu, c_1)
    return cma_rank_mu_update(C, Y, w, p_c, decay, c_mu, c_1,
                              interpret=not _on_tpu())


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    impl: str = "auto"):
    """GQA flash attention (see kernels/flash_attention.py)."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.flash_attention(q, k, v, causal=causal, window=window)
    from repro.kernels.flash_attention import flash_attention as fa
    return fa(q, k, v, causal=causal, window=window, interpret=not _on_tpu())


def wkv6(r, k, v, logw, u, impl: str = "auto"):
    """Chunked RWKV-6 WKV (see kernels/rwkv6_wkv.py)."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.wkv6(r, k, v, logw, u)
    from repro.kernels.rwkv6_wkv import wkv6_forward
    return wkv6_forward(r, k, v, logw, u, interpret=not _on_tpu())
