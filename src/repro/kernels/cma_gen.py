"""Pallas TPU megakernels: one fused CMA-ES generation, slot-batched.

The paper's §3.1 rewrites the CMA-ES hot spots as Level-3 BLAS; PR 2/3
made every λ-proportional cost work-proportional, leaving the
λ-independent O(n²) per-generation state update as the dominant per-step
cost at large n.  These kernels take that update Pallas-native END TO END:

* ``cma_gen_sample`` — fused sampling emitting BOTH ``Y = Z·diag(D)·Bᵀ``
  and ``X = m + σ·Y`` in one pass (the separate-op path writes Y to HBM,
  reads it back, and writes X; here the epilogue reuses the accumulator
  tile while it is still in VMEM).
* ``cma_gen_update`` — the update megakernel: rank-μ gram, weighted-mean
  GEMV, evolution-path recursions (including the h_σ stall test), the
  ``decay·C + c_μ·G + c₁·p_c'p_c'ᵀ`` epilogue, and the whitened-step GEMV
  ``C^{-1/2}·y_w = B·diag(1/D)·Bᵀ·y_w`` — so C, B and D are each read
  from HBM exactly ONCE per generation instead of once per op.  The gram
  accumulates as ``(√w·Y)ᵀ(√w·Y)``, which keeps C' symmetric by
  construction — the unfused path's ``0.5·(C + Cᵀ)`` repair pass (the
  memory-bound transpose-add that dominates the update at large n) has no
  counterpart here at all (see ref.fused_gen_update).

Both kernels are **slot-batched**: every input carries a leading slot (or
member) axis that maps onto the leading grid dimension, so the stacked-slot
ladder programs (core/ladder.py::slots_gen_step) invoke ONE kernel for all
slots instead of vmapping a per-slot kernel (whose batching rule would
re-trace and rely on vmap lowering — the dead corner PR 4 removes).
Inactive/parked slots ride through with all-zero weights: the gram,
``y_w`` and p_c/p_σ pulls they contribute are zero, and the engine's
``ran``/``stop`` tree-select discards their outputs — the repo-wide
zero-weight masking convention, now honored in-kernel.

Geometry: grid ``(S, n_k)`` with λ chunked over ``n_k`` and whole-(n,n)
C/B tiles per slot.  The λ-contraction accumulates in a VMEM scratch tile
across the ``n_k`` steps; the epilogue (everything after the gram) runs on
the last λ chunk.  Whole-matrix tiles bound the kernel to roughly
n ≤ 768 in f32 on a 16 MB-VMEM core (4 n² tiles live: C, B, C', gram
accumulator) — comfortably past the paper's n = 1000 BBOB ceiling in
bf16/f16 state and past every config this repo ships in f32.  Off-TPU the
kernels execute in interpret mode (correctness oracle only; the XLA ref
``kernels/ref.py::fused_gen_update`` is the production CPU path).
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import jax.numpy as jnp

# per-slot scalar coefficients of the update megakernel, in SMEM layout
# order (see ops.gen_update for the packing)
COEF_FIELDS = ("c_sigma", "mu_eff", "c_c", "c_1", "c_mu", "chi_n", "gen1")


def _round_block(n: int, cap: int = 128) -> int:
    """Block edge for an axis of size n: 8-aligned, capped at the MXU edge."""
    return min(cap, -(-max(n, 1) // 8) * 8)


# ---------------------------------------------------------------------------
# fused sample kernel
# ---------------------------------------------------------------------------

def _sample_kernel(sigma_ref, z_ref, d_ref, b_ref, m_ref, y_ref, x_ref,
                   acc_ref, *, n_k: int):
    s, k = pl.program_id(0), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    z = z_ref[0].astype(jnp.float32)            # (bl, bk)
    d = d_ref[0].astype(jnp.float32)            # (bk,)
    b = b_ref[0].astype(jnp.float32)            # (np, bk)
    acc_ref[...] += jax.lax.dot_general(
        z * d[None, :], b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        sigma = sigma_ref[s]
        m = m_ref[0].astype(jnp.float32)        # (np,)
        y = acc_ref[...]
        y_ref[0] = y.astype(y_ref.dtype)
        x_ref[0] = (m[None, :] + sigma * y).astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bl", "bn", "interpret"))
def cma_gen_sample(m: jnp.ndarray, sigma: jnp.ndarray, B: jnp.ndarray,
                   D: jnp.ndarray, Z: jnp.ndarray, *, bl: int = 128,
                   bn: int = 128, interpret: bool = False):
    """Slot-batched fused sampling.  All inputs carry a leading slot axis:
    m (S,n), sigma (S,), B (S,n,n), D (S,n), Z (S,lam,n).  Returns
    (Y, X), each (S, lam, n)."""
    S, lam, n = Z.shape
    dt = Z.dtype
    bl = _round_block(lam, bl)
    bn = _round_block(n, bn)
    lp = -(-lam // bl) * bl
    np_ = -(-n // bn) * bn
    Zp = jnp.zeros((S, lp, np_), dt).at[:, :lam, :n].set(Z)
    Bp = jnp.zeros((S, np_, np_), dt).at[:, :n, :n].set(B)
    Dp = jnp.zeros((S, np_), dt).at[:, :n].set(D)
    Mp = jnp.zeros((S, np_), dt).at[:, :n].set(m)
    sig = jnp.asarray(sigma, jnp.float32)

    n_l, n_k = lp // bl, np_ // bn
    out_spec = pl.BlockSpec((1, bl, np_), lambda s, l, k: (s, l, 0))
    Y, X = pl.pallas_call(
        functools.partial(_sample_kernel, n_k=n_k),
        grid=(S, n_l, n_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                   # sigma (S,)
            pl.BlockSpec((1, bl, bn), lambda s, l, k: (s, l, k)),    # Z
            pl.BlockSpec((1, bn), lambda s, l, k: (s, k)),           # D
            pl.BlockSpec((1, np_, bn), lambda s, l, k: (s, 0, k)),   # B
            pl.BlockSpec((1, np_), lambda s, l, k: (s, 0)),          # m
        ],
        out_specs=(out_spec, out_spec),
        out_shape=(jax.ShapeDtypeStruct((S, lp, np_), dt),
                   jax.ShapeDtypeStruct((S, lp, np_), dt)),
        scratch_shapes=[pltpu.VMEM((bl, np_), jnp.float32)],
        interpret=interpret,
    )(sig, Zp, Dp, Bp, Mp)
    return Y[:, :lam, :n], X[:, :lam, :n]


# ---------------------------------------------------------------------------
# residency variants: in-kernel RNG + eval-fused epilogue (PR 7)
# ---------------------------------------------------------------------------
#
# One parametrized factory covers the three residency combinations on top
# of the plain kernel above (kept verbatim — it is the default tier and the
# HLO-pinned baseline):
#
#   rng=True         Z is drawn IN the kernel via the portable threefry2x32
#                    counter stream (kernels/ref.py — plain jnp uint32 ops,
#                    so the same code lowers under Mosaic AND interpret
#                    mode), seeded per slot from the row base key with
#                    counter (row << 16) | col.  The host-shaped fold_in
#                    stream and the HBM-resident (S, λ, n) Z tile both
#                    disappear from the sampled path.
#   fused_eval=True  the separable-fid fitness (bbob.SepCoeffs) is computed
#                    in the epilogue while X = m + σ·Y is still in
#                    registers: the kernel emits (Y, F) and X never exists
#                    in HBM.
#
# ``pltpu.prng_random_bits`` (the hardware PRNG) has no interpret/CPU
# lowering on this jax, so the threefry stream is the portable default;
# the hw path stays available behind ``rng_bits="hw"`` for TPU-only runs
# (seeded per (slot, row-block) from the same seeds — a DIFFERENT stream,
# gated out of every parity test off-TPU).

def _make_sample_kernel(*, n_k: int, bl: int, bn: int, np_: int, n_true: int,
                        rng: bool, fused_eval: bool, rng_bits: str = "counter",
                        z_dtype=None):
    from repro.kernels import ref as _ref

    def body(*refs):
        it = iter(refs)
        sigma_ref = next(it)
        seeds_ref = next(it) if rng else None
        z_ref = None if rng else next(it)
        d_ref, b_ref, m_ref = next(it), next(it), next(it)
        if fused_eval:
            scale_ref, shift_ref, fopt_ref = next(it), next(it), next(it)
            mode_ref, valid_ref = next(it), next(it)
        y_ref, out2_ref, acc_ref = next(it), next(it), next(it)

        s, l, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

        @pl.when(k == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        if rng and rng_bits == "hw":
            # TPU hardware PRNG: per-(slot, row-block) seed keeps each
            # grid step's draw independent of every other step's.
            pltpu.prng_seed(seeds_ref[s, 0], seeds_ref[s, 1], l, k)
            bits = pltpu.prng_random_bits((bl, bn))
            z = _ref._bits_to_unit(bits.astype(jnp.uint32), jnp.float32)
            z2 = _ref._bits_to_unit(
                pltpu.prng_random_bits((bl, bn)).astype(jnp.uint32),
                jnp.float32)
            two_pi = jnp.float32(2.0 * 3.14159265358979323846)
            z = jnp.sqrt(jnp.float32(-2.0) * jnp.log1p(-z)) * jnp.cos(
                two_pi * z2)
        elif rng:
            rows = (jax.lax.broadcasted_iota(jnp.uint32, (bl, bn), 0)
                    + (l * bl).astype(jnp.uint32))
            cols = (jax.lax.broadcasted_iota(jnp.uint32, (bl, bn), 1)
                    + (k * bn).astype(jnp.uint32))
            z = _ref.threefry_normal(seeds_ref[s, 0], seeds_ref[s, 1],
                                     rows, cols, z_dtype).astype(jnp.float32)
        else:
            z = z_ref[0].astype(jnp.float32)        # (bl, bn)
        d = d_ref[0].astype(jnp.float32)            # (bn,)
        b = b_ref[0].astype(jnp.float32)            # (np, bn)
        acc_ref[...] += jax.lax.dot_general(
            z * d[None, :], b, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

        @pl.when(k == n_k - 1)
        def _epilogue():
            sigma = sigma_ref[s]
            m = m_ref[0].astype(jnp.float32)        # (np,)
            y = acc_ref[...]
            y_ref[0] = y.astype(y_ref.dtype)
            x = m[None, :] + sigma * y              # (bl, np) — in registers
            if not fused_eval:
                out2_ref[0] = x.astype(out2_ref.dtype)
                return
            from repro.fitness import bbob as _bbob
            # the eval chain runs in the OUTPUT dtype on the f32-computed x
            # — exactly the values the two-program path would hand the
            # dispatched menu.  (On TPU the output dtype is f32 anyway; the
            # state-dtype chain is what keeps the f64 interpret tier at ref
            # precision, e.g. + f_opt must not round to f32.)
            dt = out2_ref.dtype
            xe = x.astype(dt)
            t = xe - shift_ref[0][None, :]
            tg = jnp.where(mode_ref[s] == 1, _bbob.t_osz(t), t)
            # padding cols: scale is zero-padded, but guard the transform
            # output anyway (0·NaN would poison the row sum)
            colm = jax.lax.broadcasted_iota(jnp.int32, (bl, np_), 1) < n_true
            tg = jnp.where(colm, tg, jnp.zeros((), dt))
            fv = jnp.sum(scale_ref[0][None, :] * tg * tg, axis=1) \
                + fopt_ref[0, 0]
            fv = jnp.where(valid_ref[s] == 1, fv, jnp.asarray(jnp.nan, dt))
            out2_ref[0] = fv.astype(dt)

    return body


def _sample_call(m, sigma, B, D, *, Z=None, seeds=None, sep=None,
                 lam=None, bl=128, bn=128, interpret=False,
                 rng_bits: str = "counter"):
    """Shared pad/spec plumbing of the residency sample kernels.  Returns
    (Y, X) without ``sep`` and (Y, F) with it."""
    rng = seeds is not None
    fused_eval = sep is not None
    S, n = m.shape
    lam = Z.shape[1] if Z is not None else int(lam)
    dt = m.dtype
    bl = _round_block(lam, bl)
    bn = _round_block(n, bn)
    lp = -(-lam // bl) * bl
    np_ = -(-n // bn) * bn
    Bp = jnp.zeros((S, np_, np_), dt).at[:, :n, :n].set(B)
    Dp = jnp.zeros((S, np_), dt).at[:, :n].set(D)
    Mp = jnp.zeros((S, np_), dt).at[:, :n].set(m)
    sig = jnp.asarray(sigma, jnp.float32)

    n_l, n_k = lp // bl, np_ // bn
    in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM)]          # sigma (S,)
    args = [sig]
    if rng:
        in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))  # seeds (S,2)
        args.append(jnp.asarray(seeds, jnp.uint32))
    else:
        in_specs.append(pl.BlockSpec((1, bl, bn), lambda s, l, k: (s, l, k)))
        args.append(jnp.zeros((S, lp, np_), dt).at[:, :lam, :n].set(Z))
    in_specs += [
        pl.BlockSpec((1, bn), lambda s, l, k: (s, k)),           # D
        pl.BlockSpec((1, np_, bn), lambda s, l, k: (s, 0, k)),   # B
        pl.BlockSpec((1, np_), lambda s, l, k: (s, 0)),          # m
    ]
    args += [Dp, Bp, Mp]
    if fused_eval:
        scale, shift, fopt, mode, valid = sep
        row = pl.BlockSpec((1, np_), lambda s, l, k: (s, 0))
        in_specs += [row, row,
                     pl.BlockSpec((1, 1), lambda s, l, k: (s, 0)),   # f_opt
                     pl.BlockSpec(memory_space=pltpu.SMEM),          # mode
                     pl.BlockSpec(memory_space=pltpu.SMEM)]          # valid
        args += [jnp.zeros((S, np_), dt).at[:, :n].set(scale),
                 jnp.zeros((S, np_), dt).at[:, :n].set(shift),
                 jnp.asarray(fopt, dt).reshape(S, 1),
                 jnp.asarray(mode, jnp.int32),
                 jnp.asarray(valid, jnp.int32)]

    y_spec = pl.BlockSpec((1, bl, np_), lambda s, l, k: (s, l, 0))
    if fused_eval:
        out_specs = (y_spec, pl.BlockSpec((1, bl), lambda s, l, k: (s, l)))
        out_shape = (jax.ShapeDtypeStruct((S, lp, np_), dt),
                     jax.ShapeDtypeStruct((S, lp), dt))
    else:
        out_specs = (y_spec, y_spec)
        out_shape = (jax.ShapeDtypeStruct((S, lp, np_), dt),
                     jax.ShapeDtypeStruct((S, lp, np_), dt))

    kernel = _make_sample_kernel(n_k=n_k, bl=bl, bn=bn, np_=np_, n_true=n,
                                 rng=rng, fused_eval=fused_eval,
                                 rng_bits=rng_bits, z_dtype=dt)
    Y, out2 = pl.pallas_call(
        kernel, grid=(S, n_l, n_k), in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bl, np_), jnp.float32)],
        interpret=interpret,
    )(*args)
    if fused_eval:
        return Y[:, :lam, :n], out2[:, :lam]
    return Y[:, :lam, :n], out2[:, :lam, :n]


@functools.partial(jax.jit,
                   static_argnames=("lam", "bl", "bn", "interpret",
                                    "rng_bits"))
def cma_gen_sample_rng(m, sigma, B, D, seeds, *, lam: int, bl: int = 128,
                       bn: int = 128, interpret: bool = False,
                       rng_bits: str = "counter"):
    """Fused sampling with in-kernel RNG: per-slot ``seeds`` (S, 2) uint32
    replace the (S, lam, n) Z operand.  Returns (Y, X), each (S, lam, n).
    Oracle: ``ref.gen_sample_rng`` (bit-exact Z stream by construction)."""
    return _sample_call(m, sigma, B, D, seeds=seeds, lam=lam, bl=bl, bn=bn,
                        interpret=interpret, rng_bits=rng_bits)


@functools.partial(jax.jit, static_argnames=("bl", "bn", "interpret"))
def cma_gen_sample_eval(m, sigma, B, D, Z, scale, shift, fopt, mode, valid,
                        *, bl: int = 128, bn: int = 128,
                        interpret: bool = False):
    """Eval-fused sampling: the separable fid (per-slot SepCoeffs rows
    ``scale``/``shift`` (S, n), scalars ``fopt``/``mode``/``valid`` (S,))
    is evaluated in the epilogue; returns (Y, F) — X never leaves VMEM.
    Oracle: ``ref.gen_sample_eval``."""
    return _sample_call(m, sigma, B, D, Z=Z,
                        sep=(scale, shift, fopt, mode, valid),
                        bl=bl, bn=bn, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("lam", "bl", "bn", "interpret",
                                    "rng_bits"))
def cma_gen_sample_rng_eval(m, sigma, B, D, seeds, scale, shift, fopt, mode,
                            valid, *, lam: int, bl: int = 128, bn: int = 128,
                            interpret: bool = False,
                            rng_bits: str = "counter"):
    """The full residency kernel: seeds → (Y, F).  No host RNG stream, no
    HBM Z, no HBM X — one kernel in, one kernel out per generation."""
    return _sample_call(m, sigma, B, D, seeds=seeds,
                        sep=(scale, shift, fopt, mode, valid), lam=lam,
                        bl=bl, bn=bn, interpret=interpret, rng_bits=rng_bits)


def _z_kernel(seeds_ref, z_ref, *, bl: int, bn: int, z_dtype):
    from repro.kernels import ref as _ref
    s, l, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    rows = (jax.lax.broadcasted_iota(jnp.uint32, (bl, bn), 0)
            + (l * bl).astype(jnp.uint32))
    cols = (jax.lax.broadcasted_iota(jnp.uint32, (bl, bn), 1)
            + (k * bn).astype(jnp.uint32))
    z_ref[0] = _ref.threefry_normal(seeds_ref[s, 0], seeds_ref[s, 1],
                                    rows, cols, z_dtype).astype(z_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("lam", "n", "dtype", "bl", "bn",
                                    "interpret"))
def cma_sample_z_rng(seeds, *, lam: int, n: int, dtype, bl: int = 128,
                     bn: int = 128, interpret: bool = False):
    """Materialize the in-kernel Z stream — the parity surface the bit-exact
    kernel↔ref tests compare (``ref.sample_z_rng``), and the compile probe
    target for ``ops._rng_kernel_supported``."""
    seeds = jnp.asarray(seeds, jnp.uint32)
    S = seeds.shape[0]
    bl = _round_block(lam, bl)
    bn = _round_block(n, bn)
    lp, np_ = -(-lam // bl) * bl, -(-n // bn) * bn
    Z = pl.pallas_call(
        functools.partial(_z_kernel, bl=bl, bn=bn, z_dtype=dtype),
        grid=(S, lp // bl, np_ // bn),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_specs=pl.BlockSpec((1, bl, bn), lambda s, l, k: (s, l, k)),
        out_shape=jax.ShapeDtypeStruct((S, lp, np_), dtype),
        interpret=interpret,
    )(seeds)
    return Z[:, :lam, :n]


# ---------------------------------------------------------------------------
# update megakernel
# ---------------------------------------------------------------------------

def _update_kernel(coef_ref, y_ref, w_ref, c_ref, b_ref, d_ref, psig_ref,
                   pc_ref, cn_ref, psn_ref, pcn_ref, yw_ref, acc_g, acc_yw,
                   *, n_k: int, n_true: int):
    s, k = pl.program_id(0), pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_g[...] = jnp.zeros_like(acc_g)
        acc_yw[...] = jnp.zeros_like(acc_yw)

    y = y_ref[0].astype(jnp.float32)            # (bk, np)
    wv = w_ref[0].astype(jnp.float32)           # (bk,)
    ys = jnp.sqrt(wv)[:, None] * y
    # (np, np) += Y_sᵀ·Y_s — the rank-μ gram chunk on the MXU; the √w
    # factoring keeps the accumulated gram (and C') symmetric by
    # construction, so no 0.5·(C + Cᵀ) repair pass exists anywhere
    acc_g[...] += jax.lax.dot_general(
        ys, ys, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    acc_yw[...] += jnp.sum(wv[:, None] * y, axis=0, keepdims=True)  # (1, np)

    @pl.when(k == n_k - 1)
    def _epilogue():
        c_sig, mu_eff = coef_ref[s, 0], coef_ref[s, 1]
        c_c, c_1 = coef_ref[s, 2], coef_ref[s, 3]
        c_mu, chi_n, gen1 = coef_ref[s, 4], coef_ref[s, 5], coef_ref[s, 6]

        b = b_ref[0].astype(jnp.float32)        # (np, np)
        d = d_ref[0].astype(jnp.float32)        # (np,)
        psig = psig_ref[0].astype(jnp.float32)  # (np,)
        pc = pc_ref[0].astype(jnp.float32)      # (np,)
        yw = acc_yw[...]                        # (1, np)

        # whitened step: (y_wᵀ·B / D) · Bᵀ, padded D rows guarded by the max
        t = jax.lax.dot_general(yw, b, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        t = t / jnp.maximum(d, 1e-30)[None, :]
        whiten = jax.lax.dot_general(t, b, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)

        ps_new = (1.0 - c_sig) * psig[None, :] + jnp.sqrt(
            c_sig * (2.0 - c_sig) * mu_eff) * whiten
        ps_norm = jnp.sqrt(jnp.sum(ps_new * ps_new))
        h_denom = jnp.sqrt(1.0 - (1.0 - c_sig) ** (2.0 * gen1))
        h_sigma = (ps_norm / h_denom / chi_n
                   < 1.4 + 2.0 / (n_true + 1.0)).astype(jnp.float32)
        pc_new = (1.0 - c_c) * pc[None, :] + h_sigma * jnp.sqrt(
            c_c * (2.0 - c_c) * mu_eff) * yw
        decay = 1.0 - c_1 - c_mu + (1.0 - h_sigma) * c_1 * c_c * (2.0 - c_c)

        c_old = c_ref[0].astype(jnp.float32)    # (np, np)
        c_new = decay * c_old + c_mu * acc_g[...] \
            + c_1 * pc_new[0][:, None] * pc_new[0][None, :]

        cn_ref[0] = c_new.astype(cn_ref.dtype)
        psn_ref[0] = ps_new[0].astype(psn_ref.dtype)
        pcn_ref[0] = pc_new[0].astype(pcn_ref.dtype)
        yw_ref[0] = yw[0].astype(yw_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "bn", "interpret"))
def cma_gen_update(C: jnp.ndarray, B: jnp.ndarray, D: jnp.ndarray,
                   p_sigma: jnp.ndarray, p_c: jnp.ndarray, Y: jnp.ndarray,
                   w: jnp.ndarray, coef: jnp.ndarray, *, bk: int = 128,
                   bn: int = 128, interpret: bool = False):
    """Slot-batched fused generation update (oracle: ref.fused_gen_update).

    Shapes (S = slots): C/B (S,n,n); D/p_sigma/p_c (S,n); Y (S,lam,n);
    w (S,lam); coef (S, len(COEF_FIELDS)) f32 per-slot scalars.  Returns
    ``(C_new, p_sigma_new, p_c_new, y_w)``.
    """
    S, lam, n = Y.shape
    dt = C.dtype
    bk = _round_block(lam, bk)
    bn = _round_block(n, bn)
    lp = -(-lam // bk) * bk
    np_ = -(-n // bn) * bn
    Yp = jnp.zeros((S, lp, np_), dt).at[:, :lam, :n].set(Y)
    wp = jnp.zeros((S, lp), dt).at[:, :lam].set(w)      # zero weight ⇒ inert
    Cp = jnp.zeros((S, np_, np_), dt).at[:, :n, :n].set(C)
    Bp = jnp.zeros((S, np_, np_), dt).at[:, :n, :n].set(B)
    Dp = jnp.zeros((S, np_), dt).at[:, :n].set(D)
    psp = jnp.zeros((S, np_), dt).at[:, :n].set(p_sigma)
    pcp = jnp.zeros((S, np_), dt).at[:, :n].set(p_c)
    coef = jnp.asarray(coef, jnp.float32)

    n_k = lp // bk
    mat = pl.BlockSpec((1, np_, np_), lambda s, k: (s, 0, 0))
    vec = pl.BlockSpec((1, np_), lambda s, k: (s, 0))
    C_new, ps_new, pc_new, y_w = pl.pallas_call(
        functools.partial(_update_kernel, n_k=n_k, n_true=n),
        grid=(S, n_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),               # coef (S, 7)
            pl.BlockSpec((1, bk, np_), lambda s, k: (s, k, 0)),  # Y
            pl.BlockSpec((1, bk), lambda s, k: (s, k)),          # w
            mat,                                                 # C
            mat,                                                 # B
            vec,                                                 # D
            vec,                                                 # p_sigma
            vec,                                                 # p_c
        ],
        out_specs=(mat, vec, vec, vec),
        out_shape=(jax.ShapeDtypeStruct((S, np_, np_), dt),
                   jax.ShapeDtypeStruct((S, np_), dt),
                   jax.ShapeDtypeStruct((S, np_), dt),
                   jax.ShapeDtypeStruct((S, np_), dt)),
        scratch_shapes=[pltpu.VMEM((np_, np_), jnp.float32),
                        pltpu.VMEM((1, np_), jnp.float32)],
        interpret=interpret,
    )(coef, Yp, wp, Cp, Bp, Dp, psp, pcp)
    return (C_new[:, :n, :n], ps_new[:, :n], pc_new[:, :n], y_w[:, :n])
