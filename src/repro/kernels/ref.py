"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *reference implementations*: numerically straightforward XLA
programs.  They are also the default execution path on non-TPU backends (the
paper's "Level-3 BLAS" insight maps to plain einsum/matmul here, which XLA
lowers to MXU ops on TPU anyway — the Pallas kernels additionally fuse the
epilogues; see kernels/cma_update.py and kernels/cma_sample.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_transform(B: jnp.ndarray, D: jnp.ndarray, Z: jnp.ndarray) -> jnp.ndarray:
    """Y = Z · diag(D) · Bᵀ, i.e. y_k = B·(D ∘ z_k).   (paper eq. 1, batched)

    B: (n, n) eigenvectors; D: (n,) sqrt-eigenvalues; Z: (lam, n) ~ N(0, I).
    Returns Y: (lam, n).
    """
    return (Z * D[None, :]) @ B.T


def sample_points(m: jnp.ndarray, sigma: jnp.ndarray, B: jnp.ndarray,
                  D: jnp.ndarray, Z: jnp.ndarray) -> jnp.ndarray:
    """X = M + σ·(B·diag(D))·Z in row convention: (lam, n)."""
    return m[None, :] + sigma * sample_transform(B, D, Z)


def rank_mu_gram(Y: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Σᵢ wᵢ yᵢyᵢᵀ as one GEMM:  Aᵀ·B with A = Y, B = diag(w)·Y (paper eq. 3)."""
    return Y.T @ (w[:, None] * Y)


def covariance_combine(C: jnp.ndarray, gram: jnp.ndarray, p_c: jnp.ndarray,
                       decay: jnp.ndarray, c_mu: jnp.ndarray,
                       c_1: jnp.ndarray) -> jnp.ndarray:
    """C ← decay·C + c_μ·gram + c₁·p_c p_cᵀ   (paper eq. 3 epilogue)."""
    return decay * C + c_mu * gram + c_1 * jnp.outer(p_c, p_c)


def rank_mu_update(C: jnp.ndarray, Y: jnp.ndarray, w: jnp.ndarray,
                   p_c: jnp.ndarray, decay: jnp.ndarray, c_mu: jnp.ndarray,
                   c_1: jnp.ndarray) -> jnp.ndarray:
    """Fully fused covariance adaptation (what the Pallas kernel computes)."""
    return covariance_combine(C, rank_mu_gram(Y, w), p_c, decay, c_mu, c_1)


# ---------------------------------------------------------------------------
# fused generation step (oracles for kernels/cma_gen.py)
# ---------------------------------------------------------------------------

def gen_sample(m: jnp.ndarray, sigma: jnp.ndarray, B: jnp.ndarray,
               D: jnp.ndarray, Z: jnp.ndarray):
    """Fused sampling: (Y, X) = (Z·diag(D)·Bᵀ, m + σ·Y) in one pass.

    Accepts either per-slot arrays (m (n,), sigma (), B (n,n), Z (lam,n))
    or slot-stacked arrays with one leading axis (m (S,n), sigma (S,), ...);
    the Pallas form (kernels/cma_gen.py) maps that slot axis onto its
    leading grid dimension.
    """
    sigma = jnp.asarray(sigma)
    Y = (Z * D[..., None, :]) @ jnp.swapaxes(B, -1, -2)
    X = m[..., None, :] + sigma[..., None, None] * Y
    return Y, X


# ---------------------------------------------------------------------------
# in-kernel RNG: portable threefry2x32 counter stream (oracle of the
# `impl="pallas_rng"` sample-kernel tier)
# ---------------------------------------------------------------------------
#
# One function, two callers: the Pallas sample kernel's body and this XLA
# ref both evaluate _threefry2x32 with identical jnp uint32 vector ops, so
# kernel↔ref agreement is bit-exact BY CONSTRUCTION (no tolerance band).
# Each Z element depends only on (slot seed, row, col) through the counter
# (row << 16) | col — chunk- and padding-independent, the in-kernel
# analogue of the engines' row-keyed prefix-stable sampling.

_TF_ROT_A = (13, 15, 26, 6)
_TF_ROT_B = (17, 29, 16, 24)
_TF_PARITY = 0x1BD11BDA


def _rotl32(x, r: int):
    r = jnp.uint32(r)
    return (x << r) | (x >> (jnp.uint32(32) - r))


def _threefry2x32(k0, k1, c0, c1):
    """Threefry-2x32, 20 rounds — the standard counter-based block cipher
    jax's own PRNG builds on, spelled in plain jnp uint32 arithmetic so the
    SAME code runs inside a Pallas kernel body (Mosaic and interpret mode)
    and as an XLA program.  ``k0/k1`` key words, ``c0/c1`` counter words
    (any broadcastable uint32 shapes); returns two uint32 output words."""
    k0, k1 = jnp.uint32(k0), jnp.uint32(k1)
    x0 = jnp.asarray(c0, jnp.uint32) + k0
    x1 = jnp.asarray(c1, jnp.uint32) + k1
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(_TF_PARITY))
    for i in range(5):
        for r in (_TF_ROT_A if i % 2 == 0 else _TF_ROT_B):
            x0 = x0 + x1
            x1 = _rotl32(x1, r)
            x1 = x0 ^ x1
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + jnp.uint32(i + 1)
    return x0, x1


def _bits_to_unit(bits, dtype):
    """uint32 → [0, 1): keep the top 23 bits as an f32 mantissa in [1, 2)
    and subtract 1 — branch-free, Mosaic-lowerable (lax.bitcast, not
    pltpu.bitcast, so the interpret/CPU path works too)."""
    f = jax.lax.bitcast_convert_type(
        (bits >> jnp.uint32(9)) | jnp.uint32(0x3F800000), jnp.float32)
    return (f - jnp.float32(1.0)).astype(dtype)


def threefry_normal(seed0, seed1, rows, cols, dtype):
    """Standard-normal grid keyed by (seed, row, col).

    ``rows``/``cols`` are integer index arrays (broadcastable against each
    other); element (r, c) draws counter ``(r << 16) | c`` — rows and
    columns each bounded by 2¹⁶, far above any λ or n this repo runs — and
    maps the two threefry output words through one Box–Muller cosine branch
    (one normal per counter; the sine partner is discarded so each element
    stays an independent function of its own counter).
    """
    c0 = ((jnp.asarray(rows, jnp.uint32) << jnp.uint32(16))
          | jnp.asarray(cols, jnp.uint32))
    b0, b1 = _threefry2x32(seed0, seed1, c0, jnp.zeros_like(c0))
    u1 = _bits_to_unit(b0, dtype)
    u2 = _bits_to_unit(b1, dtype)
    two_pi = jnp.asarray(2.0 * 3.14159265358979323846, dtype)
    return jnp.sqrt(jnp.asarray(-2.0, dtype)
                    * jnp.log1p(-u1)) * jnp.cos(two_pi * u2)


def sample_z_rng(seeds: jnp.ndarray, lam: int, n: int, dtype) -> jnp.ndarray:
    """The pallas_rng tier's Z stream as an XLA program.

    ``seeds`` (2,) uint32 per slot or (S, 2) slot-stacked; returns Z of
    shape (lam, n) / (S, lam, n).  Bit-exact against the in-kernel draw.
    """
    seeds = jnp.asarray(seeds, jnp.uint32)
    rows = jnp.arange(lam, dtype=jnp.uint32)[:, None]
    cols = jnp.arange(n, dtype=jnp.uint32)[None, :]
    if seeds.ndim == 1:
        return threefry_normal(seeds[0], seeds[1], rows, cols, dtype)
    return threefry_normal(seeds[:, 0, None, None], seeds[:, 1, None, None],
                           rows[None], cols[None], dtype)


def gen_sample_rng(m: jnp.ndarray, sigma: jnp.ndarray, B: jnp.ndarray,
                   D: jnp.ndarray, seeds: jnp.ndarray, lam: int):
    """Fused sampling with the device-side counter RNG: (Y, X) straight from
    per-slot seeds — no host-shaped fold_in stream, no HBM-resident Z."""
    n = B.shape[-1]
    Z = sample_z_rng(seeds, lam, n, m.dtype)
    return gen_sample(m, sigma, B, D, Z)


def gen_sample_eval(m: jnp.ndarray, sigma: jnp.ndarray, B: jnp.ndarray,
                    D: jnp.ndarray, Z: jnp.ndarray, sep):
    """Eval-fused sampling for the separable BBOB family: (Y, F).

    ``sep`` is a ``bbob.SepCoeffs``; F is bit-identical to evaluating the
    dispatched fid on the materialized X (same elementwise chain, same
    reduce) — but expressed without X as a program output, so XLA fuses the
    m + σ·Y elementwise chain into the fitness reduction and the (λ, n) X
    tile never reaches HBM (pinned in tests/test_eval_fusion.py).
    """
    from repro.fitness import bbob
    sigma = jnp.asarray(sigma)
    Y = (Z * D[..., None, :]) @ jnp.swapaxes(B, -1, -2)
    X = m[..., None, :] + sigma[..., None, None] * Y
    return Y, bbob.separable_eval(X, sep)


def gen_sample_rng_eval(m: jnp.ndarray, sigma: jnp.ndarray, B: jnp.ndarray,
                        D: jnp.ndarray, seeds: jnp.ndarray, lam: int, sep):
    """Full residency ref: seeds → (Y, F), no host RNG and no X."""
    n = B.shape[-1]
    Z = sample_z_rng(seeds, lam, n, m.dtype)
    return gen_sample_eval(m, sigma, B, D, Z, sep)


def fused_update_from_gram(C: jnp.ndarray, B: jnp.ndarray, D: jnp.ndarray,
                           p_sigma: jnp.ndarray, p_c: jnp.ndarray,
                           gram: jnp.ndarray, y_w: jnp.ndarray,
                           c_sigma, mu_eff, c_c, c_1, c_mu, chi_n, gen1):
    """The post-dot half of ``fused_gen_update``: everything downstream of
    the gram-family contraction, O(n²) elementwise + two B GEMVs.

    Factored out so the cross-device strategies path (strategies.py
    KDistributed/KReplicated) can psum ONE √w-factored ``[gram | y_w]``
    tensor and run this epilogue replicated — the collectives path then
    executes the same fused form as the dense path instead of the unfused
    moments soup.  ``gram``/``y_w`` must already be normalized to unit
    total weight (the dense caller's weights sum to 1 by construction; the
    distributed caller divides the psum by the reduced weight total, which
    is semantically identical because both are linear in w).
    """
    n = C.shape[-1]
    dt = C.dtype
    whiten = B @ ((B.T @ y_w) / jnp.maximum(D, 1e-300))
    p_sigma_new = (1.0 - c_sigma) * p_sigma + jnp.sqrt(
        c_sigma * (2.0 - c_sigma) * mu_eff) * whiten
    ps_norm = jnp.linalg.norm(p_sigma_new)
    gen1 = jnp.asarray(gen1, dt)       # 1-based generation counter, as float
    h_sig_denom = jnp.sqrt(1.0 - (1.0 - c_sigma) ** (2.0 * gen1))
    h_sigma = (ps_norm / h_sig_denom / chi_n
               < 1.4 + 2.0 / (n + 1.0)).astype(dt)
    p_c_new = (1.0 - c_c) * p_c + h_sigma * jnp.sqrt(
        c_c * (2.0 - c_c) * mu_eff) * y_w
    decay = 1.0 - c_1 - c_mu + (1.0 - h_sigma) * c_1 * c_c * (2.0 - c_c)
    # gram and outer are symmetric by construction — no 0.5·(C + Cᵀ) pass
    C_new = decay * C + c_mu * gram + c_1 * p_c_new[:, None] * p_c_new[None, :]
    return C_new, p_sigma_new, p_c_new, y_w


def fused_gen_update(C: jnp.ndarray, B: jnp.ndarray, D: jnp.ndarray,
                     p_sigma: jnp.ndarray, p_c: jnp.ndarray, Y: jnp.ndarray,
                     w: jnp.ndarray, c_sigma, mu_eff, c_c, c_1, c_mu, chi_n,
                     gen1):
    """One CMA-ES generation's O(n²) state update, fused (paper §3.1 taken
    end-to-end).  Per-slot oracle of the Pallas megakernel.

    Collapses the former op soup — rank-μ gram, weighted-mean GEMV,
    covariance combine, p_c outer product, and the whitened-step GEMV
    ``C^{-1/2}·y_w = B·diag(1/D)·Bᵀ·y_w`` — into ONE gram-family
    dot-general plus two B GEMVs:

        [gram | y_w] = Y_sᵀ · [Y_s | √w],    Y_s = √w ⊙ Y         (n, n+1)

    so the λ-contraction runs once (HLO-pinned in tests/test_fused_gen.py)
    and C/B/D are each read once.  The √w factoring is the key perf move:
    every product feeding cell (i, j) equals the product feeding (j, i)
    (multiplication commutes), so the gram — and hence C' — is symmetric BY
    CONSTRUCTION and the unfused path's ``0.5·(C + Cᵀ)`` repair pass is
    dropped.  That transpose-add is memory-bound and dominated the whole
    per-generation update at large n (~85% of wall time at n = 1024 on
    CPU); the residual asymmetry here is ≤ machine-eps per generation at
    ragged shapes (edge-block reduction order), shrinks under ``decay < 1``
    instead of accumulating, and ``eigh`` reads a single triangle anyway.
    Inactive padded population rows carry zero weight and contribute
    nothing (the repo-wide masking convention; weights are non-negative by
    construction, so the √ is total).

    Returns ``(C_new, p_sigma_new, p_c_new, y_w)``; the caller finishes the
    O(n) scalar updates (mean, σ, bookkeeping — cmaes._finish_update).
    """
    n = C.shape[-1]
    # -- the one gram-family dot: rank-μ gram AND y_w ---------------------
    rw = jnp.sqrt(w)
    Ys = rw[:, None] * Y
    G = Ys.T @ jnp.concatenate([Ys, rw[:, None]], axis=1)  # (n, n+1)
    gram, y_w = G[:, :n], G[:, n]
    # -- whitened step + paths + covariance (shared with strategies.py) ---
    return fused_update_from_gram(C, B, D, p_sigma, p_c, gram, y_w,
                                  c_sigma, mu_eff, c_c, c_1, c_mu, chi_n,
                                  gen1)


# ---------------------------------------------------------------------------
# LM kernels
# ---------------------------------------------------------------------------

def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0) -> jnp.ndarray:
    """Materialized-softmax GQA attention oracle.  q (B,S,H,D); k/v (B,Skv,Hk,D)."""
    import jax
    B, S, H, D = q.shape
    Skv, Hk = k.shape[1], k.shape[2]
    rep = H // Hk
    qg = q.reshape(B, S, Hk, rep, D).astype(jnp.float32) * (D ** -0.5)
    logits = jnp.einsum("bshrd,bthd->bhrst", qg, k.astype(jnp.float32))
    q_ids = jnp.arange(S)[:, None]
    k_ids = jnp.arange(Skv)[None, :]
    mask = jnp.ones((S, Skv), bool)
    if causal:
        mask &= k_ids <= q_ids
    if window > 0:
        mask &= k_ids > q_ids - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhrst,bthd->bshrd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)


def wkv6(r, k, v, logw, u):
    """RWKV-6 WKV oracle — the chunked-parallel jnp form (models/rwkv6.py)."""
    from repro.models import rwkv6 as _rwkv6
    B, S, H, D = r.shape
    state0 = jnp.zeros((B, H, D, D), jnp.float32)
    o, _ = _rwkv6.wkv_chunked(r, k, v, logw, u, state0)
    return o
