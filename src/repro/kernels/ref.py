"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *reference implementations*: numerically straightforward XLA
programs.  They are also the default execution path on non-TPU backends (the
paper's "Level-3 BLAS" insight maps to plain einsum/matmul here, which XLA
lowers to MXU ops on TPU anyway — the Pallas kernels additionally fuse the
epilogues; see kernels/cma_update.py and kernels/cma_sample.py).
"""
from __future__ import annotations

import jax.numpy as jnp


def sample_transform(B: jnp.ndarray, D: jnp.ndarray, Z: jnp.ndarray) -> jnp.ndarray:
    """Y = Z · diag(D) · Bᵀ, i.e. y_k = B·(D ∘ z_k).   (paper eq. 1, batched)

    B: (n, n) eigenvectors; D: (n,) sqrt-eigenvalues; Z: (lam, n) ~ N(0, I).
    Returns Y: (lam, n).
    """
    return (Z * D[None, :]) @ B.T


def sample_points(m: jnp.ndarray, sigma: jnp.ndarray, B: jnp.ndarray,
                  D: jnp.ndarray, Z: jnp.ndarray) -> jnp.ndarray:
    """X = M + σ·(B·diag(D))·Z in row convention: (lam, n)."""
    return m[None, :] + sigma * sample_transform(B, D, Z)


def rank_mu_gram(Y: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Σᵢ wᵢ yᵢyᵢᵀ as one GEMM:  Aᵀ·B with A = Y, B = diag(w)·Y (paper eq. 3)."""
    return Y.T @ (w[:, None] * Y)


def covariance_combine(C: jnp.ndarray, gram: jnp.ndarray, p_c: jnp.ndarray,
                       decay: jnp.ndarray, c_mu: jnp.ndarray,
                       c_1: jnp.ndarray) -> jnp.ndarray:
    """C ← decay·C + c_μ·gram + c₁·p_c p_cᵀ   (paper eq. 3 epilogue)."""
    return decay * C + c_mu * gram + c_1 * jnp.outer(p_c, p_c)


def rank_mu_update(C: jnp.ndarray, Y: jnp.ndarray, w: jnp.ndarray,
                   p_c: jnp.ndarray, decay: jnp.ndarray, c_mu: jnp.ndarray,
                   c_1: jnp.ndarray) -> jnp.ndarray:
    """Fully fused covariance adaptation (what the Pallas kernel computes)."""
    return covariance_combine(C, rank_mu_gram(Y, w), p_c, decay, c_mu, c_1)


# ---------------------------------------------------------------------------
# LM kernels
# ---------------------------------------------------------------------------

def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0) -> jnp.ndarray:
    """Materialized-softmax GQA attention oracle.  q (B,S,H,D); k/v (B,Skv,Hk,D)."""
    import jax
    B, S, H, D = q.shape
    Skv, Hk = k.shape[1], k.shape[2]
    rep = H // Hk
    qg = q.reshape(B, S, Hk, rep, D).astype(jnp.float32) * (D ** -0.5)
    logits = jnp.einsum("bshrd,bthd->bhrst", qg, k.astype(jnp.float32))
    q_ids = jnp.arange(S)[:, None]
    k_ids = jnp.arange(Skv)[None, :]
    mask = jnp.ones((S, Skv), bool)
    if causal:
        mask &= k_ids <= q_ids
    if window > 0:
        mask &= k_ids > q_ids - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhrst,bthd->bshrd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)


def wkv6(r, k, v, logw, u):
    """RWKV-6 WKV oracle — the chunked-parallel jnp form (models/rwkv6.py)."""
    from repro.models import rwkv6 as _rwkv6
    B, S, H, D = r.shape
    state0 = jnp.zeros((B, H, D, D), jnp.float32)
    o, _ = _rwkv6.wkv_chunked(r, k, v, logw, u, state0)
    return o
