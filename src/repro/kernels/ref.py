"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *reference implementations*: numerically straightforward XLA
programs.  They are also the default execution path on non-TPU backends (the
paper's "Level-3 BLAS" insight maps to plain einsum/matmul here, which XLA
lowers to MXU ops on TPU anyway — the Pallas kernels additionally fuse the
epilogues; see kernels/cma_update.py and kernels/cma_sample.py).
"""
from __future__ import annotations

import jax.numpy as jnp


def sample_transform(B: jnp.ndarray, D: jnp.ndarray, Z: jnp.ndarray) -> jnp.ndarray:
    """Y = Z · diag(D) · Bᵀ, i.e. y_k = B·(D ∘ z_k).   (paper eq. 1, batched)

    B: (n, n) eigenvectors; D: (n,) sqrt-eigenvalues; Z: (lam, n) ~ N(0, I).
    Returns Y: (lam, n).
    """
    return (Z * D[None, :]) @ B.T


def sample_points(m: jnp.ndarray, sigma: jnp.ndarray, B: jnp.ndarray,
                  D: jnp.ndarray, Z: jnp.ndarray) -> jnp.ndarray:
    """X = M + σ·(B·diag(D))·Z in row convention: (lam, n)."""
    return m[None, :] + sigma * sample_transform(B, D, Z)


def rank_mu_gram(Y: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Σᵢ wᵢ yᵢyᵢᵀ as one GEMM:  Aᵀ·B with A = Y, B = diag(w)·Y (paper eq. 3)."""
    return Y.T @ (w[:, None] * Y)


def covariance_combine(C: jnp.ndarray, gram: jnp.ndarray, p_c: jnp.ndarray,
                       decay: jnp.ndarray, c_mu: jnp.ndarray,
                       c_1: jnp.ndarray) -> jnp.ndarray:
    """C ← decay·C + c_μ·gram + c₁·p_c p_cᵀ   (paper eq. 3 epilogue)."""
    return decay * C + c_mu * gram + c_1 * jnp.outer(p_c, p_c)


def rank_mu_update(C: jnp.ndarray, Y: jnp.ndarray, w: jnp.ndarray,
                   p_c: jnp.ndarray, decay: jnp.ndarray, c_mu: jnp.ndarray,
                   c_1: jnp.ndarray) -> jnp.ndarray:
    """Fully fused covariance adaptation (what the Pallas kernel computes)."""
    return covariance_combine(C, rank_mu_gram(Y, w), p_c, decay, c_mu, c_1)


# ---------------------------------------------------------------------------
# fused generation step (oracles for kernels/cma_gen.py)
# ---------------------------------------------------------------------------

def gen_sample(m: jnp.ndarray, sigma: jnp.ndarray, B: jnp.ndarray,
               D: jnp.ndarray, Z: jnp.ndarray):
    """Fused sampling: (Y, X) = (Z·diag(D)·Bᵀ, m + σ·Y) in one pass.

    Accepts either per-slot arrays (m (n,), sigma (), B (n,n), Z (lam,n))
    or slot-stacked arrays with one leading axis (m (S,n), sigma (S,), ...);
    the Pallas form (kernels/cma_gen.py) maps that slot axis onto its
    leading grid dimension.
    """
    sigma = jnp.asarray(sigma)
    Y = (Z * D[..., None, :]) @ jnp.swapaxes(B, -1, -2)
    X = m[..., None, :] + sigma[..., None, None] * Y
    return Y, X


def fused_gen_update(C: jnp.ndarray, B: jnp.ndarray, D: jnp.ndarray,
                     p_sigma: jnp.ndarray, p_c: jnp.ndarray, Y: jnp.ndarray,
                     w: jnp.ndarray, c_sigma, mu_eff, c_c, c_1, c_mu, chi_n,
                     gen1):
    """One CMA-ES generation's O(n²) state update, fused (paper §3.1 taken
    end-to-end).  Per-slot oracle of the Pallas megakernel.

    Collapses the former op soup — rank-μ gram, weighted-mean GEMV,
    covariance combine, p_c outer product, and the whitened-step GEMV
    ``C^{-1/2}·y_w = B·diag(1/D)·Bᵀ·y_w`` — into ONE gram-family
    dot-general plus two B GEMVs:

        [gram | y_w] = Y_sᵀ · [Y_s | √w],    Y_s = √w ⊙ Y         (n, n+1)

    so the λ-contraction runs once (HLO-pinned in tests/test_fused_gen.py)
    and C/B/D are each read once.  The √w factoring is the key perf move:
    every product feeding cell (i, j) equals the product feeding (j, i)
    (multiplication commutes), so the gram — and hence C' — is symmetric BY
    CONSTRUCTION and the unfused path's ``0.5·(C + Cᵀ)`` repair pass is
    dropped.  That transpose-add is memory-bound and dominated the whole
    per-generation update at large n (~85% of wall time at n = 1024 on
    CPU); the residual asymmetry here is ≤ machine-eps per generation at
    ragged shapes (edge-block reduction order), shrinks under ``decay < 1``
    instead of accumulating, and ``eigh`` reads a single triangle anyway.
    Inactive padded population rows carry zero weight and contribute
    nothing (the repo-wide masking convention; weights are non-negative by
    construction, so the √ is total).

    Returns ``(C_new, p_sigma_new, p_c_new, y_w)``; the caller finishes the
    O(n) scalar updates (mean, σ, bookkeeping — cmaes._finish_update).
    """
    n = C.shape[-1]
    dt = C.dtype
    # -- the one gram-family dot: rank-μ gram AND y_w ---------------------
    rw = jnp.sqrt(w)
    Ys = rw[:, None] * Y
    G = Ys.T @ jnp.concatenate([Ys, rw[:, None]], axis=1)  # (n, n+1)
    gram, y_w = G[:, :n], G[:, n]
    # -- whitened step (old factorization, as in update_from_moments) -----
    whiten = B @ ((B.T @ y_w) / jnp.maximum(D, 1e-300))
    p_sigma_new = (1.0 - c_sigma) * p_sigma + jnp.sqrt(
        c_sigma * (2.0 - c_sigma) * mu_eff) * whiten
    ps_norm = jnp.linalg.norm(p_sigma_new)
    gen1 = jnp.asarray(gen1, dt)       # 1-based generation counter, as float
    h_sig_denom = jnp.sqrt(1.0 - (1.0 - c_sigma) ** (2.0 * gen1))
    h_sigma = (ps_norm / h_sig_denom / chi_n
               < 1.4 + 2.0 / (n + 1.0)).astype(dt)
    p_c_new = (1.0 - c_c) * p_c + h_sigma * jnp.sqrt(
        c_c * (2.0 - c_c) * mu_eff) * y_w
    decay = 1.0 - c_1 - c_mu + (1.0 - h_sigma) * c_1 * c_c * (2.0 - c_c)
    # gram and outer are symmetric by construction — no 0.5·(C + Cᵀ) pass
    C_new = decay * C + c_mu * gram + c_1 * p_c_new[:, None] * p_c_new[None, :]
    return C_new, p_sigma_new, p_c_new, y_w


# ---------------------------------------------------------------------------
# LM kernels
# ---------------------------------------------------------------------------

def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0) -> jnp.ndarray:
    """Materialized-softmax GQA attention oracle.  q (B,S,H,D); k/v (B,Skv,Hk,D)."""
    import jax
    B, S, H, D = q.shape
    Skv, Hk = k.shape[1], k.shape[2]
    rep = H // Hk
    qg = q.reshape(B, S, Hk, rep, D).astype(jnp.float32) * (D ** -0.5)
    logits = jnp.einsum("bshrd,bthd->bhrst", qg, k.astype(jnp.float32))
    q_ids = jnp.arange(S)[:, None]
    k_ids = jnp.arange(Skv)[None, :]
    mask = jnp.ones((S, Skv), bool)
    if causal:
        mask &= k_ids <= q_ids
    if window > 0:
        mask &= k_ids > q_ids - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhrst,bthd->bshrd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)


def wkv6(r, k, v, logw, u):
    """RWKV-6 WKV oracle — the chunked-parallel jnp form (models/rwkv6.py)."""
    from repro.models import rwkv6 as _rwkv6
    B, S, H, D = r.shape
    state0 = jnp.zeros((B, H, D, D), jnp.float32)
    o, _ = _rwkv6.wkv_chunked(r, k, v, logw, u, state0)
    return o
