"""Pallas TPU kernel: fused CMA-ES covariance adaptation (paper eq. 3).

    C' = decay·C + c_μ · Σ_i w_i·yᵢyᵢᵀ + c₁·p_c p_cᵀ

The paper's key linear-algebra contribution is rewriting the λ rank-one
covariance updates as ONE rank-λ GEMM (A·B with A = [yᵢ], B = [w_i·yᵢᵀ]) so a
Level-3 BLAS can be used.  The TPU-native version tiles that GEMM onto the MXU
and — unlike the dgemm + two scaled-add passes the paper's C code needs —
fuses the decay term and the rank-one p_c p_cᵀ term into the output epilogue,
so C is read and written exactly once from HBM.

Layout: out[i, j] = decay·C[i,j] + c_μ·Σ_k w[k]·Y[k,i]·Y[k,j] + c₁·pc[i]·pc[j]
Grid: (n/bi, n/bj, λ/bk) — k innermost, accumulation in a VMEM scratch tile.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import jax.numpy as jnp


def _kernel(coef_ref, yi_ref, yj_ref, w_ref, c_ref, pci_ref, pcj_ref,
            out_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    yi = yi_ref[...].astype(jnp.float32)        # (bk, bi)
    yj = yj_ref[...].astype(jnp.float32)        # (bk, bj)
    w = w_ref[...].astype(jnp.float32)          # (bk,)
    # (bi, bj) += Yᵢᵀ · diag(w) · Yⱼ — one MXU contraction per k-step
    acc_ref[...] += jax.lax.dot_general(
        yi, yj * w[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        decay, c_mu, c_1 = coef_ref[0], coef_ref[1], coef_ref[2]
        c = c_ref[...].astype(jnp.float32)       # (bi, bj)
        pci = pci_ref[...].astype(jnp.float32)   # (bi,)
        pcj = pcj_ref[...].astype(jnp.float32)   # (bj,)
        out = decay * c + c_mu * acc_ref[...] + c_1 * pci[:, None] * pcj[None, :]
        out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bi", "bj", "bk", "interpret"))
def cma_rank_mu_update(C: jnp.ndarray, Y: jnp.ndarray, w: jnp.ndarray,
                       p_c: jnp.ndarray, decay, c_mu, c_1, *, bi: int = 128,
                       bj: int = 128, bk: int = 128,
                       interpret: bool = False) -> jnp.ndarray:
    """Fused covariance adaptation.  Y: (λ, n) rows are yᵢ; w: (λ,) rank weights."""
    lam, n = Y.shape
    dt = C.dtype
    bi = min(bi, n)
    bj = min(bj, n)
    bk = min(bk, max(8, lam))
    p_n_i = -(-n // bi) * bi
    p_n_j = -(-n // bj) * bj
    p_n = max(p_n_i, p_n_j)
    p_lam = -(-lam // bk) * bk
    Yp = jnp.zeros((p_lam, p_n), dt).at[:lam, :n].set(Y)
    wp = jnp.zeros((p_lam,), dt).at[:lam].set(w)        # zero weight ⇒ no effect
    Cp = jnp.zeros((p_n, p_n), dt).at[:n, :n].set(C)
    pcp = jnp.zeros((p_n,), dt).at[:n].set(p_c)
    coef = jnp.stack([jnp.asarray(decay, jnp.float32),
                      jnp.asarray(c_mu, jnp.float32),
                      jnp.asarray(c_1, jnp.float32)])

    n_i, n_j, n_k = p_n // bi, p_n // bj, p_lam // bk
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(n_i, n_j, n_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                # coef (3,)
            pl.BlockSpec((bk, bi), lambda i, j, k: (k, i)),       # Y (rows i)
            pl.BlockSpec((bk, bj), lambda i, j, k: (k, j)),       # Y (rows j)
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),            # w
            pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),       # C
            pl.BlockSpec((bi,), lambda i, j, k: (i,)),            # p_c rows
            pl.BlockSpec((bj,), lambda i, j, k: (j,)),            # p_c cols
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p_n, p_n), dt),
        scratch_shapes=[pltpu.VMEM((bi, bj), jnp.float32)],
        interpret=interpret,
    )(coef, Yp, Yp, wp, Cp, pcp, pcp)
    return out[:n, :n]
