"""Pallas TPU kernel: batched CMA-ES sampling   X = M + σ·(B·diag(D))·Z.

The paper (§3.1) rewrites the per-point sampling (eq. 1) as one Level-3 BLAS
GEMM over the whole population.  On TPU the analogous move is an MXU-tiled
matmul; this kernel additionally fuses the diag(D) scaling (a VPU multiply on
the loaded Z tile — zero extra HBM traffic) and the `m + σ·(·)` epilogue that
BLAS required separate axpy-style passes for.

Layout:  out[l, j] = m[j] + σ · Σ_k Z[l, k]·D[k]·B[j, k]
Grid: (lam/bl, n/bj, n/bk) — k innermost so each output tile accumulates in
VMEM across the contraction; epilogue applied on the last k step.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import jax.numpy as jnp


def _kernel(coef_ref, z_ref, d_ref, b_ref, m_ref, x_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    z = z_ref[...].astype(jnp.float32)          # (bl, bk)
    d = d_ref[...].astype(jnp.float32)          # (bk,)
    b = b_ref[...].astype(jnp.float32)          # (bj, bk)
    acc_ref[...] += jax.lax.dot_general(
        z * d[None, :], b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        sigma = coef_ref[0]
        m = m_ref[...].astype(jnp.float32)       # (bj,)
        x_ref[...] = (m[None, :] + sigma * acc_ref[...]).astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bl", "bj", "bk", "interpret"))
def cma_sample(m: jnp.ndarray, sigma: jnp.ndarray, B: jnp.ndarray,
               D: jnp.ndarray, Z: jnp.ndarray, *, bl: int = 128, bj: int = 128,
               bk: int = 128, interpret: bool = False) -> jnp.ndarray:
    """X = m + σ·(B·diag(D))·Z, row convention (lam, n).  Pads to block shape."""
    lam, n = Z.shape
    dt = Z.dtype
    bl = min(bl, max(8, lam))
    bj = min(bj, n)
    bk = min(bk, n)
    pl_lam = -(-lam // bl) * bl
    pl_n = -(-n // bj) * bj
    pk_n = -(-n // bk) * bk
    if pl_n != pk_n:
        pl_n = pk_n = max(pl_n, pk_n)
    Zp = jnp.zeros((pl_lam, pk_n), dt).at[:lam, :n].set(Z)
    Bp = jnp.zeros((pl_n, pk_n), dt).at[:n, :n].set(B)
    Dp = jnp.zeros((pk_n,), dt).at[:n].set(D)
    Mp = jnp.zeros((pl_n,), dt).at[:n].set(m)
    coef = jnp.asarray([sigma], jnp.float32)

    n_l, n_j, n_k = pl_lam // bl, pl_n // bj, pk_n // bk
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(n_l, n_j, n_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),               # coef (1,)
            pl.BlockSpec((bl, bk), lambda l, j, k: (l, k)),      # Z
            pl.BlockSpec((bk,), lambda l, j, k: (k,)),           # D
            pl.BlockSpec((bj, bk), lambda l, j, k: (j, k)),      # B
            pl.BlockSpec((bj,), lambda l, j, k: (j,)),           # m
        ],
        out_specs=pl.BlockSpec((bl, bj), lambda l, j, k: (l, j)),
        out_shape=jax.ShapeDtypeStruct((pl_lam, pl_n), dt),
        scratch_shapes=[pltpu.VMEM((bl, bj), jnp.float32)],
        interpret=interpret,
    )(coef, Zp, Dp, Bp, Mp)
    return out[:lam, :n]
