"""Mesh campaign engine — the paper's two deployment strategies (§4–5).

The rung-bucketed engine (core/bucketed.py) is mesh-ready in shape: a small
family of fixed per-bucket programs, host syncs only between segments.  This
module deploys it across a device mesh, implementing both of the paper's
strategies for running many IPOP-CMA-ES searches on a large machine:

* ``strategy="ordered"`` (S1 — sequential order): the campaign members
  shard over a 1-d ``("camp",)`` mesh axis and EVERY segment runs as one
  ``shard_map`` program over the whole mesh — all shards advance through the
  same global segment schedule with a barrier per segment (the host pull
  that re-buckets), preserving the bucketed driver's sequential rung
  ordering exactly.  Inside the program each device vmaps
  ``BucketedLadderEngine.segment_scan`` over its member slice; the budget
  and best-f scalars are reduced with replicated ``psum``/``pmin`` so every
  shard (and the host) sees the campaign-global values.
* ``strategy="concurrent"`` (S2 — the paper's winner): each device is an
  island owning a contiguous member slice and drives its OWN budget-adaptive
  segment schedule — the host round-robins over islands, dispatching each
  one's next bucket program asynchronously (dispatch returns before the
  segment finishes, so islands genuinely overlap); between segments the
  islands exchange only the global best/budget scalars.  No barrier: a
  shard whose members finished stops paying for the stragglers' schedule,
  which is exactly where the paper's S2 wins super-linearly.  With
  ``stop_at`` set, the global-best exchange also retires every island as
  soon as any island reaches the target (S2's early-sharing win; off by
  default to keep strict equivalence with the single-device driver).

Compilation stays bounded by the bucket family: segment lengths are fixed
once per (campaign, bucket), so the ordered path holds ``compiles ≤
#buckets`` at the jit-cache level, and the concurrent path traces at most
one program per bucket (each island then holds its device's executable copy
of that same traced program — copies, not new programs; ``compiles()``
counts traced programs).

Equivalence with ``backend="bucketed"`` (tests/mesh_check.py, run on 8
virtual CPU devices): member trajectories depend only on their own
(slot, incarnation, generation) key schedule and row-keyed sampling, never
on which shard or segment executed them — so at ``eigen_interval == 1``
both strategies are trajectory-equivalent to the single-device driver
(modulo per-shape XLA fusion rounding, the tolerance every engine pair here
carries), and at ``eigen_interval > 1`` (segment-local eigen cadence, and
shard-local segment cuts under S2) they are ECDF-equivalent.

Between segments the scheduling arrays ride ONE
``multihost_utils.process_allgather`` call (``k_idx``, ``active``,
``total_fevals``, ``best_f`` as a single tree) — multi-process ready, and on
a single process exactly the batched ``device_get`` the bucketed driver
uses.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import numpy as np

from repro import obs
from repro.core import bucketed, ladder
from repro.core.eval_dispatch import shard_map_compat
from repro.distributed.sharding import campaign_shardings
from repro.fitness import bbob
from repro.launch.mesh import make_campaign_mesh


def _finite_or_none(x: float):
    """Strict-JSON-safe scalar for logged records (json.dump emits a bare
    ``Infinity`` token otherwise): None until a best exists."""
    x = float(x)
    return x if np.isfinite(x) else None


# ---------------------------------------------------------------------------
# island program cache (S2)
# ---------------------------------------------------------------------------
# S2 island bring-up used to be O(buckets·P) *per driver call*: `island_runner`
# cached its jitted programs on the engine instance (or, for baked-in fitness
# closures, in a dict that died with the call), so every new campaign, every
# `run_mesh_single`, and every round of a long-lived service re-traced the
# same bucket programs.  Executables are now keyed here, at module level, by a
# compilation-cache key — everything that determines the compiled program:
# the bucket's full CMAConfig (shape + trajectory knobs), the engine's ladder
# geometry/budget/impl, the segment length, the fitness identity (the static
# BBOB fid set, or the closure OBJECT for generic runs — keying by object
# removes the stale-closure hazard that forced the per-call caches), and the
# mesh's device fingerprint.  Per-island dispatch therefore reuses ONE traced
# program per bucket for the life of the process; the per-device executables
# XLA still wants live inside that single callable's jit cache and fill
# lazily, only for islands that actually run the bucket.  The campaign
# service's segment programs ride the same class (service/server.py).


def _contains_callable(x) -> bool:
    return callable(x) or (isinstance(x, tuple)
                           and any(_contains_callable(i) for i in x))


class ProgramCache:
    """Process-wide compiled-program cache with closure-aware eviction.

    Entries whose key embeds a callable (a fitness closure, a service
    registry) keep that closure — and everything its cells capture — alive;
    unbounded, a long-lived process that builds a fresh closure per call
    would leak one traced program per (closure, bucket) forever.  Those
    entries are therefore capped at ``max_closure_entries`` with FIFO
    eviction (evicting a live program only costs a re-trace on its next
    use); purely-static keys (BBOB fid sets + config scalars) are bounded by
    the configuration space and never evicted.
    """

    def __init__(self, max_closure_entries: int = 64):
        self.max_closure_entries = int(max_closure_entries)
        self._programs: Dict[tuple, Callable] = {}
        self.stats = {"traces": 0, "hits": 0}

    def get(self, key: tuple, build: Callable[[], Callable]) -> Callable:
        fn = self._programs.get(key)
        if fn is not None:
            self.stats["hits"] += 1
            return fn
        fn = build()
        self._programs[key] = fn
        self.stats["traces"] += 1
        if _contains_callable(key):
            closure_keys = [k for k in self._programs
                            if _contains_callable(k)]
            for k in closure_keys[:max(0, len(closure_keys)
                                       - self.max_closure_entries)]:
                del self._programs[k]
        return fn

    def snapshot(self) -> dict:
        return {"programs": len(self._programs), **self.stats}

    def clear(self):
        self._programs.clear()
        self.stats.update(traces=0, hits=0)


_ISLAND_CACHE = ProgramCache()


def island_program_key(eng: bucketed.BucketedLadderEngine, k: int,
                       seg_gens: int, branch_fids: Tuple[int, ...],
                       fitness_fn: Optional[Callable], devices) -> tuple:
    """Compilation-cache key of one island segment program (bucket shape +
    mesh) — hashable because ``CMAConfig`` is a frozen dataclass of scalars."""
    fit_id = tuple(branch_fids) if fitness_fn is None else fitness_fn
    return (eng.bucket_cfgs[k], eng.lam_start, eng.kmax_exp, eng.max_evals,
            tuple(eng.domain), eng.impl, int(k), int(seg_gens), fit_id,
            bbob.eval_fusion_enabled(),
            tuple((d.platform, d.id) for d in devices))


def island_cache_stats() -> dict:
    """{"programs": live cached programs, "traces": total traced,
    "hits": cache hits} — island bring-up is O(buckets) iff ``traces`` stops
    growing across campaigns (asserted in tests/test_mesh_engine.py)."""
    return _ISLAND_CACHE.snapshot()


def clear_island_program_cache():
    """Drop all cached island programs (tests; also frees the engines the
    program closures keep alive)."""
    _ISLAND_CACHE.clear()


def pull_schedule_allgather(carry: ladder.LadderCarry):
    """Mesh variant of ``bucketed.pull_schedule``: the four scheduling arrays
    cross the device boundary as ONE ``process_allgather`` of a single tree
    (global views of the sharded members), not four blocking per-array
    ``np.asarray`` pulls.  Single-process this is the same batched
    ``device_get``; multi-process it is the collective the ROADMAP named."""
    from jax.experimental import multihost_utils

    k_idx, active, fevals, best_f = multihost_utils.process_allgather(
        (carry.k_idx[..., 0], carry.active[..., 0],
         carry.total_fevals, carry.best_f), tiled=True)
    return (np.atleast_1d(k_idx), np.atleast_1d(active),
            np.atleast_1d(fevals), np.atleast_1d(best_f))


@dataclasses.dataclass
class MeshCampaignEngine:
    """Bucketed-ladder campaigns sharded over a ``("camp",)`` device mesh.

    Wraps a ``BucketedLadderEngine`` (which owns the bucket configs, segment
    sizing and single-device semantics); this engine only decides WHERE each
    segment program runs and how shards synchronize — per the two paper
    strategies above.  ``mesh`` defaults to all local devices
    (``launch.mesh.make_campaign_mesh``).
    """

    n: int
    lam_start: int = 12
    kmax_exp: int = 4
    max_evals: int = 200_000
    domain: Tuple[float, float] = (-5.0, 5.0)
    sigma0_frac: float = 0.25
    impl: str = "auto"                  # kernel dispatch — see kernels/ops.py
    dtype: str = "float64"
    eigen_interval: Optional[int] = None
    seg_blocks: Optional[int] = None
    policy: str = "cover"
    strategy: str = "ordered"           # "ordered" (S1) | "concurrent" (S2)
    mesh: Optional[object] = None       # jax.sharding.Mesh over axis "camp"
    axis: str = "camp"
    stop_at: Optional[float] = None     # S2 early-stop on the shared best
    overlap: bool = True                # S1 speculative double-buffered
                                        # dispatch (exchange scalars fold
                                        # lazily at the boundary pull)

    def __post_init__(self):
        if self.strategy not in ("ordered", "concurrent"):
            raise ValueError(f"unknown strategy {self.strategy!r}")
        self.bucketed = bucketed.BucketedLadderEngine(
            n=self.n, lam_start=self.lam_start, kmax_exp=self.kmax_exp,
            max_evals=self.max_evals, domain=self.domain,
            sigma0_frac=self.sigma0_frac, impl=self.impl, dtype=self.dtype,
            eigen_interval=self.eigen_interval, seg_blocks=self.seg_blocks,
            policy=self.policy)
        if self.mesh is None:
            self.mesh = make_campaign_mesh()
        self.n_devices = int(self.mesh.devices.size)
        self._runner_cache: dict = {}
        self._island_keys: set = set()

    # -- segment programs -----------------------------------------------------
    def _seg_fn(self, k: int, seg_gens: int, branch_fids: Tuple[int, ...],
                fitness_fn: Optional[Callable]):
        """The vmapped local segment body shared by both strategies: one
        device's member slice through ``segment_scan``.  With ``branch_fids``
        the fitness is the stacked-instance BBOB dispatch (campaigns); with
        ``fitness_fn`` it is a baked-in closure (generic single runs)."""
        eng = self.bucketed
        if fitness_fn is None:
            def run_one(base_key, inst, c):
                def fit(X):
                    return bbob.evaluate_dynamic(inst, X, branch_fids)
                return eng.segment_scan(
                    k, base_key, bbob.fusable_fitness(inst, branch_fids, fit),
                    c, seg_gens)
            return jax.vmap(run_one)

        def run_one(base_key, c):
            return eng.segment_scan(k, base_key, fitness_fn, c, seg_gens)
        return jax.vmap(run_one)

    def ordered_runner(self, k: int, seg_gens: int,
                       branch_fids: Tuple[int, ...] = (),
                       fitness_fn: Optional[Callable] = None,
                       cache: Optional[dict] = None):
        """One S1 segment as a ``shard_map`` program over the whole mesh:
        member batch sharded over ``axis``, budget/best scalars psum/pmin-
        reduced to replicated outputs.  Cached per (bucket, length, fids) —
        jit-cache size 1 per entry, so ``compiles ≤ #buckets`` holds at the
        executable level (asserted in tests/mesh_check.py)."""
        cache = self._runner_cache if cache is None else cache
        key = ("ordered", int(k), int(seg_gens), tuple(branch_fids),
               bbob.eval_fusion_enabled())
        if key not in cache:
            axis = self.axis
            vmapped = self._seg_fn(k, seg_gens, branch_fids, fitness_fn)
            n_args = 2 if fitness_fn is not None else 3

            def local_seg(*args):
                c, tr = vmapped(*args)
                g_fev = jax.lax.psum(jnp.sum(c.total_fevals), axis)
                g_best = jax.lax.pmin(jnp.min(c.best_f), axis)
                return c, tr, g_fev, g_best

            fn = shard_map_compat(
                local_seg, mesh=self.mesh,
                in_specs=(P(axis),) * n_args,
                out_specs=(P(axis), P(axis), P(), P()))
            # explicit in/out shardings pin the jit cache key: without them a
            # 1-device mesh canonicalizes P(axis) outputs to P(), and feeding
            # a segment's carry back in recompiles the same bucket program
            sh_c = jax.sharding.NamedSharding(self.mesh, P(axis))
            sh_r = jax.sharding.NamedSharding(self.mesh, P())
            cache[key] = jax.jit(fn, in_shardings=(sh_c,) * n_args,
                                 out_shardings=(sh_c, sh_c, sh_r, sh_r))
        return cache[key]

    def island_runner(self, k: int, seg_gens: int,
                      branch_fids: Tuple[int, ...] = (),
                      fitness_fn: Optional[Callable] = None):
        """One S2 segment as a plain jitted program over one island's member
        slice; dispatching it on inputs committed to island ``s``'s device
        runs it there, asynchronously.  Programs come from the module-level
        compilation-cache (``island_program_key``): one traced program per
        (bucket shape, mesh) reused across islands, campaigns and engine
        instances — island bring-up is O(buckets), not O(buckets·calls)."""
        key = island_program_key(self.bucketed, k, seg_gens, branch_fids,
                                 fitness_fn, self.mesh.devices.flat)
        traces0 = _ISLAND_CACHE.stats["traces"]
        with obs.tracer().span("compile", key=f"island.k{k}.g{seg_gens}") \
                as sp:
            fn = _ISLAND_CACHE.get(key, lambda: jax.jit(
                self._seg_fn(k, seg_gens, branch_fids, fitness_fn)))
            sp.attrs["hit"] = _ISLAND_CACHE.stats["traces"] == traces0
        self._island_keys.add(key)
        return fn

    def compiles(self) -> int:
        """Distinct segment programs this engine used: jit-cache entries for
        ordered runners (always 1 each — same shardings every call), one per
        island program key (counted as used even on a module-cache hit, so
        the ``compiles ≤ #buckets`` bound stays meaningful per campaign;
        process-wide reuse shows up in ``island_cache_stats`` instead)."""
        total = len(self._island_keys)
        for key, fn in self._runner_cache.items():
            if key[0] == "ordered":
                cs = getattr(fn, "_cache_size", None)
                total += int(cs()) if callable(cs) else 1
        return total

    # -- member layout --------------------------------------------------------
    def pad_batch(self, keys: jax.Array, carry: ladder.LadderCarry,
                  insts=None):
        """Pad the member batch to a multiple of the mesh size with inert
        rows: ``active=False`` from the start, so they never run a
        generation, spend budget, or win a pmin — results slice back to the
        real members.  Returns (keys, carry, insts, B_real, B_pad)."""
        B = int(keys.shape[0])
        P_n = self.n_devices
        B_pad = -(-B // P_n) * P_n
        if B_pad != B:
            pad = B_pad - B
            pad_keys = jnp.stack([jax.random.fold_in(keys[-1], 1 + j)
                                  for j in range(pad)])
            keys = jnp.concatenate([keys, pad_keys])
            carry = jax.tree_util.tree_map(
                lambda a: jnp.concatenate(
                    [a, jnp.repeat(a[-1:], pad, axis=0)]), carry)
            if insts is not None:
                insts = jax.tree_util.tree_map(
                    lambda a: jnp.concatenate(
                        [a, jnp.repeat(a[-1:], pad, axis=0)]), insts)
        active = jnp.asarray(carry.active)
        mask = (jnp.arange(B_pad) < B)[:, None]
        carry = carry._replace(active=active & mask)
        return keys, carry, insts, B, B_pad

    # -- drivers --------------------------------------------------------------
    def _drive_ordered(self, keys, insts, carry, branch_fids, fitness_fn,
                       max_segments: int, supervisor=None):
        """S1: the bucketed re-bucketing loop verbatim (``drive_segments``),
        with shard_map dispatch and the allgather pull.

        The psum'd exchange scalars are folded LAZILY: ``dispatch`` leaves
        them device-resident (keyed by the segment's output carry) and the
        boundary pull — which already blocks on that same segment's carry —
        folds the matching entry afterwards, when the values are guaranteed
        ready and ``int()`` costs a ready-buffer read instead of a device
        round-trip.  With nothing in ``dispatch`` blocking on its own
        outputs, S1 runs the bucketed driver's speculative double-buffered
        dispatch (``engine.overlap``, default on): trajectories are
        bit-identical (a mispredicted segment's output — and its pending
        exchange entry — is discarded without ever being forced), and each
        accepted segment still produces exactly one exchange record."""
        shd = campaign_shardings(keys, self.mesh, self.axis)
        keys = jax.device_put(keys, shd)
        carry = jax.tree_util.tree_map(
            lambda a: jax.device_put(a, shd), carry)
        if insts is not None:
            insts = jax.tree_util.tree_map(
                lambda a: jax.device_put(a, shd), insts)
        local_cache = None if fitness_fn is None else {}
        exchange: List[dict] = []
        reg = obs.metrics()
        # pending exchange scalars, matched to the accepted carry by object
        # identity (holding the array also pins its id against reuse)
        inflight: List[tuple] = []

        def dispatch(k, seg_gens, c):
            runner = self.ordered_runner(k, seg_gens, branch_fids,
                                         fitness_fn, cache=local_cache)
            args = (keys, c) if insts is None else (keys, insts, c)
            # no island attr on purpose: drive_segments already covers this
            # wall with its island="all" segment span — a second island-
            # attributed span would double-count busy time in the digest
            sp = obs.tracer().start("dispatch", strategy="ordered",
                                    bucket=int(k))
            t0 = time.perf_counter()
            c, tr, g_fev, g_best = runner(*args)
            obs.tracer().end(sp)
            reg.histogram("mesh_island_dispatch_s", strategy="ordered",
                          island="all").observe(time.perf_counter() - t0)
            inflight.append((c.total_fevals, int(k), g_fev, g_best))
            return c, tr

        def pull(c):
            res = pull_schedule_allgather(c)
            for i, (arr, k, g_fev, g_best) in enumerate(inflight):
                if arr is c.total_fevals:
                    t0 = time.perf_counter()
                    exchange.append({
                        "bucket": k, "global_fevals": int(g_fev),
                        "global_best": _finite_or_none(g_best)})
                    reg.histogram("mesh_exchange_s", strategy="ordered"
                                  ).observe(time.perf_counter() - t0)
                    reg.counter("mesh_exchange_rounds_total",
                                strategy="ordered").inc()
                    # anything dispatched before the accepted segment can
                    # never be pulled again — mispredicted spec entries drop
                    del inflight[:i + 1]
                    break
            return res

        # every accepted segment is folded: the loop always pulls the carry
        # it just accepted before deciding whether another bucket exists
        # (a supervisor sees S1 as ONE island — its failure domain is the
        # whole mesh program, so recovery restarts the whole-batch carry)
        carry, trace, segments, bucket_wall = bucketed.drive_segments(
            self.bucketed, carry, dispatch, max_segments,
            time_axis=1, pull=pull, overlap=self.overlap,
            supervisor=supervisor)
        return carry, trace, segments, bucket_wall, exchange, None

    def _drive_concurrent(self, keys, insts, carry, branch_fids, fitness_fn,
                          max_segments: int, supervisor=None):
        """S2: one island per device, each with its own re-bucketing loop;
        the host round-robins dispatches (async — islands overlap) and folds
        the per-island budget/best scalars into the shared campaign view.

        ``supervisor`` (``repro.fleet``) supervises each island: periodic
        host snapshots of shard state, kill/delay/corrupt fault application,
        health grading of the per-island pulls, and recovery by replay —
        a killed shard's snapshot is device_put onto a surviving device and
        re-driven (identical trajectories: shard state is complete and
        sampling row-keyed).  ``None`` (default) costs one host ``if`` per
        hook site."""
        eng = self.bucketed
        devs = list(self.mesh.devices.flat)
        P_n = len(devs)
        B_pad = int(keys.shape[0])
        Bl = B_pad // P_n

        shards = []
        for s, dev in enumerate(devs):
            sl = slice(s * Bl, (s + 1) * Bl)

            def put(a, _sl=sl, _dev=dev):
                return jax.device_put(a[_sl], _dev)

            shards.append({
                "keys": put(keys),
                "insts": None if insts is None
                else jax.tree_util.tree_map(put, insts),
                "carry": jax.tree_util.tree_map(put, carry),
                "traces": [], "segments": [], "done": False,
                "best": np.inf, "fevals": 0,
            })

        seg_len: Dict[int, int] = {}    # shared per bucket: compiles ≤ #buckets
        bucket_wall: Dict[int, float] = {}
        exchange: List[dict] = []
        reg = obs.metrics()
        if supervisor is not None:
            supervisor.mesh_init(shards, devs)
        for rnd in range(max_segments):
            if supervisor is not None:
                supervisor.mesh_round(rnd, shards, devs)
            dispatched = retired = finished = 0
            for s, sh in enumerate(shards):
                if sh["done"]:
                    continue
                blk = obs.tracer().start("block", island=s, boundary=rnd)
                t0 = time.perf_counter()
                if supervisor is not None:
                    k_idx, active, fevals, best_f = supervisor.pull(
                        s, rnd,
                        lambda _c=sh["carry"]: bucketed.pull_schedule(_c))
                else:
                    k_idx, active, fevals, best_f = bucketed.pull_schedule(
                        sh["carry"])             # blocks on THIS island only
                obs.tracer().end(blk)
                reg.histogram("mesh_island_block_s",
                              island=s).observe(time.perf_counter() - t0)
                sh["best"] = float(best_f.min())
                sh["fevals"] = int(fevals.sum())
                if self.stop_at is not None and \
                        min(x["best"] for x in shards) <= self.stop_at:
                    # the shared best already meets the target: this island
                    # (and, as their turns come, every other) retires instead
                    # of dispatching another segment — S2's early sharing
                    sh["done"] = True
                    retired += 1
                    reg.counter("mesh_retirements_total",
                                reason="target").inc()
                    continue
                # shard-local re-bucketing: the same decision the
                # single-device driver makes, over this island's slice only
                _live, k = bucketed.next_bucket(eng, k_idx, active, fevals,
                                                seg_len)
                if k is None:
                    sh["done"] = True
                    finished += 1
                    reg.counter("mesh_retirements_total",
                                reason="exhausted").inc()
                    continue
                runner = self.island_runner(k, seg_len[k], branch_fids,
                                            fitness_fn)
                args = (sh["keys"], sh["carry"]) if sh["insts"] is None \
                    else (sh["keys"], sh["insts"], sh["carry"])
                if supervisor is not None:
                    supervisor.before_dispatch(s, rnd)
                dsp = obs.tracer().start("dispatch", island=s,
                                         bucket=int(k), boundary=rnd)
                t0 = time.perf_counter()
                sh["carry"], tr = runner(*args)   # async: no block here
                wall = time.perf_counter() - t0
                obs.tracer().end(dsp)
                reg.histogram("mesh_island_dispatch_s",
                              strategy="concurrent",
                              island=s).observe(wall)
                sh["traces"].append(tr)
                sh["segments"].append({"shard": s, "bucket": k,
                                       "gens": seg_len[k],
                                       "dispatch_s": round(wall, 5)})
                bucket_wall[k] = bucket_wall.get(k, 0.0) + wall
                dispatched += 1
            # -- the only cross-island traffic: two scalars ----------------
            if dispatched or retired or finished:
                t0 = time.perf_counter()
                entry = {"round": rnd,
                         "global_best": _finite_or_none(
                             min(sh["best"] for sh in shards)),
                         "global_fevals": sum(sh["fevals"] for sh in shards)}
                if retired:
                    entry["stopped_early"] = True
                exchange.append(entry)
                reg.histogram("mesh_exchange_s", strategy="concurrent"
                              ).observe(time.perf_counter() - t0)
                reg.counter("mesh_exchange_rounds_total",
                            strategy="concurrent").inc()
            if not dispatched and all(sh["done"] for sh in shards):
                break
        else:
            raise RuntimeError("island driver did not converge "
                               f"within {max_segments} rounds")

        # -- assemble the global (B_pad, T_max, ...) trace --------------------
        shard_traces = []
        for sh in shards:
            if sh["traces"]:
                tr = jax.tree_util.tree_map(
                    lambda *xs: np.concatenate(
                        [np.asarray(x) for x in xs], axis=1), *sh["traces"])
            else:
                tr = bucketed._empty_trace(
                    jax.tree_util.tree_map(np.asarray, sh["carry"]),
                    time_axis=1)
            shard_traces.append(tr)
        T_max = max(tr.ran.shape[1] for tr in shard_traces)
        trace = jax.tree_util.tree_map(
            lambda *xs: np.concatenate(xs, axis=0),
            *[_pad_time(tr, T_max) for tr in shard_traces])
        carry = jax.tree_util.tree_map(
            lambda *xs: np.concatenate([np.asarray(x) for x in xs], axis=0),
            *[sh["carry"] for sh in shards])
        segments = [seg for sh in shards for seg in sh["segments"]]
        return carry, trace, segments, bucket_wall, exchange, \
            [sh["segments"] for sh in shards]


def _pad_time(tr: ladder.LadderTrace, T: int) -> ladder.LadderTrace:
    """Pad a shard trace to ``T`` generations along axis 1 with inert rows:
    ``ran=False`` steps (every consumer masks on ``ran``), edge-extended
    budget/best accumulators so ``hit_evals`` stays monotone."""
    t = tr.ran.shape[1]
    if t == T:
        return tr

    def cpad(a, fill):
        pw = [(0, 0)] * a.ndim
        pw[1] = (0, T - t)
        return np.pad(a, pw, constant_values=fill)

    def epad(a, fill):
        if t == 0:
            return cpad(a, fill)
        pw = [(0, 0)] * a.ndim
        pw[1] = (0, T - t)
        return np.pad(a, pw, mode="edge")

    return ladder.LadderTrace(
        ran=cpad(tr.ran, False), k_idx=cpad(tr.k_idx, 0),
        gen=cpad(tr.gen, 0), fevals=cpad(tr.fevals, 0),
        best_f=cpad(tr.best_f, np.inf), stop_reason=cpad(tr.stop_reason, 0),
        stopped=cpad(tr.stopped, False),
        total_fevals=epad(tr.total_fevals, 0),
        global_best=epad(tr.global_best, np.inf))


@dataclasses.dataclass
class MeshCampaignResult(bucketed.BucketedCampaignResult):
    """Bucketed campaign result plus the mesh deployment record."""

    strategy: str = "ordered"
    n_devices: int = 1
    exchange: List[dict] = dataclasses.field(default_factory=list)
    shard_segments: Optional[List[List[dict]]] = None


def run_campaign_mesh(engine: MeshCampaignEngine, fids, instances=(1,),
                      runs: int = 1, seed: int = 0,
                      max_segments: int = 10_000,
                      supervisor=None) -> MeshCampaignResult:
    """Run a whole BBOB campaign through the mesh engine — same member
    layout, instance stacking and key schedule as ``run_campaign_bucketed``
    (and therefore the λ_max-padded engine), with the batch padded to the
    mesh with inert members and deployed per ``engine.strategy``."""
    eng = engine.bucketed
    fids = tuple(fids)
    members = [(f, i, r) for f in fids for i in instances for r in range(runs)]
    insts = [bbob.make_instance(f, engine.n, i, eng.full.cfg.jdtype)
             for (f, i, _r) in members]
    stacked = bbob.stack_instances(insts)
    branch_fids = tuple(sorted(set(fids)))

    base = jax.random.PRNGKey(seed)
    keys = jnp.stack([jax.random.fold_in(base, j)
                      for j in range(len(members))])
    carry = eng._init_runner(keys)
    keys, carry, stacked, B, _B_pad = engine.pad_batch(keys, carry, stacked)

    drive = (engine._drive_ordered if engine.strategy == "ordered"
             else engine._drive_concurrent)
    carry, trace, segments, bucket_wall, exchange, shard_segments = drive(
        keys, stacked, carry, branch_fids, None, max_segments,
        supervisor=supervisor)

    sl = lambda a: np.asarray(a)[:B]
    trace = jax.tree_util.tree_map(sl, trace)
    useful = bucketed._useful_evals_per_rung(trace, eng.lam_start,
                                             eng.kmax_exp)
    rows = {"ordered": _B_pad, "concurrent": _B_pad // engine.n_devices}
    padded = sum(rows[engine.strategy] * s["gens"]
                 * (2 ** s["bucket"]) * eng.lam_start for s in segments)
    return MeshCampaignResult(
        members=members,
        f_opt=np.asarray([i.f_opt for i in insts], np.float64),
        best_f=sl(carry.best_f),
        best_x=sl(carry.best_x),
        total_fevals=sl(carry.total_fevals),
        trace=trace,
        compiles=engine.compiles(),
        segments=segments,
        bucket_wall_s={k: round(v, 5) for k, v in bucket_wall.items()},
        useful_evals=int(sum(useful.values())),
        padded_evals=int(padded),
        strategy=engine.strategy,
        n_devices=engine.n_devices,
        exchange=exchange,
        shard_segments=shard_segments)


def run_mesh_single(engine: MeshCampaignEngine, base_key: jax.Array,
                    fitness_fn: Callable, max_segments: int = 10_000,
                    supervisor=None):
    """One (un-vmapped) problem through the mesh engine — the ``mesh``
    backend behind ``ipop.run_ipop``.  The single member rides shard 0; the
    other shards carry inert padding rows.  Returns ``(carry, trace)`` with
    the single-run layout (trace leaves (T, S)) of ``run_bucketed_single``.

    Ordered runners are cached per call (the fitness closure is baked in at
    trace time — same reasoning as ``run_bucketed_single``); island runners
    ride the module-level program cache, which keys by the closure OBJECT and
    therefore can never replay a previous call's fitness.
    """
    keys = base_key[None]
    carry = engine.bucketed._init_runner(keys)
    keys, carry, _, _B, _B_pad = engine.pad_batch(keys, carry, None)
    drive = (engine._drive_ordered if engine.strategy == "ordered"
             else engine._drive_concurrent)
    carry, trace, _segs, _walls, _exch, _ss = drive(
        keys, None, carry, (), fitness_fn, max_segments,
        supervisor=supervisor)
    one = lambda a: np.asarray(a)[0]
    return (jax.tree_util.tree_map(one, carry),
            jax.tree_util.tree_map(one, trace))


# ---------------------------------------------------------------------------
# dry-run / roofline hook
# ---------------------------------------------------------------------------

def lower_ordered_segment(engine: MeshCampaignEngine, fid: int = 8,
                          seg_blocks: int = 1):
    """Lower (no execute, no real buffers) one S1 shard_map segment of the
    widest bucket over ``engine.mesh`` with one member per device — the
    mesh-engine cell of the dry-run/roofline harness (launch/dryrun.py).

    Returns ``(lowered, meta)`` with the bucket/segment geometry; the caller
    compiles and feeds the HLO to ``hlo_analyzer.analyze``.
    """
    eng = engine.bucketed
    k = engine.kmax_exp
    seg_gens = int(seg_blocks) * eng.interval
    B = engine.n_devices
    runner = engine.ordered_runner(k, seg_gens, (fid,), cache={})

    inst = bbob.make_instance(fid, engine.n, 1, eng.full.cfg.jdtype)
    stacked1 = bbob.stack_instances([inst])
    insts_abs = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct((B,) + a.shape[1:], a.dtype), stacked1)
    keys_abs = jax.ShapeDtypeStruct((B, 2), jnp.uint32)
    carry_abs = jax.eval_shape(
        jax.vmap(eng.full.init_carry),
        jax.ShapeDtypeStruct((B, 2), jnp.uint32))
    lowered = runner.lower(keys_abs, insts_abs, carry_abs)
    meta = {"bucket": k, "lam_bucket": (2 ** k) * engine.lam_start,
            "seg_gens": seg_gens, "members": B,
            "n_devices": engine.n_devices, "strategy": "ordered"}
    return lowered, meta
