"""Logical→physical sharding rules (MaxText-style) + activation constraints.

Parallelism mapping (DESIGN.md §7):
  * TP  — attention heads / FFN hidden / vocab / experts over ``model``
  * FSDP — the complementary weight dim over ``data`` (and ``pod`` when present)
  * DP  — batch over ``(pod, data)``
  * EP  — MoE expert dim over ``model``
  * SP  — long-context KV cache sequence dim over ``data`` (split-K decode)

Rules are a (path-regex, rank, builder) table matched by *leaf path suffix*
over the param tree.  ``rank`` is the rank of the un-stacked leaf: any extra
leading dims (layer stacking — one level for uniform scans, two for
pattern-unit scans like the VLM's (n_units, 4, ...) self-attn stack) are
replicated with leading ``None``s automatically.

A dim that does not divide its mesh axis falls back to replication (e.g.
qwen2's 2 KV heads on a 16-way model axis), so every architecture lowers on
every mesh without per-arch tuning.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P
import numpy as np

_CURRENT_MESH: Optional[jax.sharding.Mesh] = None


def set_mesh(mesh):
    global _CURRENT_MESH
    _CURRENT_MESH = mesh


def get_mesh():
    return _CURRENT_MESH


def dp_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def constrain(x, spec: P):
    """with_sharding_constraint against the current mesh (no-op without one)."""
    mesh = _CURRENT_MESH
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_spec(mesh, extra_dims: int = 2) -> P:
    """(B, S, ...) activations: batch over DP axes, rest replicated."""
    return P(dp_axes(mesh), *([None] * extra_dims))


def campaign_shardings(tree, mesh, axis: str = "camp"):
    """NamedSharding tree placing every leaf's leading (campaign-batch) dim
    on ``axis`` — the CMA-ES analogue of ``batch_spec``.  Every leaf of a
    stacked campaign pytree (keys, stacked BBOB instances, ladder carries,
    segment traces) carries the member batch as its leading dim, so one
    leading-axis spec shards the whole tree (distributed/mesh_engine.py)."""
    sh = NamedSharding(mesh, P(axis))
    return jax.tree_util.tree_map(lambda _: sh, tree)


# ---------------------------------------------------------------------------
# rule table
# ---------------------------------------------------------------------------
# Builders receive (r, shape_tail) where ``r`` exposes .t(i)/.f(i) — tp/fsdp
# axis for dim i of the *tail* shape, with divisibility fallback.

_RULES = [
    # --- embeddings / heads ---------------------------------------------------
    (r"tok_embed$", 2, lambda r, s: P(r.t(0), r.f(1))),          # (V, d)
    (r"lm_head$", 2, lambda r, s: P(r.f(0), r.t(1))),            # (d, V)
    # --- attention --------------------------------------------------------------
    (r"attn/wq$", 3, lambda r, s: P(r.f(0), r.t(1), None)),      # (d, H, Dh)
    (r"attn/w[kv]$", 3, lambda r, s: P(r.f(0), r.t(1), None)),   # (d_kv, Hk, Dh)
    (r"attn/wo$", 3, lambda r, s: P(r.t(0), None, r.f(2))),      # (H, Dh, d)
    (r"attn/b[qkv]$", 2, lambda r, s: P(r.t(0), None)),          # (H, Dh)
    # --- MoE (EP over model) -----------------------------------------------------
    (r"moe/router$", 2, lambda r, s: P(r.f(0), None)),
    (r"moe/w[ig]$", 3, lambda r, s: P(r.t(0), r.f(1), None)),    # (E, d, ff)
    (r"moe/wo$", 3, lambda r, s: P(r.t(0), None, r.f(2))),       # (E, ff, d)
    # --- dense MLP ----------------------------------------------------------------
    (r"mlp/w[ig]$", 2, lambda r, s: P(r.f(0), r.t(1))),          # (d, ff)
    (r"mlp/wo$", 2, lambda r, s: P(r.t(0), r.f(1))),             # (ff, d)
    # --- RWKV -----------------------------------------------------------------------
    (r"tmix/w[rkvg]$", 2, lambda r, s: P(r.f(0), r.t(1))),
    (r"tmix/wo$", 2, lambda r, s: P(r.t(0), r.f(1))),
    (r"tmix/maa_w1$", 2, lambda r, s: P(r.f(0), None)),
    (r"tmix/dec_w1$", 2, lambda r, s: P(r.f(0), None)),
    (r"cmix/wk$", 2, lambda r, s: P(r.f(0), r.t(1))),
    (r"cmix/wv$", 2, lambda r, s: P(r.t(0), r.f(1))),
    (r"cmix/wr$", 2, lambda r, s: P(r.f(0), r.t(1))),
    # --- Mamba ------------------------------------------------------------------------
    (r"mamba/in_proj$", 2, lambda r, s: P(r.f(0), r.t(1))),
    (r"mamba/out_proj$", 2, lambda r, s: P(r.t(0), r.f(1))),
    # --- zamba2 shared-block glue -------------------------------------------------------
    (r"shared_proj$", 2, lambda r, s: P(r.f(0), r.t(1))),
]


class Rules:
    def __init__(self, mesh):
        self.mesh = mesh
        self.tp_name = "model"
        fs = dp_axes(mesh)
        self.fsdp_name = fs if len(fs) > 1 else fs[0]
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.tp_size = sizes.get("model", 1)
        self.fsdp_size = int(np.prod([sizes[a] for a in fs]))
        self._tail: tuple = ()

    # dim helpers for builders (against the current tail shape)
    def t(self, i):
        return self.tp_name if self._tail[i] % self.tp_size == 0 else None

    def f(self, i):
        return self.fsdp_name if self._tail[i] % self.fsdp_size == 0 else None

    def spec_for(self, path: str, shape: tuple) -> P:
        for pat, rank, builder in _RULES:
            if re.search(pat, path):
                if len(shape) < rank:
                    return P()                      # scalarized (e.g. smoke)
                self._tail = shape[len(shape) - rank:]
                inner = builder(self, self._tail)
                lead = len(shape) - rank
                return P(*([None] * lead), *tuple(inner))
        # default: replicate (norms, scalars, LoRAs, convs, gates)
        return P(*([None] * len(shape))) if shape else P()


def tree_paths(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(tree_paths(v, f"{prefix}/{k}" if prefix else k))
    else:
        out[prefix] = tree
    return out


def param_specs(params, mesh):
    """PartitionSpec tree matching ``params`` structure."""
    rules = Rules(mesh)

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in tree.items()}
        shape = tree.shape if hasattr(tree, "shape") else ()
        return rules.spec_for(prefix, tuple(shape))

    return walk(params)


def param_shardings(params, mesh):
    specs = param_specs(params, mesh)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# cache sharding (serving)
# ---------------------------------------------------------------------------

def cache_specs(cache, mesh, batch: int):
    """KV/state cache PartitionSpecs — matched by *leaf name* (the cache is a
    flat dict built by models/lm.py:init_cache).

    KV leaves (``*k``/``*v``): (..., B, S, Hk, Dh).  Batch shards over DP axes
    when divisible; otherwise (long-context B=1 decode) the *sequence* dim
    shards over 'data' — split-K/flash-decoding style (SP), with XLA inserting
    the psum-merged softmax.  Heads shard over 'model' (TP).
    State leaves (``ssm``/``wkv``): (..., B, H, ...) — batch over DP, heads
    over model.  Shift/conv leaves: batch over DP only.
    """
    dp = dp_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_size = int(np.prod([sizes[a] for a in dp]))
    tp_size = sizes.get("model", 1)
    data_size = sizes.get("data", 1)

    def find_b(shape) -> int:
        # batch dim = first dim equal to ``batch`` (leading dims are layer
        # stack counts, which never equal the batch in the assigned cells)
        return shape.index(batch)

    def leaf_spec(name: str, x) -> P:
        shape = tuple(x.shape)
        if not shape:
            return P()
        spec = [None] * len(shape)
        base = name.rsplit("_", 1)[-1]
        try:
            b = find_b(shape)
        except ValueError:
            return P(*spec)
        if batch % dp_size == 0:
            spec[b] = dp
        if base in ("k", "v") and len(shape) >= b + 4:    # (B, S, Hk, Dh)
            if shape[b + 2] % tp_size == 0:
                spec[b + 2] = "model"
            if batch % dp_size != 0 and shape[b + 1] % data_size == 0:
                spec[b + 1] = "data"                      # SP / split-K
        elif base in ("ssm", "wkv") and len(shape) >= b + 2:  # (B, H, ...)
            if shape[b + 1] % tp_size == 0:
                spec[b + 1] = "model"
        return P(*spec)

    return {name: leaf_spec(name, leaf) for name, leaf in cache.items()}


def count_params(params) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(params)))
