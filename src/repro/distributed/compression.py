"""Gradient compression: per-leaf symmetric int8 quantization with error
feedback residual, used to cut the DP all-reduce bytes 4× (bf16→int8... f32→4×).

``compress_decompress`` is the *in-graph* hook used by train_step: it
round-trips gradients through int8 so the DP collective (inserted by XLA at
the sharding boundary after this op) moves int8 + one f32 scale per leaf.
XLA cannot all-reduce int8 sums exactly across shards without overflow, so
we model the standard trick: scale to int8 range, all-reduce in f32 of the
*dequantized* values — what's saved in a real deployment is the network
serialization (the collective-bytes roofline term counts the dequantized
dtype; the int8 variant is reported separately in EXPERIMENTS §Perf).

``ErrorFeedback`` keeps the quantization residual and adds it to the next
step's gradient (1-bit/`signSGD`-style EF), preserving convergence.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray):
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_decompress(grads):
    """In-graph int8 round-trip of every gradient leaf (lossy)."""
    def rt(g):
        q, s = quantize_int8(g)
        return dequantize_int8(q, s, jnp.float32)
    return jax.tree_util.tree_map(rt, grads)


class ErrorFeedback(NamedTuple):
    residual: dict


def init_error_feedback(params) -> ErrorFeedback:
    return ErrorFeedback(residual=jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compress_with_feedback(grads, ef: ErrorFeedback):
    """g' = Q(g + r);  r ← (g + r) − g'.  Returns (g', new_ef)."""
    def one(g, r):
        t = g.astype(jnp.float32) + r
        q, s = quantize_int8(t)
        d = dequantize_int8(q, s)
        return d, t - d
    flat = jax.tree_util.tree_map(one, grads, ef.residual)
    g2 = jax.tree_util.tree_map(lambda x: x[0], flat,
                                is_leaf=lambda x: isinstance(x, tuple))
    r2 = jax.tree_util.tree_map(lambda x: x[1], flat,
                                is_leaf=lambda x: isinstance(x, tuple))
    return g2, ErrorFeedback(residual=r2)
