"""GPipe-style pipeline parallelism over a mesh axis (DESIGN.md §7).

At 1000+ nodes the ``pod`` axis can run as pipeline stages instead of pure
DP: each pod holds a contiguous block of layers, microbatches stream
through with ``ppermute`` handoffs.  This module implements the schedule as
a shard_map program:

  * ``params_stages`` — every leaf has a leading stage dim sharded over the
    pipeline axis (each device group holds only its block's weights);
  * classic GPipe timing: with M microbatches and S stages the loop runs
    M + S − 1 ticks; at tick t, stage s processes microbatch t − s
    (bubble fraction = (S−1)/(M+S−1));
  * the handoff is one ``ppermute`` of the (mb, ...) activation per tick —
    point-to-point, matching the 1-hop pod-to-pod ICI links.

``pipeline_apply`` is deliberately schedule-only: the stage function is any
jax-traceable layer block (the scanned LM units slot in directly).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(stage_fn: Callable, params_stages, x_microbatches,
                   mesh, axis: str = "pod"):
    """Run ``stage_fn(stage_params, x) -> y`` through S pipeline stages.

    params_stages: pytree, leaves (S, ...) — stage dim sharded over ``axis``.
    x_microbatches: (M, mb, ...) — replicated input microbatches.
    Returns (M, mb, ...) outputs having traversed all S stages in order.
    """
    S = mesh.shape[axis]
    M = x_microbatches.shape[0]
    T = M + S - 1

    def per_device(pstack, xs):
        # pstack leaves arrive as (1, ...) local slices — this device's stage
        p_local = jax.tree_util.tree_map(lambda a: a[0], pstack)
        s = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        perm = [(i, i + 1) for i in range(S - 1)]

        def tick(carry, t):
            cur, outs = carry                       # cur: this stage's input
            # stage s works on microbatch (t - s); valid while 0 ≤ t−s < M
            active = (t - s >= 0) & (t - s < M)
            inj = jnp.where(t < M, t, M - 1)
            cur = jnp.where(s == 0, xs[inj], cur)   # stage 0 injects
            y = stage_fn(p_local, cur)
            y = jnp.where(active, y, cur)
            # last stage emits microbatch t−(S−1)
            emit_idx = jnp.clip(t - (S - 1), 0, M - 1)
            do_emit = (s == S - 1) & (t - (S - 1) >= 0)
            outs = jax.lax.cond(
                do_emit, lambda o: o.at[emit_idx].set(y), lambda o: o, outs)
            nxt = jax.lax.ppermute(y, axis, perm)   # hand to stage s+1
            return (nxt, outs), None

        outs0 = jnp.zeros((M,) + mb_shape, xs.dtype)
        cur0 = jnp.zeros(mb_shape, xs.dtype)
        (_, outs), _ = jax.lax.scan(tick, (cur0, outs0), jnp.arange(T))
        # outputs live on the last stage; broadcast so out_specs can be
        # replicated (a real serving loop would keep them stage-local)
        outs = jax.lax.psum(jnp.where(s == S - 1, outs, 0.0), axis)
        return outs

    in_specs = (jax.tree_util.tree_map(lambda _: P(axis), params_stages),
                P())
    from repro.core.eval_dispatch import shard_map_compat
    fn = shard_map_compat(per_device, mesh=mesh, in_specs=in_specs,
                          out_specs=P())
    return fn(params_stages, x_microbatches)


def bubble_fraction(n_microbatches: int, n_stages: int) -> float:
    """GPipe idle fraction — the (S−1)/(M+S−1) law used in DESIGN §7."""
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
