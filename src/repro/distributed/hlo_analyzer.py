"""Loop-aware roofline accounting from optimized HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE — for
scan-over-layers programs (every cell here) that undercounts FLOPs, bytes
and collective traffic by the trip count (validated in
tests/test_hlo_analyzer.py).  This module re-derives the three roofline
inputs from ``compiled.as_text()`` with trip-count multipliers:

  * flops       — dot ops (2·|out|·|contract|) wherever they appear
                  (top-level or inside fused computations) + elementwise;
  * bytes       — per-kernel HBM traffic proxy: Σ over top-level
                  instructions of (output + operand bytes); fusions count as
                  one kernel (inner ops touch no HBM);
  * collectives — per-kind byte totals (all-gather / all-reduce /
                  reduce-scatter / all-to-all / collective-permute).

Totals are computed per computation and folded through the call graph:
``while`` bodies/conds × known_trip_count (XLA annotates scan loops with
``backend_config={"known_trip_count":{"n":...}}``); fusion/call/cond called
computations contribute flops (bytes only at the call site).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "u1": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "sine",
    "cosine", "logistic", "select", "compare", "and", "or", "xor", "floor",
    "ceil", "round-nearest-even", "remainder", "atan2", "expm1", "log1p",
}

_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast", "iota",
    "after-all", "opt-barrier", "partition-id", "replica-id",
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?.*?\)??)\s+"
    r"([a-z][\w\-]*)\((.*)$")
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_COND_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(shape_str: str) -> int:
    n_total = 0
    for _, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        n_total += n
    return n_total


# named-scope tags attributed per instruction via op_name metadata
TAGS = ("flash_tile", "wkv_tile", "ssd_tile")

_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    rest: str          # operand list + attributes (raw tail of the line)

    def tag(self):
        m = _OPNAME_RE.search(self.rest)
        if not m:
            return None
        for t in TAGS:
            if t in m.group(1):
                return t
        return None


@dataclasses.dataclass
class Comp:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]


def parse_module(txt: str) -> Dict[str, Comp]:
    comps: Dict[str, Comp] = {}
    cur: Optional[Comp] = None
    for line in txt.splitlines():
        if cur is None:
            m = _COMP_HEAD_RE.match(line.strip())
            if m:
                cur = Comp(name=m.group(1), instrs=[], shapes={})
            continue
        s = line.strip()
        if s == "}" or s.startswith("} "):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, shape, opcode, rest = m.groups()
            cur.instrs.append(Instr(name, shape, opcode, rest))
            cur.shapes[name] = shape
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _dot_flops(instr: Instr, comp: Comp) -> float:
    out_elems = _shape_elems(instr.shape)
    ops = _OPERAND_RE.findall(instr.rest.split(", lhs_")[0])
    lhs_shape = comp.shapes.get(ops[0], "") if ops else ""
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    if not mc or not lhs_shape:
        return 2.0 * out_elems                       # degenerate fallback
    dims_m = _SHAPE_RE.search(lhs_shape)
    if not dims_m:
        return 2.0 * out_elems
    lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
    contract = 1
    for i in mc.group(1).split(","):
        if i:
            contract *= lhs_dims[int(i)]
    return 2.0 * out_elems * contract


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    tagged: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {t: 0.0 for t in TAGS})
    unknown_trip: int = 0

    def add(self, other: "Totals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in _COLLECTIVES:
            self.coll[k] += other.coll[k] * mult
            self.coll_counts[k] += other.coll_counts[k] * mult
        for t in TAGS:
            self.tagged[t] += other.tagged[t] * mult
        self.unknown_trip += other.unknown_trip


class Analyzer:
    def __init__(self, txt: str):
        self.comps = parse_module(txt)
        self._memo: Dict[str, Totals] = {}
        self._flops_memo: Dict[str, float] = {}
        entry = None
        for line in txt.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HEAD_RE.match(line.strip()[len("ENTRY"):].strip()
                                        if False else line.strip())
                # header regex already strips ENTRY
                m2 = re.match(r"^ENTRY\s+%?([\w.\-]+)", line.strip())
                if m2:
                    entry = m2.group(1)
                break
        if entry is None:                      # fall back: last computation
            entry = list(self.comps)[-1] if self.comps else ""
        self.entry = entry

    # -- generic loop-aware scalar fold over the call graph -------------------
    # One traversal serves every scalar metric (flops, executed-op counts):
    # while bodies/conds × known_trip_count, conditionals take their max
    # branch, fusions/calls/custom-calls recurse into the called computation.
    def _fold_scalar(self, name: str, leaf_fn, memo: Dict[str, float]) -> float:
        if name in memo:
            return memo[name]
        comp = self.comps.get(name)
        if comp is None:
            return 0.0
        memo[name] = 0.0                      # cycle guard
        total = 0.0
        for ins in comp.instrs:
            if ins.opcode == "while":
                trip, body, cond = self._while_parts(ins)
                total += trip * (self._fold_scalar(body, leaf_fn, memo)
                                 + self._fold_scalar(cond, leaf_fn, memo))
                continue
            if ins.opcode == "conditional":
                m = _COND_BRANCH_RE.search(ins.rest)
                if m:
                    branches = _OPERAND_RE.findall(m.group(1))
                    total += max(
                        (self._fold_scalar(b, leaf_fn, memo)
                         for b in branches), default=0.0)
                continue
            total += leaf_fn(ins, comp)
            if ins.opcode in ("fusion", "call", "custom-call"):
                m = _CALLED_RE.search(ins.rest)
                if m:
                    total += self._fold_scalar(m.group(1), leaf_fn, memo)
        memo[name] = total
        return total

    @staticmethod
    def _leaf_flops(ins: Instr, comp: Comp) -> float:
        if ins.opcode == "dot":
            return _dot_flops(ins, comp)
        if ins.opcode in _ELEMENTWISE:
            return float(_shape_elems(ins.shape))
        if ins.opcode in ("reduce", "reduce-window"):
            return float(_shape_elems(ins.shape)) * 2.0
        return 0.0

    # flops of a computation including everything called from it, NO bytes
    # (used for fused computations, whose inner ops touch no HBM)
    def _flops_only(self, name: str) -> float:
        return self._fold_scalar(name, self._leaf_flops, self._flops_memo)

    def _while_parts(self, ins: Instr):
        mt = _TRIP_RE.search(ins.rest)
        trip = int(mt.group(1)) if mt else 1
        mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
        mc = re.search(r"condition=%?([\w.\-]+)", ins.rest)
        return trip, (mb.group(1) if mb else ""), (mc.group(1) if mc else "")

    def totals(self, name: Optional[str] = None) -> Totals:
        name = name or self.entry
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        t = Totals()
        if comp is None:
            return t
        self._memo[name] = t                  # cycle guard
        for ins in comp.instrs:
            base = ins.opcode.replace("-start", "")
            if base in _COLLECTIVES and not ins.opcode.endswith("-done"):
                b = shape_bytes(ins.shape)
                t.coll[base] += b
                t.coll_counts[base] += 1
                t.bytes += b * 2              # read + write at the NIC/HBM
                continue
            if ins.opcode == "while":
                trip, body, cond = self._while_parts(ins)
                mt = _TRIP_RE.search(ins.rest)
                if not mt:
                    t.unknown_trip += 1
                t.add(self.totals(body), trip)
                t.add(self.totals(cond), trip)
                continue
            if ins.opcode == "conditional":
                m = _COND_BRANCH_RE.search(ins.rest)
                if m:
                    branches = _OPERAND_RE.findall(m.group(1))
                    subs = [self.totals(b) for b in branches]
                    if subs:
                        best = max(subs, key=lambda s: s.flops)
                        t.add(best)
                continue
            if ins.opcode == "call":
                m = _CALLED_RE.search(ins.rest)
                if m:
                    t.add(self.totals(m.group(1)))
                continue
            # ordinary / fusion instruction
            t.flops += self._leaf_flops(ins, comp)
            if ins.opcode in ("fusion", "custom-call"):
                m = _CALLED_RE.search(ins.rest)
                if m:
                    t.flops += self._flops_only(m.group(1))
            if ins.opcode not in _SKIP_BYTES:
                out_b = shape_bytes(ins.shape)
                opnds = _OPERAND_RE.findall(ins.rest.split("), ")[0])
                in_b = sum(shape_bytes(comp.shapes.get(o, "")) for o in opnds)
                t.bytes += out_b + in_b
                tag = ins.tag()
                if tag:
                    t.tagged[tag] += out_b + in_b
        return t


_TARGET_RE = re.compile(r'custom_call_target="([^"]+)"')


def count_ops(hlo_text: str, pattern: str) -> float:
    """Executed-instance count of instructions matching ``pattern``.

    ``pattern`` is a regex tested against each instruction's opcode and — for
    custom calls — its ``custom_call_target`` (e.g. ``r"syevd|Eigh"`` counts
    eigendecompositions on CPU/GPU backends).  Counts are folded through the
    call graph with the same loop-aware accounting ``Totals`` uses for flops:
    while bodies multiply by their ``known_trip_count``, conditionals count
    their maximum branch, fusions/calls recurse into the called computation.
    Used by tests/test_eigen_amortization.py to pin the number of ``eigh``
    executions per compiled campaign to ⌈T/eigen_interval⌉.
    """
    a = Analyzer(hlo_text)
    rx = re.compile(pattern)

    def leaf(ins: Instr, _comp: Comp) -> float:
        if rx.search(ins.opcode):
            return 1.0
        m = _TARGET_RE.search(ins.rest)
        return 1.0 if (m and rx.search(m.group(1))) else 0.0

    return a._fold_scalar(a.entry, leaf, {})


def count_instrs(hlo_text: str, pattern: str) -> float:
    """Shape-aware sibling of ``count_ops``: executed-instance count of
    instructions whose ``"<shape> <opcode>"`` text matches ``pattern``.

    Where ``count_ops`` matches opcodes (and custom-call targets) only, this
    matches the instruction's result shape too — e.g.
    ``r"f64\\[(?:1,)*6,7\\]\\S* dot\\b"`` counts the (n, n+1)-shaped
    gram-family dot-generals of the fused CMA generation update (allowing
    vmap-inserted unit batch dims), which tests/test_fused_gen.py pins to
    exactly one per generation.  Same loop-aware fold as every other
    counter here: while bodies multiply by ``known_trip_count``,
    conditionals take their max branch, fusions/calls recurse.
    """
    a = Analyzer(hlo_text)
    rx = re.compile(pattern)

    def leaf(ins: Instr, _comp: Comp) -> float:
        return 1.0 if rx.search(f"{ins.shape} {ins.opcode}") else 0.0

    return a._fold_scalar(a.entry, leaf, {})


def analyze(hlo_text: str) -> dict:
    """One-call summary used by the dry-run artifacts."""
    a = Analyzer(hlo_text)
    t = a.totals()
    coll_total = sum(t.coll.values())
    return {
        "flops": t.flops,
        "bytes": t.bytes,
        "collective_bytes": dict(t.coll, total=coll_total,
                                 counts=t.coll_counts),
        "tagged_bytes": dict(t.tagged),
        "unknown_trip_whiles": t.unknown_trip,
    }
