"""The jitted training step: microbatched grad accumulation, remat (inside
the model's scanned units), mixed precision (bf16 compute / f32 params+opt),
optional int8 gradient compression with error feedback, AdamW update.

This is what the multi-pod dry-run lowers for every ``train_4k`` cell:
  jax.jit(make_train_step(cfg, opt_cfg, mesh),
          in_shardings=(param_shardings, opt_shardings, batch_shardings),
          ...).lower(params, opt_state, batch).compile()
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.distributed.compression import compress_decompress
from repro.models import lm
from repro.train import optimizer as opt_mod


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1           # grad-accumulation steps per train step
    grad_compress: str = "none"     # none | int8 (error-feedback handled by
                                    # the all-reduce being exact post-dequant)
    # §Perf iteration 2 knobs (collective-bound cells):
    grad_accum_dtype: str = "float32"   # bfloat16 halves per-µb reduce bytes
    shard_grad_accum: bool = False      # constrain the accumulator to the
                                        # param shardings → XLA emits per-µb
                                        # reduce-scatter instead of all-reduce
    adamw: opt_mod.AdamWConfig = dataclasses.field(
        default_factory=opt_mod.AdamWConfig)


def _split_microbatches(batch: dict, n: int):
    """(B, ...) → (n, B/n, ...) for every leaf with a leading batch dim."""
    def sp(x):
        B = x.shape[0]
        assert B % n == 0, f"batch {B} not divisible by microbatches {n}"
        return x.reshape((n, B // n) + x.shape[1:])
    return jax.tree_util.tree_map(sp, batch)


def grads_and_loss(cfg: ModelConfig, params: dict, batch: dict,
                   microbatches: int = 1, accum_dtype=jnp.float32,
                   shard_accum: bool = False, mesh=None):
    """Microbatch-accumulated gradients.

    ``shard_accum`` constrains the running accumulator to the parameter
    shardings: XLA then reduce-scatters each microbatch's gradient into the
    owning shard instead of all-reducing the full tree every iteration —
    ~2× less collective traffic per microbatch (§Perf iteration 2), and the
    final all-gather happens once inside the optimizer."""
    def loss_fn(p, b):
        val, metrics = lm.loss(cfg, p, b)
        return val, metrics

    if microbatches <= 1:
        (val, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        if accum_dtype != jnp.float32:
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(accum_dtype), grads)
        return grads, val, metrics

    mb = _split_microbatches(batch, microbatches)
    g0 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, accum_dtype), params)

    pspecs = None
    if shard_accum and mesh is not None:
        pspecs = sharding.param_specs(params, mesh)

    def constrain_tree(a, s):
        if isinstance(a, dict):
            return {k: constrain_tree(a[k], s[k]) for k in a}
        return sharding.constrain(a, s)

    def body(carry, b):
        acc, tot = carry
        (val, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b)
        acc = jax.tree_util.tree_map(
            lambda a, x: a + x.astype(accum_dtype), acc, g)
        if pspecs is not None:
            acc = constrain_tree(acc, pspecs)
        return (acc, tot + val), None

    (gsum, tot), _ = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)), mb)
    inv = 1.0 / microbatches
    grads = jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * inv).astype(accum_dtype), gsum)
    return grads, tot * inv, {"ce": tot * inv,
                              "moe_aux": jnp.zeros((), jnp.float32)}


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    mesh: Optional[jax.sharding.Mesh] = None):
    """Returns step(params, opt_state, batch) → (params, opt_state, metrics).

    Under jit with sharded in/out params, XLA SPMD inserts the DP gradient
    all-reduce (reduce-scatter + all-gather with FSDP) automatically; the
    optional compression hook quantizes gradients to int8 *before* that
    collective and dequantizes after, cutting collective bytes 4× (§Perf).
    """
    def step(params, opt_state, batch):
        if mesh is not None:
            sharding.set_mesh(mesh)
        grads, loss_val, metrics = grads_and_loss(
            cfg, params, batch, tcfg.microbatches,
            accum_dtype=jnp.dtype(tcfg.grad_accum_dtype),
            shard_accum=tcfg.shard_grad_accum, mesh=mesh)
        if tcfg.grad_compress == "int8":
            grads = compress_decompress(grads)
        params2, opt2, opt_metrics = opt_mod.adamw_update(
            tcfg.adamw, params, grads, opt_state)
        return params2, opt2, dict(loss=loss_val, **metrics, **opt_metrics)

    return step


def shardings_for(cfg: ModelConfig, mesh, batch_example=None,
                  params_abstract=None):
    """(param, opt, batch) NamedShardings for jit in/out_shardings."""
    if params_abstract is None:
        params_abstract = jax.eval_shape(
            lambda k: lm.init_params(cfg, k),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = sharding.param_specs(params_abstract, mesh)
    psh = jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s), pspecs,
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
    opt_sh = opt_mod.OptState(
        mu=psh, nu=jax.tree_util.tree_map(lambda x: x, psh),
        step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()))
    bspec = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(sharding.dp_axes(mesh)))
    if batch_example is not None:
        bsh = jax.tree_util.tree_map(lambda x: bspec, batch_example)
    else:
        bsh = bspec
    return psh, opt_sh, bsh
