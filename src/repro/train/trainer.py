"""Fault-tolerant training loop.

Production behaviors, all exercised by tests on CPU-sized configs:
  * checkpoint/restart — async sharded checkpoints every ``ckpt_every``
    steps; on (re)start the trainer resumes from the newest committed step,
    and the deterministic data pipeline replays from exactly that step;
  * elastic scaling — restore accepts a different mesh than the writer's
    (re-placement via shardings at restore);
  * crash containment — a per-step watchdog: NaN/inf loss skips the update
    (grads discarded, step still advances) and counts toward a bounded
    skip budget, the ES analogue of gradient skipping at scale;
  * straggler/failure hooks at the data layer (shard-aware pipeline) — a
    lost host's shard is regenerable from (step, shard) alone.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticTokens
from repro.models import lm
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    log_every: int = 10
    max_skipped: int = 10           # NaN-step budget before aborting
    seed: int = 0
    train: ts_mod.TrainConfig = dataclasses.field(
        default_factory=ts_mod.TrainConfig)


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainerConfig,
                 seq_len: int, global_batch: int,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 log_fn: Callable[[str], None] = print):
        self.cfg, self.tc, self.mesh = cfg, tc, mesh
        self.log = log_fn
        self.data = SyntheticTokens(cfg, seq_len, global_batch, seed=tc.seed)
        self.step_fn = jax.jit(ts_mod.make_train_step(cfg, tc.train, mesh))
        self.history: list[dict] = []
        self._pending_ckpt = None

    # -- state ----------------------------------------------------------------
    def init_state(self, key=None):
        key = key if key is not None else jax.random.PRNGKey(self.tc.seed)
        params = lm.init_params(self.cfg, key)
        return params, opt_mod.init_opt_state(params)

    def try_restore(self, params, opt_state):
        step = store.latest_step(self.tc.ckpt_dir)
        if step is None:
            return params, opt_state, 0
        shardings = None
        if self.mesh is not None:
            psh, osh, _ = ts_mod.shardings_for(
                self.cfg, self.mesh, params_abstract=params)
            shardings = (psh, osh)
        tree = store.restore(self.tc.ckpt_dir, step, (params, opt_state),
                             shardings)
        self.log(f"[trainer] restored step {step} from {self.tc.ckpt_dir}")
        return tree[0], tree[1], step

    # -- loop -------------------------------------------------------------------
    def run(self, resume: bool = True):
        params, opt_state = self.init_state()
        start = 0
        if resume:
            params, opt_state, start = self.try_restore(params, opt_state)
        skipped = 0
        t0 = time.time()
        for step in range(start, self.tc.total_steps):
            batch = {k: jnp.asarray(v)
                     for k, v in self.data.batch_at(step).items()}
            new_params, new_opt, metrics = self.step_fn(
                params, opt_state, batch)
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                skipped += 1
                self.log(f"[trainer] step {step}: non-finite loss, "
                         f"skipping update ({skipped}/{self.tc.max_skipped})")
                if skipped > self.tc.max_skipped:
                    raise RuntimeError("NaN budget exhausted")
            else:
                params, opt_state = new_params, new_opt
            self.history.append({"step": step, "loss": loss,
                                 "grad_norm": float(metrics["grad_norm"]),
                                 "lr": float(metrics["lr"])})
            if step % self.tc.log_every == 0:
                dt = time.time() - t0
                self.log(f"[trainer] step {step} loss={loss:.4f} "
                         f"gnorm={float(metrics['grad_norm']):.3f} "
                         f"({dt:.1f}s)")
            if (step + 1) % self.tc.ckpt_every == 0:
                self._checkpoint(step + 1, params, opt_state)
        self._checkpoint(self.tc.total_steps, params, opt_state,
                         blocking=True)
        return params, opt_state

    def _checkpoint(self, step, params, opt_state, blocking=False):
        if self._pending_ckpt is not None:
            self._pending_ckpt.join()                 # one writer in flight
        os.makedirs(self.tc.ckpt_dir, exist_ok=True)
        self._pending_ckpt = store.save(
            self.tc.ckpt_dir, step, (params, opt_state), blocking=blocking)
        store.prune(self.tc.ckpt_dir, self.tc.keep_ckpts)
