"""AdamW with decoupled weight decay and global-norm clipping — from scratch
(this container has no optax), sharded states.

Optimizer moments are f32 pytrees mirroring the params; their shardings are
the *same specs as the params* (the FSDP idiom: states live wherever their
weight shard lives), so the dry-run's memory analysis reflects a real
sharded-optimizer deployment.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    mu: dict          # first moment  (f32, sharded like params)
    nu: dict          # second moment (f32, sharded like params)
    step: jnp.ndarray  # () int32


def init_opt_state(params) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(mu=zeros,
                    nu=jax.tree_util.tree_map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32))


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup → cosine decay to min_lr_frac·lr."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree_util.tree_leaves(tree)))


def _decayable(path: str) -> bool:
    """No decay on norms/scalars/biases (path-suffix heuristic)."""
    last = path.rsplit("/", 1)[-1]
    return not (last.startswith("ln") or "norm" in last or "scale" in last
                or last.startswith("b") and len(last) <= 2
                or last.startswith("gate") or last in ("u", "w0", "D",
                                                       "A_log", "dt_bias"))


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    from repro.distributed.sharding import tree_paths

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    paths = tree_paths(params)
    decay_mask = {p: _decayable(p) for p in paths}

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    path_list = list(paths.keys())

    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu, path in zip(flat_p, flat_g, flat_mu, flat_nu, path_list):
        g32 = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * jnp.square(g32)
        upd = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        if decay_mask[path] and cfg.weight_decay > 0:
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_mu.append(mu)
        new_nu.append(nu)

    params2 = jax.tree_util.tree_unflatten(treedef, new_p)
    mu2 = jax.tree_util.tree_unflatten(treedef, new_mu)
    nu2 = jax.tree_util.tree_unflatten(treedef, new_nu)
    return params2, OptState(mu=mu2, nu=nu2, step=step), {
        "grad_norm": gnorm, "lr": lr}
