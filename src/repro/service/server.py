"""The campaign server: streaming optimization-as-a-service over the engines.

Architecture
------------
The server owns a set of *lanes*, one per dim-class (``allocator.lane_key``).
A lane is a ``BucketedLadderEngine`` plus a fixed grid of member rows split
into *islands* — one island per device of the campaign mesh, each driving
its OWN budget-adaptive segment schedule exactly like the mesh engine's S2
strategy (shard-local ``bucketed.next_bucket``, async dispatch, host syncs
only at segment boundaries).  On a single device the lane degenerates to one
island and the loop is the bucketed segment driver with service hooks.

Everything per-job is a row-indexed *operand* of the lane's segment
programs — base key, per-row budget (``segment_scan(max_evals=...)``, traced),
fitness branch index, stacked BBOB instance — never a compile key.  Admission
therefore writes a row (one ``dynamic_update_index_in_dim`` program per lane)
at a segment boundary and the next segment just runs it: compiles stay
≤ #buckets × #dim-classes for the whole service lifetime, no per-request
recompilation (asserted in tests/test_service.py).  Segment programs ride a
module-level compilation cache keyed like the mesh engine's island cache
(bucket shape + fitness identity + mesh), so successive rounds — and
successive *servers*, e.g. across a crash-resume — reuse one traced program
per bucket.

Per boundary the server: pulls the island's scheduling arrays (ONE batched
transfer, ``bucketed.pull_schedule``), streams ticket updates, retires rows
whose job finished its budget / ladder / target (early, ``stop_at``-style),
frees their slots, admits queued requests into free rows, and dispatches the
island's next bucket segment asynchronously.  Traces stay device-resident
until a row's job completes, then exactly that row is pulled and sliced into
the job's ``IPOPResult``.

Durability: ``snapshot()`` writes the stacked ``CMAState`` carries, per-row
operands, device-resident traces and the allocator/job map through
``checkpoint/store.py`` (arrays + atomically-committed ``meta.json``);
``CampaignServer.restore`` rebuilds a server from the latest committed step —
onto a *different* device count if asked: rows are relocatable (trajectories
depend only on their base key and state, never the row/island), so the
allocator just re-packs them across the new islands (elastic re-shard).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.obs.recorder import recorder as flight_recorder
from repro.checkpoint import store
from repro.core import bucketed, ipop as ipop_mod, ladder
from repro.distributed.mesh_engine import ProgramCache
from repro.fitness import bbob
from repro.service import queue as qmod
from repro.service.allocator import SlotAllocator, lane_key
from repro.service.queue import (JOB_CANCELLED, JOB_DONE, JOB_EXPIRED,
                                 JOB_QUARANTINED, JOB_QUEUED, JOB_REJECTED,
                                 JOB_RUNNING, JOB_SHED, CampaignRequest,
                                 CampaignTicket)


class FitnessRegistry:
    """Named fitness callables compiled into every lane's dispatch switch.

    Branch 0 of a lane program is always the BBOB traced-fid dispatch over
    the server's configured ``bbob_fids``; custom callables occupy branches
    1..N in registration order.  Callables must be pure jnp batch evaluators
    ``f(X: (lam, n)) -> (lam,)`` and total (under vmap the switch evaluates
    every branch and selects, exactly like the campaign engines' fid
    dispatch).

    The branch list is part of every compiled lane program, so the registry
    is *versioned* rather than frozen-forever: starting a server freezes the
    current **generation**, and registering a callable on a live server opens
    generation g+1.  Lanes are keyed by the generation they were traced
    against (``allocator.lane_key``): resident generation-g lanes keep their
    compiled programs and their prefix ``fns_at(g)`` of the branch list
    untouched, while new jobs route to generation-g+1 lanes whose programs
    include the new branch.  Registration is append-only, so a callable's
    branch index (``1 + index(name)``) is identical in every generation that
    contains it — fn_idx row operands stay valid across rollouts.
    """

    def __init__(self):
        self._names: List[str] = []
        self._fns: List[Callable] = []
        self._gens: List[int] = []      # birth generation per callable
        self._gen = 0                   # current (newest) generation
        self._frozen = False

    def register(self, name: str, fn: Callable):
        if name in self._names:
            raise ValueError(f"fitness {name!r} already registered")
        if self._frozen:
            # live rollout: open a new program-family generation instead of
            # refusing — existing lanes never see the grown branch list
            self._gen += 1
            self._frozen = False
        self._names.append(name)
        self._fns.append(fn)
        self._gens.append(self._gen)
        return fn

    def freeze(self):
        self._frozen = True

    @property
    def generation(self) -> int:
        return self._gen

    def index(self, name: str) -> int:
        return self._names.index(name)

    def gen_added(self, name: str) -> int:
        """The generation a callable first appeared in — the *minimum* lane
        generation that can run a job naming it."""
        return self._gens[self._names.index(name)]

    def fns_at(self, gen: int) -> Tuple[Callable, ...]:
        """The branch list of generation ``gen`` (a prefix of ``fns``)."""
        return tuple(f for f, g in zip(self._fns, self._gens) if g <= gen)

    def names_at(self, gen: int) -> Tuple[str, ...]:
        return tuple(n for n, g in zip(self._names, self._gens) if g <= gen)

    def align_generations(self, names: Sequence[str], gens: Sequence[int],
                          gen: int):
        """Snapshot-restore hook: stamp re-registered callables with their
        original birth generations (callables cannot be persisted, so the
        restoring process re-registers them by name and this restores the
        generation structure the snapshot's lane keys refer to)."""
        for n, g in zip(names, gens):
            if n in self._names:
                self._gens[self._names.index(n)] = int(g)
        self._gen = max(self._gen, int(gen))

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(self._names)

    @property
    def fns(self) -> Tuple[Callable, ...]:
        return tuple(self._fns)


# ---------------------------------------------------------------------------
# lane program cache — shares the mesh engine's ProgramCache discipline
# (closure-capped FIFO eviction: a retired server generation's registry tuple
# stops pinning its programs once newer closure-keyed entries push it out)
# ---------------------------------------------------------------------------

_SEGMENT_CACHE = ProgramCache()


def _lane_label(key: tuple) -> str:
    """Metric label of a lane key: ``d<dim>.l<lam_start>.k<kmax_exp>.<dtype>``
    plus ``.g<gen>`` for post-rollout registry generations (stable,
    low-cardinality — one value per dim-class per generation)."""
    dim, lam, kmax, dtype = key[:4]
    gen = key[4] if len(key) > 4 else 0
    base = f"d{dim}.l{lam}.k{kmax}.{dtype}"
    return f"{base}.g{gen}" if gen else base


def program_cache_stats() -> dict:
    return _SEGMENT_CACHE.snapshot()


def clear_program_cache():
    _SEGMENT_CACHE.clear()


class _Island:
    """One device's slice of a lane: per-row operands + carry + traces."""

    __slots__ = ("device", "arrays", "traces")

    def __init__(self, device, arrays):
        self.device = device
        self.arrays = arrays            # {"keys","fn_idx","budgets","insts","carry"}
        # [(LadderTrace (Bl, g, S) device-resident, own (Bl, g) np job ids)]
        self.traces: List[tuple] = []


class _Lane:
    """One dim-class: engine + islands + allocator + program bookkeeping."""

    def __init__(self, key: tuple, server: "CampaignServer"):
        dim, lam_start, kmax_exp, dtype, reg_gen = key
        self.key = key
        self.reg_gen = int(reg_gen)
        self.server = server
        self.engine = bucketed.BucketedLadderEngine(
            n=dim, lam_start=lam_start, kmax_exp=kmax_exp,
            max_evals=server.max_budget, domain=server.domain,
            sigma0_frac=server.sigma0_frac, impl=server.impl, dtype=dtype,
            eigen_interval=server.eigen_interval,
            seg_blocks=server.seg_blocks, policy=server.policy)
        self.bbob_fids = tuple(server.bbob_fids)
        # the branch list of THIS lane's registry generation: a later
        # rollout grows the registry but never this tuple, so the lane's
        # compiled programs (keyed on it) stay valid and untouched
        self.custom_fns = server.registry.fns_at(self.reg_gen)
        self.m_peaks = (101 if 21 in self.bbob_fids
                        else 21 if 22 in self.bbob_fids else 1)
        fill_fid = self.bbob_fids[0] if self.bbob_fids else 1
        self.filler_inst = bbob.pad_instance(
            bbob.make_instance(fill_fid, dim, 0, self.engine.full.cfg.jdtype),
            self.m_peaks)
        self.seg_len: Dict[int, int] = {}
        self.used_programs: set = set()
        self.allocator = SlotAllocator(len(server.devices),
                                       server.rows_per_island)
        self.fev_dt = jax.dtypes.canonicalize_dtype(jnp.int64)
        self._row_init = jax.jit(self.engine.full.init_carry)
        self._write_row = jax.jit(self._write_row_fn)
        self._deactivate = jax.jit(self._deactivate_fn)
        self.islands = [
            _Island(dev, jax.device_put(self._blank_arrays(), dev))
            for dev in server.devices]

    # -- array plumbing -------------------------------------------------------
    @staticmethod
    def _write_row_fn(arrays, vals, row):
        return jax.tree_util.tree_map(
            lambda a, v: jax.lax.dynamic_update_index_in_dim(
                a, jnp.asarray(v, a.dtype), row, 0), arrays, vals)

    @staticmethod
    def _deactivate_fn(carry, mask):
        return carry._replace(active=carry.active & ~mask[:, None])

    def _blank_arrays(self, Bl: Optional[int] = None) -> dict:
        """One island's inert initial arrays (host; caller device_puts)."""
        Bl = self.allocator.rows_per_island if Bl is None else int(Bl)
        keys = jnp.stack([jax.random.fold_in(jax.random.PRNGKey(0), j)
                          for j in range(Bl)])
        carry = jax.vmap(self.engine.full.init_carry)(keys)
        carry = carry._replace(active=jnp.zeros_like(carry.active))
        insts = jax.tree_util.tree_map(
            lambda a: jnp.repeat(a[None], Bl, axis=0), self.filler_inst)
        return {"keys": keys,
                "fn_idx": jnp.zeros((Bl,), jnp.int32),
                "budgets": jnp.zeros((Bl,), self.fev_dt),
                "insts": insts,
                "carry": carry}

    # -- segment programs -----------------------------------------------------
    def program_key(self, k: int, seg_gens: int) -> tuple:
        eng = self.engine
        return ("service", eng.bucket_cfgs[k], self.key, eng.max_evals,
                tuple(self.server.domain), self.server.sigma0_frac,
                self.server.impl, self.bbob_fids, self.custom_fns,
                self.m_peaks, int(k), int(seg_gens),
                bbob.eval_fusion_enabled(),
                tuple((d.platform, d.id) for d in self.server.devices))

    def runner(self, k: int, seg_gens: int) -> Callable:
        key = self.program_key(k, seg_gens)
        traces0 = _SEGMENT_CACHE.stats["traces"]
        with obs.tracer().span(
                "compile", key=f"{_lane_label(self.key)}.k{k}.g{seg_gens}",
                lane=_lane_label(self.key)) as sp:
            fn = _SEGMENT_CACHE.get(key,
                                    lambda: self._build_runner(k, seg_gens))
            sp.attrs["hit"] = _SEGMENT_CACHE.stats["traces"] == traces0
        self.used_programs.add(key)
        return fn

    def _build_runner(self, k: int, seg_gens: int) -> Callable:
        eng, bbob_fids, custom = self.engine, self.bbob_fids, self.custom_fns

        def run_one(base_key, fn_idx, budget, inst, carry):
            def fit(X):
                if bbob_fids:
                    branches = [lambda x: bbob.evaluate_dynamic(
                        inst, x, bbob_fids)]
                else:       # no BBOB menu configured: branch 0 is poison
                    branches = [lambda x: jnp.full(x.shape[:-1], jnp.inf,
                                                   x.dtype)]
                branches += [lambda x, f=f: jnp.asarray(f(x), x.dtype)
                             for f in custom]
                idx = jnp.clip(fn_idx, 0, len(branches) - 1)
                return jax.lax.switch(idx, branches, X)
            if bbob_fids and not custom:
                # pure-BBOB menu: ride the eval-fused sample epilogue when
                # the whole menu is separable (custom callables keep the
                # two-program path — their branch can't carry SepCoeffs)
                fit = bbob.fusable_fitness(inst, bbob_fids, fit)
            return eng.segment_scan(k, base_key, fit, carry, seg_gens,
                                    max_evals=budget)

        return jax.jit(jax.vmap(run_one))


@dataclasses.dataclass
class StepStats:
    dispatched: int = 0
    admitted: int = 0
    finalized: int = 0
    rejected: int = 0
    expired: int = 0                    # queue-TTL/deadline retirements
    shed: int = 0                       # priority-shed settlements

    def progressed(self) -> bool:
        return bool(self.dispatched or self.admitted or self.finalized
                    or self.rejected or self.expired or self.shed)


class CampaignServer:
    """Multi-tenant streaming campaign service (see module docstring).

    ``devices`` / ``mesh`` pick the fleet (default: all local devices — one
    S2-style island per device per lane).  ``bbob_fids`` is the compiled-in
    BBOB menu: requests may use any of these fids without recompilation;
    custom callables come from ``registry`` and must be registered before the
    first submit.  ``max_budget`` bounds every job's budget (it is baked into
    the bucket programs' segment sizing).
    """

    def __init__(self, registry: Optional[FitnessRegistry] = None,
                 mesh=None, devices: Optional[Sequence] = None,
                 bbob_fids: Tuple[int, ...] = (1, 8),
                 lam_start: int = 12, kmax_exp: int = 4,
                 dtype: str = "float64", impl: str = "auto",
                 policy: str = "cover", eigen_interval: Optional[int] = None,
                 seg_blocks: Optional[int] = None,
                 domain: Tuple[float, float] = (-5.0, 5.0),
                 sigma0_frac: float = 0.25, max_budget: int = 200_000,
                 rows_per_island: int = 4, max_pending: int = 256,
                 max_lanes: int = 16, snapshot_dir: Optional[str] = None,
                 snapshot_every: int = 0,
                 metrics_out: Optional[str] = None,
                 quarantine_nonfinite: bool = True,
                 quarantine_stall_boundaries: int = 0):
        if devices is not None:
            self.devices = list(devices)
        elif mesh is not None:
            self.devices = list(mesh.devices.flat)
        else:
            self.devices = [jax.devices()[0]]
        self.registry = registry if registry is not None else FitnessRegistry()
        self.registry.freeze()
        self.bbob_fids = tuple(bbob_fids)
        self.lam_start, self.kmax_exp = int(lam_start), int(kmax_exp)
        self.dtype, self.impl, self.policy = dtype, impl, policy
        self.eigen_interval, self.seg_blocks = eigen_interval, seg_blocks
        self.domain, self.sigma0_frac = tuple(domain), float(sigma0_frac)
        self.max_budget = int(max_budget)
        self.rows_per_island = int(rows_per_island)
        self.max_lanes = int(max_lanes)
        self.snapshot_dir, self.snapshot_every = snapshot_dir, snapshot_every
        # JSONL metrics sink, flushed once per service round (step()); NOT a
        # _CONFIG_FIELDS member — where metrics go is a property of the
        # serving process, not of the snapshot-persisted service config
        self.metrics_out = metrics_out
        # poison policy: quarantine a job whose best_f is non-finite after
        # real evaluations, and/or whose fevals watermark stays flat for N
        # consecutive boundaries it was actually dispatched (0 = off).  Both
        # are host-side checks on the already-pulled schedule arrays — no
        # new syncs, no row operands, no programs.
        self.quarantine_nonfinite = bool(quarantine_nonfinite)
        self.quarantine_stall_boundaries = int(quarantine_stall_boundaries)
        self.queue = qmod.AdmissionQueue(max_pending=max_pending)
        self.tickets: Dict[int, CampaignTicket] = {}
        self.lanes: Dict[tuple, _Lane] = {}
        self._completed: set = set()
        self._boundary_n = 0
        # per-job trace spans (obs/trace.py): root "job" span + the current
        # lifecycle phase child ("queued"/"running") — kept OFF the ticket
        # so snapshots stay span-free (a restore re-opens fresh spans)
        self._job_spans: Dict[int, dict] = {}
        # request lifecycle state (all host-side)
        self._cancels: set = set()      # running job ids to retire at boundary
        self._dedup: Dict[str, int] = {}        # dedup_key -> job id
        self._noprog: Dict[int, Tuple[int, int]] = {}   # job -> (fev, flats)
        self._seg_jobs: Dict[tuple, set] = {}   # (lane key, island) -> jobs
        #                                         in the last dispatched set
        # fleet supervision hook points (repro.fleet.FleetController): the
        # server never imports the fleet — a controller installs itself on
        # ``fleet`` and marks failed islands in ``down_islands``; without
        # one, both stay empty and every hook site is a host-side no-op
        self.fleet = None
        self.down_islands: set = set()

    # -- config round-trip (snapshots) ----------------------------------------
    _CONFIG_FIELDS = ("bbob_fids", "lam_start", "kmax_exp", "dtype", "impl",
                      "policy", "eigen_interval", "seg_blocks", "domain",
                      "sigma0_frac", "max_budget", "rows_per_island",
                      "max_lanes", "quarantine_nonfinite",
                      "quarantine_stall_boundaries")

    def config_meta(self) -> dict:
        out = {f: getattr(self, f) for f in self._CONFIG_FIELDS}
        out["bbob_fids"] = list(out["bbob_fids"])
        out["domain"] = list(out["domain"])
        return out

    # -- submission -----------------------------------------------------------
    def submit(self, req: CampaignRequest,
               now_s: Optional[float] = None) -> CampaignTicket:
        """Enqueue one job; returns its ``CampaignTicket`` immediately.

        The request is validated against THIS server's compiled surface
        (budget ≤ ``max_budget``, ``fid`` in the compiled-in BBOB menu,
        ``fitness`` registered before the server started) — violations raise
        ``ValueError`` here, at the front door, instead of failing inside a
        traced program.  A full pending queue raises ``queue.QueueFull``
        (admission backpressure).  The ticket streams per-boundary updates
        once the job is admitted into a lane row and carries the full
        ``IPOPResult`` when it completes; ``now_s`` overrides the submit
        timestamp (``time.monotonic()``) for replayed arrival traces — the
        soak harness uses it to measure queue wait under a synthetic load.

        A ``req.dedup_key`` makes the submit idempotent: if the key maps to
        a ticket that is still live (queued/running) or completed, THAT
        ticket is returned and nothing is enqueued — so a client retrying
        with backoff after a ``shed``/``expired`` outcome never double-runs
        a job that actually made it in.  A key whose previous attempt ended
        shed/cancelled/expired/rejected/quarantined admits the retry fresh.
        """
        req.validate()
        if req.dedup_key is not None:
            prev = self.tickets.get(self._dedup.get(req.dedup_key, -1))
            if prev is not None and (not prev.terminal or prev.done):
                return prev             # idempotent resubmit
        if req.budget > self.max_budget:
            raise ValueError(f"budget {req.budget} exceeds the service "
                             f"max_budget {self.max_budget}")
        if req.fid is not None and req.fid not in self.bbob_fids:
            raise ValueError(f"fid {req.fid} is not in the compiled-in BBOB "
                             f"menu {self.bbob_fids}")
        if req.fitness is not None and req.fitness not in self.registry.names:
            raise ValueError(f"unknown fitness {req.fitness!r}; registered: "
                             f"{self.registry.names}")
        self.registry.freeze()          # pin the current generation
        t = self.queue.submit(
            req, now_s=time.monotonic() if now_s is None else now_s)
        self.tickets[t.job_id] = t
        if req.dedup_key is not None:
            self._dedup[req.dedup_key] = t.job_id
        reg = obs.metrics()
        reg.counter("service_jobs_total", event="submitted").inc()
        reg.counter("service_job_lifecycle_total",
                    **{"from": "new", "to": JOB_QUEUED}).inc()
        self._open_job_trace(t)
        self._settle_shed()             # the submit may have evicted a victim
        return t

    def cancel(self, job_id: int) -> bool:
        """Cancel one job.  A queued job is retired immediately (terminal
        ``status="cancelled"``); a running job is retired at its island's
        next segment boundary — the partial result up to that boundary lands
        on the ticket.  Returns False for unknown or already-terminal jobs
        (cancellation is idempotent, not an error)."""
        t = self.tickets.get(job_id)
        if t is None or t.terminal:
            return False
        if t.status == JOB_QUEUED:
            if self.queue.remove(job_id) is None:
                return False
            t.done_s = time.monotonic()
            self._transition(t, JOB_CANCELLED, "cancelled by client")
            obs.metrics().counter("service_jobs_total",
                                  event="cancelled").inc()
            return True
        self._cancels.add(job_id)       # honored at the next boundary pull
        return True

    # -- lifecycle bookkeeping ------------------------------------------------
    _TERMINAL_STATES = (JOB_DONE, JOB_REJECTED, JOB_CANCELLED, JOB_EXPIRED,
                        JOB_QUARANTINED, JOB_SHED)

    def _open_job_trace(self, t: CampaignTicket, phase: str = JOB_QUEUED):
        """Start a job's root trace span plus its current lifecycle-phase
        child.  The root spans submit → terminal; phase children ("queued",
        "running", "recover") chain through parent_id so the whole
        lifecycle — including post-failure recovery — is one trace."""
        tr = obs.tracer()
        root = tr.start("job", job=t.job_id, dim=t.request.dim,
                        priority=t.request.priority)
        ph = tr.start("running" if phase == JOB_RUNNING else "queued",
                      parent=root, job=t.job_id)
        self._job_spans[t.job_id] = {"root": root, "phase": ph}

    def _close_job_trace(self, t: CampaignTicket):
        """End a job's open phase + root spans with its terminal status and
        reason as span attrs (no-op for jobs without a live trace)."""
        spans = self._job_spans.pop(t.job_id, None)
        if spans is None:
            return
        tr = obs.tracer()
        ph = spans.get("phase")
        if ph is not None and ph.t1 is None:
            tr.end(ph)
        tr.end(spans["root"], status=t.status, reason=t.reason)

    def note_recovery(self, job_id: int, island: int, mode: str,
                      boundary: int):
        """Fleet hook: stitch a recovered job's trace across the failure.
        Ends the pre-failure "running" phase, drops a "recover" marker, and
        opens the post-failure "running" phase — all children of the SAME
        root span, so the pre/post parent_id chain is intact (asserted by
        the chaos gate)."""
        spans = self._job_spans.get(job_id)
        if spans is None:
            return
        tr = obs.tracer()
        ph = spans.get("phase")
        if ph is not None and ph.t1 is None:
            tr.end(ph, failed_island=island)
        tr.event("recover", parent=spans["root"], job=job_id, mode=mode,
                 failed_island=island, boundary=boundary)
        spans["phase"] = tr.start("running", parent=spans["root"],
                                  job=job_id)

    def _transition(self, t: CampaignTicket, status: str, reason: str = ""):
        """Move a ticket to ``status``, recording the edge in the lifecycle
        counter (every state-machine transition is observable) and keeping
        the job's trace spans in step: entering ``running`` swaps the phase
        child, a terminal status ends the root span."""
        frm = t.status
        t.status = status
        if reason:
            t.reason = reason
        obs.metrics().counter("service_job_lifecycle_total",
                              **{"from": frm, "to": status}).inc()
        if status in self._TERMINAL_STATES:
            self._close_job_trace(t)
        elif status == JOB_RUNNING:
            spans = self._job_spans.get(t.job_id)
            if spans is not None:
                tr = obs.tracer()
                ph = spans.get("phase")
                if ph is not None and ph.t1 is None:
                    tr.end(ph)
                spans["phase"] = tr.start("running", parent=spans["root"],
                                          job=t.job_id)

    def _settle_shed(self, stats: Optional[StepStats] = None):
        """Account tickets the queue shed since the last settle: lifecycle +
        shed counters, terminal timestamps (the queue already set status)."""
        reg = obs.metrics()
        for t in self.queue.drain_shed():
            t.done_s = time.monotonic()
            reg.counter("service_job_lifecycle_total",
                        **{"from": JOB_QUEUED, "to": JOB_SHED}).inc()
            reg.counter("service_shed_total").inc()
            reg.counter("service_jobs_total", event="shed").inc()
            self._close_job_trace(t)
            if stats is not None:
                stats.shed += 1

    def _expire_queued(self, stats: Optional[StepStats] = None):
        """Retire pending tickets whose queue-TTL or total deadline passed
        (host clock check; the queue sets terminal ``status="expired"``)."""
        reg = obs.metrics()
        for t in self.queue.expire(time.monotonic()):
            t.done_s = time.monotonic()
            reg.counter("service_job_lifecycle_total",
                        **{"from": JOB_QUEUED, "to": JOB_EXPIRED}).inc()
            reg.counter("service_jobs_total", event="expired").inc()
            self._close_job_trace(t)
            if stats is not None:
                stats.expired += 1

    # -- lanes ----------------------------------------------------------------
    def _lane_key(self, req: CampaignRequest) -> tuple:
        """Routing: the request's dim-class at the right registry generation.

        A request needs at least the generation its fitness callable was born
        in (BBOB requests run in any generation).  It routes to the *newest*
        existing lane of its dim-class that satisfies that floor — resident
        older-generation lanes are never grown — and, when no lane fits, keys
        a fresh lane at the registry's current generation, so post-rollout
        lanes compile against the full branch list exactly once.
        """
        need = (0 if req.fitness is None
                else self.registry.gen_added(req.fitness))
        base = lane_key(req, lam_start=self.lam_start, kmax_exp=self.kmax_exp,
                        dtype=self.dtype)[:4]
        fits = [k for k in self.lanes
                if k[:4] == base and k[4] >= need]
        if fits:
            return max(fits, key=lambda k: k[4])
        return base + (max(need, self.registry.generation),)

    def _get_lane(self, key: tuple, create: bool = True) -> Optional[_Lane]:
        lane = self.lanes.get(key)
        if lane is None and create:
            if len(self.lanes) >= self.max_lanes:
                return None
            lane = _Lane(key, self)
            self.lanes[key] = lane
        return lane

    def _create_lanes(self):
        for t in self.queue.pending():
            self._get_lane(self._lane_key(t.request))

    # -- the service loop -----------------------------------------------------
    def step(self) -> StepStats:
        """One service round: every island gets a segment boundary —
        pull, stream, retire, admit, dispatch (async)."""
        stats = StepStats()
        self._settle_shed(stats)        # submits between steps may have shed
        self._expire_queued(stats)      # queue-TTL/deadline, host clock only
        self._create_lanes()
        for lane in self.lanes.values():
            for i, isl in enumerate(lane.islands):
                if i in self.down_islands:
                    continue            # dead island: no pull, no dispatch
                self._island_boundary(lane, i, isl, stats)
        self._boundary_n += 1
        reg = obs.metrics()
        reg.counter("service_boundaries_total").inc()
        reg.gauge("service_queue_depth").set(len(self.queue))
        for lane in self.lanes.values():
            lbl = _lane_label(lane.key)
            al = lane.allocator
            for i in range(al.n_islands):
                reg.gauge("service_slot_occupancy", lane=lbl, island=i).set(
                    1.0 - al.free_rows(i) / al.rows_per_island)
        pc = program_cache_stats()
        if pc["hits"] + pc["traces"]:
            reg.gauge("service_program_cache_hit_rate").set(
                pc["hits"] / (pc["hits"] + pc["traces"]))
        reg.gauge("service_registry_generation").set(
            self.registry.generation)
        if self.metrics_out:
            reg.flush_jsonl(self.metrics_out)
        if (self.snapshot_dir and self.snapshot_every
                and self._boundary_n % self.snapshot_every == 0):
            self.snapshot()
        return stats

    def drain(self, max_steps: int = 10_000) -> List[CampaignTicket]:
        """Run until every submitted job completed (or was rejected)."""
        for _ in range(max_steps):
            stats = self.step()
            if not stats.progressed() and not self._resident_jobs():
                break                   # idle: everything placeable finished
        else:
            raise RuntimeError(f"service did not drain in {max_steps} steps")
        # anything still queued at idle can never be placed (lane cap): reject
        while len(self.queue):
            item = self.queue.take()
            if item is None:
                break
            _req, t = item
            t.done_s = time.monotonic()
            self._transition(t, JOB_REJECTED, "unplaceable at idle")
            obs.metrics().counter("service_jobs_total",
                                  event="rejected").inc()
        return [t for t in self.tickets.values() if t.done]

    def release_ticket(self, job_id: int) -> Optional[CampaignTicket]:
        """Pop a *terminal* ticket and return it (``None`` if unknown or
        still live).  Long-running callers (the soak harness) release
        tickets as jobs reach any terminal state — done, shed, expired,
        cancelled, quarantined — so host memory stays O(resident), not
        O(total jobs); the job id remains in ``_completed`` so trace
        pruning still recognises the retired rows, and the job's dedup key
        (if any) is unpinned so a later resubmit starts fresh."""
        t = self.tickets.get(job_id)
        if t is None or not t.terminal:
            return None
        dk = t.request.dedup_key
        if dk is not None and self._dedup.get(dk) == job_id:
            del self._dedup[dk]
        return self.tickets.pop(job_id)

    def _resident_jobs(self) -> int:
        return sum(len(lane.allocator.occupied())
                   for lane in self.lanes.values())

    def _island_boundary(self, lane: _Lane, i: int, isl: _Island,
                         stats: StepStats):
        al = lane.allocator
        reg = obs.metrics()
        lbl = _lane_label(lane.key)
        pull_span = obs.tracer().start("pull", lane=lbl, island=i)
        t0 = time.perf_counter()
        if self.fleet is not None:
            k_idx, active, fevals, best_f = self.fleet.pull(
                i, self._boundary_n,
                lambda: bucketed.pull_schedule(isl.arrays["carry"]),
                lane=lane.key, jobs=al.row_jobs[i].copy())
        else:
            k_idx, active, fevals, best_f = bucketed.pull_schedule(
                isl.arrays["carry"])
        pull_wall = time.perf_counter() - t0
        obs.tracer().end(pull_span, boundary=self._boundary_n)
        reg.histogram("service_boundary_pull_s", lane=lbl).observe(pull_wall)
        k_idx, active, fevals = k_idx.copy(), active.copy(), fevals.copy()
        lam_cur = lane.engine.lam_start * (2 ** k_idx)

        # -- stream + enforce lifecycle + collect finished rows -----------
        # every verdict below is a host-side decision on the arrays the
        # boundary ALREADY pulled plus the host clock: retiring a row for
        # deadline/cancel/poison costs zero extra syncs and zero programs
        # (it rides the same _deactivate mask as target retirement)
        now = time.monotonic()
        ran = self._seg_jobs.get((lane.key, i), ())
        finish: List[Tuple[int, int, Optional[Tuple[str, str]]]] = []
        deact = np.zeros(len(k_idx), bool)
        for row in np.nonzero(al.row_jobs[i] >= 0)[0]:
            job = int(al.row_jobs[i][row])
            t = self.tickets[job]
            t.best_f = float(best_f[row])
            t.fevals = int(fevals[row])
            if not t.updates and t.submit_s is not None:
                reg.histogram("service_time_to_first_ticket_s").observe(
                    time.monotonic() - t.submit_s)
            t.push({"boundary": self._boundary_n, "fevals": t.fevals,
                    "best_f": t.best_f, "k": int(k_idx[row])})
            target = t.request.target
            hit = target is not None and best_f[row] <= target
            done = (not active[row]
                    or fevals[row] + lam_cur[row] > al.budgets[i][row])
            verdict = None if done else self._row_verdict(
                t, job, int(fevals[row]), float(best_f[row]), job in ran,
                now)
            if (hit or verdict is not None) and not done:
                deact[row] = True       # early/lifecycle retirement
                active[row] = False
                done = True
            if done:
                finish.append((int(row), job, None if hit else verdict))
        # flight-recorder feed: one observation per island boundary, built
        # entirely from the arrays this boundary ALREADY pulled plus the
        # fleet's (host-side) health grade — the last K of these become the
        # post-mortem timeline when this island dies or quarantines a job
        flight_recorder().observe(
            i, self._boundary_n, lane=lbl,
            wall=round(pull_wall, 6), fevals=int(np.sum(fevals)),
            grade=(self.fleet.health.state(i) if self.fleet is not None
                   else "alive"),
            verdicts=[{"job": job, "status": v[0], "reason": v[1]}
                      for _row, job, v in finish if v is not None])
        if deact.any():
            isl.arrays["carry"] = lane._deactivate(
                isl.arrays["carry"], jax.device_put(deact, isl.device))
        if finish:
            with obs.tracer().span("retire", lane=lbl, island=i,
                                   boundary=self._boundary_n,
                                   rows=len(finish)):
                for row, job, verdict in finish:
                    if verdict is None:
                        self._finalize(lane, i, isl, row, job)
                    else:
                        self._finalize(lane, i, isl, row, job,
                                       status=verdict[0], reason=verdict[1])
                    stats.finalized += 1
        self._prune_traces(isl)

        # -- admission (highest priority first, this island's free rows) --
        while al.free_rows(i) > 0:
            item = self.queue.take(
                lambda r: self._lane_key(r) == lane.key)
            if item is None:
                break
            req, t = item
            row = self._admit(lane, i, isl, req, t)
            k_idx[row], active[row], fevals[row] = 0, True, 0
            stats.admitted += 1

        # -- dispatch the island's next segment (async) -------------------
        live, k = bucketed.next_bucket(lane.engine, k_idx, active, fevals,
                                       lane.seg_len, budgets=al.budgets[i])
        if k is None:
            self._seg_jobs[(lane.key, i)] = set()
            return
        # the jobs whose rows actually run this segment: the no-progress
        # watermark only charges flat boundaries against these, and the
        # fleet health detector only expects island progress when some
        # live, non-quarantined row was dispatched
        self._seg_jobs[(lane.key, i)] = {
            int(al.row_jobs[i][r]) for r in np.nonzero(live)[0]
            if al.row_jobs[i][r] >= 0}
        with obs.tracer().span("dispatch", lane=lbl, island=i, bucket=int(k),
                               boundary=self._boundary_n):
            runner = lane.runner(k, lane.seg_len[k])
            if self.fleet is not None:
                self.fleet.before_dispatch(i, self._boundary_n,
                                           live_rows=int(np.sum(live)))
            a = isl.arrays
            carry, tr = runner(a["keys"], a["fn_idx"], a["budgets"],
                               a["insts"], a["carry"])
        isl.arrays["carry"] = carry
        own = np.repeat(al.row_jobs[i].copy()[:, None], lane.seg_len[k],
                        axis=1)
        isl.traces.append((tr, own))
        reg.counter("service_segments_total", lane=lbl, bucket=k).inc()
        stats.dispatched += 1

    def _row_verdict(self, t: CampaignTicket, job: int, fevals: int,
                     best_f: float, ran: bool,
                     now: float) -> Optional[Tuple[str, str]]:
        """Lifecycle verdict for one running row at a boundary: ``(status,
        reason)`` to retire it with, or None to keep running.  Order:
        explicit cancel beats deadline beats poison."""
        if job in self._cancels:
            return (JOB_CANCELLED, "cancelled by client")
        if t.deadline_at is not None and now >= t.deadline_at:
            return (JOB_EXPIRED, "deadline exceeded while running")
        if self.quarantine_nonfinite and fevals > 0 \
                and not np.isfinite(best_f):
            # NaN/inf fitness never improves best_f (NaN comparisons are
            # False in the ladder's best update), so a poison callable
            # shows up here as best_f == inf after real evaluations
            return (JOB_QUARANTINED,
                    "non-finite fitness after "
                    f"{fevals} evaluations")
        if self.quarantine_stall_boundaries > 0:
            last, flats = self._noprog.get(job, (-1, 0))
            if ran and fevals == last:
                flats += 1
                if flats >= self.quarantine_stall_boundaries:
                    self._noprog.pop(job, None)
                    return (JOB_QUARANTINED,
                            f"no progress for {flats} dispatched boundaries")
            elif fevals != last:
                flats = 0
            self._noprog[job] = (fevals, flats)
        return None

    def _job_vals(self, lane: _Lane, req: CampaignRequest) -> dict:
        """A job's full row state as a pure function of its request —
        matching ``_Lane._write_row``'s structure.  Admission writes it; the
        fleet controller rebuilds it to replay a job whose island died
        before a snapshot captured its progress (same key, same init: the
        replayed trajectory is the one the dead island was computing)."""
        base_key = (jnp.asarray(req.key, jnp.uint32) if req.key is not None
                    else jax.random.PRNGKey(req.seed))
        if req.fid is not None:
            fn_idx = 0
            inst = bbob.pad_instance(
                bbob.make_instance(req.fid, req.dim, req.instance,
                                   lane.engine.full.cfg.jdtype),
                lane.m_peaks)
        else:
            fn_idx = 1 + self.registry.index(req.fitness)
            inst = lane.filler_inst
        return {"keys": base_key, "fn_idx": fn_idx, "budgets": req.budget,
                "insts": inst, "carry": lane._row_init(base_key)}

    def _admit(self, lane: _Lane, i: int, isl: _Island,
               req: CampaignRequest, t: CampaignTicket) -> int:
        al = lane.allocator
        placed = al.alloc(t.job_id, req.budget, island=i)
        assert placed is not None, "admission called without a free row"
        _i, row = placed
        vals = self._job_vals(lane, req)
        isl.arrays = lane._write_row(isl.arrays, vals, row)
        self._transition(t, JOB_RUNNING)
        t.lane, t.island, t.row = lane.key, i, row
        t.admit_s = time.monotonic()
        t.admit_boundary = self._boundary_n
        reg = obs.metrics()
        reg.counter("service_jobs_total", event="admitted").inc()
        if t.submit_s is not None:
            reg.histogram("service_admission_wait_s").observe(
                t.admit_s - t.submit_s)
        return row

    def _finalize(self, lane: _Lane, i: int, isl: _Island, row: int,
                  job: int, status: str = JOB_DONE, reason: str = ""):
        """Retire one resident row: slice its carry + owned trace pieces into
        an ``IPOPResult`` on the ticket, free the slot.  ``status`` is the
        terminal state — ``done`` for a normally-finished job, or a
        lifecycle state (cancelled/expired/quarantined), in which case the
        result is the *partial* trajectory up to this boundary and
        ``reason`` says why it stopped there."""
        carry_row = jax.tree_util.tree_map(
            lambda a: np.asarray(a[row]), isl.arrays["carry"])
        pieces = []
        for tr, own in isl.traces:
            mask = own[row] == job
            if mask.any():
                pieces.append(jax.tree_util.tree_map(
                    lambda a: np.asarray(a[row])[mask], tr))
        if pieces:
            trace = jax.tree_util.tree_map(
                lambda *xs: np.concatenate(xs, axis=0), *pieces)
        else:
            trace = bucketed._empty_trace(carry_row, time_axis=0)
        t = self.tickets[job]
        t.result = ipop_mod._result_from_ladder(lane.engine.full, carry_row,
                                                trace)
        self._transition(t, status, reason)
        t.best_f = t.result.best_f
        t.fevals = t.result.total_fevals
        t.done_s = time.monotonic()
        lane.allocator.release(i, row)
        # every terminal resident job joins _completed so trace pruning
        # recognises its retired rows, whatever state it ended in
        self._completed.add(job)
        self._cancels.discard(job)
        self._noprog.pop(job, None)
        reg = obs.metrics()
        if status == JOB_DONE:
            reg.counter("service_jobs_total", event="completed").inc()
            if t.submit_s is not None:
                reg.histogram("service_time_to_completion_s").observe(
                    t.done_s - t.submit_s)
        else:
            reg.counter("service_jobs_total",
                        event=status).inc()
            if status == JOB_QUARANTINED:
                kind = ("nonfinite" if "non-finite" in reason
                        else "no_progress")
                reg.counter("service_quarantine_total", reason=kind).inc()
                # a poisoned job is a failure artifact worth a post-mortem:
                # dump the island's last-K boundary timeline around it
                flight_recorder().dump(
                    i, self._boundary_n, "quarantine",
                    extra={"job": job, "reason": reason,
                           "lane": _lane_label(lane.key), "row": row})

    def _prune_traces(self, isl: _Island):
        def live(own):
            jobs = np.unique(own)
            jobs = jobs[jobs >= 0]
            return any(int(j) not in self._completed for j in jobs)
        isl.traces = [(tr, own) for tr, own in isl.traces if live(own)]

    # -- accounting -----------------------------------------------------------
    def segment_compiles(self) -> int:
        """Distinct segment programs used — the acceptance bound is
        ≤ #buckets × #dim-classes (#lanes)."""
        return sum(len(lane.used_programs) for lane in self.lanes.values())

    def stats(self) -> dict:
        return {
            "lanes": len(self.lanes),
            "boundaries": self._boundary_n,
            "queued": len(self.queue),
            "resident": self._resident_jobs(),
            "done": len(self._completed),
            "segment_compiles": self.segment_compiles(),
            "program_cache": program_cache_stats(),
        }

    def statusz(self) -> dict:
        """Live introspection snapshot for the HTTP ``/statusz`` endpoint
        (``start_metrics_server(status_fn=srv.statusz)``): lanes with
        per-island occupancy and health grade, registry generation, queue
        depth, active trace count.  Reads only host-side bookkeeping —
        safe to call from the HTTP thread mid-round."""
        lanes = {}
        for key, lane in self.lanes.items():
            al = lane.allocator
            lanes[_lane_label(key)] = {
                "islands": {
                    str(i): {
                        "occupancy": round(
                            1.0 - al.free_rows(i) / al.rows_per_island, 4),
                        "health": (self.fleet.health.state(i)
                                   if self.fleet is not None else "alive"),
                        "down": i in self.down_islands,
                    } for i in range(al.n_islands)},
            }
        return {"boundary": self._boundary_n,
                "lanes": lanes,
                "queue_depth": len(self.queue),
                "resident_jobs": self._resident_jobs(),
                "registry_generation": self.registry.generation,
                "active_traces": obs.tracer().active_count(),
                "down_islands": sorted(self.down_islands)}

    # -- durability -----------------------------------------------------------
    def snapshot(self) -> int:
        """Write a crash-resume snapshot; returns the committed step id.

        Persists, through ``checkpoint/store.py`` (arrays + an atomically
        committed ``meta.json``): every lane's island arrays (per-row
        operands + stacked carries), device-resident traces with their
        per-generation job-ownership columns, the allocator maps, all
        tickets, and the service config.  Anything a later ``restore`` needs
        to continue bit-exactly is in the snapshot EXCEPT host wall-clock
        ticket timestamps (latency measurements do not survive a resume) and
        custom fitness callables (the restoring process re-registers them by
        name).  Called automatically every ``snapshot_every`` boundaries when
        both it and ``snapshot_dir`` are set.
        """
        if not self.snapshot_dir:
            raise ValueError("server has no snapshot_dir")
        t0 = time.perf_counter()
        step = self._boundary_n
        tree: dict = {"lanes": {}}
        lanes_meta = []
        for li, (key, lane) in enumerate(self.lanes.items()):
            ltree: dict = {"islands": {}}
            trace_T = {}
            for i, isl in enumerate(lane.islands):
                entry = dict(isl.arrays)
                if isl.traces:
                    tr = jax.tree_util.tree_map(
                        lambda *xs: np.concatenate(
                            [np.asarray(x) for x in xs], axis=1),
                        *[t for t, _o in isl.traces])
                    own = np.concatenate([o for _t, o in isl.traces], axis=1)
                    entry["trace"] = tr
                    entry["own"] = own
                    trace_T[str(i)] = int(own.shape[1])
                else:
                    trace_T[str(i)] = 0
                ltree["islands"][str(i)] = entry
            tree["lanes"][str(li)] = ltree
            lanes_meta.append({
                "key": list(key),
                "seg_len": {str(k): int(v) for k, v in lane.seg_len.items()},
                "alloc": lane.allocator.to_meta(),
                "trace_T": trace_T,
            })
        jobs_meta = {}
        tree["results"] = {}
        for jid, t in self.tickets.items():
            jobs_meta[str(jid)] = {
                "status": t.status, "reason": t.reason,
                "request": t.request.to_meta(),
                "best_f": None if not np.isfinite(t.best_f) else t.best_f,
                "fevals": t.fevals, "island": t.island, "row": t.row,
                "lane": None if t.lane is None else list(t.lane),
                "admit_boundary": t.admit_boundary,
                # full ticket persistence: the streamed-update tail (already
                # bounded by CampaignTicket.TAIL_CAP) and, for completed
                # jobs, the full IPOPResult (arrays as checkpoint leaves) —
                # a post-crash --resume streams identical tickets
                "updates": list(t.updates),
            }
            if t.result is not None:
                rtree, rmeta = ipop_mod.result_to_tree(t.result)
                tree["results"][str(jid)] = rtree
                jobs_meta[str(jid)]["result"] = rmeta
        meta = {"config": self.config_meta(), "boundary": self._boundary_n,
                "lanes": lanes_meta, "jobs": jobs_meta,
                "next_job_id": max(self.tickets, default=-1) + 1,
                # lifecycle state: pending cancels (honored after resume),
                # dedup pins, and the registry's generation structure (the
                # restoring process re-registers callables by name; this
                # re-stamps their birth generations so 5-tuple lane keys
                # resolve identically)
                "cancels": sorted(self._cancels),
                "dedup": dict(self._dedup),
                "registry": {"names": list(self.registry.names),
                             "gens": list(self.registry._gens),
                             "gen": self.registry.generation}}
        store.save(self.snapshot_dir, step, tree, meta=meta)
        obs.metrics().histogram("service_snapshot_s").observe(
            time.perf_counter() - t0)
        return step

    @classmethod
    def restore(cls, ckpt_dir: str,
                registry: Optional[FitnessRegistry] = None,
                mesh=None, devices: Optional[Sequence] = None,
                step: Optional[int] = None,
                snapshot_every: Optional[int] = None) -> "CampaignServer":
        """Rebuild a server from the newest committed snapshot.

        ``registry`` must re-register the same custom fitness names the
        killed server had (callables cannot be persisted).  ``mesh`` /
        ``devices`` may differ from the writing run — the allocator re-packs
        resident rows across the new islands (elastic re-shard); state is
        restored exactly, so the remaining trajectory reproduces the
        uninterrupted run bit-for-bit on the same shapes.
        """
        if step is None:
            step = store.latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no committed snapshot in {ckpt_dir}")
        meta = store.load_meta(ckpt_dir, step)
        if meta is None:
            raise ValueError(f"snapshot step {step} has no meta.json")
        cfg = dict(meta["config"])
        cfg["bbob_fids"] = tuple(cfg["bbob_fids"])
        cfg["domain"] = tuple(cfg["domain"])
        srv = cls(registry=registry, mesh=mesh, devices=devices,
                  snapshot_dir=ckpt_dir,
                  snapshot_every=(snapshot_every if snapshot_every is not None
                                  else 0), **cfg)
        srv._boundary_n = int(meta["boundary"])
        # fast-forward BOTH queue counters: re-queued pending entries reuse
        # their job id in the heap's sequence slot, so fresh submissions must
        # draw sequence numbers beyond every restored id (a collision would
        # make heap ordering fall through to CampaignRequest comparison)
        srv.queue._ids = itertools.count(int(meta["next_job_id"]))
        srv.queue._seq = itertools.count(int(meta["next_job_id"]))
        # lifecycle state (absent in pre-lifecycle snapshots: empty defaults)
        srv._cancels = set(int(j) for j in meta.get("cancels", []))
        srv._dedup = {k: int(v) for k, v in meta.get("dedup", {}).items()}
        rmeta = meta.get("registry")
        if rmeta is not None:
            srv.registry.align_generations(rmeta["names"], rmeta["gens"],
                                           rmeta["gen"])
            srv.registry.freeze()

        # tickets: full persistence — streamed-update tails always, and the
        # complete IPOPResult for finished jobs (array leaves under
        # tree["results"]), so a resumed server streams identical tickets.
        # TTL/deadline clocks are RE-armed with the full allowance: a
        # restored server has no past wall clock to charge against.
        now = time.monotonic()
        for jid_s, jm in meta["jobs"].items():
            req = CampaignRequest.from_meta(jm["request"])
            t = CampaignTicket(job_id=int(jid_s), request=req,
                               status=jm["status"],
                               reason=jm.get("reason", ""),
                               best_f=(float("inf") if jm["best_f"] is None
                                       else jm["best_f"]),
                               fevals=jm["fevals"],
                               admit_boundary=jm["admit_boundary"])
            t.updates = list(jm.get("updates", []))
            if not t.terminal:
                t.arm(now)
                # spans are process-local (never snapshotted): a restored
                # live job gets a fresh trace rooted at the resume
                srv._open_job_trace(t, phase=t.status)
            srv.tickets[t.job_id] = t
            if t.terminal and t.status != JOB_REJECTED:
                # any terminal resident job must be recognised by trace
                # pruning; never-resident terminal jobs are harmless here
                srv._completed.add(t.job_id)

        template_tree = {"lanes": {}, "results": {}}
        for li, lmeta in enumerate(meta["lanes"]):
            key = tuple(lmeta["key"])
            if len(key) == 4:           # pre-generation snapshot lane key
                key = key + (0,)
            lane = srv._get_lane(key)
            lane.seg_len = {int(k): v for k, v in lmeta["seg_len"].items()}
            template_tree["lanes"][str(li)] = _lane_template(lane, lmeta)
        for jid_s, jm in meta["jobs"].items():
            if jm.get("result") is not None:
                template_tree["results"][jid_s] = ipop_mod.result_template(
                    jm["result"])
        if not template_tree["results"]:     # pre-results snapshot layout
            del template_tree["results"]
        restored = store.restore(ckpt_dir, step, template_tree)
        restored = jax.tree_util.tree_map(np.asarray, restored)

        for jid_s, jm in meta["jobs"].items():
            if jm.get("result") is not None:
                srv.tickets[int(jid_s)].result = ipop_mod.result_from_tree(
                    restored["results"][jid_s], jm["result"])

        for li, lmeta in enumerate(meta["lanes"]):
            key = tuple(lmeta["key"])
            if len(key) == 4:
                key = key + (0,)
            _repack_lane(srv, srv.lanes[key], lmeta,
                         restored["lanes"][str(li)])

        # re-queue pending jobs (preserving ids and priority order)
        for jid, t in sorted(srv.tickets.items()):
            if t.status == JOB_QUEUED:
                heapq.heappush(srv.queue._heap,
                               (-t.request.priority, jid, t.request, t))
        return srv


def _lane_template(lane: _Lane, lmeta: dict) -> dict:
    """Shape/dtype template matching one lane's snapshot subtree (built for
    the WRITING run's island grid, which may differ from ``lane``'s)."""
    sds = jax.ShapeDtypeStruct
    al = lmeta["alloc"]
    Bl = int(al["rows_per_island"])
    carry_t = jax.eval_shape(jax.vmap(lane.engine.full.init_carry),
                             sds((Bl, 2), jnp.uint32))
    insts_t = jax.tree_util.tree_map(
        lambda a: sds((Bl,) + a.shape, a.dtype), lane.filler_inst)
    out = {"islands": {}}
    for i in range(int(al["n_islands"])):
        entry = {"keys": sds((Bl, 2), jnp.uint32),
                 "fn_idx": sds((Bl,), jnp.int32),
                 "budgets": sds((Bl,), lane.fev_dt),
                 "insts": insts_t,
                 "carry": carry_t}
        T = int(lmeta["trace_T"][str(i)])
        if T:
            st = carry_t.states
            entry["trace"] = ladder.LadderTrace(
                ran=sds((Bl, T, 1), jnp.bool_),
                k_idx=sds((Bl, T, 1), jnp.int32),
                gen=sds((Bl, T, 1), st.gen.dtype),
                fevals=sds((Bl, T, 1), st.fevals.dtype),
                best_f=sds((Bl, T, 1), st.best_f.dtype),
                stop_reason=sds((Bl, T, 1), st.stop_reason.dtype),
                stopped=sds((Bl, T, 1), jnp.bool_),
                total_fevals=sds((Bl, T), carry_t.total_fevals.dtype),
                global_best=sds((Bl, T), carry_t.best_f.dtype))
            entry["own"] = sds((Bl, T), jnp.int64)
        out["islands"][str(i)] = entry
    return out


def _repack_lane(srv: CampaignServer, lane: _Lane, lmeta: dict,
                 ltree: dict):
    """Lay a restored lane's rows out on the (possibly different) new island
    grid and device_put each island to its device — the elastic re-shard.

    Rows carry everything trajectory-relevant (base key, budget, fitness
    index, instance, state), so moving a row between islands is a pure
    copy; restored traces keep their per-generation ownership columns
    (padding columns own -1 → never sliced into any job's result).
    """
    old_al = SlotAllocator.from_meta(lmeta["alloc"])
    new_al, moves, layout = old_al.repack(len(srv.devices),
                                          srv.rows_per_island)
    lane.allocator = new_al
    Bl = new_al.rows_per_island
    old = [ltree["islands"][str(i)] for i in range(old_al.n_islands)]
    operand_keys = ("keys", "fn_idx", "budgets", "insts", "carry")
    blank = jax.tree_util.tree_map(np.asarray, lane._blank_arrays(Bl))

    lane.islands = []
    for ni, dev in enumerate(srv.devices):
        arrays = jax.tree_util.tree_map(np.copy, blank)
        srcs = [(nr, layout[ni][nr]) for nr in range(Bl)
                if layout[ni][nr] is not None]
        for nr, (oi, orow) in srcs:
            for kk in operand_keys:
                for d, s in zip(jax.tree_util.tree_leaves(arrays[kk]),
                                jax.tree_util.tree_leaves(old[oi][kk])):
                    d[nr] = s[orow]
        isl = _Island(dev, jax.device_put(arrays, dev))
        traced = [(nr, oi, orow) for nr, (oi, orow) in srcs
                  if "own" in old[oi]]
        if traced:
            T = max(old[oi]["own"].shape[1] for _nr, oi, _r in traced)
            ref = old[traced[0][1]]["trace"]
            tr = jax.tree_util.tree_map(
                lambda a: np.zeros((Bl, T) + a.shape[2:], a.dtype), ref)
            own = np.full((Bl, T), -1, np.int64)
            for nr, oi, orow in traced:
                t_src = old[oi]["own"].shape[1]
                own[nr, :t_src] = old[oi]["own"][orow]
                for d, s in zip(jax.tree_util.tree_leaves(tr),
                                jax.tree_util.tree_leaves(old[oi]["trace"])):
                    d[nr, :t_src] = s[orow]
            isl.traces = [(tr, own)]
        lane.islands.append(isl)

    # reconcile resident tickets with their new placement
    for job, (ni, nr) in moves.items():
        t = srv.tickets.get(job)
        if t is not None:
            t.lane, t.island, t.row = lane.key, ni, nr


# ---------------------------------------------------------------------------
# one-shot parity wrapper — the `service` backend of ipop.run_ipop
# ---------------------------------------------------------------------------

def run_service_single(fitness_fn: Callable, n: int, key,
                       lam_start: int = 12, kmax_exp: int = 8,
                       max_evals: int = 200_000, domain=(-5.0, 5.0),
                       sigma0_frac: float = 0.25, impl: str = "auto",
                       dtype: str = "float64", fleet=None):
    """One problem through a single-row campaign service — trajectory parity
    with ``backend="bucketed"`` on the same key (tests/test_service.py).

    ``fleet`` (a ``repro.fleet.FleetConfig``) wraps the run in a
    ``FleetController`` with a throwaway snapshot store, so fault plans can
    be exercised through the public ``run_ipop`` surface.
    """
    reg = FitnessRegistry()
    reg.register("job", fitness_fn)
    srv = CampaignServer(registry=reg, bbob_fids=(), lam_start=lam_start,
                         kmax_exp=kmax_exp, dtype=dtype, impl=impl,
                         domain=domain, sigma0_frac=sigma0_frac,
                         max_budget=max_evals, rows_per_island=1,
                         devices=[jax.devices()[0]])
    ticket = srv.submit(CampaignRequest(dim=n, budget=max_evals,
                                        fitness="job", key=key))
    if fleet is None:
        srv.drain()
        return ticket.result
    import tempfile

    from repro.fleet.controller import FleetController
    with tempfile.TemporaryDirectory() as td:
        srv.snapshot_dir = td
        ctl = FleetController(srv, fleet)
        ctl.drain()
    return ticket.result
