"""Campaign service — multi-tenant streaming optimization-as-a-service.

The mesh engines run one batch-mode campaign fixed at trace time; this
package turns them into a *service*: independent optimization jobs are
admitted as they arrive, join a running bucketed program at segment
boundaries without recompilation, retire early, stream results, and survive
crashes through periodic snapshots (README "Campaign service").
"""
from repro.service.allocator import SlotAllocator, lane_key          # noqa: F401
from repro.service.queue import (AdmissionQueue, CampaignRequest,    # noqa: F401
                                 CampaignTicket, QueueFull,
                                 JOB_CANCELLED, JOB_DONE, JOB_EXPIRED,
                                 JOB_QUARANTINED, JOB_QUEUED, JOB_REJECTED,
                                 JOB_RUNNING, JOB_SHED, TERMINAL_STATUSES)
from repro.service.server import (CampaignServer, FitnessRegistry,   # noqa: F401
                                  run_service_single)
