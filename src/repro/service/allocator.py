"""Slot allocation: dim-class routing + free-slot bitmaps over island rows.

The compiled surface of the service is a fixed grid: each *lane* (one
dim-class) owns ``n_islands × rows_per_island`` member rows of the PR-2
rung-bucket slot machinery — stacked ``CMAState`` rows exactly like a
bucketed campaign's batch, padded with inert rows (``active=False``) where
no job lives.  Admission packs a request into a free row of the island with
the most head-room; retirement frees the row for the next tenant.  Because
every per-job quantity (base key, budget, fitness index, instance) is a
*row-indexed operand* of the segment programs — never part of a compile key
— jobs join and leave a RUNNING program family without recompilation:
compiles stay ≤ #buckets × #dim-classes (asserted in tests/test_service.py).

Rows are fully relocatable: a member's trajectory depends only on its base
key and its own state, not on which row or island executes it (row-keyed
sampling, ``ladder.slot_key`` over slot 0).  ``repack`` exploits that for
elastic restore — a snapshot taken on P islands re-packs onto P′ without
touching any trajectory.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.service.queue import CampaignRequest


def lane_key(req: CampaignRequest, *, lam_start: int, kmax_exp: int,
             dtype: str, reg_gen: int = 0) -> tuple:
    """Dim-class routing key: requests sharing it run in one lane (one
    compiled program family).  Request fields override the server defaults
    passed as keywords.  ``reg_gen`` is the fitness-registry *generation* the
    lane's programs are traced against (service/server.py): registering a new
    callable on a live server opens generation g+1 — new lanes key against
    it and compile fresh program families, while resident generation-g lanes
    keep running their already-compiled programs untouched."""
    return (int(req.dim),
            int(req.lam_start if req.lam_start is not None else lam_start),
            int(req.kmax_exp if req.kmax_exp is not None else kmax_exp),
            str(req.dtype if req.dtype is not None else dtype),
            int(reg_gen))


class SlotAllocator:
    """Free-slot bitmap per island + host mirrors of per-row job state.

    ``row_jobs[i][r]`` is the resident job id (-1 free); ``budgets`` mirrors
    the device-side per-row budget operand so the host re-bucketing decision
    (``bucketed.next_bucket(budgets=...)``) matches the device gate exactly —
    freed rows keep their last budget until reuse for the same reason.
    """

    def __init__(self, n_islands: int, rows_per_island: int):
        self.n_islands = int(n_islands)
        self.rows_per_island = int(rows_per_island)
        self.free = [np.ones(rows_per_island, bool) for _ in range(n_islands)]
        self.row_jobs = [np.full(rows_per_island, -1, np.int64)
                         for _ in range(n_islands)]
        self.budgets = [np.zeros(rows_per_island, np.int64)
                        for _ in range(n_islands)]

    @property
    def capacity(self) -> int:
        return self.n_islands * self.rows_per_island

    def free_rows(self, island: Optional[int] = None) -> int:
        """Free row count on ``island`` (or lane-wide when None)."""
        if island is not None:
            return int(self.free[island].sum())
        return int(sum(f.sum() for f in self.free))

    def occupied(self) -> List[Tuple[int, int, int]]:
        """(island, row, job_id) triples, deterministic order."""
        out = []
        for i, jobs in enumerate(self.row_jobs):
            for r in np.nonzero(jobs >= 0)[0]:
                out.append((i, int(r), int(jobs[r])))
        return out

    def alloc(self, job_id: int, budget: int,
              island: Optional[int] = None) -> Optional[Tuple[int, int]]:
        """Claim a free row (on ``island``, or the island with the most free
        rows — keeps islands balanced so S2 schedules stay even).  Returns
        (island, row) or None when the lane is full."""
        if island is None:
            frees = [f.sum() for f in self.free]
            island = int(np.argmax(frees))
            if frees[island] == 0:
                return None
        elif not self.free[island].any():
            return None
        row = int(np.argmax(self.free[island]))
        self.free[island][row] = False
        self.row_jobs[island][row] = job_id
        self.budgets[island][row] = budget
        return island, row

    def release(self, island: int, row: int):
        """Free the row for the next tenant (job retirement)."""
        self.free[island][row] = True
        self.row_jobs[island][row] = -1
        # budgets deliberately kept: the device mirror still holds the old
        # value and the row must stay schedule-inert under the same rule

    def to_meta(self) -> dict:
        return {"n_islands": self.n_islands,
                "rows_per_island": self.rows_per_island,
                "row_jobs": [[int(x) for x in jobs]
                             for jobs in self.row_jobs],
                "budgets": [[int(x) for x in b] for b in self.budgets]}

    @classmethod
    def from_meta(cls, meta: dict) -> "SlotAllocator":
        al = cls(meta["n_islands"], meta["rows_per_island"])
        for i, (jobs, buds) in enumerate(zip(meta["row_jobs"],
                                             meta["budgets"])):
            al.row_jobs[i] = np.asarray(jobs, np.int64)
            al.budgets[i] = np.asarray(buds, np.int64)
            al.free[i] = al.row_jobs[i] < 0
        return al

    def repack(self, n_islands: int, rows_per_island: Optional[int] = None,
               ) -> Tuple["SlotAllocator", Dict[int, Tuple[int, int]],
                          List[List[Optional[Tuple[int, int]]]]]:
        """Elastic re-shard: lay the occupied rows out on a new island grid.

        Returns ``(allocator', moves, layout)`` where ``moves[job_id] =
        (new_island, new_row)`` and ``layout[i'][r']`` names the OLD
        ``(island, row)`` each new cell pulls its state from (None → fresh
        inert filler).  Occupied rows fill the new grid island-major in
        deterministic order; capacity grows with padding rows and may shrink
        down to the occupied count.
        """
        occ = self.occupied()
        if rows_per_island is None:
            rows_per_island = max(self.rows_per_island,
                                  -(-len(occ) // int(n_islands)))
        new = SlotAllocator(n_islands, rows_per_island)
        if len(occ) > new.capacity:
            raise ValueError(
                f"cannot repack {len(occ)} resident jobs into "
                f"{n_islands}×{rows_per_island} rows")
        moves: Dict[int, Tuple[int, int]] = {}
        layout: List[List[Optional[Tuple[int, int]]]] = [
            [None] * rows_per_island for _ in range(n_islands)]
        for idx, (i, r, job) in enumerate(occ):
            ni, nr = idx % n_islands, idx // n_islands
            new.free[ni][nr] = False
            new.row_jobs[ni][nr] = job
            new.budgets[ni][nr] = self.budgets[i][r]
            moves[job] = (ni, nr)
            layout[ni][nr] = (i, r)
        return new, moves, layout
