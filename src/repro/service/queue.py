"""Campaign request spec, streaming tickets, and the admission queue.

A ``CampaignRequest`` is one tenant's optimization job: a BBOB (fid,
instance) pair or a registered fitness callable, a problem dimension, an
evaluation budget, an optional absolute fitness target (early retirement),
and a priority.  Submitting one to the server yields a ``CampaignTicket``
immediately — the job's streaming handle: per-boundary progress updates
while it runs, and the full ``IPOPResult`` once it completes.

The ``AdmissionQueue`` is the service's front door: priority-ordered pending
requests with *backpressure* — beyond ``max_pending`` the queue refuses new
work (``QueueFull``) instead of growing without bound, so a drowning service
degrades by rejecting rather than by dying.  Admission itself (taking a
request out of the queue and packing it into a running lane) only ever
happens at segment boundaries (service/server.py).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Callable, List, Optional, Tuple

JOB_QUEUED = "queued"
JOB_RUNNING = "running"
JOB_DONE = "done"
JOB_REJECTED = "rejected"


class QueueFull(RuntimeError):
    """Admission backpressure: the pending queue is at capacity."""


@dataclasses.dataclass
class CampaignRequest:
    """One optimization job.

    Exactly one of ``fid`` (BBOB, with ``instance``) or ``fitness`` (the name
    of a callable registered in the server's ``FitnessRegistry``) selects the
    objective.  ``budget`` is the evaluation budget (the ``max_evals`` a
    standalone ``run_ipop`` would get); ``target`` an optional absolute
    fitness value that retires the job early once reached (checked at segment
    boundaries).  ``key`` optionally overrides the PRNG key derived from
    ``seed`` — ``run_ipop(backend="service")`` uses it for bit-parity with
    the other backends.  ``lam_start``/``kmax_exp``/``dtype`` default to the
    server's configuration; together with ``dim`` they form the dim-class
    routing key (service/allocator.py) — requests in the same class share one
    compiled program family.
    """

    dim: int
    budget: int
    seed: int = 0
    fid: Optional[int] = None
    instance: int = 1
    fitness: Optional[str] = None
    target: Optional[float] = None
    priority: int = 0
    lam_start: Optional[int] = None
    kmax_exp: Optional[int] = None
    dtype: Optional[str] = None
    tag: str = ""
    key: Any = None                     # explicit jax PRNG key (overrides seed)

    def validate(self):
        if (self.fid is None) == (self.fitness is None):
            raise ValueError("exactly one of fid / fitness must be set")
        if self.dim < 1:
            raise ValueError(f"dim must be >= 1, got {self.dim}")
        if self.budget < 0:
            raise ValueError(f"budget must be >= 0, got {self.budget}")

    def to_meta(self) -> dict:
        """JSON-able form for snapshots (the explicit key is host-encoded)."""
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self) if f.name != "key"}
        if self.key is not None:
            import numpy as np
            d["_key"] = [int(x) for x in np.asarray(self.key).ravel()]
        return d

    @classmethod
    def from_meta(cls, d: dict) -> "CampaignRequest":
        d = dict(d)
        raw = d.pop("_key", None)
        req = cls(**d)
        if raw is not None:
            import jax.numpy as jnp
            req.key = jnp.asarray(raw, jnp.uint32)
        return req


@dataclasses.dataclass
class CampaignTicket:
    """Streaming handle of one submitted job (updated in place by the server).

    ``updates`` is the trajectory tail: one record per segment boundary while
    the job is resident ({boundary, fevals, best_f, k}), capped at
    ``TAIL_CAP`` most-recent entries.  ``result`` (an ``ipop.IPOPResult``
    with the full per-descent trajectory) lands when status turns "done".
    """

    TAIL_CAP = 512

    job_id: int
    request: CampaignRequest
    status: str = JOB_QUEUED
    best_f: float = float("inf")
    fevals: int = 0
    updates: List[dict] = dataclasses.field(default_factory=list)
    result: Any = None
    lane: Optional[tuple] = None
    island: Optional[int] = None
    row: Optional[int] = None
    # host wall-clock timestamps; None on tickets rebuilt from a snapshot
    # (timestamps are not persisted, so a resumed job has no latency)
    submit_s: Optional[float] = None
    admit_s: Optional[float] = None
    done_s: Optional[float] = None
    admit_boundary: Optional[int] = None

    def push(self, rec: dict):
        """Append one boundary update, dropping the oldest beyond
        ``TAIL_CAP`` (server-side; consumers just read ``updates``)."""
        self.updates.append(rec)
        if len(self.updates) > self.TAIL_CAP:
            del self.updates[:len(self.updates) - self.TAIL_CAP]

    @property
    def done(self) -> bool:
        """True once the full result landed (status ``"done"``)."""
        return self.status == JOB_DONE

    def latency_s(self) -> Optional[float]:
        """submit → done wall-clock latency (the quantity the soak SLO is
        written against); None while running or on a snapshot-restored
        ticket (timestamps are not persisted)."""
        if self.done_s is None or self.submit_s is None:
            return None
        return self.done_s - self.submit_s


class AdmissionQueue:
    """Priority-ordered pending requests with backpressure.

    ``submit`` is O(log n); ``take`` pops the highest-priority request (ties
    broken FIFO) matching a predicate — the server's admission pass calls it
    with "fits a lane with a free row" so a blocked wide job never starves
    narrower ones behind it.
    """

    def __init__(self, max_pending: int = 256):
        self.max_pending = int(max_pending)
        self._heap: List[Tuple[int, int, CampaignRequest, CampaignTicket]] = []
        self._seq = itertools.count()
        self._ids = itertools.count()

    def __len__(self) -> int:
        return len(self._heap)

    def submit(self, req: CampaignRequest, *,
               now_s: float = 0.0) -> CampaignTicket:
        """Validate and enqueue ``req``; returns its fresh ticket (job id
        assigned here).  Raises ``QueueFull`` at ``max_pending`` — the
        backpressure contract — and ``ValueError`` on an invalid request.
        ``now_s`` stamps ``ticket.submit_s`` (queue-wait measurements)."""
        req.validate()
        if len(self._heap) >= self.max_pending:
            raise QueueFull(
                f"admission queue at capacity ({self.max_pending} pending)")
        ticket = CampaignTicket(job_id=next(self._ids), request=req,
                                submit_s=now_s)
        heapq.heappush(self._heap,
                       (-req.priority, next(self._seq), req, ticket))
        return ticket

    def take(self, match: Optional[Callable[[CampaignRequest], bool]] = None,
             ) -> Optional[Tuple[CampaignRequest, CampaignTicket]]:
        """Remove and return the best-priority (request, ticket) for which
        ``match`` holds (None matches everything); None if nothing matches."""
        kept, out = [], None
        while self._heap:
            item = heapq.heappop(self._heap)
            if out is None and (match is None or match(item[2])):
                out = (item[2], item[3])
            else:
                kept.append(item)
        for item in kept:
            heapq.heappush(self._heap, item)
        return out

    def pending(self) -> List[CampaignTicket]:
        """Tickets still queued, in admission (priority, FIFO) order."""
        return [t for (_p, _s, _r, t) in sorted(self._heap)]
