"""Campaign request spec, streaming tickets, and the admission queue.

A ``CampaignRequest`` is one tenant's optimization job: a BBOB (fid,
instance) pair or a registered fitness callable, a problem dimension, an
evaluation budget, an optional absolute fitness target (early retirement),
and a priority.  Submitting one to the server yields a ``CampaignTicket``
immediately — the job's streaming handle: per-boundary progress updates
while it runs, and the full ``IPOPResult`` once it completes.

The ``AdmissionQueue`` is the service's front door: priority-ordered pending
requests with *backpressure* — beyond ``max_pending`` the queue sheds the
lowest-priority pending ticket to make room for a strictly higher-priority
submit (``status="shed"``, a terminal state the client can retry against),
and refuses the submit itself (``QueueFull``) when nothing pending ranks
below it — so a drowning service degrades by priority, not by dying.
Admission itself (taking a request out of the queue and packing it into a
running lane) only ever happens at segment boundaries (service/server.py).

Every ticket ends in exactly one terminal state::

    queued ──────────────▶ running ──▶ done
       │                     │  │
       ├─▶ expired (TTL)     │  ├─▶ expired (deadline)
       ├─▶ cancelled         │  ├─▶ cancelled
       ├─▶ shed              │  └─▶ quarantined (poison)
       └─▶ rejected          ▼
                           (island recovery re-places, state unchanged)
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Callable, List, Optional, Tuple

JOB_QUEUED = "queued"
JOB_RUNNING = "running"
JOB_DONE = "done"
JOB_REJECTED = "rejected"
JOB_CANCELLED = "cancelled"
JOB_EXPIRED = "expired"
JOB_QUARANTINED = "quarantined"
JOB_SHED = "shed"

#: Statuses a ticket can never leave; every submitted job reaches exactly one.
TERMINAL_STATUSES = frozenset({
    JOB_DONE, JOB_REJECTED, JOB_CANCELLED, JOB_EXPIRED, JOB_QUARANTINED,
    JOB_SHED,
})


class QueueFull(RuntimeError):
    """Admission backpressure: the pending queue is at capacity and nothing
    pending ranks strictly below the incoming request's priority."""


@dataclasses.dataclass
class CampaignRequest:
    """One optimization job.

    Exactly one of ``fid`` (BBOB, with ``instance``) or ``fitness`` (the name
    of a callable registered in the server's ``FitnessRegistry``) selects the
    objective.  ``budget`` is the evaluation budget (the ``max_evals`` a
    standalone ``run_ipop`` would get); ``target`` an optional absolute
    fitness value that retires the job early once reached (checked at segment
    boundaries).  ``key`` optionally overrides the PRNG key derived from
    ``seed`` — ``run_ipop(backend="service")`` uses it for bit-parity with
    the other backends.  ``lam_start``/``kmax_exp``/``dtype`` default to the
    server's configuration; together with ``dim`` they form the dim-class
    routing key (service/allocator.py) — requests in the same class share one
    compiled program family.

    Lifecycle knobs (all optional, all host-side — none is a row operand, so
    none costs a sync or a compile): ``queue_ttl_s`` expires the job if it is
    still queued that long after submit; ``deadline_s`` bounds the job's
    total submit→done age (queued *or* running — enforced at the next segment
    boundary); ``dedup_key`` makes resubmits idempotent — a submit whose key
    maps to a live or completed ticket returns that ticket instead of
    enqueueing a duplicate, while a key whose job ended ``shed``/``expired``/
    ``cancelled`` admits the retry fresh.
    """

    dim: int
    budget: int
    seed: int = 0
    fid: Optional[int] = None
    instance: int = 1
    fitness: Optional[str] = None
    target: Optional[float] = None
    priority: int = 0
    lam_start: Optional[int] = None
    kmax_exp: Optional[int] = None
    dtype: Optional[str] = None
    tag: str = ""
    queue_ttl_s: Optional[float] = None
    deadline_s: Optional[float] = None
    dedup_key: Optional[str] = None
    key: Any = None                     # explicit jax PRNG key (overrides seed)

    def validate(self):
        if (self.fid is None) == (self.fitness is None):
            raise ValueError("exactly one of fid / fitness must be set")
        if self.dim < 1:
            raise ValueError(f"dim must be >= 1, got {self.dim}")
        if self.budget < 0:
            raise ValueError(f"budget must be >= 0, got {self.budget}")
        for name in ("queue_ttl_s", "deadline_s"):
            v = getattr(self, name)
            if v is not None and v < 0:
                raise ValueError(f"{name} must be >= 0, got {v}")

    def to_meta(self) -> dict:
        """JSON-able form for snapshots (the explicit key is host-encoded)."""
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self) if f.name != "key"}
        if self.key is not None:
            import numpy as np
            d["_key"] = [int(x) for x in np.asarray(self.key).ravel()]
        return d

    @classmethod
    def from_meta(cls, d: dict) -> "CampaignRequest":
        d = dict(d)
        raw = d.pop("_key", None)
        # pre-lifecycle snapshots lack fields added later; dataclass defaults
        # cover them, and unknown future fields are dropped
        names = {f.name for f in dataclasses.fields(cls)}
        req = cls(**{k: v for k, v in d.items() if k in names})
        if raw is not None:
            import jax.numpy as jnp
            req.key = jnp.asarray(raw, jnp.uint32)
        return req


@dataclasses.dataclass
class CampaignTicket:
    """Streaming handle of one submitted job (updated in place by the server).

    ``updates`` is the trajectory tail: one record per segment boundary while
    the job is resident ({boundary, fevals, best_f, k}), capped at
    ``TAIL_CAP`` most-recent entries.  ``result`` (an ``ipop.IPOPResult``
    with the full per-descent trajectory) lands when status turns "done" —
    and, partially, when a running job is cancelled/expired/quarantined (the
    trajectory up to the retirement boundary, with ``reason`` saying why).
    """

    TAIL_CAP = 512

    job_id: int
    request: CampaignRequest
    status: str = JOB_QUEUED
    reason: str = ""
    best_f: float = float("inf")
    fevals: int = 0
    updates: List[dict] = dataclasses.field(default_factory=list)
    result: Any = None
    lane: Optional[tuple] = None
    island: Optional[int] = None
    row: Optional[int] = None
    # host wall-clock timestamps; None on tickets rebuilt from a snapshot
    # (timestamps are not persisted, so a resumed job has no latency)
    submit_s: Optional[float] = None
    admit_s: Optional[float] = None
    done_s: Optional[float] = None
    admit_boundary: Optional[int] = None
    # absolute (monotonic-clock) expiry instants, armed from queue_ttl_s /
    # deadline_s at submit and RE-armed with the full allowance on restore
    # (a restored server has no past wall clock to charge against)
    ttl_at: Optional[float] = None
    deadline_at: Optional[float] = None

    def arm(self, now_s: float):
        """(Re)compute the absolute expiry instants from the request's
        relative allowances, charging from ``now_s``."""
        if self.request.queue_ttl_s is not None:
            self.ttl_at = now_s + self.request.queue_ttl_s
        if self.request.deadline_s is not None:
            self.deadline_at = now_s + self.request.deadline_s

    def push(self, rec: dict):
        """Append one boundary update, dropping the oldest beyond
        ``TAIL_CAP`` (server-side; consumers just read ``updates``)."""
        self.updates.append(rec)
        if len(self.updates) > self.TAIL_CAP:
            del self.updates[:len(self.updates) - self.TAIL_CAP]

    @property
    def done(self) -> bool:
        """True once the full result landed (status ``"done"``)."""
        return self.status == JOB_DONE

    @property
    def terminal(self) -> bool:
        """True once the ticket reached any terminal lifecycle state."""
        return self.status in TERMINAL_STATUSES

    def latency_s(self) -> Optional[float]:
        """submit → done wall-clock latency (the quantity the soak SLO is
        written against); None while running or on a snapshot-restored
        ticket (timestamps are not persisted)."""
        if self.done_s is None or self.submit_s is None:
            return None
        return self.done_s - self.submit_s


def _heap_remove_at(heap: list, i: int):
    """Remove and return ``heap[i]`` in O(log n), preserving the invariant:
    replace with the last element and sift it in whichever direction the
    ordering demands (no full re-heapify)."""
    item = heap[i]
    last = heap.pop()
    if i < len(heap):
        heap[i] = last
        if last < item:
            heapq._siftdown(heap, 0, i)     # may need to rise toward the root
        else:
            heapq._siftup(heap, i)          # may need to sink into the subtree
    return item


class AdmissionQueue:
    """Priority-ordered pending requests with priority-aware backpressure.

    ``submit`` is O(log n); ``take`` scans for the highest-priority request
    (ties broken FIFO) matching a predicate — the server's admission pass
    calls it with "fits a lane with a free row" so a blocked wide job never
    starves narrower ones behind it — and removes just that entry without
    disturbing the rest of the heap.
    """

    def __init__(self, max_pending: int = 256):
        self.max_pending = int(max_pending)
        self._heap: List[Tuple[int, int, CampaignRequest, CampaignTicket]] = []
        self._seq = itertools.count()
        self._ids = itertools.count()
        #: tickets evicted by priority shedding since the last ``drain_shed``
        #: (the server drains these to emit metrics / settle dedup keys)
        self._shed: List[CampaignTicket] = []

    def __len__(self) -> int:
        return len(self._heap)

    def submit(self, req: CampaignRequest, *,
               now_s: float = 0.0) -> CampaignTicket:
        """Validate and enqueue ``req``; returns its fresh ticket (job id
        assigned here).  At ``max_pending`` the *lowest*-priority pending
        ticket is shed — terminal ``status="shed"`` — iff it ranks strictly
        below ``req``; otherwise ``QueueFull`` (the backpressure contract is
        unchanged for equal-or-higher-priority traffic).  ``ValueError`` on
        an invalid request.  ``now_s`` stamps ``ticket.submit_s`` and arms
        the TTL/deadline clocks."""
        req.validate()
        if len(self._heap) >= self.max_pending:
            victim_i = max(range(len(self._heap)),
                           key=lambda i: self._heap[i][:2])
            # heap entries sort (-priority, seq): the max is the lowest
            # priority, youngest.  Shed only on a STRICT priority win.
            if self._heap[victim_i][0] <= -req.priority:
                raise QueueFull(
                    f"admission queue at capacity "
                    f"({self.max_pending} pending)")
            victim = _heap_remove_at(self._heap, victim_i)[3]
            victim.status = JOB_SHED
            victim.reason = ("displaced by a priority-"
                             f"{req.priority} submit")
            self._shed.append(victim)
        ticket = CampaignTicket(job_id=next(self._ids), request=req,
                                submit_s=now_s)
        ticket.arm(now_s)
        heapq.heappush(self._heap,
                       (-req.priority, next(self._seq), req, ticket))
        return ticket

    def take(self, match: Optional[Callable[[CampaignRequest], bool]] = None,
             ) -> Optional[Tuple[CampaignRequest, CampaignTicket]]:
        """Remove and return the best-priority (request, ticket) for which
        ``match`` holds (None matches everything); None if nothing matches.
        One O(n) scan + one O(log n) removal — the heap order survives."""
        best = -1
        for i, item in enumerate(self._heap):
            if match is None or match(item[2]):
                if best < 0 or item[:2] < self._heap[best][:2]:
                    best = i
        if best < 0:
            return None
        item = _heap_remove_at(self._heap, best)
        return (item[2], item[3])

    def remove(self, job_id: int) -> Optional[CampaignTicket]:
        """Pull one still-queued ticket out by job id (cancellation path);
        None if the id is not pending.  Status is left to the caller."""
        for i, item in enumerate(self._heap):
            if item[3].job_id == job_id:
                return _heap_remove_at(self._heap, i)[3]
        return None

    def expire(self, now_s: float) -> List[CampaignTicket]:
        """Retire every pending ticket whose queue-TTL or total deadline has
        passed (terminal ``status="expired"``); returns the expired tickets.
        Host-side bookkeeping only — never touches a device."""
        hit = [item[3] for item in self._heap
               if (item[3].ttl_at is not None and now_s >= item[3].ttl_at)
               or (item[3].deadline_at is not None
                   and now_s >= item[3].deadline_at)]
        for t in hit:                   # re-scan per removal: each removal
            for i, item in enumerate(self._heap):   # re-sifts the heap, so
                if item[3] is t:                    # indices don't survive
                    _heap_remove_at(self._heap, i)
                    break
            t.status = JOB_EXPIRED
            t.reason = ("queue TTL exceeded"
                        if t.ttl_at is not None and now_s >= t.ttl_at
                        else "deadline exceeded while queued")
        return hit

    def drain_shed(self) -> List[CampaignTicket]:
        """Tickets shed since the last drain (server bookkeeping hook)."""
        out, self._shed = self._shed, []
        return out

    def pending(self) -> List[CampaignTicket]:
        """Tickets still queued, in admission (priority, FIFO) order."""
        return [t for (_p, _s, _r, t) in sorted(self._heap)]
