"""Sequential IPOP-CMA-ES (paper Alg. 2) — the baseline both parallel
strategies are compared against (paper Table 2).

Runs descents of population K·λ_start for K = 2⁰, 2¹, …, K_max in order,
restarting fresh (new random mean, reset σ) after each stopping criterion.
Each descent is a jitted scan in chunks with host-side early exit, so the
baseline does not waste compute after a stop fires (matching the reference
C code's control flow).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cmaes
from repro.core.params import CMAConfig, make_params


class DescentTrace(NamedTuple):
    k_exp: int                 # descent index i (K = 2^i)
    lam: int
    gens: np.ndarray           # (T,)
    fevals: np.ndarray         # (T,) cumulative evals within the descent
    best_f: np.ndarray         # (T,) best-so-far within the descent
    stop_reason: int


@dataclasses.dataclass
class IPOPResult:
    best_f: float
    best_x: np.ndarray
    total_fevals: int
    descents: List[DescentTrace]

    def hit_evals(self, targets: np.ndarray, f_opt: float) -> np.ndarray:
        """First cumulative evaluation count at which best-f − f_opt ≤ target.

        Returns +inf where the target was never hit (ERT bookkeeping,
        paper §4.3.1).
        """
        hits = np.full(len(targets), np.inf)
        base = 0
        best = np.inf
        for d in self.descents:
            for fe, bf in zip(d.fevals, d.best_f):
                best = min(best, bf)
                err = best - f_opt
                for i, t in enumerate(targets):
                    if np.isinf(hits[i]) and err <= t:
                        hits[i] = base + fe
            base += int(d.fevals[-1]) if len(d.fevals) else 0
        return hits


def run_ipop(fitness_fn: Callable, n: int, key: jax.Array,
             lam_start: int = 12, kmax_exp: int = 8,
             max_evals: int = 200_000, domain=(-5.0, 5.0),
             sigma0_frac: float = 0.25, chunk: int = 32,
             impl: str = "xla", dtype: str = "float64") -> IPOPResult:
    """Paper Alg. 2 with multiplicative factor 2 and K_max = 2^kmax_exp."""
    lo, hi = domain
    width = hi - lo
    total_evals = 0
    best_f, best_x = np.inf, np.zeros(n)
    descents: List[DescentTrace] = []

    for k_exp in range(kmax_exp + 1):
        if total_evals >= max_evals:
            break
        lam = (2 ** k_exp) * lam_start
        cfg = CMAConfig(n=n, lam=lam, sigma0=sigma0_frac * width, dtype=dtype)
        params = make_params(cfg)
        key, k_init, k_x0 = jax.random.split(key, 3)
        x0 = jax.random.uniform(k_x0, (n,), cfg.jdtype, lo, hi)
        state = cmaes.init_state(cfg, k_init, x0)

        @jax.jit
        def run_chunk(st, ks):
            def body(s, kk):
                s = cmaes.step(cfg, params, s, fitness_fn, kk, impl=impl)
                return s, (s.best_f, s.fevals, s.stop)
            return jax.lax.scan(body, st, ks)

        gens_l, fe_l, bf_l = [], [], []
        gen = 0
        budget_gens = max(1, (max_evals - total_evals) // lam)
        while gen < min(cfg.max_iter, budget_gens):
            key, k_chunk = jax.random.split(key)
            ks = jax.random.split(k_chunk, chunk)
            state, (bfs, fes, stops) = run_chunk(state, ks)
            bfs, fes, stops = map(np.asarray, (bfs, fes, stops))
            n_valid = int(np.argmax(stops)) + 1 if stops.any() else chunk
            gens_l.extend(range(gen + 1, gen + n_valid + 1))
            fe_l.extend(fes[:n_valid])
            bf_l.extend(bfs[:n_valid])
            gen += n_valid
            if stops.any():
                break

        total_evals += int(fe_l[-1]) if fe_l else 0
        if float(state.best_f) < best_f:
            best_f = float(state.best_f)
            best_x = np.asarray(state.best_x)
        descents.append(DescentTrace(
            k_exp=k_exp, lam=lam, gens=np.asarray(gens_l),
            fevals=np.asarray(fe_l, dtype=np.int64),
            best_f=np.asarray(bf_l, dtype=np.float64),
            stop_reason=int(state.stop_reason)))

    return IPOPResult(best_f=best_f, best_x=best_x,
                      total_fevals=total_evals, descents=descents)
