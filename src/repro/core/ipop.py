"""Sequential IPOP-CMA-ES (paper Alg. 2) — thin host wrapper over the
device-resident ladder engine (core/ladder.py).

``run_ipop`` runs descents of population K·λ_start for K = 2⁰, 2¹, …, K_max
in order, restarting fresh (new random mean, reset σ) after each stopping
criterion — but the whole ladder now executes as ONE scanned, jitted device
program with in-place restarts; this wrapper only slices the scanned trace
back into per-descent ``DescentTrace`` records.

``run_ipop_hostloop`` keeps the original control flow — per-descent jitted
chunks with host-side early exit — on the SAME key schedule and λ_max-padded
generation step, so it is trajectory-equivalent to the ladder (asserted in
tests/test_ladder.py) and serves as the baseline for
benchmarks/bench_ladder.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ladder as ladder_mod
from repro.core.params import select_params


class DescentTrace(NamedTuple):
    k_exp: int                 # descent index i (K = 2^i)
    lam: int
    gens: np.ndarray           # (T,)
    fevals: np.ndarray         # (T,) cumulative evals within the descent
    best_f: np.ndarray         # (T,) best-so-far within the descent
    stop_reason: int


@dataclasses.dataclass
class IPOPResult:
    best_f: float
    best_x: np.ndarray
    total_fevals: int
    descents: List[DescentTrace]

    def hit_evals(self, targets: np.ndarray, f_opt: float) -> np.ndarray:
        """First cumulative evaluation count at which best-f − f_opt ≤ target.

        Returns +inf where the target was never hit (ERT bookkeeping,
        paper §4.3.1).
        """
        hits = np.full(len(targets), np.inf)
        base = 0
        best = np.inf
        for d in self.descents:
            for fe, bf in zip(d.fevals, d.best_f):
                best = min(best, bf)
                err = best - f_opt
                for i, t in enumerate(targets):
                    if np.isinf(hits[i]) and err <= t:
                        hits[i] = base + fe
            base += int(d.fevals[-1]) if len(d.fevals) else 0
        return hits


def _result_from_ladder(engine: ladder_mod.LadderEngine,
                        carry: ladder_mod.LadderCarry,
                        trace: ladder_mod.LadderTrace) -> IPOPResult:
    """Slice a sequential-ladder trace (leaves (T, 1)) into DescentTraces."""
    ran = np.asarray(trace.ran)[:, 0]
    k = np.asarray(trace.k_idx)[:, 0]
    gens = np.asarray(trace.gen)[:, 0]
    fevals = np.asarray(trace.fevals)[:, 0]
    best_f = np.asarray(trace.best_f)[:, 0]
    reason = np.asarray(trace.stop_reason)[:, 0]
    descents: List[DescentTrace] = []
    for k_exp in range(engine.kmax_exp + 1):
        idx = np.nonzero(ran & (k == k_exp))[0]
        if idx.size == 0:
            continue
        descents.append(DescentTrace(
            k_exp=k_exp, lam=(2 ** k_exp) * engine.lam_start,
            gens=np.asarray(gens[idx], np.int64),
            fevals=np.asarray(fevals[idx], np.int64),
            best_f=np.asarray(best_f[idx], np.float64),
            stop_reason=int(reason[idx[-1]])))
    return IPOPResult(best_f=float(carry.best_f),
                      best_x=np.asarray(carry.best_x),
                      total_fevals=int(carry.total_fevals),
                      descents=descents)


def _fleet_supervisor(fleet):
    """A fresh engine-level supervisor from a FleetConfig (None passes
    through — the zero-overhead default)."""
    if fleet is None:
        return None
    from repro.fleet.controller import IslandSupervisor
    return IslandSupervisor(fleet)


# ---------------------------------------------------------------------------
# IPOPResult persistence (campaign-service snapshots carry full results)
# ---------------------------------------------------------------------------

def result_to_tree(res: IPOPResult):
    """Split a result into ``(array_tree, json_meta)`` for the checkpoint
    store: arrays (including the possibly-infinite ``best_f``, which JSON
    meta must not hold) as leaves, static scalars in meta."""
    tree = {"best_x": np.asarray(res.best_x),
            "best_f": np.asarray(res.best_f, np.float64),
            "total_fevals": np.asarray(res.total_fevals, np.int64),
            "descents": {}}
    meta = {"x_shape": [int(s) for s in np.shape(res.best_x)],
            "x_dtype": str(np.asarray(res.best_x).dtype), "descents": []}
    for di, d in enumerate(res.descents):
        tree["descents"][str(di)] = {
            "gens": np.asarray(d.gens, np.int64),
            "fevals": np.asarray(d.fevals, np.int64),
            "best_f": np.asarray(d.best_f, np.float64)}
        meta["descents"].append({"k_exp": int(d.k_exp), "lam": int(d.lam),
                                 "stop_reason": int(d.stop_reason),
                                 "T": int(len(d.gens))})
    return tree, meta


def result_template(meta: dict) -> dict:
    """Shape/dtype template matching ``result_to_tree``'s array tree."""
    sds = jax.ShapeDtypeStruct
    tree = {"best_x": sds(tuple(meta["x_shape"]),
                          np.dtype(meta["x_dtype"])),
            "best_f": sds((), np.float64),
            "total_fevals": sds((), np.int64),
            "descents": {}}
    for di, dm in enumerate(meta["descents"]):
        T = int(dm["T"])
        tree["descents"][str(di)] = {"gens": sds((T,), np.int64),
                                     "fevals": sds((T,), np.int64),
                                     "best_f": sds((T,), np.float64)}
    return tree


def result_from_tree(tree: dict, meta: dict) -> IPOPResult:
    descents = []
    for di, dm in enumerate(meta["descents"]):
        dt = tree["descents"][str(di)]
        descents.append(DescentTrace(
            k_exp=int(dm["k_exp"]), lam=int(dm["lam"]),
            gens=np.asarray(dt["gens"], np.int64),
            fevals=np.asarray(dt["fevals"], np.int64),
            best_f=np.asarray(dt["best_f"], np.float64),
            stop_reason=int(dm["stop_reason"])))
    return IPOPResult(best_f=float(tree["best_f"]),
                      best_x=np.asarray(tree["best_x"]),
                      total_fevals=int(tree["total_fevals"]),
                      descents=descents)


def run_ipop(fitness_fn: Callable, n: int, key: jax.Array,
             lam_start: int = 12, kmax_exp: int = 8,
             max_evals: int = 200_000, domain=(-5.0, 5.0),
             sigma0_frac: float = 0.25, chunk: int = 32,
             impl: str = "auto", dtype: str = "float64",
             total_gens: int | None = None,
             backend: str = "ladder",
             mesh_strategy: str = "ordered",
             fleet=None) -> IPOPResult:
    """Paper Alg. 2 with multiplicative factor 2 and K_max = 2^kmax_exp.

    ``backend="ladder"`` (default) runs the whole restart ladder as one
    device-resident scanned program; ``backend="bucketed"`` drives it through
    the rung-bucketed segment programs (core/bucketed.py — work proportional
    to the live rung instead of λ_max); ``backend="mesh"`` runs those segment
    programs through the mesh campaign engine
    (distributed/mesh_engine.py) over all local devices with the paper's S1
    (``mesh_strategy="ordered"``) or S2 (``"concurrent"``) deployment;
    ``backend="service"`` submits the problem as a single-tenant job to the
    campaign service (service/server.py) and drains it — the one-shot parity
    path of the streaming product; ``backend="hostloop"`` keeps the legacy
    host-driven chunked loop (same keys, same padded arithmetic).  ``chunk``
    only affects the host-loop backend; ``mesh_strategy`` only the mesh
    backend.

    ``impl`` selects the kernel dispatch uniformly for EVERY backend —
    ``"auto"`` (Pallas megakernels on TPU, fused jnp ref elsewhere),
    ``"xla"``, ``"xla_unfused"`` (the pre-PR-4 op soup, kept as the
    regression baseline) or ``"pallas"`` — and is validated here, at entry,
    instead of failing deep inside a traced engine program
    (kernels/ops.py documents the semantics).

    ``fleet`` (a ``repro.fleet.FleetConfig``) adds fault-tolerant fleet
    supervision — periodic island snapshots, health monitoring, optional
    injected faults, snapshot-replay recovery — to the segment-driven
    backends (``bucketed``/``mesh``/``service``); the recovered result is
    identical to the unsupervised run (tests/test_fleet.py).
    """
    from repro.kernels import ops as kops
    kops.validate_impl(impl)
    if fleet is not None and backend not in ("bucketed", "mesh", "service"):
        raise ValueError("fleet supervision applies to backend='bucketed', "
                         f"'mesh' or 'service', not {backend!r}")
    if backend == "hostloop":
        if total_gens is not None:
            raise ValueError("total_gens only applies to backend='ladder'; "
                             "the host loop is bounded by max_evals/stops")
        return run_ipop_hostloop(
            fitness_fn, n, key, lam_start=lam_start, kmax_exp=kmax_exp,
            max_evals=max_evals, domain=domain, sigma0_frac=sigma0_frac,
            chunk=chunk, impl=impl, dtype=dtype)
    if backend == "bucketed":
        from repro.core import bucketed as bucketed_mod
        if total_gens is not None:
            raise ValueError("total_gens only applies to backend='ladder'; "
                             "the segment driver sizes its own programs")
        engine_b = bucketed_mod.BucketedLadderEngine(
            n=n, lam_start=lam_start, kmax_exp=kmax_exp, max_evals=max_evals,
            domain=domain, sigma0_frac=sigma0_frac, impl=impl, dtype=dtype)
        carry, trace = bucketed_mod.run_bucketed_single(
            engine_b, key, fitness_fn, supervisor=_fleet_supervisor(fleet))
        return _result_from_ladder(engine_b.full, carry, trace)
    if backend == "service":
        from repro.service import run_service_single
        if total_gens is not None:
            raise ValueError("total_gens only applies to backend='ladder'; "
                             "the service sizes its own segment programs")
        return run_service_single(
            fitness_fn, n, key, lam_start=lam_start, kmax_exp=kmax_exp,
            max_evals=max_evals, domain=domain, sigma0_frac=sigma0_frac,
            impl=impl, dtype=dtype, fleet=fleet)
    if backend == "mesh":
        from repro.distributed import mesh_engine as mesh_mod
        if total_gens is not None:
            raise ValueError("total_gens only applies to backend='ladder'; "
                             "the segment driver sizes its own programs")
        engine_m = mesh_mod.MeshCampaignEngine(
            n=n, lam_start=lam_start, kmax_exp=kmax_exp, max_evals=max_evals,
            domain=domain, sigma0_frac=sigma0_frac, impl=impl, dtype=dtype,
            strategy=mesh_strategy)
        carry, trace = mesh_mod.run_mesh_single(
            engine_m, key, fitness_fn, supervisor=_fleet_supervisor(fleet))
        return _result_from_ladder(engine_m.bucketed.full, carry, trace)
    if backend != "ladder":
        raise ValueError(f"unknown backend {backend!r}")
    engine = ladder_mod.LadderEngine(
        n=n, lam_start=lam_start, kmax_exp=kmax_exp, schedule="sequential",
        max_evals=max_evals, domain=domain, sigma0_frac=sigma0_frac,
        impl=impl, dtype=dtype)
    carry, trace = engine.run(key, fitness_fn, total_gens)
    return _result_from_ladder(engine, carry, trace)


def run_ipop_hostloop(fitness_fn: Callable, n: int, key: jax.Array,
                      lam_start: int = 12, kmax_exp: int = 8,
                      max_evals: int = 200_000, domain=(-5.0, 5.0),
                      sigma0_frac: float = 0.25, chunk: int = 32,
                      impl: str = "auto",
                      dtype: str = "float64") -> IPOPResult:
    """Host-driven baseline: one jitted chunk-scan per descent, host-side
    early exit on the stop flag, Python-level restart between rungs."""
    engine = ladder_mod.LadderEngine(
        n=n, lam_start=lam_start, kmax_exp=kmax_exp, schedule="sequential",
        max_evals=max_evals, domain=domain, sigma0_frac=sigma0_frac,
        impl=impl, dtype=dtype)
    cfg, sparams = engine.cfg, engine.sparams

    @jax.jit
    def run_chunk(params, st, ks):
        def body(s, kg):
            s = ladder_mod.padded_gen_step(cfg, params, s, kg, fitness_fn,
                                           impl=impl)
            return s, (s.best_f, s.fevals, s.stop)
        return jax.lax.scan(body, st, ks)

    total_evals = 0
    best_f, best_x = np.inf, np.zeros(n)
    descents: List[DescentTrace] = []

    for k_exp in range(kmax_exp + 1):
        lam = (2 ** k_exp) * lam_start
        if total_evals + lam > max_evals:
            break
        params = select_params(sparams, k_exp)
        kd = ladder_mod.slot_key(key, 0, k_exp)
        state = ladder_mod.fresh_state(cfg, kd, domain)

        budget_gens = (max_evals - total_evals) // lam
        gens_l, fe_l, bf_l = [], [], []
        gen = 0
        while gen < budget_gens:
            m = min(chunk, budget_gens - gen)
            ks = jax.vmap(lambda g: ladder_mod.gen_key(kd, g))(
                jnp.arange(gen, gen + m))
            state, (bfs, fes, stops) = run_chunk(params, state, ks)
            bfs, fes, stops = map(np.asarray, (bfs, fes, stops))
            n_valid = int(np.argmax(stops)) + 1 if stops.any() else m
            gens_l.extend(range(gen + 1, gen + n_valid + 1))
            fe_l.extend(fes[:n_valid])
            bf_l.extend(bfs[:n_valid])
            gen += n_valid
            if stops.any():
                break

        total_evals += int(fe_l[-1]) if fe_l else 0
        if float(state.best_f) < best_f:
            best_f = float(state.best_f)
            best_x = np.asarray(state.best_x)
        descents.append(DescentTrace(
            k_exp=k_exp, lam=lam, gens=np.asarray(gens_l, np.int64),
            fevals=np.asarray(fe_l, dtype=np.int64),
            best_f=np.asarray(bf_l, dtype=np.float64),
            stop_reason=int(state.stop_reason)))

    return IPOPResult(best_f=best_f, best_x=best_x,
                      total_fevals=total_evals, descents=descents)
