"""Rung-bucketed campaign execution — work proportional to the active rung.

The λ_max-padded ladder engine (core/ladder.py) compiles ONE program whose
every generation samples, evaluates and Gram-reduces λ_max points even when
the live rung needs only λ_start — a 2^kmax× (16× at paper defaults)
overcount of sampling, evaluation and rank-μ GEMM work on the first rung,
and a λ_max-padded masked tail once a member's ladder is exhausted.  This
module replaces the single program with a small FAMILY of per-rung-bucket
programs plus a host-side segment driver:

* **Bucket programs** — bucket k pads to λ_k = 2^k·λ_start
  (``params.bucket_config``) and carries the rung-0..k parameter stack
  padded to that width.  Each program runs a fixed-length *segment*
  (``seg_blocks`` eigen blocks of the nested scan — see
  ``ladder.scan_eigen_blocks``) over the FULL campaign batch, jitted and
  vmapped exactly like ``LadderEngine.campaign_runner``; shapes are cached,
  so the whole campaign compiles at most once per bucket
  (``compiles ≤ kmax_exp+1``, asserted in tests/test_bucketed.py).
* **Parking** — inside a bucket-k program, members whose rung index exceeds
  k (their in-place restart outgrew the bucket) or whose ladder
  retired/budget died are parked: ``ran=False``, state frozen
  (``slots_gen_step(bucket_cap=k)``).
* **Segment driver** (``run_campaign_bucketed``) — between device-resident
  segments it pulls only the (B,) rung indices / active flags, re-buckets
  members as their rungs advance (members only move up, so it always runs
  the lowest occupied bucket next), and stops as soon as every member has
  retired or exhausted its budget — no λ_max-padded masked tail.

Trajectory equivalence with the padded engine holds when the eigen cadence
is unchanged (``eigen_interval == 1``): sampling is row-keyed
(``cmaes.sample_population``), so a member sees the identical z-stream, rank
weights and Gram reductions no matter which bucket executes it — while each
bucket pays RNG proportional to its own width, not λ_max's.  The compiled
programs differ in shape, so XLA's fusion choices leave ~1e-13 seed noise
that chaos can amplify late in a descent — the same tolerance the host-loop
baseline comparison carries (tests/test_ladder.py).  With
``eigen_interval > 1`` the nested-scan eigen cadence is segment-local rather
than campaign-global and the engines are ECDF-equivalent instead
(tests/test_bucketed.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import ladder
from repro.core.params import bucket_config, default_max_iter, ladder_params
from repro.fitness import bbob


@dataclasses.dataclass
class BucketedLadderEngine:
    """Per-rung-bucket compiled programs over a shared ladder state.

    Mirrors ``LadderEngine``'s sequential schedule (one slot walking the
    rungs) and its key schedule exactly; ``schedule="concurrent"`` keeps all
    rungs live at once and therefore has no narrow bucket to exploit.
    """

    n: int
    lam_start: int = 12
    kmax_exp: int = 4
    max_evals: int = 200_000
    domain: Tuple[float, float] = (-5.0, 5.0)
    sigma0_frac: float = 0.25
    impl: str = "auto"                  # kernel dispatch — see kernels/ops.py
    dtype: str = "float64"
    eigen_interval: Optional[int] = None
    seg_blocks: Optional[int] = None    # segment length cap in eigen blocks
    policy: str = "cover"               # "cover" | "min" (see run_campaign_bucketed)
    overlap: bool = False               # double-buffered segment dispatch

    def __post_init__(self):
        if self.policy not in ("cover", "min"):
            raise ValueError(f"unknown policy {self.policy!r}")
        if self.seg_blocks is None and self.policy == "cover":
            # cover tracks the max live rung, so segments must stay short
            # enough for the covering bucket to follow climbers between
            # host syncs (a sync is ~ms; a 64-block segment is ~100ms)
            self.seg_blocks = 64
        # the λ_max-padded engine supplies cfg/sparams/key schedule/init —
        # buckets only narrow the padding.
        self.full = ladder.LadderEngine(
            n=self.n, lam_start=self.lam_start, kmax_exp=self.kmax_exp,
            schedule="sequential", max_evals=self.max_evals,
            domain=self.domain, sigma0_frac=self.sigma0_frac, impl=self.impl,
            dtype=self.dtype, eigen_interval=self.eigen_interval)
        self.lam_max = self.full.lam_max
        self.interval = int(self.full.cfg.eigen_interval)
        self.bucket_cfgs = []
        self.bucket_sparams = []
        for k in range(self.kmax_exp + 1):
            lam_k = (2 ** k) * self.lam_start
            cfg_k = bucket_config(self.full.cfg, lam_k)
            self.bucket_cfgs.append(cfg_k)
            self.bucket_sparams.append(
                ladder_params(cfg_k, self.lam_start, k))
        self._runner_cache: dict = {}
        self._init_runner = jax.jit(jax.vmap(self.full.init_carry))

    # -- sizing ---------------------------------------------------------------
    def bucket_seg_gens(self, k: int, need_gens: Optional[int] = None) -> int:
        """Segment length (generations) of bucket k: whole eigen blocks,
        capped by what a rung-k descent can possibly still run — its MaxIter
        allowance, the budget's generation count at λ_k, and (when the
        driver knows it) the cohort's actual remaining-budget need.  The
        block count is rounded UP to a power of two so segment shapes come
        from a tiny menu and the jit cache stays hot across campaigns."""
        lam_k = (2 ** k) * self.lam_start
        most = max(1, self.max_evals // lam_k)
        if self.policy == "min":
            # a bucket-k cohort is all ON rung k, so its descents cannot
            # outlive rung k's own MaxIter allowance; under "cover" lower-rung
            # members keep walking the ladder inside the same segment
            most = min(most, default_max_iter(self.n, lam_k))
        if need_gens is not None:
            most = min(most, max(1, int(need_gens)))
        blocks = -(-most // self.interval)
        blocks = 1 << (blocks - 1).bit_length()          # next power of two
        if self.seg_blocks is not None:
            blocks = min(blocks, max(1, int(self.seg_blocks)))
        return blocks * self.interval

    def init_carry(self, base_key: jax.Array) -> ladder.LadderCarry:
        return self.full.init_carry(base_key)

    # -- one bucket segment as a pure scanned program --------------------------
    def segment_scan(self, k: int, base_key: jax.Array, fitness_fn: Callable,
                     carry: ladder.LadderCarry, seg_gens: int,
                     max_evals=None,
                     ) -> Tuple[ladder.LadderCarry, ladder.LadderTrace]:
        """``max_evals`` overrides the engine budget for this member — it may
        be a *traced* scalar, which is how the campaign service runs
        heterogeneous per-job budgets through one compiled bucket program
        (service/server.py vmaps it as a per-row operand)."""
        cfg_k = self.bucket_cfgs[k]
        sparams_k = self.bucket_sparams[k]
        budget = self.max_evals if max_evals is None else max_evals

        def step_fn(c, eigen):
            return ladder.slots_gen_step(
                cfg_k, sparams_k, c, base_key, fitness_fn,
                max_evals=budget, kmax_exp=self.kmax_exp,
                schedule="sequential", domain=self.domain, impl=self.impl,
                eigen=eigen, bucket_cap=k)

        return ladder.scan_eigen_blocks(step_fn, carry, self.interval,
                                        int(seg_gens) // self.interval)

    def segment_runner(self, k: int, branch_fids: Tuple[int, ...],
                       seg_gens: int):
        """Jitted vmapped segment program, cached per (bucket, length, fids)
        — plus the trace-time eval-fusion toggle (``REPRO_EVAL_FUSION``)."""
        key = (int(k), int(seg_gens), tuple(branch_fids),
               bbob.eval_fusion_enabled())
        if key not in self._runner_cache:
            def run_one(base_key, inst, carry):
                def fit(X):
                    return bbob.evaluate_dynamic(inst, X, branch_fids)
                return self.segment_scan(
                    k, base_key, bbob.fusable_fitness(inst, branch_fids, fit),
                    carry, seg_gens)
            self._runner_cache[key] = jax.jit(jax.vmap(run_one))
        return self._runner_cache[key]

    def compiles(self) -> int:
        total = 0
        for fn in self._runner_cache.values():
            cs = getattr(fn, "_cache_size", None)
            total += int(cs()) if callable(cs) else 1
        return total


@dataclasses.dataclass
class BucketedCampaignResult(ladder.CampaignResult):
    """Campaign result plus the driver's per-bucket execution record.

    ``trace`` concatenates the per-segment traces along time: each member's
    generations appear in its own chronological order (the driver runs one
    bucket at a time and members only move upward), with parked steps as
    ``ran=False`` rows — every ``CampaignResult`` consumer (``hit_evals``,
    the ipop slicer) already masks on ``ran``.
    """

    segments: List[dict] = dataclasses.field(default_factory=list)
    bucket_wall_s: Dict[int, float] = dataclasses.field(default_factory=dict)
    useful_evals: int = 0
    padded_evals: int = 0

    def padding_waste(self) -> float:
        """Padded-to-useful evaluation ratio actually paid on device."""
        return self.padded_evals / max(self.useful_evals, 1)


def _useful_evals_per_rung(trace: ladder.LadderTrace, lam_start: int,
                           kmax_exp: int) -> Dict[int, int]:
    """Σ over executed generations of that generation's true λ, keyed by rung."""
    ran = np.asarray(trace.ran)
    k_idx = np.asarray(trace.k_idx)
    out = {}
    for k in range(kmax_exp + 1):
        gens_k = int(np.sum(ran & (k_idx == k)))
        out[k] = gens_k * (2 ** k) * lam_start
    return out


def padding_report(trace: ladder.LadderTrace, lam_start: int, kmax_exp: int,
                   padded_lam: int) -> dict:
    """Padded-vs-useful evaluation accounting of a fixed-width campaign trace.

    Every (member, step, slot) cell of a ``padded_lam``-wide program pays
    ``padded_lam`` evaluation rows on device (masked tail steps included);
    the useful count is each executed generation's true rung λ.  Returns
    per-rung useful counts plus the overall waste ratio — the number the
    rung-bucketed driver exists to shrink (benchmarks/bench_ladder.py).
    """
    useful = _useful_evals_per_rung(trace, lam_start, kmax_exp)
    padded = int(np.asarray(trace.ran).size) * int(padded_lam)
    total_useful = int(sum(useful.values()))
    return {
        "useful_evals": total_useful,
        "padded_evals": padded,
        "waste": round(padded / max(total_useful, 1), 3),
        "useful_per_rung": {str(k): v for k, v in useful.items()},
    }


def pull_schedule(carry: ladder.LadderCarry):
    """The driver's per-segment host sync: ONE batched transfer of the four
    scheduling arrays — (B,) rung indices, active flags, budget counters and
    member bests — instead of four separate blocking ``np.asarray`` pulls
    (each of which paid its own device round-trip).  Returns 1-d np arrays.

    The mesh engine substitutes a ``process_allgather``-based puller with the
    same signature (distributed/mesh_engine.py), so the re-bucketing loop is
    identical on one device and on a sharded campaign mesh.
    """
    k_idx, active, fevals, best_f = jax.device_get(
        (carry.k_idx[..., 0], carry.active[..., 0],
         carry.total_fevals, carry.best_f))
    return (np.atleast_1d(k_idx), np.atleast_1d(active),
            np.atleast_1d(fevals), np.atleast_1d(best_f))


def next_bucket(engine: BucketedLadderEngine, k_idx: np.ndarray,
                active: np.ndarray, fevals: np.ndarray,
                seg_len: Dict[int, int], budgets=None):
    """One re-bucketing decision — THE scheduling invariant shared by
    ``drive_segments``, the mesh engine's per-island loops
    (distributed/mesh_engine.py) and the campaign service's lane boundaries
    (service/server.py), so the three can never silently diverge.

    Returns ``(live, k)`` with ``k is None`` when no member can pay for
    another generation.  Policy ``"min"`` picks the narrowest occupied rung
    (members only move up the ladder, so the lowest occupied bucket is
    work-conserving — least padded rows); ``"cover"`` picks the widest LIVE
    rung (every live member executes every step, fewest total scan steps —
    best on host-dispatch-bound backends).  On a bucket's first open its
    segment length is sized for what the cohort can still possibly run and
    recorded in ``seg_len`` (in place) — ONE length per bucket keeps
    ``compiles ≤ #buckets``.

    ``budgets`` (optional (B,) array) replaces the engine-wide ``max_evals``
    with per-member budgets — the host mirror of the traced budget operand
    the service threads through ``segment_scan``; the liveness rule here must
    match the device-side gate in ``ladder.slots_gen_step`` exactly.
    """
    cap = engine.max_evals if budgets is None else np.asarray(budgets)
    lam_cur = engine.lam_start * (2 ** k_idx)
    live = active & (fevals + lam_cur <= cap)
    if not live.any():
        return live, None
    if engine.policy == "min":
        k = int(k_idx[live].min())
    else:
        k = int(k_idx[live].max())
    if k not in seg_len:
        cohort = live if engine.policy == "cover" else live & (k_idx == k)
        need = int(np.max((cap - fevals)[cohort] // lam_cur[cohort]))
        seg_len[k] = engine.bucket_seg_gens(k, need_gens=need)
    return live, k


def drive_segments(engine: BucketedLadderEngine, carry: ladder.LadderCarry,
                   dispatch: Callable, max_segments: int = 10_000,
                   time_axis: int = 1, pull: Optional[Callable] = None,
                   budgets=None, overlap: Optional[bool] = None,
                   supervisor=None):
    """The host-side re-bucketing loop shared by campaign and single runs.

    ``dispatch(k, seg_gens, carry) -> (carry, trace)`` runs one jitted
    segment of bucket ``k``.  Between segments only the (B,) rung indices,
    active flags, budget counters and member bests cross the device boundary
    — one batched ``pull`` (default ``pull_schedule``; the mesh engine passes
    a ``process_allgather`` variant); per-segment traces stay device-resident
    until the driver finishes.  Returns ``(carry, trace, segments,
    bucket_wall)``; segment traces are concatenated along ``time_axis`` (1
    for vmapped campaigns whose leaves are (B, T, ...), 0 for a single run's
    (T, ...)).

    ``overlap`` (default ``engine.overlap``) double-buffers the carries: the
    next segment is dispatched SPECULATIVELY with the previous bucket before
    the blocking re-bucketing ``pull``, so jax's async dispatch chains it
    behind the running segment and the host sync drops off the device's
    critical path.  Members only move up the ladder and most boundaries keep
    the bucket, so the speculation usually lands (``spec_hit`` per segment
    record); when the bucket changes the speculative output is discarded —
    it never touches the accepted carry, so trajectories are bit-identical
    to the unoverlapped driver (the in-device budget/active gates make a
    mispredicted segment run its members exactly as the right bucket would,
    or park them).  ``dispatch`` must not block on its own outputs for
    overlap to help (the mesh S1 driver forces its psum scalars, so it pins
    ``overlap=False``).

    Observability: the loop emits the ``bucketed_*`` series of
    ``repro.obs.schema`` — segment wall, boundary sync, speculative-dispatch
    hit/miss, useful vs padded evaluations and eigh-block counts — from
    values that are ALREADY host-side here (the pull's np arrays and the
    perf_counter deltas), so instrumentation adds no device syncs and no
    recompiles (guarded in tests/test_obs.py).

    ``supervisor`` (a ``repro.fleet`` ``IslandSupervisor``) adds fleet
    supervision at three host-side points: a per-boundary snapshot/recovery
    hook (restoring the carry and truncating the trace list on a death
    verdict — replay regenerates the lost segments identically, since the
    carry is the complete state and sampling is row-keyed prefix-stable),
    a supervised pull (corruption retries + health grading), and a
    pre-dispatch delay hook.  When ``supervisor is None`` (the default)
    each hook site is one host ``if`` — no extra device syncs, no extra
    programs (pinned in tests/test_obs.py and tests/test_fleet.py).
    """
    pull = pull_schedule if pull is None else pull
    overlap = bool(engine.overlap) if overlap is None else bool(overlap)
    reg = obs.metrics()
    seg_traces: List[ladder.LadderTrace] = []
    segments: List[dict] = []
    bucket_wall: Dict[int, float] = {}
    seg_len: Dict[int, int] = {}        # one segment length per bucket/campaign
    k_prev: Optional[int] = None
    fev_prev: Optional[float] = None    # pulled-budget sum at the last boundary

    for b in range(max_segments):
        if supervisor is not None:
            carry, keep, recovered = supervisor.segment_boundary(
                b, carry, len(seg_traces))
            if recovered:
                # replay from the restored snapshot: drop post-snapshot
                # traces and forget the stale speculation/progress anchors
                del seg_traces[keep:]
                del segments[keep:]
                k_prev = None
                fev_prev = None
        spec = None
        if overlap and k_prev is not None:
            # double-buffered carry: enqueue the likely next segment before
            # the host blocks on the schedule pull
            if supervisor is not None:
                supervisor.before_dispatch(0, b)
            spec = dispatch(k_prev, seg_len[k_prev], carry)
        pull_span = obs.tracer().start("pull", island="all", boundary=b)
        t0 = time.perf_counter()
        if supervisor is not None:
            k_idx, active, fevals, best_f = supervisor.pull(
                0, b, lambda: pull(carry))
        else:
            k_idx, active, fevals, best_f = pull(carry)
        sync_s = time.perf_counter() - t0
        obs.tracer().end(pull_span)
        reg.histogram("bucketed_sync_s").observe(sync_s)
        fev_sum = float(np.sum(fevals))
        if fev_prev is not None:
            reg.counter("bucketed_useful_evals_total").inc(
                max(0.0, fev_sum - fev_prev))
        fev_prev = fev_sum
        if segments:
            # the pull reflects the PREVIOUS segment's result — attach its
            # post-segment best there (finite by then; None keeps the record
            # strict-JSON-safe on the pathological all-inf fitness)
            gb = float(best_f.min())
            segments[-1]["global_best"] = gb if np.isfinite(gb) else None
        _live, k = next_bucket(engine, k_idx, active, fevals, seg_len,
                               budgets=budgets)
        if k is None:
            break
        seg_span = obs.tracer().start("segment", island="all",
                                      bucket=int(k), boundary=b)
        t0 = time.perf_counter()
        hit = spec is not None and k == k_prev
        if hit:
            carry, tr = spec
        else:
            if supervisor is not None:
                supervisor.before_dispatch(0, b)
            carry, tr = dispatch(k, seg_len[k], carry)
        if not overlap:
            jax.block_until_ready(carry.total_fevals)
        wall = time.perf_counter() - t0
        obs.tracer().end(
            seg_span, spec=("hit" if hit
                            else "miss" if spec is not None else "sync"))
        seg_traces.append(tr)           # device-resident; transfer at the end
        seg = {"bucket": k, "gens": seg_len[k], "wall_s": round(wall, 5)}
        if overlap:
            # wall_s is dispatch-only here (no block); the host-blocked time
            # rides the pull instead
            seg["sync_s"] = round(sync_s, 5)
            seg["spec_hit"] = hit
        if spec is not None:
            reg.counter("bucketed_spec_dispatch_total",
                        outcome="hit" if hit else "miss").inc()
        reg.counter("bucketed_segments_total", bucket=k).inc()
        reg.histogram("bucketed_segment_wall_s", bucket=k).observe(wall)
        reg.counter("bucketed_padded_evals_total", bucket=k).inc(
            int(np.size(k_idx)) * seg_len[k] * (2 ** k) * engine.lam_start)
        reg.counter("bucketed_eigh_blocks_total", bucket=k).inc(
            seg_len[k] // engine.interval)
        segments.append(seg)
        bucket_wall[k] = bucket_wall.get(k, 0.0) + wall + \
            (sync_s if overlap else 0.0)
        k_prev = k
    else:
        raise RuntimeError("segment driver did not converge "
                           f"within {max_segments} segments")

    if not seg_traces:
        # nothing could run (e.g. max_evals below one λ_start generation):
        # return a zero-length trace shaped like the padded engine's, so
        # every consumer sees the same empty-progress result
        return carry, _empty_trace(carry, time_axis), segments, bucket_wall
    trace = jax.tree_util.tree_map(
        lambda *xs: np.concatenate([np.asarray(x) for x in xs],
                                   axis=time_axis),
        *seg_traces)
    return carry, trace, segments, bucket_wall


def _empty_trace(carry: ladder.LadderCarry, time_axis: int) -> ladder.LadderTrace:
    """Zero-generation LadderTrace with the batch/slot layout of ``carry``."""
    k = np.asarray(carry.k_idx)                       # (B, S) or (S,)
    slot = k.shape[:time_axis] + (0,) + k.shape[time_axis:]
    glob = k.shape[:time_axis] + (0,)
    dt = np.asarray(carry.best_f).dtype
    return ladder.LadderTrace(
        ran=np.zeros(slot, bool),
        k_idx=np.zeros(slot, np.int32),
        gen=np.zeros(slot, np.int32),
        fevals=np.zeros(slot, np.asarray(carry.states.fevals).dtype),
        best_f=np.zeros(slot, dt),
        stop_reason=np.zeros(slot, np.int32),
        stopped=np.zeros(slot, bool),
        total_fevals=np.zeros(glob, np.asarray(carry.total_fevals).dtype),
        global_best=np.zeros(glob, dt))


def run_bucketed_single(engine: BucketedLadderEngine, base_key: jax.Array,
                        fitness_fn: Callable,
                        max_segments: int = 10_000, supervisor=None):
    """One (un-vmapped) problem through the segment driver — the bucketed
    backend behind ``ipop.run_ipop``.  Returns ``(carry, trace)`` shaped like
    ``LadderEngine.run``'s output (trace leaves (T, S)).

    Runners are cached per call, not on the engine: the fitness closure is
    baked in at trace time, so an engine-level cache would silently replay a
    previous call's fitness.
    """
    carry = jax.jit(engine.init_carry)(base_key)
    cache: Dict[Tuple[int, int], Callable] = {}

    def dispatch(k, seg_gens, c):
        ck = (k, seg_gens)
        if ck not in cache:
            def run_seg(bk, cc, _k=k, _g=seg_gens):
                return engine.segment_scan(_k, bk, fitness_fn, cc, _g)
            cache[ck] = jax.jit(run_seg)
        return cache[ck](base_key, c)

    carry, trace, _segs, _walls = drive_segments(engine, carry, dispatch,
                                                 max_segments, time_axis=0,
                                                 supervisor=supervisor)
    return carry, trace


def run_campaign_bucketed(engine: BucketedLadderEngine, fids,
                          instances=(1,), runs: int = 1, seed: int = 0,
                          max_segments: int = 10_000,
                          ) -> BucketedCampaignResult:
    """Run a whole BBOB campaign through the rung-bucketed segment driver.

    Same member layout, instance stacking and key schedule as
    ``ladder.run_campaign`` — the two are trajectory-equivalent (bit-exact
    arithmetic per generation at ``eigen_interval == 1``, modulo per-shape
    XLA fusion rounding); this driver just never pays λ_max padding on a
    λ_start rung and stops as soon as the whole cohort is done.
    """
    fids = tuple(fids)
    members = [(f, i, r) for f in fids for i in instances for r in range(runs)]
    insts = [bbob.make_instance(f, engine.n, i, engine.full.cfg.jdtype)
             for (f, i, _r) in members]
    stacked = bbob.stack_instances(insts)
    branch_fids = tuple(sorted(set(fids)))

    base = jax.random.PRNGKey(seed)
    keys = jnp.stack([jax.random.fold_in(base, j) for j in range(len(members))])
    carry = engine._init_runner(keys)

    fused_menu = (bbob.eval_fusion_enabled()
                  and all(f in bbob.FUSABLE_FIDS for f in branch_fids))
    reg = obs.metrics()

    def dispatch(k, seg_gens, c):
        runner = engine.segment_runner(k, branch_fids, seg_gens)
        if fused_menu:
            # whole-menu-separable segments run the eval-fused sample
            # epilogue — count their generations (host-known statics only:
            # no device sync, no recompile)
            reg.counter("bucketed_eval_fused_generations_total").inc(
                int(seg_gens))
        return runner(keys, stacked, c)

    carry, trace, segments, bucket_wall = drive_segments(
        engine, carry, dispatch, max_segments)
    lam_start, kmax = engine.lam_start, engine.kmax_exp
    useful = _useful_evals_per_rung(trace, lam_start, kmax)
    B = len(members)
    padded = sum(B * s["gens"] * (2 ** s["bucket"]) * lam_start
                 for s in segments)
    return BucketedCampaignResult(
        members=members,
        f_opt=np.asarray([i.f_opt for i in insts], np.float64),
        best_f=np.asarray(carry.best_f),
        best_x=np.asarray(carry.best_x),
        total_fevals=np.asarray(carry.total_fevals),
        trace=trace,
        compiles=engine.compiles(),
        segments=segments,
        bucket_wall_s={k: round(v, 5) for k, v in bucket_wall.items()},
        useful_evals=int(sum(useful.values())),
        padded_evals=int(padded))
