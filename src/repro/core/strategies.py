"""K-Replicated and K-Distributed (paper §3.2) as SPMD mesh schedules.

Both strategies are written from the *per-device view* with named-axis
collectives, so one implementation runs under

  * ``shard_map`` on a real device mesh (production path; the multi-pod
    dry-run lowers exactly this program), or
  * nested ``vmap`` with the same axis names (simulation path — bit-identical
    math on a single CPU device; unit tests assert cross-device consistency).

Layouts (DESIGN.md §4)
----------------------
K-Distributed: one flat axis of P devices.  Descent k ∈ {0..K_max} owns the
contiguous "heap" range [2ᵏ−1, 2ᵏ⁺¹−1) — Σ2ᵏ = 2^{K_max+1}−1 devices, matching
the paper's 511-of-512-CMG layout.  Per-descent states are replicated; the
per-descent rank-μ Gram partials are merged with ONE stacked psum (a single
fused all-reduce instead of log₂K_max ragged group reductions — beyond-paper
collective optimization, see EXPERIMENTS §Perf).

K-Replicated: per phase, the device axis is re-viewed as (grp=G, mem=g) with
g = 2ᵏ devices per descent; group reductions are psums over 'mem' only.
Descent states are *sharded* over 'grp' (each group holds only its own state,
as on Fugaku), so phase K=1 with P descents never replicates P covariance
matrices.  Phases advance when every group's descent stopped (the paper's
sibling-pair merge becomes a phase barrier — DESIGN.md §8.2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import numpy as np

from repro.core import cmaes, eval_dispatch
from repro.core.params import CMAConfig, CMAParams, make_params, stack_params


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def heap_descent_of(idx: jnp.ndarray, n_active: int) -> jnp.ndarray:
    """Descent index for device ``idx`` in the heap layout (K-Dist)."""
    i = jnp.clip(idx, 0, n_active - 1)
    return jnp.floor(jnp.log2(i.astype(jnp.float64) + 1.5)).astype(jnp.int32)


def _select_state(stacked, idx):
    return jax.tree_util.tree_map(lambda a: a[idx], stacked)


def _where_state(mask_d, a, b):
    """Per-descent select over stacked states: mask (D,), leaves (D, ...)."""
    def sel(x, y):
        m = mask_d.reshape((mask_d.shape[0],) + (1,) * (x.ndim - 1))
        return jnp.where(m, x, y)
    return jax.tree_util.tree_map(sel, a, b)


def _apply_drop(key, f, drop_prob):
    """Straggler/failure simulation: masked evaluations become +inf."""
    if drop_prob <= 0.0:
        return f
    drop = jax.random.uniform(key, f.shape) < drop_prob
    return jnp.where(drop, jnp.inf, f)


# ---------------------------------------------------------------------------
# K-Distributed
# ---------------------------------------------------------------------------

class KDistCarry(NamedTuple):
    states: cmaes.CMAState     # stacked (D, ...), replicated on all devices
    restarts: jnp.ndarray      # (D,)
    fevals: jnp.ndarray        # (D,) cumulative across restarts
    best_f: jnp.ndarray        # () global best across descents & restarts
    best_x: jnp.ndarray        # (n,)


class KDistTrace(NamedTuple):
    best_f: jnp.ndarray        # () global best-so-far
    gen_best: jnp.ndarray      # (D,) this generation's best per descent
    descent_best: jnp.ndarray  # (D,) best within current descent incarnation
    fevals: jnp.ndarray        # () total evaluations so far
    stopped: jnp.ndarray       # (D,) which descents restarted this gen
    restarts: jnp.ndarray      # (D,)


@dataclasses.dataclass
class KDistributed:
    """All population sizes K = 2⁰..2^kmax_exp run concurrently (paper §3.2.3)."""

    n: int
    n_devices: int
    lam_start: int = 12
    lam_slots: int = 12           # evaluations per device per generation ("threads")
    kmax_exp: Optional[int] = None
    domain: Tuple[float, float] = (-5.0, 5.0)
    sigma0_frac: float = 0.25
    impl: str = "xla"
    drop_prob: float = 0.0
    restart_on_stop: bool = True  # paper §5 recommendation
    dtype: str = "float64"
    # communication schedule (§Perf hillclimb 3):
    #  "central" — paper-faithful: gather all sampled points to every
    #              device (emulating each descent's main process) and
    #              compute moments from the gathered population;
    #  "stacked" — local partial Grams + ONE fused stacked psum (default);
    comm: str = "stacked"
    gram_dtype: str = ""          # e.g. "float32": psum the Gram at reduced
                                  # precision (halves collective bytes)
    eigen_interval: Optional[int] = None  # None → c-cmaes default (CMAConfig)

    def __post_init__(self):
        if self.kmax_exp is None:
            # largest ladder fitting the machine: Σ_{k≤K}2ᵏ ≤ P
            self.kmax_exp = max(0, int(math.floor(math.log2(self.n_devices + 1))) - 1)
        self.n_descents = self.kmax_exp + 1
        self.n_active = 2 ** (self.kmax_exp + 1) - 1
        if self.n_active > self.n_devices:
            raise ValueError(
                f"kmax_exp={self.kmax_exp} needs {self.n_active} devices, "
                f"have {self.n_devices}")
        # NOTE: the descent with exponent k has population 2ᵏ·λ_start evaluated
        # by 2ᵏ devices × lam_slots evals each ⇒ lam_slots must equal lam_start.
        if self.lam_slots != self.lam_start:
            raise ValueError("lam_slots must equal lam_start (one device per "
                             "2ᵏ slice of the population, paper §4.1)")
        width = self.domain[1] - self.domain[0]
        self.lam_max = (2 ** self.kmax_exp) * self.lam_start
        self.cfg = CMAConfig(n=self.n, lam=self.lam_max, lam_max=self.lam_max,
                             sigma0=self.sigma0_frac * width, dtype=self.dtype,
                             eigen_interval=self.eigen_interval)
        self.sparams = stack_params([
            make_params(self.cfg, lam=(2 ** k) * self.lam_start)
            for k in range(self.n_descents)])

    # -- carry ----------------------------------------------------------------
    def init_carry(self, key: jax.Array) -> KDistCarry:
        D, n, dt = self.n_descents, self.n, self.cfg.jdtype
        lo, hi = self.domain
        keys = jax.random.split(key, D)
        x0 = jax.vmap(lambda k: jax.random.uniform(k, (n,), dt, lo, hi))(keys)
        states = jax.vmap(lambda k, x: cmaes.init_state(self.cfg, k, x))(keys, x0)
        return KDistCarry(
            states=states,
            restarts=jnp.zeros((D,), jnp.int32),
            fevals=jnp.zeros((D,), jnp.int64),
            best_f=jnp.asarray(jnp.inf, dt),
            best_x=jnp.zeros((n,), dt),
        )

    # -- one generation, per-device view ---------------------------------------
    def device_step(self, carry: KDistCarry, gen_key: jax.Array,
                    fitness_fn: Callable, axes: Tuple[str, ...],
                    eigen: str = "lazy") -> Tuple[KDistCarry, KDistTrace]:
        D, n, dt = self.n_descents, self.n, self.cfg.jdtype
        lam_slots, n_active = self.lam_slots, self.n_active
        P_sz = eval_dispatch.axis_size(axes)

        d = eval_dispatch.flat_index(axes)
        active = d < n_active
        kd = heap_descent_of(d, n_active)
        my_state = _select_state(carry.states, kd)

        key = jax.random.fold_in(gen_key, d)
        key = jax.random.fold_in(key, carry.restarts[kd])
        k_sample, k_drop = jax.random.split(key)

        y, x = cmaes.sample_population(my_state, k_sample, lam_slots, impl=self.impl)
        f = fitness_fn(x)
        f = _apply_drop(k_drop, f, self.drop_prob)
        f = jnp.where(active, f, jnp.inf)

        # ---- exchange fitnesses (the paper's gather, §3.2.1) ------------------
        f_all = eval_dispatch.all_gather_flat(f, axes)    # (P, lam_slots)
        f_flat = f_all.reshape(P_sz * lam_slots)
        rows = jnp.arange(P_sz)
        kd_rows = jnp.where(rows < n_active, heap_descent_of(rows, n_active), D)
        kd_flat = jnp.repeat(kd_rows, lam_slots)

        f_mine = jnp.where(kd_flat == kd, f_flat, jnp.inf)
        ranks = eval_dispatch.local_ranks(f, f_mine, d * lam_slots)
        w = self.sparams.weights[kd][jnp.clip(ranks, 0, self.lam_max - 1)]
        w = jnp.where(jnp.isfinite(f), w, 0.0)

        # ---- population moments ------------------------------------------------
        fused = cmaes.kops.use_fused(self.impl)
        if self.comm == "central":
            # paper-faithful (§3.2.1): the λ points travel to the descent's
            # main process (here: gathered everywhere, SPMD-replicated main).
            y_all = eval_dispatch.all_gather_flat(y, axes)   # (P, lam, n)
            y_flat = y_all.reshape(P_sz * lam_slots, n)
            w_flat_all = jnp.zeros((P_sz * lam_slots,), dt)
            # per-descent weights from global ranks (same math as local path)
            for_desc = kd_flat[:, None] == jnp.arange(D)[None, :]
            ranks_flat = jnp.argsort(jnp.argsort(
                jnp.where(for_desc.T, f_flat[None, :], jnp.inf), axis=1),
                axis=1)                                       # (D, P·lam)
            w_rows = jnp.take_along_axis(
                self.sparams.weights,
                jnp.clip(ranks_flat, 0, self.lam_max - 1), axis=1)
            w_rows = jnp.where(for_desc.T & jnp.isfinite(f_flat)[None, :],
                               w_rows, 0.0)                   # (D, P·lam)
            gram_st = jnp.einsum("dp,pn,pm->dnm", w_rows, y_flat, y_flat)
            yw_st = jnp.einsum("dp,pn->dn", w_rows, y_flat)
            wsum_st = jnp.sum(w_rows, axis=1)
            nval_st = jnp.sum(for_desc.T & jnp.isfinite(f_flat)[None, :],
                              axis=1).astype(jnp.int64)
        elif fused:
            # beyond-paper, fused (this PR): ONE √w-factored gram-FAMILY dot
            # per device — [gram | y_w] = Ysᵀ·[Ys | √w] — scattered into a
            # stacked (D, n, n+1) tensor, so the generation pays a single
            # λ-contraction and a single psum'd tensor where the unfused
            # path pays two dots (gram, w·y) and two reduced arrays.
            rw = jnp.sqrt(w)
            Ys = rw[:, None] * y
            fam_part = Ys.T @ jnp.concatenate([Ys, rw[:, None]], axis=1)
            gdt = jnp.dtype(self.gram_dtype) if self.gram_dtype else dt
            fam_st = jnp.zeros((D, n, n + 1), gdt).at[kd].add(
                fam_part.astype(gdt))
            wsum_st = jnp.zeros((D,), dt).at[kd].add(jnp.sum(w))
            nval_st = jnp.zeros((D,), jnp.int64).at[kd].add(
                jnp.sum(jnp.isfinite(f)).astype(jnp.int64))
            fam_st, wsum_st, nval_st = jax.lax.psum(
                (fam_st, wsum_st, nval_st), axes)
            fam_st = fam_st.astype(dt)
            gram_st, yw_st = fam_st[:, :, :n], fam_st[:, :, n]
        else:
            # pre-fused op soup, kept under impl="xla_unfused" for A/B
            yw_part = w @ y
            gram_part = cmaes.kops.rank_mu_gram(y, w, impl=self.impl)
            gdt = jnp.dtype(self.gram_dtype) if self.gram_dtype else dt
            gram_st = jnp.zeros((D, n, n), gdt).at[kd].add(
                gram_part.astype(gdt))
            yw_st = jnp.zeros((D, n), dt).at[kd].add(yw_part)
            wsum_st = jnp.zeros((D,), dt).at[kd].add(jnp.sum(w))
            nval_st = jnp.zeros((D,), jnp.int64).at[kd].add(
                jnp.sum(jnp.isfinite(f)).astype(jnp.int64))
            gram_st, yw_st, wsum_st, nval_st = jax.lax.psum(
                (gram_st, yw_st, wsum_st, nval_st), axes)
            gram_st = gram_st.astype(dt)

        # straggler mitigation: renormalize surviving weights
        scale = jnp.where(wsum_st > 1e-12, 1.0 / jnp.maximum(wsum_st, 1e-12), 0.0)
        yw_st = yw_st * scale[:, None]
        gram_st = gram_st * scale[:, None, None]

        # ---- per-descent order statistics (replicated compute) ----------------
        desc_ids = jnp.arange(D)
        masked = jnp.where(kd_flat[None, :] == desc_ids[:, None],
                           f_flat[None, :], jnp.inf)
        f_sorted = jnp.sort(masked, axis=1)[:, :self.lam_max]     # (D, lam_max)

        i_loc = jnp.argmin(f)
        xb_loc = x[i_loc]
        xb_all = eval_dispatch.all_gather_flat(xb_loc, axes)     # (P, n)
        fb_rows = jnp.min(f_all, axis=1)                          # (P,)
        row_masked = jnp.where(kd_rows[None, :] == desc_ids[:, None],
                               fb_rows[None, :], jnp.inf)
        r_star = jnp.argmin(row_masked, axis=1)
        x_best = xb_all[r_star]                                   # (D, n)

        if fused:
            # replicated fused epilogue (PR-4 form) on the reduced family —
            # both comm schedules feed the same mathematically-identical
            # gram, so they share this tail bit-for-bit.
            upd = jax.vmap(lambda p, s, g, yw, fs, xb, ne:
                           cmaes.masked_update_from_gram(
                               self.cfg, p, s, g, yw, fs, xb, ne,
                               eigen=eigen))(
                self.sparams, carry.states, gram_st, yw_st, f_sorted,
                x_best, nval_st.astype(jnp.int32))
        else:
            mom = cmaes.Moments(y_w=yw_st, gram=gram_st, f_sorted=f_sorted,
                                x_best=x_best,
                                n_evals=nval_st.astype(jnp.int32))
            upd = jax.vmap(lambda p, s, m: cmaes.masked_update(
                self.cfg, p, s, m, impl=self.impl, eigen=eigen))(
                    self.sparams, carry.states, mom)

        # ---- global best (before any restart wipes descent state) -------------
        gen_best = f_sorted[:, 0]
        gb = jnp.argmin(gen_best)
        better = gen_best[gb] < carry.best_f
        best_f = jnp.where(better, gen_best[gb], carry.best_f)
        best_x = jnp.where(better, x_best[gb], carry.best_x)

        # ---- in-place restart of stopped descents (same K, fresh mean/σ) ------
        stopped = upd.stop
        if self.restart_on_stop:
            lo, hi = self.domain
            rkeys = jax.vmap(
                lambda i: jax.random.fold_in(
                    jax.random.fold_in(gen_key, 1_000_003 + i), carry.restarts[i])
            )(desc_ids)
            x0s = jax.vmap(lambda k: jax.random.uniform(k, (n,), dt, lo, hi))(rkeys)
            fresh = jax.vmap(lambda k, x0: cmaes.init_state(self.cfg, k, x0))(rkeys, x0s)
            new_states = _where_state(stopped, fresh, upd)
            restarts = carry.restarts + stopped.astype(jnp.int32)
        else:
            new_states = upd
            restarts = carry.restarts

        fevals = carry.fevals + nval_st
        new_carry = KDistCarry(states=new_states, restarts=restarts,
                               fevals=fevals, best_f=best_f, best_x=best_x)
        trace = KDistTrace(best_f=best_f, gen_best=gen_best,
                           descent_best=upd.best_f, fevals=jnp.sum(fevals),
                           stopped=stopped, restarts=restarts)
        return new_carry, trace

    # -- chunked scan over generations ------------------------------------------
    def chunk_fn(self, fitness_fn, axes, chunk: int):
        """Scan over a chunk of per-generation keys, nested in eigen blocks.

        Whenever ``cfg.eigen_interval > 1`` divides the key count, the chunk
        runs as ``ladder.scan_eigen_blocks`` (structural defer/always cadence
        — one batched ``eigh`` per block) instead of the flat lazy scan whose
        per-descent ``lax.cond`` vmap lowers to a both-branches select paying
        the O(n³) factorization every generation (the leftover named in the
        ROADMAP; HLO-pinned in tests/test_eigen_amortization.py).  Ragged key
        counts (a final partial chunk) keep the flat scan — they recompile
        for the new shape anyway and stay bit-compatible with PR-1 behavior.
        """
        from repro.core import ladder

        interval = int(self.cfg.eigen_interval)

        def run_chunk(carry, keys):
            T = int(keys.shape[0])
            if interval > 1 and T % interval == 0:
                def step(c, k, eigen):
                    return self.device_step(c, k, fitness_fn, axes,
                                            eigen=eigen)
                return ladder.scan_eigen_blocks(step, carry, interval,
                                                T // interval, xs=keys)
            return jax.lax.scan(
                lambda c, k: self.device_step(c, k, fitness_fn, axes),
                carry, keys)
        return run_chunk

    # -- drivers -------------------------------------------------------------
    def run_sim(self, key: jax.Array, fitness_fn, total_gens: int,
                chunk: int = 16):
        """Single-device simulation via vmap with the same axis names."""
        axes = ("ev",)
        carry = self.init_carry(jax.random.fold_in(key, 0))
        fn = jax.jit(jax.vmap(self.chunk_fn(fitness_fn, axes, chunk),
                              in_axes=(None, None), out_axes=0,
                              axis_name="ev", axis_size=self.n_devices))
        traces = []
        for c0 in range(0, total_gens, chunk):
            key, kc = jax.random.split(key)
            keys = jax.random.split(kc, min(chunk, total_gens - c0))
            carry_b, tr = fn(carry, keys)
            # replicated outputs: take device 0 (consistency asserted in tests)
            carry = jax.tree_util.tree_map(lambda a: a[0], carry_b)
            traces.append(jax.tree_util.tree_map(lambda a: np.asarray(a[0]), tr))
        return carry, _concat_traces(traces)

    def run_on_mesh(self, mesh, key: jax.Array, fitness_fn, total_gens: int,
                    chunk: int = 16, axes: Optional[Tuple[str, ...]] = None):
        """shard_map on a real mesh (all axes collapsed into the eval axis)."""
        axes = tuple(axes if axes is not None else mesh.axis_names)
        fn = eval_dispatch.shard_map_compat(
            self.chunk_fn(fitness_fn, axes, chunk), mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()))
        fn = jax.jit(fn)
        carry = self.init_carry(jax.random.fold_in(key, 0))
        traces = []
        for c0 in range(0, total_gens, chunk):
            key, kc = jax.random.split(key)
            keys = jax.random.split(kc, min(chunk, total_gens - c0))
            carry, tr = fn(carry, keys)
            traces.append(jax.tree_util.tree_map(np.asarray, tr))
        return carry, _concat_traces(traces)

    def lower_step(self, mesh, fitness_fn, chunk: int = 1,
                   axes: Optional[Tuple[str, ...]] = None):
        """Lower (no execute) one chunk for the dry-run / roofline harness."""
        axes = tuple(axes if axes is not None else mesh.axis_names)
        fn = eval_dispatch.shard_map_compat(
            self.chunk_fn(fitness_fn, axes, chunk), mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()))
        carry = jax.eval_shape(lambda k: self.init_carry(k),
                               jax.ShapeDtypeStruct((2,), jnp.uint32))
        keys = jax.ShapeDtypeStruct((chunk, 2), jnp.uint32)
        return jax.jit(fn).lower(carry, keys)


# ---------------------------------------------------------------------------
# K-Replicated
# ---------------------------------------------------------------------------

class KRepCarry(NamedTuple):
    state: cmaes.CMAState      # this group's descent (sharded over 'grp')
    best_f: jnp.ndarray        # () global best (replicated)
    best_x: jnp.ndarray        # (n,)
    fevals: jnp.ndarray        # () total evaluations (replicated)


class KRepTrace(NamedTuple):
    best_f: jnp.ndarray        # ()
    group_best: jnp.ndarray    # (G,) per-group best-so-far
    n_stopped: jnp.ndarray     # ()
    fevals: jnp.ndarray        # ()


@dataclasses.dataclass
class KReplicated:
    """Successive phases of replicated same-K descents (paper §3.2.2)."""

    n: int
    n_devices: int
    lam_start: int = 12
    lam_slots: int = 12
    domain: Tuple[float, float] = (-5.0, 5.0)
    sigma0_frac: float = 0.25
    impl: str = "xla"
    drop_prob: float = 0.0
    dtype: str = "float64"
    eigen_interval: Optional[int] = None  # None → c-cmaes default (CMAConfig)

    def __post_init__(self):
        if self.lam_slots != self.lam_start:
            raise ValueError("lam_slots must equal lam_start")
        if self.n_devices & (self.n_devices - 1):
            raise ValueError("K-Replicated needs a power-of-two device count")
        self.kmax_exp = int(math.log2(self.n_devices))
        width = self.domain[1] - self.domain[0]
        self.sigma0 = self.sigma0_frac * width

    def phase_cfg(self, k_exp: int) -> tuple[CMAConfig, CMAParams, int, int]:
        g = 2 ** k_exp                       # devices per descent
        G = self.n_devices // g              # concurrent descents
        lam = g * self.lam_start
        cfg = CMAConfig(n=self.n, lam=lam, lam_max=lam, sigma0=self.sigma0,
                        dtype=self.dtype, eigen_interval=self.eigen_interval)
        return cfg, make_params(cfg), G, g

    def init_phase_states(self, cfg: CMAConfig, G: int, key: jax.Array):
        lo, hi = self.domain
        keys = jax.random.split(key, G)
        x0 = jax.vmap(lambda k: jax.random.uniform(
            k, (self.n,), cfg.jdtype, lo, hi))(keys)
        return jax.vmap(lambda k, x: cmaes.init_state(cfg, k, x))(keys, x0)

    def device_step(self, cfg: CMAConfig, params: CMAParams, carry: KRepCarry,
                    gen_key: jax.Array, fitness_fn: Callable,
                    eigen: str = "lazy") -> Tuple[KRepCarry, KRepTrace]:
        n, dt, lam_slots = self.n, cfg.jdtype, self.lam_slots
        g = eval_dispatch.axis_size(("mem",))
        mem = jax.lax.axis_index("mem")
        dev = eval_dispatch.flat_index(("grp", "mem"))

        key = jax.random.fold_in(gen_key, dev)
        k_sample, k_drop = jax.random.split(key)
        state = carry.state

        y, x = cmaes.sample_population(state, k_sample, lam_slots, impl=self.impl)
        f = fitness_fn(x)
        f = _apply_drop(k_drop, f, self.drop_prob)

        f_all = jax.lax.all_gather(f, "mem").reshape(g, lam_slots)
        f_flat = f_all.reshape(g * lam_slots)
        ranks = eval_dispatch.local_ranks(f, f_flat, mem * lam_slots)
        w = params.weights[jnp.clip(ranks, 0, params.weights.shape[0] - 1)]
        w = jnp.where(jnp.isfinite(f), w, 0.0)

        fused = cmaes.kops.use_fused(self.impl)
        if fused:
            # one √w-factored gram-family dot + ONE psum'd tensor over 'mem'
            # (same residency move as KDistributed — see masked_update_from_gram)
            rw = jnp.sqrt(w)
            Ys = rw[:, None] * y
            fam_part = Ys.T @ jnp.concatenate([Ys, rw[:, None]], axis=1)
            fam, wsum, nval = jax.lax.psum(
                (fam_part, jnp.sum(w),
                 jnp.sum(jnp.isfinite(f)).astype(jnp.int64)), "mem")
            gram, yw = fam[:, :n], fam[:, n]
        else:
            yw_part = w @ y
            gram_part = cmaes.kops.rank_mu_gram(y, w, impl=self.impl)
            gram, yw, wsum, nval = jax.lax.psum(
                (gram_part, yw_part, jnp.sum(w),
                 jnp.sum(jnp.isfinite(f)).astype(jnp.int64)), "mem")
        scale = jnp.where(wsum > 1e-12, 1.0 / jnp.maximum(wsum, 1e-12), 0.0)
        yw, gram = yw * scale, gram * scale

        f_sorted = jnp.sort(f_flat)                           # lam == lam_max
        i_loc = jnp.argmin(f)
        xb_all = jax.lax.all_gather(x[i_loc], "mem").reshape(g, n)
        x_best = xb_all[jnp.argmin(jnp.min(f_all, axis=1))]

        if fused:
            new_state = cmaes.masked_update_from_gram(
                cfg, params, state, gram, yw, f_sorted, x_best,
                nval.astype(jnp.int32), eigen=eigen)
        else:
            mom = cmaes.Moments(y_w=yw, gram=gram, f_sorted=f_sorted,
                                x_best=x_best, n_evals=nval.astype(jnp.int32))
            new_state = cmaes.masked_update(cfg, params, state, mom,
                                            impl=self.impl, eigen=eigen)

        # global best across groups (gather per-group candidates)
        gen_best = f_sorted[0]
        fb_grp = jax.lax.all_gather(gen_best, "grp")
        xb_grp = jax.lax.all_gather(x_best, "grp")
        G = fb_grp.shape[0]
        fb_grp = fb_grp.reshape(G)
        xb_grp = xb_grp.reshape(G, n)
        i_star = jnp.argmin(fb_grp)
        better = fb_grp[i_star] < carry.best_f
        best_f = jnp.where(better, fb_grp[i_star], carry.best_f)
        best_x = jnp.where(better, xb_grp[i_star], carry.best_x)

        # stopped descents idle (masked) until the phase barrier — paper Fig. 3
        n_stopped = jax.lax.psum(new_state.stop.astype(jnp.int32), "grp")
        # evals: stopped descents idle, so they stop consuming budget
        evals_gen = jax.lax.psum(jnp.where(state.stop, 0, nval), "grp")
        fevals = carry.fevals + evals_gen

        group_best = jax.lax.all_gather(new_state.best_f, "grp").reshape(G)
        new_carry = KRepCarry(state=new_state, best_f=best_f, best_x=best_x,
                              fevals=fevals)
        trace = KRepTrace(best_f=best_f, group_best=group_best,
                          n_stopped=n_stopped, fevals=fevals)
        return new_carry, trace

    def phase_chunk_fn(self, cfg, params, fitness_fn, chunk: int):
        """Phase chunk scan, nested in eigen blocks exactly as
        ``KDistributed.chunk_fn`` (same vmapped-lazy-eigh rationale)."""
        from repro.core import ladder

        interval = int(cfg.eigen_interval)

        def run_chunk(carry, keys):
            T = int(keys.shape[0])
            if interval > 1 and T % interval == 0:
                def step(c, k, eigen):
                    return self.device_step(cfg, params, c, k, fitness_fn,
                                            eigen=eigen)
                return ladder.scan_eigen_blocks(step, carry, interval,
                                                T // interval, xs=keys)
            return jax.lax.scan(
                lambda c, k: self.device_step(cfg, params, c, k, fitness_fn),
                carry, keys)
        return run_chunk

    def run_sim(self, key: jax.Array, fitness_fn, phase_gens: int,
                chunk: int = 16, max_evals: Optional[int] = None,
                phases: Optional[List[int]] = None):
        """All phases on one device via nested vmap('mem' ⊗ 'grp').

        Every carry leaf is pre-broadcast to a full per-device copy
        ((g, G, ...)), so both vmap levels use plain in/out_axes=0 and the
        extraction after each chunk is uniform (device [0, 0]; group states
        are taken from member 0 of each group).
        """
        best_f, best_x = np.inf, np.zeros(self.n)
        fevals = 0
        all_traces: List[dict] = []
        phase_list = phases if phases is not None else list(range(self.kmax_exp + 1))
        for k_exp in phase_list:
            cfg, params, G, g = self.phase_cfg(k_exp)
            key, k_init = jax.random.split(key)
            states = self.init_phase_states(cfg, G, k_init)    # (G, ...)
            carry = KRepCarry(
                state=states,
                best_f=jnp.asarray(best_f, cfg.jdtype),
                best_x=jnp.asarray(best_x, cfg.jdtype),
                fevals=jnp.asarray(fevals, jnp.int64))

            def to_dev(c: KRepCarry) -> KRepCarry:
                st = jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a[None], (g,) + a.shape), c.state)
                rep = lambda a: jnp.broadcast_to(a[None, None], (g, G) + a.shape)
                return KRepCarry(state=st, best_f=rep(c.best_f),
                                 best_x=rep(c.best_x), fevals=rep(c.fevals))

            def from_dev(cb: KRepCarry) -> KRepCarry:
                st = jax.tree_util.tree_map(lambda a: a[0], cb.state)
                return KRepCarry(state=st, best_f=cb.best_f[0, 0],
                                 best_x=cb.best_x[0, 0], fevals=cb.fevals[0, 0])

            inner = jax.vmap(self.phase_chunk_fn(cfg, params, fitness_fn, chunk),
                             in_axes=0, out_axes=0, axis_name="grp")
            outer = jax.jit(jax.vmap(inner, in_axes=0, out_axes=0,
                                     axis_name="mem"))

            traces = []
            gens_done = 0
            while gens_done < phase_gens:
                key, kc = jax.random.split(key)
                n_keys = min(chunk, phase_gens - gens_done)
                keys = jax.random.split(kc, n_keys)
                keys_b = jnp.broadcast_to(keys[None, None], (g, G) + keys.shape)
                carry_b, tr = outer(to_dev(carry), keys_b)
                carry = from_dev(carry_b)
                tr0 = jax.tree_util.tree_map(lambda a: np.asarray(a[0, 0]), tr)
                traces.append(tr0)
                gens_done += n_keys
                if int(np.asarray(tr0.n_stopped)[-1]) >= G:
                    break
                if max_evals is not None and int(tr0.fevals[-1]) >= max_evals:
                    break
            trace = _concat_traces(traces)
            trace["k_exp"] = k_exp
            trace["lam"] = cfg.lam
            trace["n_groups"] = G
            all_traces.append(trace)
            best_f = float(carry.best_f)
            best_x = np.asarray(carry.best_x)
            fevals = int(carry.fevals)
            if max_evals is not None and fevals >= max_evals:
                break
        return dict(best_f=best_f, best_x=best_x, fevals=fevals,
                    phases=all_traces)

    def lower_phase(self, mesh, fitness_fn, k_exp: int, chunk: int = 1):
        """Lower one phase chunk under shard_map for the dry-run harness.

        The mesh must have axes ('grp', 'mem') with sizes (G, g) for this
        phase.  States are sharded over 'grp' (one descent per group), the
        global-best scalars replicated.
        """
        cfg, params, G, g = self.phase_cfg(k_exp)
        run_chunk = self.phase_chunk_fn(cfg, params, fitness_fn, chunk)

        def wrapped(carry, keys):
            # shard_map hands each device a (1, ...) slice of the 'grp'-sharded
            # state; squeeze to the per-device view and re-expand on the way out.
            c = carry._replace(state=jax.tree_util.tree_map(
                lambda a: a[0], carry.state))
            c, tr = run_chunk(c, keys)
            return c._replace(state=jax.tree_util.tree_map(
                lambda a: a[None], c.state)), tr

        in_specs = (KRepCarry(state=P("grp"), best_f=P(), best_x=P(),
                              fevals=P()), P())
        out_specs = (KRepCarry(state=P("grp"), best_f=P(), best_x=P(),
                               fevals=P()), P())
        fn = eval_dispatch.shard_map_compat(wrapped, mesh=mesh,
                                            in_specs=in_specs,
                                            out_specs=out_specs)
        carry = jax.eval_shape(
            lambda k: KRepCarry(
                state=self.init_phase_states(cfg, G, k),
                best_f=jnp.asarray(jnp.inf, cfg.jdtype),
                best_x=jnp.zeros((self.n,), cfg.jdtype),
                fevals=jnp.asarray(0, jnp.int64)),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        keys = jax.ShapeDtypeStruct((chunk, 2), jnp.uint32)
        return jax.jit(fn).lower(carry, keys)


def _concat_traces(traces: List) -> dict:
    if not traces:
        return {}
    first = traces[0]
    if isinstance(first, dict):
        keys = first.keys()
        return {k: np.concatenate([t[k] for t in traces]) for k in keys}
    fields = first._fields
    return {k: np.concatenate([np.asarray(getattr(t, k)) for t in traces])
            for k in fields}
