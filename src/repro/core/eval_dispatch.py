"""Sharded evaluation dispatch — the TPU analogue of the paper's MPI
scatter/gather of the λ function evaluations (§3.2.1).

Differences from the paper (DESIGN.md §2):
  * points are sampled device-locally (identical distribution, zero scatter
    traffic) instead of centrally sampled + scattered;
  * fitnesses are exchanged with one small ``all_gather``;
  * straggler mitigation: an evaluation may be reported as failed/late by the
    ``valid`` mask — it enters the rank computation as +inf, receives zero
    recombination weight and the remaining weights are renormalized.  This is
    the ES analogue of gradient-skipping and costs no synchronization.

All functions here are written from the *per-device view* and are agnostic to
how that view is produced: ``shard_map`` on a real mesh, or nested ``vmap``
with the same axis names (the simulation path used by unit tests — bit-exact
same program).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


AxisNames = Sequence[str]


class FusableEval:
    """A fitness closure that ALSO carries the separable coefficients of its
    (traced-fid) evaluation — the per-fid ``fusable`` capability flag of the
    dispatch menu, in object form.

    Calling it behaves exactly like the wrapped closure (the two-program
    fallback, and what every non-fused engine path keeps using); engines
    that can fuse (``ladder._slots_fused_update``) detect the ``.sep``
    payload via ``getattr(fitness_fn, "sep", None)`` and route sampling
    through the eval-fused kernel ops instead, so X never materializes.
    Built by ``bbob.fusable_fitness`` — only when the whole static fid menu
    is separable.
    """

    __slots__ = ("fn", "sep")

    def __init__(self, fn, sep):
        self.fn = fn
        self.sep = sep

    def __call__(self, X):
        return self.fn(X)


def shard_map_compat(f, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across jax versions.

    New jax exposes it at the top level with ``check_vma``; 0.4.x has
    ``jax.experimental.shard_map.shard_map`` with ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check)


def flat_index(axes: AxisNames) -> jnp.ndarray:
    """Linearized device index over (possibly multiple) named axes."""
    return jax.lax.axis_index(tuple(axes))


def axis_size(axes: AxisNames) -> int:
    # jax.lax.axis_size only exists in newer jax; psum of the constant 1 is
    # the portable spelling and constant-folds to a python int at trace time.
    if hasattr(jax.lax, "axis_size"):
        sizes = [jax.lax.axis_size(a) for a in axes]
    else:
        sizes = [jax.lax.psum(1, a) for a in axes]
    out = 1
    for s in sizes:
        out *= int(s)
    return out


def all_gather_flat(x: jnp.ndarray, axes: AxisNames) -> jnp.ndarray:
    """all_gather over (possibly multiple) named axes, flattened to one
    leading dim of size P in row-major (= ``flat_index``) order."""
    y = x
    for a in reversed(tuple(axes)):
        y = jax.lax.all_gather(y, a)
    return y.reshape((-1,) + x.shape)


def local_ranks(f_local: jnp.ndarray, f_all_flat: jnp.ndarray,
                my_flat_base: jnp.ndarray) -> jnp.ndarray:
    """Rank of each local fitness among a (masked) flat fitness vector.

    ``f_all_flat`` holds the full descent population's fitnesses with
    non-members / failed evaluations set to +inf.  Ties are broken by the
    global slot index so the ranking is a strict total order (matching a
    centralized argsort).
    """
    lam_local = f_local.shape[0]
    my_idx = my_flat_base + jnp.arange(lam_local)
    all_idx = jnp.arange(f_all_flat.shape[0])
    smaller = f_all_flat[None, :] < f_local[:, None]
    tie = (f_all_flat[None, :] == f_local[:, None]) & (
        all_idx[None, :] < my_idx[:, None])
    finite = jnp.isfinite(f_all_flat)[None, :]
    return jnp.sum((smaller | tie) & finite, axis=1)


def masked_fitness(f: jnp.ndarray, valid: jnp.ndarray | None) -> jnp.ndarray:
    """Apply the straggler/failure mask: invalid evaluations rank last."""
    if valid is None:
        return f
    return jnp.where(valid, f, jnp.inf)
