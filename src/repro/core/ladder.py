"""Device-resident IPOP restart ladder — single-jit campaigns (paper Alg. 2).

The sequential baseline in core/ipop.py used to drive each descent as a
host-side chunked Python loop: per-descent recompiles (a new CMAConfig per
population size), a host round-trip per chunk to poll the stop flag, and a
host-side restart between rungs.  This module keeps the *entire* restart
ladder on device:

* All rungs K = 2⁰..2^kmax share ONE λ_max-padded ``CMAConfig``; their
  per-rung strategy parameters are precomputed as a stacked ``CMAParams``
  (``params.ladder_params``) whose leaves carry a leading rung axis, so a
  *traced* rung index can gather a descent's parameters on device
  (``params.select_params``).
* Descent slots live in one stacked ``CMAState`` pytree and advance inside a
  single ``jax.lax.scan``.  When ``stopping.check_stop`` fires for a slot,
  the slot re-initializes **in place** from a fresh key with doubled-λ
  weights gathered from the stack — no host round-trip, no recompile.
* Two schedules: ``sequential`` (paper Alg. 2 semantics — one active descent
  whose rung index walks the ladder, masked no-ops once it is exhausted or
  the evaluation budget cannot pay for another generation) and
  ``concurrent`` (all rungs as live slots at once, the IPOP analogue of
  K-Distributed).  ``run_concurrent`` additionally wraps the strategies.py
  collectives (KDistributed's per-device program) in one full-length scan
  for the sharded concurrent path.
* ``run_campaign`` vmaps the scanned ladder over (function, instance, run)
  triples: stacked instances (``bbob.stack_instances``) with traced-fid
  dispatch (``bbob.evaluate_dynamic``; its pre-vmapped form is
  ``bbob.evaluate_stacked``), so an entire campaign compiles once per
  (n, λ_max) shape and runs as one program.

The price of the single program is padding: every generation samples and
evaluates λ_max points even on the λ_start rung (masked slots carry zero
weight and +inf fitness, exactly as core/cmaes.py promises).  On the target
deployment — one evaluation per core, the paper's §3.2.1 — those lanes are
idle hardware, not wasted wall-clock; on CPU the padded GEMMs are still far
cheaper than per-chunk host synchronization (see benchmarks/bench_ladder.py).

The key schedule (``slot_key`` / ``gen_key``) is shared with the host-loop
baseline ``ipop.run_ipop_hostloop`` so the two are trajectory-equivalent on
identical base keys (tests/test_ladder.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cmaes
from repro.core.params import (CMAConfig, default_max_iter, ladder_params,
                               select_params)
from repro.fitness import bbob


# ---------------------------------------------------------------------------
# key schedule — shared by the device ladder and the host-loop baseline
# ---------------------------------------------------------------------------

def slot_key(base_key: jax.Array, slot_id, incarnation) -> jax.Array:
    """Key of one descent incarnation of one slot (both indices may be traced)."""
    return jax.random.fold_in(jax.random.fold_in(base_key, slot_id), incarnation)


def init_keys(kd: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(k_init, k_x0) for a fresh descent keyed by ``kd``."""
    ks = jax.random.split(kd)
    return ks[0], ks[1]


def gen_key(kd: jax.Array, gen) -> jax.Array:
    """Sampling key of (0-based) generation ``gen`` within an incarnation."""
    return jax.random.fold_in(kd, gen)


def fresh_state(cfg: CMAConfig, kd: jax.Array,
                domain: Tuple[float, float]) -> cmaes.CMAState:
    """Fresh descent state: uniform mean in the search domain, reset σ."""
    k_init, k_x0 = init_keys(kd)
    lo, hi = domain
    x0 = jax.random.uniform(k_x0, (cfg.n,), cfg.jdtype, lo, hi)
    return cmaes.init_state(cfg, k_init, x0)


# ---------------------------------------------------------------------------
# one λ_max-padded generation (also the host-loop baseline's step)
# ---------------------------------------------------------------------------

def padded_gen_step(cfg: CMAConfig, params, state: cmaes.CMAState,
                    k_gen: jax.Array, fitness_fn: Callable,
                    impl: str = "xla", eigen: str = "lazy") -> cmaes.CMAState:
    """Sample ``cfg.lam_max`` points, mask slots ≥ λ to +inf, apply the update.

    Sampling is row-keyed (``cmaes.sample_z``), so the points a descent sees
    depend only on its (slot, incarnation, generation) key and each row's
    index — bit-identical whether the program pads to the campaign's λ_max
    or to a rung bucket's narrower width (core/bucketed.py).  ``eigen``
    picks the B/D refresh mode (see ``cmaes.update_from_moments``).

    ``impl`` additionally selects the update structure: the fused
    generation path (``cmaes.masked_update_fused`` — one gram-family op,
    C/B/D read once) for every impl except ``"xla_unfused"``, which keeps
    the pre-PR-4 moments op soup (kernels/ops.py has the full semantics).
    """
    lam_max = cfg.lam_max
    if cmaes.kops.use_fused(impl):
        z = cmaes.sample_z(state, k_gen, lam_max)
        y, x = cmaes.kops.gen_sample(state.m, state.sigma, state.B, state.D,
                                     z, impl=impl)
        f = fitness_fn(x)
        f = jnp.where(jnp.arange(lam_max) < params.lam, f, jnp.inf)
        return cmaes.masked_update_fused(cfg, params, state, y, f, x,
                                         impl=impl, eigen=eigen)
    y, x = cmaes.sample_population(state, k_gen, lam_max, impl=impl)
    f = fitness_fn(x)
    f = jnp.where(jnp.arange(lam_max) < params.lam, f, jnp.inf)
    mom = cmaes.compute_moments(y, f, x, params, lam_max, impl=impl)
    return cmaes.masked_update(cfg, params, state, mom, impl=impl, eigen=eigen)


def _tree_select(mask: jnp.ndarray, a, b):
    """Per-slot select over stacked pytrees: mask (S,), leaves (S, ...)."""
    def sel(x, y):
        m = mask.reshape(mask.shape + (1,) * (x.ndim - mask.ndim))
        return jnp.where(m, x, y)
    return jax.tree_util.tree_map(sel, a, b)


def _slots_fused_update(cfg: CMAConfig, params_k, states: cmaes.CMAState,
                        kgs: jax.Array, fitness_fn: Callable,
                        impl: str, eigen: str) -> cmaes.CMAState:
    """One fused generation over ALL slots at once — the slot-batched form
    of ``padded_gen_step``.

    The two heavy ops run ONCE on the stacked (S, ...) state with the slot
    axis mapped onto the kernels' leading grid dimension (the Pallas
    megakernels of kernels/cma_gen.py when ``impl`` resolves to pallas; the
    batched fused jnp ref otherwise) — replacing the old vmap-of-per-slot-
    kernel corner, which no engine ever exercised.  RNG, fitness and the
    O(n) scalar epilogue stay vmapped per slot: they are cheap, and vmap of
    the identical jnp keeps them bit-compatible with the per-slot step the
    host-loop baseline runs.
    """
    lam_max = cfg.lam_max
    resolved = cmaes.kops.resolve_impl(impl)
    sep = getattr(fitness_fn, "sep", None)   # bbob.fusable_fitness payload
    if resolved == "pallas_rng":
        # In-kernel RNG tier: the per-(slot, incarnation, generation) key IS
        # the counter-stream seed — nothing host-shaped remains on the
        # sampled path, and the stream stays chunk/padding-independent the
        # same way the row-keyed fold_in draw is (counter = (row<<16)|col).
        seeds = jnp.asarray(kgs, jnp.uint32).reshape(kgs.shape[0], 2)
        if sep is not None:
            X = None
            Y, F = cmaes.kops.gen_sample_rng_eval(
                states.m, states.sigma, states.B, states.D, seeds, lam_max,
                sep, impl=impl)
        else:
            Y, X = cmaes.kops.gen_sample_rng(
                states.m, states.sigma, states.B, states.D, seeds, lam_max,
                impl=impl)
            F = jax.vmap(fitness_fn)(X)
    else:
        Z = jax.vmap(lambda st, kg: cmaes.sample_z(st, kg, lam_max))(states,
                                                                     kgs)
        if sep is not None:
            # eval-fused epilogue: the kernel/ref returns F directly and X
            # never materializes in HBM (bit-identical F to the dispatched
            # menu on the XLA tiers — see bbob.separable_eval).
            X = None
            Y, F = cmaes.kops.gen_sample_eval(
                states.m, states.sigma, states.B, states.D, Z, sep,
                impl=impl)
        else:
            Y, X = cmaes.kops.gen_sample(states.m, states.sigma, states.B,
                                         states.D, Z, impl=impl)
            F = jax.vmap(fitness_fn)(X)
    F = jnp.where(jnp.arange(lam_max)[None, :] < params_k.lam[:, None],
                  F, jnp.inf)
    if X is None:
        W, f_sorted, x_best, n_evals = jax.vmap(
            lambda f, y, m, s, p: cmaes.population_stats_from_y(
                f, y, m, s, p, lam_max))(
                F, Y, states.m, states.sigma, params_k)
    else:
        W, f_sorted, x_best, n_evals = jax.vmap(
            lambda f, x, p: cmaes.population_stats(f, x, p, lam_max))(
                F, X, params_k)
    C_new, ps_new, pc_new, y_w = cmaes.kops.gen_update(
        states.C, states.B, states.D, states.p_sigma, states.p_c, Y, W,
        cmaes.gen_coef(params_k, states), impl=impl)
    new = jax.vmap(
        lambda p, st, fs, xb, ne, cn, ps, pc, yw: cmaes._finish_update(
            cfg, p, st, fs, xb, ne, cn, ps, pc, yw, eigen))(
                params_k, states, f_sorted, x_best, n_evals,
                C_new, ps_new, pc_new, y_w)
    # masked_update semantics: stopped slots keep their state frozen
    return _tree_select(states.stop, states, new)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class LadderCarry(NamedTuple):
    states: cmaes.CMAState      # (S, ...) stacked descent slots
    k_idx: jnp.ndarray          # (S,) int32 — rung index, λ = 2ᵏ·λ_start
    incarnation: jnp.ndarray    # (S,) int32 — restarts of this slot so far
    active: jnp.ndarray         # (S,) bool — False once a slot retired
    total_fevals: jnp.ndarray   # () int — across all slots and restarts
    best_f: jnp.ndarray         # () global best
    best_x: jnp.ndarray         # (n,)


class LadderTrace(NamedTuple):
    """Per-generation record (slot-stacked leaves, shape (S,) unless noted)."""
    ran: jnp.ndarray            # bool — slot executed this generation
    k_idx: jnp.ndarray          # int32 — rung during this generation
    gen: jnp.ndarray            # int32 — within-descent generation (1-based)
    fevals: jnp.ndarray         # within-descent cumulative evaluations
    best_f: jnp.ndarray         # within-descent best-so-far
    stop_reason: jnp.ndarray    # int32 bitmask (core/stopping.py)
    stopped: jnp.ndarray        # bool — stop fired; slot restarted or retired
    total_fevals: jnp.ndarray   # () cumulative across the whole ladder
    global_best: jnp.ndarray    # () best across slots and restarts


def slots_gen_step(cfg: CMAConfig, sparams, carry: "LadderCarry",
                   base_key: jax.Array, fitness_fn: Callable, *,
                   max_evals: int, kmax_exp: int,
                   schedule: str = "sequential", restart_mode: str = "double",
                   domain: Tuple[float, float] = (-5.0, 5.0),
                   impl: str = "xla", eigen: str = "lazy",
                   bucket_cap: Optional[int] = None,
                   ) -> Tuple["LadderCarry", "LadderTrace"]:
    """One generation over all slots — the shared inner step of every ladder
    program (λ_max-padded engine, host-loop baseline chunks, and the
    rung-bucketed programs of core/bucketed.py).

    Static knobs beyond the engine's own:

    * ``bucket_cap`` — capacity (highest rung index) of the executing program.
      Slots whose rung exceeds it are *parked*: ``ran=False``, state frozen,
      until the segment driver migrates them to a wider bucket.  ``None``
      means the program pads to the full ladder (no parking).
    * ``eigen`` — B/D refresh mode for this generation (the nested eigen-block
      scan passes ``"defer"`` / ``"always"``; see ``scan_eigen_blocks``).
    """
    S = carry.k_idx.shape[0]
    slot_ids = jnp.arange(S, dtype=jnp.int32)

    gather_idx = (carry.k_idx if bucket_cap is None
                  else jnp.minimum(carry.k_idx, bucket_cap))
    params_k = select_params(sparams, gather_idx)      # leaves (S, ...)
    lam_k = params_k.lam.astype(carry.total_fevals.dtype)

    # budget gate: a slot only starts a generation it can fully pay for.
    # Concurrent slots spend from the shared budget in the same step, so
    # each is gated on the cumulative reservation of the slots before it —
    # the summed spend never exceeds max_evals.
    runnable = carry.active
    if bucket_cap is not None:
        runnable = runnable & (carry.k_idx <= bucket_cap)
    reserve = jnp.cumsum(jnp.where(runnable, lam_k, 0))
    ran = runnable & (carry.total_fevals + reserve <= max_evals)

    kds = jax.vmap(lambda s, i: slot_key(base_key, s, i))(
        slot_ids, carry.incarnation)
    kgs = jax.vmap(gen_key)(kds, carry.states.gen)

    if cmaes.kops.use_fused(impl):
        upd = _slots_fused_update(cfg, params_k, carry.states, kgs,
                                  fitness_fn, impl, eigen)
    else:
        upd = jax.vmap(lambda p, st, kg: padded_gen_step(
            cfg, p, st, kg, fitness_fn, impl=impl, eigen=eigen))(
                params_k, carry.states, kgs)
    new_states = _tree_select(ran, upd, carry.states)

    evals_gen = jnp.sum(jnp.where(ran, lam_k, 0))
    total_fevals = carry.total_fevals + evals_gen

    cand = jnp.where(ran, new_states.best_f, jnp.inf)
    i_star = jnp.argmin(cand)
    better = cand[i_star] < carry.best_f
    best_f = jnp.where(better, cand[i_star], carry.best_f)
    best_x = jnp.where(better, new_states.best_x[i_star], carry.best_x)

    stopped = ran & new_states.stop
    trace = LadderTrace(
        ran=ran, k_idx=carry.k_idx, gen=new_states.gen,
        fevals=new_states.fevals, best_f=new_states.best_f,
        stop_reason=new_states.stop_reason, stopped=stopped,
        total_fevals=total_fevals, global_best=best_f)

    # -- in-place restart: doubled-λ params gathered from the stack -------
    if schedule == "concurrent" and restart_mode == "same_k":
        next_k = carry.k_idx
    else:
        next_k = carry.k_idx + 1
    if schedule == "sequential":
        retire = stopped & (next_k > kmax_exp)   # ladder exhausted
    else:
        retire = jnp.zeros_like(stopped)
        next_k = jnp.minimum(next_k, kmax_exp)
    restart = stopped & ~retire
    k_new = jnp.where(restart, next_k, carry.k_idx)
    inc_new = carry.incarnation + restart.astype(jnp.int32)
    active_new = carry.active & ~retire

    kds_new = jax.vmap(lambda s, i: slot_key(base_key, s, i))(
        slot_ids, inc_new)
    fresh = jax.vmap(lambda kd: fresh_state(cfg, kd, domain))(kds_new)
    fresh = fresh._replace(restarts=inc_new)
    states_out = _tree_select(restart, fresh, new_states)

    return LadderCarry(
        states=states_out, k_idx=k_new, incarnation=inc_new,
        active=active_new, total_fevals=total_fevals,
        best_f=best_f, best_x=best_x), trace


def scan_eigen_blocks(step_fn: Callable, carry, interval: int, n_blocks: int,
                      xs=None):
    """Nested generation scan that keeps the eigendecomposition amortized
    under jit+vmap (paper §3.1).

    The flat scan used to rely on ``lax.cond`` inside the update to skip the
    O(n³) ``eigh`` between refreshes — but vmap lowers that cond to a select
    which executes both branches, so every vmapped campaign generation paid
    the full ``eigh`` regardless of ``eigen_interval``.  Here the cadence is
    structural instead of data-dependent: ``n_blocks`` outer steps each run
    ``interval − 1`` inner generations with frozen B/D (``eigen="defer"``)
    and close with one generation whose update ends in an *unconditional*
    batched ``eigh`` (``eigen="always"``).  The compiled program contains
    exactly one ``eigh`` per outer step — ⌈T/eigen_interval⌉ executions, not
    T (asserted via HLO in tests/test_eigen_amortization.py).

    ``step_fn(carry, eigen_mode) -> (carry, trace)``; returns the final carry
    and the per-generation trace with leading axis ``n_blocks·interval``.
    With ``xs`` (a pytree whose leaves carry a leading ``n_blocks·interval``
    axis — e.g. the strategies chunk scans' per-generation keys) the step
    signature becomes ``step_fn(carry, x, eigen_mode)`` and each generation
    consumes one slice, exactly like ``lax.scan`` xs.

    With ``interval == 1`` every generation refreshes — identical arithmetic
    to the lazy flat scan, so trajectory equivalence with the host-loop
    baseline stays bit-exact there.  For ``interval > 1`` the cadence is
    aligned to scan blocks rather than each descent's private generation
    counter (restarts re-phase the latter), a tolerance-bounded change
    (tests/test_eigen_amortization.py).
    """
    interval, n_blocks = int(interval), int(n_blocks)
    if xs is None:
        fn = lambda c, _x, eigen: step_fn(c, eigen)
        xs_blocks = None
    else:
        fn = step_fn
        xs_blocks = jax.tree_util.tree_map(
            lambda a: a.reshape((n_blocks, interval) + a.shape[1:]), xs)

    def outer(c, xb):
        take = (lambda i: None) if xb is None else (
            lambda i: jax.tree_util.tree_map(lambda a: a[i], xb))
        if interval > 1:
            head = None if xb is None else jax.tree_util.tree_map(
                lambda a: a[:interval - 1], xb)
            c, ys = jax.lax.scan(lambda c2, x: fn(c2, x, "defer"),
                                 c, head, length=interval - 1)
            c, last = fn(c, take(interval - 1), "always")
            tr = jax.tree_util.tree_map(
                lambda a, b: jnp.concatenate([a, b[None]]), ys, last)
        else:
            c, last = fn(c, take(0), "always")
            tr = jax.tree_util.tree_map(lambda b: b[None], last)
        return c, tr

    carry, tr = jax.lax.scan(outer, carry, xs_blocks, length=n_blocks)
    tr = jax.tree_util.tree_map(
        lambda a: a.reshape((n_blocks * interval,) + a.shape[2:]), tr)
    return carry, tr


@dataclasses.dataclass
class LadderEngine:
    """Stacked IPOP ladder: all rungs in one padded pytree, one scanned program."""

    n: int
    lam_start: int = 12
    kmax_exp: int = 4
    schedule: str = "sequential"        # "sequential" | "concurrent"
    max_evals: int = 200_000
    domain: Tuple[float, float] = (-5.0, 5.0)
    sigma0_frac: float = 0.25
    impl: str = "auto"                  # kernel dispatch — see kernels/ops.py
    dtype: str = "float64"
    restart_mode: str = "double"        # concurrent slots: "double" | "same_k"
    eigen_interval: Optional[int] = None  # None → c-cmaes default (CMAConfig)
    eigen_schedule: str = "nested"      # "nested" | "flat" (PR-1 legacy scan)

    def __post_init__(self):
        if self.schedule not in ("sequential", "concurrent"):
            raise ValueError(f"unknown schedule {self.schedule!r}")
        if self.restart_mode not in ("double", "same_k"):
            raise ValueError(f"unknown restart_mode {self.restart_mode!r}")
        cmaes.kops.validate_impl(self.impl)
        if self.eigen_schedule not in ("nested", "flat"):
            raise ValueError(f"unknown eigen_schedule {self.eigen_schedule!r}")
        self.lam_max = (2 ** self.kmax_exp) * self.lam_start
        width = self.domain[1] - self.domain[0]
        self.cfg = CMAConfig(n=self.n, lam=self.lam_max, lam_max=self.lam_max,
                             sigma0=self.sigma0_frac * width, dtype=self.dtype,
                             eigen_interval=self.eigen_interval)
        self.sparams = ladder_params(self.cfg, self.lam_start, self.kmax_exp)
        self.n_slots = 1 if self.schedule == "sequential" else self.kmax_exp + 1
        # the budget counter lives on device: check it fits the widest int
        # dtype actually available (int32 when jax_enable_x64 is off)
        fev_dt = jax.dtypes.canonicalize_dtype(jnp.int64)
        if self.max_evals > jnp.iinfo(fev_dt).max:
            raise ValueError(
                f"max_evals={self.max_evals} overflows the device budget "
                f"counter ({fev_dt.name}); enable jax_enable_x64 for int64")
        self._runner_cache: dict = {}

    # -- sizing ---------------------------------------------------------------
    def default_gens(self, total_gens: Optional[int] = None) -> int:
        """Upper bound on useful scan length for the sequential schedule."""
        if total_gens is not None:
            return int(total_gens)
        by_budget = self.max_evals // self.lam_start
        by_iter = sum(default_max_iter(self.n, (2 ** k) * self.lam_start)
                      for k in range(self.kmax_exp + 1))
        return max(1, min(by_budget, by_iter))

    # -- init -----------------------------------------------------------------
    def init_carry(self, base_key: jax.Array) -> LadderCarry:
        S, n, dt = self.n_slots, self.n, self.cfg.jdtype
        slot_ids = jnp.arange(S, dtype=jnp.int32)
        if self.schedule == "concurrent":
            k0 = slot_ids                       # slot i starts on rung i
        else:
            k0 = jnp.zeros((S,), jnp.int32)     # the single slot walks the ladder
        inc0 = jnp.zeros((S,), jnp.int32)
        kds = jax.vmap(lambda s, i: slot_key(base_key, s, i))(slot_ids, inc0)
        states = jax.vmap(lambda kd: fresh_state(self.cfg, kd, self.domain))(kds)
        # int64 budget counter when x64 is on; an *explicit* int32 otherwise
        # (a bare jnp.int64 would silently downcast with a warning) — the
        # __post_init__ guard already rejected budgets that cannot fit.
        fev_dt = jax.dtypes.canonicalize_dtype(jnp.int64)
        return LadderCarry(
            states=states, k_idx=k0, incarnation=inc0,
            active=jnp.ones((S,), bool),
            total_fevals=jnp.zeros((), fev_dt),
            best_f=jnp.asarray(jnp.inf, dt),
            best_x=jnp.zeros((n,), dt))

    # -- one generation over all slots ----------------------------------------
    def gen_step(self, carry: LadderCarry, base_key: jax.Array,
                 fitness_fn: Callable,
                 eigen: str = "lazy") -> Tuple[LadderCarry, LadderTrace]:
        return slots_gen_step(
            self.cfg, self.sparams, carry, base_key, fitness_fn,
            max_evals=self.max_evals, kmax_exp=self.kmax_exp,
            schedule=self.schedule, restart_mode=self.restart_mode,
            domain=self.domain, impl=self.impl, eigen=eigen)

    # -- the whole ladder as one scan ------------------------------------------
    def run_scan(self, base_key: jax.Array, fitness_fn: Callable,
                 total_gens: int) -> Tuple[LadderCarry, LadderTrace]:
        """Pure scanned program — call under jit (and vmap, for campaigns).

        The scan is nested in eigen blocks (``scan_eigen_blocks``); its true
        length is ``total_gens`` rounded up to a whole number of blocks.
        ``eigen_schedule="flat"`` keeps the PR-1 flat scan whose per-descent
        ``lax.cond`` laziness vmap silently defeats — the measured regression
        baseline in benchmarks/bench_ladder.py.
        """
        carry0 = self.init_carry(base_key)
        if self.eigen_schedule == "flat":
            def body(c, _):
                return self.gen_step(c, base_key, fitness_fn, eigen="lazy")
            return jax.lax.scan(body, carry0, None, length=int(total_gens))

        interval = int(self.cfg.eigen_interval)
        n_blocks = -(-int(total_gens) // interval)

        def step_fn(c, eigen):
            return self.gen_step(c, base_key, fitness_fn, eigen=eigen)

        return scan_eigen_blocks(step_fn, carry0, interval, n_blocks)

    def run(self, base_key: jax.Array, fitness_fn: Callable,
            total_gens: Optional[int] = None) -> Tuple[LadderCarry, LadderTrace]:
        """Single-problem convenience wrapper (one jit, one device program)."""
        total_gens = self.default_gens(total_gens)
        fn = jax.jit(lambda k: self.run_scan(k, fitness_fn, total_gens))
        return fn(base_key)

    # -- campaign: vmap over (function, instance, run) triples -----------------
    def campaign_runner(self, branch_fids: Tuple[int, ...], total_gens: int):
        """Jitted vmapped runner, cached per (fid set, scan length) — plus
        the eval-fusion toggle, read at trace time like the impl override."""
        key = (tuple(branch_fids), int(total_gens),
               bbob.eval_fusion_enabled())
        if key not in self._runner_cache:
            def run_one(base_key, inst):
                def fit(X):
                    return bbob.evaluate_dynamic(inst, X, branch_fids)
                return self.run_scan(
                    base_key, bbob.fusable_fitness(inst, branch_fids, fit),
                    total_gens)
            self._runner_cache[key] = jax.jit(jax.vmap(run_one))
        return self._runner_cache[key]


@dataclasses.dataclass
class CampaignResult:
    members: List[Tuple[int, int, int]]   # (fid, instance, run) per batch row
    f_opt: np.ndarray                     # (B,)
    best_f: np.ndarray                    # (B,)
    best_x: np.ndarray                    # (B, n)
    total_fevals: np.ndarray              # (B,)
    trace: LadderTrace                    # leaves (B, T, S) / (B, T)
    compiles: int                         # jit cache entries of the runner

    def hit_evals(self, targets: np.ndarray) -> np.ndarray:
        """(B, len(targets)) first total-eval count reaching best−f_opt ≤ t.

        Batched ``cummin``/``searchsorted`` formulation: the running-best
        error per row (``np.minimum.accumulate`` — a safety net, since
        ``global_best`` is already monotone) is non-increasing, so the
        generations hitting a target form a suffix whose length one
        ``np.searchsorted`` over the reversed row finds for ALL targets at
        once.  Replacing the former Python B×targets double loop
        (``np.nonzero`` per cell) this measures ~8× faster at campaign
        scale — B=64, T=4096, 51 targets: 2.3 ms vs 17.4 ms on one CPU
        core — and ~9× on a B=4, T=750 smoke trace (0.06 ms vs 0.6 ms).
        """
        gb = np.minimum.accumulate(
            np.asarray(self.trace.global_best), axis=1)   # (B, T)
        fe = np.asarray(self.trace.total_fevals)          # (B, T)
        err = gb - np.asarray(self.f_opt)[:, None]        # (B, T) non-incr.
        targets = np.asarray(targets, np.float64)
        T = err.shape[1]
        out = np.full((err.shape[0], targets.shape[0]), np.inf)
        for b, row in enumerate(err):
            n_hit = np.searchsorted(row[::-1], targets, side="right")
            hit = n_hit > 0
            out[b, hit] = fe[b, T - n_hit[hit]]
        return out


def run_campaign(engine: LadderEngine, fids, instances=(1,), runs: int = 1,
                 seed: int = 0,
                 total_gens: Optional[int] = None) -> CampaignResult:
    """Run a whole BBOB campaign as ONE jitted/vmapped ladder program.

    Every (fid, instance, run) triple becomes one batch row of the vmapped
    scan; the instance pytrees are stacked (Gallagher peaks padded) and the
    fitness dispatch is a lax.switch over the campaign's static fid set.
    Compiles at most once per (n, λ_max, fid set, batch, scan length) shape.
    """
    fids = tuple(fids)
    members = [(f, i, r) for f in fids for i in instances for r in range(runs)]
    insts = [bbob.make_instance(f, engine.n, i, engine.cfg.jdtype)
             for (f, i, _r) in members]
    stacked = bbob.stack_instances(insts)
    branch_fids = tuple(sorted(set(fids)))
    total_gens = engine.default_gens(total_gens)

    runner = engine.campaign_runner(branch_fids, total_gens)
    base = jax.random.PRNGKey(seed)
    keys = jnp.stack([jax.random.fold_in(base, j) for j in range(len(members))])
    carry, trace = runner(keys, stacked)

    compiles = -1
    cache_size = getattr(runner, "_cache_size", None)
    if callable(cache_size):
        compiles = int(cache_size())
    return CampaignResult(
        members=members,
        f_opt=np.asarray([i.f_opt for i in insts], np.float64),
        best_f=np.asarray(carry.best_f),
        best_x=np.asarray(carry.best_x),
        total_fevals=np.asarray(carry.total_fevals),
        trace=jax.tree_util.tree_map(np.asarray, trace),
        compiles=compiles)


# ---------------------------------------------------------------------------
# concurrent schedule on the strategies.py collectives (single-jit)
# ---------------------------------------------------------------------------

def run_concurrent(n: int, n_devices: int, key: jax.Array,
                   fitness_fn: Callable, total_gens: int,
                   lam_start: int = 12, kmax_exp: Optional[int] = None,
                   domain: Tuple[float, float] = (-5.0, 5.0),
                   sigma0_frac: float = 0.25, impl: str = "auto",
                   dtype: str = "float64", drop_prob: float = 0.0,
                   eigen_interval: Optional[int] = None):
    """All rungs concurrently via KDistributed's per-device program, scanned
    over ALL generations inside one jit — the device-resident replacement for
    ``KDistributed.run_sim``'s host-side chunk loop.

    The chunk scan is nested in eigen blocks whenever ``eigen_interval > 1``
    divides ``total_gens`` (``KDistributed.chunk_fn`` — the vmapped lazy-eigh
    ``lax.cond`` executed ``eigh`` every generation otherwise; HLO-pinned in
    tests/test_eigen_amortization.py).

    Returns ``(kd, carry, trace_dict)`` with the same trace-dict layout
    ``run_sim`` produced, so the benchmarks swap in directly.
    """
    from repro.core.strategies import KDistributed

    kd = KDistributed(n=n, n_devices=n_devices, lam_start=lam_start,
                      lam_slots=lam_start, kmax_exp=kmax_exp, domain=domain,
                      sigma0_frac=sigma0_frac, impl=impl, dtype=dtype,
                      drop_prob=drop_prob, eigen_interval=eigen_interval)
    axes = ("ev",)
    fn = jax.jit(jax.vmap(kd.chunk_fn(fitness_fn, axes, int(total_gens)),
                          in_axes=(None, None), out_axes=0,
                          axis_name="ev", axis_size=kd.n_devices))
    carry0 = kd.init_carry(jax.random.fold_in(key, 0))
    keys = jax.random.split(key, int(total_gens))
    carry_b, tr = fn(carry0, keys)
    # replicated outputs: take the device-0 view
    carry = jax.tree_util.tree_map(lambda a: a[0], carry_b)
    trace = {k: np.asarray(getattr(tr, k)[0]) for k in tr._fields}
    return kd, carry, trace
