"""Device-resident IPOP restart ladder — single-jit campaigns (paper Alg. 2).

The sequential baseline in core/ipop.py used to drive each descent as a
host-side chunked Python loop: per-descent recompiles (a new CMAConfig per
population size), a host round-trip per chunk to poll the stop flag, and a
host-side restart between rungs.  This module keeps the *entire* restart
ladder on device:

* All rungs K = 2⁰..2^kmax share ONE λ_max-padded ``CMAConfig``; their
  per-rung strategy parameters are precomputed as a stacked ``CMAParams``
  (``params.ladder_params``) whose leaves carry a leading rung axis, so a
  *traced* rung index can gather a descent's parameters on device
  (``params.select_params``).
* Descent slots live in one stacked ``CMAState`` pytree and advance inside a
  single ``jax.lax.scan``.  When ``stopping.check_stop`` fires for a slot,
  the slot re-initializes **in place** from a fresh key with doubled-λ
  weights gathered from the stack — no host round-trip, no recompile.
* Two schedules: ``sequential`` (paper Alg. 2 semantics — one active descent
  whose rung index walks the ladder, masked no-ops once it is exhausted or
  the evaluation budget cannot pay for another generation) and
  ``concurrent`` (all rungs as live slots at once, the IPOP analogue of
  K-Distributed).  ``run_concurrent`` additionally wraps the strategies.py
  collectives (KDistributed's per-device program) in one full-length scan
  for the sharded concurrent path.
* ``run_campaign`` vmaps the scanned ladder over (function, instance, run)
  triples: stacked instances (``bbob.stack_instances``) with traced-fid
  dispatch (``bbob.evaluate_dynamic``; its pre-vmapped form is
  ``bbob.evaluate_stacked``), so an entire campaign compiles once per
  (n, λ_max) shape and runs as one program.

The price of the single program is padding: every generation samples and
evaluates λ_max points even on the λ_start rung (masked slots carry zero
weight and +inf fitness, exactly as core/cmaes.py promises).  On the target
deployment — one evaluation per core, the paper's §3.2.1 — those lanes are
idle hardware, not wasted wall-clock; on CPU the padded GEMMs are still far
cheaper than per-chunk host synchronization (see benchmarks/bench_ladder.py).

The key schedule (``slot_key`` / ``gen_key``) is shared with the host-loop
baseline ``ipop.run_ipop_hostloop`` so the two are trajectory-equivalent on
identical base keys (tests/test_ladder.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cmaes
from repro.core.params import (CMAConfig, default_max_iter, ladder_params,
                               select_params)
from repro.fitness import bbob


# ---------------------------------------------------------------------------
# key schedule — shared by the device ladder and the host-loop baseline
# ---------------------------------------------------------------------------

def slot_key(base_key: jax.Array, slot_id, incarnation) -> jax.Array:
    """Key of one descent incarnation of one slot (both indices may be traced)."""
    return jax.random.fold_in(jax.random.fold_in(base_key, slot_id), incarnation)


def init_keys(kd: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(k_init, k_x0) for a fresh descent keyed by ``kd``."""
    ks = jax.random.split(kd)
    return ks[0], ks[1]


def gen_key(kd: jax.Array, gen) -> jax.Array:
    """Sampling key of (0-based) generation ``gen`` within an incarnation."""
    return jax.random.fold_in(kd, gen)


def fresh_state(cfg: CMAConfig, kd: jax.Array,
                domain: Tuple[float, float]) -> cmaes.CMAState:
    """Fresh descent state: uniform mean in the search domain, reset σ."""
    k_init, k_x0 = init_keys(kd)
    lo, hi = domain
    x0 = jax.random.uniform(k_x0, (cfg.n,), cfg.jdtype, lo, hi)
    return cmaes.init_state(cfg, k_init, x0)


# ---------------------------------------------------------------------------
# one λ_max-padded generation (also the host-loop baseline's step)
# ---------------------------------------------------------------------------

def padded_gen_step(cfg: CMAConfig, params, state: cmaes.CMAState,
                    k_gen: jax.Array, fitness_fn: Callable,
                    impl: str = "xla") -> cmaes.CMAState:
    """Sample λ_max points, mask slots ≥ λ to +inf, apply the CMA update."""
    lam_max = cfg.lam_max
    y, x = cmaes.sample_population(state, k_gen, lam_max, impl=impl)
    f = fitness_fn(x)
    f = jnp.where(jnp.arange(lam_max) < params.lam, f, jnp.inf)
    mom = cmaes.compute_moments(y, f, x, params, lam_max, impl=impl)
    return cmaes.masked_update(cfg, params, state, mom, impl=impl)


def _tree_select(mask: jnp.ndarray, a, b):
    """Per-slot select over stacked pytrees: mask (S,), leaves (S, ...)."""
    def sel(x, y):
        m = mask.reshape(mask.shape + (1,) * (x.ndim - mask.ndim))
        return jnp.where(m, x, y)
    return jax.tree_util.tree_map(sel, a, b)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class LadderCarry(NamedTuple):
    states: cmaes.CMAState      # (S, ...) stacked descent slots
    k_idx: jnp.ndarray          # (S,) int32 — rung index, λ = 2ᵏ·λ_start
    incarnation: jnp.ndarray    # (S,) int32 — restarts of this slot so far
    active: jnp.ndarray         # (S,) bool — False once a slot retired
    total_fevals: jnp.ndarray   # () int — across all slots and restarts
    best_f: jnp.ndarray         # () global best
    best_x: jnp.ndarray         # (n,)


class LadderTrace(NamedTuple):
    """Per-generation record (slot-stacked leaves, shape (S,) unless noted)."""
    ran: jnp.ndarray            # bool — slot executed this generation
    k_idx: jnp.ndarray          # int32 — rung during this generation
    gen: jnp.ndarray            # int32 — within-descent generation (1-based)
    fevals: jnp.ndarray         # within-descent cumulative evaluations
    best_f: jnp.ndarray         # within-descent best-so-far
    stop_reason: jnp.ndarray    # int32 bitmask (core/stopping.py)
    stopped: jnp.ndarray        # bool — stop fired; slot restarted or retired
    total_fevals: jnp.ndarray   # () cumulative across the whole ladder
    global_best: jnp.ndarray    # () best across slots and restarts


@dataclasses.dataclass
class LadderEngine:
    """Stacked IPOP ladder: all rungs in one padded pytree, one scanned program."""

    n: int
    lam_start: int = 12
    kmax_exp: int = 4
    schedule: str = "sequential"        # "sequential" | "concurrent"
    max_evals: int = 200_000
    domain: Tuple[float, float] = (-5.0, 5.0)
    sigma0_frac: float = 0.25
    impl: str = "xla"
    dtype: str = "float64"
    restart_mode: str = "double"        # concurrent slots: "double" | "same_k"

    def __post_init__(self):
        if self.schedule not in ("sequential", "concurrent"):
            raise ValueError(f"unknown schedule {self.schedule!r}")
        if self.restart_mode not in ("double", "same_k"):
            raise ValueError(f"unknown restart_mode {self.restart_mode!r}")
        self.lam_max = (2 ** self.kmax_exp) * self.lam_start
        width = self.domain[1] - self.domain[0]
        self.cfg = CMAConfig(n=self.n, lam=self.lam_max, lam_max=self.lam_max,
                             sigma0=self.sigma0_frac * width, dtype=self.dtype)
        self.sparams = ladder_params(self.cfg, self.lam_start, self.kmax_exp)
        self.n_slots = 1 if self.schedule == "sequential" else self.kmax_exp + 1
        self._runner_cache: dict = {}

    # -- sizing ---------------------------------------------------------------
    def default_gens(self, total_gens: Optional[int] = None) -> int:
        """Upper bound on useful scan length for the sequential schedule."""
        if total_gens is not None:
            return int(total_gens)
        by_budget = self.max_evals // self.lam_start
        by_iter = sum(default_max_iter(self.n, (2 ** k) * self.lam_start)
                      for k in range(self.kmax_exp + 1))
        return max(1, min(by_budget, by_iter))

    # -- init -----------------------------------------------------------------
    def init_carry(self, base_key: jax.Array) -> LadderCarry:
        S, n, dt = self.n_slots, self.n, self.cfg.jdtype
        slot_ids = jnp.arange(S, dtype=jnp.int32)
        if self.schedule == "concurrent":
            k0 = slot_ids                       # slot i starts on rung i
        else:
            k0 = jnp.zeros((S,), jnp.int32)     # the single slot walks the ladder
        inc0 = jnp.zeros((S,), jnp.int32)
        kds = jax.vmap(lambda s, i: slot_key(base_key, s, i))(slot_ids, inc0)
        states = jax.vmap(lambda kd: fresh_state(self.cfg, kd, self.domain))(kds)
        return LadderCarry(
            states=states, k_idx=k0, incarnation=inc0,
            active=jnp.ones((S,), bool),
            total_fevals=jnp.zeros((), jnp.int64),
            best_f=jnp.asarray(jnp.inf, dt),
            best_x=jnp.zeros((n,), dt))

    # -- one generation over all slots ----------------------------------------
    def gen_step(self, carry: LadderCarry, base_key: jax.Array,
                 fitness_fn: Callable) -> Tuple[LadderCarry, LadderTrace]:
        cfg = self.cfg
        S = self.n_slots
        slot_ids = jnp.arange(S, dtype=jnp.int32)

        params_k = select_params(self.sparams, carry.k_idx)   # leaves (S, ...)
        lam_k = params_k.lam.astype(carry.total_fevals.dtype)

        # budget gate: a slot only starts a generation it can fully pay for.
        # Concurrent slots spend from the shared budget in the same step, so
        # each is gated on the cumulative reservation of the slots before it —
        # the summed spend never exceeds max_evals.
        reserve = jnp.cumsum(jnp.where(carry.active, lam_k, 0))
        ran = carry.active & (carry.total_fevals + reserve <= self.max_evals)

        kds = jax.vmap(lambda s, i: slot_key(base_key, s, i))(
            slot_ids, carry.incarnation)
        kgs = jax.vmap(gen_key)(kds, carry.states.gen)

        upd = jax.vmap(lambda p, st, kg: padded_gen_step(
            cfg, p, st, kg, fitness_fn, impl=self.impl))(
                params_k, carry.states, kgs)
        new_states = _tree_select(ran, upd, carry.states)

        evals_gen = jnp.sum(jnp.where(ran, lam_k, 0))
        total_fevals = carry.total_fevals + evals_gen

        cand = jnp.where(ran, new_states.best_f, jnp.inf)
        i_star = jnp.argmin(cand)
        better = cand[i_star] < carry.best_f
        best_f = jnp.where(better, cand[i_star], carry.best_f)
        best_x = jnp.where(better, new_states.best_x[i_star], carry.best_x)

        stopped = ran & new_states.stop
        trace = LadderTrace(
            ran=ran, k_idx=carry.k_idx, gen=new_states.gen,
            fevals=new_states.fevals, best_f=new_states.best_f,
            stop_reason=new_states.stop_reason, stopped=stopped,
            total_fevals=total_fevals, global_best=best_f)

        # -- in-place restart: doubled-λ params gathered from the stack -------
        if self.schedule == "concurrent" and self.restart_mode == "same_k":
            next_k = carry.k_idx
        else:
            next_k = carry.k_idx + 1
        if self.schedule == "sequential":
            retire = stopped & (next_k > self.kmax_exp)   # ladder exhausted
        else:
            retire = jnp.zeros_like(stopped)
            next_k = jnp.minimum(next_k, self.kmax_exp)
        restart = stopped & ~retire
        k_new = jnp.where(restart, next_k, carry.k_idx)
        inc_new = carry.incarnation + restart.astype(jnp.int32)
        active_new = carry.active & ~retire

        kds_new = jax.vmap(lambda s, i: slot_key(base_key, s, i))(
            slot_ids, inc_new)
        fresh = jax.vmap(lambda kd: fresh_state(cfg, kd, self.domain))(kds_new)
        fresh = fresh._replace(restarts=inc_new)
        states_out = _tree_select(restart, fresh, new_states)

        return LadderCarry(
            states=states_out, k_idx=k_new, incarnation=inc_new,
            active=active_new, total_fevals=total_fevals,
            best_f=best_f, best_x=best_x), trace

    # -- the whole ladder as one scan ------------------------------------------
    def run_scan(self, base_key: jax.Array, fitness_fn: Callable,
                 total_gens: int) -> Tuple[LadderCarry, LadderTrace]:
        """Pure scanned program — call under jit (and vmap, for campaigns)."""
        carry0 = self.init_carry(base_key)

        def body(c, _):
            return self.gen_step(c, base_key, fitness_fn)

        return jax.lax.scan(body, carry0, None, length=int(total_gens))

    def run(self, base_key: jax.Array, fitness_fn: Callable,
            total_gens: Optional[int] = None) -> Tuple[LadderCarry, LadderTrace]:
        """Single-problem convenience wrapper (one jit, one device program)."""
        total_gens = self.default_gens(total_gens)
        fn = jax.jit(lambda k: self.run_scan(k, fitness_fn, total_gens))
        return fn(base_key)

    # -- campaign: vmap over (function, instance, run) triples -----------------
    def campaign_runner(self, branch_fids: Tuple[int, ...], total_gens: int):
        """Jitted vmapped runner, cached per (fid set, scan length)."""
        key = (tuple(branch_fids), int(total_gens))
        if key not in self._runner_cache:
            def run_one(base_key, inst):
                def fit(X):
                    return bbob.evaluate_dynamic(inst, X, branch_fids)
                return self.run_scan(base_key, fit, total_gens)
            self._runner_cache[key] = jax.jit(jax.vmap(run_one))
        return self._runner_cache[key]


@dataclasses.dataclass
class CampaignResult:
    members: List[Tuple[int, int, int]]   # (fid, instance, run) per batch row
    f_opt: np.ndarray                     # (B,)
    best_f: np.ndarray                    # (B,)
    best_x: np.ndarray                    # (B, n)
    total_fevals: np.ndarray              # (B,)
    trace: LadderTrace                    # leaves (B, T, S) / (B, T)
    compiles: int                         # jit cache entries of the runner

    def hit_evals(self, targets: np.ndarray) -> np.ndarray:
        """(B, len(targets)) first total-eval count reaching best−f_opt ≤ t."""
        gb = np.asarray(self.trace.global_best)          # (B, T)
        fe = np.asarray(self.trace.total_fevals)         # (B, T)
        out = np.full((gb.shape[0], len(targets)), np.inf)
        for b in range(gb.shape[0]):
            err = gb[b] - self.f_opt[b]
            for i, t in enumerate(targets):
                idx = np.nonzero(err <= t)[0]
                if idx.size:
                    out[b, i] = fe[b, idx[0]]
        return out


def run_campaign(engine: LadderEngine, fids, instances=(1,), runs: int = 1,
                 seed: int = 0,
                 total_gens: Optional[int] = None) -> CampaignResult:
    """Run a whole BBOB campaign as ONE jitted/vmapped ladder program.

    Every (fid, instance, run) triple becomes one batch row of the vmapped
    scan; the instance pytrees are stacked (Gallagher peaks padded) and the
    fitness dispatch is a lax.switch over the campaign's static fid set.
    Compiles at most once per (n, λ_max, fid set, batch, scan length) shape.
    """
    fids = tuple(fids)
    members = [(f, i, r) for f in fids for i in instances for r in range(runs)]
    insts = [bbob.make_instance(f, engine.n, i, engine.cfg.jdtype)
             for (f, i, _r) in members]
    stacked = bbob.stack_instances(insts)
    branch_fids = tuple(sorted(set(fids)))
    total_gens = engine.default_gens(total_gens)

    runner = engine.campaign_runner(branch_fids, total_gens)
    base = jax.random.PRNGKey(seed)
    keys = jnp.stack([jax.random.fold_in(base, j) for j in range(len(members))])
    carry, trace = runner(keys, stacked)

    compiles = -1
    cache_size = getattr(runner, "_cache_size", None)
    if callable(cache_size):
        compiles = int(cache_size())
    return CampaignResult(
        members=members,
        f_opt=np.asarray([i.f_opt for i in insts], np.float64),
        best_f=np.asarray(carry.best_f),
        best_x=np.asarray(carry.best_x),
        total_fevals=np.asarray(carry.total_fevals),
        trace=jax.tree_util.tree_map(np.asarray, trace),
        compiles=compiles)


# ---------------------------------------------------------------------------
# concurrent schedule on the strategies.py collectives (single-jit)
# ---------------------------------------------------------------------------

def run_concurrent(n: int, n_devices: int, key: jax.Array,
                   fitness_fn: Callable, total_gens: int,
                   lam_start: int = 12, kmax_exp: Optional[int] = None,
                   domain: Tuple[float, float] = (-5.0, 5.0),
                   sigma0_frac: float = 0.25, impl: str = "xla",
                   dtype: str = "float64", drop_prob: float = 0.0):
    """All rungs concurrently via KDistributed's per-device program, scanned
    over ALL generations inside one jit — the device-resident replacement for
    ``KDistributed.run_sim``'s host-side chunk loop.

    Returns ``(kd, carry, trace_dict)`` with the same trace-dict layout
    ``run_sim`` produced, so the benchmarks swap in directly.
    """
    from repro.core.strategies import KDistributed

    kd = KDistributed(n=n, n_devices=n_devices, lam_start=lam_start,
                      lam_slots=lam_start, kmax_exp=kmax_exp, domain=domain,
                      sigma0_frac=sigma0_frac, impl=impl, dtype=dtype,
                      drop_prob=drop_prob)
    axes = ("ev",)
    fn = jax.jit(jax.vmap(kd.chunk_fn(fitness_fn, axes, int(total_gens)),
                          in_axes=(None, None), out_axes=0,
                          axis_name="ev", axis_size=kd.n_devices))
    carry0 = kd.init_carry(jax.random.fold_in(key, 0))
    keys = jax.random.split(key, int(total_gens))
    carry_b, tr = fn(carry0, keys)
    # replicated outputs: take the device-0 view
    carry = jax.tree_util.tree_map(lambda a: a[0], carry_b)
    trace = {k: np.asarray(getattr(tr, k)[0]) for k in tr._fields}
    return kd, carry, trace
