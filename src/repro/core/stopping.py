"""CMA-ES stopping criteria (Auger & Hansen 2005; c-cmaes reference defaults).

Each criterion sets one bit in the returned int32 reason mask so logs can
distinguish *why* a descent stopped (the IPOP ladder restarts on any reason
except budget exhaustion, which the strategy level handles).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

TOLFUN = 1
TOLFUNHIST = 2
TOLX = 4
CONDITIONCOV = 8
NOEFFECTAXIS = 16
NOEFFECTCOORD = 32
TOLUPSIGMA = 64
MAXITER = 128

REASON_NAMES = {
    TOLFUN: "TolFun", TOLFUNHIST: "TolFunHist", TOLX: "TolX",
    CONDITIONCOV: "ConditionCov", NOEFFECTAXIS: "NoEffectAxis",
    NOEFFECTCOORD: "NoEffectCoord", TOLUPSIGMA: "TolUpSigma",
    MAXITER: "MaxIter",
}


def reason_to_str(mask: int) -> str:
    names = [name for bit, name in REASON_NAMES.items() if mask & bit]
    return "|".join(names) if names else "none"


def check_stop(cfg, params, state, f_sorted: jnp.ndarray) -> jnp.ndarray:
    """Evaluate all criteria on a *post-update* state; returns int32 bitmask."""
    n = cfg.n
    dt = state.m.dtype
    reason = jnp.asarray(0, jnp.int32)

    # -- TolFun: best-f range over the history window AND this generation's
    #    fitness spread both below tolfun (c-cmaes combines them).
    hist_len = cfg.hist_len
    idx = jnp.arange(hist_len)
    window = jnp.minimum(params.hist_window, jnp.minimum(state.hist_count, hist_len))
    # positions of the last `window` entries in the ring buffer
    newest = jnp.mod(state.hist_count - 1, hist_len)
    age = jnp.mod(newest - idx, hist_len)          # 0 = newest
    in_window = age < window
    h = jnp.where(in_window, state.f_hist, jnp.nan)
    hist_range = jnp.nanmax(h) - jnp.nanmin(h)
    lam_idx = jnp.clip(params.lam - 1, 0, f_sorted.shape[0] - 1)
    gen_range = f_sorted[lam_idx] - f_sorted[0]
    enough_hist = state.hist_count >= jnp.minimum(params.hist_window, hist_len)
    tolfun_hit = enough_hist & (jnp.maximum(hist_range, gen_range) < cfg.tolfun)
    reason = reason | jnp.where(tolfun_hit, TOLFUN, 0)

    # -- TolFunHist: history range alone below a tighter threshold.
    tolfunhist_hit = enough_hist & (hist_range < cfg.tolfunhist)
    reason = reason | jnp.where(tolfunhist_hit, TOLFUNHIST, 0)

    # -- TolX: search has shrunk — σ·√C_ii and σ·p_c all tiny vs initial σ.
    tolx = cfg.tolx_factor * params.sigma0
    diagC = jnp.diagonal(state.C)
    tolx_hit = (jnp.all(state.sigma * jnp.sqrt(jnp.maximum(diagC, 0.0)) < tolx)
                & jnp.all(state.sigma * jnp.abs(state.p_c) < tolx))
    reason = reason | jnp.where(tolx_hit, TOLX, 0)

    # -- ConditionCov: covariance ill-conditioned.
    dmax, dmin = jnp.max(state.D), jnp.maximum(jnp.min(state.D), 1e-300)
    cond_hit = (dmax / dmin) ** 2 > cfg.tol_condition
    reason = reason | jnp.where(cond_hit, CONDITIONCOV, 0)

    # -- NoEffectAxis: 0.1σ along principal axis (gen % n) does not move m.
    ax = jnp.mod(state.gen, n)
    axis_step = 0.1 * state.sigma * state.D[ax] * state.B[:, ax]
    noaxis_hit = jnp.all(state.m == state.m + axis_step)
    reason = reason | jnp.where(noaxis_hit, NOEFFECTAXIS, 0)

    # -- NoEffectCoord: 0.2σ√C_ii does not move any single coordinate.
    coord_step = 0.2 * state.sigma * jnp.sqrt(jnp.maximum(diagC, 0.0))
    nocoord_hit = jnp.any(state.m == state.m + coord_step)
    reason = reason | jnp.where(nocoord_hit, NOEFFECTCOORD, 0)

    # -- TolUpSigma: divergence — σ exploded relative to covariance scale.
    upsig_hit = state.sigma / params.sigma0 > cfg.tolupsigma * dmax
    reason = reason | jnp.where(upsig_hit, TOLUPSIGMA, 0)

    # -- MaxIter.
    maxiter_hit = state.gen >= params.max_iter
    reason = reason | jnp.where(maxiter_hit, MAXITER, 0)

    return reason.astype(jnp.int32)


def check_stop_stacked(cfg, sparams, states, f_sorted: jnp.ndarray) -> jnp.ndarray:
    """``check_stop`` over stacked leaves: params/state pytrees carry a leading
    descent axis, ``f_sorted`` is (D, lam_max).  Returns (D,) int32 masks.

    ``cfg`` stays a single static config — the ladder engine (core/ladder.py)
    shares one λ_max-padded config across every rung of the stack.
    """
    return jax.vmap(lambda p, s, fs: check_stop(cfg, p, s, fs))(
        sparams, states, f_sorted)
