"""Core CMA-ES in JAX — GEMM-form linear algebra per the paper (§3.1).

Design notes
------------
* The update is split into ``compute_moments`` (needs the sampled points) and
  ``update_from_moments`` (needs only O(n²) reductions).  This is what lets the
  distributed strategies (core/strategies.py) shard the λ evaluations *and* the
  rank-μ GEMM across a descent's device group: each device reduces its local
  partial Gram matrix ``Σ_local w_i yᵢyᵢᵀ`` and partial ``y_w``, a psum merges
  them, and every device replays the cheap state update identically (SPMD).
* The covariance adaptation uses the paper's eq. (3) rewrite:
      C ← (1 − c₁ − c_μ)·C + c_μ·(Yᵀ·diag(w)·Y) + c₁·p_c p_cᵀ
  i.e. one rank-μ GEMM instead of λ rank-one updates (Level-3 BLAS → MXU).
* The sampling step uses the paper's batched eq. (1):  X = M + σ·(B·diag(D))·Z,
  one (n×n)·(n×λ) GEMM for the whole population.
* All shapes are static; a descent of population λ inside a padded buffer of
  width λ_max carries zero weights / +inf fitnesses for the padding slots, so a
  stack of descents with different population sizes vmaps into one program.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import stopping
from repro.core.params import CMAConfig, CMAParams
from repro.kernels import ops as kops
from repro.kernels import ref as kref


class CMAState(NamedTuple):
    m: jnp.ndarray          # (n,) distribution mean
    sigma: jnp.ndarray      # () step size
    C: jnp.ndarray          # (n, n) covariance
    B: jnp.ndarray          # (n, n) eigenvectors of C (lazy)
    D: jnp.ndarray          # (n,) sqrt of eigenvalues of C (lazy)
    p_sigma: jnp.ndarray    # (n,) evolution path of sigma
    p_c: jnp.ndarray        # (n,) evolution path of C
    gen: jnp.ndarray        # () int32 generation counter
    last_eigen_gen: jnp.ndarray  # () int32
    best_f: jnp.ndarray     # () best fitness seen in this descent
    best_x: jnp.ndarray     # (n,)
    fevals: jnp.ndarray     # () int64-ish counter (int32 is enough here)
    f_hist: jnp.ndarray     # (hist_len,) per-generation best f ring buffer
    hist_count: jnp.ndarray  # () int32 number of valid history entries
    stop: jnp.ndarray       # () bool
    stop_reason: jnp.ndarray  # () int32 bitmask (see core/stopping.py)
    restarts: jnp.ndarray   # () int32 — how many times this slot restarted (IPOP / in-place)


def init_state(cfg: CMAConfig, key: jax.Array, x0: jnp.ndarray,
               sigma0: float | jnp.ndarray | None = None) -> CMAState:
    n, dt = cfg.n, cfg.jdtype
    sigma0 = jnp.asarray(cfg.sigma0 if sigma0 is None else sigma0, dt)
    return CMAState(
        m=jnp.asarray(x0, dt),
        sigma=sigma0,
        C=jnp.eye(n, dtype=dt),
        B=jnp.eye(n, dtype=dt),
        D=jnp.ones((n,), dt),
        p_sigma=jnp.zeros((n,), dt),
        p_c=jnp.zeros((n,), dt),
        gen=jnp.asarray(0, jnp.int32),
        last_eigen_gen=jnp.asarray(0, jnp.int32),
        best_f=jnp.asarray(jnp.inf, dt),
        best_x=jnp.asarray(x0, dt),
        fevals=jnp.asarray(0, jnp.int32),
        f_hist=jnp.full((cfg.hist_len,), jnp.inf, dt),
        hist_count=jnp.asarray(0, jnp.int32),
        stop=jnp.asarray(False),
        stop_reason=jnp.asarray(0, jnp.int32),
        restarts=jnp.asarray(0, jnp.int32),
    )


# ---------------------------------------------------------------------------
# Sampling (paper eq. 1, batched GEMM form)
# ---------------------------------------------------------------------------

def sample_z(state: CMAState, key: jax.Array, lam_slots: int,
             row_keys: bool = True) -> jnp.ndarray:
    """The raw N(0, I) draw behind ``sample_population`` — (lam_slots, n).

    ``row_keys=True`` (the repo-wide key schema) keys each population member
    by ``fold_in(key, row)``, so row i's draw is independent of how many rows
    a program materializes.  That makes the sample stream *prefix-stable
    across padded widths*: a rung-bucketed program padded to λ_bucket < λ_max
    (core/bucketed.py) sees bit-identical points to the λ_max-padded engine
    and to the host-loop baseline on the same (slot, incarnation, generation)
    key — AND pays RNG proportional to its own width instead of λ_max's.
    ``row_keys=False`` keeps the flat counter draw (one block keyed by ``key``),
    whose draw is width-dependent.
    """
    n = state.m.shape[0]
    if row_keys:
        ks = jax.vmap(jax.random.fold_in, (None, 0))(
            key, jnp.arange(lam_slots, dtype=jnp.uint32))
        return jax.vmap(lambda k: jax.random.normal(k, (n,), state.m.dtype))(ks)
    return jax.random.normal(key, (lam_slots, n), dtype=state.m.dtype)


def sample_population(state: CMAState, key: jax.Array, lam_slots: int,
                      impl: str = "xla",
                      row_keys: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sample ``lam_slots`` points.  Returns (Y, X): x_k = m + σ·y_k, y = B·(D∘z).

    ``lam_slots`` is static — strategies call this with the per-device slot
    count.  See ``sample_z`` for the row-keyed draw semantics.
    """
    z = sample_z(state, key, lam_slots, row_keys=row_keys)
    y = kops.sample_transform(state.B, state.D, z, impl=impl)   # (lam, n)
    x = state.m[None, :] + state.sigma * y
    return y, x


# ---------------------------------------------------------------------------
# Moments (what the update actually needs from the population)
# ---------------------------------------------------------------------------

class Moments(NamedTuple):
    y_w: jnp.ndarray        # (n,)  Σ w_rk(i) · yᵢ
    gram: jnp.ndarray       # (n, n)  Σ w_rk(i) · yᵢ yᵢᵀ   (rank-μ GEMM)
    f_sorted: jnp.ndarray   # (lam_max,) ascending, +inf padded
    x_best: jnp.ndarray     # (n,) best point of this generation
    n_evals: jnp.ndarray    # () int32 — valid (non-masked) evaluations


def rank_weights(fitness: jnp.ndarray, params: CMAParams) -> jnp.ndarray:
    """Per-point weights by fitness rank (ascending).  Masked points (+inf) get 0.

    Works for any subset of a descent's population: ``fitness`` may be the full
    λ vector (dense path) or a gathered one (distributed path).
    """
    order = jnp.argsort(fitness)                      # indices of sorted points
    ranks = jnp.argsort(order)                        # rank of each point
    w = params.weights[jnp.clip(ranks, 0, params.weights.shape[0] - 1)]
    return jnp.where(jnp.isfinite(fitness), w, 0.0)


def population_stats(fitness: jnp.ndarray, x: jnp.ndarray, params: CMAParams,
                     lam_max: int):
    """Order statistics shared by the moments and fused paths:
    ``(w, f_sorted, x_best, n_evals)`` — rank weights, the λ_max-padded
    ascending fitness vector, this generation's best point, and the count of
    valid (finite-fitness) evaluations."""
    w = rank_weights(fitness, params)                 # (lam,)
    f_sorted_full = jnp.sort(fitness)
    lam = fitness.shape[0]
    if lam >= lam_max:
        f_sorted = f_sorted_full[:lam_max]
    else:
        f_sorted = jnp.concatenate(
            [f_sorted_full, jnp.full((lam_max - lam,), jnp.inf, fitness.dtype)])
    x_best = x[jnp.argmin(fitness)]
    n_evals = jnp.sum(jnp.isfinite(fitness)).astype(jnp.int32)
    return w, f_sorted, x_best, n_evals


def population_stats_from_y(fitness: jnp.ndarray, y: jnp.ndarray, m, sigma,
                            params: CMAParams, lam_max: int):
    """``population_stats`` for the eval-fused path, where X never
    materialized: the generation's best point is reconstructed from its Y
    row as ``m + σ·y`` — the same algebra that produced every X row, so the
    result is bit-identical to indexing a materialized X."""
    w, f_sorted, _, n_evals = population_stats(
        fitness, jnp.zeros((fitness.shape[0], 1), y.dtype), params, lam_max)
    x_best = m + jnp.asarray(sigma, y.dtype) * y[jnp.argmin(fitness)]
    return w, f_sorted, x_best, n_evals


def compute_moments(y: jnp.ndarray, fitness: jnp.ndarray, x: jnp.ndarray,
                    params: CMAParams, lam_max: int,
                    impl: str = "xla") -> Moments:
    """Dense (single-group) path: full population on one device."""
    w, f_sorted, x_best, n_evals = population_stats(fitness, x, params, lam_max)
    y_w = w @ y                                       # (n,)
    gram = kops.rank_mu_gram(y, w, impl=impl)         # (n, n) == yᵀ diag(w) y
    return Moments(y_w=y_w, gram=gram, f_sorted=f_sorted, x_best=x_best,
                   n_evals=n_evals)


# ---------------------------------------------------------------------------
# State update (replicated O(n²) part)
# ---------------------------------------------------------------------------

def eigen_decompose(C: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(B, D) factorization of a covariance: C = B·diag(D²)·Bᵀ."""
    evals, evecs = jnp.linalg.eigh(C)
    d = jnp.sqrt(jnp.maximum(evals, 1e-300))
    return evecs, d


def update_from_moments(cfg: CMAConfig, params: CMAParams, state: CMAState,
                        mom: Moments, impl: str = "xla",
                        eigen: str = "lazy") -> CMAState:
    """One CMA-ES generation given population moments.  Pure; no masking here.

    ``eigen`` (static) controls the B/D refresh from the new covariance:

    * ``"lazy"``   — ``lax.cond`` on the per-descent cadence counter
      (``gen − last_eigen_gen ≥ cfg.eigen_interval``).  Correct and cheap in
      un-vmapped code, but vmap lowers the cond to a select that executes BOTH
      branches, so every vmapped generation pays the full O(n³) ``eigh``
      regardless of ``eigen_interval``.
    * ``"always"`` — unconditional ``eigh``.  Used by the ladder engine on the
      last generation of each eigen block of its nested scan: exactly one
      batched ``eigh`` per block survives jit+vmap.
    * ``"defer"``  — keep the frozen B/D and leave ``last_eigen_gen``
      untouched; the inner generations of an eigen block.  The covariance C
      itself is always updated — only its factorization is stale.
    """
    n = cfg.n
    dt = state.m.dtype

    y_w, gram = mom.y_w, mom.gram

    # -- step-size path:  p_σ ← (1−c_σ)p_σ + sqrt(c_σ(2−c_σ)μ_eff)·C^{-1/2}·y_w
    c_sig = params.c_sigma
    inv_sqrt_C_yw = state.B @ ((state.B.T @ y_w) / jnp.maximum(state.D, 1e-300))
    p_sigma = (1.0 - c_sig) * state.p_sigma + jnp.sqrt(
        c_sig * (2.0 - c_sig) * params.mu_eff) * inv_sqrt_C_yw
    ps_norm = jnp.linalg.norm(p_sigma)

    gen1 = (state.gen + 1).astype(dt)
    h_sig_denom = jnp.sqrt(1.0 - (1.0 - c_sig) ** (2.0 * gen1))
    h_sigma = (ps_norm / h_sig_denom / params.chi_n
               < 1.4 + 2.0 / (n + 1.0)).astype(dt)

    # -- covariance path ------------------------------------------------------
    c_c = params.c_c
    p_c = (1.0 - c_c) * state.p_c + h_sigma * jnp.sqrt(
        c_c * (2.0 - c_c) * params.mu_eff) * y_w

    # -- covariance adaptation (paper eq. 3 + h_σ correction) -----------------
    c_1, c_mu = params.c_1, params.c_mu
    decay = 1.0 - c_1 - c_mu + (1.0 - h_sigma) * c_1 * c_c * (2.0 - c_c)
    C_new = kops.covariance_combine(state.C, gram, p_c, decay, c_mu, c_1, impl=impl)
    C_new = 0.5 * (C_new + C_new.T)

    return _finish_update(cfg, params, state, mom.f_sorted, mom.x_best,
                          mom.n_evals, C_new, p_sigma, p_c, y_w, eigen)


def _finish_update(cfg: CMAConfig, params: CMAParams, state: CMAState,
                   f_sorted, x_best, n_evals, C_new, p_sigma_new, p_c_new,
                   y_w, eigen: str) -> CMAState:
    """The O(n)/O(1) generation epilogue shared by the unfused (moments) and
    fused (kernels/cma_gen.py) update paths: mean and step-size updates, the
    eigen refresh policy, bookkeeping, and the stopping check.  Everything
    O(n²) already happened in the caller (gram/whiten/covariance)."""
    f_best_gen = f_sorted[0]
    c_sig, d_sig = params.c_sigma, params.d_sigma

    # -- mean ---------------------------------------------------------------
    m_new = state.m + state.sigma * y_w

    # -- step size -----------------------------------------------------------
    ps_norm = jnp.linalg.norm(p_sigma_new)
    sigma_new = state.sigma * jnp.exp((c_sig / d_sig) * (ps_norm / params.chi_n - 1.0))
    # flat-fitness guard (c-cmaes): bump sigma if best equals the ~λ/4-th value
    kth = jnp.clip((params.lam // 4 + 1).astype(jnp.int32), 0,
                   f_sorted.shape[0] - 1)
    flat = f_sorted[0] == f_sorted[kth]
    sigma_new = jnp.where(flat, sigma_new * jnp.exp(0.2 + c_sig / d_sig), sigma_new)

    # -- lazy eigendecomposition ------------------------------------------------
    if eigen == "lazy":
        do_eigen = (state.gen + 1 - state.last_eigen_gen) >= cfg.eigen_interval
        B_new, D_new = jax.lax.cond(
            do_eigen, lambda C: eigen_decompose(C), lambda _: (state.B, state.D),
            C_new)
        last_eigen = jnp.where(do_eigen, state.gen + 1, state.last_eigen_gen)
    elif eigen == "always":
        B_new, D_new = eigen_decompose(C_new)
        last_eigen = state.gen + 1
    elif eigen == "defer":
        B_new, D_new = state.B, state.D
        last_eigen = state.last_eigen_gen
    else:
        raise ValueError(f"unknown eigen mode {eigen!r}")

    # -- bookkeeping -------------------------------------------------------------
    better = f_best_gen < state.best_f
    best_f = jnp.where(better, f_best_gen, state.best_f)
    best_x = jnp.where(better, x_best, state.best_x)
    hist_idx = jnp.mod(state.hist_count, cfg.hist_len)
    f_hist = state.f_hist.at[hist_idx].set(f_best_gen)

    new = CMAState(
        m=m_new, sigma=sigma_new, C=C_new, B=B_new, D=D_new,
        p_sigma=p_sigma_new, p_c=p_c_new,
        gen=state.gen + 1, last_eigen_gen=last_eigen,
        best_f=best_f, best_x=best_x,
        fevals=state.fevals + n_evals,
        f_hist=f_hist, hist_count=state.hist_count + 1,
        stop=state.stop, stop_reason=state.stop_reason,
        restarts=state.restarts,
    )
    reason = stopping.check_stop(cfg, params, new, f_sorted)
    return new._replace(stop=reason > 0, stop_reason=reason)


def gen_coef(params: CMAParams, state: CMAState) -> dict:
    """Per-slot scalar coefficients of the fused update op
    (``kops.gen_update`` / kernels/cma_gen.py).  Works on per-slot params
    and on stacked (S,)-leaved params alike."""
    dt = state.m.dtype
    return {
        "c_sigma": params.c_sigma, "mu_eff": params.mu_eff,
        "c_c": params.c_c, "c_1": params.c_1, "c_mu": params.c_mu,
        "chi_n": params.chi_n, "gen1": (state.gen + 1).astype(dt),
    }


def update_from_population(cfg: CMAConfig, params: CMAParams, state: CMAState,
                           y: jnp.ndarray, fitness: jnp.ndarray,
                           x: jnp.ndarray, impl: str = "auto",
                           eigen: str = "lazy") -> CMAState:
    """One CMA-ES generation straight from the sampled population — the
    FUSED path: the rank-μ gram, weighted mean, evolution paths, covariance
    epilogue and whitened-step GEMV run as one op (``kops.gen_update`` —
    the slot-batched Pallas megakernel on TPU, ``ref.fused_gen_update``'s
    single gram-family dot elsewhere), so C/B/D are read once per
    generation.  Tolerance-equivalent to ``compute_moments`` +
    ``update_from_moments`` (identical arithmetic, different op grouping)."""
    w, f_sorted, x_best, n_evals = population_stats(
        fitness, x, params, fitness.shape[0])
    C_new, p_sigma_new, p_c_new, y_w = kops.gen_update(
        state.C, state.B, state.D, state.p_sigma, state.p_c, y, w,
        gen_coef(params, state), impl=impl)
    return _finish_update(cfg, params, state, f_sorted, x_best, n_evals,
                          C_new, p_sigma_new, p_c_new, y_w, eigen)


def masked_update(cfg: CMAConfig, params: CMAParams, state: CMAState,
                  mom: Moments, impl: str = "xla",
                  eigen: str = "lazy") -> CMAState:
    """Apply the generation update unless the descent already stopped."""
    new = update_from_moments(cfg, params, state, mom, impl=impl, eigen=eigen)
    return jax.tree_util.tree_map(
        lambda old, nw: jnp.where(state.stop, old, nw), state, new)


def masked_update_fused(cfg: CMAConfig, params: CMAParams, state: CMAState,
                        y: jnp.ndarray, fitness: jnp.ndarray, x: jnp.ndarray,
                        impl: str = "auto", eigen: str = "lazy") -> CMAState:
    """Fused-path sibling of ``masked_update`` (population in, state out)."""
    new = update_from_population(cfg, params, state, y, fitness, x,
                                 impl=impl, eigen=eigen)
    return jax.tree_util.tree_map(
        lambda old, nw: jnp.where(state.stop, old, nw), state, new)


def masked_update_from_gram(cfg: CMAConfig, params: CMAParams,
                            state: CMAState, gram, y_w, f_sorted, x_best,
                            n_evals, eigen: str = "lazy") -> CMAState:
    """Generation update from an ALREADY-REDUCED gram family — the
    replicated tail of the cross-device fused path (core/strategies.py):
    each device contributes its √w-factored partial ``Ysᵀ·[Ys | √w]`` dot,
    ONE psum merges the stacked (n, n+1) family, and every device replays
    this identical O(n²) epilogue.  ``gram``/``y_w`` must be normalized to
    unit total weight (both are linear in w, so post-psum renormalization
    by 1/Σw is exactly the per-piece scaling).  ``gram`` must be symmetric
    by construction — true for every caller (the √w partials, their psum,
    and the central-comm einsum all produce bitwise-symmetric grams), so
    the memory-bound ``0.5·(C + Cᵀ)`` repair pass stays dropped exactly as
    in ``ref.fused_gen_update``."""
    c = gen_coef(params, state)
    C_new, p_sigma_new, p_c_new, y_w = kref.fused_update_from_gram(
        state.C, state.B, state.D, state.p_sigma, state.p_c, gram, y_w,
        c["c_sigma"], c["mu_eff"], c["c_c"], c["c_1"], c["c_mu"],
        c["chi_n"], c["gen1"])
    new = _finish_update(cfg, params, state, f_sorted, x_best, n_evals,
                         C_new, p_sigma_new, p_c_new, y_w, eigen)
    return jax.tree_util.tree_map(
        lambda old, nw: jnp.where(state.stop, old, nw), state, new)


# ---------------------------------------------------------------------------
# Dense single-descent step + run loop (paper Alg. 1)
# ---------------------------------------------------------------------------

def step(cfg: CMAConfig, params: CMAParams, state: CMAState,
         fitness_fn: Callable[[jnp.ndarray], jnp.ndarray], key: jax.Array,
         impl: str = "xla") -> CMAState:
    """One full CMA-ES generation on a single device (Alg. 1 lines 4–8).

    Dispatches to the fused update path unless ``impl`` pins the pre-PR-4
    op soup (``"xla_unfused"``) — see kernels/ops.py for the semantics.
    """
    lam = int(params.lam)  # static in the dense path
    if kops.use_fused(impl):
        z = sample_z(state, key, lam)
        y, x = kops.gen_sample(state.m, state.sigma, state.B, state.D, z,
                               impl=impl)
        f = fitness_fn(x)
        return masked_update_fused(cfg, params, state, y, f, x, impl=impl)
    y, x = sample_population(state, key, lam, impl=impl)
    f = fitness_fn(x)
    mom = compute_moments(y, f, x, params, cfg.lam_max, impl=impl)
    return masked_update(cfg, params, state, mom, impl=impl)


def run(cfg: CMAConfig, params: CMAParams, fitness_fn, key: jax.Array,
        x0: jnp.ndarray, sigma0=None, max_gens: int | None = None,
        impl: str = "xla") -> CMAState:
    """Run a descent until a stopping criterion fires (jitted scan)."""
    max_gens = int(max_gens if max_gens is not None else cfg.max_iter)
    key, init_key = jax.random.split(key)
    state = init_state(cfg, init_key, x0, sigma0)

    def body(carry, k):
        st = carry
        st = step(cfg, params, st, fitness_fn, k, impl=impl)
        return st, st.best_f

    keys = jax.random.split(key, max_gens)
    final, _ = jax.lax.scan(body, state, keys)
    return final
