"""CMA-ES strategy parameters (Hansen's defaults, as in the c-cmaes reference code).

All per-descent fields are arrays so a batch of descents with *different* population
sizes can be stacked and vmapped: a descent with population ``lam`` inside a padded
buffer of width ``lam_max`` simply carries zero weights for the padding slots.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np


def _raw_weights(lam: int) -> np.ndarray:
    """Positive recombination weights w_i ∝ ln((λ+1)/2) − ln(i), i = 1..μ, Σw = 1."""
    mu = lam // 2
    w = np.log((lam + 1.0) / 2.0) - np.log(np.arange(1, mu + 1))
    return w / np.sum(w)


def default_max_iter(n: int, lam: int) -> int:
    """Default per-descent generation allowance (evaluation budget usually
    stops a run first).  Single source of truth — the ladder engine sizes its
    scan from the same formula (core/ladder.py)."""
    return 100 + int(3000 * n / lam)


@dataclasses.dataclass(frozen=True)
class CMAConfig:
    """Static (Python-level) configuration of a CMA-ES run."""

    n: int                      # problem dimension
    lam: int                    # population size (lambda)
    sigma0: float = 0.25        # initial step size (caller scales by search width)
    lam_max: Optional[int] = None   # padded population width for stacked descents
    hist_len: int = 64          # ring-buffer length for TolFun / stagnation
    eigen_interval: Optional[int] = None  # generations between eigendecompositions
    tolfun: float = 1e-12
    tolfunhist: float = 1e-13
    tolx_factor: float = 1e-11  # TolX = tolx_factor * sigma0
    tol_condition: float = 1e14
    tolupsigma: float = 1e20
    max_iter: Optional[int] = None
    dtype: str = "float64"

    def __post_init__(self):
        # remember whether max_iter was derived so make_params can re-derive
        # it per population size when building a stacked ladder (core/ladder.py)
        object.__setattr__(self, "max_iter_auto", self.max_iter is None)
        if self.lam_max is None:
            object.__setattr__(self, "lam_max", self.lam)
        if self.eigen_interval is None:
            # c-cmaes: update the eigensystem when gen - last > 1/(c1+cmu)/n/10.
            w = _raw_weights(self.lam)
            mu_eff = float(1.0 / np.sum(w ** 2))
            c_1 = 2.0 / ((self.n + 1.3) ** 2 + mu_eff)
            c_mu = min(
                1.0 - c_1,
                2.0 * (mu_eff - 2.0 + 1.0 / mu_eff) / ((self.n + 2.0) ** 2 + mu_eff),
            )
            interval = max(1, int(1.0 / ((c_1 + c_mu) * self.n * 10.0)))
            object.__setattr__(self, "eigen_interval", interval)
        if self.max_iter is None:
            object.__setattr__(self, "max_iter",
                               default_max_iter(self.n, self.lam))

    @property
    def jdtype(self) -> jnp.dtype:
        return jnp.dtype(self.dtype)


class CMAParams(NamedTuple):
    """Per-descent strategy parameters (a pytree of arrays — stackable / vmappable).

    ``weights`` has width ``lam_max``; entries beyond μ (and beyond ``lam``) are zero,
    so the same update code handles heterogeneous population sizes.
    """

    lam: jnp.ndarray        # () int32 — actual population size of this descent
    weights: jnp.ndarray    # (lam_max,) — rank-indexed recombination weights, Σ = 1
    mu: jnp.ndarray         # () int32
    mu_eff: jnp.ndarray     # ()
    c_sigma: jnp.ndarray    # ()
    d_sigma: jnp.ndarray    # ()
    c_c: jnp.ndarray        # ()
    c_1: jnp.ndarray        # ()
    c_mu: jnp.ndarray       # ()
    chi_n: jnp.ndarray      # () E||N(0,I)||
    sigma0: jnp.ndarray     # ()
    hist_window: jnp.ndarray  # () int32 — effective TolFun window = min(hist_len, 10+30n/λ)
    max_iter: jnp.ndarray   # () int32


def make_params(cfg: CMAConfig, lam: Optional[int] = None) -> CMAParams:
    """Build CMAParams for a descent of population ``lam`` padded to ``cfg.lam_max``."""
    lam = int(lam if lam is not None else cfg.lam)
    if lam > cfg.lam_max:
        raise ValueError(f"lam={lam} exceeds lam_max={cfg.lam_max}")
    n = cfg.n
    dt = cfg.jdtype
    mu = lam // 2
    w = np.zeros(cfg.lam_max, dtype=np.float64)
    w[:mu] = _raw_weights(lam)
    mu_eff = 1.0 / np.sum(w ** 2)
    c_sigma = (mu_eff + 2.0) / (n + mu_eff + 5.0)
    d_sigma = 1.0 + 2.0 * max(0.0, np.sqrt((mu_eff - 1.0) / (n + 1.0)) - 1.0) + c_sigma
    c_c = (4.0 + mu_eff / n) / (n + 4.0 + 2.0 * mu_eff / n)
    c_1 = 2.0 / ((n + 1.3) ** 2 + mu_eff)
    c_mu = min(1.0 - c_1, 2.0 * (mu_eff - 2.0 + 1.0 / mu_eff) / ((n + 2.0) ** 2 + mu_eff))
    chi_n = np.sqrt(n) * (1.0 - 1.0 / (4.0 * n) + 1.0 / (21.0 * n ** 2))
    hist_window = min(cfg.hist_len, 10 + int(np.ceil(30.0 * n / lam)))
    if lam != cfg.lam and getattr(cfg, "max_iter_auto", False):
        # per-descent budget: a small-λ rung of a stacked ladder gets the
        # generation allowance its own population size implies
        max_iter = default_max_iter(n, lam)
    else:
        max_iter = cfg.max_iter
    return CMAParams(
        lam=jnp.asarray(lam, jnp.int32),
        weights=jnp.asarray(w, dt),
        mu=jnp.asarray(mu, jnp.int32),
        mu_eff=jnp.asarray(mu_eff, dt),
        c_sigma=jnp.asarray(c_sigma, dt),
        d_sigma=jnp.asarray(d_sigma, dt),
        c_c=jnp.asarray(c_c, dt),
        c_1=jnp.asarray(c_1, dt),
        c_mu=jnp.asarray(c_mu, dt),
        chi_n=jnp.asarray(chi_n, dt),
        sigma0=jnp.asarray(cfg.sigma0, dt),
        hist_window=jnp.asarray(hist_window, jnp.int32),
        max_iter=jnp.asarray(max_iter, jnp.int32),
    )


def stack_params(params: list[CMAParams]) -> CMAParams:
    """Stack per-descent params along a leading descent axis (for vmap)."""
    import jax
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *params)


def ladder_params(cfg: CMAConfig, lam_start: int, kmax_exp: int) -> CMAParams:
    """Stacked params for the IPOP ladder: rung k has λ = 2ᵏ·lam_start.

    All rungs share ``cfg`` (and its λ_max padding); the result's leaves have a
    leading (kmax_exp+1,) rung axis, so a traced rung index can gather a
    descent's parameters on device (``select_params``) — the mechanism behind
    the in-place doubled-λ restarts in core/ladder.py.
    """
    return stack_params([make_params(cfg, lam=(2 ** k) * lam_start)
                         for k in range(kmax_exp + 1)])


def bucket_config(cfg: CMAConfig, lam_bucket: int) -> CMAConfig:
    """Narrow a full-ladder config to one rung bucket's padding width.

    Everything that shapes the *trajectory* — tolerances, history length,
    eigen cadence, per-rung iteration allowances — is inherited verbatim from
    the λ_max-padded config; only the padded population width changes.  This
    is what lets the rung-bucketed programs (core/bucketed.py) reproduce the
    padded engine's arithmetic exactly while sampling/evaluating/Gram-reducing
    λ_bucket instead of λ_max points.
    """
    if lam_bucket > cfg.lam_max:
        raise ValueError(f"lam_bucket={lam_bucket} exceeds lam_max={cfg.lam_max}")
    # dataclasses.replace keeps every *other* field verbatim — including any
    # added later — so bucket programs can never silently drift from the
    # full config's trajectory knobs.  max_iter=None re-derives the auto
    # allowance for the bucket's own λ in __post_init__.
    return dataclasses.replace(
        cfg, lam=lam_bucket, lam_max=lam_bucket,
        max_iter=None if getattr(cfg, "max_iter_auto", False) else cfg.max_iter)


def select_params(sparams: CMAParams, idx) -> CMAParams:
    """Gather one rung's params from a stacked ladder by (possibly traced) index."""
    import jax
    return jax.tree_util.tree_map(lambda a: a[idx], sparams)
