from repro.fitness import bbob, surrogates  # noqa: F401
