"""Neural-network loss as a blackbox objective for IPOP-CMA-ES.

The paper motivates expensive evaluations with NN training (§4.1 cites
5–30 min/eval [40]); this module makes that concrete on the repo's own LM
substrate: a low-dimensional vector θ ∈ Rⁿ parameterizes an *adapter* on a
frozen model (per-layer output gains + a rank-1 logit bias), and the fitness
is the validation cross-entropy of the adapted model on a fixed batch.

This is the supported CMA-ES ↔ LM integration (DESIGN.md §5): full-weight
CMA-ES is structurally inapplicable (O(n²) covariance for n ≥ 5·10⁸), so the
ES optimizes a projection — the standard practice the paper's own
large-scale-variant discussion points to.

The returned fitness function evaluates a *batch* of candidate vectors
(lam, n) → (lam,), exactly the interface the parallel strategies shard across
the mesh: an ES population of adapter candidates evaluates data-parallel,
one candidate per device group, reproducing the paper's evaluation
parallelism with real NN workloads.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm


@dataclasses.dataclass(frozen=True)
class AdapterSpace:
    """θ layout: [layer_gains (n_scales) | logit_scale (1) | embed_gain (1)]."""
    cfg: ModelConfig
    n_scales: int

    @property
    def dim(self) -> int:
        return self.n_scales + 2


def adapter_space(cfg: ModelConfig) -> AdapterSpace:
    return AdapterSpace(cfg=cfg, n_scales=cfg.n_layers)


def _apply_adapter(space: AdapterSpace, params: dict, theta: jnp.ndarray):
    """Scale the stacked layer outputs' wo/out_proj leaves by (1 + g_l)."""
    cfg = space.cfg
    gains = theta[: space.n_scales]

    def scale_stacked(leaf, lead_dims: int):
        # leaf has one or two leading stack dims; broadcast per-layer gains
        n_lead = leaf.shape[0]
        g = gains[: n_lead]
        g = (1.0 + 0.1 * g).astype(leaf.dtype)
        return leaf * g.reshape((n_lead,) + (1,) * (leaf.ndim - 1))

    p2 = jax.tree_util.tree_map(lambda x: x, params)    # shallow copy tree
    seg = dict(p2["segments"])
    unit = seg["unit"]

    def walk_scale(tree):
        if isinstance(tree, dict):
            return {k: (walk_scale(v) if k not in ("wo", "out_proj")
                        else scale_stacked(v, 1)) for k, v in tree.items()}
        return tree

    seg["unit"] = walk_scale(unit)
    p2["segments"] = seg
    return p2, theta[space.n_scales], theta[space.n_scales + 1]


def make_nn_fitness(cfg: ModelConfig, params: dict, batch: dict
                    ) -> tuple[Callable, AdapterSpace]:
    """Returns (fitness(X (lam, dim)) → (lam,), space)."""
    space = adapter_space(cfg)

    def eval_one(theta):
        p2, logit_scale, embed_gain = _apply_adapter(space, params, theta)
        b2 = dict(batch)
        hidden, _ = lm.forward(cfg, p2, b2)
        hidden = hidden * (1.0 + 0.1 * embed_gain).astype(hidden.dtype)
        ce = lm.chunked_ce(cfg, p2, hidden, batch["labels"])
        return ce * (1.0 + 0.01 * jnp.tanh(logit_scale))

    def fitness(X):
        return jax.lax.map(eval_one, X.astype(jnp.float32))

    return fitness, space
