"""Evaluation-cost shaping (paper §4.1: artificial additional costs).

The paper adds 1/10/100 ms to every BBOB evaluation to emulate expensive
real-world blackboxes (CFD, NN training, docking …) and shows the parallel
strategies' speedups grow with evaluation granularity (Table 2, Fig. 6).

On TPU we model cost two ways:
  * ``with_flops_cost``   — really burns device FLOPs inside the evaluation
    (a dependency-carried matmul chain that XLA cannot DCE), used by the
    benchmarks to reproduce the granularity sweep on hardware;
  * ``CostModel``         — an analytic per-evaluation cost used by the
    parallel-time model (benchmarks/parallel_time.py) so ERT-vs-wallclock
    tables can be produced deterministically on this CPU-only container.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def with_flops_cost(fitness_fn: Callable, extra_flops: float,
                    width: int = 64) -> Callable:
    """Wrap a fitness fn so each evaluation burns ~extra_flops device FLOPs.

    The filler is a chained (width×width) matmul loop seeded from the input,
    whose result is folded back at ~1e-300 scale: numerically negligible,
    structurally un-removable.
    """
    if extra_flops <= 0:
        return fitness_fn
    iters = max(1, int(extra_flops / (2 * width ** 3)))

    def wrapped(X):
        f = fitness_fn(X)

        def burn_one(x):
            a = jnp.ones((width, width), X.dtype) * (1.0 + 1e-12 * x[0])

            def body(_, m):
                return m @ a * (1.0 / jnp.maximum(jnp.max(jnp.abs(m)), 1e-30))

            m = jax.lax.fori_loop(0, iters, body, a)
            return m[0, 0]

        junk = jax.vmap(burn_one)(jnp.atleast_2d(X))
        return f + 0.0 * junk

    return wrapped


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Analytic per-generation timing for the parallel-time model.

    Mirrors the paper's accounting: an iteration of a descent with population
    λ on ``devices`` devices costs
        t_iter = ceil(λ / (devices·slots)) · t_eval  +  t_linalg(λ, n)  +  t_comm
    In the paper's K-Distributed layout λ == devices·slots so the first term
    is exactly t_eval (perfect evaluation parallelism, §3.2.1).
    """

    eval_cost_s: float = 0.0        # the paper's "additional cost" knob
    base_eval_s: float = 1e-5       # intrinsic BBOB evaluation cost
    linalg_flops_per_s: float = 5e10  # per-device effective linalg throughput
    comm_s: float = 2e-5            # per-generation collective latency

    def t_eval(self) -> float:
        return self.base_eval_s + self.eval_cost_s

    def t_linalg(self, lam: int, n: int, distributed_over: int = 1) -> float:
        # sampling GEMM (λn²) + rank-μ GEMM (λn²/2·…) + amortized eigh (n³ / interval)
        gemm = 2.0 * 2.0 * lam * n * n / distributed_over
        eigh = 10.0 * n ** 3 * min(1.0, lam / max(n, 1) / 10.0)
        return (gemm + eigh) / self.linalg_flops_per_s

    def t_iter(self, lam: int, n: int, devices: int, slots_per_device: int = 1,
               distributed_linalg: bool = True) -> float:
        waves = -(-lam // max(1, devices * slots_per_device))
        linalg = self.t_linalg(lam, n, devices if distributed_linalg else 1)
        return waves * self.t_eval() + linalg + self.comm_s
