"""BBOB noiseless test suite (f1–f24) in JAX.

Faithful to the function definitions of Hansen, Finck, Ros & Auger,
"Real-Parameter Black-Box Optimization Benchmarking 2009: Noiseless Functions
Definitions" (RR-6829, INRIA) — the benchmark the paper evaluates on.

Instances are seeded (x_opt, rotations R/Q, Gallagher peak sets are drawn from
a PRNG keyed by (fid, dim, instance)); they follow the published definitions
but are not bit-identical to COCO's instance-id derivation (DESIGN.md §8.3).

Every evaluator is pure jnp over a batch: ``evaluate(fid, inst, X) -> (batch,)``
so it jit/vmap/shard_maps cleanly — this is what the strategies shard across
the mesh (the paper's 'scatter the λ evaluations', §3.2.1).
"""
from __future__ import annotations

import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

SEARCH_DOMAIN = (-5.0, 5.0)

#: fids whose evaluation is fully separable AFTER the x_opt shift — i.e.
#: expressible as Σᵢ scaleᵢ·g(xᵢ − shiftᵢ)² with an elementwise g — and
#: therefore fusable into the sample kernel's epilogue (f(X) computed while
#: X is still in registers; X never stored).  f1 sphere (g = identity) and
#: f2 ellipsoid (g = t_osz, the f10-style 10^{6i/(n−1)} conditioning
#: WITHOUT f10's rotation).  Rotated fids (f10 itself: `@ R.T`) are not
#: separable and take the dispatched two-program path.
FUSABLE_FIDS = (1, 2)

GROUPS = {  # paper §4.1: the five BBOB difficulty groups
    "separable": (1, 2, 3, 4, 5),
    "low_conditioning": (6, 7, 8, 9),
    "high_conditioning": (10, 11, 12, 13, 14),
    "multimodal_adequate": (15, 16, 17, 18, 19),
    "multimodal_weak": (20, 21, 22, 23, 24),
}

NAMES = {
    1: "Sphere", 2: "Ellipsoidal", 3: "Rastrigin", 4: "BucheRastrigin",
    5: "LinearSlope", 6: "AttractiveSector", 7: "StepEllipsoidal",
    8: "Rosenbrock", 9: "RosenbrockRotated", 10: "EllipsoidalRotated",
    11: "Discus", 12: "BentCigar", 13: "SharpRidge", 14: "DifferentPowers",
    15: "RastriginRotated", 16: "Weierstrass", 17: "SchaffersF7",
    18: "SchaffersF7Ill", 19: "GriewankRosenbrock", 20: "Schwefel",
    21: "Gallagher101", 22: "Gallagher21", 23: "Katsuura", 24: "LunacekBiRastrigin",
}


class BBOBInstance(NamedTuple):
    fid: jnp.ndarray      # () int32 (informational)
    x_opt: jnp.ndarray    # (n,) location encoding of the optimum (see per-f use)
    f_opt: jnp.ndarray    # ()
    R: jnp.ndarray        # (n, n) orthogonal
    Q: jnp.ndarray        # (n, n) orthogonal
    peaks_y: jnp.ndarray  # (m, n) Gallagher peak locations (else (1, n) zeros)
    peaks_w: jnp.ndarray  # (m,)
    peaks_c: jnp.ndarray  # (m, n) per-peak diagonal scalings (already permuted)


# ---------------------------------------------------------------------------
# transforms (RR-6829 §0)
# ---------------------------------------------------------------------------

def t_osz(x):
    xhat = jnp.where(x != 0.0, jnp.log(jnp.abs(jnp.where(x != 0.0, x, 1.0))), 0.0)
    c1 = jnp.where(x > 0.0, 10.0, 5.5)
    c2 = jnp.where(x > 0.0, 7.9, 3.1)
    return jnp.sign(x) * jnp.exp(
        xhat + 0.049 * (jnp.sin(c1 * xhat) + jnp.sin(c2 * xhat)))


def t_asy(x, beta):
    n = x.shape[-1]
    idx = jnp.arange(n) / jnp.maximum(n - 1.0, 1.0)
    expo = 1.0 + beta * idx * jnp.sqrt(jnp.maximum(x, 0.0))
    return jnp.where(x > 0.0, jnp.maximum(x, 0.0) ** expo, x)


def lam_alpha(alpha, n, dtype=jnp.float64):
    idx = jnp.arange(n, dtype=dtype) / jnp.maximum(n - 1.0, 1.0)
    return jnp.asarray(alpha, dtype) ** (0.5 * idx)


def f_pen(x):
    return jnp.sum(jnp.maximum(0.0, jnp.abs(x) - 5.0) ** 2, axis=-1)


def _orth(key, n, dtype=jnp.float64):
    a = jax.random.normal(key, (n, n), dtype)
    q, r = jnp.linalg.qr(a)
    return q * jnp.sign(jnp.diagonal(r))[None, :]


# ---------------------------------------------------------------------------
# instance factory
# ---------------------------------------------------------------------------

def make_instance(fid: int, n: int, instance: int = 0,
                  dtype=jnp.float64) -> BBOBInstance:
    key = jax.random.PRNGKey(np.uint32(1_000_003 * fid + 97 * n + instance))
    k_xopt, k_fopt, k_R, k_Q, k_peaks, k_w, k_alpha, k_sign = jax.random.split(key, 8)

    x_opt = jax.random.uniform(k_xopt, (n,), dtype, -4.0, 4.0)
    if fid == 5:       # optimum at a ±5 corner
        x_opt = 5.0 * jnp.sign(jax.random.normal(k_sign, (n,), dtype) + 1e-12)
    elif fid == 20:    # x_opt = 4.2096874633/2 · ±1
        x_opt = (4.2096874633 / 2.0) * jnp.sign(
            jax.random.normal(k_sign, (n,), dtype) + 1e-12)
    elif fid == 24:    # x_opt = (μ0/2)·±1
        x_opt = (2.5 / 2.0) * jnp.sign(
            jax.random.normal(k_sign, (n,), dtype) + 1e-12)
    elif fid in (8,):  # plain Rosenbrock: x_opt free in [-3, 3] (z=1 shift)
        x_opt = jax.random.uniform(k_xopt, (n,), dtype, -3.0, 3.0)

    f_opt = jnp.round(jax.random.uniform(k_fopt, (), dtype, -100.0, 100.0), 2)
    R = _orth(k_R, n, dtype)
    Q = _orth(k_Q, n, dtype)

    if fid == 9:       # optimum implied by z = c·R·x + 1/2 == 1
        c = max(1.0, np.sqrt(n) / 8.0)
        x_opt = R.T @ (jnp.full((n,), 0.5 / c, dtype))
    elif fid == 19:    # z = c·R·x + 0.5 == 1
        c = max(1.0, np.sqrt(n) / 8.0)
        x_opt = R.T @ (jnp.full((n,), 0.5 / c, dtype))

    # Gallagher peak sets (f21: 101 peaks, f22: 21 peaks)
    if fid in (21, 22):
        m = 101 if fid == 21 else 21
        span = 4.0 if fid == 21 else 3.92
        base = 1000.0 if fid == 21 else 1000.0 ** 2
        y = jax.random.uniform(k_peaks, (m, n), dtype, -4.9, 4.9)
        y = y.at[0].set(jax.random.uniform(k_xopt, (n,), dtype, -span, span))
        x_opt = y[0]
        w = jnp.concatenate([
            jnp.asarray([10.0], dtype),
            1.1 + 8.0 * jnp.arange(m - 1, dtype=dtype) / (m - 2.0),
        ])
        # per-peak condition numbers: random permutation of 1000^{2j/(m-2)}
        j = jax.random.permutation(k_alpha, m - 1)
        alphas = jnp.concatenate([
            jnp.asarray([base], dtype),
            1000.0 ** (2.0 * j.astype(dtype) / jnp.maximum(m - 2.0, 1.0)),
        ])
        idx = jnp.arange(n, dtype=dtype) / jnp.maximum(n - 1.0, 1.0)
        diag = alphas[:, None] ** (0.5 * idx[None, :]) / (alphas[:, None] ** 0.25)
        peaks_y, peaks_w, peaks_c = y, w, diag
    else:
        peaks_y = jnp.zeros((1, n), dtype)
        peaks_w = jnp.zeros((1,), dtype)
        peaks_c = jnp.ones((1, n), dtype)

    return BBOBInstance(
        fid=jnp.asarray(fid, jnp.int32), x_opt=x_opt, f_opt=f_opt, R=R, Q=Q,
        peaks_y=peaks_y, peaks_w=peaks_w, peaks_c=peaks_c)


# ---------------------------------------------------------------------------
# the 24 functions — X: (batch, n) → (batch,) raw value; f_opt added by caller
# ---------------------------------------------------------------------------

def _f01(inst, X):
    z = X - inst.x_opt
    return jnp.sum(z ** 2, -1)


def _ell_scale(n: int, dtype) -> jnp.ndarray:
    """The ellipsoid axis weights 10^(6·i/(n−1)), host-computed so the SAME
    literal constant is embedded in every program that needs them.  (XLA's
    compiled/folded ``pow`` differs from the eager one by ulps; sharing the
    literal is what makes the eval-fused f2 bit-identical to the dispatched
    ``_f02``.)"""
    return jnp.asarray(
        np.power(10.0, 6.0 * np.arange(n) / max(n - 1.0, 1.0)), dtype)


def _f02(inst, X):
    n = X.shape[-1]
    z = t_osz(X - inst.x_opt)
    return jnp.sum(_ell_scale(n, X.dtype) * z ** 2, -1)


def _f03(inst, X):
    n = X.shape[-1]
    z = lam_alpha(10.0, n, X.dtype) * t_asy(t_osz(X - inst.x_opt), 0.2)
    return 10.0 * (n - jnp.sum(jnp.cos(2 * jnp.pi * z), -1)) + jnp.sum(z ** 2, -1)


def _f04(inst, X):
    n = X.shape[-1]
    t = t_osz(X - inst.x_opt)
    s = 10.0 ** (0.5 * jnp.arange(n) / max(n - 1.0, 1.0))
    odd = (jnp.arange(n) % 2) == 0      # 1-based odd indices
    s = jnp.where(odd & (t > 0), 10.0 * s, s)
    z = s * t
    return (10.0 * (n - jnp.sum(jnp.cos(2 * jnp.pi * z), -1))
            + jnp.sum(z ** 2, -1) + 100.0 * f_pen(X))


def _f05(inst, X):
    n = X.shape[-1]
    s = jnp.sign(inst.x_opt) * 10.0 ** (jnp.arange(n) / max(n - 1.0, 1.0))
    z = jnp.where(X * inst.x_opt < 25.0, X, inst.x_opt)
    return jnp.sum(5.0 * jnp.abs(s) - s * z, -1)


def _f06(inst, X):
    z = (X - inst.x_opt) @ inst.R.T * lam_alpha(10.0, X.shape[-1], X.dtype)
    z = z @ inst.Q.T
    # sector: s_i = 100 where z_i·x_opt_i > 0 (RR-6829 uses raw x_opt_i)
    s = jnp.where(z * inst.x_opt > 0, 100.0, 1.0)
    val = jnp.sum((s * z) ** 2, -1)
    return t_osz(val) ** 0.9


def _f07(inst, X):
    n = X.shape[-1]
    zhat = (X - inst.x_opt) @ inst.R.T * lam_alpha(10.0, n, X.dtype)
    ztil = jnp.where(jnp.abs(zhat) > 0.5,
                     jnp.floor(0.5 + zhat),
                     jnp.floor(0.5 + 10.0 * zhat) / 10.0)
    z = ztil @ inst.Q.T
    scale = 10.0 ** (2.0 * jnp.arange(n) / max(n - 1.0, 1.0))
    body = 0.1 * jnp.maximum(jnp.abs(zhat[..., 0]) / 1e4,
                             jnp.sum(scale * z ** 2, -1))
    return body + f_pen(X)


def _f08(inst, X):
    n = X.shape[-1]
    c = max(1.0, np.sqrt(n) / 8.0)
    z = c * (X - inst.x_opt) + 1.0
    return jnp.sum(100.0 * (z[..., :-1] ** 2 - z[..., 1:]) ** 2
                   + (z[..., :-1] - 1.0) ** 2, -1)


def _f09(inst, X):
    n = X.shape[-1]
    c = max(1.0, np.sqrt(n) / 8.0)
    z = c * (X @ inst.R.T) + 0.5
    return jnp.sum(100.0 * (z[..., :-1] ** 2 - z[..., 1:]) ** 2
                   + (z[..., :-1] - 1.0) ** 2, -1)


def _f10(inst, X):
    n = X.shape[-1]
    z = t_osz((X - inst.x_opt) @ inst.R.T)
    scale = 10.0 ** (6.0 * jnp.arange(n) / max(n - 1.0, 1.0))
    return jnp.sum(scale * z ** 2, -1)


def _f11(inst, X):
    z = t_osz((X - inst.x_opt) @ inst.R.T)
    return 1e6 * z[..., 0] ** 2 + jnp.sum(z[..., 1:] ** 2, -1)


def _f12(inst, X):
    z = t_asy((X - inst.x_opt) @ inst.R.T, 0.5) @ inst.R.T
    return z[..., 0] ** 2 + 1e6 * jnp.sum(z[..., 1:] ** 2, -1)


def _f13(inst, X):
    z = ((X - inst.x_opt) @ inst.R.T * lam_alpha(10.0, X.shape[-1], X.dtype)) @ inst.Q.T
    return z[..., 0] ** 2 + 100.0 * jnp.sqrt(jnp.sum(z[..., 1:] ** 2, -1))


def _f14(inst, X):
    n = X.shape[-1]
    z = (X - inst.x_opt) @ inst.R.T
    expo = 2.0 + 4.0 * jnp.arange(n) / max(n - 1.0, 1.0)
    return jnp.sqrt(jnp.sum(jnp.abs(z) ** expo, -1))


def _f15(inst, X):
    n = X.shape[-1]
    z = t_asy(t_osz((X - inst.x_opt) @ inst.R.T), 0.2) @ inst.Q.T
    z = (z * lam_alpha(10.0, n, X.dtype)) @ inst.R.T
    return 10.0 * (n - jnp.sum(jnp.cos(2 * jnp.pi * z), -1)) + jnp.sum(z ** 2, -1)


def _f16(inst, X):
    n = X.shape[-1]
    z = t_osz((X - inst.x_opt) @ inst.R.T) @ inst.Q.T
    z = (z * lam_alpha(0.01, n, X.dtype)) @ inst.R.T
    k = jnp.arange(12, dtype=X.dtype)
    halfk = 0.5 ** k
    threek = 3.0 ** k
    f0 = jnp.sum(halfk * jnp.cos(jnp.pi * threek))
    inner = jnp.sum(halfk[None, None, :] * jnp.cos(
        2 * jnp.pi * threek[None, None, :] * (z[..., None] + 0.5)), -1)
    return 10.0 * (jnp.mean(inner, -1) - f0) ** 3 + (10.0 / n) * f_pen(X)


def _schaffers(inst, X, alpha):
    n = X.shape[-1]
    z = t_asy((X - inst.x_opt) @ inst.R.T, 0.5) @ inst.Q.T
    z = z * lam_alpha(alpha, n, X.dtype)
    s = jnp.sqrt(z[..., :-1] ** 2 + z[..., 1:] ** 2)
    val = jnp.mean(jnp.sqrt(s) * (1.0 + jnp.sin(50.0 * s ** 0.2) ** 2), -1) ** 2
    return val + 10.0 * f_pen(X)


def _f17(inst, X):
    return _schaffers(inst, X, 10.0)


def _f18(inst, X):
    return _schaffers(inst, X, 1000.0)


def _f19(inst, X):
    n = X.shape[-1]
    c = max(1.0, np.sqrt(n) / 8.0)
    z = c * (X @ inst.R.T) + 0.5
    s = 100.0 * (z[..., :-1] ** 2 - z[..., 1:]) ** 2 + (z[..., :-1] - 1.0) ** 2
    return (10.0 / (n - 1.0)) * jnp.sum(s / 4000.0 - jnp.cos(s), -1) + 10.0


def _f20(inst, X):
    n = X.shape[-1]
    ones_pm = 2.0 * jnp.sign(inst.x_opt)     # ±2 pattern from x_opt signs
    xhat = ones_pm * X
    xo = 2.0 * jnp.abs(inst.x_opt)
    zhat = jnp.concatenate([
        xhat[..., :1],
        xhat[..., 1:] + 0.25 * (xhat[..., :-1] - xo[:-1]),
    ], -1)
    z = 100.0 * (lam_alpha(10.0, n, X.dtype) * (zhat - xo) + xo)
    body = -jnp.mean(z * jnp.sin(jnp.sqrt(jnp.abs(z))), -1) / 100.0
    return body + 4.189828872724339 + 100.0 * f_pen(z / 100.0)


def _gallagher(inst, X):
    n = X.shape[-1]
    d = (X @ inst.R.T)[:, None, :] - (inst.peaks_y @ inst.R.T)[None, :, :]
    quad = jnp.sum(d * d * inst.peaks_c[None, :, :], -1)      # (batch, m)
    vals = inst.peaks_w[None, :] * jnp.exp(-quad / (2.0 * n))
    best = jnp.max(vals, -1)
    return t_osz(10.0 - best) ** 2 + f_pen(X)


def _f21(inst, X):
    return _gallagher(inst, X)


def _f22(inst, X):
    return _gallagher(inst, X)


def _f23(inst, X):
    n = X.shape[-1]
    z = ((X - inst.x_opt) @ inst.R.T * lam_alpha(100.0, n, X.dtype)) @ inst.Q.T
    j = 2.0 ** jnp.arange(1, 33, dtype=X.dtype)
    zj = z[..., None] * j                                  # (batch, n, 32)
    frac = jnp.abs(zj - jnp.round(zj)) / j
    inner = 1.0 + (jnp.arange(1, n + 1, dtype=X.dtype))[None, :] * jnp.sum(frac, -1)
    prod = jnp.prod(inner ** (10.0 / n ** 1.2), -1)
    return (10.0 / n ** 2) * prod - 10.0 / n ** 2 + f_pen(X)


def _f24(inst, X):
    n = X.shape[-1]
    mu0 = 2.5
    s = 1.0 - 1.0 / (2.0 * np.sqrt(n + 20.0) - 8.2)
    mu1 = -np.sqrt((mu0 ** 2 - 1.0) / s)
    xhat = 2.0 * jnp.sign(inst.x_opt) * X
    z = ((xhat - mu0) @ inst.R.T * lam_alpha(100.0, n, X.dtype)) @ inst.Q.T
    term1 = jnp.sum((xhat - mu0) ** 2, -1)
    term2 = n + s * jnp.sum((xhat - mu1) ** 2, -1)
    ras = 10.0 * (n - jnp.sum(jnp.cos(2 * jnp.pi * z), -1))
    return jnp.minimum(term1, term2) + ras + 1e4 * f_pen(X)


_EVALS = {1: _f01, 2: _f02, 3: _f03, 4: _f04, 5: _f05, 6: _f06, 7: _f07,
          8: _f08, 9: _f09, 10: _f10, 11: _f11, 12: _f12, 13: _f13, 14: _f14,
          15: _f15, 16: _f16, 17: _f17, 18: _f18, 19: _f19, 20: _f20,
          21: _f21, 22: _f22, 23: _f23, 24: _f24}


def evaluate(fid: int, inst: BBOBInstance, X: jnp.ndarray) -> jnp.ndarray:
    """Batch evaluation f(X) (absolute value, i.e. f_opt included)."""
    X = jnp.atleast_2d(X)
    return _EVALS[fid](inst, X) + inst.f_opt


def make_fitness(fid: int, n: int, instance: int = 0, dtype=jnp.float64):
    """Returns (fitness_fn, inst): fitness_fn(X) -> (batch,) closed over inst."""
    inst = make_instance(fid, n, instance, dtype)
    def fn(X):
        return evaluate(fid, inst, X)
    return fn, inst


# ---------------------------------------------------------------------------
# stacked campaigns — traced-fid dispatch over a batch of instances
# ---------------------------------------------------------------------------

def pad_instance(inst: BBOBInstance, m_max: int) -> BBOBInstance:
    """Pad the Gallagher peak set to ``m_max`` rows so heterogeneous instances
    stack into one pytree.  Padding peaks carry weight 0 and therefore never
    win the max in ``_gallagher`` (real peaks have weight ≥ 1.1)."""
    m, n = inst.peaks_y.shape
    if m >= m_max:
        return inst
    pad = m_max - m
    dt = inst.peaks_y.dtype
    return inst._replace(
        peaks_y=jnp.concatenate([inst.peaks_y, jnp.zeros((pad, n), dt)]),
        peaks_w=jnp.concatenate([inst.peaks_w, jnp.zeros((pad,), dt)]),
        peaks_c=jnp.concatenate([inst.peaks_c, jnp.ones((pad, n), dt)]),
    )


def stack_instances(instances: list[BBOBInstance]) -> BBOBInstance:
    """Stack instances along a leading batch axis (peaks padded to a common m)."""
    m_max = max(int(i.peaks_y.shape[0]) for i in instances)
    padded = [pad_instance(i, m_max) for i in instances]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *padded)


def evaluate_dynamic(inst: BBOBInstance, X: jnp.ndarray,
                     branch_fids: tuple = tuple(range(1, 25))) -> jnp.ndarray:
    """``evaluate`` with a *traced* function id (``inst.fid``).

    Dispatch is a ``lax.switch`` over ``branch_fids`` — pass the (static) set
    of fids actually present in a campaign to keep the compiled program small.
    Under ``vmap`` a batched switch index evaluates every branch and selects,
    so the per-point cost is len(branch_fids)×; with the per-campaign fid set
    that is the price of running heterogeneous functions in one program.
    """
    branch_fids = tuple(branch_fids)
    branches = [lambda i, x, f=f: _EVALS[f](i, x) for f in branch_fids]
    fid_tab = jnp.asarray(branch_fids, jnp.int32)
    match = fid_tab == inst.fid.astype(jnp.int32)
    idx = jnp.argmax(match)
    val = jax.lax.switch(idx, branches, inst, X) + inst.f_opt
    # a fid outside branch_fids would silently dispatch to branch 0 (argmax of
    # all-False is 0); the fid is traced so we cannot raise — poison instead
    return jnp.where(jnp.any(match), val, jnp.nan)


# ---------------------------------------------------------------------------
# separable-fid eval fusion (the sample kernel's fitness epilogue)
# ---------------------------------------------------------------------------

class SepCoeffs(NamedTuple):
    """Per-instance coefficients of a separable fid: f(X) = Σᵢ scaleᵢ·
    g(Xᵢ − shiftᵢ)² + f_opt with g selected by ``mode`` (0 = identity,
    1 = t_osz).  Pure data — rides kernel calls (SMEM scalars + two (n,)
    rows) and program-cache keys never see the values."""
    scale: jnp.ndarray     # (n,)
    shift: jnp.ndarray     # (n,) — x_opt
    f_opt: jnp.ndarray     # ()
    mode: jnp.ndarray      # () int32: 0 identity, 1 t_osz
    valid: jnp.ndarray     # () bool: fid ∈ branch_fids (else poison NaN)


def separable_coeffs(inst: BBOBInstance, branch_fids: tuple) -> SepCoeffs:
    """SepCoeffs for a (traced-fid) instance over a fusable static fid menu.

    The per-fid scale/mode tables are selected by the same argmax-match
    index ``evaluate_dynamic`` dispatches on, so a stacked campaign keeps
    its fid a row operand; a fid outside ``branch_fids`` poisons to NaN
    exactly like the dispatched path.
    """
    branch_fids = tuple(branch_fids)
    assert all(f in FUSABLE_FIDS for f in branch_fids), branch_fids
    n = inst.x_opt.shape[-1]
    dt = inst.x_opt.dtype
    scale_tab = {1: jnp.ones((n,), dt), 2: _ell_scale(n, dt)}
    mode_tab = {1: 0, 2: 1}
    fid_tab = jnp.asarray(branch_fids, jnp.int32)
    match = fid_tab == inst.fid.astype(jnp.int32)
    idx = jnp.argmax(match)
    return SepCoeffs(
        scale=jnp.stack([scale_tab[f] for f in branch_fids])[idx],
        shift=inst.x_opt,
        f_opt=inst.f_opt,
        mode=jnp.asarray([mode_tab[f] for f in branch_fids],
                         jnp.int32)[idx],
        valid=jnp.any(match))


def separable_eval(X: jnp.ndarray, sep: SepCoeffs) -> jnp.ndarray:
    """Evaluate a separable fid from its coefficients — bit-identical to the
    dispatched ``evaluate_dynamic`` on the same X (same elementwise chain,
    same last-axis reduce; ×1.0 and +0.0 are IEEE-exact)."""
    t = X - sep.shift[..., None, :]
    tg = jnp.where(sep.mode[..., None, None] == 1, t_osz(t), t)
    val = jnp.sum(sep.scale[..., None, :] * tg ** 2, -1) + sep.f_opt[..., None]
    return jnp.where(sep.valid[..., None], val, jnp.nan)


def eval_fusion_enabled() -> bool:
    """Env toggle (``REPRO_EVAL_FUSION=0`` disables) — read at TRACE time,
    like ``REPRO_KERNEL_IMPL``: export before the first engine call, and
    mind that cached programs keep the setting they were traced with (the
    engines' program-cache keys include it)."""
    return os.environ.get("REPRO_EVAL_FUSION", "1").strip() != "0"


def fusable_fitness(inst: BBOBInstance, branch_fids: tuple, fn):
    """Wrap a campaign fitness closure with its separable coefficients when
    the WHOLE static fid menu is fusable (and fusion is enabled) — the
    engines detect the ``.sep`` attribute and route sampling through the
    eval-fused kernels; any non-fusable fid in the menu, or the env kill
    switch, returns ``fn`` unchanged (two-program fallback)."""
    branch_fids = tuple(branch_fids)
    if (not branch_fids or not eval_fusion_enabled()
            or any(f not in FUSABLE_FIDS for f in branch_fids)):
        return fn
    from repro.core.eval_dispatch import FusableEval
    return FusableEval(fn, separable_coeffs(inst, branch_fids))


def evaluate_stacked(fid_array: jnp.ndarray, inst_params: BBOBInstance,
                     X: jnp.ndarray,
                     branch_fids: tuple = tuple(range(1, 25))) -> jnp.ndarray:
    """Batched campaign evaluation: one program over stacked instances.

    ``fid_array``: (B,) int32; ``inst_params``: BBOBInstance with (B, ...)
    leaves (see ``stack_instances``); ``X``: (B, batch, n).  Returns
    (B, batch) absolute fitness values.
    """
    def one(fid, inst, x):
        return evaluate_dynamic(inst._replace(fid=fid), x, branch_fids)
    return jax.vmap(one)(fid_array.astype(jnp.int32), inst_params, X)
