"""Batched serving engine: prefill + decode with sharded KV caches.

``make_serve_step`` / ``make_prefill`` are what the dry-run lowers for the
``prefill_32k`` / ``decode_32k`` / ``long_500k`` cells:

  * batch shards over the DP axes; KV heads over ``model`` (TP);
  * ``long_500k`` (global_batch=1) cannot absorb DP, so the KV *sequence*
    dim shards over ``data`` — split-K / flash-decoding-style attention whose
    softmax max/sum reductions become psums (SP — DESIGN.md §7);
  * greedy sampling on-device; the host loop batches requests and swaps
    finished sequences (continuous batching at the step granularity).

The engine is deliberately step-synchronous: one jitted ``decode_step`` per
token over the whole batch — the production idiom for TPU serving.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.models import lm


def make_prefill(cfg: ModelConfig, max_len: int,
                 mesh: Optional[jax.sharding.Mesh] = None):
    def prefill(params, batch):
        if mesh is not None:
            sharding.set_mesh(mesh)
        return lm.prefill(cfg, params, batch, max_len)
    return prefill


def make_serve_step(cfg: ModelConfig,
                    mesh: Optional[jax.sharding.Mesh] = None,
                    greedy: bool = True):
    """(params, cache, batch) → (next_token (B,1), logits, cache)."""
    def step(params, cache, batch):
        if mesh is not None:
            sharding.set_mesh(mesh)
        logits, cache = lm.decode_step(cfg, params, cache, batch)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, cache
    return step


def serve_shardings(cfg: ModelConfig, mesh, cache_abstract, batch: int):
    """NamedShardings for (cache,) under the serving layout."""
    specs = sharding.cache_specs(cache_abstract, mesh, batch)
    return jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s), specs,
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 32
    out: Optional[np.ndarray] = None


class Engine:
    """Minimal batched greedy engine for the examples (CPU-sized configs)."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 256):
        self.cfg, self.params, self.max_len = cfg, params, max_len
        self._prefill = jax.jit(
            lambda p, b: lm.prefill(cfg, p, b, max_len))
        self._step = jax.jit(make_serve_step(cfg))

    def generate(self, requests: List[Request]) -> List[Request]:
        cfg = self.cfg
        B = len(requests)
        S = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):                 # left-pad-free: right align
            toks[i, S - len(r.prompt):] = r.prompt
        batch = {"tokens": jnp.asarray(toks)}
        last_logits, cache = self._prefill(self.params, batch)
        nxt = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
        outs = [list() for _ in range(B)]
        n_steps = max(r.max_new_tokens for r in requests)
        for _ in range(n_steps):
            for i in range(B):
                outs[i].append(int(nxt[i, 0]))
            nxt, _, cache = self._step(self.params, cache,
                                       {"tokens": nxt})
        for i, r in enumerate(requests):
            r.out = np.asarray(outs[i][: r.max_new_tokens], np.int32)
        return requests
