"""Fleet supervision: health monitoring, fault injection, recovery.

The subsystem splits by dependency weight so chaos *plans* stay data:

* ``faults.py``  — ``FaultPlan`` / ``FaultEvent`` (stdlib only)
* ``health.py``  — per-island ALIVE/SUSPECT/DEAD detector (stdlib + obs)
* ``controller.py`` — ``FleetConfig``, the engine-level
  ``IslandSupervisor`` and the service-level ``FleetController``
  (imports jax and the service — loaded lazily here so
  ``from repro.fleet import FaultPlan`` stays light)
"""
from repro.fleet.faults import (CORRUPT, DELAY, KILL,          # noqa: F401
                                FaultEvent, FaultPlan)
from repro.fleet.health import (ALIVE, DEAD, SUSPECT,          # noqa: F401
                                FleetHealth, HealthConfig, IslandHealth)

_LAZY = ("FleetConfig", "FleetController", "IslandSupervisor",
         "occupancy_skew")


def __getattr__(name):
    if name in _LAZY:
        from repro.fleet import controller
        return getattr(controller, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = ["FaultEvent", "FaultPlan", "KILL", "DELAY", "CORRUPT",
           "FleetHealth", "HealthConfig", "IslandHealth",
           "ALIVE", "SUSPECT", "DEAD", *_LAZY]
