"""Deterministic fault injection: the ``FaultPlan`` chaos schedule.

A plan is a static list of ``FaultEvent``s, each pinned to a (island,
segment-boundary) coordinate, so a chaos run is exactly reproducible: the
same plan against the same request trace produces the same failures at
the same points of the schedule.  Three fault kinds:

* ``kill``    — the island dies at boundary ``b`` (its device state is
  considered lost); ``down_for`` boundaries later it may rejoin empty.
* ``delay``   — a host-side ``delay_s`` sleep is injected immediately
  before the island's next segment dispatch (models a slow worker; used
  to exercise the health deadline without killing anything).
* ``corrupt`` — the island's next boundary schedule pull returns a
  garbled (non-monotone) budget counter once; the supervisor's retry
  path must detect and re-pull.

This module is deliberately stdlib-only (no jax, no numpy): plans are
data.  Applying a fault — restoring a snapshot, sleeping, garbling a
pulled array — is the supervisor's job (fleet/controller.py).  The
zero-overhead contract is structural: engines hold no reference to this
module; a run without a supervisor pays a single host-side ``is None``
check per boundary and nothing else (pinned in tests/test_obs.py and
tests/test_fleet.py).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Tuple

KILL = "kill"
DELAY = "delay"
CORRUPT = "corrupt"
KINDS = (KILL, DELAY, CORRUPT)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: ``kind`` at (``island``, ``boundary``)."""

    kind: str
    island: int
    boundary: int
    down_for: int = 0        # kill: boundaries until rejoin (0 = never)
    delay_s: float = 0.0     # delay: injected host sleep before dispatch

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.island < 0 or self.boundary < 0:
            raise ValueError(f"fault coordinates must be >= 0: {self}")
        if self.kind == KILL and self.boundary == 0:
            # boundary 0 precedes the first snapshot-able state; a kill
            # there is indistinguishable from never starting the island
            raise ValueError("kill events start at boundary 1")
        if self.down_for < 0 or self.delay_s < 0:
            raise ValueError(f"negative fault magnitude: {self}")


class FaultPlan:
    """An immutable, indexable schedule of ``FaultEvent``s."""

    def __init__(self, events: Sequence[FaultEvent]):
        self.events: Tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: (e.boundary, e.island, e.kind)))
        self._kills: Dict[int, List[FaultEvent]] = {}
        self._delays: Dict[Tuple[int, int], float] = {}
        self._corrupts: Dict[Tuple[int, int], bool] = {}
        for ev in self.events:
            if ev.kind == KILL:
                self._kills.setdefault(ev.boundary, []).append(ev)
            elif ev.kind == DELAY:
                key = (ev.island, ev.boundary)
                self._delays[key] = self._delays.get(key, 0.0) + ev.delay_s
            else:
                self._corrupts[(ev.island, ev.boundary)] = True

    def __len__(self) -> int:
        return len(self.events)

    def __repr__(self) -> str:
        return f"FaultPlan({list(self.events)!r})"

    def kills_at(self, boundary: int) -> List[FaultEvent]:
        """Kill events due exactly at ``boundary`` (sorted by island)."""
        return self._kills.get(boundary, [])

    def kill_at(self, island: int, boundary: int) -> Optional[FaultEvent]:
        for ev in self._kills.get(boundary, []):
            if ev.island == island:
                return ev
        return None

    def delay(self, island: int, boundary: int) -> float:
        """Injected sleep (seconds) before this island's dispatch."""
        return self._delays.get((island, boundary), 0.0)

    def corrupts(self, island: int, boundary: int) -> bool:
        """True when this island's boundary pull must be garbled once."""
        return self._corrupts.get((island, boundary), False)

    def max_boundary(self) -> int:
        return max((e.boundary for e in self.events), default=0)

    # -- reproducible schedule generation -----------------------------------

    @classmethod
    def seeded(cls, seed: int, n_islands: int, *, kills: int = 1,
               delays: int = 0, corrupts: int = 0, horizon: int = 16,
               min_boundary: int = 1, down_for: int = 0,
               delay_s: float = 0.05) -> "FaultPlan":
        """A deterministic chaos schedule drawn from ``seed``: ``kills``
        kill events (at most one per island), plus optional delay/corrupt
        noise, all landing in ``[min_boundary, horizon]``."""
        if n_islands < 1:
            raise ValueError("need at least one island")
        lo = max(1, min_boundary)
        if horizon < lo:
            raise ValueError(f"horizon {horizon} < min boundary {lo}")
        rng = random.Random(seed)
        events: List[FaultEvent] = []
        victims = list(range(n_islands))
        rng.shuffle(victims)
        for i in victims[:kills]:
            events.append(FaultEvent(KILL, i, rng.randint(lo, horizon),
                                     down_for=down_for))
        for _ in range(delays):
            events.append(FaultEvent(DELAY, rng.randrange(n_islands),
                                     rng.randint(lo, horizon),
                                     delay_s=delay_s))
        for _ in range(corrupts):
            events.append(FaultEvent(CORRUPT, rng.randrange(n_islands),
                                     rng.randint(lo, horizon)))
        return cls(events)

    @classmethod
    def parse(cls, spec: str, *, down_for: int = 0) -> "FaultPlan":
        """Parse a CLI kill schedule: ``"island:boundary[:down_for],..."``
        — e.g. ``"0:3,1:5:4"`` kills island 0 at boundary 3 forever and
        island 1 at boundary 5 for 4 boundaries."""
        events = []
        for cell in spec.split(","):
            cell = cell.strip()
            if not cell:
                continue
            parts = [int(p) for p in cell.split(":")]
            if len(parts) not in (2, 3):
                raise ValueError(f"bad kill cell {cell!r} "
                                 "(want island:boundary[:down_for])")
            dfor = parts[2] if len(parts) == 3 else down_for
            events.append(FaultEvent(KILL, parts[0], parts[1],
                                     down_for=dfor))
        return cls(events)
