"""Per-island health tracking at segment boundaries.

The fleet's failure detector is a stale-lock-style watcher folded into the
one host sync the engines already pay: every boundary schedule pull is an
implicit heartbeat.  ``FleetHealth.observe`` grades each pull on two
axes —

* **deadline** — the pull's wall time.  A boundary pull is the only
  blocking wait on an island's device, so a pull that exceeds
  ``deadline_s`` means the island's running segment is wedged (or the
  device is gone).  One slow pull makes the island SUSPECT; ``retries``
  consecutive slow pulls make it DEAD.
* **progress** — the island's summed budget counters.  Counters that sit
  still for ``stall_boundaries`` boundaries while work is expected mean
  the island is burning schedule without evaluating (a stale lock on
  progress); that too is DEAD, with ``reason="stalled"``.

A *regressing* counter (fewer total evaluations than last observed) is
not graded here at all: budget counters are monotone by construction, so
a regress can only be a garbled read — the supervisor's pull wrapper
retries it (with ``backoff_s`` backoff) before the observation lands.

State transitions emit the ``fleet_island_state`` gauge (0=alive,
1=suspect, 2=dead).  The module needs nothing beyond the stdlib and the
dependency-free obs registry, so health logic is unit-testable with
synthetic observations — no devices, no engines.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro import obs

ALIVE = "alive"
SUSPECT = "suspect"
DEAD = "dead"
STATE_CODE = {ALIVE: 0, SUSPECT: 1, DEAD: 2}


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Failure-detector knobs (see FleetConfig for the user surface)."""

    deadline_s: float = 30.0     # boundary pull slower than this: suspect
    stall_boundaries: int = 3    # no-progress boundaries before dead
    retries: int = 2             # consecutive suspect pulls before dead
    backoff_s: float = 0.0       # sleep between garbled-pull re-reads


@dataclasses.dataclass
class IslandHealth:
    """One island's detector record."""

    state: str = ALIVE
    reason: str = ""             # why DEAD: killed | deadline | stalled
    last_fev: float = 0.0        # last observed summed budget counter
    stalled_for: int = 0         # consecutive no-progress boundaries
    slow_pulls: int = 0          # consecutive over-deadline pulls
    down_since: Optional[int] = None


class FleetHealth:
    """The per-island state machine; islands materialize on first touch
    so one instance serves 1-island engine runs and N-island services."""

    def __init__(self, cfg: Optional[HealthConfig] = None):
        self.cfg = cfg or HealthConfig()
        self._islands: Dict[int, IslandHealth] = {}

    def island(self, i: int) -> IslandHealth:
        if i not in self._islands:
            self._islands[i] = IslandHealth()
            self._emit(i)
        return self._islands[i]

    def _emit(self, i: int):
        rec = self._islands[i]
        obs.metrics().gauge("fleet_island_state", island=i).set(
            float(STATE_CODE[rec.state]))

    def _set(self, i: int, state: str, boundary: int, reason: str = ""):
        rec = self.island(i)
        if rec.state == state:
            return
        rec.state = state
        rec.reason = reason if state == DEAD else ""
        rec.down_since = boundary if state == DEAD else None
        self._emit(i)
        # the detection timestamp of a kill→detect→restore recovery trace:
        # an instantaneous marker on the island's lane track
        obs.tracer().event("health", island=i, state=state,
                           boundary=boundary, reason=reason)

    # -- observations -------------------------------------------------------

    def observe_progress(self, i: int, boundary: int, progressed: bool,
                         wall_s: float, expect_progress: bool = True) -> str:
        """Grade one boundary with an EXPLICIT progress verdict; returns the
        island's new state.  This is the detector core: callers that can
        attribute progress precisely — the service-level controller knows
        per-row, per-job feval deltas and which rows were actually
        dispatched — pass their own ``progressed``/``expect_progress``
        booleans, so job-level pathology (a quarantined poison row, a
        retired slot being re-used) never reads as island stall.  The
        engine-level ``observe`` wraps this with the summed-counter
        watermark."""
        rec = self.island(i)
        if rec.state == DEAD:
            return DEAD
        if wall_s > self.cfg.deadline_s:
            rec.slow_pulls += 1
            if rec.slow_pulls > self.cfg.retries:
                self._set(i, DEAD, boundary, reason="deadline")
                return DEAD
            self._set(i, SUSPECT, boundary)
        else:
            rec.slow_pulls = 0
        if expect_progress and not progressed:
            rec.stalled_for += 1
            if rec.stalled_for >= self.cfg.stall_boundaries:
                self._set(i, DEAD, boundary, reason="stalled")
                return DEAD
            if rec.state == ALIVE:
                self._set(i, SUSPECT, boundary)
        else:
            rec.stalled_for = 0
            if rec.state == SUSPECT and rec.slow_pulls == 0:
                self._set(i, ALIVE, boundary)
        return rec.state

    def observe(self, i: int, boundary: int, fev_sum: float, wall_s: float,
                expect_progress: bool = True) -> str:
        """Grade one boundary pull; returns the island's new state.
        Progress is the summed budget counter advancing past its watermark
        (the single-tenant engine view — one island, one monotone sum)."""
        rec = self.island(i)
        state = self.observe_progress(i, boundary, fev_sum > rec.last_fev,
                                      wall_s, expect_progress=expect_progress)
        if state != DEAD:
            rec.last_fev = max(rec.last_fev, fev_sum)
        return state

    def last_fev(self, i: int) -> float:
        return self.island(i).last_fev

    def reset_progress(self, i: int, fev_sum: float):
        """Rebase the progress watermark after a snapshot restore (the
        restored counters are legitimately behind the last observation)."""
        rec = self.island(i)
        rec.last_fev = float(fev_sum)
        rec.stalled_for = 0

    # -- verdicts (the controller applies them) -----------------------------

    def mark_dead(self, i: int, boundary: int, reason: str):
        self._set(i, DEAD, boundary, reason=reason)

    def revive(self, i: int, boundary: int):
        rec = self.island(i)
        rec.slow_pulls = 0
        rec.stalled_for = 0
        rec.last_fev = 0.0
        self._set(i, ALIVE, boundary)

    def state(self, i: int) -> str:
        return self.island(i).state

    def is_dead(self, i: int) -> bool:
        return self.island(i).state == DEAD

    def dead_islands(self) -> List[int]:
        return sorted(i for i, r in self._islands.items() if r.state == DEAD)
